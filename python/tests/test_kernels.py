"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Integer paths (int8 matmul, score tiles) must be bit-exact; float paths
(softmax state, accumulators) are checked to tight f32 tolerance. Hypothesis
sweeps shapes/seeds; interpret-mode pallas is slow, so example counts are
kept moderate but the sweeps cover the dimensions that matter (dh, tiling,
scale magnitudes, adversarial score ranges).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import block_attn, flex_index, ref
from compile.kernels.int8_matmul import int8_matmul

jax.config.update("jax_enable_x64", False)

RNG = np.random.default_rng


def rand_i8(rng, shape):
    return jnp.asarray(rng.integers(-127, 128, size=shape, dtype=np.int64),
                       dtype=jnp.int8)


# ---------------------------------------------------------------------------
# int8 matmul (Hybrid MPU contract)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [
    (128, 64, 128), (128, 256, 128), (128, 256, 256),
    (128, 768, 2048), (64, 64, 64), (128, 2048, 768),
])
def test_int8_matmul_exact(m, k, n):
    rng = RNG(m * 7 + k * 13 + n)
    a, b = rand_i8(rng, (m, k)), rand_i8(rng, (k, n))
    got = int8_matmul(a, b)
    want = ref.int8_matmul_ref(a, b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_int8_matmul_extremes():
    """Saturated operands: max-magnitude accumulation must not overflow i32
    for our K ranges (127*127*2304 < 2^31)."""
    k = 2304
    a = jnp.full((128, k), 127, jnp.int8)
    b = jnp.full((k, 128), 127, jnp.int8)
    got = int8_matmul(a, b)
    assert int(got[0, 0]) == 127 * 127 * k
    b2 = jnp.full((k, 128), -127, jnp.int8)
    got2 = int8_matmul(a, b2)
    assert int(got2[0, 0]) == -127 * 127 * k


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([64, 128, 192]),
       st.sampled_from([128, 256]))
def test_int8_matmul_hypothesis(seed, k, n):
    rng = RNG(seed)
    a, b = rand_i8(rng, (128, k)), rand_i8(rng, (k, n))
    np.testing.assert_array_equal(
        np.asarray(int8_matmul(a, b)), np.asarray(ref.int8_matmul_ref(a, b)))


# ---------------------------------------------------------------------------
# Quantization contract
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.floats(1e-3, 1e3))
def test_quantize_roundtrip_bound(seed, mag):
    rng = RNG(seed)
    x = jnp.asarray(rng.normal(0, mag, size=(64, 32)), jnp.float32)
    q, s = ref.quantize_sym(x)
    err = np.abs(np.asarray(q, np.float32) * float(s) - np.asarray(x))
    assert err.max() <= float(s) * 0.5 + 1e-6
    assert np.abs(np.asarray(q)).max() <= 127


def test_quantize_zero_input():
    q, s = ref.quantize_sym(jnp.zeros((4, 4), jnp.float32))
    assert float(s) > 0 and np.all(np.asarray(q) == 0)


# ---------------------------------------------------------------------------
# SAU block step
# ---------------------------------------------------------------------------

def _rand_attn_inputs(seed, dh=64, b=128):
    rng = RNG(seed)
    q, k, v = (rand_i8(rng, (b, dh)) for _ in range(3))
    qs, ks, vs = (float(rng.uniform(1e-3, 0.1)) for _ in range(3))
    m = jnp.full((b,), -1e30, jnp.float32)
    l = jnp.zeros((b,), jnp.float32)
    acc = jnp.zeros((b, dh), jnp.float32)
    return q, qs, k, ks, v, vs, m, l, acc


@pytest.mark.parametrize("dh", [64, 128])
@pytest.mark.parametrize("diag", [0.0, 1.0])
def test_attn_block_step_matches_ref(dh, diag):
    q, qs, k, ks, v, vs, m, l, acc = _rand_attn_inputs(42, dh)
    got = block_attn.attn_block_step(q, qs, k, ks, v, vs, m, l, acc, diag)
    want = ref.attn_block_step_ref(q, qs, k, ks, v, vs, m, l, acc,
                                   jnp.int32(int(diag)))
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=1e-5)


def test_attn_block_step_chained_state():
    """State threading across three kv blocks equals the ref fold."""
    q, qs, _, _, _, _, m, l, acc = _rand_attn_inputs(7)
    mr, lr, accr = m, l, acc
    for seed in (1, 2, 3):
        rng = RNG(seed)
        k, v = rand_i8(rng, (128, 64)), rand_i8(rng, (128, 64))
        ks, vs = 0.03, 0.05
        m, l, acc = block_attn.attn_block_step(q, qs, k, ks, v, vs, m, l, acc, 0.0)
        mr, lr, accr = ref.attn_block_step_ref(q, qs, k, ks, v, vs, mr, lr,
                                               accr, jnp.int32(0))
    np.testing.assert_allclose(np.asarray(acc), np.asarray(accr), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(l), np.asarray(lr), rtol=1e-5)


def test_attn_merge_order_independence():
    """The online-softmax merge is order-independent in exact arithmetic —
    the paper's block-major (out of query order) schedule relies on this.
    Under W8A8 the P-tile is requantized against the *running* max, so
    permuted folds differ by bounded quantization noise (<= ~0.5/127 per
    element before accumulation); the coordinator always uses ascending
    block order, making results deterministic in practice. We assert
    agreement within the quantization-noise bound."""
    q, qs, _, _, _, _, m0, l0, acc0 = _rand_attn_inputs(11)
    blocks = []
    for seed in range(4):
        rng = RNG(100 + seed)
        blocks.append((rand_i8(rng, (128, 64)), 0.02 + 0.01 * seed,
                       rand_i8(rng, (128, 64)), 0.04))

    def fold(order):
        m, l, acc = m0, l0, acc0
        for i in order:
            k, ks, v, vs = blocks[i]
            m, l, acc = block_attn.attn_block_step(q, qs, k, ks, v, vs, m, l,
                                                   acc, 0.0)
        return block_attn.attn_finalize(l, acc)

    a = fold([0, 1, 2, 3])
    b = fold([3, 1, 0, 2])
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=0.05,
                               atol=0.1)


def test_attn_block_batch_matches_loop():
    js = 8
    qs_l, ks_l, vs_l = [], [], []
    q_l, k_l, v_l, m_l, l_l, a_l, d_l = [], [], [], [], [], [], []
    for j in range(js):
        q, qs, k, ks, v, vs, m, l, acc = _rand_attn_inputs(200 + j)
        q_l.append(q); k_l.append(k); v_l.append(v)
        qs_l.append(qs); ks_l.append(ks); vs_l.append(vs)
        m_l.append(m); l_l.append(l); a_l.append(acc)
        d_l.append(float(j % 2))
    batched = block_attn.attn_block_batch(
        jnp.stack(q_l), jnp.asarray(qs_l, jnp.float32),
        jnp.stack(k_l), jnp.asarray(ks_l, jnp.float32),
        jnp.stack(v_l), jnp.asarray(vs_l, jnp.float32),
        jnp.stack(m_l), jnp.stack(l_l), jnp.stack(a_l),
        jnp.asarray(d_l, jnp.float32))
    for j in range(js):
        single = block_attn.attn_block_step(
            q_l[j], qs_l[j], k_l[j], ks_l[j], v_l[j], vs_l[j],
            m_l[j], l_l[j], a_l[j], d_l[j])
        for g, w in zip((batched[0][j], batched[1][j], batched[2][j]), single):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       rtol=1e-5, atol=1e-5)


def test_diag_mask_blocks_future():
    """With the diagonal mask on, future columns contribute nothing."""
    q, qs, k, ks, v, vs, m, l, acc = _rand_attn_inputs(5)
    m1, l1, _ = block_attn.attn_block_step(q, qs, k, ks, v, vs, m, l, acc, 1.0)
    # row 0 sees only column 0 -> l == exp(0) == 1 exactly (m == s00).
    np.testing.assert_allclose(float(l1[0]), 1.0, rtol=1e-6)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_attn_block_step_hypothesis(seed):
    q, qs, k, ks, v, vs, m, l, acc = _rand_attn_inputs(seed)
    got = block_attn.attn_block_step(q, qs, k, ks, v, vs, m, l, acc, 0.0)
    want = ref.attn_block_step_ref(q, qs, k, ks, v, vs, m, l, acc, jnp.int32(0))
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-4,
                                   atol=1e-4)


# ---------------------------------------------------------------------------
# SIGU phases
# ---------------------------------------------------------------------------

def _rand_index_inputs(seed, nblocks=4, dh=64):
    rng = RNG(seed)
    qhat = rand_i8(rng, (128, dh))
    kblks = [rand_i8(rng, (128, dh)) for _ in range(nblocks)]
    return qhat, float(rng.uniform(0.01, 0.05)), kblks, \
        float(rng.uniform(0.01, 0.05))


def test_index_phase_a_matches_ref():
    qhat, qs, kblks, ks = _rand_index_inputs(3)
    m = jnp.full((128,), -1e30, jnp.float32)
    l = jnp.zeros((128,), jnp.float32)
    mr, lr = m, l
    for kb in kblks:
        m, l = flex_index.index_phase_a(qhat, qs, kb, ks, m, l)
        mr, lr = ref.index_phase_a_ref(qhat, qs, kb, ks, mr, lr)
    np.testing.assert_allclose(np.asarray(m), np.asarray(mr), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(l), np.asarray(lr), rtol=1e-5)


def test_index_phase_b_matches_ref():
    qhat, qs, kblks, ks = _rand_index_inputs(9)
    m = jnp.full((128,), -1e30, jnp.float32)
    l = jnp.zeros((128,), jnp.float32)
    for kb in kblks:
        m, l = ref.index_phase_a_ref(qhat, qs, kb, ks, m, l)
    for kb in kblks:
        stats = flex_index.index_phase_b(qhat, qs, kb, ks, m, l)
        vw, sw, uw = ref.index_phase_b_ref(qhat, qs, kb, ks, m, l)
        np.testing.assert_allclose(float(stats[0]), float(vw), rtol=1e-5)
        np.testing.assert_allclose(float(stats[1]), float(sw), rtol=1e-5)
        np.testing.assert_allclose(float(stats[2]), float(uw), rtol=1e-4,
                                   atol=1e-6)


def test_index_vsum_is_probability_mass():
    """Sum of vsum over all key blocks == number of query rows (each row's
    softmax sums to 1)."""
    qhat, qs, kblks, ks = _rand_index_inputs(21, nblocks=6)
    m = jnp.full((128,), -1e30, jnp.float32)
    l = jnp.zeros((128,), jnp.float32)
    for kb in kblks:
        m, l = ref.index_phase_a_ref(qhat, qs, kb, ks, m, l)
    total = 0.0
    for kb in kblks:
        v, _, _ = ref.index_phase_b_ref(qhat, qs, kb, ks, m, l)
        total += float(v)
    np.testing.assert_allclose(total, 128.0, rtol=1e-4)


def test_fused_index_scores_matches_phases():
    """The single-pallas_call grid-streamed SIGU == phase A then phase B."""
    qhat, qs, kblks, ks = _rand_index_inputs(33, nblocks=4)
    kfull = jnp.concatenate(kblks, axis=0)
    v_f, slo_f, sup_f = flex_index.fused_index_scores(qhat, qs, kfull, ks)
    m = jnp.full((128,), -1e30, jnp.float32)
    l = jnp.zeros((128,), jnp.float32)
    for kb in kblks:
        m, l = ref.index_phase_a_ref(qhat, qs, kb, ks, m, l)
    for i, kb in enumerate(kblks):
        v, slo, sup = ref.index_phase_b_ref(qhat, qs, kb, ks, m, l)
        np.testing.assert_allclose(float(v_f[i]), float(v), rtol=1e-4)
        np.testing.assert_allclose(float(slo_f[i]), float(slo), rtol=1e-4)
        np.testing.assert_allclose(float(sup_f[i]), float(sup), rtol=1e-3,
                                   atol=1e-6)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([2, 3, 5]))
def test_fused_index_scores_hypothesis(seed, nblocks):
    qhat, qs, kblks, ks = _rand_index_inputs(seed, nblocks=nblocks)
    kfull = jnp.concatenate(kblks, axis=0)
    v_f, slo_f, sup_f = flex_index.fused_index_scores(qhat, qs, kfull, ks)
    np.testing.assert_allclose(float(jnp.sum(v_f)), 128.0, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(v_f),
                               np.asarray(slo_f) + np.asarray(sup_f),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# JSD / pooling oracles (consumed by Rust; sanity-check their math here)
# ---------------------------------------------------------------------------

def test_jsd_properties():
    p = jnp.asarray([0.25, 0.25, 0.25, 0.25])
    q = jnp.asarray([0.25, 0.25, 0.25, 0.25])
    assert float(ref.jsd_ref(p, q)) < 1e-9
    r = jnp.asarray([1.0, 0.0, 0.0, 0.0])
    s = jnp.asarray([0.0, 1.0, 0.0, 0.0])
    # JSD is bounded by ln 2 and symmetric.
    np.testing.assert_allclose(float(ref.jsd_ref(r, s)), float(np.log(2)),
                               rtol=1e-5)
    np.testing.assert_allclose(float(ref.jsd_ref(r, s)),
                               float(ref.jsd_ref(s, r)), rtol=1e-6)


def test_block_pool():
    x = jnp.arange(256 * 4, dtype=jnp.float32).reshape(256, 4)
    p = ref.block_pool_ref(x)
    assert p.shape == (2, 4)
    np.testing.assert_allclose(np.asarray(p[0]),
                               np.asarray(jnp.mean(x[:128], axis=0)))


def test_pooled_attention_causal_mask():
    rng = RNG(1)
    qp = jnp.asarray(rng.normal(size=(4, 64)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(4, 64)), jnp.float32)
    a = ref.pooled_attention_ref(qp, kp, causal=True)
    # row 0 can only attend to block 0.
    np.testing.assert_allclose(float(a[0, 0]), 1.0, rtol=1e-6)
    assert float(jnp.sum(a[0, 1:])) < 1e-6
