"""L2 model entry points vs ref oracles + shape checks for every artifact."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.configs import TINY, SMALL100M
from compile.kernels import ref

RNG = np.random.default_rng


def rand_i8(rng, shape):
    return jnp.asarray(rng.integers(-127, 128, size=shape, dtype=np.int64),
                       dtype=jnp.int8)


def _qkv_inputs(cfg, seed=0):
    rng = RNG(seed)
    x = jnp.asarray(rng.normal(0, 1, (model.B, cfg.d_model)), jnp.float32)
    g = jnp.asarray(rng.uniform(0.5, 1.5, (cfg.d_model,)), jnp.float32)
    wq = rand_i8(rng, (cfg.d_model, cfg.q_dim))
    wk = rand_i8(rng, (cfg.d_model, cfg.kv_dim))
    wv = rand_i8(rng, (cfg.d_model, cfg.kv_dim))
    sq, sk, sv = 0.01, 0.012, 0.009
    return x, g, wq, sq, wk, sk, wv, sv, jnp.int32(256)


@pytest.mark.parametrize("cfg", [TINY], ids=lambda c: c.name)
def test_qkv_chunk_matches_ref(cfg):
    args = _qkv_inputs(cfg)
    got = model.qkv_chunk(cfg)(*args)
    want = ref.qkv_chunk_ref(args[0], args[1], args[2], args[3], args[4],
                             args[5], args[6], args[7], args[8], cfg)
    names = ["q_i8", "qs", "k_i8", "ks", "v_i8", "vs", "qpool", "kpool"]
    for n, g, w in zip(names, got, want):
        if g.dtype == jnp.int8:
            # rounding at the int8 boundary can differ by 1 ulp of scale when
            # the f32 matmul order differs; require 99.9% exact, rest +/-1.
            diff = np.abs(np.asarray(g, np.int32) - np.asarray(w, np.int32))
            assert diff.max() <= 1, n
            assert (diff == 0).mean() > 0.995, n
        else:
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       rtol=2e-4, atol=2e-4, err_msg=n)


@pytest.mark.parametrize("cfg", [TINY], ids=lambda c: c.name)
def test_qkv_chunk_shapes(cfg):
    got = model.qkv_chunk(cfg)(*_qkv_inputs(cfg))
    assert got[0].shape == (cfg.n_heads, model.B, cfg.d_head)
    assert got[2].shape == (cfg.n_kv_heads, model.B, cfg.d_head)
    assert got[4].shape == (cfg.n_kv_heads, model.B, cfg.d_head)
    assert got[6].shape == (cfg.n_heads, cfg.d_head)
    assert got[7].shape == (cfg.n_kv_heads, cfg.d_head)


def test_rope_positions_differ():
    """RoPE must inject absolute positions: same x at different pos0 gives
    different q/k."""
    cfg = TINY
    args = list(_qkv_inputs(cfg))
    out0 = model.qkv_chunk(cfg)(*args)
    args[8] = jnp.int32(4096)
    out1 = model.qkv_chunk(cfg)(*args)
    assert not np.array_equal(np.asarray(out0[0]), np.asarray(out1[0]))


def test_ffn_chunk_matches_ref():
    cfg = TINY
    rng = RNG(4)
    x = jnp.asarray(rng.normal(0, 1, (model.B, cfg.d_model)), jnp.float32)
    g = jnp.asarray(rng.uniform(0.5, 1.5, (cfg.d_model,)), jnp.float32)
    wg = rand_i8(rng, (cfg.d_model, cfg.d_ffn))
    wu = rand_i8(rng, (cfg.d_model, cfg.d_ffn))
    wd = rand_i8(rng, (cfg.d_ffn, cfg.d_model))
    got = model.ffn_chunk(cfg)(x, g, wg, 0.01, wu, 0.01, wd, 0.01)
    want = ref.ffn_chunk_ref(x, g, wg, 0.01, wu, 0.01, wd, 0.01, cfg.rms_eps)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3,
                               atol=1e-3)


def test_o_proj_chunk_matches_ref():
    cfg = TINY
    rng = RNG(5)
    attn = jnp.asarray(rng.normal(0, 1, (model.B, cfg.q_dim)), jnp.float32)
    wo = rand_i8(rng, (cfg.q_dim, cfg.d_model))
    resid = jnp.asarray(rng.normal(0, 1, (model.B, cfg.d_model)), jnp.float32)
    got = model.o_proj_chunk(cfg)(attn, wo, 0.01, resid)
    want = ref.o_proj_chunk_ref(attn, wo, 0.01, resid)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4,
                               atol=1e-4)


def test_logits_chunk_matches_ref():
    cfg = TINY
    rng = RNG(6)
    x = jnp.asarray(rng.normal(0, 1, (model.B, cfg.d_model)), jnp.float32)
    g = jnp.ones((cfg.d_model,), jnp.float32)
    wlm = rand_i8(rng, (cfg.d_model, cfg.vocab))
    got = model.logits_chunk(cfg)(x, g, wlm, 0.02)
    want = ref.logits_chunk_ref(x, g, wlm, 0.02, cfg.rms_eps)
    assert got.shape == (model.B, cfg.vocab)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4,
                               atol=1e-4)


def test_entry_specs_cover_both_configs():
    for cfg in (TINY, SMALL100M):
        specs = model.entry_specs(cfg)
        assert set(specs) == {
            "qkv_chunk", "index_phase_a", "index_phase_b", "attn_block_step",
            "attn_block_batch", "o_proj_chunk", "ffn_chunk", "logits_chunk"}
        for name, (fn, args) in specs.items():
            # every arg spec must be concrete (no None dims)
            for a in args:
                assert all(isinstance(d, int) and d > 0 for d in a.shape), name


def test_dense_attention_w8a8_composition():
    """Full 2-block causal dense attention out of block steps equals a direct
    (non-streamed) W8A8 computation."""
    rng = RNG(7)
    S, dh = 256, 64
    q = rand_i8(rng, (S, dh))
    k = rand_i8(rng, (S, dh))
    v = rand_i8(rng, (S, dh))
    qs, ks, vs = 0.02, 0.02, 0.03
    got = ref.dense_attention_w8a8_ref(q, qs, k, ks, v, vs)
    # direct: full masked softmax, P requantized per 128-col tile like the
    # streamed version (scale 1/127 is global so tiling does not matter).
    s = np.asarray(ref.int8_matmul_ref(q, k.T), np.float32) * (qs * ks / np.sqrt(dh))
    mask = np.triu(np.ones((S, S), bool), 1)
    s[mask] = -1e30
    p = np.exp(s - s.max(-1, keepdims=True))
    li = p.sum(-1, keepdims=True)
    p_i8 = np.clip(np.round(p * 127.0), -127, 127)
    out = (p_i8 @ np.asarray(v, np.float32)) * (vs / 127.0) / li
    # The streamed path requantizes each P tile against the *running* max,
    # the direct path against the final max — bounded quantization noise
    # (same effect as in test_attn_merge_order_independence).
    np.testing.assert_allclose(np.asarray(got), out, rtol=0.05, atol=0.15)
