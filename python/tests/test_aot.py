"""AOT artifact generation: HLO text round-trips and the manifest is sound."""

import os

import pytest

from compile import aot, model
from compile.configs import TINY


@pytest.fixture(scope="module")
def artifacts(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    aot.lower_all(out, configs=[TINY], verbose=False)
    return out


def test_all_entries_lowered(artifacts):
    files = set(os.listdir(artifacts))
    for name in model.entry_specs(TINY):
        assert f"tiny__{name}.hlo.txt" in files, name
    assert "manifest.txt" in files


def test_hlo_text_is_parseable_hlo(artifacts):
    """Text must be an HloModule (the format xla_extension 0.5.1 parses),
    not StableHLO/MLIR."""
    for name in model.entry_specs(TINY):
        with open(os.path.join(artifacts, f"tiny__{name}.hlo.txt")) as f:
            text = f.read()
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name
        # The lowering must preserve the return_tuple=True convention the
        # Rust loader relies on (root is a tuple).
        assert "tuple(" in text or "(f32[" in text or ") tuple" in text, name


def test_manifest_schema(artifacts):
    with open(os.path.join(artifacts, "manifest.txt")) as f:
        lines = [ln.strip() for ln in f if ln.strip()]
    kinds = {ln.split()[0] for ln in lines}
    assert kinds <= {"cfg", "artifact", "in", "out"}
    arts = [ln for ln in lines if ln.startswith("artifact ")]
    assert len(arts) == len(model.entry_specs(TINY))
    # every artifact line is followed by at least one in and one out line
    for i, ln in enumerate(lines):
        if ln.startswith("artifact "):
            rest = lines[i + 1:]
            assert rest and rest[0].startswith("in "), ln


def test_manifest_records_config_dims(artifacts):
    with open(os.path.join(artifacts, "manifest.txt")) as f:
        content = f.read()
    assert f"cfg tiny d_model={TINY.d_model}" in content
    assert f"n_heads={TINY.n_heads}" in content
    assert f"sau_batch={model.SAU_BATCH}" in content


def test_attn_block_step_artifact_shapes(artifacts):
    """Spot-check that the lowered HLO's ENTRY signature matches the spec
    (int8 q/k/v of [128, dh], f32 state)."""
    with open(os.path.join(artifacts, "tiny__attn_block_step.hlo.txt")) as f:
        text = f.read()
    assert f"s8[128,{TINY.d_head}]" in text
    assert "f32[128]" in text
