"""Model configurations shared by the L2 model, the AOT driver and tests.

These mirror `rust/src/config/model.rs` — the Rust side re-declares the same
constants and the AOT manifest records them so any drift is caught at
artifact-load time.

Only the configs we run *functionally* on the CPU PJRT backend get AOT
artifacts (tiny + small100m). The paper-scale configs (Llama-3.2-1B/3B,
Qwen2.5-1B) exist on the Rust side for the cycle-level simulator and the GPU
cost model, where only shapes matter.
"""

from dataclasses import dataclass

BLOCK = 128  # token block size B (also the FlexPrefill block granularity)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int      # D
    n_heads: int      # H (query heads)
    n_kv_heads: int   # Hk (GQA)
    d_head: int       # dh
    d_ffn: int        # F
    n_layers: int
    vocab: int        # byte-level tokenizer -> 256 (+ padding to 256)
    rope_theta: float = 10000.0
    rms_eps: float = 1e-5

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.d_head

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.d_head

    @property
    def group_size(self) -> int:
        return self.n_heads // self.n_kv_heads

    def params(self) -> int:
        """Approximate parameter count (weights only, no biases)."""
        attn = self.d_model * (self.q_dim + 2 * self.kv_dim) + self.q_dim * self.d_model
        ffn = 3 * self.d_model * self.d_ffn
        per_layer = attn + ffn + 2 * self.d_model  # + rmsnorm gains
        embed = self.vocab * self.d_model
        head = self.d_model * self.vocab
        return self.n_layers * per_layer + embed + head + self.d_model


# Functional configs (AOT artifacts are generated for these).
TINY = ModelConfig("tiny", d_model=256, n_heads=4, n_kv_heads=2, d_head=64,
                   d_ffn=768, n_layers=2, vocab=256)
SMALL100M = ModelConfig("small100m", d_model=768, n_heads=12, n_kv_heads=4,
                        d_head=64, d_ffn=2048, n_layers=16, vocab=256)

AOT_CONFIGS = [TINY, SMALL100M]

# FlexPrefill hyper-parameters (paper / Flex-Prefill defaults).
TAU = 0.1     # JSD threshold for pattern selection
GAMMA = 0.9   # cumulative-attention coverage budget
