"""L1 Pallas kernel: fused online-softmax sparse-attention block step (SAU).

This is the compute hot-spot of the paper's Sparse Attention Unit: for one
(query-block, KV-block) job the SAU computes a 128x128 score tile on the
Hybrid MPU, streams softmax normalization through the SFU, and immediately
applies the attention weights to the Value tile, accumulating into the keyed
accumulator — never materializing anything larger than one tile.

Here the same fusion is one Pallas kernel: score matmul (int8->int32),
running-max/denominator update, probability requantization to int8 (the W8A8
contract: P is quantized with fixed scale 1/127), P@V (int8->int32), and the
rescale-and-accumulate into (m, l, acc). The (m, l, acc) triple is the keyed
accumulator entry — the Rust coordinator owns one per (head, query-block) and
threads it through successive jobs in KV-block-major order, exactly like the
paper's banked accumulator memory.

The update is an order-independent merge, which is what makes the paper's
block-major schedule legal; `python/tests/test_kernels.py` checks permutation
invariance and `rust/tests/proptests.rs` re-checks it on the Rust side.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .int8_matmul import exact_int8_dot

NEG_INF = -1e30


def _attn_step_kernel(q_ref, k_ref, v_ref, scal_ref, m_ref, l_ref, acc_ref,
                      mo_ref, lo_ref, accu_ref):
    """q,k,v: [B,dh] int8; scal: [4] f32 = (qs, ks, vs, diag_flag);
    m,l: [B] f32; acc: [B,dh] f32. Outputs m', l', acc'."""
    b, dh = q_ref.shape
    qs = scal_ref[0]
    ks = scal_ref[1]
    vs = scal_ref[2]
    diag = scal_ref[3]
    inv_sqrt_d = 1.0 / jnp.sqrt(jnp.float32(dh))
    # Hybrid-MPU score tile: exact int8 matmul (nibble-plane form).
    s_i32 = exact_int8_dot(q_ref[...], k_ref[...].T)
    s = s_i32.astype(jnp.float32) * (qs * ks * inv_sqrt_d)
    rows = jax.lax.broadcasted_iota(jnp.int32, (b, b), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (b, b), 1)
    s = jnp.where((diag > 0.5) & (cols > rows), NEG_INF, s)
    m = m_ref[...]
    l = l_ref[...]
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    corr = jnp.exp(m - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l * corr + jnp.sum(p, axis=-1)
    # W8A8: requantize probabilities to int8 (fixed scale 1/127) before P@V.
    p_i8 = jnp.clip(jnp.round(p * 127.0), -127, 127).astype(jnp.int8)
    pv = exact_int8_dot(p_i8, v_ref[...])
    acc_new = acc_ref[...] * corr[:, None] + pv.astype(jnp.float32) * (vs / 127.0)
    mo_ref[...] = m_new
    lo_ref[...] = l_new
    accu_ref[...] = acc_new


@jax.jit
def attn_block_step(q_i8, qs, k_i8, ks, v_i8, vs, m, l, acc, diag_flag):
    """One SAU job. Shapes: q/k/v [B,dh] i8, m/l [B] f32, acc [B,dh] f32.

    qs/ks/vs: scalar f32 chunk scales; diag_flag: scalar (1.0 => apply the
    intra-block causal mask, i.e. this KV block IS the query block).
    Returns (m', l', acc').
    """
    b, dh = q_i8.shape
    scal = jnp.stack([jnp.float32(qs), jnp.float32(ks), jnp.float32(vs),
                      jnp.float32(diag_flag)])
    return pl.pallas_call(
        _attn_step_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b, dh), jnp.float32),
        ),
        interpret=True,
    )(q_i8, k_i8, v_i8, scal, m, l, acc)


@jax.jit
def attn_block_batch(q_i8, qs, k_i8, ks, v_i8, vs, m, l, acc, diag_flags):
    """Batched SAU jobs: leading dim J (the coordinator pads job groups to a
    fixed J so the artifact shape stays static). q/k/v: [J,B,dh] i8;
    scales [J] f32; m/l [J,B]; acc [J,B,dh]; diag_flags [J] f32."""
    return jax.vmap(attn_block_step)(q_i8, qs, k_i8, ks, v_i8, vs, m, l, acc,
                                     diag_flags)


def attn_finalize(l, acc):
    """Final normalization once all of a (head, query-block)'s jobs ran."""
    return acc / jnp.maximum(l, 1e-8)[:, None]
