"""Pure-jnp reference oracles for every Pallas kernel and model entry point.

This file is the numeric CONTRACT between the three layers:

  * the Pallas kernels (`flex_index.py`, `block_attn.py`, `int8_matmul.py`)
    must match these functions bit-for-bit (integer paths) or to float
    tolerance (f32 paths) — enforced by `python/tests/test_kernels.py`;
  * the Rust reference implementation (`rust/src/tensor`, `rust/src/quant`,
    `rust/src/flexprefill`) re-implements the same definitions — enforced by
    `rust/tests/runtime_integration.rs`, which runs the AOT artifacts through
    PJRT and compares with Rust math.

Shared definitions
------------------
quantize_sym(x):  s = max(|x|)/127 (>= 1e-8);  q = clip(round(x/s), -127, 127)
int8 matmul:      C = A_i8 @ B_i8 accumulated in int32; dequant C*(sa*sb)
RMSNorm:          x * rsqrt(mean(x^2) + eps) * g        (f32)
RoPE:             llama-style half-rotation, theta=1e4   (f32, pre-quant)
attention scale:  1/sqrt(d_head)
online softmax:   (m, l, acc) running state, order-independent merge
W8A8 attention:   scores int8xint8->int32; P tile requantized to int8
                  (p_q = round(P*127)); P@V int8xint8->int32, dequant vs/127
"""

import jax
import jax.numpy as jnp

SCALE_EPS = 1e-8


# ---------------------------------------------------------------------------
# Quantization
# ---------------------------------------------------------------------------

def quant_scale(x):
    """Symmetric per-tensor scale: max|x| / 127, floored at SCALE_EPS."""
    return jnp.maximum(jnp.max(jnp.abs(x)), SCALE_EPS) / 127.0


def quantize(x, scale):
    return jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)


def quantize_sym(x):
    s = quant_scale(x)
    return quantize(x, s), s


def int8_matmul_ref(a_i8, b_i8):
    """int8 x int8 -> int32 exact accumulation (the MPU contract)."""
    return jnp.dot(a_i8.astype(jnp.int32), b_i8.astype(jnp.int32),
                   preferred_element_type=jnp.int32)


def int8_matmul_deq_ref(a_i8, sa, b_i8, sb):
    return int8_matmul_ref(a_i8, b_i8).astype(jnp.float32) * (sa * sb)


# ---------------------------------------------------------------------------
# Norm / RoPE
# ---------------------------------------------------------------------------

def rmsnorm_ref(x, g, eps=1e-5):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * g


def rope_ref(x, pos, theta=10000.0):
    """Apply rotary embedding. x: [..., T, dh]; pos: [T] absolute positions.

    Llama-style: pairs are (x[..., :dh/2], x[..., dh/2:]) (half-rotation).
    """
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = pos.astype(jnp.float32)[:, None] * freqs[None, :]  # [T, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


# ---------------------------------------------------------------------------
# Online-softmax block attention (SAU contract)
# ---------------------------------------------------------------------------

def attn_block_step_ref(q_i8, qs, k_i8, ks, v_i8, vs, m, l, acc, diag_mask):
    """One (query-block, kv-block) online-softmax update, W8A8.

    q_i8 [B,dh], k_i8 [B,dh], v_i8 [B,dh]; m,l [B]; acc [B,dh] f32.
    diag_mask: 0/1 scalar — apply intra-block causal mask (kv block == q block).
    Returns (m', l', acc').
    """
    B = q_i8.shape[0]
    inv_sqrt_d = 1.0 / jnp.sqrt(jnp.float32(q_i8.shape[1]))
    s = int8_matmul_ref(q_i8, k_i8.T).astype(jnp.float32) * (qs * ks * inv_sqrt_d)
    rows = jax.lax.broadcasted_iota(jnp.int32, (B, B), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (B, B), 1)
    neg = jnp.float32(-1e30)
    masked = jnp.where((diag_mask > 0) & (cols > rows), neg, s)
    m_new = jnp.maximum(m, jnp.max(masked, axis=-1))
    corr = jnp.exp(m - m_new)
    p = jnp.exp(masked - m_new[:, None])
    l_new = l * corr + jnp.sum(p, axis=-1)
    # W8A8: requantize the probability tile to int8 with fixed scale 1/127.
    p_i8 = jnp.clip(jnp.round(p * 127.0), -127, 127).astype(jnp.int8)
    pv = int8_matmul_ref(p_i8, v_i8).astype(jnp.float32) * (vs / 127.0)
    acc_new = acc * corr[:, None] + pv
    return m_new, l_new, acc_new


def attn_finalize_ref(l, acc):
    return acc / jnp.maximum(l, SCALE_EPS)[:, None]


def dense_attention_w8a8_ref(q_i8, qs, k_i8, ks, v_i8, vs, causal=True):
    """Oracle: full causal attention with the same W8A8 semantics, computed
    by folding attn_block_step_ref over kv blocks (order-independence is
    checked with permuted folds in tests)."""
    B = 128
    S = q_i8.shape[0]
    nb = S // B
    outs = []
    for qb in range(nb):
        q = q_i8[qb * B:(qb + 1) * B]
        m = jnp.full((B,), -1e30, jnp.float32)
        l = jnp.zeros((B,), jnp.float32)
        acc = jnp.zeros((B, q.shape[1]), jnp.float32)
        for kb in range(qb + 1 if causal else nb):
            diag = jnp.int32(1 if (causal and kb == qb) else 0)
            m, l, acc = attn_block_step_ref(
                q, qs, k_i8[kb * B:(kb + 1) * B], ks,
                v_i8[kb * B:(kb + 1) * B], vs, m, l, acc, diag)
        outs.append(attn_finalize_ref(l, acc))
    return jnp.concatenate(outs, axis=0)


# ---------------------------------------------------------------------------
# FlexPrefill sparse index generation (SIGU contract)
# ---------------------------------------------------------------------------

def index_phase_a_ref(qhat_i8, qs, kblk_i8, ks, m, l):
    """Phase A: stream one K block, update per-row online (m, l) softmax
    state over the full context. No causal mask: qhat is the LAST query
    block, all key blocks precede it. FlexPrefill scores the last block
    without the intra-block triangle mask; we follow suit, consistently
    across ref / kernels / Rust."""
    inv_sqrt_d = 1.0 / jnp.sqrt(jnp.float32(qhat_i8.shape[1]))
    s = int8_matmul_ref(qhat_i8, kblk_i8.T).astype(jnp.float32) * (qs * ks * inv_sqrt_d)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    l_new = l * jnp.exp(m - m_new) + jnp.sum(jnp.exp(s - m_new[:, None]), axis=-1)
    return m_new, l_new


def index_phase_b_ref(qhat_i8, qs, kblk_i8, ks, m_final, l_final):
    """Phase B: with final (M, L), emit this block's aggregate statistics:
      vsum — total probability mass landing in this key block (vertical)
      slo  — mass on intra-tile offsets i-j >= 0 (maps to slash group N-1-b)
      sup  — mass on intra-tile offsets i-j <  0 (maps to slash group N-b)
    vsum == slo + sup; vsum/B is the block-pooled true attention (a-hat).
    """
    B = qhat_i8.shape[0]
    inv_sqrt_d = 1.0 / jnp.sqrt(jnp.float32(qhat_i8.shape[1]))
    s = int8_matmul_ref(qhat_i8, kblk_i8.T).astype(jnp.float32) * (qs * ks * inv_sqrt_d)
    p = jnp.exp(s - m_final[:, None]) / jnp.maximum(l_final, SCALE_EPS)[:, None]
    rows = jax.lax.broadcasted_iota(jnp.int32, (B, B), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (B, B), 1)
    lower = jnp.where(rows >= cols, p, 0.0)
    slo = jnp.sum(lower)
    vsum = jnp.sum(p)
    return vsum, slo, vsum - slo


def block_pool_ref(x):
    """Mean-pool token vectors within each 128-block: [S, d] -> [S/128, d]."""
    S, d = x.shape
    return jnp.mean(x.reshape(S // 128, 128, d), axis=1)


def pooled_attention_ref(qpool, kpool, causal=False):
    """softmax(pool(Q) pool(K)^T / sqrt(d)) — [Nq, Nk] block-level map."""
    d = qpool.shape[-1]
    s = (qpool @ kpool.T) / jnp.sqrt(jnp.float32(d))
    if causal:
        nq, nk = s.shape
        rows = jax.lax.broadcasted_iota(jnp.int32, (nq, nk), 0) + (nk - nq)
        cols = jax.lax.broadcasted_iota(jnp.int32, (nq, nk), 1)
        s = jnp.where(cols > rows, -1e30, s)
    return jax.nn.softmax(s, axis=-1)


def jsd_ref(p, q, eps=1e-12):
    """Jensen-Shannon divergence between two distributions (natural log)."""
    p = p / jnp.maximum(jnp.sum(p), eps)
    q = q / jnp.maximum(jnp.sum(q), eps)
    m = 0.5 * (p + q)

    def kl(a, b):
        return jnp.sum(jnp.where(a > eps, a * (jnp.log(a + eps) - jnp.log(b + eps)), 0.0))

    return 0.5 * kl(p, m) + 0.5 * kl(q, m)


# ---------------------------------------------------------------------------
# Model blocks (L2 contract)
# ---------------------------------------------------------------------------

def silu_ref(x):
    return x * jax.nn.sigmoid(x)


def qkv_chunk_ref(x, g, wq_i8, sq, wk_i8, sk, wv_i8, sv, pos0, cfg):
    """RMSNorm -> W8A8 QKV projection -> RoPE(q,k) -> per-chunk quantization.

    Returns (q_i8[H,B,dh], q_scale, k_i8[Hk,B,dh], k_scale,
             v_i8[Hk,B,dh], v_scale, qpool[H,dh], kpool[Hk,dh]).
    """
    B = x.shape[0]
    xn = rmsnorm_ref(x, g, cfg.rms_eps)
    xs = quant_scale(xn)
    x_i8 = quantize(xn, xs)
    q = int8_matmul_deq_ref(x_i8, xs, wq_i8, sq)   # [B, H*dh]
    k = int8_matmul_deq_ref(x_i8, xs, wk_i8, sk)   # [B, Hk*dh]
    v = int8_matmul_deq_ref(x_i8, xs, wv_i8, sv)   # [B, Hk*dh]
    pos = pos0 + jnp.arange(B, dtype=jnp.int32)
    q = q.reshape(B, cfg.n_heads, cfg.d_head).transpose(1, 0, 2)
    k = k.reshape(B, cfg.n_kv_heads, cfg.d_head).transpose(1, 0, 2)
    v = v.reshape(B, cfg.n_kv_heads, cfg.d_head).transpose(1, 0, 2)
    q = rope_ref(q, pos, cfg.rope_theta)
    k = rope_ref(k, pos, cfg.rope_theta)
    qpool = jnp.mean(q, axis=1)  # [H, dh]
    kpool = jnp.mean(k, axis=1)  # [Hk, dh]
    qsc, ksc, vsc = quant_scale(q), quant_scale(k), quant_scale(v)
    return (quantize(q, qsc), qsc, quantize(k, ksc), ksc,
            quantize(v, vsc), vsc, qpool, kpool)


def o_proj_chunk_ref(attn, wo_i8, so, resid):
    """W8A8 output projection + residual add. attn: [B, H*dh]."""
    s = quant_scale(attn)
    a_i8 = quantize(attn, s)
    return resid + int8_matmul_deq_ref(a_i8, s, wo_i8, so)


def ffn_chunk_ref(x, g, wg_i8, sg, wu_i8, su, wd_i8, sd, eps=1e-5):
    """RMSNorm -> W8A8 SwiGLU FFN -> residual add."""
    xn = rmsnorm_ref(x, g, eps)
    xs = quant_scale(xn)
    x_i8 = quantize(xn, xs)
    gate = silu_ref(int8_matmul_deq_ref(x_i8, xs, wg_i8, sg))
    up = int8_matmul_deq_ref(x_i8, xs, wu_i8, su)
    h = gate * up
    hs = quant_scale(h)
    h_i8 = quantize(h, hs)
    return x + int8_matmul_deq_ref(h_i8, hs, wd_i8, sd)


def logits_chunk_ref(x, g, wlm_i8, sl, eps=1e-5):
    xn = rmsnorm_ref(x, g, eps)
    xs = quant_scale(xn)
    return int8_matmul_deq_ref(quantize(xn, xs), xs, wlm_i8, sl)
