"""L1 Pallas kernel: W8A8 tiled matmul — the Hybrid MPU's software contract.

The paper's Hybrid MPU is twelve 32x32 systolic arrays (six DSP-based, six
LUT/bit-plane based) computing INT8 x INT8 -> INT32. On the TPU-shaped Pallas
side the same schedule is expressed as MXU-shaped int8 matmuls tiled for VMEM
with `BlockSpec`s: the (M, N) grid plays the role of the paper's array-level
parallelism, and the K-resident operand tiles play the role of the URAM-fed
operand registers.

CPU note: `interpret=True` everywhere — the CPU PJRT plugin cannot execute
Mosaic custom-calls. On-hardware performance is modeled in `rust/src/sim/mpu.rs`
(cycle model), not measured here.

Numerics are exact integer arithmetic and must match
`ref.int8_matmul_ref` bit-for-bit (asserted in python/tests/test_kernels.py)
and `rust/src/quant` (asserted in rust runtime_integration tests).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile sizes: 128 aligns with the token-block granularity B and keeps each
# VMEM-resident tile (128 x K int8) within a U280-URAM-like budget for the
# K ranges we lower (K <= 2304).
TILE_M = 128
TILE_N = 128


def exact_int8_dot(a_i8, b_i8):
    """Exact INT8 matmul via the paper's nibble decomposition (Eq. 7-8),
    evaluated as two f32 GEMMs.

    a = aH*16 + aL with aH in [-8, 7], aL in [0, 15]:
        C = 16*(aH @ b) + (aL @ b)
    Each plane's products are <= 1016/1905 in magnitude, so partial sums
    stay below 2^24 for K <= ~7000 and every f32 accumulation is EXACT —
    the result equals int32 arithmetic bit-for-bit (asserted in tests)
    while running on the CPU's fast f32 GEMM path (~5x over the XLA s32
    dot; see EXPERIMENTS.md §Perf). This is the software realization of
    the Hybrid MPU's nibble trick.
    """
    assert a_i8.shape[-1] <= 7000, "nibble-plane exactness bound"
    ah = jnp.floor_divide(a_i8.astype(jnp.float32), 16.0)
    al = a_i8.astype(jnp.float32) - ah * 16.0
    bf = b_i8.astype(jnp.float32)
    hi = jnp.dot(ah, bf, preferred_element_type=jnp.float32)
    lo = jnp.dot(al, bf, preferred_element_type=jnp.float32)
    # combine in i32: each plane is < 2^24 (exact in f32); the 16x-scaled
    # sum can exceed 2^25, so the recombination must be integer arithmetic
    return hi.astype(jnp.int32) * 16 + lo.astype(jnp.int32)


def _matmul_kernel(a_ref, b_ref, o_ref):
    """One (TILE_M, TILE_N) output tile; K is kept whole per tile.

    a_ref: [TILE_M, K] int8, b_ref: [K, TILE_N] int8, o_ref: [TILE_M, TILE_N] int32.
    """
    o_ref[...] = exact_int8_dot(a_ref[...], b_ref[...])


@functools.partial(jax.jit, static_argnames=())
def int8_matmul(a_i8, b_i8):
    """C_i32[M,N] = A_i8[M,K] @ B_i8[K,N] with int32 accumulation.

    M and N must be multiples of the tile sizes or small enough to be a
    single tile; K is unconstrained (kept whole, streamed by XLA).
    """
    m, k = a_i8.shape
    k2, n = b_i8.shape
    assert k == k2, f"inner dims mismatch {k} vs {k2}"
    def pick_tile(dim, pref):
        # largest power-of-two tile <= pref that divides dim, else whole dim
        t = min(pref, dim)
        while t > 1 and dim % t != 0:
            t //= 2
        return t if dim % t == 0 else dim

    tm = pick_tile(m, TILE_M)
    tn = pick_tile(n, TILE_N)
    grid = (m // tm, n // tn)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, tn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=True,
    )(a_i8, b_i8)


def int8_matmul_deq(a_i8, sa, b_i8, sb):
    """Dequantized W8A8 matmul: f32 = (A_i8 @ B_i8) * sa * sb."""
    return int8_matmul(a_i8, b_i8).astype(jnp.float32) * (sa * sb)
