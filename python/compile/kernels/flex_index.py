"""L1 Pallas kernels: streaming sparse index generation (SIGU).

The paper's SIGU streams Key blocks in ascending block order (long contiguous
HBM bursts), scores each block against the last query block Q-hat on the
Hybrid MPU, and *incrementally* folds the 128 x S score tensor into O(S/B)
per-block statistics — vertical scores, slash scores and block-pooled
attention — so nothing bigger than a tile ever exists.

Exact softmax normalization requires the per-row max/denominator over the
full context. We implement this as two streaming phases with identical
per-tile compute:

  phase A: per-row online (m, l) update          — O(B) state
  phase B: normalized per-block statistics       — O(1) per block

The paper's single-fetch claim is realized in hardware with deferred-rescale
buffers; for the functional path two passes over K are numerically identical,
and `rust/src/sim/sigu.rs` models the single-fetch memory behaviour (see
DESIGN.md). Both phases are single fused Pallas kernels; `fused_index_scores`
below additionally demonstrates the full grid-streamed pipeline in one
`pallas_call` (used by the python tests; the AOT path uses the per-block
kernels because the grid length S/B must stay static per artifact).

Slash statistics: for key block b and the last query block (row block N-1),
the token diagonal offset is o = (S-B+i) - (b*B+j) = (N-1-b)*B + (i-j).
A tile therefore contributes to exactly two block-diagonal groups:
  i-j >= 0  ->  slash group N-1-b   ("slo")
  i-j <  0  ->  slash group N-b     ("sup")
The Rust coordinator scatters (slo, sup) into the slash score buffer — the
paper's Slash Accumulator.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .int8_matmul import exact_int8_dot


def _phase_a_kernel(q_ref, k_ref, sc_ref, m_ref, l_ref, mo_ref, lo_ref):
    """Online (m, l) update for one streamed K block."""
    b, dh = q_ref.shape
    inv_sqrt_d = 1.0 / jnp.sqrt(jnp.float32(dh))
    s = exact_int8_dot(q_ref[...], k_ref[...].T).astype(jnp.float32)
    s = s * (sc_ref[0] * sc_ref[1] * inv_sqrt_d)
    m = m_ref[...]
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    lo_ref[...] = l_ref[...] * jnp.exp(m - m_new) + \
        jnp.sum(jnp.exp(s - m_new[:, None]), axis=-1)
    mo_ref[...] = m_new


@jax.jit
def index_phase_a(qhat_i8, qs, kblk_i8, ks, m, l):
    b, dh = qhat_i8.shape
    sc = jnp.stack([jnp.float32(qs), jnp.float32(ks)])
    return pl.pallas_call(
        _phase_a_kernel,
        out_shape=(jax.ShapeDtypeStruct((b,), jnp.float32),
                   jax.ShapeDtypeStruct((b,), jnp.float32)),
        interpret=True,
    )(qhat_i8, kblk_i8, sc, m, l)


def _phase_b_kernel(q_ref, k_ref, sc_ref, m_ref, l_ref, out_ref):
    """Normalized per-block statistics: out = [vsum, slo, sup]."""
    b, dh = q_ref.shape
    inv_sqrt_d = 1.0 / jnp.sqrt(jnp.float32(dh))
    s = exact_int8_dot(q_ref[...], k_ref[...].T).astype(jnp.float32)
    s = s * (sc_ref[0] * sc_ref[1] * inv_sqrt_d)
    p = jnp.exp(s - m_ref[...][:, None]) / \
        jnp.maximum(l_ref[...], 1e-8)[:, None]
    rows = jax.lax.broadcasted_iota(jnp.int32, (b, b), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (b, b), 1)
    slo = jnp.sum(jnp.where(rows >= cols, p, 0.0))
    vsum = jnp.sum(p)
    out_ref[0] = vsum
    out_ref[1] = slo
    out_ref[2] = vsum - slo


@jax.jit
def index_phase_b(qhat_i8, qs, kblk_i8, ks, m_final, l_final):
    """Returns stats[3] = (vsum, slo, sup) for one key block."""
    sc = jnp.stack([jnp.float32(qs), jnp.float32(ks)])
    return pl.pallas_call(
        _phase_b_kernel,
        out_shape=jax.ShapeDtypeStruct((3,), jnp.float32),
        interpret=True,
    )(qhat_i8, kblk_i8, sc, m_final, l_final)


# ---------------------------------------------------------------------------
# Fully fused grid-streamed variant (tests / fixed-S demos).
# ---------------------------------------------------------------------------

def _fused_kernel(q_ref, k_ref, sc_ref, m_ref, l_ref, stat_ref):
    """Grid axis = key block index (the paper's streaming order).

    Demonstrates the one-pallas_call SIGU pipeline: the (m, l) outputs are
    revisited across grid steps (running softmax state), and per-block raw
    statistics are emitted per grid step. Because normalization needs final
    (M, L), the raw stats carry the per-step m so the host (or a final pass)
    applies the deferred rescale — mirroring the hardware's rescale buffers.
    stat_ref[b] = [raw_vsum_b, raw_slo_b, m_snapshot_row0...]; see
    fused_index_scores for the exact layout.
    """
    bidx = pl.program_id(0)
    b, dh = q_ref.shape
    inv_sqrt_d = 1.0 / jnp.sqrt(jnp.float32(dh))
    s = exact_int8_dot(q_ref[...], k_ref[...].T).astype(jnp.float32)
    s = s * (sc_ref[0] * sc_ref[1] * inv_sqrt_d)

    @pl.when(bidx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)

    m = m_ref[...]
    l = l_ref[...]
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    l_new = l * jnp.exp(m - m_new) + jnp.sum(p, axis=-1)
    m_ref[...] = m_new
    l_ref[...] = l_new
    rows = jax.lax.broadcasted_iota(jnp.int32, (b, b), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (b, b), 1)
    # Raw (pre-normalization) per-row partials for the deferred rescale:
    # stat[0,:] = sum_j exp(s - m_snapshot), stat[1,:] = lower-tri part,
    # stat[2,:] = m snapshot at this step.
    stat_ref[0, :] = jnp.sum(p, axis=-1)
    stat_ref[1, :] = jnp.sum(jnp.where(rows >= cols, p, 0.0), axis=-1)
    stat_ref[2, :] = m_new


def fused_index_scores(qhat_i8, qs, k_i8, ks):
    """One-call streamed SIGU over all S/B key blocks (static S).

    Returns (vscore[N], slo[N], sup[N]) exactly equal to running
    phase A then phase B per block. k_i8: [S, dh] int8 (contiguous blocks).
    """
    s_len, dh = k_i8.shape
    b = qhat_i8.shape[0]
    n = s_len // b
    sc = jnp.stack([jnp.float32(qs), jnp.float32(ks)])
    m, l, raw = pl.pallas_call(
        _fused_kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((b, dh), lambda i: (0, 0)),
            pl.BlockSpec((b, dh), lambda i: (i, 0)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=(
            pl.BlockSpec((b,), lambda i: (0,)),
            pl.BlockSpec((b,), lambda i: (0,)),
            pl.BlockSpec((3, b), lambda i: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((n * 3, b), jnp.float32),
        ),
        interpret=True,
    )(qhat_i8, k_i8, sc)
    raw = raw.reshape(n, 3, b)
    # Deferred rescale: raw partials were taken against the running max at
    # stream time; bring them to the final (M, L) basis.
    corr = jnp.exp(raw[:, 2, :] - m[None, :]) / jnp.maximum(l, 1e-8)[None, :]
    vsum = jnp.sum(raw[:, 0, :] * corr, axis=-1)
    slo = jnp.sum(raw[:, 1, :] * corr, axis=-1)
    return vsum, slo, vsum - slo
