"""AOT driver: lower every L2 entry point to HLO *text* artifacts.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the Rust side's xla_extension
0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Outputs, per functional config C and entry E:
    artifacts/<C>__<E>.hlo.txt
plus a machine-readable manifest (artifacts/manifest.txt) that the Rust
runtime parses to validate parameter/result shapes before compiling:

    artifact <cfg> <entry> <file>
    in <idx> <dtype> <d0>x<d1>...      (scalar => "scalar")
    out <idx> <dtype> <dims>
    cfg <name> d_model=... n_heads=... ...

Usage: python -m compile.aot --out-dir ../artifacts
"""

import argparse
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .configs import AOT_CONFIGS

_DTYPE_NAMES = {
    jnp.float32.dtype: "f32",
    jnp.int8.dtype: "s8",
    jnp.int32.dtype: "s32",
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _fmt_shape(sds) -> str:
    dt = _DTYPE_NAMES[jnp.dtype(sds.dtype)]
    dims = "x".join(str(d) for d in sds.shape) if sds.shape else "scalar"
    return f"{dt} {dims}"


def lower_all(out_dir: str, configs=AOT_CONFIGS, verbose=True):
    os.makedirs(out_dir, exist_ok=True)
    manifest = []
    for cfg in configs:
        manifest.append(
            f"cfg {cfg.name} d_model={cfg.d_model} n_heads={cfg.n_heads} "
            f"n_kv_heads={cfg.n_kv_heads} d_head={cfg.d_head} "
            f"d_ffn={cfg.d_ffn} n_layers={cfg.n_layers} vocab={cfg.vocab} "
            f"sau_batch={model.SAU_BATCH}")
        for name, (fn, args) in model.entry_specs(cfg).items():
            fname = f"{cfg.name}__{name}.hlo.txt"
            path = os.path.join(out_dir, fname)
            lowered = jax.jit(fn).lower(*args)
            text = to_hlo_text(lowered)
            with open(path, "w") as f:
                f.write(text)
            manifest.append(f"artifact {cfg.name} {name} {fname}")
            for i, a in enumerate(args):
                manifest.append(f"in {i} {_fmt_shape(a)}")
            outs = lowered.out_info
            flat, _ = jax.tree_util.tree_flatten(outs)
            for i, o in enumerate(flat):
                manifest.append(f"out {i} {_fmt_shape(o)}")
            if verbose:
                print(f"  lowered {fname} ({len(text)} chars, "
                      f"{len(args)} in / {len(flat)} out)")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    if verbose:
        print(f"wrote {os.path.join(out_dir, 'manifest.txt')}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--configs", default="",
                    help="comma-separated config names (default: all)")
    args = ap.parse_args()
    cfgs = AOT_CONFIGS
    if args.configs:
        want = set(args.configs.split(","))
        cfgs = [c for c in AOT_CONFIGS if c.name in want]
        missing = want - {c.name for c in cfgs}
        if missing:
            sys.exit(f"unknown configs: {missing}")
    lower_all(args.out_dir, cfgs)


if __name__ == "__main__":
    main()
