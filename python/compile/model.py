"""L2: chunk-level JAX compute graphs for the W8A8 transformer prefill.

Each public function here is one AOT entry point: a fixed-shape, jit-able
function over one 128-token chunk (or one block-level job), composing the L1
Pallas kernels with the f32 glue (RMSNorm, RoPE, SiLU, dequantization).
`aot.py` lowers every entry point for every functional config to HLO text;
the Rust coordinator (L3) owns all dynamic control flow — chunk loop, SIGU
pattern decision, coverage top-k, job lists, cache policy.

All matmuls route through the Pallas int8 kernel (the Hybrid MPU); keeping
them W8A8 end-to-end is the paper's W8A8 claim (Table III row 3).
"""

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels import block_attn, flex_index
from .kernels.int8_matmul import int8_matmul, int8_matmul_deq
from .kernels.ref import (quant_scale, quantize, rmsnorm_ref, rope_ref,
                          silu_ref)


# ---------------------------------------------------------------------------
# Chunked KV generation
# ---------------------------------------------------------------------------

def qkv_chunk(cfg: ModelConfig):
    """Entry factory: RMSNorm -> W8A8 QKV -> RoPE -> quantized chunk tensors.

    Inputs: x[B,D] f32, g[D] f32, wq[D,H*dh] i8, sq f32, wk[D,Hk*dh] i8,
            sk f32, wv[D,Hk*dh] i8, sv f32, pos0 i32.
    Outputs: q_i8[H,B,dh], q_scale, k_i8[Hk,B,dh], k_scale,
             v_i8[Hk,B,dh], v_scale, qpool[H,dh], kpool[Hk,dh].
    """

    def fn(x, g, wq, sq, wk, sk, wv, sv, pos0):
        b = x.shape[0]
        xn = rmsnorm_ref(x, g, cfg.rms_eps)
        xs = quant_scale(xn)
        x_i8 = quantize(xn, xs)
        q = int8_matmul(x_i8, wq).astype(jnp.float32) * (xs * sq)
        k = int8_matmul(x_i8, wk).astype(jnp.float32) * (xs * sk)
        v = int8_matmul(x_i8, wv).astype(jnp.float32) * (xs * sv)
        pos = pos0 + jnp.arange(b, dtype=jnp.int32)
        q = q.reshape(b, cfg.n_heads, cfg.d_head).transpose(1, 0, 2)
        k = k.reshape(b, cfg.n_kv_heads, cfg.d_head).transpose(1, 0, 2)
        v = v.reshape(b, cfg.n_kv_heads, cfg.d_head).transpose(1, 0, 2)
        q = rope_ref(q, pos, cfg.rope_theta)
        k = rope_ref(k, pos, cfg.rope_theta)
        qpool = jnp.mean(q, axis=1)
        kpool = jnp.mean(k, axis=1)
        qsc, ksc, vsc = quant_scale(q), quant_scale(k), quant_scale(v)
        return (quantize(q, qsc), qsc, quantize(k, ksc), ksc,
                quantize(v, vsc), vsc, qpool, kpool)

    return fn


# ---------------------------------------------------------------------------
# SIGU / SAU / FFN entry points (config-independent shapes except dh, D, F)
# ---------------------------------------------------------------------------

def index_phase_a_entry(x_qhat, qs, kblk, ks, m, l):
    return flex_index.index_phase_a(x_qhat, qs, kblk, ks, m, l)


def index_phase_b_entry(x_qhat, qs, kblk, ks, m, l):
    return flex_index.index_phase_b(x_qhat, qs, kblk, ks, m, l)


def attn_block_step_entry(q, qs, k, ks, v, vs, m, l, acc, diag):
    return block_attn.attn_block_step(q, qs, k, ks, v, vs, m, l, acc, diag)


def attn_block_batch_entry(q, qs, k, ks, v, vs, m, l, acc, diag):
    return block_attn.attn_block_batch(q, qs, k, ks, v, vs, m, l, acc, diag)


def o_proj_chunk(cfg: ModelConfig):
    """attn[B,H*dh] f32 x Wo -> + resid[B,D]."""

    def fn(attn, wo, so, resid):
        s = quant_scale(attn)
        a_i8 = quantize(attn, s)
        return resid + int8_matmul_deq(a_i8, s, wo, so)

    return fn


def ffn_chunk(cfg: ModelConfig):
    """x[B,D] -> x + W8A8 SwiGLU FFN(RMSNorm(x))."""

    def fn(x, g, wg, sg, wu, su, wd, sd):
        xn = rmsnorm_ref(x, g, cfg.rms_eps)
        xs = quant_scale(xn)
        x_i8 = quantize(xn, xs)
        gate = silu_ref(int8_matmul_deq(x_i8, xs, wg, sg))
        up = int8_matmul_deq(x_i8, xs, wu, su)
        h = gate * up
        hs = quant_scale(h)
        h_i8 = quantize(h, hs)
        return x + int8_matmul_deq(h_i8, hs, wd, sd)

    return fn


def logits_chunk(cfg: ModelConfig):
    """Final RMSNorm + W8A8 LM head over one chunk: -> logits[B,V]."""

    def fn(x, g, wlm, sl):
        xn = rmsnorm_ref(x, g, cfg.rms_eps)
        xs = quant_scale(xn)
        return int8_matmul_deq(quantize(xn, xs), xs, wlm, sl)

    return fn


# ---------------------------------------------------------------------------
# Entry-point registry used by aot.py and tests.
# Shapes use B=128 token blocks; J is the SAU batch width (padded job groups).
# ---------------------------------------------------------------------------

B = 128
SAU_BATCH = 8  # J: jobs per batched SAU call (pad with zero-weight jobs)


def entry_specs(cfg: ModelConfig):
    """Returns {name: (fn, [ShapeDtypeStruct args])} for AOT lowering."""
    f32, i8, i32 = jnp.float32, jnp.int8, jnp.int32
    S = jax.ShapeDtypeStruct
    dh, D, F = cfg.d_head, cfg.d_model, cfg.d_ffn
    H, Hk, V = cfg.n_heads, cfg.n_kv_heads, cfg.vocab
    sc = S((), f32)
    return {
        "qkv_chunk": (qkv_chunk(cfg), [
            S((B, D), f32), S((D,), f32),
            S((D, H * dh), i8), sc, S((D, Hk * dh), i8), sc,
            S((D, Hk * dh), i8), sc, S((), i32),
        ]),
        "index_phase_a": (index_phase_a_entry, [
            S((B, dh), i8), sc, S((B, dh), i8), sc,
            S((B,), f32), S((B,), f32),
        ]),
        "index_phase_b": (index_phase_b_entry, [
            S((B, dh), i8), sc, S((B, dh), i8), sc,
            S((B,), f32), S((B,), f32),
        ]),
        "attn_block_step": (attn_block_step_entry, [
            S((B, dh), i8), sc, S((B, dh), i8), sc, S((B, dh), i8), sc,
            S((B,), f32), S((B,), f32), S((B, dh), f32), sc,
        ]),
        "attn_block_batch": (attn_block_batch_entry, [
            S((SAU_BATCH, B, dh), i8), S((SAU_BATCH,), f32),
            S((SAU_BATCH, B, dh), i8), S((SAU_BATCH,), f32),
            S((SAU_BATCH, B, dh), i8), S((SAU_BATCH,), f32),
            S((SAU_BATCH, B), f32), S((SAU_BATCH, B), f32),
            S((SAU_BATCH, B, dh), f32), S((SAU_BATCH,), f32),
        ]),
        "o_proj_chunk": (o_proj_chunk(cfg), [
            S((B, H * dh), f32), S((H * dh, D), i8), sc, S((B, D), f32),
        ]),
        "ffn_chunk": (ffn_chunk(cfg), [
            S((B, D), f32), S((D,), f32),
            S((D, F), i8), sc, S((D, F), i8), sc, S((F, D), i8), sc,
        ]),
        "logits_chunk": (logits_chunk(cfg), [
            S((B, D), f32), S((D,), f32), S((D, V), i8), sc,
        ]),
    }
