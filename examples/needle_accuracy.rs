//! Needle-retrieval accuracy across precision modes — the human-readable
//! companion to `cargo bench --bench table3_accuracy` (paper Table III).
//!
//!     cargo run --release --example needle_accuracy

use fast_prefill::accuracy::{table3_cell, Precision};
use fast_prefill::config::FlexParams;
use fast_prefill::metrics::fmt_ctx;
use fast_prefill::util::table::{fnum, Table};

fn main() {
    let params = FlexParams::default();
    // contexts (blocks of 128 tokens) and task difficulty mirror the bench
    let contexts = [(32usize, "4K"), (64, "8K"), (128, "16K")];
    let (gain, noise) = (0.85f32, 0.5f32);
    let n_tasks = 4;

    println!("Needle retrieval through the FlexPrefill + quantized attention stack");
    println!("(RULER proxy — see DESIGN.md substitutions; higher is better)\n");
    let mut t = Table::new(&["Method", "4K", "8K", "16K", "Avg"]);
    for prec in [Precision::Bf16, Precision::Int8Deq, Precision::W8A8] {
        let mut row = vec![prec.label().to_string()];
        let mut sum = 0.0;
        for (nb, _) in contexts {
            let acc = table3_cell(nb, 64, prec, &params, n_tasks, gain, noise, 99);
            sum += acc;
            row.push(fnum(acc));
        }
        row.push(fnum(sum / contexts.len() as f64));
        t.row(&row);
    }
    t.print();
    println!("\ncontexts: {}", contexts.iter().map(|c| fmt_ctx(c.0 * 128)).collect::<Vec<_>>().join(", "));
    println!("expected shape (paper Table III): BF16 >> INT8 ~= W8A8.");
}
