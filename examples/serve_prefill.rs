//! END-TO-END DRIVER (EXPERIMENTS.md §E2E): serve batched long-context
//! prefill requests on the ~100M-parameter model through the full system —
//! AOT artifacts on the PJRT runtime, chunked KV generation, SIGU sparse
//! index generation, block-major SAU with the liveness cache, FFN, first
//! token — reporting per-request TTFT, throughput, sparsity and cache
//! statistics, plus the U280/A5000 model estimates for the same trace.
//!
//!     make artifacts && cargo run --release --example serve_prefill
//!
//! Flags (positional): [n_requests] [tokens] [workers]
//! Defaults: 6 requests x 2048 tokens on 2 workers (a few minutes on CPU).

use anyhow::Result;
use fast_prefill::config::{a5000, u280_fast_prefill, SMALL100M};
use fast_prefill::coordinator::{EngineConfig, Policy, Server};
use fast_prefill::gpu_model::simulate_gpu_prefill;
use fast_prefill::sim::simulate_prefill;
use fast_prefill::util::stats::{mean, percentile};
use fast_prefill::util::table::{fnum, Table};
use fast_prefill::workload::prompts::RequestTrace;

fn main() -> Result<()> {
    let args: Vec<usize> = std::env::args()
        .skip(1)
        .filter_map(|a| a.parse().ok())
        .collect();
    let n_requests = args.first().copied().unwrap_or(6);
    let tokens = args.get(1).copied().unwrap_or(2048);
    let workers = args.get(2).copied().unwrap_or(2);

    let mut cfg = EngineConfig::new(SMALL100M.clone());
    cfg.native_sau = true; // PJRT SAU is exercised by quickstart/tests;
                           // native keeps the 100M E2E run in minutes
    // cheap availability probe: manifest present AND executable (the
    // Runtime::load attempt is only paid when artifacts exist on disk)
    let artifacts_usable = std::path::Path::new("artifacts/manifest.txt").exists()
        && fast_prefill::runtime::Runtime::load("artifacts").is_ok();
    if !artifacts_usable {
        eprintln!("artifacts unavailable; serving on the native tiled kernels");
        cfg.native_sigu = true;
        cfg.native_linear = true;
    }
    println!(
        "== E2E: {} ({}M params, {} layers) | {} req x {} tokens | {} workers ==",
        SMALL100M.name,
        SMALL100M.params() / 1_000_000,
        SMALL100M.n_layers,
        n_requests,
        tokens,
        workers
    );

    let trace = RequestTrace::generate(n_requests, tokens, 2000, 2026);
    let t0 = std::time::Instant::now();
    let server = Server::start("artifacts".into(), cfg, workers, Policy::Sjf)?;
    for r in trace.requests.clone() {
        server.submit(r);
    }
    let completions = server.drain()?;
    let wall_s = t0.elapsed().as_secs_f64();

    let mut t = Table::new(&[
        "req", "TTFT (ms)", "queue (ms)", "e2e (ms)", "density %", "QA heads %", "hit %", "jobs",
    ]);
    let mut e2e = Vec::new();
    let mut ttft = Vec::new();
    for c in &completions {
        e2e.push(c.e2e_us / 1e3);
        ttft.push(c.run.metrics.ttft_us / 1e3);
        t.row(&[
            c.request_id.to_string(),
            fnum(c.run.metrics.ttft_us / 1e3),
            fnum(c.queue_us / 1e3),
            fnum(c.e2e_us / 1e3),
            fnum(c.run.metrics.density * 100.0),
            fnum(c.run.metrics.query_aware_frac * 100.0),
            fnum(c.run.metrics.cache_hit_rate * 100.0),
            c.run.metrics.jobs.to_string(),
        ]);
    }
    t.print();
    println!(
        "wall {:.1}s | prefill throughput {:.0} tok/s | TTFT mean {:.0} ms p95 {:.0} ms | e2e mean {:.0} ms",
        wall_s,
        (n_requests * tokens) as f64 / wall_s,
        mean(&ttft),
        percentile(&ttft, 95.0),
        mean(&e2e),
    );

    // hardware estimates for the same real index sets (first completion)
    if let Some(c) = completions.first() {
        let f = simulate_prefill(&u280_fast_prefill(), &SMALL100M, tokens, &c.run.index_sets);
        let g = simulate_gpu_prefill(&a5000(), &SMALL100M, tokens, &c.run.index_sets);
        println!(
            "\nhardware estimates for this trace (same index sets):\n  U280-sim  {:.1} ms, {:.3} J (hit {:.0}%)\n  A5000-mdl {:.1} ms, {:.3} J\n  speedup {:.2}x, energy-eff {:.2}x",
            f.ttft_ms,
            f.energy_j,
            f.cache_hit_rate * 100.0,
            g.ttft_ms,
            g.energy_j,
            g.ttft_ms / f.ttft_ms,
            f.tokens_per_joule() / g.tokens_per_joule()
        );
    }
    Ok(())
}
