//! END-TO-END DRIVER (EXPERIMENTS.md §E2E): serve batched long-context
//! prefill requests through the full system — chunked KV generation, SIGU
//! sparse index generation, block-major SAU with the liveness cache, FFN,
//! first token — and measure the **phase-pipelined** server against the
//! serial baseline at the same total kernel-thread budget. Per-request
//! outputs are bit-identical between the two; only the scheduling differs.
//! Also reports the U280/A5000 model estimates for the same trace.
//!
//!     cargo run --release --example serve_prefill
//!
//! Flags (positional): [n_requests] [tokens] [workers]; `--closed-loop`
//! submits the whole trace up front instead of the default **open-loop
//! replay** (requests arrive at their recorded `arrival_us`, modeling
//! bursts; closed-loop gives the head-of-line Batch anchor a short head
//! start so contention forms the same way). Defaults: 6 requests on 2
//! workers with mixed context lengths {tokens/2, tokens, 2*tokens}
//! around tokens=2048 (minutes on CPU).
//! Env: FASTP_SERVE_MODEL picks the model config (default `small100m`;
//! CI smoke uses `tiny`), FASTP_THREADS bounds the shared budget,
//! FASTP_SERVE_POLICY picks fcfs|sjf|preemptive (default sjf; the
//! preemptive run also measures a pipelined-FCFS baseline, asserts
//! preemption counters > 0, and on closed-loop runs additionally
//! asserts the Interactive-class mean-TTFT win — open-loop prints the
//! comparison without gating, since arrival timing shapes contention),
//! FASTP_SERVE_JSON writes the machine-readable summary (CI artifact),
//! FASTP_SERVE_PREFIX=1 adds a prefix-reuse leg: a shared-prefix cohort
//! trace served cold vs warm through the content-hashed prefix KV store
//! (dense mode), asserting bit-identity, a positive store hit-rate and a
//! warm-over-cold mean-TTFT win, with `prefix_cold`/`prefix_warm` legs
//! in the JSON summary.
//! FASTP_SERVE_FUSED=1 adds a fused-IndexGen leg (sparse mode): the same
//! trace served with phase batching off vs on (adaptive fused groups),
//! asserting per-request bit-identity, > 0 fused IndexGen groups, and a
//! lower total priced K-stream HBM read than the unfused baseline, with
//! `indexgen_unfused`/`indexgen_fused` legs in the JSON summary.
//! FASTP_SERVE_DECODE=1 adds a continuous-batching leg (dense mode): a
//! long Batch prefill anchor plus short Interactive requests continuing
//! into decode, served monolithic vs chunked (`prefill_chunk = BLOCK`)
//! on one worker — asserting decode bit-identity between the legs,
//! reporting TPOT/ITL/tok/s, and gating the chunked leg's strictly lower
//! Interactive mean TTFT (chunk boundaries release the engine, so the
//! anchor's prefill no longer blocks interactive admissions end-to-end),
//! with `decode_monolithic`/`decode_chunked` legs in the JSON summary.
//! FASTP_SERVE_REPLICAS=N adds a replica-sharding leg (dense mode): a
//! closed-loop bimodal load-generator trace served through
//! `coordinator::cluster` — once on a single replica (the reference) and
//! once across N replicas under the FASTP_ROUTER policy
//! (round_robin|least_loaded|cost_model, default cost_model) at the same
//! total thread budget — asserting per-request bit-identity between the
//! two (placement only moves work, never changes math). When the router
//! is cost_model and N > 1 the leg also serves the same trace
//! round-robin and gates the cost model's strictly lower mean TTFT
//! (prefill-only, so e2e = submission -> first token): the trace plants
//! its long requests on round-robin's replica-0 stride, so the
//! placement-blind policy piles them onto one replica while the cost
//! model spreads them by priced backlog. JSON legs:
//! `replica_solo`/`replica_sharded` (+ `replica_round_robin`), each
//! carrying per-replica request and utilization vectors.

use std::sync::Arc;

use anyhow::Result;
use fast_prefill::config::{a5000, by_name, u280_fast_prefill, SMALL100M};
use fast_prefill::coordinator::{
    Cluster, ClusterRun, Completion, EngineConfig, Policy, RouterPolicy, Server, ServerOptions,
};
use fast_prefill::gpu_model::simulate_gpu_prefill;
use fast_prefill::metrics::{ServeSample, ServeSummary};
use fast_prefill::model::ModelWeights;
use fast_prefill::sim::{simulate_prefill, simulate_prefill_batch};
use fast_prefill::util::table::{fnum, Table};
use fast_prefill::workload::prompts::{
    Priority, PromptKind, PromptSpec, RequestTrace, TraceRequest,
};
use fast_prefill::workload::LoadGen;

fn serve(
    cfg: &EngineConfig,
    weights: &Arc<ModelWeights>,
    trace: &RequestTrace,
    opts: ServerOptions,
    open_loop: bool,
) -> Result<(Vec<Completion>, f64)> {
    let t0 = std::time::Instant::now();
    let server =
        Server::start_with_weights("artifacts".into(), cfg.clone(), opts, Arc::clone(weights))?;
    if open_loop {
        // honor the trace's arrival times (bursts queue as recorded)
        server.replay(trace);
    } else {
        for (i, r) in trace.requests.clone().into_iter().enumerate() {
            server.submit(r);
            if i == 0 {
                // closed-loop head-of-line anchor: let the first (Batch)
                // request get mid-flight before the backlog lands, so
                // every policy faces the same contention shape
                std::thread::sleep(std::time::Duration::from_millis(25));
            }
        }
    }
    let completions = server.drain()?;
    Ok((completions, t0.elapsed().as_secs_f64()))
}

fn summarize(completions: &[Completion]) -> ServeSummary {
    let samples: Vec<ServeSample> = completions.iter().map(|c| c.sample()).collect();
    ServeSummary::from_samples(&samples)
}

fn serve_cluster(
    cfg: &EngineConfig,
    weights: &Arc<ModelWeights>,
    trace: &RequestTrace,
    opts: ServerOptions,
    policy: RouterPolicy,
) -> Result<ClusterRun> {
    let cluster = Cluster::start_with_weights(
        "artifacts".into(),
        cfg.clone(),
        opts,
        policy,
        Arc::clone(weights),
    )?;
    // every load-generator arrival is at t=0, so replay degenerates to
    // closed-loop submit-as-fast-as-possible in id order
    cluster.replay(trace);
    cluster.drain()
}

fn main() -> Result<()> {
    let args: Vec<usize> = std::env::args()
        .skip(1)
        .filter_map(|a| a.parse().ok())
        .collect();
    let n_requests = args.first().copied().unwrap_or(6);
    let tokens = args.get(1).copied().unwrap_or(2048);
    let workers = args.get(2).copied().unwrap_or(2);
    let open_loop = !std::env::args().any(|a| a == "--closed-loop");
    let policy = match std::env::var("FASTP_SERVE_POLICY").as_deref() {
        Ok("fcfs") => Policy::Fcfs,
        Ok("preemptive") => Policy::Preemptive,
        Ok("sjf") | Err(_) => Policy::Sjf,
        Ok(p) => anyhow::bail!("FASTP_SERVE_POLICY={p} (want fcfs|sjf|preemptive)"),
    };
    let model = std::env::var("FASTP_SERVE_MODEL")
        .ok()
        .and_then(|n| by_name(&n).cloned())
        .unwrap_or_else(|| SMALL100M.clone());

    let mut cfg = EngineConfig::new(model.clone());
    cfg.native_sau = true; // PJRT SAU is exercised by quickstart/tests;
                           // native keeps the 100M E2E run in minutes
    // cheap availability probe: manifest present AND executable (the
    // Runtime::load attempt is only paid when artifacts exist on disk)
    let artifacts_usable = std::path::Path::new("artifacts/manifest.txt").exists()
        && fast_prefill::runtime::Runtime::load("artifacts").is_ok();
    if !artifacts_usable {
        eprintln!("artifacts unavailable; serving on the native tiled kernels");
        cfg.native_sigu = true;
        cfg.native_linear = true;
    }
    // mixed-length contention trace: {~tokens/2, tokens, 2*tokens}, each
    // rounded to the BLOCK granularity the engine requires
    let block = fast_prefill::config::BLOCK;
    let rb = |t: usize| (t.max(block) / block) * block;
    let choices = [rb(tokens / 2), rb(tokens), rb(tokens) * 2];
    println!(
        "== E2E: {} ({}M params, {} layers) | {} req x {{{}, {}, {}}} tokens | {} workers | \
         {policy:?} ==",
        model.name,
        model.params() / 1_000_000,
        model.n_layers,
        n_requests,
        choices[0],
        choices[1],
        choices[2],
        workers
    );
    let mut trace = RequestTrace::generate_mixed(n_requests, &choices, 2000, 2026);
    // head-of-line anchors: the first arrival is a longest-class Batch
    // prefill and the last a shortest Interactive, guaranteeing both
    // priority classes and the head-of-line shape the preemptive policy
    // is measured (and CI-asserted) on
    if let Some(r0) = trace.requests.first_mut() {
        r0.spec.tokens = choices[2];
        r0.priority = Priority::Batch;
    }
    if n_requests > 1 {
        let last = trace.requests.last_mut().unwrap();
        last.spec.tokens = choices[0];
        last.priority = Priority::Interactive;
    }
    // one generated model shared by both servers (and all their workers)
    let weights = Arc::new(ModelWeights::generate(&cfg.model, cfg.weight_seed));

    println!("arrival mode: {}", if open_loop { "open-loop replay" } else { "closed-loop" });
    // serial baseline first (PR-1 behaviour at equal total threads), then
    // the phase-pipelined scheduler on the same trace
    let (serial, serial_wall) =
        serve(&cfg, &weights, &trace, ServerOptions::serial(workers, policy), open_loop)?;
    let (pipelined, pipe_wall) =
        serve(&cfg, &weights, &trace, ServerOptions::new(workers, policy), open_loop)?;
    // the preemptive run also measures a pipelined-FCFS baseline: the
    // head-of-line-blocked schedule its TTFT win is asserted against
    let fcfs_baseline = if policy == Policy::Preemptive {
        Some(serve(&cfg, &weights, &trace, ServerOptions::new(workers, Policy::Fcfs), open_loop)?)
    } else {
        None
    };

    // bit-identity across schedulers is an invariant, not a hope
    for (a, b) in serial.iter().zip(&pipelined) {
        assert_eq!(a.request_id, b.request_id);
        assert_eq!(a.run.first_token, b.run.first_token, "req {}", a.request_id);
        assert_eq!(a.run.logits_last, b.run.logits_last, "req {}", a.request_id);
    }
    if let Some((fcfs, _)) = &fcfs_baseline {
        for (a, b) in fcfs.iter().zip(&pipelined) {
            assert_eq!(a.request_id, b.request_id);
            assert_eq!(a.run.first_token, b.run.first_token, "req {}", a.request_id);
            assert_eq!(a.run.logits_last, b.run.logits_last, "req {}", a.request_id);
        }
    }

    // optional prefix-reuse leg (FASTP_SERVE_PREFIX=1): serve a
    // shared-prefix cohort trace cold (no store) and warm (store
    // attached) in dense mode and gate the reuse win. Strict sequencing
    // (1 worker, 1 inflight slot) makes publish-then-hit deterministic
    // and penalizes both legs identically.
    let prefix_legs = if std::env::var("FASTP_SERVE_PREFIX").as_deref() == Ok("1") {
        let mut dense = cfg.clone();
        dense.flex = None; // the prefix store is dense-mode only
        let n_cohorts = if n_requests >= 4 { 2 } else { 1 };
        let ptrace =
            RequestTrace::generate_shared_prefix(n_requests, &choices, 2000, 2026, 8, n_cohorts);
        let strict = ServerOptions::builder().policy(Policy::Fcfs).max_inflight(1);
        let popts = strict.build().map_err(anyhow::Error::msg)?;
        let wopts = strict
            .prefix(fast_prefill::coordinator::PrefixConfig::default())
            .build()
            .map_err(anyhow::Error::msg)?;
        let (cold, _) = serve(&dense, &weights, &ptrace, popts, false)?;
        let (warm, _) = serve(&dense, &weights, &ptrace, wopts, false)?;
        // reused-prefix outputs are bit-identical to the cold serve
        for (a, b) in cold.iter().zip(&warm) {
            assert_eq!(a.request_id, b.request_id);
            assert_eq!(a.run.first_token, b.run.first_token, "prefix req {}", a.request_id);
            assert_eq!(a.run.logits_last, b.run.logits_last, "prefix req {}", a.request_id);
        }
        let cold_sum = summarize(&cold);
        let warm_sum = summarize(&warm);
        println!("{}", cold_sum.render("prefix-cold"));
        println!("{}", warm_sum.render("prefix-warm"));
        assert!(warm_sum.prefix_hit_rate > 0.0, "prefix leg recorded no store hits");
        assert!(warm_sum.prefix_tokens_skipped > 0, "prefix leg skipped no tokens");
        assert!(
            warm_sum.ttft_mean_ms < cold_sum.ttft_mean_ms,
            "prefix reuse did not cut mean TTFT ({:.1} ms warm vs {:.1} ms cold)",
            warm_sum.ttft_mean_ms,
            cold_sum.ttft_mean_ms
        );
        println!(
            "prefix reuse: hit-rate {:.0}% | {} tokens skipped | mean TTFT {:.1} -> {:.1} ms | \
             warm-vs-cold dTTFT {:.1} ms",
            warm_sum.prefix_hit_rate * 100.0,
            warm_sum.prefix_tokens_skipped,
            cold_sum.ttft_mean_ms,
            warm_sum.ttft_mean_ms,
            warm_sum.prefix_ttft_delta_ms
        );
        Some((cold_sum, warm_sum))
    } else {
        None
    };

    // optional fused-IndexGen leg (FASTP_SERVE_FUSED=1, sparse mode): the
    // same trace served with phase batching off (per-request stepping)
    // vs on (adaptive fused groups). Closed-loop submission lands the
    // whole backlog up front, so co-resident same-phase states are
    // available for fusion from the first layer; once two lanes fuse at
    // QKV they advance in lockstep and every later IndexGen fuses too.
    let fused_legs = if std::env::var("FASTP_SERVE_FUSED").as_deref() == Ok("1") {
        anyhow::ensure!(
            cfg.flex.is_some(),
            "FASTP_SERVE_FUSED needs sparse mode (IndexGen streams no K blocks when dense)"
        );
        let grouped = ServerOptions::builder().n_workers(workers.max(2)).policy(policy);
        let uopts = grouped.batch_phases(false).build().map_err(anyhow::Error::msg)?;
        let fopts = grouped.build().map_err(anyhow::Error::msg)?;
        let (mut unfused, _) = serve(&cfg, &weights, &trace, uopts, false)?;
        let (mut fused, _) = serve(&cfg, &weights, &trace, fopts, false)?;
        // completion order is scheduling-dependent; compare per request
        unfused.sort_by_key(|c| c.request_id);
        fused.sort_by_key(|c| c.request_id);
        for (a, b) in unfused.iter().zip(&fused) {
            assert_eq!(a.request_id, b.request_id);
            assert_eq!(a.run.first_token, b.run.first_token, "fused req {}", a.request_id);
            assert_eq!(a.run.logits_last, b.run.logits_last, "fused req {}", a.request_id);
        }
        let base_sum = summarize(&unfused);
        let fused_sum = summarize(&fused);
        println!("{}", base_sum.render("idx-unfused"));
        println!("{}", fused_sum.render("idx-fused"));
        assert!(
            fused_sum.sigu_fused_phases > 0,
            "fused leg formed no fused IndexGen groups (backlog never co-parked)"
        );
        let base_sigu: u64 = unfused.iter().map(|c| c.run.metrics.sigu_hbm_read_bytes).sum();
        let fused_sigu: u64 = fused.iter().map(|c| c.run.metrics.sigu_hbm_read_bytes).sum();
        assert!(
            fused_sigu < base_sigu,
            "fused IndexGen did not cut priced K-stream reads ({fused_sigu} vs {base_sigu} B)"
        );
        println!(
            "fused IndexGen: {} groups, mean width {:.2} | K-stream reads \
             {:.3} -> {:.3} GB ({:.1}% saved)",
            fused_sum.sigu_fused_phases,
            fused_sum.sigu_fused_width_mean,
            base_sigu as f64 / 1e9,
            fused_sigu as f64 / 1e9,
            (1.0 - fused_sigu as f64 / base_sigu as f64) * 100.0
        );
        Some((base_sum, fused_sum))
    } else {
        None
    };

    // optional continuous-batching leg (FASTP_SERVE_DECODE=1, dense
    // mode): a long Batch prefill anchor plus short Interactive requests
    // that continue into decode, served monolithic vs chunked on one
    // worker. Chunked slices release the engine at every slice boundary,
    // so the interactive admissions (and their decode steps) slot
    // between the anchor's chunks instead of waiting out its longest
    // phases — the Interactive-TTFT win gated below. Outputs and decode
    // tokens are bit-identical between the legs by construction.
    let decode_legs = if std::env::var("FASTP_SERVE_DECODE").as_deref() == Ok("1") {
        let mut dense = cfg.clone();
        dense.flex = None; // chunked prefill is dense-only
        let mut dtrace = RequestTrace {
            requests: vec![TraceRequest {
                id: 0,
                spec: PromptSpec { kind: PromptKind::Mixed, tokens: choices[2], seed: 3000 },
                arrival_us: 0,
                priority: Priority::Batch,
                decode_tokens: 0,
            }],
        };
        for i in 1..=3u64 {
            dtrace.requests.push(TraceRequest {
                id: i,
                spec: PromptSpec { kind: PromptKind::Mixed, tokens: choices[0], seed: 3000 + i },
                arrival_us: 0,
                priority: Priority::Interactive,
                decode_tokens: 8,
            });
        }
        let lanes = ServerOptions::builder().policy(Policy::Preemptive).max_inflight(4);
        let mopts = lanes.build().map_err(anyhow::Error::msg)?;
        let copts = lanes.prefill_chunk(block).build().map_err(anyhow::Error::msg)?;
        let (mut mono, _) = serve(&dense, &weights, &dtrace, mopts, false)?;
        let (mut chunked, _) = serve(&dense, &weights, &dtrace, copts, false)?;
        mono.sort_by_key(|c| c.request_id);
        chunked.sort_by_key(|c| c.request_id);
        for (a, b) in mono.iter().zip(&chunked) {
            assert_eq!(a.request_id, b.request_id);
            assert_eq!(a.run.first_token, b.run.first_token, "decode req {}", a.request_id);
            assert_eq!(a.run.logits_last, b.run.logits_last, "decode req {}", a.request_id);
            assert_eq!(
                a.decode_tokens, b.decode_tokens,
                "decode req {}: chunked serving changed generated tokens",
                a.request_id
            );
        }
        let mono_sum = summarize(&mono);
        let chunk_sum = summarize(&chunked);
        println!("{}", mono_sum.render("decode-mono "));
        println!("{}", chunk_sum.render("decode-chunk"));
        assert_eq!(chunk_sum.decode_tokens, 24, "three interactives x 8 tokens");
        assert!(chunk_sum.tpot_mean_us > 0.0, "decode leg reported no TPOT");
        println!(
            "continuous batching: {} decode tok | TPOT {:.2} ms | ITL p95 {:.2} ms | \
             {:.0} tok/s | interactive mean TTFT {:.1} -> {:.1} ms",
            chunk_sum.decode_tokens,
            chunk_sum.tpot_mean_us / 1e3,
            chunk_sum.itl_p95_us / 1e3,
            chunk_sum.decode_tokens_per_s,
            mono_sum.interactive.ttft_mean_ms,
            chunk_sum.interactive.ttft_mean_ms
        );
        assert!(
            chunk_sum.interactive.ttft_mean_ms < mono_sum.interactive.ttft_mean_ms,
            "chunked prefill did not cut Interactive mean TTFT vs monolithic \
             ({:.1} ms vs {:.1} ms)",
            chunk_sum.interactive.ttft_mean_ms,
            mono_sum.interactive.ttft_mean_ms
        );
        Some((mono_sum, chunk_sum))
    } else {
        None
    };

    // optional replica-sharding leg (FASTP_SERVE_REPLICAS=N, dense
    // mode): a closed-loop load-generator trace served once on a single
    // replica and once across N replicas at the same total thread
    // budget. Long requests are planted at ids ≡ 0 (mod stride) so a
    // placement-blind round-robin router lands every one of them on
    // replica 0 — the skew the cost model's priced backlog must undo.
    let replica_legs = if let Some(replicas) =
        std::env::var("FASTP_SERVE_REPLICAS").ok().and_then(|v| v.parse::<usize>().ok())
    {
        anyhow::ensure!(replicas > 0, "FASTP_SERVE_REPLICAS must be > 0");
        // empty env counts as unset (the CI matrix blanks unused knobs)
        let router = match std::env::var("FASTP_ROUTER").ok().filter(|s| !s.is_empty()) {
            None => RouterPolicy::CostModel,
            Some(name) => RouterPolicy::from_name(&name).ok_or_else(|| {
                anyhow::anyhow!("FASTP_ROUTER={name} (want round_robin|least_loaded|cost_model)")
            })?,
        };
        let mut dense = cfg.clone();
        dense.flex = None; // replica prefix affinity mirrors the dense-mode store
        let gen = LoadGen::new(n_requests.max(2) * 2, 2, &[choices[0], choices[2]], 2026);
        let mut ltrace = gen.trace();
        let stride = replicas.max(2);
        for r in ltrace.requests.iter_mut() {
            if r.id as usize % stride == 0 {
                r.spec.tokens = choices[2];
                r.priority = Priority::Batch;
            } else {
                r.spec.tokens = choices[0];
                r.priority = Priority::Interactive;
            }
        }
        let lane = ServerOptions::builder()
            .policy(Policy::Fcfs)
            .total_threads(workers.max(replicas));
        let solo_opts = lane.replicas(1).build().map_err(anyhow::Error::msg)?;
        let shard_opts = lane.replicas(replicas).build().map_err(anyhow::Error::msg)?;
        let solo = serve_cluster(&dense, &weights, &ltrace, solo_opts, RouterPolicy::RoundRobin)?;
        let shard = serve_cluster(&dense, &weights, &ltrace, shard_opts, router)?;
        // placement only moves work between identical engines: outputs
        // are bit-identical to single-replica serving, per request
        assert_eq!(solo.completions.len(), shard.completions.len());
        for (a, b) in solo.completions.iter().zip(&shard.completions) {
            assert_eq!(a.request_id, b.request_id);
            assert_eq!(a.run.first_token, b.run.first_token, "replica req {}", a.request_id);
            assert_eq!(a.run.logits_last, b.run.logits_last, "replica req {}", a.request_id);
        }
        let solo_sum = solo.summary();
        let shard_sum = shard.summary();
        println!("{}", solo_sum.render("replica-solo"));
        println!("{}", shard_sum.render(&format!("replica-x{replicas} {}", router.name())));
        if replicas > 1 {
            assert_eq!(shard_sum.replicas, replicas);
            assert!(
                shard_sum.replica_requests.iter().all(|&n| n > 0),
                "router starved a replica: {:?}",
                shard_sum.replica_requests
            );
        }
        // the cost-model gate: strictly lower mean TTFT than round-robin
        // at equal total threads (prefill-only, so e2e = user TTFT)
        let rr_sum = if router == RouterPolicy::CostModel && replicas > 1 {
            let rr =
                serve_cluster(&dense, &weights, &ltrace, shard_opts, RouterPolicy::RoundRobin)?;
            for (a, b) in rr.completions.iter().zip(&shard.completions) {
                assert_eq!(a.request_id, b.request_id);
                assert_eq!(a.run.first_token, b.run.first_token, "rr req {}", a.request_id);
                assert_eq!(a.run.logits_last, b.run.logits_last, "rr req {}", a.request_id);
            }
            let rr_sum = rr.summary();
            println!("{}", rr_sum.render(&format!("replica-x{replicas} round_robin")));
            println!(
                "replica routing: cost_model mean TTFT {:.1} ms vs round_robin {:.1} ms \
                 ({:.1}% saved) | util {:?}",
                shard_sum.e2e_mean_ms,
                rr_sum.e2e_mean_ms,
                (1.0 - shard_sum.e2e_mean_ms / rr_sum.e2e_mean_ms.max(1e-9)) * 100.0,
                shard_sum
                    .replica_utilization
                    .iter()
                    .map(|u| (u * 100.0).round() as i64)
                    .collect::<Vec<_>>()
            );
            assert!(
                shard_sum.e2e_mean_ms < rr_sum.e2e_mean_ms,
                "cost-model routing did not cut mean TTFT vs round-robin \
                 ({:.1} ms vs {:.1} ms)",
                shard_sum.e2e_mean_ms,
                rr_sum.e2e_mean_ms
            );
            Some(rr_sum)
        } else {
            None
        };
        Some((solo_sum, shard_sum, rr_sum))
    } else {
        None
    };

    let mut t = Table::new(&[
        "req", "class", "tokens", "TTFT (ms)", "queue (ms)", "phase-wait (ms)", "e2e (ms)",
        "yields", "density %", "hit %", "KV MB", "jobs",
    ]);
    for c in &pipelined {
        t.row(&[
            c.request_id.to_string(),
            c.priority.name().to_string(),
            c.run.metrics.context_tokens.to_string(),
            fnum(c.run.metrics.ttft_us / 1e3),
            fnum(c.queue_us / 1e3),
            fnum(c.pipeline_wait_us / 1e3),
            fnum(c.e2e_us / 1e3),
            c.preemptions.to_string(),
            fnum(c.run.metrics.density * 100.0),
            fnum(c.run.metrics.cache_hit_rate * 100.0),
            fnum(c.run.metrics.hbm_read_bytes as f64 / 1e6),
            c.run.metrics.jobs.to_string(),
        ]);
    }
    println!("-- pipelined server, per request --");
    t.print();

    let ser = summarize(&serial);
    let pip = summarize(&pipelined);
    println!("{}", ser.render("serial   "));
    println!("{}", pip.render("pipelined"));
    let fcfs_sum = fcfs_baseline.as_ref().map(|(c, _)| summarize(c));
    if let Some(f) = &fcfs_sum {
        println!("{}", f.render("fcfs base"));
    }

    // machine-readable summary for the CI serving artifact
    if let Ok(path) = std::env::var("FASTP_SERVE_JSON") {
        let mut legs = vec![ser.to_json("serial"), pip.to_json("pipelined")];
        if let Some(f) = &fcfs_sum {
            legs.push(f.to_json("pipelined_fcfs_baseline"));
        }
        if let Some((c, w)) = &prefix_legs {
            legs.push(c.to_json("prefix_cold"));
            legs.push(w.to_json("prefix_warm"));
        }
        if let Some((u, f)) = &fused_legs {
            legs.push(u.to_json("indexgen_unfused"));
            legs.push(f.to_json("indexgen_fused"));
        }
        if let Some((m, c)) = &decode_legs {
            legs.push(m.to_json("decode_monolithic"));
            legs.push(c.to_json("decode_chunked"));
        }
        if let Some((solo, shard, rr)) = &replica_legs {
            legs.push(solo.to_json("replica_solo"));
            legs.push(shard.to_json("replica_sharded"));
            if let Some(rr) = rr {
                legs.push(rr.to_json("replica_round_robin"));
            }
        }
        let json = format!(
            "{{\"policy\": \"{policy:?}\", \"arrival\": \"{}\", \"legs\": [{}]}}\n",
            if open_loop { "open" } else { "closed" },
            legs.join(", ")
        );
        std::fs::write(&path, &json)?;
        println!("wrote serving summary to {path}");
    }

    // the preemptive acceptance gates (CI serving-matrix): the long
    // Batch anchor must actually have yielded phase slots, and on the
    // deterministic closed-loop backlog the Interactive-class mean TTFT
    // must beat head-of-line-blocking FCFS at equal total threads
    if policy == Policy::Preemptive && n_requests > 1 {
        assert!(
            pip.preemptions > 0,
            "preemptive leg recorded no phase-boundary yields (batch anchor never preempted)"
        );
        let f = fcfs_sum.as_ref().unwrap();
        println!(
            "interactive mean TTFT: preemptive {:.0} ms vs FCFS {:.0} ms ({:.1}% saved)",
            pip.interactive.ttft_mean_ms,
            f.interactive.ttft_mean_ms,
            (1.0 - pip.interactive.ttft_mean_ms / f.interactive.ttft_mean_ms.max(1e-9)) * 100.0
        );
        if !open_loop {
            assert!(
                pip.interactive.ttft_mean_ms < f.interactive.ttft_mean_ms,
                "preemptive SJF+priority did not cut Interactive mean TTFT vs FCFS \
                 ({:.1} ms vs {:.1} ms)",
                pip.interactive.ttft_mean_ms,
                f.interactive.ttft_mean_ms
            );
        }
    }
    let total_tokens: usize = trace.requests.iter().map(|r| r.spec.tokens).sum();
    println!(
        "wall serial {:.1}s -> pipelined {:.1}s | pipelined throughput {:.0} tok/s | \
         mean TTFT saving {:.1}% | queue saving {:.1}%",
        serial_wall,
        pipe_wall,
        total_tokens as f64 / pipe_wall,
        pip.ttft_saving_pct(&ser),
        if ser.queue_mean_ms > 0.0 {
            (1.0 - pip.queue_mean_ms / ser.queue_mean_ms) * 100.0
        } else {
            0.0
        },
    );

    // hardware estimates for the same real index sets (first completion)
    if let Some(c) = pipelined.first() {
        let ctx_tokens = c.run.metrics.context_tokens;
        let f = simulate_prefill(&u280_fast_prefill(), &model, ctx_tokens, &c.run.index_sets);
        let g = simulate_gpu_prefill(&a5000(), &model, ctx_tokens, &c.run.index_sets);
        println!(
            "\nhardware estimates for this trace (same index sets):\n  \
             U280-sim  {:.1} ms, {:.3} J (hit {:.0}%)\n  \
             A5000-mdl {:.1} ms, {:.3} J\n  \
             speedup {:.2}x, energy-eff {:.2}x",
            f.ttft_ms,
            f.energy_j,
            f.cache_hit_rate * 100.0,
            g.ttft_ms,
            g.energy_j,
            g.ttft_ms / f.ttft_ms,
            f.tokens_per_joule() / g.tokens_per_joule()
        );
    }

    // batch-merged estimate: co-resident lanes share weight streams and
    // merge SAU waves through the schedule spine — vs N independent solos
    let k = pipelined.len().min(3);
    if k > 1 {
        let lane_s: Vec<usize> =
            pipelined[..k].iter().map(|c| c.run.metrics.context_tokens).collect();
        let lane_sets: Vec<_> =
            pipelined[..k].iter().map(|c| c.run.index_sets.as_slice()).collect();
        let u280 = u280_fast_prefill();
        let batch = simulate_prefill_batch(&u280, &model, &lane_s, &lane_sets);
        let solo_sum: f64 = pipelined[..k]
            .iter()
            .map(|c| {
                simulate_prefill(&u280, &model, c.run.metrics.context_tokens, &c.run.index_sets)
                    .ttft_ms
            })
            .sum();
        println!(
            "U280 batch={k} sim: TTFT {:.1} ms vs {:.1} ms as {} solos ({:.1}% saved) | \
             HBM read {:.3} GB | per-lane KV MB: {}",
            batch.combined.ttft_ms,
            solo_sum,
            k,
            (1.0 - batch.combined.ttft_ms / solo_sum.max(1e-9)) * 100.0,
            batch.combined.traffic.hbm_read_bytes / 1e9,
            batch
                .lanes
                .iter()
                .map(|l| format!("{:.1}", l.hbm_read_bytes / 1e6))
                .collect::<Vec<_>>()
                .join("/")
        );
    }
    Ok(())
}
