//! Quickstart: run one sparse prefill on the tiny model and print the
//! first token and pipeline statistics.
//!
//! Prefers the AOT artifacts on the PJRT CPU client when they exist
//! (`make artifacts` + the `pjrt` feature); otherwise falls back to the
//! native tiled parallel kernels, which need nothing but this crate:
//!
//!     cargo run --release --example quickstart
//!     FASTP_THREADS=4 cargo run --release --example quickstart

use anyhow::Result;
use fast_prefill::config::TINY;
use fast_prefill::coordinator::{Engine, EngineConfig};
use fast_prefill::workload::prompts::{PromptKind, PromptSpec};

fn main() -> Result<()> {
    // 1. configure: tiny 2-layer model, default FlexPrefill parameters
    //    (tau=0.1, gamma=0.9), dual-tier KV cache.
    let cfg = EngineConfig::new(TINY.clone());

    // 2. load artifacts + compile every entry point on the PJRT CPU
    //    client — or fall back to the artifact-free native engine.
    let mut engine = match Engine::new("artifacts", cfg) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("artifacts unavailable ({e}); using native tiled kernels");
            Engine::new_native(EngineConfig::new(TINY.clone()))?
        }
    };
    println!("backend: {}", engine.platform());

    // 3. synthesize a 1K-token prompt with mixed attention structure.
    let prompt = PromptSpec { kind: PromptKind::Mixed, tokens: 1024, seed: 42 };
    let tokens = prompt.generate();

    // 4. prefill: chunked KV generation -> SIGU -> block-major SAU -> FFN.
    let run = engine.prefill(0, &tokens)?;

    println!("first generated token : {}", run.first_token);
    println!("TTFT (functional)     : {:.1} ms", run.metrics.ttft_us / 1e3);
    println!("attention density     : {:.1} %", run.metrics.density * 100.0);
    println!("query-aware heads     : {:.1} %", run.metrics.query_aware_frac * 100.0);
    println!("SAU jobs              : {}", run.metrics.jobs);
    println!("KV cache hit rate     : {:.1} %", run.metrics.cache_hit_rate * 100.0);
    for (layer, pats) in run.patterns.iter().enumerate() {
        let qa = pats
            .iter()
            .filter(|p| **p == fast_prefill::flexprefill::HeadPattern::QueryAware)
            .count();
        println!("  layer {layer}: {qa}/{} heads query-aware", pats.len());
    }
    Ok(())
}
