//! Context-length sweep: functional TTFT on the tiny model (measured on
//! CPU through the PJRT pipeline) side by side with the simulated U280 and
//! modeled A5000 numbers for the same index sets — showing how the three
//! views of the system line up.
//!
//!     cargo run --release --example context_sweep

use anyhow::Result;
use fast_prefill::config::{a5000, u280_fast_prefill, TINY};
use fast_prefill::coordinator::{Engine, EngineConfig};
use fast_prefill::gpu_model::simulate_gpu_prefill;
use fast_prefill::metrics::fmt_ctx;
use fast_prefill::sim::simulate_prefill;
use fast_prefill::util::table::{fnum, Table};
use fast_prefill::workload::prompts::{PromptKind, PromptSpec};

fn main() -> Result<()> {
    let mut cfg = EngineConfig::new(TINY.clone());
    cfg.native_sau = true; // fast functional path; PJRT SAU in quickstart
    let mut engine = match Engine::new("artifacts", cfg) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("artifacts unavailable ({e}); using native tiled kernels");
            Engine::new_native(EngineConfig::new(TINY.clone()))?
        }
    };
    let fpga = u280_fast_prefill();
    let gpu = a5000();

    let mut t = Table::new(&[
        "context", "CPU-functional (ms)", "U280-sim (ms)", "A5000-model (ms)",
        "density %", "hit %",
    ]);
    for tokens in [512usize, 1024, 2048, 4096] {
        let prompt = PromptSpec { kind: PromptKind::Mixed, tokens, seed: 9 };
        let run = engine.prefill(0, &prompt.generate())?;
        // drive both performance models with the *same real* index sets
        let f = simulate_prefill(&fpga, &TINY, tokens, &run.index_sets);
        let g = simulate_gpu_prefill(&gpu, &TINY, tokens, &run.index_sets);
        t.row(&[
            fmt_ctx(tokens),
            fnum(run.metrics.ttft_us / 1e3),
            fnum(f.ttft_ms),
            fnum(g.ttft_ms),
            fnum(run.metrics.density * 100.0),
            fnum(run.metrics.cache_hit_rate * 100.0),
        ]);
    }
    t.print();
    println!("\nNote: the tiny model is linear-layer dominated; paper-scale");
    println!("figures (Fig. 5/6) come from `cargo bench --bench fig5_ttft`.");
    Ok(())
}
