//! Minimal, API-compatible subset of the `anyhow` crate, vendored because
//! the build environment has no access to crates.io.
//!
//! Implements exactly what this repository uses: [`Error`] with a context
//! chain, [`Result`], the [`Context`] extension trait for `Result`/`Option`,
//! and the `anyhow!` / `bail!` / `ensure!` macros. Display follows anyhow's
//! convention: `{}` prints the outermost message, `{:#}` prints the whole
//! chain joined by `": "`, and `{:?}` prints the message plus a
//! `Caused by:` list.

use std::fmt;

/// Error type: an outermost message plus an optional cause chain.
///
/// Deliberately does NOT implement `std::error::Error` — exactly like the
/// real anyhow — so the blanket `From<E: std::error::Error>` impl below
/// cannot collide with the reflexive `From<Error> for Error`.
pub struct Error {
    msg: String,
    cause: Option<Box<Error>>,
}

/// `anyhow::Result<T>` alias with the crate error as the default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string(), cause: None }
    }

    /// Wrap `self` with an outer context message.
    pub fn context<C: fmt::Display>(self, ctx: C) -> Error {
        Error { msg: ctx.to_string(), cause: Some(Box::new(self)) }
    }

    /// Iterate the chain from the outermost message inward.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut items = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            items.push(e.msg.as_str());
            cur = e.cause.as_deref();
        }
        items.into_iter()
    }

    /// The innermost message of the chain.
    pub fn root_cause(&self) -> &str {
        let mut cur = self;
        while let Some(c) = cur.cause.as_deref() {
            cur = c;
        }
        &cur.msg
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            let parts: Vec<&str> = self.chain().collect();
            write!(f, "{}", parts.join(": "))
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let causes: Vec<&str> = self.chain().skip(1).collect();
        if !causes.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in causes.iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

/// Any std error converts into [`Error`], flattening its source chain.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain: Vec<String> = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        let mut out: Option<Error> = None;
        for msg in chain.into_iter().rev() {
            out = Some(Error { msg, cause: out.map(Box::new) });
        }
        out.expect("non-empty chain")
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`, mirroring anyhow's.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, ctx: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, ctx: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, ctx: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = Err::<(), _>(io_err()).context("reading manifest").unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: missing file");
    }

    #[test]
    fn with_context_and_option() {
        let e = None::<u32>.with_context(|| format!("slot {}", 3)).unwrap_err();
        assert_eq!(format!("{e}"), "slot 3");
        let ok: Result<u32> = Some(7u32).context("present");
        assert_eq!(ok.unwrap(), 7);
    }

    #[test]
    fn macros_work() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            if x > 100 {
                bail!("too large");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(format!("{}", f(-1).unwrap_err()), "negative input -1");
        assert_eq!(format!("{}", f(200).unwrap_err()), "too large");
        let e = anyhow!("value {} bad", 9);
        assert_eq!(format!("{e}"), "value 9 bad");
    }

    #[test]
    fn debug_prints_cause_chain() {
        let e: Error = Err::<(), _>(io_err()).context("outer").unwrap_err();
        let dbg = format!("{e:?}");
        assert!(dbg.contains("outer"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("missing file"));
        assert_eq!(e.root_cause(), "missing file");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn g() -> Result<String> {
            let s = std::str::from_utf8(&[0xFF])?;
            Ok(s.to_string())
        }
        assert!(g().is_err());
    }
}
