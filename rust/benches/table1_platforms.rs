//! Table I: hardware parameters of the GPU and FPGA platforms (as encoded
//! in the configuration the simulator and cost model consume).

use fast_prefill::config::{a5000, u280_fast_prefill};
use fast_prefill::util::table::Table;

fn main() {
    println!("== Table I: hardware parameters ==\n");
    let g = a5000();
    let f = u280_fast_prefill();
    let mut t = Table::new(&["Platform", "NVIDIA A5000 GPU", "AMD U280 FPGA"]);
    t.row_strs(&["Compute units", "8,192 CUDA cores", "9,024 DSP48s"]);
    t.row(&[
        "Frequency (MHz)".into(),
        format!("{:.0}", g.freq_mhz),
        format!("{:.0} (achieved)", f.freq_mhz),
    ]);
    t.row(&[
        "TOPS".into(),
        format!("{:.0} (INT8 dense)", g.int8_tops),
        format!("{:.1} (hybrid MPU + SFU)", f.peak_tops() + 1.1),
    ]);
    t.row(&[
        "Memory (GB)".into(),
        format!("{:.0}", g.mem_gb),
        format!("{:.0} (HBM) & {:.0} (DDR)", f.hbm_gb, f.ddr_gb),
    ]);
    t.row(&[
        "BW (GB/s)".into(),
        format!("{:.0}", g.mem_bw_gbs),
        format!("{:.0} (DDR) & {:.0} (HBM)", f.ddr_bw_gbs, f.hbm_bw_gbs),
    ]);
    t.print();
    println!("\n(The FPGA TOPS line adds the SFU/auxiliary DSP MACs to the MPU peak");
    println!("of {:.1} TOPS, matching the paper's 5.4 TOPS accounting.)", f.peak_tops());
}
