//! Figure 7: impact of the liveness-driven dual-tier cache on TTFT
//! (Llama-3.2-3B). Compares the full design against the cacheless design
//! (on-demand short-burst gathers, no prefetch) under identical compute.
//! Both designs price the same canonical `ScheduleWalk` events — only the
//! per-event cost model differs.
//!
//! Env overrides for smoke runs: `FASTP_SIM_MODEL` picks the model config
//! (e.g. `tiny` in CI), `FASTP_SIM_MAX_CTX` caps the context sweep.

use fast_prefill::config::{
    by_name, paper_context_lengths, u280_cacheless, u280_fast_prefill, FlexParams, LLAMA32_3B,
};
use fast_prefill::metrics::fmt_ctx;
use fast_prefill::sim::{simulate_prefill, simulate_prefill_batch, synth_model_indices, HeadMix};
use fast_prefill::util::table::{fnum, Table};

fn main() {
    let cfg = std::env::var("FASTP_SIM_MODEL")
        .ok()
        .and_then(|n| by_name(&n).cloned())
        .unwrap_or_else(|| LLAMA32_3B.clone());
    let max_ctx: usize = std::env::var("FASTP_SIM_MAX_CTX")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(usize::MAX);
    println!("== Figure 7: cache ablation, TTFT (ms), {} ==\n", cfg.name);
    let with = u280_fast_prefill();
    let without = u280_cacheless();
    let params = FlexParams::default();
    let mix = HeadMix::default();

    let mut t = Table::new(&[
        "context", "cached TTFT", "cacheless TTFT", "TTFT ratio",
        "cached SAU", "cacheless SAU", "SAU ratio", "hit %",
    ]);
    let contexts: Vec<usize> =
        paper_context_lengths().into_iter().filter(|&c| c <= max_ctx).collect();
    for &ctx in &contexts {
        let idx = synth_model_indices(cfg.n_heads, 2, ctx / 128, 32, &mix, &params, 7);
        let a = simulate_prefill(&with, &cfg, ctx, &idx);
        let b = simulate_prefill(&without, &cfg, ctx, &idx);
        t.row(&[
            fmt_ctx(ctx),
            fnum(a.ttft_ms),
            fnum(b.ttft_ms),
            format!("{:.2}x", b.ttft_ms / a.ttft_ms),
            fnum(a.t_sau_ms),
            fnum(b.t_sau_ms),
            format!("{:.2}x", b.t_sau_ms / a.t_sau_ms),
            fnum(a.cache_hit_rate * 100.0),
        ]);
    }
    t.print();

    // batch-merged point (the spine's batched consumer): two co-resident
    // lanes of the smallest context vs two independent solo sims
    if let Some(&ctx) = contexts.first() {
        let la = synth_model_indices(cfg.n_heads, 2, ctx / 128, 32, &mix, &params, 8);
        let lb = synth_model_indices(cfg.n_heads, 2, ctx / 128, 32, &mix, &params, 9);
        let solo = simulate_prefill(&with, &cfg, ctx, &la).ttft_ms
            + simulate_prefill(&with, &cfg, ctx, &lb).ttft_ms;
        let batch =
            simulate_prefill_batch(&with, &cfg, &[ctx, ctx], &[la.as_slice(), lb.as_slice()]);
        println!(
            "\nbatch=2 @ {}: merged TTFT {:.1} ms vs {:.1} ms solo-sum ({:.1}% saved, \
             per-lane hit {:.0}%/{:.0}%)",
            fmt_ctx(ctx),
            batch.combined.ttft_ms,
            solo,
            (1.0 - batch.combined.ttft_ms / solo.max(1e-9)) * 100.0,
            batch.lanes[0].cache_hit_rate * 100.0,
            batch.lanes[1].cache_hit_rate * 100.0,
        );
    }
    println!("\npaper: ~2.5x TTFT improvement at a ~65% hit rate (16 MB cache).");
    println!("The attention-stage (SAU) ratio is the direct analogue of the paper's");
    println!("claim; the whole-TTFT ratio is diluted by the linear layers, which the");
    println!("cache cannot accelerate — see EXPERIMENTS.md Fidelity notes.");
}
