//! Figure 7: impact of the liveness-driven dual-tier cache on TTFT
//! (Llama-3.2-3B). Compares the full design against the cacheless design
//! (on-demand short-burst gathers, no prefetch) under identical compute.

use fast_prefill::config::{paper_context_lengths, u280_cacheless, u280_fast_prefill, FlexParams, LLAMA32_3B};
use fast_prefill::metrics::fmt_ctx;
use fast_prefill::sim::{simulate_prefill, synth_model_indices, HeadMix};
use fast_prefill::util::table::{fnum, Table};

fn main() {
    println!("== Figure 7: cache ablation, TTFT (ms), Llama-3.2-3B ==\n");
    let with = u280_fast_prefill();
    let without = u280_cacheless();
    let cfg = &LLAMA32_3B;
    let params = FlexParams::default();
    let mix = HeadMix::default();

    let mut t = Table::new(&[
        "context", "cached TTFT", "cacheless TTFT", "TTFT ratio",
        "cached SAU", "cacheless SAU", "SAU ratio", "hit %",
    ]);
    for ctx in paper_context_lengths() {
        let idx = synth_model_indices(cfg.n_heads, 2, ctx / 128, 32, &mix, &params, 7);
        let a = simulate_prefill(&with, cfg, ctx, &idx);
        let b = simulate_prefill(&without, cfg, ctx, &idx);
        t.row(&[
            fmt_ctx(ctx),
            fnum(a.ttft_ms),
            fnum(b.ttft_ms),
            format!("{:.2}x", b.ttft_ms / a.ttft_ms),
            fnum(a.t_sau_ms),
            fnum(b.t_sau_ms),
            format!("{:.2}x", b.t_sau_ms / a.t_sau_ms),
            fnum(a.cache_hit_rate * 100.0),
        ]);
    }
    t.print();
    println!("\npaper: ~2.5x TTFT improvement at a ~65% hit rate (16 MB cache).");
    println!("The attention-stage (SAU) ratio is the direct analogue of the paper's");
    println!("claim; the whole-TTFT ratio is diluted by the linear layers, which the");
    println!("cache cannot accelerate — see EXPERIMENTS.md Fidelity notes.");
}
