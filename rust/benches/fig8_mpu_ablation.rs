//! Figure 8: impact of the Hybrid MPU on TTFT (Llama-3.2-3B) — the full
//! twelve-array hybrid (6 DSP + 6 LUT bit-plane) vs the DSP-only design,
//! plus the LUT-idle statistic the paper quotes.

use fast_prefill::config::{paper_context_lengths, u280_dsp_only, u280_fast_prefill, FlexParams, LLAMA32_3B};
use fast_prefill::metrics::fmt_ctx;
use fast_prefill::sim::{resource_report, simulate_prefill, synth_model_indices, HeadMix};
use fast_prefill::util::table::{fnum, Table};

fn main() {
    println!("== Figure 8: Hybrid MPU ablation, TTFT (ms), Llama-3.2-3B ==\n");
    let hybrid = u280_fast_prefill();
    let dsp = u280_dsp_only();
    let cfg = &LLAMA32_3B;
    let params = FlexParams::default();
    let mix = HeadMix::default();

    let mut t = Table::new(&["context", "hybrid TTFT", "DSP-only TTFT", "speedup"]);
    let mut ratios = Vec::new();
    for ctx in paper_context_lengths() {
        let idx = synth_model_indices(cfg.n_heads, 2, ctx / 128, 32, &mix, &params, 8);
        let a = simulate_prefill(&hybrid, cfg, ctx, &idx);
        let b = simulate_prefill(&dsp, cfg, ctx, &idx);
        let r = b.ttft_ms / a.ttft_ms;
        ratios.push(r);
        t.row(&[fmt_ctx(ctx), fnum(a.ttft_ms), fnum(b.ttft_ms), format!("{r:.2}x")]);
    }
    t.print();
    let mean: f64 = ratios.iter().sum::<f64>() / ratios.len() as f64;
    println!("\nmean hybrid-MPU speedup {mean:.2}x (paper: ~1.8x)");

    let dsp_rep = resource_report(&dsp);
    let idle_luts = 100.0 * (1.0 - dsp_rep.total.lut_k / dsp_rep.available.lut_k);
    println!("LUTs idle without the hybrid MPU: {idle_luts:.0}% (paper: ~85%)");
}
