//! Hot-path microbenchmarks (EXPERIMENTS.md §Perf): the L3 kernels the
//! coordinator spends its time in, timed with the local harness. Run via
//! `cargo bench --bench hotpath_micro`.
//!
//! Besides the human-readable report, a machine-readable JSON summary of
//! the kernel-backend comparison is written to `FASTP_BENCH_JSON`
//! (default `target/hotpath_micro.json`, relative to the bench cwd —
//! cargo runs benches from the package root, `rust/`). CI pins it to the
//! workspace root and uploads it as the per-PR perf artifact.

use std::sync::{Arc, Mutex};

use fast_prefill::config::{FlexParams, BLOCK, TINY};
use fast_prefill::coordinator::joblist::build_schedule;
use fast_prefill::coordinator::{Engine, EngineConfig, PrefillArgs, PrefixConfig, PrefixStore};
use fast_prefill::flexprefill::{coverage, scores};
use fast_prefill::kvcache::LivenessCache;
use fast_prefill::model::forward::{attn_step_w8a8, prefill_reference_ctx};
use fast_prefill::model::ModelWeights;
use fast_prefill::quant::{int8_matmul_bt, quant_scale, quantize_with};
use fast_prefill::sim::{simulate_prefill, synth_model_indices, HeadMix};
use fast_prefill::tensor::ops;
use fast_prefill::tensor::simd::{self, Backend};
use fast_prefill::tensor::tile::{self, KernelCtx};
use fast_prefill::tensor::{MatF32, MatI8};
use fast_prefill::util::bench::{bench_for, black_box};
use fast_prefill::util::pool::WorkerPool;
use fast_prefill::util::prng::Prng;
use fast_prefill::workload::prompts::{PromptKind, PromptSpec};

fn rand_mat(rng: &mut Prng, r: usize, c: usize) -> MatI8 {
    MatI8 { rows: r, cols: c, data: (0..r * c).map(|_| rng.i8_sym()).collect() }
}

fn main() {
    let mut rng = Prng::new(0xBE7C);
    println!("== hot-path microbenchmarks ==\n");

    let detected = simd::detect();
    println!(
        "kernel dispatch: detected {} / active {} on {}\n",
        detected.name(),
        simd::active().name(),
        std::env::consts::ARCH
    );

    // --- int8 score tile (the SAU/SIGU inner matmul), per backend ---
    let q = rand_mat(&mut rng, BLOCK, 64);
    let k = rand_mat(&mut rng, BLOCK, 64);
    let r = bench_for("int8_matmul_bt 128x64x128 (score tile)", 300, 20, || {
        black_box(int8_matmul_bt(&q, &k));
    });
    println!("{r}");
    let macs = (BLOCK * BLOCK * 64) as f64;
    println!("    -> {:.2} GMAC/s", macs / r.mean_ns);
    let r_tile_scalar = bench_for("int8_matmul_bt score tile (scalar backend)", 300, 20, || {
        black_box(tile::int8_matmul_bt_with_bk(&q, &k, 64, Backend::Scalar));
    });
    println!("{r_tile_scalar}");
    let name = format!("int8_matmul_bt score tile ({} backend)", detected.name());
    let r_tile_simd = bench_for(&name, 300, 20, || {
        black_box(tile::int8_matmul_bt_with_bk(&q, &k, 64, detected));
    });
    println!("{r_tile_simd}");
    println!(
        "    -> SIMD score-tile speedup {:.2}x",
        r_tile_scalar.mean_ns / r_tile_simd.mean_ns
    );

    // --- tiled vs scalar kernels on a linear-layer-shaped matmul ---
    let xa = rand_mat(&mut rng, BLOCK, 768);
    let xb = rand_mat(&mut rng, 768, 768);
    let r_scalar = bench_for("int8_matmul 128x768x768 (scalar oracle)", 300, 5, || {
        black_box(fast_prefill::quant::int8_matmul(&xa, &xb));
    });
    println!("{r_scalar}");
    let r_tiled = bench_for("int8_matmul 128x768x768 (tiled)", 300, 5, || {
        black_box(tile::int8_matmul(&xa, &xb));
    });
    println!("{r_tiled}");
    println!("    -> tiling speedup {:.2}x", r_scalar.mean_ns / r_tiled.mean_ns);

    // --- full W8A8 SAU job (score + softmax + PV + accumulate) ---
    let v = rand_mat(&mut rng, BLOCK, 64);
    let mut m = vec![-1e30f32; BLOCK];
    let mut l = vec![0.0f32; BLOCK];
    let mut acc = MatF32::zeros(BLOCK, 64);
    let r = bench_for("attn_step_w8a8 (one SAU job)", 300, 20, || {
        attn_step_w8a8(&q, 0.02, &k, 0.02, &v, 0.02, &mut m, &mut l, &mut acc, false);
        black_box(&acc);
    });
    println!("{r}");

    // --- SIGU streaming scores over 64 blocks ---
    let kblocks: Vec<(MatI8, f32)> = (0..64).map(|_| (rand_mat(&mut rng, BLOCK, 64), 0.02)).collect();
    let r = bench_for("stream_head_scores (64 K blocks)", 500, 5, || {
        black_box(scores::stream_head_scores(&q, 0.02, &kblocks));
    });
    println!("{r}");

    // --- coverage selection at 128K scale (1024 blocks) ---
    let scores_v: Vec<f32> = (0..1024).map(|_| rng.f32()).collect();
    let r = bench_for("coverage_select (1024 blocks)", 200, 50, || {
        black_box(coverage::coverage_select(&scores_v, 0.9));
    });
    println!("{r}");

    // --- job-list bucketization at 128K scale ---
    let idx = synth_model_indices(24, 1, 1024, 32, &HeadMix::default(), &FlexParams::default(), 3);
    let r = bench_for("build_schedule (24 heads x 1024 blocks)", 1000, 3, || {
        black_box(build_schedule(&idx[0], 3, 16));
    });
    println!("{r}");

    // --- cache operations ---
    let sched = build_schedule(&idx[0], 3, 16);
    let r = bench_for("liveness cache full schedule walk", 500, 5, || {
        let mut cache = LivenessCache::new(512, 0.5, 256);
        cache.init_uses(sched.uses.iter().copied());
        fast_prefill::coordinator::ScheduleWalk::solo(&sched)
            .drive(std::slice::from_mut(&mut cache));
        black_box(cache.stats());
    });
    println!("{r}");

    // --- full simulator run at 128K (the bench-suite inner loop) ---
    let cfg = fast_prefill::config::LLAMA32_3B.clone();
    let big_idx = synth_model_indices(cfg.n_heads, 2, 1024, 32, &HeadMix::default(), &FlexParams::default(), 9);
    let fpga = fast_prefill::config::u280_fast_prefill();
    let r = bench_for("simulate_prefill llama3.2-3b @128K", 2000, 2, || {
        black_box(simulate_prefill(&fpga, &cfg, 131072, &big_idx));
    });
    println!("{r}");

    // --- 4K-context native-SAU prefill: scalar vs tiled parallel core ---
    // (the acceptance benchmark of the block-major kernel layer: the
    // tiled parallel path with FASTP_THREADS=4 must beat the scalar
    // single-threaded path by >= 2x, with bit-identical outputs)
    let w = ModelWeights::generate(&TINY, 0xBEEF);
    let toks = PromptSpec { kind: PromptKind::Mixed, tokens: 4096, seed: 3 }.generate();
    let flex = FlexParams::default();
    // tile = usize::MAX degenerates the blocked loops to the scalar
    // oracle's order — the pre-refactor hot path
    let scalar_ctx = KernelCtx {
        pool: WorkerPool::single_threaded(),
        tile: usize::MAX,
        backend: Backend::Scalar,
        tune: None,
    };
    let par_ctx = KernelCtx::with_threads(4);
    let r_scalar = bench_for("prefill 4K native-SAU (scalar, 1 thread)", 2000, 2, || {
        black_box(prefill_reference_ctx(&w, &toks, Some(&flex), &scalar_ctx));
    });
    println!("{r_scalar}");
    let r_par = bench_for("prefill 4K native-SAU (tiled, 4 threads)", 2000, 2, || {
        black_box(prefill_reference_ctx(&w, &toks, Some(&flex), &par_ctx));
    });
    println!("{r_par}");
    println!(
        "    -> parallel kernel core speedup {:.2}x (target >= 2x)",
        r_scalar.mean_ns / r_par.mean_ns
    );
    let a = prefill_reference_ctx(&w, &toks, Some(&flex), &KernelCtx::with_threads(1));
    let b = prefill_reference_ctx(&w, &toks, Some(&flex), &par_ctx);
    assert_eq!(a.logits_last, b.logits_last, "thread count changed logits");
    assert_eq!(a.first_token, b.first_token);
    println!("    -> FASTP_THREADS=1 vs 4: first-token logits bit-identical");

    // --- 4K-context native-SAU prefill: scalar vs SIMD micro-kernels ---
    // (the acceptance benchmark of the SIMD dispatch layer: same tile
    // size, same single thread — only the inner-loop backend differs.
    // Target >= 1.5x on a vector-capable host, outputs bit-identical.)
    let bk_scalar_ctx = KernelCtx::single_threaded().with_backend(Backend::Scalar);
    let bk_simd_ctx = KernelCtx::single_threaded().with_backend(detected);
    let r_bk_scalar = bench_for("prefill 4K native-SAU (scalar backend)", 2000, 2, || {
        black_box(prefill_reference_ctx(&w, &toks, Some(&flex), &bk_scalar_ctx));
    });
    println!("{r_bk_scalar}");
    let name = format!("prefill 4K native-SAU ({} backend)", detected.name());
    let r_bk_simd = bench_for(&name, 2000, 2, || {
        black_box(prefill_reference_ctx(&w, &toks, Some(&flex), &bk_simd_ctx));
    });
    println!("{r_bk_simd}");
    let simd_speedup = r_bk_scalar.mean_ns / r_bk_simd.mean_ns;
    println!(
        "    -> SIMD backend speedup {:.2}x (target >= 1.5x on a vector host; \
         detected {})",
        simd_speedup,
        detected.name()
    );
    let sc = prefill_reference_ctx(&w, &toks, Some(&flex), &bk_scalar_ctx);
    let sv = prefill_reference_ctx(&w, &toks, Some(&flex), &bk_simd_ctx);
    assert_eq!(sc.logits_last, sv.logits_last, "kernel backend changed logits");
    assert_eq!(sc.first_token, sv.first_token);
    assert_eq!(sc.hidden.data, sv.hidden.data, "kernel backend changed hidden state");
    println!("    -> scalar vs {} backends: outputs bit-identical", detected.name());

    // --- 4K-context prefix KV reuse: cold vs warm (dense mode) ---
    // (the acceptance benchmark of the cross-request prefix store: warm
    // re-serves a prompt whose prefix chain is resident, resuming at
    // block n-1 and skipping the covered blocks' QKV/SIGU/FFN work —
    // with outputs bit-identical to the cold run)
    let mut pcfg = EngineConfig::new_native(TINY.clone());
    pcfg.flex = None; // the prefix store is dense-mode only
    pcfg.threads = 1;
    let mut eng_cold = Engine::new_native(pcfg.clone()).unwrap();
    let r_cold = bench_for("prefill 4K dense (cold, no prefix store)", 2000, 2, || {
        black_box(eng_cold.prefill(0, &toks).unwrap());
    });
    println!("{r_cold}");
    let mut eng_warm = Engine::new_native(pcfg.clone()).unwrap();
    eng_warm.prefix = Some(Arc::new(Mutex::new(PrefixStore::new(
        pcfg.model.name,
        pcfg.weight_seed,
        PrefixConfig::default(),
    ))));
    eng_warm.prefill(1, &toks).unwrap(); // primes the store
    let r_warm = bench_for("prefill 4K dense (warm, prefix chain resident)", 2000, 2, || {
        black_box(eng_warm.prefill(2, &toks).unwrap());
    });
    println!("{r_warm}");
    let warm_run = eng_warm.prefill(3, &toks).unwrap();
    assert!(warm_run.metrics.prefix_tokens_skipped > 0, "warm run never resumed");
    let cold_run = eng_cold.prefill(4, &toks).unwrap();
    assert_eq!(warm_run.first_token, cold_run.first_token, "prefix reuse changed first token");
    assert_eq!(warm_run.logits_last, cold_run.logits_last, "prefix reuse changed logits");
    assert_eq!(warm_run.hidden_last_chunk, cold_run.hidden_last_chunk);
    let prefix_speedup = r_cold.mean_ns / r_warm.mean_ns;
    println!(
        "    -> prefix-reuse warm-over-cold speedup {:.2}x ({} of {} blocks resumed), \
         outputs bit-identical",
        prefix_speedup,
        warm_run.metrics.prefix_blocks_reused,
        toks.len() / BLOCK
    );

    // --- elementwise remainder (quantize / rmsnorm / rope), per backend ---
    // (the acceptance benchmark of the elementwise SIMD layer: 4K-context
    // QKV-phase shapes, scalar vs detected backend, bit-identical outputs;
    // on a scalar-only host both legs run the same code and speedup ~1.0)
    let ex: Vec<f32> = (0..4096 * 768).map(|_| rng.normal()).collect();
    let ex_scale = quant_scale(&ex);
    let mut q_sc = vec![0i8; ex.len()];
    let mut q_vc = vec![0i8; ex.len()];
    let r_q_scalar = bench_for("quantize 4096x768 (scalar backend)", 300, 5, || {
        Backend::Scalar.i8_quantize(&mut q_sc, &ex, ex_scale);
        black_box(&q_sc);
    });
    println!("{r_q_scalar}");
    let name = format!("quantize 4096x768 ({} backend)", detected.name());
    let r_q_simd = bench_for(&name, 300, 5, || {
        detected.i8_quantize(&mut q_vc, &ex, ex_scale);
        black_box(&q_vc);
    });
    println!("{r_q_simd}");
    assert_eq!(q_sc, q_vc, "kernel backend changed quantize output");
    let quantize_speedup = r_q_scalar.mean_ns / r_q_simd.mean_ns;
    println!("    -> quantize backend speedup {quantize_speedup:.2}x, outputs bit-identical");

    let em = MatF32 { rows: 4096, cols: 768, data: ex.clone() };
    let gvec: Vec<f32> = (0..768).map(|_| rng.normal()).collect();
    let r_rms_scalar = bench_for("rmsnorm 4096x768 (scalar backend)", 300, 5, || {
        black_box(ops::rmsnorm_bk(&em, &gvec, 1e-5, Backend::Scalar));
    });
    println!("{r_rms_scalar}");
    let name = format!("rmsnorm 4096x768 ({} backend)", detected.name());
    let r_rms_simd = bench_for(&name, 300, 5, || {
        black_box(ops::rmsnorm_bk(&em, &gvec, 1e-5, detected));
    });
    println!("{r_rms_simd}");
    assert_eq!(
        ops::rmsnorm_bk(&em, &gvec, 1e-5, Backend::Scalar).data,
        ops::rmsnorm_bk(&em, &gvec, 1e-5, detected).data,
        "kernel backend changed rmsnorm output"
    );
    let rmsnorm_speedup = r_rms_scalar.mean_ns / r_rms_simd.mean_ns;
    println!("    -> rmsnorm backend speedup {rmsnorm_speedup:.2}x, outputs bit-identical");

    let rp = MatF32 {
        rows: 4096,
        cols: 64,
        data: (0..4096 * 64).map(|_| rng.normal()).collect(),
    };
    let rope_pos: Vec<i32> = (0..4096).collect();
    let r_rope_scalar = bench_for("rope 4096x64 (scalar backend)", 300, 5, || {
        let mut x = rp.clone();
        ops::rope_bk(&mut x, &rope_pos, 10000.0, Backend::Scalar);
        black_box(&x);
    });
    println!("{r_rope_scalar}");
    let name = format!("rope 4096x64 ({} backend)", detected.name());
    let r_rope_simd = bench_for(&name, 300, 5, || {
        let mut x = rp.clone();
        ops::rope_bk(&mut x, &rope_pos, 10000.0, detected);
        black_box(&x);
    });
    println!("{r_rope_simd}");
    let (mut rope_sc, mut rope_vc) = (rp.clone(), rp.clone());
    ops::rope_bk(&mut rope_sc, &rope_pos, 10000.0, Backend::Scalar);
    ops::rope_bk(&mut rope_vc, &rope_pos, 10000.0, detected);
    assert_eq!(rope_sc.data, rope_vc.data, "kernel backend changed rope output");
    let rope_speedup = r_rope_scalar.mean_ns / r_rope_simd.mean_ns;
    println!("    -> rope backend speedup {rope_speedup:.2}x, outputs bit-identical");

    // --- 4K-context fused index generation: 2 lanes, one shared K stream ---
    // (the acceptance benchmark of cross-lane IndexGen fusion: streaming a
    // kv head's 32 K blocks once and scoring both lanes' Q-hats at the
    // shared stream position vs two independent solo streams — per-lane
    // outputs bit-identical; the fusion's first-order win is the halved
    // priced K-stream HBM traffic, not CPU time, so speedup ~1x here)
    let ig_q: Vec<MatI8> = (0..2).map(|_| rand_mat(&mut rng, BLOCK, 64)).collect();
    let ig_k: Vec<(MatI8, f32)> =
        (0..32).map(|_| (rand_mat(&mut rng, BLOCK, 64), 0.02)).collect();
    let lane_job = |q: &'_ MatI8| scores::HeadJob {
        qhat: q,
        qs: 0.02,
        kblocks: ig_k.iter().map(|(kb, ks)| (kb, *ks)).collect(),
    };
    let r_ig_solo = bench_for("index_gen 4K x2 lanes (solo K streams)", 500, 5, || {
        for q in &ig_q {
            black_box(lane_job(q).stream());
        }
    });
    println!("{r_ig_solo}");
    let r_ig_fused = bench_for("index_gen 4K x2 lanes (fused K stream)", 500, 5, || {
        let fused = scores::FusedHeadJob { lanes: ig_q.iter().map(|q| lane_job(q)).collect() };
        black_box(fused.stream());
    });
    println!("{r_ig_fused}");
    let fused_out =
        scores::FusedHeadJob { lanes: ig_q.iter().map(|q| lane_job(q)).collect() }.stream();
    for (lane, q) in ig_q.iter().enumerate() {
        assert_eq!(fused_out[lane], lane_job(q).stream(), "fused IndexGen changed lane {lane}");
    }
    let index_gen_speedup = r_ig_solo.mean_ns / r_ig_fused.mean_ns;
    println!(
        "    -> fused-over-solo {index_gen_speedup:.2}x, per-lane outputs bit-identical \
         (K stream priced once instead of per lane)"
    );

    // --- decode step @4K context: the continuous-batching work unit ---
    // (the acceptance benchmark of decode co-scheduling: one token through
    // the full layer stack, attending over the 4K-token KV cache captured
    // at prefill. Mean step time is the server's TPOT floor at this
    // context; the armed baseline guards the decode hot loop against
    // regressions the prefill benches can't see.)
    let mut dcfg = EngineConfig::new_native(TINY.clone());
    dcfg.flex = None; // decode attention is dense by definition
    dcfg.threads = 1;
    let mut eng_dec = Engine::new_native(dcfg).unwrap();
    let mut dst = eng_dec
        .prefill_start_with(
            10,
            &toks,
            PrefillArgs { chunk_blocks: 0, capture_decode: true },
        )
        .unwrap();
    let drun = loop {
        if let Some(run) = eng_dec.phase_step(&mut dst).unwrap() {
            break run;
        }
    };
    // seed far more steps than the bench will take so the state never
    // finishes mid-closure; the KV grows one token per step, a <1% drift
    // over a bench run at 4K context
    let mut dstate = eng_dec.decode_start(10, &drun, usize::MAX).unwrap();
    let r_decode = bench_for("decode_step @4K context (dense, 1 thread)", 500, 5, || {
        black_box(eng_dec.decode_step(&mut dstate).unwrap());
    });
    println!("{r_decode}");
    println!(
        "    -> {:.1} us/token TPOT floor at 4K context ({} tokens decoded, \
         KV now {} tokens)",
        r_decode.mean_ns / 1000.0,
        dstate.tokens.len(),
        dstate.context_tokens()
    );
    assert!(dstate.hbm_read_bytes > 0, "decode steps never priced KV reads");

    // machine-readable summary for the bench trajectory (CI artifact)
    let json_path = std::env::var("FASTP_BENCH_JSON")
        .unwrap_or_else(|_| "target/hotpath_micro.json".into());
    let json = format!(
        "{{\n  \"bench\": \"hotpath_micro\",\n  \"arch\": \"{}\",\n  \
         \"detected_backend\": \"{}\",\n  \"active_backend\": \"{}\",\n  \
         \"score_tile\": {{\"scalar_ns\": {:.1}, \"simd_ns\": {:.1}, \"speedup\": {:.3}}},\n  \
         \"prefill_4k_native_sau\": {{\"threads\": 1, \"scalar_backend_ns\": {:.1}, \
         \"simd_backend_ns\": {:.1}, \"simd_speedup\": {:.3}, \"bit_identical\": true}},\n  \
         \"parallel_core\": {{\"scalar_1t_ns\": {:.1}, \"tiled_4t_ns\": {:.1}, \
         \"speedup\": {:.3}}},\n  \
         \"prefix_reuse_4k\": {{\"cold_ns\": {:.1}, \"warm_ns\": {:.1}, \
         \"speedup\": {:.3}, \"bit_identical\": true}},\n  \
         \"quantize_4k\": {{\"scalar_ns\": {:.1}, \"simd_ns\": {:.1}, \
         \"speedup\": {:.3}, \"bit_identical\": true}},\n  \
         \"rmsnorm_4k\": {{\"scalar_ns\": {:.1}, \"simd_ns\": {:.1}, \
         \"speedup\": {:.3}, \"bit_identical\": true}},\n  \
         \"rope_4k\": {{\"scalar_ns\": {:.1}, \"simd_ns\": {:.1}, \
         \"speedup\": {:.3}, \"bit_identical\": true}},\n  \
         \"index_gen_4k\": {{\"solo_ns\": {:.1}, \"fused_ns\": {:.1}, \
         \"speedup\": {:.3}, \"bit_identical\": true}},\n  \
         \"decode_step_4k\": {{\"step_ns\": {:.1}, \"context_tokens\": 4096, \
         \"bit_identical\": true}}\n}}\n",
        std::env::consts::ARCH,
        detected.name(),
        simd::active().name(),
        r_tile_scalar.mean_ns,
        r_tile_simd.mean_ns,
        r_tile_scalar.mean_ns / r_tile_simd.mean_ns,
        r_bk_scalar.mean_ns,
        r_bk_simd.mean_ns,
        simd_speedup,
        r_scalar.mean_ns,
        r_par.mean_ns,
        r_scalar.mean_ns / r_par.mean_ns,
        r_cold.mean_ns,
        r_warm.mean_ns,
        prefix_speedup,
        r_q_scalar.mean_ns,
        r_q_simd.mean_ns,
        quantize_speedup,
        r_rms_scalar.mean_ns,
        r_rms_simd.mean_ns,
        rmsnorm_speedup,
        r_rope_scalar.mean_ns,
        r_rope_simd.mean_ns,
        rope_speedup,
        r_ig_solo.mean_ns,
        r_ig_fused.mean_ns,
        index_gen_speedup,
        r_decode.mean_ns,
    );
    if let Some(dir) = std::path::Path::new(&json_path).parent() {
        if !dir.as_os_str().is_empty() {
            let _ = std::fs::create_dir_all(dir);
        }
    }
    match std::fs::write(&json_path, &json) {
        Ok(()) => println!("    -> wrote JSON summary to {json_path}"),
        Err(e) => eprintln!("    -> could not write {json_path}: {e}"),
    }

    // --- quantization of one chunk ---
    let x: Vec<f32> = (0..BLOCK * 768).map(|_| rng.normal()).collect();
    let mut out = vec![0i8; x.len()];
    let r = bench_for("quantize chunk 128x768", 200, 20, || {
        let s = quant_scale(&x);
        quantize_with(&x, s, &mut out);
        black_box(&out);
    });
    println!("{r}");
}
