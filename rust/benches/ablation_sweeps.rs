//! Design-choice ablation sweeps (DESIGN.md §Perf): cache capacity,
//! hot-tier fraction, prefetch-relevant wave size, MPU array count and the
//! FlexPrefill coverage budget gamma — the sensitivity studies behind the
//! paper's chosen design point.

use fast_prefill::config::{u280_fast_prefill, FlexParams, LLAMA32_3B};
use fast_prefill::metrics::fmt_ctx;
use fast_prefill::sim::{simulate_prefill, synth_model_indices, HeadMix};
use fast_prefill::util::table::{fnum, Table};

fn main() {
    let cfg = &LLAMA32_3B;
    let ctx = 32768;
    let mix = HeadMix::default();
    let params = FlexParams::default();
    let idx = synth_model_indices(cfg.n_heads, 2, ctx / 128, 32, &mix, &params, 11);
    println!("== design-choice ablations (Llama-3.2-3B @ {}) ==\n", fmt_ctx(ctx));

    // ---- cache capacity sweep ----
    println!("-- KV cache capacity --");
    let mut t = Table::new(&["cache (MB)", "TTFT (ms)", "SAU (ms)", "hit %", "HBM read (GB)"]);
    for mb in [0usize, 2, 4, 8, 16, 32, 64] {
        let mut f = u280_fast_prefill();
        f.kv_cache_bytes = mb << 20;
        let r = simulate_prefill(&f, cfg, ctx, &idx);
        t.row(&[
            mb.to_string(),
            fnum(r.ttft_ms),
            fnum(r.t_sau_ms),
            fnum(r.cache_hit_rate * 100.0),
            fnum(r.traffic.hbm_read_bytes / 1e9),
        ]);
    }
    t.print();
    println!("(paper design point: 16 MB)\n");

    // ---- hot-tier fraction sweep ----
    println!("-- hot-tier fraction --");
    let mut t = Table::new(&["hot frac", "TTFT (ms)", "hit %"]);
    for frac in [0.0, 0.25, 0.5, 0.75, 1.0] {
        let mut f = u280_fast_prefill();
        f.hot_fraction = frac;
        let r = simulate_prefill(&f, cfg, ctx, &idx);
        t.row(&[format!("{frac:.2}"), fnum(r.ttft_ms), fnum(r.cache_hit_rate * 100.0)]);
    }
    t.print();
    println!("(paper design point: 0.5)\n");

    // ---- MPU array count sweep ----
    println!("-- MPU LUT-array count (DSP arrays fixed at 6) --");
    let mut t = Table::new(&["LUT arrays", "TTFT (ms)", "peak TOPS"]);
    for luts in [0usize, 2, 4, 6, 8, 10] {
        let mut f = u280_fast_prefill();
        f.mpu_lut_arrays = luts;
        let r = simulate_prefill(&f, cfg, ctx, &idx);
        t.row(&[luts.to_string(), fnum(r.ttft_ms), fnum(f.peak_tops())]);
    }
    t.print();
    println!("(paper design point: 6 — LUT budget bound, see Table II)\n");

    // ---- gamma (coverage budget) sweep: sparsity/quality knob ----
    println!("-- FlexPrefill gamma (coverage budget) --");
    let mut t = Table::new(&["gamma", "density %", "jobs/layer", "TTFT (ms)"]);
    for gamma in [0.7f32, 0.8, 0.9, 0.95, 0.99] {
        let p = FlexParams { gamma, ..Default::default() };
        let idx_g = synth_model_indices(cfg.n_heads, 2, ctx / 128, 32, &mix, &p, 11);
        let f = u280_fast_prefill();
        let r = simulate_prefill(&f, cfg, ctx, &idx_g);
        t.row(&[
            format!("{gamma:.2}"),
            fnum(r.avg_density * 100.0),
            (r.total_jobs / cfg.n_layers).to_string(),
            fnum(r.ttft_ms),
        ]);
    }
    t.print();
    println!("(paper/FlexPrefill default: 0.9)");
}
