//! Figure 6: energy efficiency (Token/Joule, token count 1) of FAST-Prefill
//! vs the GPU baseline over the paper's context sweep.

use fast_prefill::config::{a5000, paper_context_lengths, paper_models, u280_fast_prefill, FlexParams};
use fast_prefill::gpu_model::simulate_gpu_prefill;
use fast_prefill::metrics::fmt_ctx;
use fast_prefill::sim::{simulate_prefill, synth_model_indices, HeadMix};
use fast_prefill::util::table::{fnum, Table};

fn main() {
    println!("== Figure 6: energy efficiency (Token/Joule), batch 1 ==\n");
    let fpga = u280_fast_prefill();
    let gpu = a5000();
    let params = FlexParams::default();
    let mix = HeadMix::default();

    for cfg in paper_models() {
        let mut t = Table::new(&[
            "context", "FPGA (tok/J)", "GPU (tok/J)", "ratio", "FPGA (J)", "GPU (J)",
        ]);
        let mut ratios = Vec::new();
        for ctx in paper_context_lengths() {
            let idx = synth_model_indices(cfg.n_heads, 2, ctx / 128, 32, &mix, &params, 42);
            let f = simulate_prefill(&fpga, cfg, ctx, &idx);
            let g = simulate_gpu_prefill(&gpu, cfg, ctx, &idx);
            let ratio = f.tokens_per_joule() / g.tokens_per_joule();
            ratios.push(ratio);
            t.row(&[
                fmt_ctx(ctx),
                format!("{:.5}", f.tokens_per_joule()),
                format!("{:.5}", g.tokens_per_joule()),
                format!("{ratio:.2}x"),
                fnum(f.energy_j),
                fnum(g.energy_j),
            ]);
        }
        println!("-- {} --", cfg.name);
        t.print();
        let max = ratios.iter().cloned().fold(0.0, f64::max);
        println!("best energy-efficiency ratio {max:.2}x (paper: up to 4.5x)\n");
    }
}
