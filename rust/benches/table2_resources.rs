//! Table II: FPGA resource utilization of the FAST-Prefill design point,
//! derived from the architecture configuration (component breakdown plus
//! the paper's Used/Available/Utilization rows).

use fast_prefill::config::u280_fast_prefill;
use fast_prefill::sim::resource_report;
use fast_prefill::util::table::{fnum, Table};

fn main() {
    println!("== Table II: FPGA resource utilization ==\n");
    let rep = resource_report(&u280_fast_prefill());
    let mut t = Table::new(&["Module", "LUT (k)", "FF (k)", "BRAM", "URAM", "DSP"]);
    for (name, r) in &rep.components {
        t.row(&[
            name.to_string(),
            fnum(r.lut_k),
            fnum(r.ff_k),
            fnum(r.bram),
            fnum(r.uram),
            fnum(r.dsp),
        ]);
    }
    t.row(&[
        "Used".into(),
        fnum(rep.total.lut_k),
        fnum(rep.total.ff_k),
        fnum(rep.total.bram),
        fnum(rep.total.uram),
        fnum(rep.total.dsp),
    ]);
    t.row(&[
        "Available".into(),
        fnum(rep.available.lut_k),
        fnum(rep.available.ff_k),
        fnum(rep.available.bram),
        fnum(rep.available.uram),
        fnum(rep.available.dsp),
    ]);
    let u = rep.utilization();
    t.row(&[
        "Utilization (%)".into(),
        fnum(u[0].3),
        fnum(u[1].3),
        fnum(u[2].3),
        fnum(u[3].3),
        fnum(u[4].3),
    ]);
    t.print();
    println!("\npaper: 64.3 / 47.3 / 55.8 / 95 / 71.6 (%)");
}
