//! Table III: accuracy comparison across precision modes on the
//! needle-retrieval proxy (RULER cannot be run offline — see DESIGN.md
//! substitutions). Rows mirror the paper: FlexPrefill BF-16, FlexPrefill
//! INT-8 (dequantized matmuls), FAST-Prefill W8A8. Two model-shaped
//! difficulty settings stand in for LLaMA-1B and LLaMA-3B.

use fast_prefill::accuracy::{table3_cell_spec, Precision};
use fast_prefill::config::FlexParams;
use fast_prefill::util::table::{fnum, Table};
use fast_prefill::workload::needle::TaskSpec;

fn main() {
    println!("== Table III: retrieval accuracy proxy (RULER substitute), % ==\n");
    let params = FlexParams::default();
    // context lengths in 128-token blocks: 4k, 8k, 16k, 32k, 64k
    let contexts: [(usize, &str); 5] =
        [(32, "4k"), (64, "8k"), (128, "16k"), (256, "32k"), (512, "64k")];
    // (label, gain, noise, d_head, outlier dims, outlier magnitude):
    // outlier channels model the large-magnitude activation features that
    // make per-tensor int8 lossy on real LLMs (see workload::needle);
    // the 3B-shaped setting has a cleaner signal (larger d_head), like the
    // paper's higher 3B scores.
    // (label, gain, noise, d_head, outlier dims, outlier mag, distractors, rho)
    let settings = [
        ("LLaMA-1B-shaped", 1.05f32, 0.45f32, 64usize, 4usize, 170.0f32, 3usize, 0.95f32),
        ("LLaMA-3B-shaped", 0.85, 0.35, 128, 4, 110.0, 3, 0.93),
    ];
    let n_tasks = 3;

    for (label, gain, noise, dh, odims, omag, ndis, rho) in settings {
        println!(
            "-- {label} (d_head {dh}, {odims} outlier channels x{omag}, {ndis} hard negatives rho={rho}) --"
        );
        let mut t = Table::new(&["Method", "4k", "8k", "16k", "32k", "64k", "Avg"]);
        for prec in [Precision::Bf16, Precision::Int8Deq, Precision::W8A8] {
            let mut row = vec![prec.label().to_string()];
            let mut sum = 0.0;
            for (nb, _) in contexts {
                let spec = TaskSpec::new(nb, dh, gain, noise)
                    .with_outliers(odims, omag)
                    .with_distractors(ndis, rho);
                let acc = table3_cell_spec(&spec, prec, &params, n_tasks, 1234);
                sum += acc;
                row.push(fnum(acc));
            }
            row.push(fnum(sum / contexts.len() as f64));
            t.row(&row);
        }
        t.print();
        println!();
    }
    println!("expected shape (paper Table III): BF16 well above both int8 modes;");
    println!("FAST-Prefill W8A8 within ~2 points of FlexPrefill INT-8.");
}
