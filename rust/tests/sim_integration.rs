//! Simulator + GPU-model integration: the headline shapes of every paper
//! figure must hold (who wins, roughly by how much, trends with context).

use fast_prefill::config::{
    a5000, paper_context_lengths, u280_cacheless, u280_dsp_only, u280_fast_prefill, FlexParams,
    LLAMA32_1B, LLAMA32_3B, QWEN25_1B,
};
use fast_prefill::flexprefill::HeadIndex;
use fast_prefill::gpu_model::simulate_gpu_prefill;
use fast_prefill::sim::{resource_report, simulate_prefill, synth_model_indices, HeadMix};

fn indices(heads: usize, n: usize, seed: u64) -> Vec<Vec<HeadIndex>> {
    synth_model_indices(heads, 2, n, 32, &HeadMix::default(), &FlexParams::default(), seed)
}

#[test]
fn fig5_fpga_wins_and_speedup_grows_with_context() {
    for cfg in [&LLAMA32_1B, &LLAMA32_3B, &QWEN25_1B] {
        let fpga = u280_fast_prefill();
        let gpu = a5000();
        let mut last = 0.0;
        for &ctx in &[4096usize, 16384, 131072] {
            let idx = indices(cfg.n_heads, ctx / 128, 42);
            let f = simulate_prefill(&fpga, cfg, ctx, &idx);
            let g = simulate_gpu_prefill(&gpu, cfg, ctx, &idx);
            let speedup = g.ttft_ms / f.ttft_ms;
            assert!(speedup > 1.0, "{} @{}: speedup {speedup}", cfg.name, ctx);
            assert!(speedup < 3.5, "{} @{}: speedup {speedup} too large", cfg.name, ctx);
            assert!(speedup >= last * 0.95, "{}: speedup not growing", cfg.name);
            last = speedup;
        }
        // paper band: 1.2-2.5x (we accept up to ~3x at 128K)
        assert!(last > 2.0, "{}: 128K speedup {last} below paper band", cfg.name);
    }
}

#[test]
fn fig6_energy_efficiency_band() {
    let fpga = u280_fast_prefill();
    let gpu = a5000();
    let cfg = &LLAMA32_3B;
    let mut ratios = Vec::new();
    for &ctx in &paper_context_lengths() {
        let idx = indices(cfg.n_heads, ctx / 128, 7);
        let f = simulate_prefill(&fpga, cfg, ctx, &idx);
        let g = simulate_gpu_prefill(&gpu, cfg, ctx, &idx);
        let ratio = f.tokens_per_joule() / g.tokens_per_joule();
        assert!(ratio > 1.5, "@{ctx}: energy ratio {ratio}");
        ratios.push(ratio);
    }
    // "up to 4.5x": the best point must be in the 4-7 band
    let best = ratios.iter().cloned().fold(0.0, f64::max);
    assert!(best > 4.0 && best < 8.0, "best energy ratio {best}");
}

#[test]
fn fig7_cache_ablation_shape() {
    let cfg = &LLAMA32_3B;
    let ctx = 16384;
    let idx = indices(cfg.n_heads, ctx / 128, 3);
    let with = simulate_prefill(&u280_fast_prefill(), cfg, ctx, &idx);
    let without = simulate_prefill(&u280_cacheless(), cfg, ctx, &idx);
    // cacheless must be clearly slower in the SAU stage
    let sau_ratio = without.t_sau_ms / with.t_sau_ms;
    assert!(sau_ratio > 1.5, "SAU cache benefit only {sau_ratio}");
    assert!(without.ttft_ms > with.ttft_ms);
    // hit rate in a plausible band at mid context (paper: ~65%)
    assert!(with.cache_hit_rate > 0.3 && with.cache_hit_rate < 0.95,
        "hit rate {}", with.cache_hit_rate);
    // traffic must drop with the cache
    assert!(with.traffic.hbm_read_bytes < without.traffic.hbm_read_bytes);
}

#[test]
fn fig8_hybrid_mpu_ablation_shape() {
    let cfg = &LLAMA32_3B;
    let ctx = 16384;
    let idx = indices(cfg.n_heads, ctx / 128, 4);
    let hybrid = simulate_prefill(&u280_fast_prefill(), cfg, ctx, &idx);
    let dsp = simulate_prefill(&u280_dsp_only(), cfg, ctx, &idx);
    let ratio = dsp.ttft_ms / hybrid.ttft_ms;
    // paper: ~1.8x
    assert!(ratio > 1.4 && ratio < 2.2, "hybrid MPU speedup {ratio}");
}

#[test]
fn table2_resource_totals() {
    let rep = resource_report(&u280_fast_prefill());
    let util: Vec<f64> = rep.utilization().iter().map(|u| u.3).collect();
    // paper: 64.3 / 47.3 / 55.8 / 95 / 71.6 (%)
    let paper = [64.3, 47.3, 55.8, 95.0, 71.6];
    for (got, want) in util.iter().zip(&paper) {
        assert!((got - want).abs() < 5.0, "utilization {got} vs paper {want}");
    }
}

#[test]
fn density_decreases_with_context_at_scale() {
    let fpga = u280_fast_prefill();
    let cfg = &LLAMA32_1B;
    let d4k = simulate_prefill(&fpga, cfg, 4096, &indices(cfg.n_heads, 32, 9)).avg_density;
    let d128k = simulate_prefill(&fpga, cfg, 131072, &indices(cfg.n_heads, 1024, 9)).avg_density;
    assert!(d128k < d4k * 0.6, "density {d4k} -> {d128k} not falling");
    assert!(d128k > 0.005, "density {d128k} implausibly low");
}

#[test]
fn bigger_model_costs_more() {
    let fpga = u280_fast_prefill();
    let ctx = 8192;
    let t1 = simulate_prefill(&fpga, &LLAMA32_1B, ctx, &indices(LLAMA32_1B.n_heads, 64, 5)).ttft_ms;
    let t3 = simulate_prefill(&fpga, &LLAMA32_3B, ctx, &indices(LLAMA32_3B.n_heads, 64, 5)).ttft_ms;
    assert!(t3 > 1.5 * t1, "3B {t3} vs 1B {t1}");
}
