//! Runtime integration: the AOT artifacts, executed through PJRT, must
//! reproduce the pure-Rust reference math — this pins all three layers
//! (Pallas kernel, JAX graph, Rust mirror) to one numeric contract.
//!
//! Requires `make artifacts` (skipped with a message otherwise).

use fast_prefill::config::{BLOCK, TINY};
use fast_prefill::model::forward::attn_step_w8a8;
use fast_prefill::model::ModelWeights;
use fast_prefill::quant::{quant_scale, quantize_with};
use fast_prefill::runtime::{literal_f32, literal_i8, Arg, Runtime};
use fast_prefill::tensor::{MatF32, MatI8};
use fast_prefill::util::prng::Prng;
use fast_prefill::util::stats::{max_abs_diff, rel_l2};

fn runtime() -> Option<Runtime> {
    match Runtime::load("artifacts") {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e:#}");
            None
        }
    }
}

fn rand_i8(rng: &mut Prng, n: usize) -> Vec<i8> {
    (0..n).map(|_| rng.i8_sym()).collect()
}

#[test]
fn manifest_covers_all_entries_for_both_configs() {
    let Some(rt) = runtime() else { return };
    for cfg in ["tiny", "small100m"] {
        for entry in [
            "qkv_chunk", "index_phase_a", "index_phase_b", "attn_block_step",
            "attn_block_batch", "o_proj_chunk", "ffn_chunk", "logits_chunk",
        ] {
            assert!(rt.manifest.find(cfg, entry).is_some(), "{cfg}::{entry}");
        }
    }
    rt.manifest.validate_config(&TINY).unwrap();
}

#[test]
fn attn_block_step_artifact_matches_rust_mirror() {
    let Some(mut rt) = runtime() else { return };
    let mut rng = Prng::new(42);
    let dh = TINY.d_head;
    let q = rand_i8(&mut rng, BLOCK * dh);
    let k = rand_i8(&mut rng, BLOCK * dh);
    let v = rand_i8(&mut rng, BLOCK * dh);
    let (qs, ks, vs) = (0.021f32, 0.033f32, 0.027f32);
    let m0 = vec![-1e30f32; BLOCK];
    let l0 = vec![0.0f32; BLOCK];
    let acc0 = vec![0.0f32; BLOCK * dh];
    for diag in [0.0f32, 1.0] {
        let exe = rt.get("tiny", "attn_block_step").unwrap();
        let out = exe
            .run(&[
                Arg::I8(&q, &[BLOCK, dh]),
                Arg::ScalarF32(qs),
                Arg::I8(&k, &[BLOCK, dh]),
                Arg::ScalarF32(ks),
                Arg::I8(&v, &[BLOCK, dh]),
                Arg::ScalarF32(vs),
                Arg::F32(&m0, &[BLOCK]),
                Arg::F32(&l0, &[BLOCK]),
                Arg::F32(&acc0, &[BLOCK, dh]),
                Arg::ScalarF32(diag),
            ])
            .unwrap();
        let (m_a, l_a, acc_a) = (
            literal_f32(&out[0]).unwrap(),
            literal_f32(&out[1]).unwrap(),
            literal_f32(&out[2]).unwrap(),
        );

        let qm = MatI8::from_vec(BLOCK, dh, q.clone());
        let km = MatI8::from_vec(BLOCK, dh, k.clone());
        let vm = MatI8::from_vec(BLOCK, dh, v.clone());
        let mut m_r = m0.clone();
        let mut l_r = l0.clone();
        let mut acc_r = MatF32::zeros(BLOCK, dh);
        attn_step_w8a8(&qm, qs, &km, ks, &vm, vs, &mut m_r, &mut l_r, &mut acc_r, diag > 0.5);

        assert!(max_abs_diff(&m_a, &m_r) < 1e-4, "m diverges (diag={diag})");
        assert!(rel_l2(&l_a, &l_r) < 1e-5, "l diverges (diag={diag})");
        assert!(rel_l2(&acc_a, &acc_r.data) < 1e-4, "acc diverges (diag={diag})");
    }
}

#[test]
fn index_phases_artifacts_match_rust_scores() {
    let Some(mut rt) = runtime() else { return };
    let mut rng = Prng::new(7);
    let dh = TINY.d_head;
    let qhat = rand_i8(&mut rng, BLOCK * dh);
    let kblks: Vec<Vec<i8>> = (0..3).map(|_| rand_i8(&mut rng, BLOCK * dh)).collect();
    let (qs, ks) = (0.02f32, 0.03f32);

    // artifact path
    let mut m = vec![-1e30f32; BLOCK];
    let mut l = vec![0.0f32; BLOCK];
    for kb in &kblks {
        let exe = rt.get("tiny", "index_phase_a").unwrap();
        let out = exe
            .run(&[
                Arg::I8(&qhat, &[BLOCK, dh]),
                Arg::ScalarF32(qs),
                Arg::I8(kb, &[BLOCK, dh]),
                Arg::ScalarF32(ks),
                Arg::F32(&m, &[BLOCK]),
                Arg::F32(&l, &[BLOCK]),
            ])
            .unwrap();
        m = literal_f32(&out[0]).unwrap();
        l = literal_f32(&out[1]).unwrap();
    }
    // rust mirror
    use fast_prefill::flexprefill::scores::{phase_a, phase_b, StreamState};
    let qm = MatI8::from_vec(BLOCK, dh, qhat.clone());
    let mut st = StreamState::new(BLOCK);
    for kb in &kblks {
        phase_a(&qm, qs, &MatI8::from_vec(BLOCK, dh, kb.clone()), ks, &mut st);
    }
    assert!(max_abs_diff(&m, &st.m) < 1e-4, "phase A m");
    assert!(rel_l2(&l, &st.l) < 1e-5, "phase A l");

    for kb in &kblks {
        let exe = rt.get("tiny", "index_phase_b").unwrap();
        let out = exe
            .run(&[
                Arg::I8(&qhat, &[BLOCK, dh]),
                Arg::ScalarF32(qs),
                Arg::I8(kb, &[BLOCK, dh]),
                Arg::ScalarF32(ks),
                Arg::F32(&m, &[BLOCK]),
                Arg::F32(&l, &[BLOCK]),
            ])
            .unwrap();
        let stats = literal_f32(&out[0]).unwrap();
        let want = phase_b(&qm, qs, &MatI8::from_vec(BLOCK, dh, kb.clone()), ks, &st);
        assert!((stats[0] - want.vsum).abs() < 2e-3, "vsum {} vs {}", stats[0], want.vsum);
        assert!((stats[1] - want.slo).abs() < 2e-3, "slo");
        assert!((stats[2] - want.sup).abs() < 2e-3, "sup");
    }
}

#[test]
fn qkv_chunk_artifact_matches_reference_shapes_and_quant() {
    let Some(mut rt) = runtime() else { return };
    let w = ModelWeights::generate(&TINY, 99);
    let mut rng = Prng::new(3);
    let x: Vec<f32> = (0..BLOCK * TINY.d_model).map(|_| rng.normal()).collect();
    let lw = &w.layers[0];
    let exe = rt.get("tiny", "qkv_chunk").unwrap();
    let out = exe
        .run(&[
            Arg::F32(&x, &[BLOCK, TINY.d_model]),
            Arg::F32(&lw.g_attn, &[TINY.d_model]),
            Arg::I8(&lw.wq.q.data, &[TINY.d_model, TINY.q_dim()]),
            Arg::ScalarF32(lw.wq.scale),
            Arg::I8(&lw.wk.q.data, &[TINY.d_model, TINY.kv_dim()]),
            Arg::ScalarF32(lw.wk.scale),
            Arg::I8(&lw.wv.q.data, &[TINY.d_model, TINY.kv_dim()]),
            Arg::ScalarF32(lw.wv.scale),
            Arg::ScalarI32(256),
        ])
        .unwrap();
    let q = literal_i8(&out[0]).unwrap();
    assert_eq!(q.len(), TINY.n_heads * BLOCK * TINY.d_head);
    let qs = out[1].get_first_element::<f32>().unwrap();
    assert!(qs > 0.0 && qs < 10.0, "q scale {qs}");
    // quantized payloads must use the full int8 range somewhere
    assert!(q.iter().any(|&v| v.abs() > 100), "q underutilizes int8 range");
    let qpool = literal_f32(&out[6]).unwrap();
    assert_eq!(qpool.len(), TINY.n_heads * TINY.d_head);
}

#[test]
fn ffn_chunk_artifact_matches_rust_reference() {
    let Some(mut rt) = runtime() else { return };
    let w = ModelWeights::generate(&TINY, 11);
    let mut rng = Prng::new(5);
    let x: Vec<f32> = (0..BLOCK * TINY.d_model).map(|_| rng.normal()).collect();
    let lw = &w.layers[0];
    let exe = rt.get("tiny", "ffn_chunk").unwrap();
    let out = exe
        .run(&[
            Arg::F32(&x, &[BLOCK, TINY.d_model]),
            Arg::F32(&lw.g_ffn, &[TINY.d_model]),
            Arg::I8(&lw.wg.q.data, &[TINY.d_model, TINY.d_ffn]),
            Arg::ScalarF32(lw.wg.scale),
            Arg::I8(&lw.wu.q.data, &[TINY.d_model, TINY.d_ffn]),
            Arg::ScalarF32(lw.wu.scale),
            Arg::I8(&lw.wd.q.data, &[TINY.d_ffn, TINY.d_model]),
            Arg::ScalarF32(lw.wd.scale),
        ])
        .unwrap();
    let got = literal_f32(&out[0]).unwrap();

    // rust mirror (same definitions as model::forward's FFN)
    use fast_prefill::quant::int8_matmul_deq;
    use fast_prefill::tensor::ops::{rmsnorm, silu};
    let xm = MatF32::from_vec(BLOCK, TINY.d_model, x.clone());
    let xn = rmsnorm(&xm, &lw.g_ffn, TINY.rms_eps);
    let xs = quant_scale(&xn.data);
    let mut xq = MatI8::zeros(BLOCK, TINY.d_model);
    quantize_with(&xn.data, xs, &mut xq.data);
    let mut gate = int8_matmul_deq(&xq, xs, &lw.wg.q, lw.wg.scale);
    silu(&mut gate);
    let up = int8_matmul_deq(&xq, xs, &lw.wu.q, lw.wu.scale);
    for (g, u) in gate.data.iter_mut().zip(&up.data) {
        *g *= u;
    }
    let hs = quant_scale(&gate.data);
    let mut hq = MatI8::zeros(BLOCK, TINY.d_ffn);
    quantize_with(&gate.data, hs, &mut hq.data);
    let down = int8_matmul_deq(&hq, hs, &lw.wd.q, lw.wd.scale);
    let want: Vec<f32> = xm.data.iter().zip(&down.data).map(|(a, b)| a + b).collect();

    // activation quantization can differ by 1 ulp at the rounding boundary
    // between XLA and Rust f32 orders; tolerate small relative error
    assert!(rel_l2(&got, &want) < 5e-3, "ffn rel err {}", rel_l2(&got, &want));
}

#[test]
fn exec_stats_track_calls() {
    let Some(mut rt) = runtime() else { return };
    let qhat = vec![1i8; BLOCK * TINY.d_head];
    let m = vec![-1e30f32; BLOCK];
    let l = vec![0.0f32; BLOCK];
    let exe = rt.get("tiny", "index_phase_a").unwrap();
    exe.run(&[
        Arg::I8(&qhat, &[BLOCK, TINY.d_head]),
        Arg::ScalarF32(0.01),
        Arg::I8(&qhat, &[BLOCK, TINY.d_head]),
        Arg::ScalarF32(0.01),
        Arg::F32(&m, &[BLOCK]),
        Arg::F32(&l, &[BLOCK]),
    ])
    .unwrap();
    let stats = rt.exec_stats();
    let row = stats.iter().find(|(k, _, _)| k == "tiny::index_phase_a").unwrap();
    assert_eq!(row.1, 1);
}

#[test]
fn arg_shape_validation_rejects_wrong_dims() {
    let Some(mut rt) = runtime() else { return };
    let exe = rt.get("tiny", "index_phase_a").unwrap();
    let bad = vec![0i8; 4];
    let m = vec![0f32; BLOCK];
    let r = exe.run(&[
        Arg::I8(&bad, &[2, 2]),
        Arg::ScalarF32(0.01),
        Arg::I8(&bad, &[2, 2]),
        Arg::ScalarF32(0.01),
        Arg::F32(&m, &[BLOCK]),
        Arg::F32(&m, &[BLOCK]),
    ]);
    assert!(r.is_err(), "wrong dims must be rejected");
}
