//! Coordinator integration: the multi-worker server over real artifacts.
//! Requires `make artifacts` (skips otherwise).

use fast_prefill::config::TINY;
use fast_prefill::coordinator::{EngineConfig, Policy, Server};
use fast_prefill::workload::prompts::{PromptKind, PromptSpec, TraceRequest};

fn cfg() -> EngineConfig {
    let mut c = EngineConfig::new(TINY.clone());
    c.native_sau = true; // keep the test fast; PJRT SAU covered elsewhere
    c
}

fn artifacts_present() -> bool {
    if std::path::Path::new("artifacts/manifest.txt").exists() {
        true
    } else {
        eprintln!("SKIP (run `make artifacts`)");
        false
    }
}

fn req(id: u64, tokens: usize) -> TraceRequest {
    TraceRequest {
        id,
        spec: PromptSpec { kind: PromptKind::Mixed, tokens, seed: 100 + id },
        arrival_us: 0,
        priority: Default::default(),
        decode_tokens: 0,
    }
}

#[test]
fn server_completes_all_requests() {
    if !artifacts_present() {
        return;
    }
    let server = Server::start("artifacts".into(), cfg(), 2, Policy::Fcfs).unwrap();
    for i in 0..4 {
        server.submit(req(i, 256));
    }
    let done = server.drain().unwrap();
    assert_eq!(done.len(), 4);
    let ids: Vec<u64> = done.iter().map(|c| c.request_id).collect();
    assert_eq!(ids, vec![0, 1, 2, 3]);
    for c in &done {
        assert!(c.run.metrics.ttft_us > 0.0);
        assert!(c.e2e_us >= c.run.metrics.ttft_us);
        assert_eq!(c.run.metrics.context_tokens, 256);
    }
}

#[test]
fn identical_requests_get_identical_results_across_workers() {
    if !artifacts_present() {
        return;
    }
    let server = Server::start("artifacts".into(), cfg(), 2, Policy::Fcfs).unwrap();
    for i in 0..4 {
        // same seed => same prompt => same first token, whichever worker
        server.submit(TraceRequest {
            id: i,
            spec: PromptSpec { kind: PromptKind::Mixed, tokens: 256, seed: 777 },
            arrival_us: 0,
            priority: Default::default(),
            decode_tokens: 0,
        });
    }
    let done = server.drain().unwrap();
    let t0 = done[0].run.first_token;
    assert!(done.iter().all(|c| c.run.first_token == t0));
}

#[test]
fn sjf_prefers_short_contexts_under_backlog() {
    if !artifacts_present() {
        return;
    }
    // single worker, pre-filled queue: SJF must run the short ones first
    let server = Server::start("artifacts".into(), cfg(), 1, Policy::Sjf).unwrap();
    server.submit(req(0, 512));
    server.submit(req(1, 128));
    server.submit(req(2, 384));
    server.submit(req(3, 128));
    std::thread::sleep(std::time::Duration::from_millis(50));
    let done = server.drain().unwrap();
    assert_eq!(done.len(), 4);
    // the long request should have waited at least as long as the shorts
    let long = done.iter().find(|c| c.request_id == 0).unwrap();
    let short = done.iter().find(|c| c.request_id == 1).unwrap();
    assert!(long.queue_us >= short.queue_us,
        "SJF: long queued {} < short {}", long.queue_us, short.queue_us);
}
