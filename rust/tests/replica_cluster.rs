//! Replica-sharded serving contracts (`coordinator::cluster`):
//!
//!  * placements are a **pure function** of (trace, policy, replica
//!    count) — two routers fed the same stream agree bit-for-bit, and a
//!    live cluster's placement log matches a fresh router's replay;
//!  * the cost model's prefix-affinity probe beats LeastLoaded on a
//!    shared-prefix cohort (strictly lower summed priced cost, strictly
//!    more warm placements);
//!  * replica-sharded serving is **bit-identical** to solo
//!    `Engine::prefill` for random traces × replica counts × policies
//!    (placement only moves work between identical engines).
//!
//! Runs fully native on TINY — no artifacts, every tier-1 environment.

use fast_prefill::config::{BLOCK, TINY};
use fast_prefill::coordinator::{
    Cluster, Engine, EngineConfig, Policy, Router, RouterPolicy, ServerOptions,
};
use fast_prefill::util::prop::forall_ck;
use fast_prefill::util::prng::Prng;
use fast_prefill::workload::prompts::{
    Priority, PromptKind, PromptSpec, RequestTrace, TraceRequest,
};

fn native_cfg() -> EngineConfig {
    let mut cfg = EngineConfig::new_native(TINY.clone());
    cfg.weight_seed = 4242;
    cfg
}

fn req(id: u64, tokens: usize, seed: u64, arrival_us: u64) -> TraceRequest {
    TraceRequest {
        id,
        spec: PromptSpec { kind: PromptKind::Mixed, tokens, seed },
        arrival_us,
        priority: Priority::Interactive,
        decode_tokens: 0,
    }
}

const POLICIES: [RouterPolicy; 3] =
    [RouterPolicy::RoundRobin, RouterPolicy::LeastLoaded, RouterPolicy::CostModel];

#[test]
fn same_trace_and_options_route_identically() {
    let trace = RequestTrace::generate_mixed(16, &[128, 256, 512], 1200, 42);
    for policy in POLICIES {
        for replicas in [1usize, 2, 4] {
            let a = Router::new(policy, replicas, &native_cfg()).route_trace(&trace);
            let b = Router::new(policy, replicas, &native_cfg()).route_trace(&trace);
            assert_eq!(a, b, "{policy:?} x{replicas}: placements must be replayable");
            assert!(a.iter().all(|p| p.replica < replicas));
        }
    }
}

/// The affinity scenario: a cohort founder lands cold on replica 0; a
/// short filler then occupies replica 0 just as the second cohort member
/// arrives. LeastLoaded flees the filler's backlog to the idle replica
/// and pays a cold prefill; the cost model weighs the filler's tiny
/// backlog against the 7/8-coverage discount and stays — strictly
/// cheaper in total, strictly more warm placements.
#[test]
fn cost_model_affinity_beats_least_loaded_on_shared_prefix_cohort() {
    let cohort = PromptKind::SharedPrefix { prefix_seed: 11, prefix_blocks: 7 };
    let mk = |id: u64, arrival_us: u64| TraceRequest {
        id,
        spec: PromptSpec { kind: cohort, tokens: 8 * BLOCK, seed: 700 + id },
        arrival_us,
        priority: Priority::Interactive,
        decode_tokens: 0,
    };
    // price the scenario's constants on a scratch router
    let mut scratch = Router::new(RouterPolicy::CostModel, 2, &native_cfg());
    let cold = scratch.price_us(8, 0);
    let warm = scratch.price_us(8, 7);
    let filler = scratch.price_us(1, 0);
    assert!(filler > 0.0, "a 1-block prefill must price above zero");
    assert!(
        filler + warm < cold,
        "scenario needs the affinity discount to dominate the filler backlog \
         (filler {filler} + warm {warm} vs cold {cold} us)"
    );
    let drained = cold as u64 + 1; // past the founder's estimated finish
    // filler and member share an arrival instant; submission order (the
    // stable sort) routes the filler first, so the member sees replica 0
    // carrying exactly the filler's backlog
    let trace = RequestTrace {
        requests: vec![
            mk(0, 0),                    // founder -> replica 0, cold
            req(1, BLOCK, 900, drained), // filler -> replica 0 (idle tie)
            mk(2, drained),              // member: the contested choice
        ],
    };
    let ll = Router::new(RouterPolicy::LeastLoaded, 2, &native_cfg()).route_trace(&trace);
    let cm = Router::new(RouterPolicy::CostModel, 2, &native_cfg()).route_trace(&trace);
    // both policies agree on the setup placements
    assert_eq!((ll[0].replica, ll[1].replica), (0, 0));
    assert_eq!((cm[0].replica, cm[1].replica), (0, 0));
    // the contested member: LeastLoaded flees to the idle replica (cold),
    // the cost model stays with the cohort (warm)
    assert_eq!(ll[2].replica, 1, "LeastLoaded should flee the filler backlog");
    assert_eq!(ll[2].prefix_coverage, 0);
    assert_eq!(cm[2].replica, 0, "CostModel should stay for the coverage discount");
    assert_eq!(cm[2].prefix_coverage, 7);
    // totals: strictly cheaper, strictly more warm placements
    let total = |ps: &[fast_prefill::coordinator::Placement]| -> f64 {
        ps.iter().map(|p| p.est_cost_us).sum()
    };
    let warm_count =
        |ps: &[fast_prefill::coordinator::Placement]| ps.iter().filter(|p| p.prefix_coverage > 0).count();
    assert!(
        total(&cm) < total(&ll),
        "cost model total {} should be strictly below LeastLoaded {}",
        total(&cm),
        total(&ll)
    );
    assert!(warm_count(&cm) > warm_count(&ll));
}

#[derive(Debug)]
struct Case {
    n_requests: usize,
    replicas: usize,
    policy: RouterPolicy,
    trace_seed: u64,
}

#[test]
fn sharded_serving_is_bit_identical_to_solo_for_random_traces() {
    forall_ck(
        0xC1057E5,
        6,
        |rng: &mut Prng, size| Case {
            n_requests: 2 + rng.below(3),
            replicas: 1 + rng.below(3),
            policy: POLICIES[rng.below(POLICIES.len())],
            trace_seed: 1 + (size as u64) * 1000 + rng.below(1000) as u64,
        },
        |case| {
            let trace = RequestTrace::generate_mixed(
                case.n_requests,
                &[128, 256],
                800,
                case.trace_seed,
            );
            // solo reference: monolithic prefills on one fresh engine
            let mut eng = Engine::new_native(native_cfg()).map_err(|e| e.to_string())?;
            let solo: Vec<_> = trace
                .requests
                .iter()
                .map(|r| eng.prefill(r.id, &r.spec.generate()).unwrap())
                .collect();
            let opts = ServerOptions::builder()
                .replicas(case.replicas)
                .build()
                .map_err(|e| e.to_string())?;
            let cluster =
                Cluster::start_with("artifacts".into(), native_cfg(), opts, case.policy)
                    .map_err(|e| e.to_string())?;
            assert_eq!(cluster.n_replicas(), case.replicas);
            for r in trace.requests.clone() {
                cluster.submit(r);
            }
            let run = cluster.drain().map_err(|e| e.to_string())?;
            if run.completions.len() != trace.requests.len() {
                return Err(format!(
                    "{} completions for {} requests",
                    run.completions.len(),
                    trace.requests.len()
                ));
            }
            // the live placement log must match a pure router replay
            let replay =
                Router::new(case.policy, case.replicas, &native_cfg()).route_trace(&trace);
            if run.placements != replay {
                return Err("cluster placements diverged from pure router replay".into());
            }
            for (c, s) in run.completions.iter().zip(&solo) {
                if c.request_id != s.metrics.request_id {
                    return Err(format!("id order: {} vs {}", c.request_id, s.metrics.request_id));
                }
                if c.run.first_token != s.first_token {
                    return Err(format!("req {}: first token diverged", c.request_id));
                }
                if c.run.logits_last != s.logits_last {
                    return Err(format!("req {}: last-position logits diverged", c.request_id));
                }
                if c.run.hidden_last_chunk != s.hidden_last_chunk {
                    return Err(format!("req {}: hidden state diverged", c.request_id));
                }
            }
            // every request was placed on a real replica and shows up in
            // the sharded summary's per-replica counters
            let summary = run.summary();
            if summary.replicas != case.replicas {
                return Err(format!(
                    "summary saw {} replicas, cluster had {}",
                    summary.replicas, case.replicas
                ));
            }
            let placed: u64 = summary.replica_requests.iter().sum();
            if placed != trace.requests.len() as u64 {
                return Err(format!("{placed} placements for {} requests", trace.requests.len()));
            }
            Ok(())
        },
    );
}
