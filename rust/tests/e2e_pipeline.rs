//! End-to-end: the artifact-backed Engine must agree with the pure-Rust
//! reference prefill, and both SAU backends (PJRT batched vs native) must
//! agree with each other. Requires `make artifacts`.

use fast_prefill::config::{FlexParams, TINY};
use fast_prefill::coordinator::{Engine, EngineConfig};
use fast_prefill::model::{prefill_reference, ModelWeights};
use fast_prefill::util::stats::rel_l2;
use fast_prefill::workload::prompts::{PromptKind, PromptSpec};

fn engine(cfg: EngineConfig) -> Option<Engine> {
    match Engine::new("artifacts", cfg) {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e:#}");
            None
        }
    }
}

fn base_cfg() -> EngineConfig {
    let mut cfg = EngineConfig::new(TINY.clone());
    cfg.weight_seed = 1234;
    cfg
}

fn tokens(n: usize) -> Vec<u8> {
    PromptSpec { kind: PromptKind::Mixed, tokens: n, seed: 5 }.generate()
}

#[test]
fn engine_dense_matches_reference_forward() {
    let mut cfg = base_cfg();
    cfg.flex = None;
    let Some(mut eng) = engine(cfg) else { return };
    let toks = tokens(256);
    let run = eng.prefill(0, &toks).unwrap();

    let w = ModelWeights::generate(&TINY, 1234);
    let reference = prefill_reference(&w, &toks, None);
    let ref_last = &reference.hidden.data[(toks.len() - 128) * TINY.d_model..];

    let rel = rel_l2(&run.hidden_last_chunk, ref_last);
    assert!(rel < 2e-2, "hidden rel err {rel}");
    // logits should agree closely enough that argmax matches
    assert_eq!(run.first_token, reference.first_token, "first token differs");
}

#[test]
fn engine_flex_matches_reference_forward() {
    let mut cfg = base_cfg();
    cfg.flex = Some(FlexParams::default());
    let Some(mut eng) = engine(cfg) else { return };
    let toks = tokens(512);
    let run = eng.prefill(0, &toks).unwrap();

    let w = ModelWeights::generate(&TINY, 1234);
    let reference = prefill_reference(&w, &toks, Some(&FlexParams::default()));
    let ref_last = &reference.hidden.data[(toks.len() - 128) * TINY.d_model..];

    // f32 accumulation order differs between XLA and Rust; tiny rounding
    // shifts can flip borderline int8 quantization and block selections, so
    // the comparison is statistical, not bitwise.
    let rel = rel_l2(&run.hidden_last_chunk, ref_last);
    assert!(rel < 0.05, "hidden rel err {rel}");
    assert!((run.metrics.density - reference.avg_density).abs() < 0.1);
}

#[test]
fn native_and_pjrt_sau_agree() {
    let toks = tokens(384);
    let mut cfg_native = base_cfg();
    cfg_native.native_sau = true;
    let Some(mut eng_native) = engine(cfg_native) else { return };
    let run_native = eng_native.prefill(0, &toks).unwrap();

    let mut cfg_pjrt = base_cfg();
    cfg_pjrt.native_sau = false;
    let mut eng_pjrt = Engine::new("artifacts", cfg_pjrt).unwrap();
    let run_pjrt = eng_pjrt.prefill(0, &toks).unwrap();

    // XLA's exp/rounding differs from Rust's in the last ulp; P-requant
    // boundaries amplify this across layers — agreement is statistical.
    let rel = rel_l2(&run_pjrt.hidden_last_chunk, &run_native.hidden_last_chunk);
    assert!(rel < 0.05, "SAU backends diverge: rel {rel}");
    assert_eq!(run_pjrt.first_token, run_native.first_token);
    assert_eq!(run_pjrt.metrics.jobs, run_native.metrics.jobs);
}

#[test]
fn wave_partitioning_does_not_change_results() {
    let toks = tokens(512);
    let mut cfg_one = base_cfg();
    cfg_one.wave_qblocks = 0; // single wave
    cfg_one.native_sau = true;
    let Some(mut eng_one) = engine(cfg_one) else { return };
    let run_one = eng_one.prefill(0, &toks).unwrap();

    let mut cfg_waved = base_cfg();
    cfg_waved.wave_qblocks = 1; // maximal wave splitting
    cfg_waved.native_sau = true;
    let mut eng_waved = Engine::new("artifacts", cfg_waved).unwrap();
    let run_waved = eng_waved.prefill(0, &toks).unwrap();

    let rel = rel_l2(&run_waved.hidden_last_chunk, &run_one.hidden_last_chunk);
    assert!(rel < 1e-4, "wave partitioning changed numerics: {rel}");
    assert_eq!(run_waved.first_token, run_one.first_token);
}

#[test]
fn cacheless_engine_same_numerics_different_stats() {
    let toks = tokens(512);
    let mut with_cache = base_cfg();
    with_cache.native_sau = true;
    with_cache.wave_qblocks = 2;
    let Some(mut eng_a) = engine(with_cache) else { return };
    let a = eng_a.prefill(0, &toks).unwrap();

    let mut no_cache = base_cfg();
    no_cache.native_sau = true;
    no_cache.wave_qblocks = 2;
    no_cache.cache_blocks = 0;
    let mut eng_b = Engine::new("artifacts", no_cache).unwrap();
    let b = eng_b.prefill(0, &toks).unwrap();

    assert_eq!(a.first_token, b.first_token, "cache must not affect numerics");
    assert!(a.metrics.cache_hit_rate > 0.0, "waved run should have reuse hits");
    assert_eq!(b.metrics.cache_hit_rate, 0.0);
}

#[test]
fn engine_determinism() {
    let toks = tokens(256);
    let Some(mut eng) = engine(base_cfg()) else { return };
    let a = eng.prefill(0, &toks).unwrap();
    let b = eng.prefill(1, &toks).unwrap();
    assert_eq!(a.first_token, b.first_token);
    assert_eq!(a.logits_last, b.logits_last);
    assert_eq!(a.metrics.jobs, b.metrics.jobs);
}
