//! FlexPrefill algorithm integration: crafted f32 attention structures must
//! drive the expected pattern decisions and selections through the full
//! Algorithm-1 path (scores -> JSD -> coverage -> expansion).

use fast_prefill::config::{FlexParams, BLOCK};
use fast_prefill::flexprefill::{
    generate_head_index, scores, HeadPattern, HeadStats,
};
use fast_prefill::tensor::MatF32;
use fast_prefill::util::prng::Prng;

/// Build a K matrix of `n` blocks where `anchor_blocks` contain rows highly
/// similar to the query rows (vertical structure).
fn anchored_case(n: usize, anchor_blocks: &[usize], seed: u64) -> (MatF32, Vec<MatF32>) {
    let d = 64;
    let mut rng = Prng::new(seed);
    let qhat = MatF32::from_fn(BLOCK, d, |_, _| rng.normal());
    let kblocks: Vec<MatF32> = (0..n)
        .map(|b| {
            if anchor_blocks.contains(&b) {
                // keys aligned with queries -> strong scores
                MatF32::from_fn(BLOCK, d, |r, c| qhat.at(r % BLOCK, c) + 0.2 * rng.normal())
            } else {
                MatF32::from_fn(BLOCK, d, |_, _| rng.normal())
            }
        })
        .collect();
    (qhat, kblocks)
}

fn stats_from_f32(qhat: &MatF32, kblocks: &[MatF32]) -> HeadStats {
    let n = kblocks.len();
    let d = qhat.cols;
    let (vertical, slash, a_hat) = scores::stream_head_scores_f32(qhat, kblocks);
    let mut rng = Prng::new(1);
    let kpool = MatF32::from_fn(n, d, |b, c| {
        kblocks[b].data.iter().skip(c).step_by(d).sum::<f32>() / BLOCK as f32
    });
    let qpool_hat: Vec<f32> = (0..d)
        .map(|c| qhat.data.iter().skip(c).step_by(d).sum::<f32>() / BLOCK as f32)
        .collect();
    let a_bar = scores::pooled_estimate(&qpool_hat, &kpool);
    let qpool_all = MatF32::from_fn(n, d, |b, c| {
        if b == n - 1 {
            qpool_hat[c]
        } else {
            rng.normal()
        }
    });
    HeadStats { vertical, slash, a_bar, a_hat, qpool_all, kpool }
}

#[test]
fn anchored_structure_selects_anchor_blocks() {
    let anchors = [2usize, 5];
    let (qhat, kblocks) = anchored_case(8, &anchors, 3);
    let stats = stats_from_f32(&qhat, &kblocks);
    // the anchor blocks must dominate the vertical scores
    let mean: f32 = stats.vertical.iter().sum::<f32>() / 8.0;
    for &a in &anchors {
        assert!(stats.vertical[a] > 2.0 * mean, "anchor {a} not dominant");
    }
    let idx = generate_head_index(&stats, &FlexParams::default());
    idx.validate().unwrap();
    // last query block must attend to both anchors
    let last = idx.blocks.last().unwrap();
    for &a in &anchors {
        assert!(last.contains(&(a as u32)), "anchor {a} not selected: {last:?}");
    }
}

#[test]
fn pattern_decision_follows_pooled_agreement() {
    // When the pooled estimate disagrees with the true distribution
    // (anchored: pooling destroys the per-row alignment), the head must
    // fall back to vertical-slash (d_js >= tau).
    let (qhat, kblocks) = anchored_case(8, &[3], 7);
    let stats = stats_from_f32(&qhat, &kblocks);
    let idx = generate_head_index(&stats, &FlexParams::default());
    // either pattern is legal, but the divergence must be computed
    assert!(idx.d_js >= 0.0 && idx.d_js.is_finite());
    // and with a tau of 1.0 everything becomes query-aware
    let lax = FlexParams { tau: 1.0, ..Default::default() };
    assert_eq!(generate_head_index(&stats, &lax).pattern, HeadPattern::QueryAware);
    // with tau of 0 everything becomes vertical-slash
    let strict = FlexParams { tau: 0.0, ..Default::default() };
    assert_eq!(generate_head_index(&stats, &strict).pattern, HeadPattern::VerticalSlash);
}

#[test]
fn gamma_controls_density_monotonically() {
    let (qhat, kblocks) = anchored_case(12, &[1, 4, 9], 11);
    let stats = stats_from_f32(&qhat, &kblocks);
    let mut last_jobs = 0usize;
    for gamma in [0.3f32, 0.6, 0.9, 0.99] {
        let p = FlexParams { gamma, force_diagonal: false, force_sink: false, ..Default::default() };
        let idx = generate_head_index(&stats, &p);
        let jobs = idx.job_count();
        assert!(jobs >= last_jobs, "gamma {gamma}: jobs {jobs} < {last_jobs}");
        last_jobs = jobs;
    }
}

#[test]
fn i8_and_f32_scoring_agree_on_structure() {
    // quantized scoring must find the same dominant blocks as f32 scoring
    use fast_prefill::quant::{quant_scale, quantize_with};
    use fast_prefill::tensor::MatI8;
    let (qhat, kblocks) = anchored_case(6, &[2], 13);
    let (v_f32, _, _) = scores::stream_head_scores_f32(&qhat, &kblocks);

    let qs = quant_scale(&qhat.data);
    let mut q_i8 = MatI8::zeros(BLOCK, qhat.cols);
    quantize_with(&qhat.data, qs, &mut q_i8.data);
    let kq: Vec<(MatI8, f32)> = kblocks
        .iter()
        .map(|kb| {
            let ks = quant_scale(&kb.data);
            let mut k_i8 = MatI8::zeros(BLOCK, kb.cols);
            quantize_with(&kb.data, ks, &mut k_i8.data);
            (k_i8, ks)
        })
        .collect();
    let (v_i8, _, _) = scores::stream_head_scores(&q_i8, qs, &kq);

    let argmax = |v: &[f32]| v.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
    assert_eq!(argmax(&v_f32), argmax(&v_i8));
    assert_eq!(argmax(&v_f32), 2);
}

#[test]
fn local_structure_produces_slash_mass_near_diagonal() {
    // K blocks similar to Q only in the most recent blocks => slash scores
    // concentrated at small diagonal distances
    let d = 64;
    let n = 8;
    let mut rng = Prng::new(17);
    let qhat = MatF32::from_fn(BLOCK, d, |_, _| rng.normal());
    let kblocks: Vec<MatF32> = (0..n)
        .map(|b| {
            let sim = if b >= n - 2 { 1.0 } else { 0.0 };
            MatF32::from_fn(BLOCK, d, |r, c| sim * qhat.at(r, c) + 0.3 * rng.normal())
        })
        .collect();
    let (_, slash, _) = scores::stream_head_scores_f32(&qhat, &kblocks);
    let near: f32 = slash[..2].iter().sum();
    let far: f32 = slash[2..].iter().sum();
    assert!(near > far, "slash mass not local: near {near} far {far}");
}
