//! Property-based tests over coordinator/cache/selection invariants and
//! the tiled parallel kernel core
//! (own mini-framework in `util::prop`; proptest is unavailable offline).

use fast_prefill::config::FlexParams;
use fast_prefill::coordinator::joblist::build_schedule;
use fast_prefill::flexprefill::{coverage, expand, scores, HeadIndex, HeadPattern};
use fast_prefill::kvcache::{Access, LivenessCache};
use fast_prefill::quant::{self, bitplane, nibble};
use fast_prefill::tensor::{tile, MatF32, MatI8};
use fast_prefill::util::pool::WorkerPool;
use fast_prefill::util::prng::Prng;
use fast_prefill::util::prop::{forall, forall_ck};

fn random_indices(rng: &mut Prng, heads: usize, n: usize) -> Vec<HeadIndex> {
    (0..heads)
        .map(|_| {
            let blocks: Vec<Vec<u32>> = (0..n)
                .map(|q| {
                    let mut sel: Vec<u32> = (0..=q as u32)
                        .filter(|_| rng.f32() < 0.4)
                        .collect();
                    if sel.is_empty() {
                        sel.push(q as u32);
                    }
                    sel
                })
                .collect();
            HeadIndex { pattern: HeadPattern::VerticalSlash, d_js: 0.5, blocks }
        })
        .collect()
}

#[test]
fn prop_schedule_invariants_hold() {
    forall_ck(
        0xA11CE,
        40,
        |rng, size| {
            let heads = 1 + rng.below(8);
            let group = [1, 2, 4][rng.below(3)].min(heads);
            let heads = (heads / group).max(1) * group;
            let n = 1 + size % 24;
            let wave = rng.below(n + 2);
            (random_indices(rng, heads, n), group, wave)
        },
        |(indices, group, wave)| {
            let s = build_schedule(indices, *group, *wave);
            s.check_invariants(indices, *group)
        },
    );
}

#[test]
fn prop_cache_never_holds_dead_blocks_and_conserves_stats() {
    forall_ck(
        0xCAC4E,
        60,
        |rng, size| {
            let n_keys = 2 + size % 32;
            let uses: Vec<(u64, u32)> =
                (0..n_keys).map(|k| (k as u64, 1 + rng.below(6) as u32)).collect();
            let capacity = rng.below(n_keys + 2);
            let t_hot = rng.below(6) as u32;
            // random access pattern respecting remaining uses
            let mut ops: Vec<u64> = Vec::new();
            for (k, u) in &uses {
                for _ in 0..*u {
                    ops.push(*k);
                }
            }
            rng.shuffle(&mut ops);
            (uses, ops, capacity, t_hot)
        },
        |(uses, ops, capacity, t_hot)| {
            let mut c = LivenessCache::new(*capacity, 0.5, *t_hot);
            c.init_uses(uses.iter().copied());
            for &key in ops {
                if matches!(c.lookup(key), Access::Miss) {
                    c.admit(key);
                }
                c.consume(key);
                c.check_invariants()?;
            }
            // after all uses consumed, the cache must be empty
            let s = c.stats();
            if s.hits() + s.misses != s.lookups {
                return Err("stat conservation".into());
            }
            for (k, _) in uses {
                if c.is_resident(*k) {
                    return Err(format!("block {k} survived its last use"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cache_hit_rate_monotone_in_capacity() {
    // a bigger cache never hits less on the same deterministic trace
    forall_ck(
        0xB16,
        25,
        |rng, size| {
            let n_keys = 4 + size % 24;
            let uses: Vec<(u64, u32)> =
                (0..n_keys).map(|k| (k as u64, 1 + rng.below(5) as u32)).collect();
            let mut ops: Vec<u64> = Vec::new();
            for (k, u) in &uses {
                for _ in 0..*u {
                    ops.push(*k);
                }
            }
            rng.shuffle(&mut ops);
            (uses, ops)
        },
        |(uses, ops)| {
            let run = |cap: usize| {
                let mut c = LivenessCache::new(cap, 0.5, 2);
                c.init_uses(uses.iter().copied());
                for &key in ops {
                    if matches!(c.lookup(key), Access::Miss) {
                        c.admit(key);
                    }
                    c.consume(key);
                }
                c.stats().hit_rate()
            };
            let small = run(2);
            let big = run(uses.len() + 4);
            if big + 1e-12 < small {
                return Err(format!("hit rate fell with capacity: {small} -> {big}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_coverage_selection_sound_minimal_and_streaming_equal() {
    forall_ck(
        0xC0FE,
        60,
        |rng, size| {
            let n = 1 + size * 3;
            let scores: Vec<f32> = (0..n)
                .map(|_| if rng.f32() < 0.2 { 0.0 } else { rng.f32() * 5.0 })
                .collect();
            let gamma = rng.range_f32(0.05, 0.99);
            let window = 1 + rng.below(16);
            (scores, gamma, window)
        },
        |(scores, gamma, window)| {
            let sel = coverage::coverage_select(scores, *gamma);
            let streaming = coverage::coverage_select_streaming(scores, *gamma, *window);
            if sel != streaming {
                return Err("streaming != reference".into());
            }
            let total: f32 = scores.iter().sum();
            if total > 0.0 {
                let cum: f32 = sel.iter().map(|&i| scores[i as usize]).sum();
                if cum < gamma * total - 1e-4 {
                    return Err("coverage unmet".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_vertical_slash_expansion_causal_and_complete() {
    forall_ck(
        0x5A5,
        50,
        |rng, size| {
            let n = 2 + size % 32;
            let nv = rng.below(n);
            let ns = rng.below(n);
            let vertical: Vec<u32> = rng.sample_indices(n, nv).into_iter().map(|v| v as u32).collect();
            let slash: Vec<u32> = rng.sample_indices(n, ns).into_iter().map(|v| v as u32).collect();
            (vertical, slash, n)
        },
        |(vertical, slash, n)| {
            let out = expand::vertical_slash(vertical, slash, *n, *n);
            for (q, row) in out.iter().enumerate() {
                for w in row.windows(2) {
                    if w[0] >= w[1] {
                        return Err("unsorted/dup".into());
                    }
                }
                for &b in row {
                    if b as usize > q {
                        return Err("acausal".into());
                    }
                }
                // completeness: every causal vertical and slash target present
                for &v in vertical {
                    if (v as usize) <= q && !row.contains(&v) {
                        return Err(format!("vertical {v} missing at q={q}"));
                    }
                }
                for &g in slash {
                    let k = q as i64 - g as i64;
                    if k >= 0 && !row.contains(&(k as u32)) {
                        return Err(format!("slash {g} missing at q={q}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_forced_blocks_always_present() {
    forall(
        0xF0,
        40,
        |rng, size| {
            let n = 1 + size % 16;
            let mut blocks: Vec<Vec<u32>> = vec![Vec::new(); n];
            for (q, row) in blocks.iter_mut().enumerate() {
                for b in 0..=q {
                    if rng.f32() < 0.3 {
                        row.push(b as u32);
                    }
                }
            }
            blocks
        },
        |blocks| {
            let mut b = blocks.clone();
            expand::apply_forced_blocks(&mut b, &FlexParams::default());
            b.iter().enumerate().all(|(q, row)| row.contains(&0) && row.contains(&(q as u32)))
        },
    );
}

fn rand_f32_mat(rng: &mut Prng, r: usize, c: usize) -> MatF32 {
    MatF32::from_fn(r, c, |_, _| rng.normal())
}

fn rand_i8_mat(rng: &mut Prng, r: usize, c: usize) -> MatI8 {
    MatI8 { rows: r, cols: c, data: (0..r * c).map(|_| rng.i8_sym()).collect() }
}

#[test]
fn prop_tiled_f32_kernels_agree_with_scalar_oracle() {
    // randomized shapes, including non-multiples of the tile edge, and
    // randomized tile sizes — tiled f32 kernels keep the oracle's exact
    // accumulation order, so agreement is bitwise
    forall_ck(
        0x711E5,
        40,
        |rng, size| {
            let m = 1 + rng.below(size + 4);
            let k = 1 + rng.below(2 * size + 9);
            let n = 1 + rng.below(size + 4);
            let tile = [1, 3, 16, 64, 100][rng.below(5)];
            (rand_f32_mat(rng, m, k), rand_f32_mat(rng, k, n), tile)
        },
        |(a, b, tile)| {
            let want = fast_prefill::tensor::ops::matmul(a, b);
            if tile::matmul_with(a, b, *tile) != want {
                return Err("tiled matmul != scalar oracle".into());
            }
            let bt = b.transpose();
            let want_bt = fast_prefill::tensor::ops::matmul_bt(a, &bt);
            if tile::matmul_bt_with(a, &bt, *tile) != want_bt {
                return Err("tiled matmul_bt != scalar oracle".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_tiled_int8_kernels_agree_with_quant_oracle() {
    forall_ck(
        0x71178,
        40,
        |rng, size| {
            let m = 1 + rng.below(size + 4);
            let k = 1 + rng.below(2 * size + 9);
            let n = 1 + rng.below(size + 4);
            let tile = [1, 5, 32, 64, 200][rng.below(5)];
            (rand_i8_mat(rng, m, k), rand_i8_mat(rng, k, n), tile)
        },
        |(a, b, tile)| {
            if tile::int8_matmul_with(a, b, *tile) != quant::int8_matmul(a, b) {
                return Err("tiled int8_matmul != oracle".into());
            }
            let bt = b.transpose();
            if tile::int8_matmul_bt_with(a, &bt, *tile) != quant::int8_matmul_bt(a, &bt) {
                return Err("tiled int8_matmul_bt != oracle".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pool_map_bit_identical_for_pool_sizes_1_2_8() {
    // the tiled kernels under the pool: same job set, any worker count,
    // identical output bytes
    forall_ck(
        0x9001,
        15,
        |rng, size| {
            let jobs = 1 + rng.below(10);
            let m = 1 + rng.below(size % 20 + 6);
            let k = 1 + rng.below(30);
            let pairs: Vec<(MatI8, MatI8)> = (0..jobs)
                .map(|_| (rand_i8_mat(rng, m, k), rand_i8_mat(rng, m, k)))
                .collect();
            pairs
        },
        |pairs| {
            let run = |threads: usize| -> Vec<Vec<i32>> {
                WorkerPool::with_threads(threads)
                    .map(pairs.len(), |i| tile::int8_matmul_bt(&pairs[i].0, &pairs[i].1))
            };
            let one = run(1);
            for threads in [2usize, 8] {
                if run(threads) != one {
                    return Err(format!("pool size {threads} changed kernel results"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_parallel_head_scoring_deterministic_across_thread_counts() {
    forall_ck(
        0x51D0,
        12,
        |rng, size| {
            let heads = 1 + rng.below(6);
            let blocks = 1 + rng.below(size % 8 + 3);
            let d = 8 + rng.below(3) * 8;
            let per_head: Vec<(MatI8, f32, Vec<(MatI8, f32)>)> = (0..heads)
                .map(|_| {
                    let qhat = rand_i8_mat(rng, 16, d);
                    let kbs: Vec<(MatI8, f32)> = (0..blocks)
                        .map(|_| (rand_i8_mat(rng, 16, d), 0.01 + rng.f32() * 0.05))
                        .collect();
                    (qhat, 0.01 + rng.f32() * 0.05, kbs)
                })
                .collect();
            per_head
        },
        |per_head| {
            let jobs: Vec<scores::HeadJob<'_>> = per_head
                .iter()
                .map(|(qhat, qs, kbs)| scores::HeadJob {
                    qhat,
                    qs: *qs,
                    kblocks: kbs.iter().map(|(kb, ks)| (kb, *ks)).collect(),
                })
                .collect();
            let one = scores::stream_heads_parallel(&WorkerPool::with_threads(1), &jobs);
            for threads in [2usize, 8] {
                let par = scores::stream_heads_parallel(&WorkerPool::with_threads(threads), &jobs);
                if par != one {
                    return Err(format!("thread count {threads} changed head scores"));
                }
            }
            // and each head agrees with the sequential owned-data API
            for (job_out, (qhat, qs, kbs)) in one.iter().zip(per_head) {
                if *job_out != scores::stream_head_scores(qhat, *qs, kbs) {
                    return Err("parallel head != sequential stream_head_scores".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_bitplane_and_nibble_equal_direct_mul() {
    forall(
        0xB17,
        80,
        |rng, _| (rng.i8_sym(), rng.i8_sym()),
        |(a, b)| {
            let want = *a as i32 * *b as i32;
            bitplane::mul_bitplane(*a, *b) == want && nibble::mul_nibble(*a, *b) == want
        },
    );
}

#[test]
fn prop_online_softmax_merge_order_independent_f32() {
    // the exact-arithmetic property the block-major schedule relies on
    // (checked here in f32 without P-requantization)
    forall_ck(
        0x50F7,
        30,
        |rng, size| {
            let blocks = 2 + size % 5;
            let vals: Vec<Vec<f32>> = (0..blocks)
                .map(|_| (0..8).map(|_| rng.normal() * 3.0).collect())
                .collect();
            let mut order: Vec<usize> = (0..blocks).collect();
            rng.shuffle(&mut order);
            (vals, order)
        },
        |(vals, order)| {
            let fold = |idxs: &[usize]| -> (f32, f32) {
                let mut m = f32::NEG_INFINITY;
                let mut l = 0.0f32;
                for &i in idxs {
                    let rmax = vals[i].iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                    let m_new = m.max(rmax);
                    let mut s = 0.0f32;
                    for &v in &vals[i] {
                        s += (v - m_new).exp();
                    }
                    l = l * (m - m_new).exp() + s;
                    m = m_new;
                }
                (m, l)
            };
            let fwd: Vec<usize> = (0..vals.len()).collect();
            let (m1, l1) = fold(&fwd);
            let (m2, l2) = fold(order);
            if (m1 - m2).abs() > 1e-6 {
                return Err(format!("m {m1} vs {m2}"));
            }
            if (l1 - l2).abs() / l1.max(1e-9) > 1e-5 {
                return Err(format!("l {l1} vs {l2}"));
            }
            Ok(())
        },
    );
}
