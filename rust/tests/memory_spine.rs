//! Memory-spine contracts: the engine-side and sim-side consumers of the
//! canonical `ScheduleWalk` must see **identical** cache statistics for
//! the same schedule — solo and batch-merged — and batch-merged walks
//! must leave every lane's stats exactly as its solo walk would (the
//! stats-identity contract). Plus: FFN-tail batch fusion is bit-identical
//! to per-request execution. Runs fully native, every tier-1 environment.

use fast_prefill::config::{u280_cacheless, u280_fast_prefill, FpgaConfig, TINY};
use fast_prefill::coordinator::{
    build_schedule, build_schedule_batch, Engine, EngineConfig, Phase, Schedule, ScheduleWalk,
};
use fast_prefill::flexprefill::{HeadIndex, HeadPattern};
use fast_prefill::kvcache::{CacheStats, LivenessCache};
use fast_prefill::sim::price_sau_walk;
use fast_prefill::sim::hbm::Traffic;
use fast_prefill::util::prng::Prng;
use fast_prefill::util::prop::forall_ck;

fn random_indices(rng: &mut Prng, heads: usize, n: usize) -> Vec<HeadIndex> {
    (0..heads)
        .map(|_| {
            let blocks = (0..n)
                .map(|q| (0..=q as u32).filter(|_| rng.f32() < 0.45).collect::<Vec<u32>>())
                .collect();
            HeadIndex { pattern: HeadPattern::VerticalSlash, d_js: 0.5, blocks }
        })
        .collect()
}

fn fresh_cache(schedule: &Schedule, capacity: usize, t_hot: u32) -> LivenessCache {
    let mut c = if capacity > 0 {
        LivenessCache::new(capacity, 0.5, t_hot)
    } else {
        LivenessCache::disabled()
    };
    c.init_uses(schedule.uses.iter().copied());
    c
}

/// Engine-side walk: stats-only drive (what `Engine::phase_sau` does).
fn engine_walk_stats(schedule: &Schedule, capacity: usize, t_hot: u32) -> CacheStats {
    let mut cache = fresh_cache(schedule, capacity, t_hot);
    ScheduleWalk::solo(schedule).drive(std::slice::from_mut(&mut cache));
    cache.stats()
}

/// Sim-side walk: the pricing consumer (what `sim::prefill` does).
fn sim_walk_stats(
    f: &FpgaConfig,
    schedule: &Schedule,
    capacity: usize,
    t_hot: u32,
) -> CacheStats {
    let mut cache = fresh_cache(schedule, capacity, t_hot);
    let mut traffic = Traffic::default();
    let walk = ScheduleWalk::solo(schedule);
    let (t_us, compute_us) =
        price_sau_walk(f, &TINY, &walk, std::slice::from_mut(&mut cache), &mut traffic);
    assert!(t_us >= compute_us && compute_us >= 0.0);
    cache.stats()
}

#[test]
fn engine_and_sim_walks_of_the_same_schedule_agree_exactly() {
    let f = u280_fast_prefill();
    let cacheless = u280_cacheless();
    forall_ck(
        0x5EED_5011,
        40,
        |rng, size| {
            let heads = 1 + rng.below(4);
            let n = 2 + rng.below(2 + size / 10);
            let indices = random_indices(rng, heads, n);
            let wave_q = rng.below(4); // 0 = single wave
            let capacity = rng.below(8); // 0 = disabled cache
            let t_hot = rng.below(4) as u32;
            (indices, wave_q, capacity, t_hot)
        },
        |(indices, wave_q, capacity, t_hot)| {
            let schedule = build_schedule(indices, 1, *wave_q);
            let eng = engine_walk_stats(&schedule, *capacity, *t_hot);
            let sim = sim_walk_stats(&f, &schedule, *capacity, *t_hot);
            if eng != sim {
                return Err(format!("engine {eng:?} != sim {sim:?}"));
            }
            // the cacheless platform prices differently but must still
            // report the very same stats stream
            let sim_nc = sim_walk_stats(&cacheless, &schedule, *capacity, *t_hot);
            if eng != sim_nc {
                return Err(format!("engine {eng:?} != cacheless-sim {sim_nc:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn batch_merged_walks_preserve_every_lanes_solo_stats() {
    let f = u280_fast_prefill();
    forall_ck(
        0x5EED_5012,
        30,
        |rng, size| {
            let lanes = 2 + rng.below(3);
            let wave_q = 1 + rng.below(3);
            let capacity = rng.below(8);
            let t_hot = rng.below(4) as u32;
            let lane_indices: Vec<Vec<HeadIndex>> = (0..lanes)
                .map(|_| {
                    let heads = 1 + rng.below(3);
                    let n = 2 + rng.below(2 + size / 12);
                    random_indices(rng, heads, n)
                })
                .collect();
            (lane_indices, wave_q, capacity, t_hot)
        },
        |(lane_indices, wave_q, capacity, t_hot)| {
            let schedules: Vec<Schedule> =
                lane_indices.iter().map(|idx| build_schedule(idx, 1, *wave_q)).collect();
            let solo: Vec<CacheStats> = schedules
                .iter()
                .map(|s| engine_walk_stats(s, *capacity, *t_hot))
                .collect();
            let refs: Vec<&Schedule> = schedules.iter().collect();
            let batch = build_schedule_batch(&refs);

            // engine-side batched drive
            let mut caches: Vec<LivenessCache> =
                schedules.iter().map(|s| fresh_cache(s, *capacity, *t_hot)).collect();
            ScheduleWalk::batched(&batch).drive(&mut caches);
            for (lane, (c, s)) in caches.iter().zip(&solo).enumerate() {
                if c.stats() != *s {
                    return Err(format!(
                        "lane {lane}: batched {:?} != solo {s:?}",
                        c.stats()
                    ));
                }
            }

            // sim-side batched pricing sees the same stats
            let mut caches: Vec<LivenessCache> =
                schedules.iter().map(|s| fresh_cache(s, *capacity, *t_hot)).collect();
            let mut traffic = Traffic::default();
            let walk = ScheduleWalk::batched(&batch);
            price_sau_walk(&f, &TINY, &walk, &mut caches, &mut traffic);
            for (lane, (c, s)) in caches.iter().zip(&solo).enumerate() {
                if c.stats() != *s {
                    return Err(format!(
                        "lane {lane}: sim-batched {:?} != solo {s:?}",
                        c.stats()
                    ));
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// FFN-tail batch fusion
// ---------------------------------------------------------------------------

fn tokens(n: usize, seed: u64) -> Vec<u8> {
    let mut rng = Prng::new(seed);
    (0..n).map(|_| rng.below(256) as u8).collect()
}

fn native_cfg() -> EngineConfig {
    let mut cfg = EngineConfig::new_native(TINY.clone());
    cfg.weight_seed = 777;
    cfg
}

#[test]
fn ffn_tail_batch_fusion_bit_identical_to_per_request_execution() {
    let ta = tokens(384, 61);
    let tb = tokens(256, 62);
    let mut eng = Engine::new_native(native_cfg()).unwrap();
    let solo_a = eng.prefill(0, &ta).unwrap();
    let solo_b = eng.prefill(1, &tb).unwrap();

    // step both requests to the first FfnLogits boundary individually,
    // fuse exactly the FFN tail, then finish each solo — isolating the
    // fused phase as the only difference from per-request execution
    let mut sa = eng.prefill_start(0, &ta).unwrap();
    let mut sb = eng.prefill_start(1, &tb).unwrap();
    for st in [&mut sa, &mut sb] {
        eng.phase_qkv(st).unwrap();
        eng.phase_index_gen(st).unwrap();
        eng.phase_sau(st).unwrap();
        assert_eq!(st.phase(), Phase::FfnLogits);
    }
    let mut pair = [sa, sb];
    let out = eng.phase_ffn_logits_batch(&mut pair).unwrap();
    assert!(out.iter().all(|r| r.is_none()), "TINY has 2 layers; layer 0 tail fused");
    let [mut sa, mut sb] = pair;
    let finish = |eng: &mut Engine, st: &mut fast_prefill::coordinator::PrefillState| loop {
        if let Some(run) = eng.phase_step(st).unwrap() {
            break run;
        }
    };
    let run_a = finish(&mut eng, &mut sa);
    let run_b = finish(&mut eng, &mut sb);

    assert_eq!(run_a.first_token, solo_a.first_token);
    assert_eq!(run_a.logits_last, solo_a.logits_last);
    assert_eq!(run_a.hidden_last_chunk, solo_a.hidden_last_chunk);
    assert_eq!(run_b.first_token, solo_b.first_token);
    assert_eq!(run_b.logits_last, solo_b.logits_last);
    assert_eq!(run_b.hidden_last_chunk, solo_b.hidden_last_chunk);
}

#[test]
fn engine_reports_per_request_memory_attribution() {
    let toks = tokens(512, 63);
    let mut eng = Engine::new_native(native_cfg()).unwrap();
    let run = eng.prefill(0, &toks).unwrap();
    // sparse schedules over 4 blocks with a finite cache must both fetch
    // and (given reuse) hit; attribution rides the same spine walk
    assert!(run.metrics.hbm_read_bytes > 0, "no KV fetch traffic attributed");
    let fetches = run.metrics.hbm_read_bytes / TINY.kv_block_bytes() as u64;
    assert!(fetches as usize <= run.metrics.jobs, "more fetches than jobs");

    // a cacheless engine pays an on-demand gather per *job* — exactly the
    // simulator's cacheless accounting — so attribution is pinned to the
    // job count, strictly above the cached run, with identical numerics
    let mut cfg = native_cfg();
    cfg.cache_blocks = 0;
    let mut eng_nc = Engine::new_native(cfg).unwrap();
    let run_nc = eng_nc.prefill(0, &toks).unwrap();
    assert_eq!(run.first_token, run_nc.first_token);
    assert_eq!(
        run_nc.metrics.hbm_read_bytes,
        run_nc.metrics.jobs as u64 * TINY.kv_block_bytes() as u64,
        "cacheless attribution must be one gather per job (sim parity)"
    );
    assert!(run_nc.metrics.hbm_read_bytes >= run.metrics.hbm_read_bytes);
    assert!(run_nc.metrics.cache_bypasses > 0, "cacheless walk must bypass");
}
