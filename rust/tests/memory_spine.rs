//! Memory-spine contracts: the engine-side and sim-side consumers of the
//! canonical `ScheduleWalk` must see **identical** cache statistics for
//! the same schedule — solo and batch-merged — and batch-merged walks
//! must leave every lane's stats exactly as its solo walk would (the
//! stats-identity contract). Plus: FFN-tail batch fusion is bit-identical
//! to per-request execution. Runs fully native, every tier-1 environment.

use fast_prefill::config::{u280_cacheless, u280_fast_prefill, FpgaConfig, BLOCK, TINY};
use fast_prefill::coordinator::{
    build_schedule, build_schedule_batch, k_block_bytes, Engine, EngineConfig, IndexGenWalk,
    Phase, PrefillRun, Schedule, ScheduleWalk,
};
use fast_prefill::flexprefill::{HeadIndex, HeadPattern};
use fast_prefill::kvcache::{CacheStats, LivenessCache};
use fast_prefill::sim::{price_sau_walk, simulate_prefill_batch};
use fast_prefill::sim::hbm::Traffic;
use fast_prefill::util::prng::Prng;
use fast_prefill::util::prop::forall_ck;

fn random_indices(rng: &mut Prng, heads: usize, n: usize) -> Vec<HeadIndex> {
    (0..heads)
        .map(|_| {
            let blocks = (0..n)
                .map(|q| (0..=q as u32).filter(|_| rng.f32() < 0.45).collect::<Vec<u32>>())
                .collect();
            HeadIndex { pattern: HeadPattern::VerticalSlash, d_js: 0.5, blocks }
        })
        .collect()
}

fn fresh_cache(schedule: &Schedule, capacity: usize, t_hot: u32) -> LivenessCache {
    let mut c = if capacity > 0 {
        LivenessCache::new(capacity, 0.5, t_hot)
    } else {
        LivenessCache::disabled()
    };
    c.init_uses(schedule.uses.iter().copied());
    c
}

/// Engine-side walk: stats-only drive (what `Engine::phase_sau` does).
fn engine_walk_stats(schedule: &Schedule, capacity: usize, t_hot: u32) -> CacheStats {
    let mut cache = fresh_cache(schedule, capacity, t_hot);
    ScheduleWalk::solo(schedule).drive(std::slice::from_mut(&mut cache));
    cache.stats()
}

/// Sim-side walk: the pricing consumer (what `sim::prefill` does).
fn sim_walk_stats(
    f: &FpgaConfig,
    schedule: &Schedule,
    capacity: usize,
    t_hot: u32,
) -> CacheStats {
    let mut cache = fresh_cache(schedule, capacity, t_hot);
    let mut traffic = Traffic::default();
    let walk = ScheduleWalk::solo(schedule);
    let (t_us, compute_us) =
        price_sau_walk(f, &TINY, &walk, std::slice::from_mut(&mut cache), &mut traffic);
    assert!(t_us >= compute_us && compute_us >= 0.0);
    cache.stats()
}

#[test]
fn engine_and_sim_walks_of_the_same_schedule_agree_exactly() {
    let f = u280_fast_prefill();
    let cacheless = u280_cacheless();
    forall_ck(
        0x5EED_5011,
        40,
        |rng, size| {
            let heads = 1 + rng.below(4);
            let n = 2 + rng.below(2 + size / 10);
            let indices = random_indices(rng, heads, n);
            let wave_q = rng.below(4); // 0 = single wave
            let capacity = rng.below(8); // 0 = disabled cache
            let t_hot = rng.below(4) as u32;
            (indices, wave_q, capacity, t_hot)
        },
        |(indices, wave_q, capacity, t_hot)| {
            let schedule = build_schedule(indices, 1, *wave_q);
            let eng = engine_walk_stats(&schedule, *capacity, *t_hot);
            let sim = sim_walk_stats(&f, &schedule, *capacity, *t_hot);
            if eng != sim {
                return Err(format!("engine {eng:?} != sim {sim:?}"));
            }
            // the cacheless platform prices differently but must still
            // report the very same stats stream
            let sim_nc = sim_walk_stats(&cacheless, &schedule, *capacity, *t_hot);
            if eng != sim_nc {
                return Err(format!("engine {eng:?} != cacheless-sim {sim_nc:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn batch_merged_walks_preserve_every_lanes_solo_stats() {
    let f = u280_fast_prefill();
    forall_ck(
        0x5EED_5012,
        30,
        |rng, size| {
            let lanes = 2 + rng.below(3);
            let wave_q = 1 + rng.below(3);
            let capacity = rng.below(8);
            let t_hot = rng.below(4) as u32;
            let lane_indices: Vec<Vec<HeadIndex>> = (0..lanes)
                .map(|_| {
                    let heads = 1 + rng.below(3);
                    let n = 2 + rng.below(2 + size / 12);
                    random_indices(rng, heads, n)
                })
                .collect();
            (lane_indices, wave_q, capacity, t_hot)
        },
        |(lane_indices, wave_q, capacity, t_hot)| {
            let schedules: Vec<Schedule> =
                lane_indices.iter().map(|idx| build_schedule(idx, 1, *wave_q)).collect();
            let solo: Vec<CacheStats> = schedules
                .iter()
                .map(|s| engine_walk_stats(s, *capacity, *t_hot))
                .collect();
            let refs: Vec<&Schedule> = schedules.iter().collect();
            let batch = build_schedule_batch(&refs);

            // engine-side batched drive
            let mut caches: Vec<LivenessCache> =
                schedules.iter().map(|s| fresh_cache(s, *capacity, *t_hot)).collect();
            ScheduleWalk::batched(&batch).drive(&mut caches);
            for (lane, (c, s)) in caches.iter().zip(&solo).enumerate() {
                if c.stats() != *s {
                    return Err(format!(
                        "lane {lane}: batched {:?} != solo {s:?}",
                        c.stats()
                    ));
                }
            }

            // sim-side batched pricing sees the same stats
            let mut caches: Vec<LivenessCache> =
                schedules.iter().map(|s| fresh_cache(s, *capacity, *t_hot)).collect();
            let mut traffic = Traffic::default();
            let walk = ScheduleWalk::batched(&batch);
            price_sau_walk(&f, &TINY, &walk, &mut caches, &mut traffic);
            for (lane, (c, s)) in caches.iter().zip(&solo).enumerate() {
                if c.stats() != *s {
                    return Err(format!(
                        "lane {lane}: sim-batched {:?} != solo {s:?}",
                        c.stats()
                    ));
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// FFN-tail batch fusion
// ---------------------------------------------------------------------------

fn tokens(n: usize, seed: u64) -> Vec<u8> {
    let mut rng = Prng::new(seed);
    (0..n).map(|_| rng.below(256) as u8).collect()
}

fn native_cfg() -> EngineConfig {
    let mut cfg = EngineConfig::new_native(TINY.clone());
    cfg.weight_seed = 777;
    cfg
}

#[test]
fn ffn_tail_batch_fusion_bit_identical_to_per_request_execution() {
    let ta = tokens(384, 61);
    let tb = tokens(256, 62);
    let mut eng = Engine::new_native(native_cfg()).unwrap();
    let solo_a = eng.prefill(0, &ta).unwrap();
    let solo_b = eng.prefill(1, &tb).unwrap();

    // step both requests to the first FfnLogits boundary individually,
    // fuse exactly the FFN tail, then finish each solo — isolating the
    // fused phase as the only difference from per-request execution
    let mut sa = eng.prefill_start(0, &ta).unwrap();
    let mut sb = eng.prefill_start(1, &tb).unwrap();
    for st in [&mut sa, &mut sb] {
        eng.phase_qkv(st).unwrap();
        eng.phase_index_gen(st).unwrap();
        eng.phase_sau(st).unwrap();
        assert_eq!(st.phase(), Phase::FfnLogits);
    }
    let mut pair = [sa, sb];
    let out = eng.phase_ffn_logits_batch(&mut pair).unwrap();
    assert!(out.iter().all(|r| r.is_none()), "TINY has 2 layers; layer 0 tail fused");
    let [mut sa, mut sb] = pair;
    let finish = |eng: &mut Engine, st: &mut fast_prefill::coordinator::PrefillState| loop {
        if let Some(run) = eng.phase_step(st).unwrap() {
            break run;
        }
    };
    let run_a = finish(&mut eng, &mut sa);
    let run_b = finish(&mut eng, &mut sb);

    assert_eq!(run_a.first_token, solo_a.first_token);
    assert_eq!(run_a.logits_last, solo_a.logits_last);
    assert_eq!(run_a.hidden_last_chunk, solo_a.hidden_last_chunk);
    assert_eq!(run_b.first_token, solo_b.first_token);
    assert_eq!(run_b.logits_last, solo_b.logits_last);
    assert_eq!(run_b.hidden_last_chunk, solo_b.hidden_last_chunk);
}

#[test]
fn engine_reports_per_request_memory_attribution() {
    let toks = tokens(512, 63);
    let mut eng = Engine::new_native(native_cfg()).unwrap();
    let run = eng.prefill(0, &toks).unwrap();
    // sparse schedules over 4 blocks with a finite cache must both fetch
    // and (given reuse) hit; attribution rides the same spine walk
    assert!(run.metrics.hbm_read_bytes > 0, "no KV fetch traffic attributed");
    let fetches = run.metrics.hbm_read_bytes / TINY.kv_block_bytes() as u64;
    assert!(fetches as usize <= run.metrics.jobs, "more fetches than jobs");

    // a cacheless engine pays an on-demand gather per *job* — exactly the
    // simulator's cacheless accounting — so attribution is pinned to the
    // job count, strictly above the cached run, with identical numerics
    let mut cfg = native_cfg();
    cfg.cache_blocks = 0;
    let mut eng_nc = Engine::new_native(cfg).unwrap();
    let run_nc = eng_nc.prefill(0, &toks).unwrap();
    assert_eq!(run.first_token, run_nc.first_token);
    assert_eq!(
        run_nc.metrics.hbm_read_bytes,
        run_nc.metrics.jobs as u64 * TINY.kv_block_bytes() as u64,
        "cacheless attribution must be one gather per job (sim parity)"
    );
    assert!(run_nc.metrics.hbm_read_bytes >= run.metrics.hbm_read_bytes);
    assert!(run_nc.metrics.cache_bypasses > 0, "cacheless walk must bypass");
}

// ---------------------------------------------------------------------------
// Fused index generation: one K stream, per-lane attribution
// ---------------------------------------------------------------------------

#[test]
fn index_gen_walk_pricing_invariants() {
    forall_ck(
        0x5EED_5013,
        40,
        |rng, size| {
            let lanes = 1 + rng.below(4);
            let n_kv_heads = 1 + rng.below(4);
            let group_size = 1 + rng.below(3);
            let blocks: Vec<usize> =
                (0..lanes).map(|_| 1 + rng.below(2 + size / 4)).collect();
            (n_kv_heads, group_size, blocks)
        },
        |(n_kv_heads, group_size, blocks)| {
            let kb = k_block_bytes(&TINY);
            let walk = IndexGenWalk::new(*n_kv_heads, *group_size, blocks.clone());
            let p = walk.price(kb);
            let merged = *blocks.iter().max().unwrap();
            if p.fused_bytes != (merged * n_kv_heads) as u64 * kb {
                return Err(format!(
                    "fused stream must span the merged extent once per kv head: {p:?}"
                ));
            }
            if p.lane_bytes.iter().sum::<u64>() != p.fused_bytes {
                return Err(format!("lane attribution must sum to the fused stream: {p:?}"));
            }
            for (l, &n) in blocks.iter().enumerate() {
                let solo = (n * n_kv_heads) as u64 * kb;
                if p.solo_bytes[l] != solo {
                    return Err(format!("lane {l}: solo pricing drifted: {p:?}"));
                }
                if p.lane_bytes[l] > solo {
                    return Err(format!("lane {l}: attributed above its solo cost: {p:?}"));
                }
                if p.lane_saved[l] != solo - p.lane_bytes[l] {
                    return Err(format!("lane {l}: saved != solo - attributed: {p:?}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn index_gen_batch_fusion_bit_identical_and_shares_one_k_stream() {
    let ta = tokens(384, 71);
    let tb = tokens(256, 72);
    let mut eng = Engine::new_native(native_cfg()).unwrap();
    let solo_a = eng.prefill(0, &ta).unwrap();
    let solo_b = eng.prefill(1, &tb).unwrap();

    // step both requests to the layer-0 IndexGen boundary individually,
    // fuse exactly that phase, then finish each solo
    let mut sa = eng.prefill_start(0, &ta).unwrap();
    let mut sb = eng.prefill_start(1, &tb).unwrap();
    for st in [&mut sa, &mut sb] {
        eng.phase_qkv(st).unwrap();
        assert_eq!(st.phase(), Phase::IndexGen);
    }
    let mut pair = [sa, sb];
    eng.phase_index_gen_batch(&mut pair).unwrap();
    let [mut sa, mut sb] = pair;
    assert_eq!(sa.phase(), Phase::Sau);
    assert_eq!(sb.phase(), Phase::Sau);
    let finish = |eng: &mut Engine, st: &mut fast_prefill::coordinator::PrefillState| loop {
        if let Some(run) = eng.phase_step(st).unwrap() {
            break run;
        }
    };
    let run_a = finish(&mut eng, &mut sa);
    let run_b = finish(&mut eng, &mut sb);

    for (fused, solo) in [(&run_a, &solo_a), (&run_b, &solo_b)] {
        assert_eq!(fused.first_token, solo.first_token);
        assert_eq!(fused.logits_last, solo.logits_last);
        assert_eq!(fused.hidden_last_chunk, solo.hidden_last_chunk);
        assert_eq!(fused.index_sets.len(), solo.index_sets.len());
        for (lf, ls) in fused.index_sets.iter().zip(&solo.index_sets) {
            for (i_f, i_s) in lf.iter().zip(ls) {
                assert_eq!(i_f.pattern, i_s.pattern);
                assert_eq!(i_f.blocks, i_s.blocks);
            }
        }
    }

    // the fused layer-0 stream covers the merged (longer-lane) extent once,
    // so together the lanes save exactly the shorter lane's solo stream
    let kb = k_block_bytes(&TINY);
    let overlap = (256 / BLOCK * TINY.n_kv_heads) as u64 * kb;
    let fused_sum = run_a.metrics.sigu_hbm_read_bytes + run_b.metrics.sigu_hbm_read_bytes;
    let solo_sum = solo_a.metrics.sigu_hbm_read_bytes + solo_b.metrics.sigu_hbm_read_bytes;
    assert!(fused_sum < solo_sum, "fusion must shrink priced K-stream reads");
    assert_eq!(solo_sum - fused_sum, overlap, "saving = shorter lane's layer-0 stream");
    assert_eq!(
        run_a.metrics.sigu_hbm_saved_bytes + run_b.metrics.sigu_hbm_saved_bytes,
        overlap
    );
    assert_eq!(run_a.metrics.sigu_fused_phases, 1);
    assert_eq!(run_b.metrics.sigu_fused_phases, 1);
    assert_eq!(run_a.metrics.sigu_fused_width_sum, 2);
    assert_eq!(solo_a.metrics.sigu_fused_phases, 0, "solo prefills never fuse");
    assert_eq!(solo_a.metrics.sigu_hbm_saved_bytes, 0);
}

#[test]
fn engine_and_sim_agree_on_fused_index_gen_attribution() {
    let ta = tokens(384, 73);
    let tb = tokens(256, 74);
    let mut eng = Engine::new_native(native_cfg()).unwrap();
    let solo_a = eng.prefill(0, &ta).unwrap();
    let solo_b = eng.prefill(1, &tb).unwrap();

    // fused serving: both lanes in lockstep through the grouped stepper,
    // so every layer's IndexGen fuses
    let mut states =
        vec![eng.prefill_start(0, &ta).unwrap(), eng.prefill_start(1, &tb).unwrap()];
    let mut runs: Vec<Option<PrefillRun>> = vec![None, None];
    while runs.iter().any(|r| r.is_none()) {
        for (slot, r) in runs.iter_mut().zip(eng.phase_step_group(&mut states).unwrap()) {
            if let Some(run) = r {
                *slot = Some(run);
            }
        }
    }
    let runs: Vec<PrefillRun> = runs.into_iter().map(|r| r.unwrap()).collect();

    // the sim's batch point prices the same fused stream through the same
    // IndexGenWalk — per-lane attribution must agree exactly
    let sim = simulate_prefill_batch(
        &u280_fast_prefill(),
        &TINY,
        &[ta.len(), tb.len()],
        &[&solo_a.index_sets, &solo_b.index_sets],
    );
    for (lane, (run, ls)) in runs.iter().zip(&sim.lanes).enumerate() {
        assert_eq!(
            run.metrics.sigu_hbm_read_bytes, ls.sigu_hbm_read_bytes,
            "lane {lane}: engine fused sigu attribution != sim's"
        );
    }
    assert_eq!(runs[0].metrics.sigu_fused_phases as usize, TINY.n_layers);
    assert_eq!(runs[0].metrics.sigu_fused_width_sum as usize, 2 * TINY.n_layers);
    // and the per-lane totals still sum to one fused stream per layer
    let fused_total: u64 = runs.iter().map(|r| r.metrics.sigu_hbm_read_bytes).sum();
    let merged = ta.len().max(tb.len()) / BLOCK;
    assert_eq!(
        fused_total,
        (TINY.n_layers * merged * TINY.n_kv_heads) as u64 * k_block_bytes(&TINY)
    );
}

#[test]
fn decode_engine_and_sim_price_identical_kv_traffic() {
    // the decode twin of the stats-identity contract: the engine's
    // per-step counters and the simulator's decode point both price KV
    // gather/append through `DecodeStepWalk`, so their byte totals must
    // agree exactly — and match a hand-priced span
    use fast_prefill::coordinator::{kv_token_bytes, DecodeStepWalk, PrefillArgs};
    use fast_prefill::sim::simulate_decode_steps;

    let n = 256usize;
    let steps = 5usize;
    let toks = tokens(n, 91);
    let mut eng = Engine::new_native(native_cfg()).unwrap();
    let mut st = eng
        .prefill_start_with(0, &toks, PrefillArgs { chunk_blocks: 0, capture_decode: true })
        .unwrap();
    let run = loop {
        if let Some(r) = eng.phase_step(&mut st).unwrap() {
            break r;
        }
    };
    let mut ds = eng.decode_start(0, &run, steps).unwrap();
    while !ds.done() {
        eng.decode_step(&mut ds).unwrap();
    }

    let walk = DecodeStepWalk::new(&TINY).price_span(n, steps);
    assert_eq!(ds.hbm_read_bytes, walk.read_bytes, "engine reads = spine span");
    assert_eq!(ds.hbm_write_bytes, walk.write_bytes, "engine writes = spine span");

    let sim = simulate_decode_steps(&u280_fast_prefill(), &TINY, n, steps);
    assert_eq!(sim.kv_read_bytes, ds.hbm_read_bytes, "sim reads = engine reads");
    assert_eq!(sim.kv_write_bytes, ds.hbm_write_bytes, "sim writes = engine writes");
    assert!(sim.total_us > 0.0 && sim.tpot_us > 0.0);

    // hand-priced: per step at pre-step pos p, each layer reads (p+1)
    // resident tokens' K/V rows and appends one
    let tok_bytes = kv_token_bytes(&TINY);
    let expect_read: u64 = (0..steps as u64)
        .map(|i| TINY.n_layers as u64 * (n as u64 + i + 1) * tok_bytes)
        .sum();
    assert_eq!(ds.hbm_read_bytes, expect_read);
    assert_eq!(ds.hbm_write_bytes, steps as u64 * TINY.n_layers as u64 * tok_bytes);
}
