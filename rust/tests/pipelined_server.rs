//! Phase-pipelined serving: under contention (2-4 in-flight requests,
//! mixed context lengths, FCFS and SJF, fused phase batches) every
//! request's output must be **bit-identical** to a solo
//! `Engine::prefill`, and the whole server must be deterministic across
//! `FASTP_THREADS`-style thread budgets. Runs fully native — no
//! artifacts, every tier-1 environment.

use fast_prefill::config::{BLOCK, TINY};
use fast_prefill::coordinator::{
    Completion, Engine, EngineConfig, Policy, PrefixConfig, PrefillRun, Server, ServerOptions,
};
use fast_prefill::workload::prompts::{Priority, PromptKind, PromptSpec, TraceRequest};

fn native_cfg() -> EngineConfig {
    let mut cfg = EngineConfig::new_native(TINY.clone());
    cfg.weight_seed = 4242;
    cfg
}

fn spec(tokens: usize, seed: u64) -> PromptSpec {
    PromptSpec { kind: PromptKind::Mixed, tokens, seed }
}

fn req(id: u64, tokens: usize, seed: u64, priority: Priority) -> TraceRequest {
    TraceRequest { id, spec: spec(tokens, seed), arrival_us: 0, priority, decode_tokens: 0 }
}

/// The contention trace: mixed context lengths, distinct seeds, the long
/// request classed `Batch` (preemptive policies exercise the class; the
/// others ignore it).
fn mixed_requests() -> Vec<TraceRequest> {
    [
        (0u64, 256usize, Priority::Interactive),
        (1, 512, Priority::Batch),
        (2, 384, Priority::Interactive),
        (3, 128, Priority::Interactive),
    ]
    .into_iter()
    .map(|(id, tokens, priority)| req(id, tokens, 900 + id, priority))
    .collect()
}

/// Solo (monolithic) runs of the same requests on a fresh engine.
fn solo_runs(reqs: &[TraceRequest]) -> Vec<PrefillRun> {
    let mut eng = Engine::new_native(native_cfg()).unwrap();
    reqs.iter().map(|r| eng.prefill(r.id, &r.spec.generate()).unwrap()).collect()
}

fn serve_with(opts: ServerOptions) -> Vec<Completion> {
    let server = Server::start_with("artifacts".into(), native_cfg(), opts).unwrap();
    for r in mixed_requests() {
        server.submit(r);
    }
    server.drain().unwrap()
}

fn assert_runs_identical(a: &PrefillRun, b: &PrefillRun, tag: &str) {
    assert_eq!(a.first_token, b.first_token, "{tag}: first token");
    assert_eq!(a.logits_last, b.logits_last, "{tag}: logits");
    assert_eq!(a.hidden_last_chunk, b.hidden_last_chunk, "{tag}: hidden");
    assert_eq!(a.metrics.jobs, b.metrics.jobs, "{tag}: SAU jobs");
    // memory attribution rides the spine walk: identical however the
    // schedule was batched or interleaved
    assert_eq!(a.metrics.hbm_read_bytes, b.metrics.hbm_read_bytes, "{tag}: HBM attribution");
    assert_eq!(a.metrics.cache_bypasses, b.metrics.cache_bypasses, "{tag}: bypasses");
    assert_eq!(a.index_sets.len(), b.index_sets.len(), "{tag}: layers");
    for (la, lb) in a.index_sets.iter().zip(&b.index_sets) {
        for (ia, ib) in la.iter().zip(lb) {
            assert_eq!(ia.pattern, ib.pattern, "{tag}: pattern");
            assert_eq!(ia.blocks, ib.blocks, "{tag}: index blocks");
        }
    }
}

#[test]
fn pipelined_outputs_bit_identical_to_solo_prefill() {
    let reqs = mixed_requests();
    let solo = solo_runs(&reqs);
    for policy in [Policy::Fcfs, Policy::Sjf, Policy::Preemptive] {
        let done = serve_with(ServerOptions::new(2, policy));
        assert_eq!(done.len(), reqs.len());
        for (c, s) in done.iter().zip(&solo) {
            assert_eq!(c.request_id, s.metrics.request_id);
            assert_runs_identical(&c.run, s, &format!("{policy:?} req {}", c.request_id));
            assert_eq!(c.run.metrics.context_tokens, s.metrics.context_tokens);
            assert!(c.e2e_us >= c.run.metrics.ttft_us - 1.0, "e2e covers ttft");
        }
    }
}

#[test]
fn pipelined_deterministic_across_thread_budgets() {
    // per-request outputs must not depend on the shared kernel budget
    // (the FASTP_THREADS determinism assertion, via total_threads)
    let mut base = ServerOptions::new(2, Policy::Fcfs);
    base.total_threads = 1;
    let one = serve_with(base);
    for threads in [2usize, 4, 8] {
        let mut opts = ServerOptions::new(2, Policy::Fcfs);
        opts.total_threads = threads;
        let n = serve_with(opts);
        assert_eq!(one.len(), n.len());
        for (a, b) in one.iter().zip(&n) {
            assert_eq!(a.request_id, b.request_id);
            assert_runs_identical(&a.run, &b.run, &format!("budget {threads}"));
        }
    }
}

#[test]
fn pipelined_matches_serial_scheduler() {
    let serial = serve_with(ServerOptions::serial(2, Policy::Sjf));
    let pipelined = serve_with(ServerOptions::new(2, Policy::Sjf));
    assert_eq!(serial.len(), pipelined.len());
    for (a, b) in serial.iter().zip(&pipelined) {
        assert_eq!(a.request_id, b.request_id);
        assert_runs_identical(&a.run, &b.run, "serial vs pipelined");
        assert_eq!(a.pipeline_wait_us, 0.0, "serial mode has no phase waits");
    }
}

#[test]
fn deeper_pipeline_and_unbatched_phases_do_not_change_outputs() {
    let solo = solo_runs(&mixed_requests());
    // 4 in-flight on 4 workers (maximal contention for this trace)
    let mut deep = ServerOptions::new(4, Policy::Fcfs);
    deep.max_inflight = 4;
    // batching off: phase fusion must be an optimization, not a semantic
    let mut unbatched = ServerOptions::new(2, Policy::Fcfs);
    unbatched.batch_phases = false;
    for (tag, opts) in [("deep", deep), ("unbatched", unbatched)] {
        let done = serve_with(opts);
        assert_eq!(done.len(), solo.len());
        for (c, s) in done.iter().zip(&solo) {
            assert_runs_identical(&c.run, s, tag);
        }
    }
}

#[test]
fn open_loop_replay_honors_arrival_times() {
    use fast_prefill::workload::prompts::RequestTrace;
    // three requests 30 ms apart: replay must not submit them early, and
    // outputs must still be bit-identical to solo runs
    let gap_us = 30_000u64;
    let reqs: Vec<TraceRequest> = (0..3u64)
        .map(|id| TraceRequest {
            id,
            spec: spec(256, 700 + id),
            arrival_us: id * gap_us,
            priority: Priority::Interactive,
            decode_tokens: 0,
        })
        .collect();
    let solo = solo_runs(&reqs);
    let server =
        Server::start_with("artifacts".into(), native_cfg(), ServerOptions::new(2, Policy::Fcfs))
            .unwrap();
    let t0 = std::time::Instant::now();
    server.replay(&RequestTrace { requests: reqs });
    let replay_wall = t0.elapsed();
    assert!(
        replay_wall >= std::time::Duration::from_micros(2 * gap_us),
        "replay returned before the last arrival ({replay_wall:?})"
    );
    let done = server.drain().unwrap();
    assert_eq!(done.len(), 3);
    for (c, s) in done.iter().zip(&solo) {
        assert_eq!(c.request_id, s.metrics.request_id);
        assert_runs_identical(&c.run, s, "open-loop replay");
    }
}

/// The head-of-line scenario (issue shape, tiny-scale): a long `Batch`
/// prefill is mid-flight on a single worker when a short `Interactive`
/// arrives. Under FCFS the short waits for the whole long request; under
/// the preemptive policy it jumps in at the next phase boundary. Run
/// both, compare the short's user-perceived TTFT, and pin bit-identity
/// to solo runs plus a positive preemption count on the long request.
#[test]
fn preemptive_short_interactive_beats_fcfs_head_of_line() {
    // the batch anchor is deliberately heavy (2048 tokens, ~16 phase
    // steps of quadratic-ish attention) so it is still mid-flight long
    // after the 50 ms head start on any reasonable machine
    let reqs = vec![req(0, 2048, 31, Priority::Batch), req(1, 128, 32, Priority::Interactive)];
    let solo = solo_runs(&reqs);
    let mut short_e2e = Vec::new();
    for policy in [Policy::Fcfs, Policy::Preemptive] {
        let mut opts = ServerOptions::new(1, policy);
        opts.max_inflight = 2;
        let server = Server::start_with("artifacts".into(), native_cfg(), opts).unwrap();
        server.submit(reqs[0].clone());
        // let the batch request get admitted and run a phase or two
        std::thread::sleep(std::time::Duration::from_millis(50));
        server.submit(reqs[1].clone());
        let done = server.drain().unwrap();
        assert_eq!(done.len(), 2);
        for (c, s) in done.iter().zip(&solo) {
            assert_eq!(c.request_id, s.metrics.request_id);
            assert_runs_identical(&c.run, s, &format!("{policy:?} head-of-line"));
        }
        let long = &done[0];
        let short = &done[1];
        assert_eq!(short.priority, Priority::Interactive);
        if policy == Policy::Preemptive {
            assert!(
                long.preemptions > 0,
                "the mid-flight batch request never yielded a phase slot"
            );
            assert_eq!(short.preemptions, 0, "the interactive request was never jumped");
        } else {
            assert_eq!(long.preemptions + short.preemptions, 0, "FCFS never preempts");
        }
        short_e2e.push(short.e2e_us);
    }
    // user-perceived TTFT of the short request: preemptive < FCFS (under
    // FCFS on one worker it waits out the entire long prefill)
    assert!(
        short_e2e[1] < short_e2e[0],
        "preemptive {} us !< fcfs {} us",
        short_e2e[1],
        short_e2e[0]
    );
}

/// Starvation protection: with a small aging bound, a mid-flight `Batch`
/// request under a backlog of `Interactive` requests yields at most
/// `max_yields` phase slots, then ages to the front and completes ahead
/// of the tail of the stream — it is never parked indefinitely.
#[test]
fn aged_batch_completes_under_interactive_stream() {
    // heavy batch anchor (see the head-of-line test): still mid-flight
    // well past the 30 ms head start on any reasonable machine
    let mut reqs = vec![req(0, 2048, 60, Priority::Batch)];
    for id in 1..=6u64 {
        reqs.push(req(id, 128, 60 + id, Priority::Interactive));
    }
    let solo = solo_runs(&reqs);
    let mut opts = ServerOptions::new(1, Policy::Preemptive);
    opts.max_inflight = 8;
    opts.max_yields = 3;
    let server = Server::start_with("artifacts".into(), native_cfg(), opts).unwrap();
    server.submit(reqs[0].clone());
    std::thread::sleep(std::time::Duration::from_millis(30));
    for r in &reqs[1..] {
        server.submit(r.clone());
    }
    let done = server.drain().unwrap();
    assert_eq!(done.len(), reqs.len());
    for (c, s) in done.iter().zip(&solo) {
        assert_runs_identical(&c.run, s, "aged batch stream");
    }
    let batch = &done[0];
    assert_eq!(batch.priority, Priority::Batch);
    assert!(batch.preemptions > 0, "the batch request was never preempted at all");
    assert!(
        batch.preemptions <= 3,
        "aging bound violated: {} yields > max_yields 3",
        batch.preemptions
    );
    // after aging, the batch drains ahead of the interactive tail: at
    // least one interactive (same submit instant) finishes after it
    let last_interactive_e2e = done[1..].iter().map(|c| c.e2e_us).fold(0.0f64, f64::max);
    assert!(
        batch.e2e_us < last_interactive_e2e,
        "aged batch finished last ({} vs {})",
        batch.e2e_us,
        last_interactive_e2e
    );
}

/// Adaptive want hints change lease sizing only: outputs are
/// bit-identical with the feedback loop on (default) and off, and the
/// completed runs actually carry the per-phase job costs the EWMA feeds
/// on.
#[test]
fn adaptive_hints_do_not_change_outputs() {
    let on = serve_with(ServerOptions::new(2, Policy::Sjf));
    let mut opts = ServerOptions::new(2, Policy::Sjf);
    opts.adaptive_hints = false;
    let off = serve_with(opts);
    assert_eq!(on.len(), off.len());
    for (a, b) in on.iter().zip(&off) {
        assert_eq!(a.request_id, b.request_id);
        assert_runs_identical(&a.run, &b.run, "adaptive hints on/off");
    }
    // the longest request's phases are all well above the microsecond
    // timer floor: its measured per-phase job costs must be present
    // (they are what the EWMA feeds on)
    let longest = on.iter().max_by_key(|c| c.run.metrics.context_tokens).unwrap();
    let m = &longest.run.metrics;
    assert!(m.qkv_job_us > 0.0, "no measured QKV job cost");
    assert!(m.sigu_job_us > 0.0, "no measured SIGU job cost");
    assert!(m.sau_job_us > 0.0, "no measured SAU job cost");
    assert!(m.ffn_job_us > 0.0, "no measured FFN job cost");
}

#[test]
fn single_worker_pipeline_preserves_sjf_backlog_order() {
    // single worker, pre-filled queue: SJF must admit the short requests
    // first (admission order is policy-driven even when phases pipeline)
    let server = Server::start_with(
        "artifacts".into(),
        native_cfg(),
        ServerOptions::new(1, Policy::Sjf),
    )
    .unwrap();
    for (id, tokens) in [(0u64, 512usize), (1, 128), (2, 384), (3, 128)] {
        server.submit(req(id, tokens, id, Priority::Interactive));
    }
    std::thread::sleep(std::time::Duration::from_millis(50));
    let done = server.drain().unwrap();
    assert_eq!(done.len(), 4);
    // r1 (128 tokens, submitted before r2) is admitted no later than
    // r2 (384): whenever both are queued, SJF picks r1 — regardless of
    // how many requests the worker admitted before the backlog formed
    let mid = done.iter().find(|c| c.request_id == 2).unwrap();
    let short = done.iter().find(|c| c.request_id == 1).unwrap();
    assert!(
        mid.queue_us >= short.queue_us,
        "SJF: mid queued {} < short {}",
        mid.queue_us,
        short.queue_us
    );
}

// ---------------------------------------------------------------------------
// Cross-request prefix KV reuse through the server
// ---------------------------------------------------------------------------

/// With a prefix store attached and strict sequencing (1 worker, 1
/// inflight slot), the first cohort member publishes its blocks and the
/// second resumes past the shared prefix — bit-identical to a cold solo
/// run, with strictly less SAU work.
#[test]
fn prefix_enabled_server_reuses_and_stays_bit_identical() {
    let mut cfg = native_cfg();
    cfg.flex = None; // the store is dense-mode only

    let cohort = |id: u64, seed: u64| TraceRequest {
        id,
        spec: PromptSpec {
            kind: PromptKind::SharedPrefix { prefix_seed: 7, prefix_blocks: 2 },
            tokens: 512,
            seed,
        },
        arrival_us: 0,
        priority: Priority::Interactive,
        decode_tokens: 0,
    };
    let reqs = vec![cohort(0, 900), cohort(1, 901)];

    // cold reference: same dense config, fresh engine, no store
    let mut eng = Engine::new_native(cfg.clone()).unwrap();
    let solo: Vec<PrefillRun> =
        reqs.iter().map(|r| eng.prefill(r.id, &r.spec.generate()).unwrap()).collect();

    let mut opts = ServerOptions::new(1, Policy::Fcfs);
    opts.max_inflight = 1;
    opts.prefix = Some(PrefixConfig::default());
    let server = Server::start_with("artifacts".into(), cfg, opts).unwrap();
    for r in reqs.clone() {
        server.submit(r);
    }
    let mut done = server.drain().unwrap();
    done.sort_by_key(|c| c.request_id);
    assert_eq!(done.len(), 2);

    assert_eq!(done[0].run.metrics.prefix_tokens_skipped, 0, "first arrival is cold");
    assert_eq!(
        done[1].run.metrics.prefix_tokens_skipped,
        (2 * BLOCK) as u64,
        "cohort mate must resume past the shared prefix"
    );
    for (c, s) in done.iter().zip(&solo) {
        let tag = format!("prefix req {}", c.request_id);
        assert_eq!(c.run.first_token, s.first_token, "{tag}: first token");
        assert_eq!(c.run.logits_last, s.logits_last, "{tag}: logits");
        assert_eq!(c.run.hidden_last_chunk, s.hidden_last_chunk, "{tag}: hidden");
    }
    // the warm lane did strictly less work, and the sample carries it
    assert!(done[1].run.metrics.jobs < solo[1].metrics.jobs, "reuse must cut jobs");
    assert_eq!(done[1].sample().prefix_tokens_skipped, (2 * BLOCK) as u64);
}
