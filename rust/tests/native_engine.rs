//! Native-engine end-to-end: the artifact-free engine (tiled parallel
//! kernels for every stage) must agree **bit-for-bit** with the pure-Rust
//! reference prefill, and its output must be independent of the worker
//! thread count — the acceptance property of the parallel kernel core.
//! Unlike the artifact-backed e2e suite, nothing here skips: it runs in
//! every tier-1 environment.

use fast_prefill::config::{FlexParams, BLOCK, TINY};
use fast_prefill::coordinator::{Engine, EngineConfig, Policy, Server};
use fast_prefill::model::{prefill_reference, ModelWeights};
use fast_prefill::tensor::simd::{self, Backend};
use fast_prefill::workload::prompts::{PromptKind, PromptSpec, TraceRequest};

fn tokens(n: usize, seed: u64) -> Vec<u8> {
    PromptSpec { kind: PromptKind::Mixed, tokens: n, seed }.generate()
}

fn native_cfg() -> EngineConfig {
    let mut cfg = EngineConfig::new_native(TINY.clone());
    cfg.weight_seed = 1234;
    cfg
}

#[test]
fn native_engine_matches_reference_bitwise() {
    let toks = tokens(384, 5);
    let mut eng = Engine::new_native(native_cfg()).unwrap();
    let run = eng.prefill(0, &toks).unwrap();

    let w = ModelWeights::generate(&TINY, 1234);
    let reference = prefill_reference(&w, &toks, Some(&FlexParams::default()));

    assert_eq!(run.first_token, reference.first_token);
    assert_eq!(run.logits_last, reference.logits_last);
    let ref_last = &reference.hidden.data[(toks.len() - BLOCK) * TINY.d_model..];
    assert_eq!(run.hidden_last_chunk, ref_last);
    assert_eq!(run.index_sets.len(), reference.index_sets.len());
    for (le, lr) in run.index_sets.iter().zip(&reference.index_sets) {
        for (ie, ir) in le.iter().zip(lr) {
            assert_eq!(ie.pattern, ir.pattern);
            assert_eq!(ie.blocks, ir.blocks);
        }
    }
    assert!((run.metrics.density - reference.avg_density).abs() < 1e-12);
}

#[test]
fn native_engine_dense_matches_reference() {
    let toks = tokens(256, 6);
    let mut cfg = native_cfg();
    cfg.flex = None;
    let mut eng = Engine::new_native(cfg).unwrap();
    let run = eng.prefill(0, &toks).unwrap();

    let w = ModelWeights::generate(&TINY, 1234);
    let reference = prefill_reference(&w, &toks, None);
    assert_eq!(run.first_token, reference.first_token);
    assert_eq!(run.logits_last, reference.logits_last);
}

#[test]
fn engine_output_bit_identical_across_thread_counts() {
    // FASTP_THREADS=1 vs N must not change first-token logits or indices
    let toks = tokens(384, 7);
    let mut one_cfg = native_cfg();
    one_cfg.threads = 1;
    let mut eng_one = Engine::new_native(one_cfg).unwrap();
    let one = eng_one.prefill(0, &toks).unwrap();

    for threads in [2usize, 4, 8] {
        let mut cfg = native_cfg();
        cfg.threads = threads;
        let mut eng = Engine::new_native(cfg).unwrap();
        let par = eng.prefill(0, &toks).unwrap();
        assert_eq!(one.first_token, par.first_token, "threads={threads}");
        assert_eq!(one.logits_last, par.logits_last, "threads={threads}");
        assert_eq!(one.hidden_last_chunk, par.hidden_last_chunk, "threads={threads}");
        assert_eq!(one.metrics.jobs, par.metrics.jobs, "threads={threads}");
        for (la, lb) in one.index_sets.iter().zip(&par.index_sets) {
            for (ia, ib) in la.iter().zip(lb) {
                assert_eq!(ia.pattern, ib.pattern);
                assert_eq!(ia.blocks, ib.blocks);
            }
        }
    }
}

#[test]
fn engine_output_bit_identical_across_kernel_backends() {
    // forcing the scalar reference vs the detected vector backend on the
    // engine's KernelCtx must not change a single output bit, and the
    // selected backend must be recorded in the run's metrics
    let toks = tokens(384, 14);
    let mut eng_scalar = Engine::new_native(native_cfg()).unwrap();
    eng_scalar.ctx.backend = Backend::Scalar;
    let scalar = eng_scalar.prefill(0, &toks).unwrap();
    assert_eq!(scalar.metrics.kernel_backend, "scalar");

    let vector = simd::detect();
    let mut eng_vec = Engine::new_native(native_cfg()).unwrap();
    eng_vec.ctx.backend = vector;
    let vec_run = eng_vec.prefill(0, &toks).unwrap();
    assert_eq!(vec_run.metrics.kernel_backend, vector.name());

    assert_eq!(scalar.first_token, vec_run.first_token);
    assert_eq!(scalar.logits_last, vec_run.logits_last);
    assert_eq!(scalar.hidden_last_chunk, vec_run.hidden_last_chunk);
    assert_eq!(scalar.metrics.jobs, vec_run.metrics.jobs);
    for (la, lb) in scalar.index_sets.iter().zip(&vec_run.index_sets) {
        for (ia, ib) in la.iter().zip(lb) {
            assert_eq!(ia.pattern, ib.pattern);
            assert_eq!(ia.blocks, ib.blocks);
        }
    }
}

#[test]
fn wave_partitioning_does_not_change_native_results() {
    let toks = tokens(384, 8);
    let mut cfg_one = native_cfg();
    cfg_one.wave_qblocks = 0; // single wave
    let mut eng_one = Engine::new_native(cfg_one).unwrap();
    let run_one = eng_one.prefill(0, &toks).unwrap();

    let mut cfg_waved = native_cfg();
    cfg_waved.wave_qblocks = 1; // maximal wave splitting
    let mut eng_waved = Engine::new_native(cfg_waved).unwrap();
    let run_waved = eng_waved.prefill(0, &toks).unwrap();

    assert_eq!(run_one.first_token, run_waved.first_token);
    assert_eq!(run_one.logits_last, run_waved.logits_last);
    assert_eq!(run_one.metrics.jobs, run_waved.metrics.jobs);
}

#[test]
fn cacheless_native_engine_same_numerics_different_stats() {
    let toks = tokens(512, 9);
    let mut with_cache = native_cfg();
    with_cache.wave_qblocks = 2;
    let mut eng_a = Engine::new_native(with_cache).unwrap();
    let a = eng_a.prefill(0, &toks).unwrap();

    let mut no_cache = native_cfg();
    no_cache.wave_qblocks = 2;
    no_cache.cache_blocks = 0;
    let mut eng_b = Engine::new_native(no_cache).unwrap();
    let b = eng_b.prefill(0, &toks).unwrap();

    assert_eq!(a.first_token, b.first_token, "cache must not affect numerics");
    assert_eq!(a.logits_last, b.logits_last);
    assert!(a.metrics.cache_hit_rate > 0.0, "waved run should have reuse hits");
    assert_eq!(b.metrics.cache_hit_rate, 0.0);
}

#[test]
fn phase_stepping_matches_monolithic_prefill() {
    // the resumable phase API is the monolithic prefill, one phase at a
    // time — bit-identical outputs
    let toks = tokens(384, 11);
    let mut eng = Engine::new_native(native_cfg()).unwrap();
    let mono = eng.prefill(0, &toks).unwrap();

    let mut st = eng.prefill_start(0, &toks).unwrap();
    // first layer through the named phase methods...
    eng.phase_qkv(&mut st).unwrap();
    eng.phase_index_gen(&mut st).unwrap();
    eng.phase_sau(&mut st).unwrap();
    // ...then generic stepping to completion
    let run = loop {
        if let Some(run) = eng.phase_step(&mut st).unwrap() {
            break run;
        }
    };
    assert_eq!(run.first_token, mono.first_token);
    assert_eq!(run.logits_last, mono.logits_last);
    assert_eq!(run.hidden_last_chunk, mono.hidden_last_chunk);
    assert_eq!(run.metrics.jobs, mono.metrics.jobs);
}

#[test]
fn fused_phase_groups_match_solo_prefill() {
    // two co-resident requests stepped as one group: QKV fuses per layer,
    // SAU fuses across the pair — outputs must equal solo prefills
    let ta = tokens(384, 12);
    let tb = tokens(256, 13);
    let mut eng = Engine::new_native(native_cfg()).unwrap();
    let solo_a = eng.prefill(0, &ta).unwrap();
    let solo_b = eng.prefill(1, &tb).unwrap();

    let mut states =
        vec![eng.prefill_start(0, &ta).unwrap(), eng.prefill_start(1, &tb).unwrap()];
    let runs = loop {
        let out = eng.phase_step_group(&mut states).unwrap();
        if out.iter().all(|r| r.is_some()) {
            break out;
        }
        // same layer count => the pair walks phases in lock-step
        assert!(out.iter().all(|r| r.is_none()));
    };
    let run_a = runs[0].as_ref().unwrap();
    let run_b = runs[1].as_ref().unwrap();
    assert_eq!(run_a.first_token, solo_a.first_token);
    assert_eq!(run_a.logits_last, solo_a.logits_last);
    assert_eq!(run_a.hidden_last_chunk, solo_a.hidden_last_chunk);
    assert_eq!(run_b.first_token, solo_b.first_token);
    assert_eq!(run_b.logits_last, solo_b.logits_last);
    assert_eq!(run_b.hidden_last_chunk, solo_b.hidden_last_chunk);
}

#[test]
fn native_server_serves_requests_without_artifacts() {
    // multi-worker serving over the fully-native engine: no artifacts,
    // no pjrt feature, just the tiled parallel kernel core
    let server = Server::start("artifacts".into(), native_cfg(), 2, Policy::Fcfs).unwrap();
    for id in 0..3u64 {
        server.submit(TraceRequest {
            id,
            spec: PromptSpec { kind: PromptKind::Mixed, tokens: 256, seed: id },
            arrival_us: 0,
            priority: Default::default(),
            decode_tokens: 0,
        });
    }
    let completions = server.drain().unwrap();
    assert_eq!(completions.len(), 3);
    for (i, c) in completions.iter().enumerate() {
        assert_eq!(c.request_id, i as u64);
        assert_eq!(c.run.metrics.context_tokens, 256);
    }
}
