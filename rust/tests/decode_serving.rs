//! Continuous batching: chunked prefill slices and decode co-scheduling
//! must be pure **scheduling** transforms. Whatever the chunk size,
//! policy, worker count or thread budget, every request's prefill
//! outputs are bit-identical to a monolithic solo `Engine::prefill`, and
//! every request's decode tokens are bit-identical to a solo
//! `Decoder::generate` continuation of the same prefill. Runs fully
//! native — no artifacts, every tier-1 environment.

use fast_prefill::config::TINY;
use fast_prefill::coordinator::{
    Completion, Engine, EngineConfig, Policy, PrefillArgs, PrefillRun, Server, ServerOptions,
};
use fast_prefill::model::decode::Decoder;
use fast_prefill::model::ModelWeights;
use fast_prefill::workload::prompts::{Priority, PromptKind, PromptSpec, TraceRequest};

fn native_cfg() -> EngineConfig {
    let mut cfg = EngineConfig::new_native(TINY.clone());
    cfg.weight_seed = 4242;
    // dense: chunked prefill is a dense-only transform (sparse SIGU is
    // not chunk-closed), and decode attention is dense by definition
    cfg.flex = None;
    cfg
}

fn req(
    id: u64,
    tokens: usize,
    seed: u64,
    priority: Priority,
    decode_tokens: usize,
) -> TraceRequest {
    TraceRequest {
        id,
        spec: PromptSpec { kind: PromptKind::Mixed, tokens, seed },
        arrival_us: 0,
        priority,
        decode_tokens,
    }
}

/// The mixed lifecycle trace: prefill-only and decoding requests side by
/// side, mixed context lengths, one request classed `Batch`.
fn mixed_trace() -> Vec<TraceRequest> {
    vec![
        req(0, 256, 900, Priority::Interactive, 4),
        req(1, 512, 901, Priority::Batch, 0),
        req(2, 384, 902, Priority::Interactive, 6),
        req(3, 128, 903, Priority::Interactive, 3),
    ]
}

/// Monolithic capture-enabled solo prefill on a fresh engine — the run
/// keeps its `decode_inputs` so a solo decoder can continue it.
fn solo_capture_run(r: &TraceRequest) -> PrefillRun {
    let mut eng = Engine::new_native(native_cfg()).unwrap();
    let mut st = eng
        .prefill_start_with(
            r.id,
            &r.spec.generate(),
            PrefillArgs { chunk_blocks: 0, capture_decode: true },
        )
        .unwrap();
    loop {
        if let Some(run) = eng.phase_step(&mut st).unwrap() {
            return run;
        }
    }
}

/// The canonical decode continuation: a solo single-threaded
/// `Decoder::generate` from the request's own prefill capture.
fn solo_decode(r: &TraceRequest) -> Vec<u8> {
    let run = solo_capture_run(r);
    let weights = ModelWeights::generate(&TINY, native_cfg().weight_seed);
    let mut dec =
        Decoder::from_prefill_inputs(&weights, run.decode_inputs.as_ref().unwrap());
    dec.generate(run.first_token, r.decode_tokens)
}

fn serve(opts: ServerOptions, reqs: &[TraceRequest]) -> Vec<Completion> {
    let server = Server::start_with("artifacts".into(), native_cfg(), opts).unwrap();
    for r in reqs {
        server.submit(r.clone());
    }
    server.drain().unwrap()
}

/// Chunked prefill changes the *schedule* (each token slice pays its own
/// cache walk), so only the numeric outputs are asserted identical —
/// priced traffic legitimately differs from the monolithic walk.
fn assert_outputs_identical(a: &PrefillRun, b: &PrefillRun, tag: &str) {
    assert_eq!(a.first_token, b.first_token, "{tag}: first token");
    assert_eq!(a.logits_last, b.logits_last, "{tag}: logits");
    assert_eq!(a.hidden_last_chunk, b.hidden_last_chunk, "{tag}: hidden");
}

fn assert_decode_matches_solo(done: &[Completion], reqs: &[TraceRequest], tag: &str) {
    assert_eq!(done.len(), reqs.len(), "{tag}");
    for (c, r) in done.iter().zip(reqs) {
        assert_eq!(c.request_id, r.id, "{tag}");
        if r.decode_tokens > 0 {
            assert_eq!(c.decode_tokens, solo_decode(r), "{tag}: req {} decode tokens", r.id);
            assert_eq!(c.decode_step_us.len(), r.decode_tokens, "{tag}: step timings");
            assert!(c.first_token_us > 0.0, "{tag}: TTFT recorded at prefill->decode");
            assert!(c.first_token_us <= c.e2e_us, "{tag}: first token before e2e");
            assert!(c.decode_hbm_read_bytes > 0, "{tag}: decode KV reads priced");
            assert!(c.decode_hbm_write_bytes > 0, "{tag}: decode KV writes priced");
        } else {
            assert!(c.decode_tokens.is_empty(), "{tag}: prefill-only");
            assert_eq!(c.first_token_us, 0.0, "{tag}: prefill-only TTFT is e2e");
            assert_eq!(c.decode_hbm_read_bytes, 0, "{tag}");
        }
    }
}

#[test]
fn served_decode_bit_identical_to_solo_decoder_generate() {
    let reqs = mixed_trace();
    for policy in [Policy::Fcfs, Policy::Preemptive] {
        let done = serve(ServerOptions::new(2, policy), &reqs);
        assert_decode_matches_solo(&done, &reqs, &format!("{policy:?}"));
    }
}

#[test]
fn serial_baseline_decodes_identically() {
    let reqs = mixed_trace();
    let done = serve(ServerOptions::serial(2, Policy::Fcfs), &reqs);
    assert_decode_matches_solo(&done, &reqs, "serial");
}

#[test]
fn decode_deterministic_across_thread_budgets_and_fusion() {
    // decode lanes fuse through the batch axis when co-resident; tokens
    // must not depend on the shared kernel budget or on whether fusion
    // happened at all
    let reqs = mixed_trace();
    let mut unfused = ServerOptions::new(2, Policy::Fcfs);
    unfused.batch_phases = false;
    unfused.total_threads = 1;
    let baseline = serve(unfused, &reqs);
    for threads in [2usize, 8] {
        let mut opts = ServerOptions::new(2, Policy::Fcfs);
        opts.total_threads = threads;
        let done = serve(opts, &reqs);
        for (a, b) in baseline.iter().zip(&done) {
            assert_eq!(a.request_id, b.request_id);
            assert_eq!(a.decode_tokens, b.decode_tokens, "budget {threads}");
            assert_eq!(a.run.first_token, b.run.first_token, "budget {threads}");
        }
    }
}

#[test]
fn chunked_prefill_bit_identical_to_monolithic_for_every_chunk_size() {
    // the chunk-size x thread-budget sweep: slices are closed under
    // dense prefill (causal attention, absolute RoPE, per-BLOCK quant
    // scales), so outputs never move. 384 covers the not-a-divisor case
    // (ragged last slice); 128 on the 128-token request covers the
    // whole-context fallback to monolithic.
    let reqs: Vec<TraceRequest> = mixed_trace()
        .into_iter()
        .map(|mut r| {
            r.decode_tokens = 0;
            r
        })
        .collect();
    let mut eng = Engine::new_native(native_cfg()).unwrap();
    let solo: Vec<PrefillRun> =
        reqs.iter().map(|r| eng.prefill(r.id, &r.spec.generate()).unwrap()).collect();
    for chunk in [128usize, 256, 384] {
        for threads in [1usize, 4] {
            let opts = ServerOptions::builder()
                .n_workers(2)
                .prefill_chunk(chunk)
                .total_threads(threads)
                .build()
                .unwrap();
            let done = serve(opts, &reqs);
            assert_eq!(done.len(), solo.len());
            for (c, s) in done.iter().zip(&solo) {
                assert_eq!(c.request_id, s.metrics.request_id);
                assert_outputs_identical(
                    &c.run,
                    s,
                    &format!("chunk {chunk} threads {threads} req {}", c.request_id),
                );
            }
        }
    }
}

#[test]
fn chunked_server_decodes_identically_too() {
    // the full continuous-batching shape: chunked prefill slices AND
    // decode steps co-scheduled in one pipeline — tokens still match the
    // solo references exactly
    let reqs = mixed_trace();
    let opts = ServerOptions::builder()
        .n_workers(2)
        .policy(Policy::Preemptive)
        .prefill_chunk(128)
        .build()
        .unwrap();
    let done = serve(opts, &reqs);
    assert_decode_matches_solo(&done, &reqs, "chunked+decode");
    let mono = serve(ServerOptions::new(2, Policy::Preemptive), &reqs);
    for (a, b) in done.iter().zip(&mono) {
        assert_outputs_identical(&a.run, &b.run, "chunked vs monolithic serving");
        assert_eq!(a.decode_tokens, b.decode_tokens);
    }
}

#[test]
fn serve_samples_report_decode_latency_decomposition() {
    let reqs = mixed_trace();
    let done = serve(ServerOptions::new(2, Policy::Fcfs), &reqs);
    let samples: Vec<_> = done.iter().map(|c| c.sample()).collect();
    let total_decode: u64 = reqs.iter().map(|r| r.decode_tokens as u64).sum();
    for (s, r) in samples.iter().zip(&reqs) {
        assert_eq!(s.decode_tokens, r.decode_tokens as u64);
        if r.decode_tokens > 0 {
            assert!(s.tpot_us > 0.0, "TPOT populated");
            assert!(s.itl_p95_us > 0.0, "ITL populated");
            assert!(s.ttft_e2e_us() <= s.e2e_us, "user TTFT within e2e");
            assert_eq!(s.ttft_e2e_us(), s.first_token_us, "decode TTFT is first-token time");
        } else {
            assert_eq!(s.tpot_us, 0.0);
            assert_eq!(s.ttft_e2e_us(), s.e2e_us, "prefill-only TTFT falls back to e2e");
        }
    }
    let summary = fast_prefill::metrics::ServeSummary::from_samples(&samples);
    assert_eq!(summary.decode_tokens, total_decode);
    assert!(summary.tpot_mean_us > 0.0);
    assert!(summary.decode_tokens_per_s > 0.0);
    assert!(summary.decode_hbm_read_gb > 0.0);
}
