//! Cross-request prefix KV reuse (`coordinator::prefix`): the hard
//! contract is that a warm (store-resumed) prefill is **bit-identical**
//! to the same request run cold, while skipping the covered blocks'
//! QKV/IndexGen/FFN work — and that reused blocks are priced as cache
//! *hits* identically by both memory-spine consumers (engine walk and
//! cycle-simulator walk). Runs fully native, every tier-1 environment.

use std::sync::{Arc, Mutex};

use fast_prefill::config::{u280_fast_prefill, BLOCK, TINY};
use fast_prefill::coordinator::{
    build_schedule, seed_prefix, Engine, EngineConfig, EvictPolicy, PrefixConfig, PrefixStore,
    ScheduleWalk,
};
use fast_prefill::kvcache::{layer_cache, CacheStats};
use fast_prefill::model::forward::suffix_dense_indices;
use fast_prefill::sim::hbm::Traffic;
use fast_prefill::sim::price_sau_walk;
use fast_prefill::util::prng::Prng;

fn tokens(n: usize, seed: u64) -> Vec<u8> {
    let mut rng = Prng::new(seed);
    (0..n).map(|_| rng.below(256) as u8).collect()
}

/// Dense-mode native config: the prefix store is only consulted when
/// `flex` is `None` (sparse SIGU is not prefix-closed).
fn dense_cfg(threads: usize) -> EngineConfig {
    let mut cfg = EngineConfig::new_native(TINY.clone());
    cfg.flex = None;
    cfg.weight_seed = 31415;
    cfg.threads = threads;
    cfg
}

fn store_for(
    cfg: &EngineConfig,
    capacity_blocks: usize,
    policy: EvictPolicy,
) -> Arc<Mutex<PrefixStore>> {
    Arc::new(Mutex::new(PrefixStore::new(
        cfg.model.name,
        cfg.weight_seed,
        PrefixConfig { capacity_blocks, policy },
    )))
}

fn assert_outputs_identical(
    warm: &fast_prefill::coordinator::PrefillRun,
    cold: &fast_prefill::coordinator::PrefillRun,
    tag: &str,
) {
    assert_eq!(warm.first_token, cold.first_token, "{tag}: first_token");
    assert_eq!(warm.logits_last, cold.logits_last, "{tag}: logits_last");
    assert_eq!(
        warm.hidden_last_chunk, cold.hidden_last_chunk,
        "{tag}: hidden_last_chunk"
    );
}

#[test]
fn reused_prefix_is_bit_identical_across_thread_counts() {
    let producer = tokens(512, 0xA11CE);
    // consumer shares the first 2 blocks, then a guaranteed-novel tail
    let mut consumer = producer[..2 * BLOCK].to_vec();
    let mut tail = tokens(2 * BLOCK, 0xB0B);
    tail[0] = producer[2 * BLOCK] ^ 1;
    consumer.extend_from_slice(&tail);

    for threads in [1usize, 3] {
        let cfg = dense_cfg(threads);
        let tag = format!("threads={threads}");

        // cold reference: no store attached
        let mut cold_eng = Engine::new_native(cfg.clone()).unwrap();
        let cold = cold_eng.prefill(0, &consumer).unwrap();
        assert_eq!(cold.metrics.prefix_blocks_reused, 0);
        assert_eq!(cold.metrics.prefix_tokens_skipped, 0);

        // warm path: producer publishes, consumer resumes at block 2
        let mut eng = Engine::new_native(cfg.clone()).unwrap();
        eng.prefix = Some(store_for(&cfg, 4096, EvictPolicy::LivenessAware));
        let produced = eng.prefill(1, &producer).unwrap();
        assert_eq!(produced.metrics.prefix_blocks_reused, 0, "{tag}: store was empty");
        let warm = eng.prefill(2, &consumer).unwrap();

        assert_eq!(warm.metrics.prefix_blocks_reused, 2, "{tag}");
        assert_eq!(warm.metrics.prefix_tokens_skipped, (2 * BLOCK) as u64, "{tag}");
        assert_outputs_identical(&warm, &cold, &tag);
        // covered blocks run no SAU query rows and skip their KV fetches
        assert!(warm.metrics.jobs < cold.metrics.jobs, "{tag}: jobs not reduced");
        assert!(
            warm.metrics.hbm_read_bytes < cold.metrics.hbm_read_bytes,
            "{tag}: reuse must cut priced KV fetch traffic"
        );
        assert!(warm.metrics.cache_hit_rate > 0.0, "{tag}: seeded blocks must hit");
    }
}

#[test]
fn identical_request_resumes_at_the_last_block() {
    let toks = tokens(512, 0xDEED);
    let cfg = dense_cfg(1);
    let mut cold_eng = Engine::new_native(cfg.clone()).unwrap();
    let cold = cold_eng.prefill(0, &toks).unwrap();

    let mut eng = Engine::new_native(cfg.clone()).unwrap();
    eng.prefix = Some(store_for(&cfg, 4096, EvictPolicy::LivenessAware));
    eng.prefill(1, &toks).unwrap();
    let warm = eng.prefill(2, &toks).unwrap();
    // `finish()` reads the last block's hidden rows, so coverage caps at
    // n-1 blocks even for an exact replay
    assert_eq!(warm.metrics.prefix_blocks_reused, 3);
    assert_outputs_identical(&warm, &cold, "replay");
}

#[test]
fn partial_block_divergence_resumes_at_the_boundary() {
    let producer = tokens(512, 0xF00D);
    // consumer matches block 0 and *half* of block 1: content hashing is
    // block-granular, so only block 0 is reusable
    let mut consumer = producer[..BLOCK + BLOCK / 2].to_vec();
    consumer.push(producer[BLOCK + BLOCK / 2] ^ 1);
    consumer.extend(tokens(512 - consumer.len(), 0xCAFE));
    assert_eq!(consumer.len(), 512);

    let cfg = dense_cfg(1);
    let mut cold_eng = Engine::new_native(cfg.clone()).unwrap();
    let cold = cold_eng.prefill(0, &consumer).unwrap();

    let mut eng = Engine::new_native(cfg.clone()).unwrap();
    eng.prefix = Some(store_for(&cfg, 4096, EvictPolicy::LivenessAware));
    eng.prefill(1, &producer).unwrap();
    let warm = eng.prefill(2, &consumer).unwrap();
    assert_eq!(warm.metrics.prefix_blocks_reused, 1, "mid-block match must not count");
    assert_eq!(warm.metrics.prefix_tokens_skipped, BLOCK as u64);
    assert_outputs_identical(&warm, &cold, "partial-block");
}

#[test]
fn capacity_bounded_store_stays_bit_identical_under_eviction_churn() {
    for policy in [EvictPolicy::Lru, EvictPolicy::LivenessAware] {
        let a = tokens(512, 0x5EED_A);
        let b = tokens(512, 0x5EED_B);
        let mut a_consumer = a[..2 * BLOCK].to_vec();
        a_consumer.extend(tokens(2 * BLOCK, 0x7A11));
        let mut b_consumer = b[..2 * BLOCK].to_vec();
        b_consumer.extend(tokens(2 * BLOCK, 0x7A12));

        let cfg = dense_cfg(1);
        let mut cold_eng = Engine::new_native(cfg.clone()).unwrap();
        let cold_a = cold_eng.prefill(0, &a_consumer).unwrap();
        let cold_b = cold_eng.prefill(1, &b_consumer).unwrap();

        // capacity 4: publishing `b` (4 blocks) after `a` (4 blocks)
        // evicts every block of `a`
        let mut eng = Engine::new_native(cfg.clone()).unwrap();
        let store = store_for(&cfg, 4, policy);
        eng.prefix = Some(store.clone());
        eng.prefill(2, &a).unwrap();
        eng.prefill(3, &b).unwrap();
        assert!(
            store.lock().unwrap().stats().evictions > 0,
            "{policy:?}: publish churn must evict"
        );

        // `b`'s prefix survives; `a`'s is gone -> cold path, still correct
        // (warm_b runs first: warm_a's own publish churns the store again)
        let warm_b = eng.prefill(4, &b_consumer).unwrap();
        assert_eq!(warm_b.metrics.prefix_blocks_reused, 2, "{policy:?}: resident prefix");
        assert_outputs_identical(&warm_b, &cold_b, "resident-prefix");
        let warm_a = eng.prefill(5, &a_consumer).unwrap();
        assert_eq!(warm_a.metrics.prefix_blocks_reused, 0, "{policy:?}: evicted prefix");
        assert_outputs_identical(&warm_a, &cold_a, "evicted-prefix");
        assert!(store.lock().unwrap().len_blocks() <= 4, "{policy:?}: capacity bound");
    }
}

// ---------------------------------------------------------------------------
// Hit-stat identity: both spine consumers price prefix seeding the same
// ---------------------------------------------------------------------------

fn seeded_cache(
    schedule: &fast_prefill::coordinator::Schedule,
    capacity: usize,
    prefix_blocks: usize,
    n_blocks: usize,
) -> fast_prefill::kvcache::LivenessCache {
    let mut cache = layer_cache(
        capacity,
        0.5,
        0.5,
        n_blocks,
        TINY.group_size(),
        schedule.uses.iter().copied(),
    );
    if prefix_blocks > 0 {
        seed_prefix(&mut cache, schedule.n_kv_heads, prefix_blocks);
    }
    cache
}

#[test]
fn engine_and_sim_price_prefix_seeding_identically() {
    let f = u280_fast_prefill();
    let n = 6usize;
    for wave_q in [0usize, 2] {
        for capacity in [0usize, 3, 64] {
            for p in [0usize, 1, 2, 5] {
                let indices = suffix_dense_indices(TINY.n_heads, n, p);
                let schedule = build_schedule(&indices, TINY.group_size(), wave_q);

                // engine-side: stats-only drive (what `phase_sau` does)
                let mut eng_cache = seeded_cache(&schedule, capacity, p, n);
                ScheduleWalk::solo(&schedule).drive(std::slice::from_mut(&mut eng_cache));
                let eng: CacheStats = eng_cache.stats();

                // sim-side: the pricing consumer, same seeding call
                let mut sim_cache = seeded_cache(&schedule, capacity, p, n);
                let mut traffic = Traffic::default();
                let walk = ScheduleWalk::solo(&schedule);
                price_sau_walk(
                    &f,
                    &TINY,
                    &walk,
                    std::slice::from_mut(&mut sim_cache),
                    &mut traffic,
                );
                let sim = sim_cache.stats();

                assert_eq!(
                    eng, sim,
                    "wave_q={wave_q} capacity={capacity} p={p}: spine consumers diverged"
                );
                if capacity > 0 && p > 0 {
                    assert!(
                        eng.hits() > 0,
                        "wave_q={wave_q} capacity={capacity} p={p}: seeded prefix never hit"
                    );
                }
            }
        }
    }
}
