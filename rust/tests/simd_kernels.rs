//! SIMD-vs-scalar bit-identity property suite (ISSUE 4 acceptance): the
//! runtime-dispatched vector backends of `tensor::simd` must produce the
//! **same bytes** as the scalar reference for every kernel the hot paths
//! use — across ragged shapes (k, n not multiples of the vector width),
//! fully-masked softmax rows, and both `FASTP_KERNEL` override values.
//!
//! On a host without a vector ISA `simd::detect()` is `Scalar` and the
//! pins hold trivially; the CI kernel-matrix guarantees at least one
//! vector-capable leg actually exercises the AVX2/NEON paths
//! (`fastp kernels --require-simd`).

use fast_prefill::model::forward::attn_step_w8a8_bk;
use fast_prefill::quant;
use fast_prefill::tensor::simd::{self, Backend};
use fast_prefill::tensor::{tile, MatF32, MatI8};
use fast_prefill::util::prng::Prng;
use fast_prefill::util::prop::forall_ck;

fn rand_f32_mat(rng: &mut Prng, r: usize, c: usize) -> MatF32 {
    MatF32::from_fn(r, c, |_, _| rng.normal())
}

fn rand_i8_mat(rng: &mut Prng, r: usize, c: usize) -> MatI8 {
    MatI8 { rows: r, cols: c, data: (0..r * c).map(|_| rng.i8_sym()).collect() }
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn prop_simd_f32_matmuls_bit_identical_to_scalar() {
    // ragged m/k/n (deliberately including widths below one vector lane)
    // and ragged tiles: the vector backend must reproduce the scalar
    // oracle bit-for-bit, because f32 lanes only ever span independent
    // output columns
    let vec_bk = simd::detect();
    forall_ck(
        0x51AD1,
        40,
        |rng, size| {
            let m = 1 + rng.below(size + 4);
            let k = 1 + rng.below(2 * size + 11);
            let n = 1 + rng.below(size + 9);
            let tile = [1, 3, 8, 16, 64, 100][rng.below(6)];
            (rand_f32_mat(rng, m, k), rand_f32_mat(rng, k, n), tile)
        },
        |(a, b, t)| {
            let want = tile::matmul_with_bk(a, b, *t, Backend::Scalar);
            let got = tile::matmul_with_bk(a, b, *t, vec_bk);
            if bits(&got.data) != bits(&want.data) {
                return Err(format!("matmul diverged on {} (tile {t})", vec_bk.name()));
            }
            if bits(&fast_prefill::tensor::ops::matmul(a, b).data) != bits(&want.data) {
                return Err("scalar backend != ops oracle".into());
            }
            let bt = b.transpose();
            let want_bt = tile::matmul_bt_with_bk(a, &bt, *t, Backend::Scalar);
            let got_bt = tile::matmul_bt_with_bk(a, &bt, *t, vec_bk);
            if bits(&got_bt.data) != bits(&want_bt.data) {
                return Err(format!("matmul_bt diverged on {}", vec_bk.name()));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_simd_int8_matmuls_exactly_equal_scalar() {
    let vec_bk = simd::detect();
    forall_ck(
        0x51AD2,
        40,
        |rng, size| {
            let m = 1 + rng.below(size + 4);
            let k = 1 + rng.below(2 * size + 13);
            let n = 1 + rng.below(size + 7);
            let tile = [1, 8, 24, 64, 200][rng.below(5)];
            (rand_i8_mat(rng, m, k), rand_i8_mat(rng, k, n), tile)
        },
        |(a, b, t)| {
            if tile::int8_matmul_with_bk(a, b, *t, vec_bk) != quant::int8_matmul(a, b) {
                return Err(format!("int8_matmul diverged on {}", vec_bk.name()));
            }
            let bt = b.transpose();
            if tile::int8_matmul_bt_with_bk(a, &bt, *t, vec_bk) != quant::int8_matmul_bt(a, &bt) {
                return Err(format!("int8_matmul_bt diverged on {}", vec_bk.name()));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_simd_fused_softmax_acc_bit_identical() {
    // ragged (rows, kv, d), non-trivial carried online state, and rows
    // that are fully masked (every score at -inf) — the vector backend
    // must match the scalar state bit-for-bit after the fold
    let vec_bk = simd::detect();
    forall_ck(
        0x51AD3,
        40,
        |rng, size| {
            let rows = 1 + rng.below(size % 10 + 4);
            let kv = 1 + rng.below(size % 20 + 9);
            let d = 1 + rng.below(2 * size + 19);
            let mut s = rand_f32_mat(rng, rows, kv);
            // mask ~a quarter of rows entirely
            for r in 0..rows {
                if rng.f32() < 0.25 {
                    for c in 0..kv {
                        *s.at_mut(r, c) = f32::NEG_INFINITY;
                    }
                }
            }
            let v = rand_f32_mat(rng, kv, d);
            let m0: Vec<f32> = (0..rows)
                .map(|_| if rng.f32() < 0.5 { -1e30 } else { rng.normal() })
                .collect();
            let l0: Vec<f32> = (0..rows).map(|_| rng.f32() * 3.0).collect();
            let acc0 = rand_f32_mat(rng, rows, d);
            (s, v, m0, l0, acc0)
        },
        |(s, v, m0, l0, acc0)| {
            let run = |bk: Backend| {
                let mut m = m0.clone();
                let mut l = l0.clone();
                let mut acc = acc0.clone();
                tile::fused_softmax_acc_bk(s, v, &mut m, &mut l, &mut acc, bk);
                (m, l, acc)
            };
            let (ms, ls, accs) = run(Backend::Scalar);
            let (mv, lv, accv) = run(vec_bk);
            if bits(&mv) != bits(&ms) || bits(&lv) != bits(&ls) {
                return Err(format!("online (m, l) diverged on {}", vec_bk.name()));
            }
            if bits(&accv.data) != bits(&accs.data) {
                return Err(format!("accumulator diverged on {}", vec_bk.name()));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_simd_attn_step_w8a8_bit_identical() {
    // the SAU inner step (exact score matmul + requantized P@V): both
    // the diagonal-masked and unmasked variants, on ragged head dims,
    // continuing from a carried accumulator state
    let vec_bk = simd::detect();
    forall_ck(
        0x51AD4,
        30,
        |rng, size| {
            let b = 1 + rng.below(size % 12 + 4);
            let dh = 1 + rng.below(2 * size + 21);
            let q = rand_i8_mat(rng, b, dh);
            let k = rand_i8_mat(rng, b, dh);
            let v = rand_i8_mat(rng, b, dh);
            let diag = rng.f32() < 0.5;
            let m0: Vec<f32> = (0..b).map(|_| -1e30 + rng.f32()).collect();
            let l0: Vec<f32> = (0..b).map(|_| rng.f32()).collect();
            let acc0 = rand_f32_mat(rng, b, dh);
            (q, k, v, diag, m0, l0, acc0)
        },
        |(q, k, v, diag, m0, l0, acc0)| {
            let run = |bk: Backend| {
                let mut m = m0.clone();
                let mut l = l0.clone();
                let mut acc = acc0.clone();
                attn_step_w8a8_bk(q, 0.02, k, 0.03, v, 0.04, &mut m, &mut l, &mut acc, *diag, bk);
                (m, l, acc)
            };
            let (ms, ls, accs) = run(Backend::Scalar);
            let (mv, lv, accv) = run(vec_bk);
            if bits(&mv) != bits(&ms) || bits(&lv) != bits(&ls) {
                return Err(format!("attn (m, l) diverged on {}", vec_bk.name()));
            }
            if bits(&accv.data) != bits(&accs.data) {
                return Err(format!("attn accumulator diverged on {}", vec_bk.name()));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_simd_quantize_bit_identical_to_oracle() {
    // the elementwise quantize remainder (ISSUE 7): ragged widths,
    // saturation edges, round-half-to-even ties, denormals, and scales
    // down to the SCALE_EPS floor — every backend must reproduce the
    // per-element `quantize_one` oracle exactly
    let vec_bk = simd::detect();
    forall_ck(
        0x51AD6,
        60,
        |rng, size| {
            let n = 1 + rng.below(2 * size + 23);
            let mut x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            for v in x.iter_mut() {
                match rng.below(12) {
                    0 => *v *= 1.0e9,   // saturates at +/-127
                    1 => *v *= 1.0e-41, // denormal
                    2 => *v = 0.5,      // tie: rounds to even (0)
                    3 => *v = -1.5,     // tie: rounds to even (-2)
                    _ => {}
                }
            }
            let scale =
                [quant::quant_scale(&x), 1.0, 0.013, quant::SCALE_EPS / 127.0][rng.below(4)];
            (x, scale)
        },
        |(x, scale)| {
            let want: Vec<i8> = x.iter().map(|&v| quant::quantize_one(v, *scale)).collect();
            let mut got = vec![0i8; x.len()];
            vec_bk.i8_quantize(&mut got, x, *scale);
            if got != want {
                return Err(format!("i8_quantize diverged on {}", vec_bk.name()));
            }
            let mut via_helper = vec![0i8; x.len()];
            quant::quantize_with_bk(x, *scale, &mut via_helper, vec_bk);
            if via_helper != want {
                return Err("quantize_with_bk != quantize_one oracle".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_simd_rmsnorm_rope_bit_identical_to_oracle() {
    // rmsnorm_bk / rope_bk against the plain ops oracles: the row
    // reduction, rsqrt and sin/cos stay scalar by design, so the wide
    // apply must land the same bytes for every shape — including odd
    // head dims (rope leaves the last element untouched) and widths
    // below one vector lane
    use fast_prefill::tensor::ops;
    let vec_bk = simd::detect();
    forall_ck(
        0x51AD7,
        40,
        |rng, size| {
            let rows = 1 + rng.below(size % 8 + 3);
            let cols = 1 + rng.below(2 * size + 21);
            let x = rand_f32_mat(rng, rows, cols);
            let g: Vec<f32> = (0..cols).map(|_| rng.normal()).collect();
            let pos: Vec<i32> = (0..rows).map(|_| rng.below(1 << 17) as i32).collect();
            (x, g, pos)
        },
        |(x, g, pos)| {
            let want = ops::rmsnorm(x, g, 1e-5);
            let got = ops::rmsnorm_bk(x, g, 1e-5, vec_bk);
            if bits(&got.data) != bits(&want.data) {
                return Err(format!("rmsnorm diverged on {}", vec_bk.name()));
            }
            let mut want_r = x.clone();
            ops::rope(&mut want_r, pos, 10000.0);
            let mut got_r = x.clone();
            ops::rope_bk(&mut got_r, pos, 10000.0, vec_bk);
            if bits(&got_r.data) != bits(&want_r.data) {
                return Err(format!("rope diverged on {} (dh {})", vec_bk.name(), x.cols));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_simd_deq_scale_bit_identical_to_scalar() {
    // int32 accumulator dequant: `acc as f32 * s` per lane, including
    // magnitudes above 2^24 where the i32 -> f32 conversion itself rounds
    let vec_bk = simd::detect();
    forall_ck(
        0x51AD8,
        40,
        |rng, size| {
            let n = 1 + rng.below(2 * size + 19);
            let acc: Vec<i32> = (0..n)
                .map(|_| {
                    let v = rng.below(1 << 30) as i64 - (1 << 29);
                    v as i32
                })
                .collect();
            let s = [1.0f32, 6.2e-5, -0.75][rng.below(3)];
            (acc, s)
        },
        |(acc, s)| {
            let want: Vec<f32> = acc.iter().map(|&a| a as f32 * s).collect();
            let mut got = vec![0.0f32; acc.len()];
            vec_bk.f32_deq_scale(&mut got, acc, *s);
            if bits(&got) != bits(&want) {
                return Err(format!("f32_deq_scale diverged on {}", vec_bk.name()));
            }
            Ok(())
        },
    );
}

#[test]
fn tuned_profile_prefill_bit_identical_to_untuned() {
    // end-to-end autotuner acceptance (ISSUE 7): a prefill resolving
    // every kernel through a swept profile must produce the same bytes
    // as the untuned static defaults. TuneOverride::Off pins the
    // baseline even when the test process itself runs under
    // FASTP_AUTOTUNE=startup (the CI autotune leg does exactly that).
    use fast_prefill::config::TINY;
    use fast_prefill::coordinator::{Engine, EngineConfig};
    use fast_prefill::tensor::tune::{self, TuneOverride};

    let prof = tune::sweep(&tune::model_shapes(&TINY), 0.05);
    assert!(!prof.entries.is_empty());
    let toks: Vec<u8> = (0..256).map(|i| (i * 31 % 256) as u8).collect();

    let mut base_cfg = EngineConfig::new_native(TINY);
    base_cfg.tune = TuneOverride::Off;
    base_cfg.threads = 1;
    let mut tuned_cfg = EngineConfig::new_native(TINY);
    tuned_cfg.tune = TuneOverride::Profile(std::sync::Arc::new(prof));
    tuned_cfg.threads = 1;

    let a = Engine::new_native(base_cfg).unwrap().prefill(0, &toks).unwrap();
    let b = Engine::new_native(tuned_cfg).unwrap().prefill(0, &toks).unwrap();

    assert_eq!(a.metrics.tune_mode, "off");
    assert_ne!(b.metrics.tune_mode, "off");
    assert!(b.metrics.tuned_shapes > 0);
    assert_eq!(a.first_token, b.first_token);
    assert_eq!(bits(&a.logits_last), bits(&b.logits_last), "tuned logits diverged");
    assert_eq!(
        bits(&a.hidden_last_chunk),
        bits(&b.hidden_last_chunk),
        "tuned hidden state diverged"
    );
}

#[test]
fn both_dispatch_override_values_resolve_and_pin() {
    // `FASTP_KERNEL=scalar` must force the scalar reference and
    // `FASTP_KERNEL=simd` must select the detected vector backend (or
    // scalar, loudly, on a host without one) — and whichever backend the
    // override picks, kernel results stay bit-identical
    assert_eq!(simd::resolve(Some("scalar")), Backend::Scalar);
    assert_eq!(simd::resolve(Some("simd")), simd::detect());

    let mut rng = Prng::new(0x51AD5);
    let a = rand_i8_mat(&mut rng, 9, 37);
    let b = rand_i8_mat(&mut rng, 37, 5);
    let want = quant::int8_matmul(&a, &b);
    for raw in [Some("scalar"), Some("simd"), None] {
        let bk = simd::resolve(raw);
        assert_eq!(tile::int8_matmul_with_bk(&a, &b, 16, bk), want, "override {raw:?}");
    }

    // the ctx constructed from the environment carries the active choice
    assert_eq!(tile::KernelCtx::from_env().backend, simd::active());
}
