//! SIMD-vs-scalar bit-identity property suite (ISSUE 4 acceptance): the
//! runtime-dispatched vector backends of `tensor::simd` must produce the
//! **same bytes** as the scalar reference for every kernel the hot paths
//! use — across ragged shapes (k, n not multiples of the vector width),
//! fully-masked softmax rows, and both `FASTP_KERNEL` override values.
//!
//! On a host without a vector ISA `simd::detect()` is `Scalar` and the
//! pins hold trivially; the CI kernel-matrix guarantees at least one
//! vector-capable leg actually exercises the AVX2/NEON paths
//! (`fastp kernels --require-simd`).

use fast_prefill::model::forward::attn_step_w8a8_bk;
use fast_prefill::quant;
use fast_prefill::tensor::simd::{self, Backend};
use fast_prefill::tensor::{tile, MatF32, MatI8};
use fast_prefill::util::prng::Prng;
use fast_prefill::util::prop::forall_ck;

fn rand_f32_mat(rng: &mut Prng, r: usize, c: usize) -> MatF32 {
    MatF32::from_fn(r, c, |_, _| rng.normal())
}

fn rand_i8_mat(rng: &mut Prng, r: usize, c: usize) -> MatI8 {
    MatI8 { rows: r, cols: c, data: (0..r * c).map(|_| rng.i8_sym()).collect() }
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn prop_simd_f32_matmuls_bit_identical_to_scalar() {
    // ragged m/k/n (deliberately including widths below one vector lane)
    // and ragged tiles: the vector backend must reproduce the scalar
    // oracle bit-for-bit, because f32 lanes only ever span independent
    // output columns
    let vec_bk = simd::detect();
    forall_ck(
        0x51AD1,
        40,
        |rng, size| {
            let m = 1 + rng.below(size + 4);
            let k = 1 + rng.below(2 * size + 11);
            let n = 1 + rng.below(size + 9);
            let tile = [1, 3, 8, 16, 64, 100][rng.below(6)];
            (rand_f32_mat(rng, m, k), rand_f32_mat(rng, k, n), tile)
        },
        |(a, b, t)| {
            let want = tile::matmul_with_bk(a, b, *t, Backend::Scalar);
            let got = tile::matmul_with_bk(a, b, *t, vec_bk);
            if bits(&got.data) != bits(&want.data) {
                return Err(format!("matmul diverged on {} (tile {t})", vec_bk.name()));
            }
            if bits(&fast_prefill::tensor::ops::matmul(a, b).data) != bits(&want.data) {
                return Err("scalar backend != ops oracle".into());
            }
            let bt = b.transpose();
            let want_bt = tile::matmul_bt_with_bk(a, &bt, *t, Backend::Scalar);
            let got_bt = tile::matmul_bt_with_bk(a, &bt, *t, vec_bk);
            if bits(&got_bt.data) != bits(&want_bt.data) {
                return Err(format!("matmul_bt diverged on {}", vec_bk.name()));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_simd_int8_matmuls_exactly_equal_scalar() {
    let vec_bk = simd::detect();
    forall_ck(
        0x51AD2,
        40,
        |rng, size| {
            let m = 1 + rng.below(size + 4);
            let k = 1 + rng.below(2 * size + 13);
            let n = 1 + rng.below(size + 7);
            let tile = [1, 8, 24, 64, 200][rng.below(5)];
            (rand_i8_mat(rng, m, k), rand_i8_mat(rng, k, n), tile)
        },
        |(a, b, t)| {
            if tile::int8_matmul_with_bk(a, b, *t, vec_bk) != quant::int8_matmul(a, b) {
                return Err(format!("int8_matmul diverged on {}", vec_bk.name()));
            }
            let bt = b.transpose();
            if tile::int8_matmul_bt_with_bk(a, &bt, *t, vec_bk) != quant::int8_matmul_bt(a, &bt) {
                return Err(format!("int8_matmul_bt diverged on {}", vec_bk.name()));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_simd_fused_softmax_acc_bit_identical() {
    // ragged (rows, kv, d), non-trivial carried online state, and rows
    // that are fully masked (every score at -inf) — the vector backend
    // must match the scalar state bit-for-bit after the fold
    let vec_bk = simd::detect();
    forall_ck(
        0x51AD3,
        40,
        |rng, size| {
            let rows = 1 + rng.below(size % 10 + 4);
            let kv = 1 + rng.below(size % 20 + 9);
            let d = 1 + rng.below(2 * size + 19);
            let mut s = rand_f32_mat(rng, rows, kv);
            // mask ~a quarter of rows entirely
            for r in 0..rows {
                if rng.f32() < 0.25 {
                    for c in 0..kv {
                        *s.at_mut(r, c) = f32::NEG_INFINITY;
                    }
                }
            }
            let v = rand_f32_mat(rng, kv, d);
            let m0: Vec<f32> = (0..rows)
                .map(|_| if rng.f32() < 0.5 { -1e30 } else { rng.normal() })
                .collect();
            let l0: Vec<f32> = (0..rows).map(|_| rng.f32() * 3.0).collect();
            let acc0 = rand_f32_mat(rng, rows, d);
            (s, v, m0, l0, acc0)
        },
        |(s, v, m0, l0, acc0)| {
            let run = |bk: Backend| {
                let mut m = m0.clone();
                let mut l = l0.clone();
                let mut acc = acc0.clone();
                tile::fused_softmax_acc_bk(s, v, &mut m, &mut l, &mut acc, bk);
                (m, l, acc)
            };
            let (ms, ls, accs) = run(Backend::Scalar);
            let (mv, lv, accv) = run(vec_bk);
            if bits(&mv) != bits(&ms) || bits(&lv) != bits(&ls) {
                return Err(format!("online (m, l) diverged on {}", vec_bk.name()));
            }
            if bits(&accv.data) != bits(&accs.data) {
                return Err(format!("accumulator diverged on {}", vec_bk.name()));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_simd_attn_step_w8a8_bit_identical() {
    // the SAU inner step (exact score matmul + requantized P@V): both
    // the diagonal-masked and unmasked variants, on ragged head dims,
    // continuing from a carried accumulator state
    let vec_bk = simd::detect();
    forall_ck(
        0x51AD4,
        30,
        |rng, size| {
            let b = 1 + rng.below(size % 12 + 4);
            let dh = 1 + rng.below(2 * size + 21);
            let q = rand_i8_mat(rng, b, dh);
            let k = rand_i8_mat(rng, b, dh);
            let v = rand_i8_mat(rng, b, dh);
            let diag = rng.f32() < 0.5;
            let m0: Vec<f32> = (0..b).map(|_| -1e30 + rng.f32()).collect();
            let l0: Vec<f32> = (0..b).map(|_| rng.f32()).collect();
            let acc0 = rand_f32_mat(rng, b, dh);
            (q, k, v, diag, m0, l0, acc0)
        },
        |(q, k, v, diag, m0, l0, acc0)| {
            let run = |bk: Backend| {
                let mut m = m0.clone();
                let mut l = l0.clone();
                let mut acc = acc0.clone();
                attn_step_w8a8_bk(q, 0.02, k, 0.03, v, 0.04, &mut m, &mut l, &mut acc, *diag, bk);
                (m, l, acc)
            };
            let (ms, ls, accs) = run(Backend::Scalar);
            let (mv, lv, accv) = run(vec_bk);
            if bits(&mv) != bits(&ms) || bits(&lv) != bits(&ls) {
                return Err(format!("attn (m, l) diverged on {}", vec_bk.name()));
            }
            if bits(&accv.data) != bits(&accs.data) {
                return Err(format!("attn accumulator diverged on {}", vec_bk.name()));
            }
            Ok(())
        },
    );
}

#[test]
fn both_dispatch_override_values_resolve_and_pin() {
    // `FASTP_KERNEL=scalar` must force the scalar reference and
    // `FASTP_KERNEL=simd` must select the detected vector backend (or
    // scalar, loudly, on a host without one) — and whichever backend the
    // override picks, kernel results stay bit-identical
    assert_eq!(simd::resolve(Some("scalar")), Backend::Scalar);
    assert_eq!(simd::resolve(Some("simd")), simd::detect());

    let mut rng = Prng::new(0x51AD5);
    let a = rand_i8_mat(&mut rng, 9, 37);
    let b = rand_i8_mat(&mut rng, 37, 5);
    let want = quant::int8_matmul(&a, &b);
    for raw in [Some("scalar"), Some("simd"), None] {
        let bk = simd::resolve(raw);
        assert_eq!(tile::int8_matmul_with_bk(&a, &b, 16, bk), want, "override {raw:?}");
    }

    // the ctx constructed from the environment carries the active choice
    assert_eq!(tile::KernelCtx::from_env().backend, simd::active());
}
