//! Shape-keyed kernel autotuner (`FASTP_AUTOTUNE`).
//!
//! PR 4's SIMD rungs and the `FASTP_TILE` override made the kernel layer
//! *configurable*; this module makes it *self-configuring*. An offline
//! or startup sweep times every tile-edge candidate × available backend
//! for each kernel **shape class** a model actually hits (the same
//! sweep-script shape as the chunk-size benchmark in SNIPPETS.md
//! Snippet 1, folded into the binary), and persists the winners to a
//! JSON profile. `KernelCtx::plan` then resolves a per-shape
//! `(tile, backend)` choice from the loaded profile instead of one fixed
//! constant for every shape.
//!
//! **Why this can never change results:** tile size is
//! property-tested to not change any kernel output
//! (`tile_size_does_not_change_results`), and every backend is
//! bit-identical to scalar by the `tensor::simd` contract — so a tuned
//! run is bit-identical to an untuned run *by construction*. The engine
//! test `tuned_profile_prefill_bit_identical_to_untuned` and the CI
//! `FASTP_AUTOTUNE=startup` leg pin it anyway.
//!
//! Modes (validated once per process, warn-and-default like
//! `FASTP_TILE` / `FASTP_KERNEL`):
//!
//!  * `off` (default) — fixed `FASTP_TILE` / `FASTP_KERNEL` behavior.
//!  * `startup` — sweep a small default shape grid at first kernel-ctx
//!    creation (sub-second budget) and, when `FASTP_TUNE_PROFILE` is
//!    set, persist the profile there (atomic temp-file + rename, so
//!    concurrent processes never expose a torn file).
//!  * `file` — load a previously persisted profile from
//!    `FASTP_TUNE_PROFILE` (unreadable/invalid profiles warn and
//!    disable tuning rather than aborting the process).
//!
//! The profile also carries measured per-phase job costs (`phase_us`)
//! that warm-start `util::pool::AdaptiveHints`, so adaptive lease
//! sizing begins from swept kernel timings instead of waiting for the
//! first live EWMA observation.
//!
//! The profile is a numeric-only JSON document so it parses with the
//! same flattening reader the perf-trend gate uses
//! (`util::trend::parse_metrics`) — no new JSON machinery.

use std::collections::BTreeMap;
use std::sync::{Arc, OnceLock};
use std::time::{Duration, Instant};

use crate::config::{ModelConfig, BLOCK};
use crate::tensor::simd::{self, Backend};
use crate::tensor::tile;
use crate::tensor::{MatF32, MatI8};
use crate::util::bench::black_box;
use crate::util::pool::{AdaptiveHints, HINT_EWMA_ALPHA, HINT_PHASES};
use crate::util::prng::Prng;
use crate::util::trend;

/// Environment variable selecting the autotune mode:
/// `off` | `startup` | `file`. Unset/empty = `off`.
pub const AUTOTUNE_ENV: &str = "FASTP_AUTOTUNE";

/// Environment variable naming the profile path: written by `startup`
/// (and `fastp tune`), read by `file`.
pub const PROFILE_ENV: &str = "FASTP_TUNE_PROFILE";

/// Tile-edge candidates swept per shape class — all valid `FASTP_TILE`
/// values (positive multiples of 8), spanning L1-resident to
/// L2-resident operand panels.
pub const TILE_CANDIDATES: [usize; 4] = [32, 64, 128, 256];

/// Per-candidate measurement budget of the `startup` sweep. Kept small:
/// every process entering `FASTP_AUTOTUNE=startup` pays the sweep once
/// (lazily, at first kernel-ctx creation).
pub const STARTUP_BUDGET_MS: f64 = 2.0;

/// Rows actually timed per measurement (shape classes bucket the row
/// count up to 8192, but tile/backend preference is driven by the k×n
/// operand footprint — m only scales the row loop — so the sweep times
/// a row-capped proxy to keep startup sub-second).
const MEASURE_M_CAP: usize = 32;

// ---------------------------------------------------------------------------
// mode (validated env parse, PR 4 convention)
// ---------------------------------------------------------------------------

/// Autotune mode — see the module doc.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum AutotuneMode {
    #[default]
    Off,
    Startup,
    File,
}

impl AutotuneMode {
    /// Stable lowercase name for banners / metrics.
    pub fn name(self) -> &'static str {
        match self {
            AutotuneMode::Off => "off",
            AutotuneMode::Startup => "startup",
            AutotuneMode::File => "file",
        }
    }
}

/// Parse a `FASTP_AUTOTUNE` value (pure — unit-testable without touching
/// the process environment). Unknown modes are rejected, not guessed.
pub fn parse_autotune_mode(raw: &str) -> Result<AutotuneMode, String> {
    match raw.trim().to_ascii_lowercase().as_str() {
        "" | "off" => Ok(AutotuneMode::Off),
        "startup" => Ok(AutotuneMode::Startup),
        "file" => Ok(AutotuneMode::File),
        other => Err(format!("{AUTOTUNE_ENV}={other:?} (expected off|startup|file)")),
    }
}

/// The single `FASTP_AUTOTUNE` parse point, resolved once per process.
/// Invalid values warn and fall back to `off` — same
/// validate-warn-default convention as `FASTP_TILE` and `FASTP_KERNEL`.
pub fn env_mode() -> AutotuneMode {
    static MODE: OnceLock<AutotuneMode> = OnceLock::new();
    *MODE.get_or_init(|| {
        crate::config::env::knob_or(AUTOTUNE_ENV, parse_autotune_mode, AutotuneMode::Off)
    })
}

fn env_profile_path() -> Option<String> {
    match std::env::var(PROFILE_ENV) {
        Ok(p) if !p.trim().is_empty() => Some(p),
        _ => None,
    }
}

// ---------------------------------------------------------------------------
// shape classes
// ---------------------------------------------------------------------------

/// Kernel families the tuner keys on — one per `KernelCtx` kernel entry
/// point (each has its own memory-access pattern, so its own winner).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum OpClass {
    /// `matmul`: f32 A[m,k] @ B[k,n].
    MatmulF32,
    /// `matmul_bt`: f32 A[m,k] @ B^T with B [n,k].
    MatmulBtF32,
    /// `int8_matmul_deq`: W8A8 A[m,k] @ B[k,n] (+ dequant).
    Int8Matmul,
    /// `int8_matmul_bt`: W8A8 score-tile shape A[m,k] @ B^T[n,k].
    Int8MatmulBt,
}

impl OpClass {
    /// Stable key prefix (no '.' — the profile parser flattens on dots).
    pub fn tag(self) -> &'static str {
        match self {
            OpClass::MatmulF32 => "mmf32",
            OpClass::MatmulBtF32 => "mmbtf32",
            OpClass::Int8Matmul => "i8mm",
            OpClass::Int8MatmulBt => "i8mmbt",
        }
    }
}

/// Bucket a row count to its shape class: next power of two, clamped to
/// [8, 8192]. `n` and `k` are model dimensions — a small fixed set per
/// config — and stay exact; `m` is the token/row count and varies per
/// request/chunk, so it buckets.
pub fn bucket_m(m: usize) -> usize {
    m.clamp(8, 8192).next_power_of_two()
}

/// One kernel shape class: op family + bucketed m + exact n, k.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShapeClass {
    pub op: OpClass,
    pub m: usize,
    pub n: usize,
    pub k: usize,
}

impl ShapeClass {
    pub fn new(op: OpClass, m: usize, n: usize, k: usize) -> ShapeClass {
        ShapeClass { op, m: bucket_m(m), n, k }
    }

    /// Stable profile key, e.g. `i8mm:m128:n768:k768` (':'-separated so
    /// the dotted-key profile parser never splits inside it).
    pub fn key(&self) -> String {
        format!("{}:m{}:n{}:k{}", self.op.tag(), self.m, self.n, self.k)
    }
}

// ---------------------------------------------------------------------------
// profile
// ---------------------------------------------------------------------------

/// One tuned choice. `vector` maps to the *caller's* backend at resolve
/// time (true = "use the ctx backend", false = force scalar), so a
/// `FASTP_KERNEL=scalar` override is never silently undone by a profile
/// swept with a vector ISA.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TuneChoice {
    pub tile: usize,
    pub vector: bool,
    /// Best measured time (ns) for this class — informational.
    pub ns: f64,
}

/// Phase labels of the `phase_us` hint seeds, in
/// `coordinator::engine::phase_hint_slot` order.
pub const PHASE_KEYS: [&str; HINT_PHASES] = ["qkv", "index_gen", "sau", "ffn_logits"];

/// A persisted autotune profile: per-shape-class winners plus measured
/// per-phase job-cost seeds for `AdaptiveHints` (0.0 = no seed).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TuneProfile {
    pub entries: BTreeMap<String, TuneChoice>,
    pub phase_us: [f64; HINT_PHASES],
}

impl TuneProfile {
    /// Resolve the (tile, backend) to run `shape` with: profile misses
    /// fall back to the caller's defaults; hits take the tuned tile and
    /// map `vector` onto the caller's backend (never upgrading a scalar
    /// caller to a vector ISA).
    pub fn resolve(&self, shape: &ShapeClass, default_tile: usize, default_bk: Backend) -> (usize, Backend) {
        match self.entries.get(&shape.key()) {
            None => (default_tile, default_bk),
            Some(c) => (c.tile, if c.vector { default_bk } else { Backend::Scalar }),
        }
    }

    /// Serialize as numeric-only JSON (see the module doc for why).
    pub fn to_json(&self) -> String {
        let mut s = String::from("{\n  \"version\": 1,\n  \"phase_us\": {");
        for (i, k) in PHASE_KEYS.iter().enumerate() {
            if i > 0 {
                s.push_str(", ");
            }
            s.push_str(&format!("\"{}\": {:.3}", k, self.phase_us[i]));
        }
        s.push_str("},\n  \"entries\": {\n");
        let mut first = true;
        for (key, c) in &self.entries {
            if !first {
                s.push_str(",\n");
            }
            first = false;
            s.push_str(&format!(
                "    \"{}\": {{\"tile\": {}, \"vector\": {}, \"ns\": {:.1}}}",
                key,
                c.tile,
                i32::from(c.vector),
                c.ns
            ));
        }
        s.push_str("\n  }\n}\n");
        s
    }

    /// Parse a profile (strict: unknown fields, bad versions and invalid
    /// tile values are errors — a corrupt profile should be loud, not
    /// silently half-applied).
    pub fn parse(json: &str) -> Result<TuneProfile, String> {
        let flat = trend::parse_metrics(json)?;
        let mut prof = TuneProfile::default();
        let mut tiles: BTreeMap<String, usize> = BTreeMap::new();
        let mut vecs: BTreeMap<String, bool> = BTreeMap::new();
        let mut nss: BTreeMap<String, f64> = BTreeMap::new();
        let mut version = None;
        for (k, v) in &flat {
            if k == "version" {
                version = Some(*v);
                continue;
            }
            if let Some(rest) = k.strip_prefix("phase_us.") {
                match PHASE_KEYS.iter().position(|p| p == &rest) {
                    Some(i) => prof.phase_us[i] = *v,
                    None => return Err(format!("unknown phase key {rest:?}")),
                }
                continue;
            }
            if let Some(rest) = k.strip_prefix("entries.") {
                if let Some(key) = rest.strip_suffix(".tile") {
                    let t = *v as usize;
                    if *v <= 0.0 || t % 8 != 0 {
                        // same validity rule as FASTP_TILE
                        return Err(format!("entry {key:?}: tile {v} is not a positive multiple of 8"));
                    }
                    tiles.insert(key.to_string(), t);
                } else if let Some(key) = rest.strip_suffix(".vector") {
                    vecs.insert(key.to_string(), *v != 0.0);
                } else if let Some(key) = rest.strip_suffix(".ns") {
                    nss.insert(key.to_string(), *v);
                } else {
                    return Err(format!("unknown entry field {rest:?}"));
                }
                continue;
            }
            return Err(format!("unknown profile field {k:?}"));
        }
        if version != Some(1.0) {
            return Err(format!("unsupported tune-profile version {version:?} (expected 1)"));
        }
        for (key, tile) in tiles {
            let vector =
                *vecs.get(&key).ok_or_else(|| format!("entry {key:?} missing vector flag"))?;
            let ns = nss.get(&key).copied().unwrap_or(0.0);
            prof.entries.insert(key, TuneChoice { tile, vector, ns });
        }
        Ok(prof)
    }

    /// Persist atomically (temp file + rename), so concurrent startup
    /// sweeps in sibling processes never expose a torn profile.
    pub fn save(&self, path: &str) -> Result<(), String> {
        let tmp = format!("{path}.tmp.{}", std::process::id());
        std::fs::write(&tmp, self.to_json()).map_err(|e| format!("writing {tmp}: {e}"))?;
        std::fs::rename(&tmp, path).map_err(|e| format!("renaming {tmp} -> {path}: {e}"))
    }

    pub fn load(path: &str) -> Result<TuneProfile, String> {
        let raw = std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
        TuneProfile::parse(&raw).map_err(|e| format!("{path}: {e}"))
    }
}

// ---------------------------------------------------------------------------
// sweep
// ---------------------------------------------------------------------------

fn splat_f32(rng: &mut Prng, rows: usize, cols: usize) -> MatF32 {
    MatF32 { rows, cols, data: (0..rows * cols).map(|_| rng.normal()).collect() }
}

fn splat_i8(rng: &mut Prng, rows: usize, cols: usize) -> MatI8 {
    MatI8 { rows, cols, data: (0..rows * cols).map(|_| rng.i8_sym()).collect() }
}

/// Min-of-iterations wall time (ns) of one candidate. Operands are
/// seeded deterministically from the shape key; at least one timed run
/// always happens, more only within `budget_ms` (so slow scalar
/// candidates on big shapes cost one run, fast ones get stable minima).
fn measure(shape: &ShapeClass, tile: usize, bk: Backend, budget_ms: f64) -> f64 {
    let mm = shape.m.min(MEASURE_M_CAP);
    let mut rng = Prng::new(0xA11C_E5EEu64 ^ shape.key().len() as u64);
    let budget = Duration::from_micros((budget_ms * 1000.0) as u64);
    let mut best = f64::INFINITY;
    let mut iters = 0usize;
    let start = Instant::now();
    match shape.op {
        OpClass::MatmulF32 => {
            let a = splat_f32(&mut rng, mm, shape.k);
            let b = splat_f32(&mut rng, shape.k, shape.n);
            while iters < 1 || (start.elapsed() < budget && iters < 8) {
                let t = Instant::now();
                black_box(tile::matmul_with_bk(&a, &b, tile, bk));
                best = best.min(t.elapsed().as_nanos() as f64);
                iters += 1;
            }
        }
        OpClass::MatmulBtF32 => {
            let a = splat_f32(&mut rng, mm, shape.k);
            let bt = splat_f32(&mut rng, shape.n, shape.k);
            while iters < 1 || (start.elapsed() < budget && iters < 8) {
                let t = Instant::now();
                black_box(tile::matmul_bt_with_bk(&a, &bt, tile, bk));
                best = best.min(t.elapsed().as_nanos() as f64);
                iters += 1;
            }
        }
        OpClass::Int8Matmul => {
            let a = splat_i8(&mut rng, mm, shape.k);
            let b = splat_i8(&mut rng, shape.k, shape.n);
            while iters < 1 || (start.elapsed() < budget && iters < 8) {
                let t = Instant::now();
                black_box(tile::int8_matmul_with_bk(&a, &b, tile, bk));
                best = best.min(t.elapsed().as_nanos() as f64);
                iters += 1;
            }
        }
        OpClass::Int8MatmulBt => {
            let a = splat_i8(&mut rng, mm, shape.k);
            let bt = splat_i8(&mut rng, shape.n, shape.k);
            while iters < 1 || (start.elapsed() < budget && iters < 8) {
                let t = Instant::now();
                black_box(tile::int8_matmul_bt_with_bk(&a, &bt, tile, bk));
                best = best.min(t.elapsed().as_nanos() as f64);
                iters += 1;
            }
        }
    }
    best
}

/// Sweep tile × backend candidates for each shape class and return the
/// winner table (duplicate keys are swept once). The phase seeds are
/// derived from the winning kernel times afterwards.
pub fn sweep(shapes: &[ShapeClass], budget_ms_per_candidate: f64) -> TuneProfile {
    let mut prof = TuneProfile::default();
    let detected = simd::detect();
    let vector_rungs: &[bool] = if detected.is_vector() { &[false, true] } else { &[false] };
    for shape in shapes {
        let key = shape.key();
        if prof.entries.contains_key(&key) {
            continue;
        }
        let mut best: Option<TuneChoice> = None;
        for &tile in &TILE_CANDIDATES {
            for &vector in vector_rungs {
                let bk = if vector { detected } else { Backend::Scalar };
                let ns = measure(shape, tile, bk, budget_ms_per_candidate);
                if best.is_none_or(|b| ns < b.ns) {
                    best = Some(TuneChoice { tile, vector, ns });
                }
            }
        }
        if let Some(c) = best {
            prof.entries.insert(key, c);
        }
    }
    prof.phase_us = phase_seeds(&prof);
    prof
}

/// Mean winning time (us) over entries of one op family; 0.0 when none.
fn class_best_us(prof: &TuneProfile, op: OpClass) -> f64 {
    let prefix = format!("{}:", op.tag());
    let (mut sum, mut n) = (0.0f64, 0usize);
    for (k, c) in &prof.entries {
        if k.starts_with(&prefix) && c.ns > 0.0 && c.ns.is_finite() {
            sum += c.ns / 1000.0;
            n += 1;
        }
    }
    if n == 0 {
        0.0
    } else {
        sum / n as f64
    }
}

/// Per-phase job-cost seeds from the swept winners. Each phase's
/// dominant kernel stands in for its job cost: QKV and FFN jobs are
/// W8A8 projections, index-gen streams score-tile products, and an SAU
/// job is a score tile plus the P@V accumulate (≈ 2× the tile).
/// `AdaptiveHints::want` only uses the *relative* magnitudes, so the
/// proxy only has to rank the phases, not price them absolutely.
fn phase_seeds(prof: &TuneProfile) -> [f64; HINT_PHASES] {
    let proj = class_best_us(prof, OpClass::Int8Matmul);
    let score = class_best_us(prof, OpClass::Int8MatmulBt);
    [proj, score, 2.0 * score, proj]
}

/// The shape grid the `startup` mode sweeps: per-chunk kernel shapes of
/// the two functional presets (tiny d=256, small100m d=768) plus the
/// BLOCK×BLOCK score tile. Lookup misses (other models/dims) fall back
/// to the ctx defaults, so the grid only has to cover the common case.
pub fn default_shapes() -> Vec<ShapeClass> {
    let mut v = Vec::new();
    for &(d, dff) in &[(256usize, 768usize), (768, 2048)] {
        v.push(ShapeClass::new(OpClass::Int8Matmul, BLOCK, d, d));
        v.push(ShapeClass::new(OpClass::Int8Matmul, BLOCK, dff, d));
        v.push(ShapeClass::new(OpClass::Int8Matmul, BLOCK, d, dff));
    }
    v.push(ShapeClass::new(OpClass::Int8MatmulBt, BLOCK, BLOCK, 64));
    v
}

/// Every kernel shape class one prefill of `cfg` hits: the per-chunk
/// QKV/output/FFN/logits projections (m = BLOCK rows per chunk) and the
/// BLOCK×BLOCK score tile. `fastp tune` sweeps exactly these.
pub fn model_shapes(cfg: &ModelConfig) -> Vec<ShapeClass> {
    let d = cfg.d_model;
    vec![
        ShapeClass::new(OpClass::Int8Matmul, BLOCK, cfg.q_dim(), d), // wq
        ShapeClass::new(OpClass::Int8Matmul, BLOCK, cfg.kv_dim(), d), // wk/wv
        ShapeClass::new(OpClass::Int8Matmul, BLOCK, d, cfg.q_dim()), // wo
        ShapeClass::new(OpClass::Int8Matmul, BLOCK, cfg.d_ffn, d),   // wg/wu
        ShapeClass::new(OpClass::Int8Matmul, BLOCK, d, cfg.d_ffn),   // wd
        ShapeClass::new(OpClass::Int8Matmul, BLOCK, cfg.vocab, d),   // lm head
        ShapeClass::new(OpClass::Int8MatmulBt, BLOCK, BLOCK, cfg.d_head), // score tile
    ]
}

// ---------------------------------------------------------------------------
// process-wide activation + hint seeding
// ---------------------------------------------------------------------------

static ACTIVE_PROFILE: OnceLock<Option<Arc<TuneProfile>>> = OnceLock::new();

/// The process-wide autotune profile, resolved once from the env (see
/// the module doc for the three modes). `KernelCtx` constructors call
/// this; tests and `fastp tune --check` inject explicit profiles via
/// `KernelCtx::with_tune` / `EngineConfig::tune` instead.
pub fn active_profile() -> Option<Arc<TuneProfile>> {
    ACTIVE_PROFILE
        .get_or_init(|| match env_mode() {
            AutotuneMode::Off => None,
            AutotuneMode::File => {
                let Some(path) = env_profile_path() else {
                    eprintln!(
                        "warning: {AUTOTUNE_ENV}=file but {PROFILE_ENV} is unset; autotuning off"
                    );
                    return None;
                };
                match TuneProfile::load(&path) {
                    Ok(p) => Some(Arc::new(p)),
                    Err(e) => {
                        eprintln!("warning: ignoring tune profile: {e}; autotuning off");
                        None
                    }
                }
            }
            AutotuneMode::Startup => {
                let prof = sweep(&default_shapes(), STARTUP_BUDGET_MS);
                if let Some(path) = env_profile_path() {
                    if let Err(e) = prof.save(&path) {
                        eprintln!("warning: could not persist tune profile: {e}");
                    }
                }
                Some(Arc::new(prof))
            }
        })
        .clone()
}

/// Warm-start `hints` from a profile's measured phase costs (first
/// observation seeds the EWMA directly, so this is exactly "start warm
/// instead of waiting for the first live job").
pub fn seed_hints(hints: &AdaptiveHints, prof: &TuneProfile) {
    for (slot, &us) in prof.phase_us.iter().enumerate() {
        if us > 0.0 {
            hints.observe(slot, us);
        }
    }
}

/// Fresh hints pre-seeded from `prof`'s phase costs; `None` when the
/// profile carries no seeds (then the engine keeps its static split
/// until a server installs shared hints).
pub fn warm_hints(prof: Option<&Arc<TuneProfile>>) -> Option<Arc<AdaptiveHints>> {
    let prof = prof?;
    if prof.phase_us.iter().all(|&u| u <= 0.0) {
        return None;
    }
    let hints = AdaptiveHints::new(HINT_EWMA_ALPHA);
    seed_hints(&hints, prof);
    Some(hints)
}

/// How an `EngineConfig` selects its autotune profile. `Env` follows
/// the process environment; `Off` forces untuned (the baseline leg of
/// `fastp tune --check`, which must ignore `FASTP_AUTOTUNE=startup`);
/// `Profile` injects an explicit table (tests, `--check`'s tuned leg).
#[derive(Clone, Debug, Default)]
pub enum TuneOverride {
    #[default]
    Env,
    Off,
    Profile(Arc<TuneProfile>),
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parse_accepts_known_rejects_unknown() {
        assert_eq!(parse_autotune_mode(""), Ok(AutotuneMode::Off));
        assert_eq!(parse_autotune_mode("off"), Ok(AutotuneMode::Off));
        assert_eq!(parse_autotune_mode(" Startup "), Ok(AutotuneMode::Startup));
        assert_eq!(parse_autotune_mode("FILE"), Ok(AutotuneMode::File));
        assert!(parse_autotune_mode("auto").is_err());
        assert!(parse_autotune_mode("on").is_err());
    }

    #[test]
    fn bucket_m_rounds_up_and_clamps() {
        assert_eq!(bucket_m(0), 8);
        assert_eq!(bucket_m(1), 8);
        assert_eq!(bucket_m(9), 16);
        assert_eq!(bucket_m(128), 128);
        assert_eq!(bucket_m(129), 256);
        assert_eq!(bucket_m(8192), 8192);
        assert_eq!(bucket_m(1 << 20), 8192);
    }

    #[test]
    fn shape_keys_are_stable() {
        let s = ShapeClass::new(OpClass::Int8Matmul, 100, 768, 768);
        assert_eq!(s.key(), "i8mm:m128:n768:k768");
        let t = ShapeClass::new(OpClass::Int8MatmulBt, 128, 128, 64);
        assert_eq!(t.key(), "i8mmbt:m128:n128:k64");
    }

    fn sample_profile() -> TuneProfile {
        let mut prof = TuneProfile::default();
        prof.entries.insert(
            "i8mm:m128:n768:k768".into(),
            TuneChoice { tile: 128, vector: true, ns: 1250.5 },
        );
        prof.entries.insert(
            "i8mmbt:m128:n128:k64".into(),
            TuneChoice { tile: 32, vector: false, ns: 400.0 },
        );
        prof.phase_us = [12.5, 3.25, 6.5, 12.5];
        prof
    }

    #[test]
    fn profile_json_round_trips() {
        let prof = sample_profile();
        let back = TuneProfile::parse(&prof.to_json()).expect("round trip");
        assert_eq!(back, prof);
    }

    #[test]
    fn profile_parse_rejects_corruption() {
        // invalid tile (not a multiple of 8)
        let bad_tile = r#"{"version": 1, "entries": {"i8mm:m8:n8:k8": {"tile": 12, "vector": 1, "ns": 1.0}}}"#;
        assert!(TuneProfile::parse(bad_tile).is_err());
        // missing vector flag
        let no_vec = r#"{"version": 1, "entries": {"i8mm:m8:n8:k8": {"tile": 32, "ns": 1.0}}}"#;
        assert!(TuneProfile::parse(no_vec).is_err());
        // wrong version
        let bad_ver = r#"{"version": 2, "entries": {}}"#;
        assert!(TuneProfile::parse(bad_ver).is_err());
        // unknown top-level field
        let unknown = r#"{"version": 1, "surprise": 3, "entries": {}}"#;
        assert!(TuneProfile::parse(unknown).is_err());
        // not JSON at all
        assert!(TuneProfile::parse("not json").is_err());
    }

    #[test]
    fn resolve_miss_falls_back_hit_maps_vector_onto_caller() {
        let prof = sample_profile();
        let vec_bk = simd::detect();
        // miss: caller defaults pass through untouched
        let miss = ShapeClass::new(OpClass::MatmulF32, 8, 8, 8);
        assert_eq!(prof.resolve(&miss, 64, vec_bk), (64, vec_bk));
        // hit with vector=true: tuned tile + the caller's backend
        let hit = ShapeClass::new(OpClass::Int8Matmul, 128, 768, 768);
        assert_eq!(prof.resolve(&hit, 64, vec_bk), (128, vec_bk));
        // ... and a scalar caller is never upgraded
        assert_eq!(prof.resolve(&hit, 64, Backend::Scalar), (128, Backend::Scalar));
        // hit with vector=false: forces scalar even for a vector caller
        let hit_sc = ShapeClass::new(OpClass::Int8MatmulBt, 128, 128, 64);
        assert_eq!(prof.resolve(&hit_sc, 64, vec_bk), (32, Backend::Scalar));
    }

    #[test]
    fn sweep_picks_a_candidate_and_seeds_phases() {
        let shapes = vec![
            ShapeClass::new(OpClass::Int8Matmul, 8, 16, 16),
            ShapeClass::new(OpClass::Int8MatmulBt, 8, 8, 16),
        ];
        let prof = sweep(&shapes, 0.05);
        assert_eq!(prof.entries.len(), 2);
        for c in prof.entries.values() {
            assert!(TILE_CANDIDATES.contains(&c.tile));
            assert!(c.ns.is_finite() && c.ns > 0.0);
        }
        // both swept families are represented, so every phase has a seed
        assert!(prof.phase_us.iter().all(|&u| u > 0.0));
    }

    #[test]
    fn sweep_dedupes_equal_shape_classes() {
        // tiny's q_dim == d_model: the wq and wo shapes collapse
        let s = ShapeClass::new(OpClass::Int8Matmul, 8, 16, 16);
        let prof = sweep(&[s.clone(), s], 0.05);
        assert_eq!(prof.entries.len(), 1);
    }

    #[test]
    fn save_load_round_trips_and_load_errors_are_loud() {
        let prof = sample_profile();
        let path = std::env::temp_dir()
            .join(format!("fastp_tune_test_{}.json", std::process::id()))
            .to_string_lossy()
            .into_owned();
        prof.save(&path).expect("save");
        let back = TuneProfile::load(&path).expect("load");
        assert_eq!(back, prof);
        let _ = std::fs::remove_file(&path);
        assert!(TuneProfile::load(&path).is_err());
    }

    #[test]
    fn warm_hints_seed_the_ewma() {
        let prof = sample_profile();
        let hints = warm_hints(Some(&Arc::new(prof.clone()))).expect("seeded hints");
        for (slot, &us) in prof.phase_us.iter().enumerate() {
            assert_eq!(hints.ewma(slot), us);
        }
        // a profile with no seeds yields no hints
        let empty = TuneProfile::default();
        assert!(warm_hints(Some(&Arc::new(empty))).is_none());
        assert!(warm_hints(None).is_none());
    }

    #[test]
    fn model_and_default_grids_stay_in_model_reach() {
        let shapes = model_shapes(&crate::config::TINY);
        assert!(shapes.iter().any(|s| s.op == OpClass::Int8MatmulBt));
        for s in &shapes {
            assert_eq!(s.m, BLOCK); // per-chunk row count
        }
        assert!(!default_shapes().is_empty());
    }
}
