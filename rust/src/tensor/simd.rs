//! Runtime-dispatched SIMD micro-kernels — the per-core width the paper's
//! MPU gets from its wide DSP/LUT integer lanes, recovered on the CPU
//! mirror with `core::arch` intrinsics.
//!
//! A [`Backend`] is selected once per process ([`active`]): the best
//! vector ISA the host supports (`is_x86_feature_detected!("avx2")` on
//! x86_64, NEON — architecturally mandatory — on aarch64), overridable
//! with the `FASTP_KERNEL` env var (`scalar` forces the scalar reference,
//! `simd` asks for the detected vector backend). The blocked kernels in
//! [`crate::tensor::tile`] and the SAU step in `model::forward` dispatch
//! their inner loops through the selected backend; `KernelCtx` carries
//! the backend so the engine can record it in `PrefillMetrics` and tests
//! can pin both backends against each other in one process.
//!
//! Numerics contract (the reason every primitive looks the way it does):
//!
//!  * **integer primitives are exact** — i8xi8 products accumulate in
//!    i32 with no saturation in range, so any lane order is bit-identical
//!    to the scalar oracle (|dot| <= k * 127^2 stays far below i32::MAX
//!    for every shape this repo uses).
//!  * **f32 primitives vectorize across independent output columns,
//!    never within k** — each output element sees the *same* sequence of
//!    (multiply, add) roundings as the scalar code, just in a different
//!    lane. No FMA is ever emitted (a fused multiply-add rounds once
//!    where mul-then-add rounds twice, which would break bit-identity
//!    with the `tensor::ops` / `quant` oracles).
//!  * tails shorter than the vector width run the scalar formula, so
//!    ragged shapes (k, n not multiples of 8/16) are covered.
//!
//! [`Backend`] variants are plain public values, so dispatch re-checks
//! ISA support at the call boundary (one cached-flag load): a vector
//! variant the host cannot run degrades to the scalar formula instead
//! of executing unsupported instructions. [`detect`] / [`resolve`] /
//! [`active`] never hand out an unsupported variant in the first place.

use std::sync::OnceLock;

/// Environment variable selecting the kernel backend:
/// `scalar` | `simd` (the detected vector ISA; falls back to scalar —
/// loudly — when the host has none). Unset = auto-detect.
pub const KERNEL_ENV: &str = "FASTP_KERNEL";

/// A micro-kernel backend. `Scalar` is the bit-level reference; the
/// vector variants are bit-identical by the contract above.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Plain Rust loops — the reference the vector paths are pinned to.
    Scalar,
    /// x86_64 AVX2 (256-bit lanes).
    Avx2,
    /// aarch64 NEON/ASIMD (128-bit lanes).
    Neon,
}

static ACTIVE: OnceLock<Backend> = OnceLock::new();

/// Cached AVX2 capability check — the soundness gate in front of every
/// `unsafe` AVX2 call (a `Backend::Avx2` constructed on a non-AVX2 host
/// must fall back to scalar, not execute unsupported instructions).
#[cfg(target_arch = "x86_64")]
fn avx2_available() -> bool {
    static AVX2: OnceLock<bool> = OnceLock::new();
    *AVX2.get_or_init(|| std::arch::is_x86_feature_detected!("avx2"))
}

impl Backend {
    /// Stable lowercase name for metrics / banners / JSON.
    pub fn name(self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Avx2 => "avx2",
            Backend::Neon => "neon",
        }
    }

    /// True for the vector backends (what the CI kernel-matrix asserts
    /// on its `FASTP_KERNEL=simd` leg).
    pub fn is_vector(self) -> bool {
        !matches!(self, Backend::Scalar)
    }

    // ------------------------------------------------------------------
    // primitives (each dispatches to the scalar reference or an
    // arch-gated vector implementation below)
    // ------------------------------------------------------------------

    /// Exact dot product `sum_i a[i] * b[i]` in i32 (order-free).
    #[inline]
    pub fn i8_dot(self, a: &[i8], b: &[i8]) -> i32 {
        debug_assert_eq!(a.len(), b.len());
        match self {
            Backend::Scalar => i8_dot_scalar(a, b),
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 if avx2_available() => unsafe { i8_dot_avx2(a, b) },
            #[cfg(target_arch = "aarch64")]
            Backend::Neon => unsafe { i8_dot_neon(a, b) },
            _ => i8_dot_scalar(a, b),
        }
    }

    /// Exact `dst[j] += a * b[j]` in i32 across output columns.
    #[inline]
    pub fn i32_axpy_i8(self, dst: &mut [i32], b: &[i8], a: i32) {
        debug_assert_eq!(dst.len(), b.len());
        match self {
            Backend::Scalar => i32_axpy_i8_scalar(dst, b, a),
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 if avx2_available() => unsafe { i32_axpy_i8_avx2(dst, b, a) },
            #[cfg(target_arch = "aarch64")]
            Backend::Neon => unsafe { i32_axpy_i8_neon(dst, b, a) },
            _ => i32_axpy_i8_scalar(dst, b, a),
        }
    }

    /// `dst[j] *= c` — one rounding per element, lane order irrelevant.
    #[inline]
    pub fn f32_scale(self, dst: &mut [f32], c: f32) {
        match self {
            Backend::Scalar => f32_scale_scalar(dst, c),
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 if avx2_available() => unsafe { f32_scale_avx2(dst, c) },
            #[cfg(target_arch = "aarch64")]
            Backend::Neon => unsafe { f32_scale_neon(dst, c) },
            _ => f32_scale_scalar(dst, c),
        }
    }

    /// `dst[j] += p * x[j]` — multiply then add (two roundings, exactly
    /// the scalar sequence; deliberately *not* an FMA).
    #[inline]
    pub fn f32_axpy(self, dst: &mut [f32], x: &[f32], p: f32) {
        debug_assert_eq!(dst.len(), x.len());
        match self {
            Backend::Scalar => f32_axpy_scalar(dst, x, p),
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 if avx2_available() => unsafe { f32_axpy_avx2(dst, x, p) },
            #[cfg(target_arch = "aarch64")]
            Backend::Neon => unsafe { f32_axpy_neon(dst, x, p) },
            _ => f32_axpy_scalar(dst, x, p),
        }
    }

    /// `dst[j] += ((pf * v[j]) as f32) * scale` — the W8A8 P@V
    /// accumulate: exact integer product, exact i32→f32 conversion
    /// (|pf * v| <= 127^2 < 2^24), then mul + add (two roundings).
    #[inline]
    pub fn f32_axpy_i8(self, dst: &mut [f32], v: &[i8], pf: i32, scale: f32) {
        debug_assert_eq!(dst.len(), v.len());
        match self {
            Backend::Scalar => f32_axpy_i8_scalar(dst, v, pf, scale),
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 if avx2_available() => unsafe { f32_axpy_i8_avx2(dst, v, pf, scale) },
            #[cfg(target_arch = "aarch64")]
            Backend::Neon => unsafe { f32_axpy_i8_neon(dst, v, pf, scale) },
            _ => f32_axpy_i8_scalar(dst, v, pf, scale),
        }
    }

    // ------------------------------------------------------------------
    // elementwise primitives (the quantize/rmsnorm/rope/dequant remainder
    // of the QKV phase; every lane is an independent output element)
    // ------------------------------------------------------------------

    /// Symmetric i8 quantization: per element
    /// `(x/scale).round_ties_even().clamp(-127, 127) as i8` — exactly
    /// [`crate::quant::quantize_one`] (the scalar rung literally calls
    /// it). Lanes are independent, so the vector forms are bit-identical:
    /// IEEE division is exactly rounded, `_mm256_round_ps` /
    /// `vrndnq_f32` round to nearest-even like `f32::round_ties_even`,
    /// and the clamped value is integral in [-127, 127] so the final
    /// int conversion is exact.
    #[inline]
    pub fn i8_quantize(self, dst: &mut [i8], x: &[f32], scale: f32) {
        debug_assert_eq!(dst.len(), x.len());
        match self {
            Backend::Scalar => i8_quantize_scalar(dst, x, scale),
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 if avx2_available() => unsafe { i8_quantize_avx2(dst, x, scale) },
            #[cfg(target_arch = "aarch64")]
            Backend::Neon => unsafe { i8_quantize_neon(dst, x, scale) },
            _ => i8_quantize_scalar(dst, x, scale),
        }
    }

    /// RMSNorm per-element apply: `dst[j] = (x[j] * inv) * g[j]` — two
    /// multiplies rounding left-to-right, exactly the `tensor::ops`
    /// oracle's sequence. The sum-of-squares reduction and the rsqrt
    /// deliberately stay with the caller (a sequential reduction; lane
    /// reordering would change the rounding order).
    #[inline]
    pub fn f32_rms_apply(self, dst: &mut [f32], x: &[f32], g: &[f32], inv: f32) {
        debug_assert_eq!(dst.len(), x.len());
        debug_assert_eq!(dst.len(), g.len());
        match self {
            Backend::Scalar => f32_rms_apply_scalar(dst, x, g, inv),
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 if avx2_available() => unsafe { f32_rms_apply_avx2(dst, x, g, inv) },
            #[cfg(target_arch = "aarch64")]
            Backend::Neon => unsafe { f32_rms_apply_neon(dst, x, g, inv) },
            _ => f32_rms_apply_scalar(dst, x, g, inv),
        }
    }

    /// Half-rotation RoPE apply for one row. Given per-pair `sin`/`cos`
    /// tables (computed scalar by the caller — transcendentals carry no
    /// cross-library bit contract, so they never vectorize), rotates the
    /// independent pairs `(row[i], row[half+i])`:
    /// `row[i] = x1*cos - x2*sin`, `row[half+i] = x1*sin + x2*cos`.
    /// Both products round individually, then one add/sub — the oracle's
    /// exact sequence (never an FMA).
    #[inline]
    pub fn f32_rope_rotate(self, row: &mut [f32], sin: &[f32], cos: &[f32]) {
        debug_assert_eq!(sin.len(), cos.len());
        debug_assert_eq!(row.len(), 2 * sin.len());
        match self {
            Backend::Scalar => f32_rope_rotate_scalar(row, sin, cos),
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 if avx2_available() => unsafe { f32_rope_rotate_avx2(row, sin, cos) },
            #[cfg(target_arch = "aarch64")]
            Backend::Neon => unsafe { f32_rope_rotate_neon(row, sin, cos) },
            _ => f32_rope_rotate_scalar(row, sin, cos),
        }
    }

    /// W8A8 dequantization of an i32 accumulator: `dst[j] = (acc[j] as
    /// f32) * s`. The int→f32 conversion rounds to nearest-even in both
    /// the scalar cast and `_mm256_cvtepi32_ps`/`vcvtq_f32_s32`, then one
    /// multiply per independent lane — bit-identical at any magnitude.
    #[inline]
    pub fn f32_deq_scale(self, dst: &mut [f32], acc: &[i32], s: f32) {
        debug_assert_eq!(dst.len(), acc.len());
        match self {
            Backend::Scalar => f32_deq_scale_scalar(dst, acc, s),
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 if avx2_available() => unsafe { f32_deq_scale_avx2(dst, acc, s) },
            #[cfg(target_arch = "aarch64")]
            Backend::Neon => unsafe { f32_deq_scale_neon(dst, acc, s) },
            _ => f32_deq_scale_scalar(dst, acc, s),
        }
    }
}

/// Best vector backend the host supports; `Scalar` when there is none.
pub fn detect() -> Backend {
    #[cfg(target_arch = "x86_64")]
    let bk = if avx2_available() { Backend::Avx2 } else { Backend::Scalar };
    // NEON/ASIMD is architecturally mandatory on AArch64.
    #[cfg(target_arch = "aarch64")]
    let bk = Backend::Neon;
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    let bk = Backend::Scalar;
    bk
}

/// Resolve a `FASTP_KERNEL` value (pure — unit-testable without touching
/// the process environment). `None`/empty = auto-detect; `scalar` forces
/// the reference; `simd` asks for the detected vector backend and warns
/// when the host has none (the CI kernel-matrix turns that warning into
/// a hard failure via `fastp kernels --require-simd`).
pub fn resolve(raw: Option<&str>) -> Backend {
    let norm = raw.map(|s| s.trim().to_ascii_lowercase());
    match norm.as_deref() {
        None | Some("") => detect(),
        Some("scalar") => Backend::Scalar,
        Some("simd") => {
            let bk = detect();
            if !bk.is_vector() {
                eprintln!(
                    "warning: {KERNEL_ENV}=simd but no vector ISA was detected; \
                     dispatch fell back to scalar"
                );
            }
            bk
        }
        Some(other) => {
            eprintln!(
                "warning: unknown {KERNEL_ENV}={other:?} (expected scalar|simd); \
                 auto-detecting"
            );
            detect()
        }
    }
}

/// The process-wide selected backend (env override + detection, resolved
/// once). `KernelCtx` constructors default to this; tests that need both
/// backends in one process pass an explicit [`Backend`] instead.
pub fn active() -> Backend {
    // `resolve` never rejects a value (unknown names warn inside it and
    // auto-detect), so the knob's parse step is infallible here; routing
    // through [`crate::config::env::knob`] keeps the read-once shape
    // shared with every other FASTP_* override.
    *ACTIVE.get_or_init(|| {
        crate::config::env::knob(KERNEL_ENV, |raw| Ok(resolve(Some(raw))), detect)
    })
}

// ---------------------------------------------------------------------------
// scalar references (the bit-level definitions)
// ---------------------------------------------------------------------------

fn i8_dot_scalar(a: &[i8], b: &[i8]) -> i32 {
    let mut s = 0i32;
    for (&x, &y) in a.iter().zip(b) {
        s += x as i32 * y as i32;
    }
    s
}

fn i32_axpy_i8_scalar(dst: &mut [i32], b: &[i8], a: i32) {
    for (o, &bv) in dst.iter_mut().zip(b) {
        *o += a * bv as i32;
    }
}

fn f32_scale_scalar(dst: &mut [f32], c: f32) {
    for v in dst.iter_mut() {
        *v *= c;
    }
}

fn f32_axpy_scalar(dst: &mut [f32], x: &[f32], p: f32) {
    for (o, &xv) in dst.iter_mut().zip(x) {
        *o += p * xv;
    }
}

fn f32_axpy_i8_scalar(dst: &mut [f32], v: &[i8], pf: i32, scale: f32) {
    for (o, &vv) in dst.iter_mut().zip(v) {
        *o += (pf * vv as i32) as f32 * scale;
    }
}

fn i8_quantize_scalar(dst: &mut [i8], x: &[f32], scale: f32) {
    for (o, &v) in dst.iter_mut().zip(x) {
        // the quant-module oracle IS the scalar rung
        *o = crate::quant::quantize_one(v, scale);
    }
}

fn f32_rms_apply_scalar(dst: &mut [f32], x: &[f32], g: &[f32], inv: f32) {
    for (o, (&v, &gv)) in dst.iter_mut().zip(x.iter().zip(g)) {
        *o = v * inv * gv;
    }
}

fn f32_rope_rotate_scalar(row: &mut [f32], sin: &[f32], cos: &[f32]) {
    let half = sin.len();
    let (a, b) = row.split_at_mut(half);
    for i in 0..half {
        let x1 = a[i];
        let x2 = b[i];
        a[i] = x1 * cos[i] - x2 * sin[i];
        b[i] = x1 * sin[i] + x2 * cos[i];
    }
}

fn f32_deq_scale_scalar(dst: &mut [f32], acc: &[i32], s: f32) {
    for (o, &v) in dst.iter_mut().zip(acc) {
        *o = v as f32 * s;
    }
}

// ---------------------------------------------------------------------------
// x86_64 AVX2
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn i8_dot_avx2(a: &[i8], b: &[i8]) -> i32 {
    use core::arch::x86_64::*;
    let n = a.len();
    let mut acc = _mm256_setzero_si256();
    let mut i = 0usize;
    while i + 16 <= n {
        // 16 i8 lanes -> 16 i16 lanes; madd pairs them into 8 exact i32
        // partial sums (|pair| <= 2 * 127^2, overflow-free for any k
        // below ~2^16 blocks of accumulation).
        let va = _mm_loadu_si128(a.as_ptr().add(i) as *const __m128i);
        let vb = _mm_loadu_si128(b.as_ptr().add(i) as *const __m128i);
        let wa = _mm256_cvtepi8_epi16(va);
        let wb = _mm256_cvtepi8_epi16(vb);
        acc = _mm256_add_epi32(acc, _mm256_madd_epi16(wa, wb));
        i += 16;
    }
    let lo = _mm256_castsi256_si128(acc);
    let hi = _mm256_extracti128_si256::<1>(acc);
    let s = _mm_add_epi32(lo, hi);
    let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0b01_00_11_10>(s));
    let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0b00_00_00_01>(s));
    let mut sum = _mm_cvtsi128_si32(s);
    while i < n {
        sum += *a.get_unchecked(i) as i32 * *b.get_unchecked(i) as i32;
        i += 1;
    }
    sum
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn i32_axpy_i8_avx2(dst: &mut [i32], b: &[i8], a: i32) {
    use core::arch::x86_64::*;
    let n = dst.len();
    let d = dst.as_mut_ptr();
    let va = _mm256_set1_epi32(a);
    let mut i = 0usize;
    while i + 8 <= n {
        let bv = _mm_loadl_epi64(b.as_ptr().add(i) as *const __m128i);
        let w = _mm256_cvtepi8_epi32(bv);
        let prod = _mm256_mullo_epi32(w, va);
        let dv = _mm256_loadu_si256(d.add(i) as *const __m256i);
        _mm256_storeu_si256(d.add(i) as *mut __m256i, _mm256_add_epi32(dv, prod));
        i += 8;
    }
    while i < n {
        *d.add(i) += a * *b.get_unchecked(i) as i32;
        i += 1;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn f32_scale_avx2(dst: &mut [f32], c: f32) {
    use core::arch::x86_64::*;
    let n = dst.len();
    let d = dst.as_mut_ptr();
    let vc = _mm256_set1_ps(c);
    let mut i = 0usize;
    while i + 8 <= n {
        let v = _mm256_loadu_ps(d.add(i));
        _mm256_storeu_ps(d.add(i), _mm256_mul_ps(v, vc));
        i += 8;
    }
    while i < n {
        *d.add(i) *= c;
        i += 1;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn f32_axpy_avx2(dst: &mut [f32], x: &[f32], p: f32) {
    use core::arch::x86_64::*;
    let n = dst.len();
    let d = dst.as_mut_ptr();
    let vp = _mm256_set1_ps(p);
    let mut i = 0usize;
    while i + 8 <= n {
        let xv = _mm256_loadu_ps(x.as_ptr().add(i));
        let dv = _mm256_loadu_ps(d.add(i));
        // mul then add — NOT _mm256_fmadd_ps (see module contract)
        _mm256_storeu_ps(d.add(i), _mm256_add_ps(dv, _mm256_mul_ps(vp, xv)));
        i += 8;
    }
    while i < n {
        *d.add(i) += p * *x.get_unchecked(i);
        i += 1;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn f32_axpy_i8_avx2(dst: &mut [f32], v: &[i8], pf: i32, scale: f32) {
    use core::arch::x86_64::*;
    let n = dst.len();
    let d = dst.as_mut_ptr();
    let vpf = _mm256_set1_epi32(pf);
    let vs = _mm256_set1_ps(scale);
    let mut i = 0usize;
    while i + 8 <= n {
        let bv = _mm_loadl_epi64(v.as_ptr().add(i) as *const __m128i);
        let w = _mm256_cvtepi8_epi32(bv);
        let prod = _mm256_cvtepi32_ps(_mm256_mullo_epi32(w, vpf)); // exact
        let dv = _mm256_loadu_ps(d.add(i));
        _mm256_storeu_ps(d.add(i), _mm256_add_ps(dv, _mm256_mul_ps(prod, vs)));
        i += 8;
    }
    while i < n {
        *d.add(i) += (pf * *v.get_unchecked(i) as i32) as f32 * scale;
        i += 1;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn i8_quantize_avx2(dst: &mut [i8], x: &[f32], scale: f32) {
    use core::arch::x86_64::*;
    let n = dst.len();
    let vs = _mm256_set1_ps(scale);
    let lo = _mm256_set1_ps(-127.0);
    let hi = _mm256_set1_ps(127.0);
    let mut i = 0usize;
    let mut tmp = [0i32; 8];
    while i + 8 <= n {
        let v = _mm256_loadu_ps(x.as_ptr().add(i));
        // x/scale: IEEE division is exactly rounded — same bits as scalar
        let q = _mm256_div_ps(v, vs);
        // nearest-even == f32::round_ties_even
        let r = _mm256_round_ps::<{ _MM_FROUND_TO_NEAREST_INT | _MM_FROUND_NO_EXC }>(q);
        let c = _mm256_min_ps(_mm256_max_ps(r, lo), hi);
        // NaN lanes (max/min pass NaN through undefined here): force to
        // 0.0 so they narrow like the scalar `NaN as i8 == 0`
        let nan = _mm256_cmp_ps::<_CMP_UNORD_Q>(r, r);
        let c = _mm256_andnot_ps(nan, c);
        // the clamped value is integral in [-127, 127]: cvt is exact
        let iv = _mm256_cvtps_epi32(c);
        _mm256_storeu_si256(tmp.as_mut_ptr() as *mut __m256i, iv);
        for (k, &t) in tmp.iter().enumerate() {
            *dst.get_unchecked_mut(i + k) = t as i8;
        }
        i += 8;
    }
    while i < n {
        *dst.get_unchecked_mut(i) = crate::quant::quantize_one(*x.get_unchecked(i), scale);
        i += 1;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn f32_rms_apply_avx2(dst: &mut [f32], x: &[f32], g: &[f32], inv: f32) {
    use core::arch::x86_64::*;
    let n = dst.len();
    let d = dst.as_mut_ptr();
    let vi = _mm256_set1_ps(inv);
    let mut i = 0usize;
    while i + 8 <= n {
        let xv = _mm256_loadu_ps(x.as_ptr().add(i));
        let gv = _mm256_loadu_ps(g.as_ptr().add(i));
        // (x * inv) * g — two roundings left-to-right, no FMA
        _mm256_storeu_ps(d.add(i), _mm256_mul_ps(_mm256_mul_ps(xv, vi), gv));
        i += 8;
    }
    while i < n {
        *d.add(i) = *x.get_unchecked(i) * inv * *g.get_unchecked(i);
        i += 1;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn f32_rope_rotate_avx2(row: &mut [f32], sin: &[f32], cos: &[f32]) {
    use core::arch::x86_64::*;
    let half = sin.len();
    let a = row.as_mut_ptr();
    let b = a.add(half);
    let mut i = 0usize;
    while i + 8 <= half {
        let x1 = _mm256_loadu_ps(a.add(i));
        let x2 = _mm256_loadu_ps(b.add(i));
        let c = _mm256_loadu_ps(cos.as_ptr().add(i));
        let s = _mm256_loadu_ps(sin.as_ptr().add(i));
        // mul, mul, then one sub/add — NOT _mm256_fmsub/fmadd_ps
        _mm256_storeu_ps(a.add(i), _mm256_sub_ps(_mm256_mul_ps(x1, c), _mm256_mul_ps(x2, s)));
        _mm256_storeu_ps(b.add(i), _mm256_add_ps(_mm256_mul_ps(x1, s), _mm256_mul_ps(x2, c)));
        i += 8;
    }
    while i < half {
        let x1 = *a.add(i);
        let x2 = *b.add(i);
        *a.add(i) = x1 * *cos.get_unchecked(i) - x2 * *sin.get_unchecked(i);
        *b.add(i) = x1 * *sin.get_unchecked(i) + x2 * *cos.get_unchecked(i);
        i += 1;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn f32_deq_scale_avx2(dst: &mut [f32], acc: &[i32], s: f32) {
    use core::arch::x86_64::*;
    let n = dst.len();
    let d = dst.as_mut_ptr();
    let vs = _mm256_set1_ps(s);
    let mut i = 0usize;
    while i + 8 <= n {
        let av = _mm256_loadu_si256(acc.as_ptr().add(i) as *const __m256i);
        // cvtepi32_ps rounds to nearest-even, exactly like `as f32`
        _mm256_storeu_ps(d.add(i), _mm256_mul_ps(_mm256_cvtepi32_ps(av), vs));
        i += 8;
    }
    while i < n {
        *d.add(i) = *acc.get_unchecked(i) as f32 * s;
        i += 1;
    }
}

// ---------------------------------------------------------------------------
// aarch64 NEON
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn i8_dot_neon(a: &[i8], b: &[i8]) -> i32 {
    use core::arch::aarch64::*;
    let n = a.len();
    let mut acc = vdupq_n_s32(0);
    let mut i = 0usize;
    while i + 16 <= n {
        let va = vld1q_s8(a.as_ptr().add(i));
        let vb = vld1q_s8(b.as_ptr().add(i));
        let lo = vmull_s8(vget_low_s8(va), vget_low_s8(vb)); // exact i16x8
        let hi = vmull_s8(vget_high_s8(va), vget_high_s8(vb));
        acc = vpadalq_s16(acc, lo); // pairwise-widen into i32 lanes
        acc = vpadalq_s16(acc, hi);
        i += 16;
    }
    let mut sum = vaddvq_s32(acc);
    while i < n {
        sum += *a.get_unchecked(i) as i32 * *b.get_unchecked(i) as i32;
        i += 1;
    }
    sum
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn i32_axpy_i8_neon(dst: &mut [i32], b: &[i8], a: i32) {
    use core::arch::aarch64::*;
    let n = dst.len();
    let d = dst.as_mut_ptr();
    let va = vdupq_n_s32(a);
    let mut i = 0usize;
    while i + 8 <= n {
        let w = vmovl_s8(vld1_s8(b.as_ptr().add(i))); // i16x8
        let lo = vmulq_s32(vmovl_s16(vget_low_s16(w)), va);
        let hi = vmulq_s32(vmovl_s16(vget_high_s16(w)), va);
        vst1q_s32(d.add(i), vaddq_s32(vld1q_s32(d.add(i)), lo));
        vst1q_s32(d.add(i + 4), vaddq_s32(vld1q_s32(d.add(i + 4)), hi));
        i += 8;
    }
    while i < n {
        *d.add(i) += a * *b.get_unchecked(i) as i32;
        i += 1;
    }
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn f32_scale_neon(dst: &mut [f32], c: f32) {
    use core::arch::aarch64::*;
    let n = dst.len();
    let d = dst.as_mut_ptr();
    let vc = vdupq_n_f32(c);
    let mut i = 0usize;
    while i + 4 <= n {
        vst1q_f32(d.add(i), vmulq_f32(vld1q_f32(d.add(i)), vc));
        i += 4;
    }
    while i < n {
        *d.add(i) *= c;
        i += 1;
    }
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn f32_axpy_neon(dst: &mut [f32], x: &[f32], p: f32) {
    use core::arch::aarch64::*;
    let n = dst.len();
    let d = dst.as_mut_ptr();
    let vp = vdupq_n_f32(p);
    let mut i = 0usize;
    while i + 4 <= n {
        let xv = vld1q_f32(x.as_ptr().add(i));
        let dv = vld1q_f32(d.add(i));
        // vmul + vadd, NOT vfmaq/vmlaq (which may fuse; see contract)
        vst1q_f32(d.add(i), vaddq_f32(dv, vmulq_f32(vp, xv)));
        i += 4;
    }
    while i < n {
        *d.add(i) += p * *x.get_unchecked(i);
        i += 1;
    }
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn f32_axpy_i8_neon(dst: &mut [f32], v: &[i8], pf: i32, scale: f32) {
    use core::arch::aarch64::*;
    let n = dst.len();
    let d = dst.as_mut_ptr();
    let vpf = vdupq_n_s32(pf);
    let vs = vdupq_n_f32(scale);
    let mut i = 0usize;
    while i + 8 <= n {
        let w = vmovl_s8(vld1_s8(v.as_ptr().add(i))); // i16x8
        let lo = vmulq_s32(vmovl_s16(vget_low_s16(w)), vpf); // exact
        let hi = vmulq_s32(vmovl_s16(vget_high_s16(w)), vpf);
        let flo = vmulq_f32(vcvtq_f32_s32(lo), vs);
        let fhi = vmulq_f32(vcvtq_f32_s32(hi), vs);
        vst1q_f32(d.add(i), vaddq_f32(vld1q_f32(d.add(i)), flo));
        vst1q_f32(d.add(i + 4), vaddq_f32(vld1q_f32(d.add(i + 4)), fhi));
        i += 8;
    }
    while i < n {
        *d.add(i) += (pf * *v.get_unchecked(i) as i32) as f32 * scale;
        i += 1;
    }
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn i8_quantize_neon(dst: &mut [i8], x: &[f32], scale: f32) {
    use core::arch::aarch64::*;
    let n = dst.len();
    let vs = vdupq_n_f32(scale);
    let lo = vdupq_n_f32(-127.0);
    let hi = vdupq_n_f32(127.0);
    let mut i = 0usize;
    let mut tmp = [0i32; 4];
    while i + 4 <= n {
        let v = vld1q_f32(x.as_ptr().add(i));
        // exactly-rounded divide, then round-to-nearest-even
        let r = vrndnq_f32(vdivq_f32(v, vs));
        // fmax/fmin propagate NaN; fcvtzs maps NaN to 0 like `as i8`
        let c = vminq_f32(vmaxq_f32(r, lo), hi);
        let iv = vcvtq_s32_f32(c); // integral in range: exact
        vst1q_s32(tmp.as_mut_ptr(), iv);
        for (k, &t) in tmp.iter().enumerate() {
            *dst.get_unchecked_mut(i + k) = t as i8;
        }
        i += 4;
    }
    while i < n {
        *dst.get_unchecked_mut(i) = crate::quant::quantize_one(*x.get_unchecked(i), scale);
        i += 1;
    }
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn f32_rms_apply_neon(dst: &mut [f32], x: &[f32], g: &[f32], inv: f32) {
    use core::arch::aarch64::*;
    let n = dst.len();
    let d = dst.as_mut_ptr();
    let vi = vdupq_n_f32(inv);
    let mut i = 0usize;
    while i + 4 <= n {
        let xv = vld1q_f32(x.as_ptr().add(i));
        let gv = vld1q_f32(g.as_ptr().add(i));
        // (x * inv) * g — two roundings, no fused form
        vst1q_f32(d.add(i), vmulq_f32(vmulq_f32(xv, vi), gv));
        i += 4;
    }
    while i < n {
        *d.add(i) = *x.get_unchecked(i) * inv * *g.get_unchecked(i);
        i += 1;
    }
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn f32_rope_rotate_neon(row: &mut [f32], sin: &[f32], cos: &[f32]) {
    use core::arch::aarch64::*;
    let half = sin.len();
    let a = row.as_mut_ptr();
    let b = a.add(half);
    let mut i = 0usize;
    while i + 4 <= half {
        let x1 = vld1q_f32(a.add(i));
        let x2 = vld1q_f32(b.add(i));
        let c = vld1q_f32(cos.as_ptr().add(i));
        let s = vld1q_f32(sin.as_ptr().add(i));
        // vmul then vsub/vadd, NOT vfmaq/vfmsq (see contract)
        vst1q_f32(a.add(i), vsubq_f32(vmulq_f32(x1, c), vmulq_f32(x2, s)));
        vst1q_f32(b.add(i), vaddq_f32(vmulq_f32(x1, s), vmulq_f32(x2, c)));
        i += 4;
    }
    while i < half {
        let x1 = *a.add(i);
        let x2 = *b.add(i);
        *a.add(i) = x1 * *cos.get_unchecked(i) - x2 * *sin.get_unchecked(i);
        *b.add(i) = x1 * *sin.get_unchecked(i) + x2 * *cos.get_unchecked(i);
        i += 1;
    }
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn f32_deq_scale_neon(dst: &mut [f32], acc: &[i32], s: f32) {
    use core::arch::aarch64::*;
    let n = dst.len();
    let d = dst.as_mut_ptr();
    let vs = vdupq_n_f32(s);
    let mut i = 0usize;
    while i + 4 <= n {
        let av = vld1q_s32(acc.as_ptr().add(i));
        // scvtf rounds to nearest-even, exactly like `as f32`
        vst1q_f32(d.add(i), vmulq_f32(vcvtq_f32_s32(av), vs));
        i += 4;
    }
    while i < n {
        *d.add(i) = *acc.get_unchecked(i) as f32 * s;
        i += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    fn rand_i8(rng: &mut Prng, n: usize) -> Vec<i8> {
        (0..n).map(|_| rng.i8_sym()).collect()
    }

    fn rand_f32(rng: &mut Prng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal()).collect()
    }

    /// Every length in 0..=67 covers empty, sub-width, exact-width and
    /// ragged-tail cases for both 128- and 256-bit lanes.
    const LENS: std::ops::RangeInclusive<usize> = 0..=67;

    #[test]
    fn vector_i8_dot_matches_scalar_exactly() {
        let bk = detect();
        let mut rng = Prng::new(0x51D1);
        for n in LENS {
            let a = rand_i8(&mut rng, n);
            let b = rand_i8(&mut rng, n);
            assert_eq!(bk.i8_dot(&a, &b), Backend::Scalar.i8_dot(&a, &b), "n={n}");
        }
    }

    #[test]
    fn vector_i32_axpy_i8_matches_scalar_exactly() {
        let bk = detect();
        let mut rng = Prng::new(0x51D2);
        for n in LENS {
            let b = rand_i8(&mut rng, n);
            let init: Vec<i32> = (0..n).map(|_| rng.below(1000) as i32 - 500).collect();
            for a in [-128i32, -3, 0, 7, 127] {
                let mut want = init.clone();
                Backend::Scalar.i32_axpy_i8(&mut want, &b, a);
                let mut got = init.clone();
                bk.i32_axpy_i8(&mut got, &b, a);
                assert_eq!(got, want, "n={n} a={a}");
            }
        }
    }

    #[test]
    fn vector_f32_primitives_bit_identical_to_scalar() {
        let bk = detect();
        let mut rng = Prng::new(0x51D3);
        for n in LENS {
            let x = rand_f32(&mut rng, n);
            let init = rand_f32(&mut rng, n);
            for p in [0.0f32, -0.75, 1.5e-3, 3.0] {
                let mut want = init.clone();
                f32_scale_scalar(&mut want, p);
                f32_axpy_scalar(&mut want, &x, p);
                let mut got = init.clone();
                bk.f32_scale(&mut got, p);
                bk.f32_axpy(&mut got, &x, p);
                // bitwise, not approximate: compare the raw bits
                let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
                let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
                assert_eq!(gb, wb, "n={n} p={p}");
            }
        }
    }

    #[test]
    fn vector_f32_axpy_i8_bit_identical_to_scalar() {
        let bk = detect();
        let mut rng = Prng::new(0x51D4);
        for n in LENS {
            let v = rand_i8(&mut rng, n);
            let init = rand_f32(&mut rng, n);
            for pf in [-127i32, -1, 1, 64, 127] {
                let mut want = init.clone();
                f32_axpy_i8_scalar(&mut want, &v, pf, 0.02);
                let mut got = init.clone();
                bk.f32_axpy_i8(&mut got, &v, pf, 0.02);
                let wb: Vec<u32> = want.iter().map(|x| x.to_bits()).collect();
                let gb: Vec<u32> = got.iter().map(|x| x.to_bits()).collect();
                assert_eq!(gb, wb, "n={n} pf={pf}");
            }
        }
    }

    #[test]
    fn vector_i8_quantize_bit_identical_to_oracle() {
        let bk = detect();
        let mut rng = Prng::new(0x51D5);
        for n in LENS {
            let mut x = rand_f32(&mut rng, n);
            // salt in saturation, tie and denormal-quotient edges
            for (k, v) in x.iter_mut().enumerate() {
                match k % 7 {
                    0 => *v = 1e9,        // saturates high
                    1 => *v = -1e9,       // saturates low
                    2 => *v *= 1e-40,     // denormal quotient
                    3 => *v = 0.5,        // tie -> even (0)
                    4 => *v = -1.5,       // tie -> even (-2)
                    _ => {}
                }
            }
            for scale in [1.0f32, 0.013, crate::quant::SCALE_EPS / 127.0] {
                let mut want = vec![0i8; n];
                i8_quantize_scalar(&mut want, &x, scale);
                // the scalar rung IS the quant oracle
                for (w, &v) in want.iter().zip(&x) {
                    assert_eq!(*w, crate::quant::quantize_one(v, scale));
                }
                let mut got = vec![0i8; n];
                bk.i8_quantize(&mut got, &x, scale);
                assert_eq!(got, want, "n={n} scale={scale}");
            }
        }
    }

    #[test]
    fn vector_f32_rms_apply_bit_identical_to_scalar() {
        let bk = detect();
        let mut rng = Prng::new(0x51D6);
        for n in LENS {
            let x = rand_f32(&mut rng, n);
            let g = rand_f32(&mut rng, n);
            for inv in [1.0f32, 0.037, 1.0e-20, 8.5] {
                let mut want = vec![0.0f32; n];
                f32_rms_apply_scalar(&mut want, &x, &g, inv);
                let mut got = vec![0.0f32; n];
                bk.f32_rms_apply(&mut got, &x, &g, inv);
                let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
                let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
                assert_eq!(gb, wb, "n={n} inv={inv}");
            }
        }
    }

    #[test]
    fn vector_f32_rope_rotate_bit_identical_to_scalar() {
        let bk = detect();
        let mut rng = Prng::new(0x51D7);
        for half in LENS {
            let row = rand_f32(&mut rng, 2 * half);
            let angles = rand_f32(&mut rng, half);
            let sin: Vec<f32> = angles.iter().map(|a| a.sin()).collect();
            let cos: Vec<f32> = angles.iter().map(|a| a.cos()).collect();
            let mut want = row.clone();
            f32_rope_rotate_scalar(&mut want, &sin, &cos);
            let mut got = row.clone();
            bk.f32_rope_rotate(&mut got, &sin, &cos);
            let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
            let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
            assert_eq!(gb, wb, "half={half}");
        }
    }

    #[test]
    fn vector_f32_deq_scale_bit_identical_to_scalar() {
        let bk = detect();
        let mut rng = Prng::new(0x51D8);
        for n in LENS {
            // include magnitudes above 2^24 (inexact i32->f32 territory)
            let acc: Vec<i32> = (0..n)
                .map(|k| {
                    let v = rng.below(1 << 30) as i32 - (1 << 29);
                    if k % 3 == 0 { v } else { v % 100_000 }
                })
                .collect();
            for s in [1.0f32, 6.2e-5, -0.75] {
                let mut want = vec![0.0f32; n];
                f32_deq_scale_scalar(&mut want, &acc, s);
                let mut got = vec![0.0f32; n];
                bk.f32_deq_scale(&mut got, &acc, s);
                let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
                let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
                assert_eq!(gb, wb, "n={n} s={s}");
            }
        }
    }

    #[test]
    fn resolve_honors_both_override_values() {
        assert_eq!(resolve(Some("scalar")), Backend::Scalar);
        assert_eq!(resolve(Some(" SCALAR ")), Backend::Scalar);
        assert_eq!(resolve(Some("simd")), detect());
        assert_eq!(resolve(None), detect());
        assert_eq!(resolve(Some("")), detect());
        // unknown values are loud but never fatal
        assert_eq!(resolve(Some("banana")), detect());
    }

    #[test]
    fn names_are_stable() {
        assert_eq!(Backend::Scalar.name(), "scalar");
        assert_eq!(Backend::Avx2.name(), "avx2");
        assert_eq!(Backend::Neon.name(), "neon");
        assert!(!Backend::Scalar.is_vector());
        assert!(Backend::Avx2.is_vector() && Backend::Neon.is_vector());
    }

    #[test]
    fn active_is_detect_or_env_forced() {
        // whatever the env says, active() must be a backend this host can
        // actually run: scalar or the detected vector ISA
        let a = active();
        assert!(a == Backend::Scalar || a == detect());
    }
}
