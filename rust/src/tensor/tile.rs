//! Block-major tiled kernels — the CPU mirror of the paper's tiled MPU.
//!
//! Cache-blocked `matmul` / `matmul_bt` (f32 and W8A8) plus the fused
//! online-softmax accumulate that the SAU applies per score tile. The
//! scalar implementations in [`crate::tensor::ops`] and
//! [`crate::quant`] remain the bit-level oracles; every kernel here is
//! asserted against them by unit and property tests.
//!
//! Inner loops dispatch through the runtime-selected SIMD backend
//! ([`crate::tensor::simd`]): AVX2 on x86_64, NEON on aarch64, scalar
//! otherwise, overridable with `FASTP_KERNEL={scalar,simd}`. Every
//! public kernel has a `*_bk` variant taking an explicit
//! [`Backend`] so tests can pin both backends in one process; the
//! plain entry points use the process-wide [`simd::active`] selection.
//!
//! Numerics contract:
//!  * integer kernels are exact (identical accumulator values in any
//!    loop order — which is why the i8 dot may vectorize *within* k);
//!  * f32 kernels accumulate each output element left-to-right in
//!    ascending-k order — the *same* addition sequence as the scalar
//!    oracle — so tiling does not perturb results. The SIMD f32 paths
//!    therefore vectorize **across independent output columns, never
//!    within k** (and never emit FMA); `matmul_bt`'s k-major layout
//!    admits no such columns, so its f32 inner dot stays scalar on
//!    every backend;
//!  * nothing here depends on the worker-thread count: parallel callers
//!    split work at job granularity (see [`crate::util::pool`]) and each
//!    job runs these kernels sequentially.
//!
//! Tile sizing: [`TILE`] by default, overridable process-wide with
//! `FASTP_TILE` (validated once: rejects 0 and non-multiples of 8 with
//! a warning, falling back to the default). Tile size never changes
//! results (property-tested) — only cache behavior.

use std::sync::{Arc, OnceLock};

use crate::tensor::simd::{self, Backend};
use crate::tensor::tune::{self, OpClass, ShapeClass, TuneProfile};
use crate::tensor::{MatF32, MatI8};
use crate::util::pool::WorkerPool;

/// Default cache tile edge. 64x64 i8 tiles are 4 KiB (two tiles per
/// operand stay L1-resident); BLOCK-sized (128) operands split into four.
pub const TILE: usize = 64;

/// Environment variable overriding the cache tile edge for every context
/// and default-tile kernel entry point (validated; see [`parse_tile_override`]).
pub const TILE_ENV: &str = "FASTP_TILE";

static TILE_FROM_ENV: OnceLock<usize> = OnceLock::new();

/// Validate a `FASTP_TILE` value: a positive multiple of 8 (vector lanes
/// never straddle a ragged tile edge for no reason; 8 divides both the
/// 128-bit and 256-bit lane widths for every element type used here).
pub fn parse_tile_override(raw: &str) -> Result<usize, String> {
    let v: usize = raw
        .trim()
        .parse()
        .map_err(|_| format!("{TILE_ENV}={raw:?} is not an unsigned integer"))?;
    if v == 0 {
        return Err(format!("{TILE_ENV} must be > 0"));
    }
    if v % 8 != 0 {
        return Err(format!("{TILE_ENV}={v} must be a multiple of 8"));
    }
    Ok(v)
}

/// The single `FASTP_TILE` parse point (resolved once per process).
/// Invalid values warn and fall back to [`TILE`] rather than aborting
/// (via [`crate::config::env::knob`]).
pub fn env_tile() -> usize {
    *TILE_FROM_ENV.get_or_init(|| crate::config::env::knob_or(TILE_ENV, parse_tile_override, TILE))
}

/// Kernel-layer context threaded through the engine phases: the shared
/// worker pool, the tile configuration, the selected SIMD backend and
/// (when autotuning is on) the per-shape tuning profile.
#[derive(Clone, Debug)]
pub struct KernelCtx {
    pub pool: WorkerPool,
    /// Cache tile edge used by the blocked kernels.
    pub tile: usize,
    /// Micro-kernel backend the inner loops dispatch to. Defaults to the
    /// process-wide selection (`FASTP_KERNEL` / ISA detection).
    pub backend: Backend,
    /// Per-shape (tile, backend) winners from the autotuner
    /// (`FASTP_AUTOTUNE`); `None` = untuned, one fixed tile/backend for
    /// every shape. Neither choice can change results (bit-identity
    /// contract), so tuned runs are bit-identical to untuned runs.
    pub tune: Option<Arc<TuneProfile>>,
}

impl KernelCtx {
    /// The shared constructor core: env-resolved tile edge, backend and
    /// autotune profile around the given pool (the one place all three
    /// env overrides land).
    fn over_pool(pool: WorkerPool) -> KernelCtx {
        KernelCtx {
            pool,
            tile: env_tile(),
            backend: simd::active(),
            tune: tune::active_profile(),
        }
    }

    /// Pool sized by `FASTP_THREADS` (default: available parallelism).
    pub fn from_env() -> KernelCtx {
        KernelCtx::over_pool(WorkerPool::from_env())
    }

    /// Explicit worker count.
    pub fn with_threads(n: usize) -> KernelCtx {
        KernelCtx::over_pool(WorkerPool::with_threads(n))
    }

    /// Everything inline on the caller thread.
    pub fn single_threaded() -> KernelCtx {
        KernelCtx::over_pool(WorkerPool::single_threaded())
    }

    /// Context over an explicit pool (e.g. a budget-shared serving pool).
    pub fn with_pool(pool: WorkerPool) -> KernelCtx {
        KernelCtx::over_pool(pool)
    }

    /// This context with a forced micro-kernel backend (tests, benches;
    /// results are bit-identical for every backend by contract).
    pub fn with_backend(mut self, backend: Backend) -> KernelCtx {
        self.backend = backend;
        self
    }

    /// This context with an explicit autotune profile (or none),
    /// overriding the env-resolved `FASTP_AUTOTUNE` selection — used by
    /// `fastp tune --check` and the tuned-vs-untuned bit-identity tests,
    /// which need both legs in one process.
    pub fn with_tune(mut self, tune: Option<Arc<TuneProfile>>) -> KernelCtx {
        self.tune = tune;
        self
    }

    /// Resolve the (tile edge, backend) one kernel shape runs with: the
    /// tuned per-shape winner when a profile is loaded (misses fall back
    /// to the ctx-wide defaults), else the defaults. A profile can only
    /// choose between this ctx's backend and scalar, so a
    /// `FASTP_KERNEL=scalar` override still pins every kernel scalar.
    pub fn plan(&self, op: OpClass, m: usize, n: usize, k: usize) -> (usize, Backend) {
        match &self.tune {
            Some(p) => p.resolve(&ShapeClass::new(op, m, n, k), self.tile, self.backend),
            None => (self.tile, self.backend),
        }
    }

    /// Label of the autotune source for metrics: `"off"` when untuned,
    /// the env mode name when the profile came from `FASTP_AUTOTUNE`, or
    /// `"profile"` for an explicitly injected profile.
    pub fn tune_label(&self) -> &'static str {
        match &self.tune {
            None => "off",
            Some(_) => match tune::env_mode() {
                tune::AutotuneMode::Off => "profile",
                m => m.name(),
            },
        }
    }

    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// A context whose fan-outs want at most `cap` pool slots — the
    /// engine's per-phase lease hint (e.g. IndexGen asks for a small
    /// share so co-resident SAU/QKV fan-outs keep the cores).
    pub fn with_want_cap(&self, cap: usize) -> KernelCtx {
        KernelCtx { pool: self.pool.with_want_cap(cap), ..self.clone() }
    }

    /// Tiled f32 matmul (C = A @ B).
    pub fn matmul(&self, a: &MatF32, b: &MatF32) -> MatF32 {
        let (t, bk) = self.plan(OpClass::MatmulF32, a.rows, b.cols, a.cols);
        matmul_with_bk(a, b, t, bk)
    }

    /// Tiled f32 matmul against a transposed B (C = A @ B^T).
    pub fn matmul_bt(&self, a: &MatF32, b: &MatF32) -> MatF32 {
        let (t, bk) = self.plan(OpClass::MatmulBtF32, a.rows, b.rows, a.cols);
        matmul_bt_with_bk(a, b, t, bk)
    }

    /// Tiled W8A8 matmul, dequantized (C_f32 = (A_i8 @ B_i8) * sa * sb).
    pub fn int8_matmul_deq(&self, a: &MatI8, sa: f32, b: &MatI8, sb: f32) -> MatF32 {
        let (t, bk) = self.plan(OpClass::Int8Matmul, a.rows, b.cols, a.cols);
        let acc = int8_matmul_with_bk(a, b, t, bk);
        let s = sa * sb;
        let mut data = vec![0.0f32; acc.len()];
        bk.f32_deq_scale(&mut data, &acc, s);
        MatF32 { rows: a.rows, cols: b.cols, data }
    }

    /// Tiled exact W8A8 score matmul (C_i32 = A_i8 @ B_i8^T).
    pub fn int8_matmul_bt(&self, a: &MatI8, bt: &MatI8) -> Vec<i32> {
        let (t, bk) = self.plan(OpClass::Int8MatmulBt, a.rows, bt.rows, a.cols);
        int8_matmul_bt_with_bk(a, bt, t, bk)
    }
}

impl Default for KernelCtx {
    fn default() -> Self {
        KernelCtx::from_env()
    }
}

// ---------------------------------------------------------------------------
// f32 kernels
// ---------------------------------------------------------------------------

/// Tiled C[M,N] = A[M,K] @ B[K,N] with the env-default tile size and the
/// active backend.
pub fn matmul(a: &MatF32, b: &MatF32) -> MatF32 {
    matmul_with_bk(a, b, env_tile(), simd::active())
}

/// Tiled f32 matmul with an explicit tile edge (active backend).
pub fn matmul_with(a: &MatF32, b: &MatF32, tile: usize) -> MatF32 {
    matmul_with_bk(a, b, tile, simd::active())
}

/// Tiled f32 matmul with explicit tile edge and backend. Accumulation
/// per output element is ascending-k left-to-right — the scalar oracle's
/// order; the backend vectorizes only across the independent output
/// columns of each `j`-tile row.
pub fn matmul_with_bk(a: &MatF32, b: &MatF32, tile: usize, bk: Backend) -> MatF32 {
    assert_eq!(a.cols, b.rows, "tile::matmul dims");
    let tile = tile.max(1);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut out = MatF32::zeros(m, n);
    for i0 in (0..m).step_by(tile) {
        let i1 = (i0 + tile).min(m);
        for k0 in (0..k).step_by(tile) {
            let k1 = (k0 + tile).min(k);
            for j0 in (0..n).step_by(tile) {
                let j1 = (j0 + tile).min(n);
                for i in i0..i1 {
                    let arow = a.row(i);
                    let orow = &mut out.row_mut(i)[j0..j1];
                    for kk in k0..k1 {
                        let av = arow[kk];
                        if av == 0.0 {
                            continue; // same skip as the scalar oracle
                        }
                        bk.f32_axpy(orow, &b.row(kk)[j0..j1], av);
                    }
                }
            }
        }
    }
    out
}

/// Tiled C[M,N] = A[M,K] @ B^T with B given as [N,K] (score-tile shape),
/// env-default tile size, active backend.
pub fn matmul_bt(a: &MatF32, b: &MatF32) -> MatF32 {
    matmul_bt_with_bk(a, b, env_tile(), simd::active())
}

/// Tiled f32 `matmul_bt` with an explicit tile edge (active backend).
pub fn matmul_bt_with(a: &MatF32, b: &MatF32, tile: usize) -> MatF32 {
    matmul_bt_with_bk(a, b, tile, simd::active())
}

/// Tiled f32 `matmul_bt` with explicit tile edge and backend; the
/// running sum per output element crosses k-tiles left-to-right (oracle
/// order). The k-major B layout leaves no contiguous independent output
/// columns, so the inner dot stays scalar on every backend — a vector
/// dot would reorder f32 additions and break bit-identity.
pub fn matmul_bt_with_bk(a: &MatF32, b: &MatF32, tile: usize, _bk: Backend) -> MatF32 {
    assert_eq!(a.cols, b.cols, "tile::matmul_bt dims");
    let tile = tile.max(1);
    let (m, n, k) = (a.rows, b.rows, a.cols);
    let mut out = MatF32::zeros(m, n);
    for i0 in (0..m).step_by(tile) {
        let i1 = (i0 + tile).min(m);
        for j0 in (0..n).step_by(tile) {
            let j1 = (j0 + tile).min(n);
            for k0 in (0..k).step_by(tile) {
                let k1 = (k0 + tile).min(k);
                for i in i0..i1 {
                    let arow = &a.row(i)[k0..k1];
                    for j in j0..j1 {
                        let brow = &b.row(j)[k0..k1];
                        let mut s = out.at(i, j);
                        for (x, y) in arow.iter().zip(brow) {
                            s += x * y;
                        }
                        *out.at_mut(i, j) = s;
                    }
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// W8A8 kernels (exact integer arithmetic — loop order free)
// ---------------------------------------------------------------------------

/// Tiled exact C_i32[M,N] = A_i8[M,K] @ B_i8[K,N] (env-default tile,
/// active backend).
pub fn int8_matmul(a: &MatI8, b: &MatI8) -> Vec<i32> {
    int8_matmul_with_bk(a, b, env_tile(), simd::active())
}

/// Tiled exact W8A8 matmul with an explicit tile edge (active backend).
pub fn int8_matmul_with(a: &MatI8, b: &MatI8, tile: usize) -> Vec<i32> {
    int8_matmul_with_bk(a, b, tile, simd::active())
}

/// Tiled exact W8A8 matmul with explicit tile edge and backend.
pub fn int8_matmul_with_bk(a: &MatI8, b: &MatI8, tile: usize, bk: Backend) -> Vec<i32> {
    assert_eq!(a.cols, b.rows, "tile::int8_matmul dims");
    let tile = tile.max(1);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut out = vec![0i32; m * n];
    for i0 in (0..m).step_by(tile) {
        let i1 = (i0 + tile).min(m);
        for k0 in (0..k).step_by(tile) {
            let k1 = (k0 + tile).min(k);
            for j0 in (0..n).step_by(tile) {
                let j1 = (j0 + tile).min(n);
                for i in i0..i1 {
                    let arow = a.row(i);
                    let orow = &mut out[i * n + j0..i * n + j1];
                    for kk in k0..k1 {
                        let av = arow[kk] as i32;
                        if av == 0 {
                            continue;
                        }
                        bk.i32_axpy_i8(orow, &b.row(kk)[j0..j1], av);
                    }
                }
            }
        }
    }
    out
}

/// Tiled exact C_i32[M,N] = A_i8[M,K] @ B_i8^T with B given as [N,K] —
/// the SIGU/SAU score-tile kernel (env-default tile, active backend).
pub fn int8_matmul_bt(a: &MatI8, bt: &MatI8) -> Vec<i32> {
    int8_matmul_bt_with_bk(a, bt, env_tile(), simd::active())
}

/// Tiled `int8_matmul_bt` with an explicit tile edge (active backend).
pub fn int8_matmul_bt_with(a: &MatI8, bt: &MatI8, tile: usize) -> Vec<i32> {
    int8_matmul_bt_with_bk(a, bt, tile, simd::active())
}

/// Tiled `int8_matmul_bt` with explicit tile edge and backend.
pub fn int8_matmul_bt_with_bk(a: &MatI8, bt: &MatI8, tile: usize, bk: Backend) -> Vec<i32> {
    assert_eq!(a.cols, bt.cols, "tile::int8_matmul_bt dims");
    let mut out = vec![0i32; a.rows * bt.rows];
    int8_dot_bt_bk(&a.data, &bt.data, a.rows, bt.rows, a.cols, tile, bk, &mut out);
    out
}

/// Slice-level core of the score-tile kernel: C[m,n] += A[m,k] @ B[n,k]^T,
/// both operands row-major over k (active backend). Lets the engine score
/// raw chunk slices without materializing `MatI8` views.
pub fn int8_dot_bt(a: &[i8], bt: &[i8], m: usize, n: usize, k: usize, tile: usize, out: &mut [i32]) {
    int8_dot_bt_bk(a, bt, m, n, k, tile, simd::active(), out);
}

/// [`int8_dot_bt`] with an explicit backend. The inner i8 dot *is*
/// vectorized within k here — integer accumulation is exact, so lane
/// order cannot change the result.
#[allow(clippy::too_many_arguments)]
pub fn int8_dot_bt_bk(
    a: &[i8],
    bt: &[i8],
    m: usize,
    n: usize,
    k: usize,
    tile: usize,
    bk: Backend,
    out: &mut [i32],
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(bt.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    let tile = tile.max(1);
    for i0 in (0..m).step_by(tile) {
        let i1 = (i0 + tile).min(m);
        for j0 in (0..n).step_by(tile) {
            let j1 = (j0 + tile).min(n);
            for k0 in (0..k).step_by(tile) {
                let k1 = (k0 + tile).min(k);
                for i in i0..i1 {
                    let arow = &a[i * k + k0..i * k + k1];
                    for j in j0..j1 {
                        let brow = &bt[j * k + k0..j * k + k1];
                        out[i * n + j] += bk.i8_dot(arow, brow);
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// fused softmax-accumulate
// ---------------------------------------------------------------------------

/// Fold one f32 score tile into online-softmax state with fused P@V
/// accumulation: the f32 sibling of `model::forward::attn_step_w8a8`
/// (no P requantization). Uses the active backend.
///
/// `s` is [B, Bk] (already scaled), `v` is [Bk, d]; `m`/`l` are per-row
/// online state and `acc` is [B, d]. After folding every tile, divide by
/// `l` (see [`crate::model::forward::attn_finalize`]).
pub fn fused_softmax_acc(s: &MatF32, v: &MatF32, m: &mut [f32], l: &mut [f32], acc: &mut MatF32) {
    fused_softmax_acc_bk(s, v, m, l, acc, simd::active());
}

/// [`fused_softmax_acc`] with an explicit backend. The row max and the
/// per-score `exp` stay scalar (sequential semantics); only the d-wide
/// rescale and P@V accumulate vectorize — across the independent output
/// columns of `acc`, preserving each element's addition order exactly.
pub fn fused_softmax_acc_bk(
    s: &MatF32,
    v: &MatF32,
    m: &mut [f32],
    l: &mut [f32],
    acc: &mut MatF32,
    bk: Backend,
) {
    assert_eq!(s.cols, v.rows, "fused_softmax_acc dims");
    assert_eq!(acc.cols, v.cols, "fused_softmax_acc acc dims");
    assert_eq!(s.rows, acc.rows, "fused_softmax_acc rows");
    for r in 0..s.rows {
        let row = s.row(r);
        let rmax = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let m_new = m[r].max(rmax);
        let corr = (m[r] - m_new).exp();
        let arow = acc.row_mut(r);
        bk.f32_scale(arow, corr);
        let mut lsum = 0.0f32;
        for (j, &sv) in row.iter().enumerate() {
            let p = (sv - m_new).exp();
            lsum += p;
            bk.f32_axpy(arow, v.row(j), p);
        }
        l[r] = l[r] * corr + lsum;
        m[r] = m_new;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops;
    use crate::util::prng::Prng;

    fn randf(rng: &mut Prng, r: usize, c: usize) -> MatF32 {
        MatF32::from_fn(r, c, |_, _| rng.normal())
    }

    fn randi(rng: &mut Prng, r: usize, c: usize) -> MatI8 {
        MatI8 { rows: r, cols: c, data: (0..r * c).map(|_| rng.i8_sym()).collect() }
    }

    #[test]
    fn f32_matmul_matches_oracle_bitwise() {
        let mut rng = Prng::new(0x71);
        let a = randf(&mut rng, 70, 130);
        let b = randf(&mut rng, 130, 67);
        let want = ops::matmul(&a, &b);
        assert_eq!(matmul_with(&a, &b, 32), want);
        for bk in [Backend::Scalar, simd::detect()] {
            assert_eq!(matmul_with_bk(&a, &b, 32, bk), want, "{}", bk.name());
        }
    }

    #[test]
    fn f32_matmul_bt_matches_oracle_bitwise() {
        let mut rng = Prng::new(2);
        let a = randf(&mut rng, 33, 100);
        let b = randf(&mut rng, 65, 100);
        let want = ops::matmul_bt(&a, &b);
        assert_eq!(matmul_bt_with(&a, &b, 16), want);
        for bk in [Backend::Scalar, simd::detect()] {
            assert_eq!(matmul_bt_with_bk(&a, &b, 16, bk), want, "{}", bk.name());
        }
    }

    #[test]
    fn int8_kernels_match_quant_oracle() {
        let mut rng = Prng::new(3);
        let a = randi(&mut rng, 37, 129);
        let b = randi(&mut rng, 129, 41);
        let bt = b.transpose();
        for bk in [Backend::Scalar, simd::detect()] {
            assert_eq!(
                int8_matmul_with_bk(&a, &b, 32, bk),
                crate::quant::int8_matmul(&a, &b),
                "{}",
                bk.name()
            );
            assert_eq!(
                int8_matmul_bt_with_bk(&a, &bt, 32, bk),
                crate::quant::int8_matmul_bt(&a, &bt),
                "{}",
                bk.name()
            );
        }
    }

    #[test]
    fn tile_size_does_not_change_results() {
        let mut rng = Prng::new(4);
        let a = randi(&mut rng, 50, 70);
        let bt = randi(&mut rng, 31, 70);
        let base = int8_matmul_bt_with(&a, &bt, 1);
        for t in [3, 16, 64, 1024] {
            assert_eq!(int8_matmul_bt_with(&a, &bt, t), base, "tile {t}");
        }
    }

    #[test]
    fn fused_softmax_acc_matches_softmax_then_matmul() {
        // folding tiles online == exact softmax over the concatenation
        let mut rng = Prng::new(5);
        let b = 8;
        let tiles = 3;
        let d = 16;
        let s_all = randf(&mut rng, b, tiles * 12);
        let v_all = randf(&mut rng, tiles * 12, d);
        let mut m = vec![-1e30f32; b];
        let mut l = vec![0.0f32; b];
        let mut acc = MatF32::zeros(b, d);
        for t in 0..tiles {
            let s_tile = MatF32::from_fn(b, 12, |r, c| s_all.at(r, t * 12 + c));
            let v_tile = v_all.slice_rows(t * 12, (t + 1) * 12);
            fused_softmax_acc(&s_tile, &v_tile, &mut m, &mut l, &mut acc);
        }
        for r in 0..b {
            let inv = 1.0 / l[r].max(1e-30);
            for x in acc.row_mut(r) {
                *x *= inv;
            }
        }
        let mut s_ref = s_all.clone();
        ops::softmax_rows(&mut s_ref);
        let direct = ops::matmul(&s_ref, &v_all);
        for (x, y) in acc.data.iter().zip(&direct.data) {
            assert!((x - y).abs() < 1e-5, "{x} vs {y}");
        }
    }

    #[test]
    fn fused_softmax_acc_backends_bit_identical() {
        let mut rng = Prng::new(0x5ACC);
        let s = randf(&mut rng, 7, 13); // ragged: neither dim lane-aligned
        let v = randf(&mut rng, 13, 19);
        let run = |bk: Backend| {
            let mut m = vec![-1e30f32; 7];
            let mut l = vec![0.0f32; 7];
            let mut acc = randf(&mut Prng::new(9), 7, 19);
            fused_softmax_acc_bk(&s, &v, &mut m, &mut l, &mut acc, bk);
            (m, l, acc)
        };
        let (ms, ls, accs) = run(Backend::Scalar);
        let (mv, lv, accv) = run(simd::detect());
        assert_eq!(ms, mv);
        assert_eq!(ls, lv);
        assert_eq!(accs.data, accv.data);
    }

    #[test]
    fn int8_dot_bt_slices_match_mat_form() {
        let mut rng = Prng::new(6);
        let a = randi(&mut rng, 12, 40);
        let bt = randi(&mut rng, 9, 40);
        let mut out = vec![0i32; 12 * 9];
        int8_dot_bt(&a.data, &bt.data, 12, 9, 40, 8, &mut out);
        assert_eq!(out, int8_matmul_bt(&a, &bt));
    }

    #[test]
    fn ctx_kernels_delegate() {
        let ctx = KernelCtx::single_threaded();
        let mut rng = Prng::new(7);
        let a = randf(&mut rng, 5, 9);
        let b = randf(&mut rng, 9, 4);
        assert_eq!(ctx.matmul(&a, &b), ops::matmul(&a, &b));
        let qa = randi(&mut rng, 6, 20);
        let qb = randi(&mut rng, 20, 5);
        let deq = ctx.int8_matmul_deq(&qa, 0.5, &qb, 0.25);
        let oracle = crate::quant::int8_matmul_deq(&qa, 0.5, &qb, 0.25);
        assert_eq!(deq, oracle);
    }

    #[test]
    fn ctx_carries_env_backend_and_forced_backend() {
        let ctx = KernelCtx::single_threaded();
        assert_eq!(ctx.backend, simd::active());
        let forced = ctx.clone().with_backend(Backend::Scalar);
        assert_eq!(forced.backend, Backend::Scalar);
        // want-cap preserves the forced backend and tile
        let capped = forced.with_want_cap(2);
        assert_eq!(capped.backend, Backend::Scalar);
        assert_eq!(capped.tile, forced.tile);
    }

    #[test]
    fn tuned_ctx_plans_from_profile_and_stays_bit_identical() {
        let mut prof = TuneProfile::default();
        let shape = ShapeClass::new(OpClass::Int8Matmul, 6, 5, 20);
        prof.entries.insert(shape.key(), tune::TuneChoice { tile: 8, vector: false, ns: 1.0 });
        let untuned = KernelCtx::single_threaded().with_tune(None);
        let tuned = untuned.clone().with_tune(Some(Arc::new(prof)));
        // profile hit: tuned tile, vector=false forces scalar
        assert_eq!(tuned.plan(OpClass::Int8Matmul, 6, 5, 20), (8, Backend::Scalar));
        // miss: ctx defaults pass through
        assert_eq!(tuned.plan(OpClass::MatmulF32, 6, 5, 20), (tuned.tile, tuned.backend));
        assert_eq!(untuned.plan(OpClass::Int8Matmul, 6, 5, 20), (untuned.tile, untuned.backend));
        // the tuned choice changes nothing but speed
        let mut rng = Prng::new(11);
        let qa = randi(&mut rng, 6, 20);
        let qb = randi(&mut rng, 20, 5);
        assert_eq!(
            tuned.int8_matmul_deq(&qa, 0.5, &qb, 0.25),
            untuned.int8_matmul_deq(&qa, 0.5, &qb, 0.25)
        );
        // labels: untuned is always "off"; the injected label depends on
        // the process env (FASTP_AUTOTUNE may be set on CI legs), so only
        // pin that it is not "off"
        assert_eq!(untuned.tune_label(), "off");
        assert_ne!(tuned.tune_label(), "off");
    }

    #[test]
    fn tile_override_validation() {
        assert_eq!(parse_tile_override("64"), Ok(64));
        assert_eq!(parse_tile_override(" 8 "), Ok(8));
        assert_eq!(parse_tile_override("1024"), Ok(1024));
        assert!(parse_tile_override("0").is_err(), "zero tile must be rejected");
        assert!(parse_tile_override("12").is_err(), "non-multiple-of-8 must be rejected");
        assert!(parse_tile_override("-8").is_err());
        assert!(parse_tile_override("sixty four").is_err());
        // the env-resolved tile is always a valid edge
        let t = env_tile();
        assert!(t > 0 && t % 8 == 0);
    }
}
