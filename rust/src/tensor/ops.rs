//! Dense reference math: matmul, softmax, RMSNorm, RoPE, SiLU.
//!
//! These mirror `python/compile/kernels/ref.py` definition-for-definition;
//! runtime_integration tests assert that running the AOT artifacts through
//! PJRT reproduces these (so Rust, JAX and the Pallas kernels agree).

use super::simd::Backend;
use super::MatF32;

/// C[M,N] = A[M,K] @ B[K,N] (f32).
pub fn matmul(a: &MatF32, b: &MatF32) -> MatF32 {
    assert_eq!(a.cols, b.rows, "matmul dims");
    let mut out = MatF32::zeros(a.rows, b.cols);
    for i in 0..a.rows {
        let arow = a.row(i);
        let orow = out.row_mut(i);
        for (k, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = b.row(k);
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
    out
}

/// C[M,N] = A[M,K] @ B^T where B is [N,K] (row-major dot of rows).
pub fn matmul_bt(a: &MatF32, b: &MatF32) -> MatF32 {
    assert_eq!(a.cols, b.cols, "matmul_bt dims");
    let mut out = MatF32::zeros(a.rows, b.rows);
    for i in 0..a.rows {
        let arow = a.row(i);
        for j in 0..b.rows {
            let brow = b.row(j);
            let mut s = 0.0f32;
            for (x, y) in arow.iter().zip(brow) {
                s += x * y;
            }
            *out.at_mut(i, j) = s;
        }
    }
    out
}

/// In-place row-wise softmax. A fully masked row (all `-inf`, as produced
/// by an empty sparse index list) yields a zero row rather than NaN.
pub fn softmax_rows(m: &mut MatF32) {
    for r in 0..m.rows {
        let row = m.row_mut(r);
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        if mx == f32::NEG_INFINITY {
            row.fill(0.0);
            continue;
        }
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            sum += *v;
        }
        let inv = 1.0 / sum.max(1e-30);
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// Softmax of a vector (out-of-place). Fully masked input (all `-inf`)
/// yields all zeros rather than NaN.
pub fn softmax(v: &[f32]) -> Vec<f32> {
    let mx = v.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    if mx == f32::NEG_INFINITY {
        return vec![0.0; v.len()];
    }
    let exps: Vec<f32> = v.iter().map(|x| (x - mx).exp()).collect();
    let sum: f32 = exps.iter().sum();
    exps.iter().map(|e| e / sum.max(1e-30)).collect()
}

/// RMSNorm: x * rsqrt(mean(x^2) + eps) * g, row-wise.
pub fn rmsnorm(x: &MatF32, g: &[f32], eps: f32) -> MatF32 {
    assert_eq!(x.cols, g.len());
    let mut out = MatF32::zeros(x.rows, x.cols);
    for r in 0..x.rows {
        let row = x.row(r);
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / x.cols as f32;
        let inv = 1.0 / (ms + eps).sqrt();
        for (o, (&v, &gv)) in out.row_mut(r).iter_mut().zip(row.iter().zip(g)) {
            *o = v * inv * gv;
        }
    }
    out
}

/// [`rmsnorm`] with the per-element apply dispatched to an explicit
/// micro-kernel backend. The Σv² row reduction and the rsqrt stay
/// scalar (sequential rounding order); only the independent
/// `v * inv * g` lanes vectorize, so every backend is bit-identical to
/// [`rmsnorm`] (pinned by `tests/simd_kernels.rs`).
pub fn rmsnorm_bk(x: &MatF32, g: &[f32], eps: f32, bk: Backend) -> MatF32 {
    assert_eq!(x.cols, g.len());
    let mut out = MatF32::zeros(x.rows, x.cols);
    for r in 0..x.rows {
        let row = x.row(r);
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / x.cols as f32;
        let inv = 1.0 / (ms + eps).sqrt();
        bk.f32_rms_apply(out.row_mut(r), row, g, inv);
    }
    out
}

/// Llama-style RoPE (half-rotation pairing), matching `ref.rope_ref`.
/// x: [T, dh] for one head; pos[t] = absolute position of row t.
pub fn rope(x: &mut MatF32, pos: &[i32], theta: f32) {
    let dh = x.cols;
    let half = dh / 2;
    assert_eq!(pos.len(), x.rows);
    for t in 0..x.rows {
        let p = pos[t] as f32;
        let row = x.row_mut(t);
        for i in 0..half {
            let freq = 1.0 / theta.powf(i as f32 / half as f32);
            let ang = p * freq;
            let (sin, cos) = ang.sin_cos();
            let x1 = row[i];
            let x2 = row[half + i];
            row[i] = x1 * cos - x2 * sin;
            row[half + i] = x1 * sin + x2 * cos;
        }
    }
}

/// [`rope`] with the pair rotation dispatched to an explicit backend.
/// The per-pair frequencies and sin/cos stay scalar per element (the
/// transcendentals have no bit-exactness contract across vector math
/// libraries, so they never vectorize — see DESIGN.md); only the
/// independent `(x1, x2)` rotations go wide. The frequency table is
/// hoisted out of the row loop — it does not depend on `t`, so the
/// hoisted values are the exact f32s the oracle recomputes per row —
/// making every backend bit-identical to [`rope`] (proptest-pinned).
pub fn rope_bk(x: &mut MatF32, pos: &[i32], theta: f32, bk: Backend) {
    let dh = x.cols;
    let half = dh / 2;
    assert_eq!(pos.len(), x.rows);
    let freqs: Vec<f32> =
        (0..half).map(|i| 1.0 / theta.powf(i as f32 / half as f32)).collect();
    let mut sin = vec![0.0f32; half];
    let mut cos = vec![0.0f32; half];
    for t in 0..x.rows {
        let p = pos[t] as f32;
        for i in 0..half {
            let (s, c) = (p * freqs[i]).sin_cos();
            sin[i] = s;
            cos[i] = c;
        }
        // odd dh: the oracle never touches row[dh-1]; neither does the
        // 2*half-long slice
        bk.f32_rope_rotate(&mut x.row_mut(t)[..2 * half], &sin, &cos);
    }
}

/// SiLU (x * sigmoid(x)) elementwise.
pub fn silu(x: &mut MatF32) {
    for v in x.data.iter_mut() {
        *v = *v / (1.0 + (-*v).exp()) * 1.0 + 0.0; // x*sigmoid(x)
    }
}

/// Mean-pool rows within fixed-size blocks: [S, d] -> [S/bs, d].
pub fn block_pool(x: &MatF32, bs: usize) -> MatF32 {
    assert_eq!(x.rows % bs, 0, "block_pool rows {} % {}", x.rows, bs);
    let nb = x.rows / bs;
    let mut out = MatF32::zeros(nb, x.cols);
    for b in 0..nb {
        for r in 0..bs {
            let row = x.row(b * bs + r);
            for (o, &v) in out.row_mut(b).iter_mut().zip(row) {
                *o += v;
            }
        }
        let inv = 1.0 / bs as f32;
        for o in out.row_mut(b) {
            *o *= inv;
        }
    }
    out
}

/// Jensen-Shannon divergence (natural log), matching `ref.jsd_ref`.
pub fn jsd(p: &[f32], q: &[f32]) -> f32 {
    assert_eq!(p.len(), q.len());
    const EPS: f32 = 1e-12;
    let ps: f32 = p.iter().sum::<f32>().max(EPS);
    let qs: f32 = q.iter().sum::<f32>().max(EPS);
    let mut acc = 0.0f64;
    for (&pi, &qi) in p.iter().zip(q) {
        let a = pi / ps;
        let b = qi / qs;
        let m = 0.5 * (a + b);
        if a > EPS {
            acc += 0.5 * (a as f64) * (((a + EPS) / (m + EPS)) as f64).ln();
        }
        if b > EPS {
            acc += 0.5 * (b as f64) * (((b + EPS) / (m + EPS)) as f64).ln();
        }
    }
    acc as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    fn randm(rng: &mut Prng, r: usize, c: usize) -> MatF32 {
        MatF32::from_fn(r, c, |_, _| rng.normal())
    }

    #[test]
    fn matmul_identity() {
        let a = MatF32::from_fn(3, 3, |r, c| if r == c { 1.0 } else { 0.0 });
        let b = MatF32::from_fn(3, 2, |r, c| (r * 2 + c) as f32);
        assert_eq!(matmul(&a, &b), b);
    }

    #[test]
    fn matmul_bt_equals_matmul_of_transpose() {
        let mut rng = Prng::new(1);
        let a = randm(&mut rng, 4, 6);
        let b = randm(&mut rng, 5, 6);
        let direct = matmul_bt(&a, &b);
        let via_t = matmul(&a, &b.transpose());
        for (x, y) in direct.data.iter().zip(&via_t.data) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut rng = Prng::new(2);
        let mut m = randm(&mut rng, 5, 7);
        softmax_rows(&mut m);
        for r in 0..5 {
            let s: f32 = m.row(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(m.row(r).iter().all(|&v| v >= 0.0));
        }
    }

    #[test]
    fn softmax_invariant_to_shift() {
        let a = softmax(&[1.0, 2.0, 3.0]);
        let b = softmax(&[101.0, 102.0, 103.0]);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn softmax_fully_masked_rows_are_zero_not_nan() {
        let neg = f32::NEG_INFINITY;
        let mut m = MatF32::from_vec(2, 3, vec![neg, neg, neg, 1.0, 2.0, neg]);
        softmax_rows(&mut m);
        assert_eq!(m.row(0), &[0.0, 0.0, 0.0]);
        let s1: f32 = m.row(1).iter().sum();
        assert!((s1 - 1.0).abs() < 1e-6);
        assert!(m.data.iter().all(|v| v.is_finite()));

        let v = softmax(&[neg, neg]);
        assert_eq!(v, vec![0.0, 0.0]);
    }

    #[test]
    fn rmsnorm_unit_gain_norm() {
        let x = MatF32::from_vec(1, 4, vec![2.0, 2.0, 2.0, 2.0]);
        let g = vec![1.0; 4];
        let out = rmsnorm(&x, &g, 0.0);
        for v in &out.data {
            assert!((v - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn rope_preserves_norm() {
        let mut rng = Prng::new(3);
        let mut x = randm(&mut rng, 4, 64);
        let orig: Vec<f32> = x.data.iter().map(|v| v * v).collect();
        let norm0: f32 = orig.iter().sum();
        rope(&mut x, &[0, 100, 2000, 50000], 10000.0);
        let norm1: f32 = x.data.iter().map(|v| v * v).sum();
        assert!((norm0 - norm1).abs() / norm0 < 1e-4);
    }

    #[test]
    fn rope_position_zero_is_identity() {
        let mut rng = Prng::new(4);
        let x0 = randm(&mut rng, 1, 8);
        let mut x = x0.clone();
        rope(&mut x, &[0], 10000.0);
        for (a, b) in x.data.iter().zip(&x0.data) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn silu_known_values() {
        let mut x = MatF32::from_vec(1, 2, vec![0.0, 10.0]);
        silu(&mut x);
        assert!(x.data[0].abs() < 1e-6);
        assert!((x.data[1] - 10.0).abs() < 1e-3);
    }

    #[test]
    fn block_pool_means() {
        let x = MatF32::from_fn(4, 2, |r, _| r as f32);
        let p = block_pool(&x, 2);
        assert_eq!(p.rows, 2);
        assert_eq!(p.at(0, 0), 0.5);
        assert_eq!(p.at(1, 0), 2.5);
    }

    #[test]
    fn jsd_bounds_and_symmetry() {
        let p = [1.0, 0.0, 0.0];
        let q = [0.0, 1.0, 0.0];
        let d = jsd(&p, &q);
        assert!((d - std::f32::consts::LN_2).abs() < 1e-4);
        assert!((jsd(&p, &q) - jsd(&q, &p)).abs() < 1e-6);
        assert!(jsd(&p, &p) < 1e-7);
    }
}
