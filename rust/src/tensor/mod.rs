//! Minimal dense tensor substrate (row-major f32 / i8 matrices).
//!
//! This is the pure-Rust oracle used by the accuracy harness (Table III),
//! the integration tests that validate the PJRT artifacts, and the
//! FlexPrefill reference implementation. It is deliberately simple and
//! allocation-transparent. The scalar kernels in [`ops`] are the bit-level
//! oracle; the performance path is the cache-blocked kernel layer in
//! [`tile`], driven by the shared worker pool (`util::pool`) with inner
//! loops dispatched through the runtime-selected SIMD backend ([`simd`]).

pub mod ops;
pub mod simd;
pub mod tile;
pub mod tune;

/// Row-major f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct MatF32 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl MatF32 {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        MatF32 { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(rows * cols, data.len());
        MatF32 { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        MatF32 { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Copy rows [r0, r1) into a new matrix.
    pub fn slice_rows(&self, r0: usize, r1: usize) -> MatF32 {
        assert!(r0 <= r1 && r1 <= self.rows);
        MatF32 {
            rows: r1 - r0,
            cols: self.cols,
            data: self.data[r0 * self.cols..r1 * self.cols].to_vec(),
        }
    }

    pub fn transpose(&self) -> MatF32 {
        let mut out = MatF32::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.at(r, c);
            }
        }
        out
    }
}

/// Row-major i8 matrix (quantized tensors; always paired with an f32 scale).
#[derive(Clone, Debug, PartialEq)]
pub struct MatI8 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<i8>,
}

impl MatI8 {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        MatI8 { rows, cols, data: vec![0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<i8>) -> Self {
        assert_eq!(rows * cols, data.len());
        MatI8 { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> i8 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[i8] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn slice_rows(&self, r0: usize, r1: usize) -> MatI8 {
        assert!(r0 <= r1 && r1 <= self.rows);
        MatI8 {
            rows: r1 - r0,
            cols: self.cols,
            data: self.data[r0 * self.cols..r1 * self.cols].to_vec(),
        }
    }

    pub fn transpose(&self) -> MatI8 {
        let mut out = MatI8::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.at(r, c);
            }
        }
        out
    }

    /// Dequantize with a symmetric scale.
    pub fn dequant(&self, scale: f32) -> MatF32 {
        MatF32 {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&q| q as f32 * scale).collect(),
        }
    }
}

/// A quantized tensor: int8 payload + per-tensor symmetric scale.
#[derive(Clone, Debug)]
pub struct QTensor {
    pub q: MatI8,
    pub scale: f32,
}

impl QTensor {
    pub fn dequant(&self) -> MatF32 {
        self.q.dequant(self.scale)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn at_and_row_consistent() {
        let m = MatF32::from_fn(3, 4, |r, c| (r * 10 + c) as f32);
        assert_eq!(m.at(1, 2), 12.0);
        assert_eq!(m.row(2), &[20.0, 21.0, 22.0, 23.0]);
    }

    #[test]
    fn transpose_involution() {
        let m = MatF32::from_fn(3, 5, |r, c| (r * 5 + c) as f32);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn slice_rows_block() {
        let m = MatF32::from_fn(4, 2, |r, _| r as f32);
        let s = m.slice_rows(1, 3);
        assert_eq!(s.rows, 2);
        assert_eq!(s.at(0, 0), 1.0);
        assert_eq!(s.at(1, 1), 2.0);
    }

    #[test]
    fn dequant_scales() {
        let q = MatI8::from_vec(1, 3, vec![-127, 0, 127]);
        let f = q.dequant(0.5);
        assert_eq!(f.data, vec![-63.5, 0.0, 63.5]);
    }

    #[test]
    #[should_panic]
    fn from_vec_len_checked() {
        MatF32::from_vec(2, 2, vec![0.0; 3]);
    }
}
