//! Nibble (4-bit) partitioned int8 multiplication — paper Eq. (7)-(8).
//!
//! a.b = aL*bL + (aH*bL + aL*bH) << 4 + (aH*bH) << 8
//!
//! Each term is an INT4xINT4 product implementable as a small LUT ROM
//! (256-entry). The paper uses this to cut the bit-plane PE's latency and
//! LUT count; we prove exact equivalence with direct multiplication and
//! export the LUT-cost constants for the resource model.

/// Split a signed i8 into (high, low) nibbles such that
/// `v == high * 16 + low` with `low` in [0, 15] (unsigned) and `high` in
/// [-8, 7] (signed) — the usual radix-16 signed-digit split.
#[inline]
pub fn split_nibbles(v: i8) -> (i32, i32) {
    let low = (v as i32) & 0xF;
    let high = (v as i32) >> 4; // arithmetic shift keeps the sign
    (high, low)
}

/// Exact int8 multiply via nibble partitioning (Eq. 8).
pub fn mul_nibble(a: i8, b: i8) -> i32 {
    let (ah, al) = split_nibbles(a);
    let (bh, bl) = split_nibbles(b);
    // each term is a product of values in [-8,15] — an INT4xINT4-class LUT
    al * bl + ((ah * bl + al * bh) << 4) + ((ah * bh) << 8)
}

/// Dot product via nibble PEs.
pub fn dot_nibble(a: &[i8], b: &[i8]) -> i32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| mul_nibble(x, y)).sum()
}

/// LUT cost of one nibble-partitioned PE: four INT4 products (LUT6-based,
/// ~11 LUTs each) + shift-add tree (~12 LUTs of carry chain) ≈ 56 LUTs —
/// the paper's motivation for preferring nibbles over raw bit-planes.
pub const LUTS_PER_NIBBLE_PE: usize = 56;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn split_reassembles() {
        for v in i8::MIN..=i8::MAX {
            let (h, l) = split_nibbles(v);
            assert_eq!(h * 16 + l, v as i32, "v={v}");
            assert!((0..16).contains(&l));
            assert!((-8..8).contains(&h));
        }
    }

    #[test]
    fn matches_direct_full_exhaustive() {
        for a in i8::MIN..=i8::MAX {
            for b in i8::MIN..=i8::MAX {
                assert_eq!(mul_nibble(a, b), a as i32 * b as i32, "{a}*{b}");
            }
        }
    }

    #[test]
    fn nibble_equals_bitplane() {
        // the two decompositions are interchangeable in the MPU
        for a in (-127i8..=127).step_by(7) {
            for b in (-127i8..=127).step_by(11) {
                assert_eq!(mul_nibble(a, b), super::super::bitplane::mul_bitplane(a, b));
            }
        }
    }

    #[test]
    fn prop_dot_matches_direct() {
        forall(
            13,
            50,
            |rng, size| {
                let n = 1 + size * 2;
                let a: Vec<i8> = (0..n).map(|_| rng.i8_sym()).collect();
                let b: Vec<i8> = (0..n).map(|_| rng.i8_sym()).collect();
                (a, b)
            },
            |(a, b)| {
                let direct: i32 = a.iter().zip(b.iter()).map(|(&x, &y)| x as i32 * y as i32).sum();
                dot_nibble(a, b) == direct
            },
        );
    }

    #[test]
    fn nibble_pe_cheaper_than_bitplane_pe() {
        assert!(LUTS_PER_NIBBLE_PE < super::super::bitplane::LUTS_PER_BITPLANE_PE);
    }
}
