//! Bit-plane decomposition of int8 multiplication — paper Eq. (5)-(6).
//!
//! An 8-bit product is a sum of AND-gated bit partial products:
//!     a.b = sum_{i,j} (a_i AND b_j) << (i+j)
//! which maps onto LUT logic. For signed operands we use the standard
//! sign-magnitude factorization (the hardware handles sign in the
//! accumulator): a*b = sign(a)*sign(b) * (|a|*|b|), with |a|,|b| in [0,127]
//! so 7 bit-planes suffice.
//!
//! These functions exist to *prove the arithmetic claim* (exact equivalence
//! with direct multiplication) and to parameterize the MPU cycle model
//! (`sim::mpu`): a bit-plane PE consumes 7x7 AND+shift+add trees' worth of
//! LUTs instead of a DSP48.

/// Exact int8 multiply via bit-plane decomposition (Eq. 6).
pub fn mul_bitplane(a: i8, b: i8) -> i32 {
    let sign = ((a as i32) < 0) ^ ((b as i32) < 0);
    let ua = (a as i32).unsigned_abs();
    let ub = (b as i32).unsigned_abs();
    let mut acc: u32 = 0;
    for i in 0..8 {
        if (ua >> i) & 1 == 0 {
            continue;
        }
        for j in 0..8 {
            if (ub >> j) & 1 == 1 {
                acc += 1u32 << (i + j);
            }
        }
    }
    if sign {
        -(acc as i32)
    } else {
        acc as i32
    }
}

/// Dot product via bit-plane PEs (what one LUT systolic-array lane computes).
pub fn dot_bitplane(a: &[i8], b: &[i8]) -> i32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| mul_bitplane(x, y)).sum()
}

/// LUT cost estimate of one bit-plane PE (AND array + carry-chain adders).
/// 7x7 AND terms, compressor tree of ~49 partial bits, ~14-bit accumulate:
/// empirically ~75 LUTs per PE in the paper's generation of fabric; the
/// resource model (Table II) uses this constant.
pub const LUTS_PER_BITPLANE_PE: usize = 75;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall_ck;

    #[test]
    fn matches_direct_exhaustive_corners() {
        for &a in &[-128i8, -127, -1, 0, 1, 63, 127] {
            for &b in &[-128i8, -127, -1, 0, 1, 63, 127] {
                assert_eq!(mul_bitplane(a, b), a as i32 * b as i32, "{a}*{b}");
            }
        }
    }

    #[test]
    fn matches_direct_full_exhaustive() {
        // 65536 products — cheap enough to check the entire space.
        for a in i8::MIN..=i8::MAX {
            for b in i8::MIN..=i8::MAX {
                assert_eq!(mul_bitplane(a, b), a as i32 * b as i32);
            }
        }
    }

    #[test]
    fn prop_dot_matches_direct() {
        forall_ck(
            11,
            50,
            |rng, size| {
                let n = 1 + size;
                let a: Vec<i8> = (0..n).map(|_| rng.i8_sym()).collect();
                let b: Vec<i8> = (0..n).map(|_| rng.i8_sym()).collect();
                (a, b)
            },
            |(a, b)| {
                let direct: i32 = a.iter().zip(b).map(|(&x, &y)| x as i32 * y as i32).sum();
                if dot_bitplane(a, b) == direct {
                    Ok(())
                } else {
                    Err(format!("got {} want {}", dot_bitplane(a, b), direct))
                }
            },
        );
    }
}
