//! W8A8 quantization + the Hybrid MPU's arithmetic decompositions (§IV-D).
//!
//! `quantize_sym` / `int8_matmul` implement the repo-wide W8A8 contract
//! (identical to `ref.py`). `bitplane` and `nibble` implement the paper's
//! LUT-based multiplier decompositions — Eq. (5)-(8) — and are proven
//! exactly equal to direct int8 multiplication by unit + property tests.
//! The simulator's MPU model uses their cost characteristics; the functional
//! path uses the direct form (same numbers by the equivalence proof).

pub mod bitplane;
pub mod nibble;

use crate::tensor::simd::Backend;
use crate::tensor::{MatF32, MatI8, QTensor};

/// Scale floor, matching `ref.SCALE_EPS`.
pub const SCALE_EPS: f32 = 1e-8;

/// Symmetric per-tensor scale: max|x| / 127, floored.
pub fn quant_scale(data: &[f32]) -> f32 {
    let mx = data.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
    mx.max(SCALE_EPS) / 127.0
}

/// Quantize to int8 with a given scale (round-half-away like jnp.round?
/// jnp.round is round-half-even; we match it exactly).
#[inline]
pub fn quantize_one(x: f32, scale: f32) -> i8 {
    let v = x / scale;
    // f32::round_ties_even matches jnp.round (banker's rounding).
    let r = v.round_ties_even();
    r.clamp(-127.0, 127.0) as i8
}

/// Quantize a matrix symmetrically (per-tensor scale).
pub fn quantize_mat(x: &MatF32) -> QTensor {
    let (q, scale) = quantize_m(x);
    QTensor { q, scale }
}

/// The one shared scale-then-quantize helper: per-tensor symmetric scale
/// plus elementwise [`quantize_one`] over a whole matrix. `quantize_mat`,
/// the model forward pass and the accuracy harness all route through
/// this pair, so the SIMD path ([`quantize_m_bk`]) has a single oracle
/// to match.
pub fn quantize_m(m: &MatF32) -> (MatI8, f32) {
    quantize_m_bk(m, Backend::Scalar)
}

/// [`quantize_m`] with the elementwise sweep dispatched to an explicit
/// micro-kernel backend — bit-identical on every backend (see the
/// `tensor::simd` contract; pinned by `tests/simd_kernels.rs`).
pub fn quantize_m_bk(m: &MatF32, bk: Backend) -> (MatI8, f32) {
    let scale = quant_scale(&m.data);
    let mut q = MatI8::zeros(m.rows, m.cols);
    bk.i8_quantize(&mut q.data, &m.data, scale);
    (q, scale)
}

/// Quantize a slice with an externally chosen scale.
pub fn quantize_with(x: &[f32], scale: f32, out: &mut [i8]) {
    for (o, &v) in out.iter_mut().zip(x) {
        *o = quantize_one(v, scale);
    }
}

/// [`quantize_with`] on an explicit micro-kernel backend (bit-identical
/// to the scalar loop on every backend).
pub fn quantize_with_bk(x: &[f32], scale: f32, out: &mut [i8], bk: Backend) {
    bk.i8_quantize(out, x, scale);
}

/// Exact W8A8 matmul: C_i32[M,N] = A_i8[M,K] @ B_i8[K,N].
pub fn int8_matmul(a: &MatI8, b: &MatI8) -> Vec<i32> {
    assert_eq!(a.cols, b.rows, "int8_matmul dims");
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut out = vec![0i32; m * n];
    for i in 0..m {
        let arow = a.row(i);
        let orow = &mut out[i * n..(i + 1) * n];
        for kk in 0..k {
            let av = arow[kk] as i32;
            if av == 0 {
                continue;
            }
            let brow = b.row(kk);
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv as i32;
            }
        }
    }
    out
}

/// W8A8 matmul where B is given transposed (B^T is [N,K] row-major) — the
/// score-tile shape (Q @ K^T). Much better locality than `int8_matmul`.
pub fn int8_matmul_bt(a: &MatI8, bt: &MatI8) -> Vec<i32> {
    assert_eq!(a.cols, bt.cols, "int8_matmul_bt dims");
    let (m, n) = (a.rows, bt.rows);
    let mut out = vec![0i32; m * n];
    for i in 0..m {
        let arow = a.row(i);
        for j in 0..n {
            let brow = bt.row(j);
            let mut s = 0i32;
            for (&x, &y) in arow.iter().zip(brow) {
                s += x as i32 * y as i32;
            }
            out[i * n + j] = s;
        }
    }
    out
}

/// Dequantized W8A8 matmul: f32 = (A @ B) * sa * sb.
pub fn int8_matmul_deq(a: &MatI8, sa: f32, b: &MatI8, sb: f32) -> MatF32 {
    let acc = int8_matmul(a, b);
    let s = sa * sb;
    MatF32 {
        rows: a.rows,
        cols: b.cols,
        data: acc.iter().map(|&v| v as f32 * s).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;
    use crate::util::prop::forall_ck;

    fn rand_i8_mat(rng: &mut Prng, r: usize, c: usize) -> MatI8 {
        MatI8 { rows: r, cols: c, data: (0..r * c).map(|_| rng.i8_sym()).collect() }
    }

    #[test]
    fn quant_scale_floor() {
        assert!(quant_scale(&[0.0, 0.0]) > 0.0);
    }

    #[test]
    fn quantize_saturates() {
        assert_eq!(quantize_one(1e9, 1.0), 127);
        assert_eq!(quantize_one(-1e9, 1.0), -127);
    }

    #[test]
    fn quantize_round_ties_even() {
        // 0.5/1.0 rounds to 0 (ties-to-even), 1.5 rounds to 2 — jnp.round.
        assert_eq!(quantize_one(0.5, 1.0), 0);
        assert_eq!(quantize_one(1.5, 1.0), 2);
        assert_eq!(quantize_one(-0.5, 1.0), 0);
    }

    #[test]
    fn roundtrip_error_bounded_by_half_scale() {
        let mut rng = Prng::new(5);
        let x = MatF32::from_fn(16, 16, |_, _| rng.normal() * 3.0);
        let qt = quantize_mat(&x);
        let back = qt.dequant();
        for (a, b) in x.data.iter().zip(&back.data) {
            // values beyond +/-127*scale saturate; inside, error <= scale/2
            if a.abs() <= 127.0 * qt.scale {
                assert!((a - b).abs() <= qt.scale * 0.5 + 1e-6);
            }
        }
    }

    #[test]
    fn int8_matmul_small_known() {
        let a = MatI8::from_vec(2, 2, vec![1, 2, 3, 4]);
        let b = MatI8::from_vec(2, 2, vec![5, 6, 7, 8]);
        assert_eq!(int8_matmul(&a, &b), vec![19, 22, 43, 50]);
    }

    #[test]
    fn int8_matmul_bt_matches_plain() {
        let mut rng = Prng::new(6);
        let a = rand_i8_mat(&mut rng, 8, 16);
        let b = rand_i8_mat(&mut rng, 16, 12);
        let plain = int8_matmul(&a, &b);
        let bt = int8_matmul_bt(&a, &b.transpose());
        assert_eq!(plain, bt);
    }

    #[test]
    fn int8_matmul_no_overflow_at_k2304() {
        // max-magnitude accumulation fits i32 for our K ranges
        let a = MatI8 { rows: 1, cols: 2304, data: vec![127; 2304] };
        let b = MatI8 { rows: 2304, cols: 1, data: vec![127; 2304] };
        assert_eq!(int8_matmul(&a, &b)[0], 127 * 127 * 2304);
    }

    #[test]
    fn prop_matmul_bt_equivalence() {
        forall_ck(
            7,
            30,
            |rng, size| {
                let m = 1 + size % 8;
                let k = 1 + size % 32;
                let n = 1 + size % 8;
                (rand_i8_mat(rng, m, k), rand_i8_mat(rng, k, n))
            },
            |(a, b)| {
                if int8_matmul(a, b) == int8_matmul_bt(a, &b.transpose()) {
                    Ok(())
                } else {
                    Err("bt mismatch".into())
                }
            },
        );
    }
}
