//! Accelerator platform configurations — Table I of the paper, plus the
//! FAST-Prefill microarchitecture parameters (§IV) used by the simulator.

/// Alveo U280 platform + FAST-Prefill design point (paper Table I, §IV-D).
#[derive(Clone, Debug)]
pub struct FpgaConfig {
    pub name: &'static str,
    /// Achieved clock (paper: 175 MHz).
    pub freq_mhz: f64,
    /// DSP48 slices available / used budget.
    pub dsp_total: usize,
    pub lut_total_k: usize,
    pub ff_total_k: usize,
    pub bram_total: usize,
    pub uram_total: usize,
    /// HBM: 8 GB, 460 GB/s over 32 pseudo-channels.
    pub hbm_gb: f64,
    pub hbm_bw_gbs: f64,
    pub hbm_channels: usize,
    /// DDR: 32 GB, 38 GB/s.
    pub ddr_gb: f64,
    pub ddr_bw_gbs: f64,
    /// Hybrid MPU: NxN systolic arrays (paper: six DSP + six LUT, 32x32).
    pub mpu_array_dim: usize,
    pub mpu_dsp_arrays: usize,
    pub mpu_lut_arrays: usize,
    /// Liveness cache capacity in bytes (paper ablation: 16 MB URAM).
    pub kv_cache_bytes: usize,
    /// Hot-tier fraction of the cache.
    pub hot_fraction: f64,
    /// T_hot admission threshold as a fraction of total query blocks
    /// (paper: 50%).
    pub t_hot_frac: f64,
    /// Prefetch FSM lookahead window (KV blocks).
    pub prefetch_lookahead: usize,
    /// Board power draw at full activity (W) — U280 max TDP 225 W; achieved
    /// designs draw well under; the power model scales by resource activity.
    pub max_power_w: f64,
    pub idle_power_w: f64,
}

impl FpgaConfig {
    /// Peak INT8 MACs/cycle of the hybrid MPU (both array types).
    pub fn mpu_macs_per_cycle(&self) -> usize {
        let per_array = self.mpu_array_dim * self.mpu_array_dim;
        (self.mpu_dsp_arrays + self.mpu_lut_arrays) * per_array
    }
    /// Peak TOPS (2 ops per MAC).
    pub fn peak_tops(&self) -> f64 {
        2.0 * self.mpu_macs_per_cycle() as f64 * self.freq_mhz * 1e6 / 1e12
    }
    pub fn cycles_per_us(&self) -> f64 {
        self.freq_mhz
    }
}

/// FAST-Prefill on Alveo U280 (paper configuration).
pub fn u280_fast_prefill() -> FpgaConfig {
    FpgaConfig {
        name: "U280/FAST-Prefill",
        freq_mhz: 175.0,
        dsp_total: 9024,
        lut_total_k: 1304,
        ff_total_k: 2607,
        bram_total: 4032,
        uram_total: 960,
        hbm_gb: 8.0,
        hbm_bw_gbs: 460.0,
        hbm_channels: 32,
        ddr_gb: 32.0,
        ddr_bw_gbs: 38.0,
        mpu_array_dim: 32,
        mpu_dsp_arrays: 6,
        mpu_lut_arrays: 6,
        kv_cache_bytes: 16 << 20,
        hot_fraction: 0.5,
        t_hot_frac: 0.5,
        prefetch_lookahead: 8,
        max_power_w: 60.0,
        idle_power_w: 20.0,
    }
}

/// DSP-only ablation variant (Fig. 8): LUT arrays removed.
pub fn u280_dsp_only() -> FpgaConfig {
    FpgaConfig { name: "U280/DSP-only", mpu_lut_arrays: 0, ..u280_fast_prefill() }
}

/// Cacheless ablation variant (Fig. 7): every KV block fetch goes to HBM.
pub fn u280_cacheless() -> FpgaConfig {
    FpgaConfig { name: "U280/cacheless", kv_cache_bytes: 0, ..u280_fast_prefill() }
}

/// Nvidia RTX A5000 platform (paper Table I) for the baseline cost model.
#[derive(Clone, Debug)]
pub struct GpuConfig {
    pub name: &'static str,
    pub cuda_cores: usize,
    pub freq_mhz: f64,
    /// Dense INT8 tensor TOPS (paper Table I reports 222 TOPS).
    pub int8_tops: f64,
    /// FP16/BF16 tensor TFLOPS.
    pub fp16_tflops: f64,
    pub mem_gb: f64,
    pub mem_bw_gbs: f64,
    /// Board TDP (A5000: 230 W).
    pub tdp_w: f64,
    pub idle_power_w: f64,
    /// PCIe bandwidth for the CPU-offloaded index-selection round-trips the
    /// paper calls out (Gen4 x16 ~ 25 GB/s effective).
    pub pcie_gbs: f64,
    /// Achievable fraction of peak for the irregular sparse-attention
    /// kernels (empirical roofline derating; see gpu_model).
    pub sparse_eff: f64,
    /// Achievable fraction of peak memory bandwidth on gather-heavy access.
    pub gather_bw_eff: f64,
}

pub fn a5000() -> GpuConfig {
    GpuConfig {
        name: "A5000",
        cuda_cores: 8192,
        freq_mhz: 1695.0,
        int8_tops: 222.0,
        fp16_tflops: 111.0,
        mem_gb: 24.0,
        mem_bw_gbs: 768.0,
        tdp_w: 230.0,
        idle_power_w: 25.0,
        pcie_gbs: 25.0,
        sparse_eff: 0.08,
        gather_bw_eff: 0.25,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u280_peak_tops_matches_table1() {
        // Table I: 5.4 TOPS at 175 MHz. 12 arrays x 1024 MACs x 2 x 175e6 = 4.3;
        // the paper's 5.4 includes SFU/aux DSP work — accept the band.
        let t = u280_fast_prefill().peak_tops();
        assert!(t > 3.5 && t < 6.0, "tops {t}");
    }

    #[test]
    fn dsp_only_halves_mpu() {
        let full = u280_fast_prefill().mpu_macs_per_cycle();
        let dsp = u280_dsp_only().mpu_macs_per_cycle();
        assert_eq!(dsp * 2, full);
    }

    #[test]
    fn ablation_configs_differ_only_in_target_knob() {
        let base = u280_fast_prefill();
        let noc = u280_cacheless();
        assert_eq!(noc.mpu_dsp_arrays, base.mpu_dsp_arrays);
        assert_eq!(noc.kv_cache_bytes, 0);
    }

    #[test]
    fn a5000_matches_table1() {
        let g = a5000();
        assert_eq!(g.cuda_cores, 8192);
        assert_eq!(g.mem_bw_gbs, 768.0);
        assert_eq!(g.int8_tops, 222.0);
    }
}
