//! Model configurations — mirrors `python/compile/configs.py`.
//!
//! The functional configs (`tiny`, `small100m`) have AOT artifacts and run
//! end-to-end on the CPU PJRT backend. The paper-scale configs
//! (Llama-3.2-1B/3B, Qwen2.5-1B) drive the FPGA simulator and the GPU cost
//! model, where only shapes matter. The AOT manifest re-states the
//! functional configs' dimensions; `runtime::artifacts` cross-checks them at
//! load time so the two languages cannot silently drift.

/// Token block size B — both the chunked-prefill granularity and the
/// FlexPrefill block granularity (the paper sets both to 128).
pub const BLOCK: usize = 128;

#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: &'static str,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_head: usize,
    pub d_ffn: usize,
    pub n_layers: usize,
    pub vocab: usize,
    pub rope_theta: f32,
    pub rms_eps: f32,
}

impl ModelConfig {
    pub const fn q_dim(&self) -> usize {
        self.n_heads * self.d_head
    }
    pub const fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.d_head
    }
    /// GQA group size (query heads per KV head).
    pub const fn group_size(&self) -> usize {
        self.n_heads / self.n_kv_heads
    }
    /// Approximate weight parameter count.
    pub fn params(&self) -> usize {
        let attn = self.d_model * (self.q_dim() + 2 * self.kv_dim())
            + self.q_dim() * self.d_model;
        let ffn = 3 * self.d_model * self.d_ffn;
        let per_layer = attn + ffn + 2 * self.d_model;
        self.n_layers * per_layer + 2 * self.vocab * self.d_model + self.d_model
    }
    /// KV cache bytes for a context of `s` tokens (int8 K + V).
    pub fn kv_bytes(&self, s: usize) -> usize {
        2 * self.n_layers * self.kv_dim() * s
    }
    /// Bytes of one KV block for one kv head (int8 K + V) — the unit the
    /// liveness cache, the simulator's HBM pricing and the engine's
    /// per-request traffic attribution all account in.
    pub const fn kv_block_bytes(&self) -> usize {
        2 * BLOCK * self.d_head
    }
}

/// Functional config with AOT artifacts: 2-layer toy for tests.
pub const TINY: ModelConfig = ModelConfig {
    name: "tiny",
    d_model: 256,
    n_heads: 4,
    n_kv_heads: 2,
    d_head: 64,
    d_ffn: 768,
    n_layers: 2,
    vocab: 256,
    rope_theta: 10000.0,
    rms_eps: 1e-5,
};

/// Functional config with AOT artifacts: ~100M-param e2e driver model.
pub const SMALL100M: ModelConfig = ModelConfig {
    name: "small100m",
    d_model: 768,
    n_heads: 12,
    n_kv_heads: 4,
    d_head: 64,
    d_ffn: 2048,
    n_layers: 16,
    vocab: 256,
    rope_theta: 10000.0,
    rms_eps: 1e-5,
};

/// Paper model: Llama-3.2-1B-Instruct (architecture dims; weights are
/// seeded-random offline — see DESIGN.md substitutions).
pub const LLAMA32_1B: ModelConfig = ModelConfig {
    name: "llama3.2-1b",
    d_model: 2048,
    n_heads: 32,
    n_kv_heads: 8,
    d_head: 64,
    d_ffn: 8192,
    n_layers: 16,
    vocab: 128256,
    rope_theta: 500000.0,
    rms_eps: 1e-5,
};

/// Paper model: Llama-3.2-3B-Instruct.
pub const LLAMA32_3B: ModelConfig = ModelConfig {
    name: "llama3.2-3b",
    d_model: 3072,
    n_heads: 24,
    n_kv_heads: 8,
    d_head: 128,
    d_ffn: 8192,
    n_layers: 28,
    vocab: 128256,
    rope_theta: 500000.0,
    rms_eps: 1e-5,
};

/// Paper model: Qwen2.5-1.5B-Instruct (the paper's "Qwen2.5-1B").
pub const QWEN25_1B: ModelConfig = ModelConfig {
    name: "qwen2.5-1b",
    d_model: 1536,
    n_heads: 12,
    n_kv_heads: 2,
    d_head: 128,
    d_ffn: 8960,
    n_layers: 28,
    vocab: 151936,
    rope_theta: 1000000.0,
    rms_eps: 1e-6,
};

pub fn by_name(name: &str) -> Option<&'static ModelConfig> {
    match name {
        "tiny" => Some(&TINY),
        "small100m" => Some(&SMALL100M),
        "llama3.2-1b" => Some(&LLAMA32_1B),
        "llama3.2-3b" => Some(&LLAMA32_3B),
        "qwen2.5-1b" => Some(&QWEN25_1B),
        _ => None,
    }
}

/// Configs evaluated in the paper's figures.
pub fn paper_models() -> Vec<&'static ModelConfig> {
    vec![&LLAMA32_1B, &LLAMA32_3B, &QWEN25_1B]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gqa_divides() {
        for cfg in [&TINY, &SMALL100M, &LLAMA32_1B, &LLAMA32_3B, &QWEN25_1B] {
            assert_eq!(cfg.n_heads % cfg.n_kv_heads, 0, "{}", cfg.name);
        }
    }

    #[test]
    fn small100m_is_about_100m() {
        let p = SMALL100M.params();
        assert!(p > 80_000_000 && p < 130_000_000, "params {p}");
    }

    #[test]
    fn llama1b_params_order() {
        let p = LLAMA32_1B.params();
        // embedding-heavy, like the real model (~1.24B)
        assert!(p > 800_000_000 && p < 1_800_000_000, "params {p}");
    }

    #[test]
    fn kv_bytes_128k_is_gb_scale() {
        // paper: "large size of the KV cache (~3-4 GB)"
        let b = LLAMA32_3B.kv_bytes(128 * 1024);
        assert!(b > 2 << 30, "kv {b}");
    }

    #[test]
    fn by_name_roundtrip() {
        assert_eq!(by_name("tiny"), Some(&TINY));
        assert!(by_name("nope").is_none());
    }
}
