//! Configuration: model architectures, accelerator platforms, FlexPrefill
//! hyper-parameters, and run settings parsed from the CLI.

pub mod accel;
pub mod env;
pub mod model;

pub use accel::{a5000, u280_cacheless, u280_dsp_only, u280_fast_prefill, FpgaConfig, GpuConfig};
pub use model::{by_name, paper_models, ModelConfig, BLOCK, LLAMA32_1B, LLAMA32_3B, QWEN25_1B, SMALL100M, TINY};

/// FlexPrefill hyper-parameters (paper: tau = 0.1, gamma = 0.9).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FlexParams {
    /// JSD threshold selecting query-aware vs vertical-slash.
    pub tau: f32,
    /// Cumulative-attention coverage budget.
    pub gamma: f32,
    /// Force-include the diagonal (self) block for every query block so the
    /// softmax denominator is never empty. FlexPrefill's implementation does
    /// the same via its local window.
    pub force_diagonal: bool,
    /// Force-include block 0 (attention-sink behaviour).
    pub force_sink: bool,
}

impl Default for FlexParams {
    fn default() -> Self {
        FlexParams { tau: 0.1, gamma: 0.9, force_diagonal: true, force_sink: true }
    }
}

/// Context lengths evaluated in the paper's figures (tokens).
pub fn paper_context_lengths() -> Vec<usize> {
    vec![4 * 1024, 8 * 1024, 16 * 1024, 32 * 1024, 128 * 1024]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let p = FlexParams::default();
        assert_eq!(p.tau, 0.1);
        assert_eq!(p.gamma, 0.9);
    }

    #[test]
    fn paper_sweep_has_128k() {
        assert!(paper_context_lengths().contains(&(128 * 1024)));
    }
}
