//! One env-knob helper: every `FASTP_*` runtime override goes through
//! [`knob`] — read, parse/validate, **warn-and-default** on bad input.
//!
//! The parse functions stay next to the subsystems that own them
//! (`tensor::tile::parse_tile_override`, `tensor::tune::parse_autotune_mode`,
//! `coordinator::server::{parse_phase_batch,parse_prefill_chunk}`,
//! `util::pool::parse_threads`, `tensor::simd::resolve`) so each error
//! message names its variable and constraint; this module owns only the
//! read-validate-warn-default *shape*, so no knob can drift into
//! panicking or silently ignoring bad input.
//!
//! Knobs are typically resolved once per process behind a `OnceLock` at
//! the call site (env mutation mid-run must not flip kernel selection
//! under a running engine); [`knob`] itself is stateless and pure given
//! the environment, which is what the unit tests poke.

/// Read env var `name`; unset returns `default()`, a value that parses
/// returns it, and a value that fails `parse` warns on stderr and
/// returns `default()`. `parse` errors should name the variable and the
/// constraint (every `parse_*` in this crate does).
pub fn knob<T>(
    name: &str,
    parse: impl FnOnce(&str) -> Result<T, String>,
    default: impl FnOnce() -> T,
) -> T {
    match std::env::var(name) {
        Err(_) => default(),
        Ok(raw) => match parse(&raw) {
            Ok(v) => v,
            Err(e) => {
                eprintln!("warning: ignoring invalid {e}; using default");
                default()
            }
        },
    }
}

/// [`knob`] for the common case of a `Copy` default value.
pub fn knob_or<T: Copy>(name: &str, parse: impl FnOnce(&str) -> Result<T, String>, default: T) -> T {
    knob(name, parse, || default)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_pos(raw: &str) -> Result<usize, String> {
        raw.trim()
            .parse::<usize>()
            .ok()
            .filter(|&n| n > 0)
            .ok_or_else(|| format!("TEST_KNOB={raw:?} must be a positive integer"))
    }

    #[test]
    fn unset_returns_default_without_parsing() {
        std::env::remove_var("FASTP_TEST_KNOB_UNSET");
        let v = knob("FASTP_TEST_KNOB_UNSET", |_| panic!("must not parse"), || 7usize);
        assert_eq!(v, 7);
    }

    #[test]
    fn valid_value_wins_over_default() {
        std::env::set_var("FASTP_TEST_KNOB_VALID", "12");
        assert_eq!(knob_or("FASTP_TEST_KNOB_VALID", parse_pos, 7), 12);
        std::env::remove_var("FASTP_TEST_KNOB_VALID");
    }

    #[test]
    fn invalid_value_warns_and_defaults() {
        // the warning itself goes to stderr; observable behavior is the
        // defaulted value (and that we did not panic)
        std::env::set_var("FASTP_TEST_KNOB_BAD", "zero");
        assert_eq!(knob_or("FASTP_TEST_KNOB_BAD", parse_pos, 7), 7);
        std::env::set_var("FASTP_TEST_KNOB_BAD", "0");
        assert_eq!(knob_or("FASTP_TEST_KNOB_BAD", parse_pos, 7), 7);
        std::env::remove_var("FASTP_TEST_KNOB_BAD");
    }

    #[test]
    fn lazy_default_only_runs_when_needed() {
        std::env::set_var("FASTP_TEST_KNOB_LAZY", "3");
        let v = knob("FASTP_TEST_KNOB_LAZY", parse_pos, || panic!("default must stay lazy"));
        assert_eq!(v, 3);
        std::env::remove_var("FASTP_TEST_KNOB_LAZY");
    }
}
