//! `fastp` — FAST-Prefill CLI (leader entrypoint).
//!
//! Subcommands (no clap offline; hand-rolled parsing):
//!   prefill   run one functional prefill through the PJRT pipeline
//!   serve     serve a synthetic request trace (multi-worker)
//!   sim       FPGA + GPU model for a (model, context) point
//!   table2    FPGA resource utilization report
//!   ttft      Fig.5-style sweep for one model
//!   kernels   report the SIMD micro-kernel dispatch decision
//!   tune      sweep tile x backend per kernel shape, persist a profile
//!   perf-trend  gate a fresh hotpath_micro.json against the baseline
//!   help

use std::collections::HashMap;

use anyhow::{bail, Context, Result};
use fast_prefill::config::{self, by_name, FlexParams};
use fast_prefill::coordinator::{Engine, EngineConfig, Policy, Server, ServerOptions};
use fast_prefill::gpu_model::simulate_gpu_prefill;
use fast_prefill::metrics::{fmt_ctx, ServeSample, ServeSummary};
use fast_prefill::sim::{resource_report, simulate_prefill, synth_model_indices, HeadMix};
use fast_prefill::tensor::tune::{self, TuneOverride};
use fast_prefill::tensor::{simd, tile};
use fast_prefill::util::table::{fnum, Table};
use fast_prefill::workload::prompts::{PromptKind, PromptSpec, RequestTrace};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("fastp: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

/// Parse `--key value` flags into a map; returns (positional, flags).
fn parse_flags(args: &[String]) -> (Vec<String>, HashMap<String, String>) {
    let mut pos = Vec::new();
    let mut flags = HashMap::new();
    let mut it = args.iter().peekable();
    while let Some(a) = it.next() {
        if let Some(key) = a.strip_prefix("--") {
            let val = it.next().cloned().unwrap_or_else(|| "true".into());
            flags.insert(key.to_string(), val);
        } else {
            pos.push(a.clone());
        }
    }
    (pos, flags)
}

fn flag<T: std::str::FromStr>(flags: &HashMap<String, String>, key: &str, default: T) -> Result<T>
where
    T::Err: std::fmt::Display,
{
    match flags.get(key) {
        None => Ok(default),
        Some(v) => v.parse().map_err(|e| anyhow::anyhow!("--{key} {v}: {e}")),
    }
}

fn run(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_help();
        return Ok(());
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "prefill" => cmd_prefill(rest),
        "serve" => cmd_serve(rest),
        "sim" => cmd_sim(rest),
        "table2" => cmd_table2(rest),
        "ttft" => cmd_ttft(rest),
        "kernels" => cmd_kernels(rest),
        "tune" => cmd_tune(rest),
        "perf-trend" => cmd_perf_trend(rest),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command {other} (try `fastp help`)"),
    }
}

fn print_help() {
    println!(
        "fastp — FAST-Prefill reproduction CLI

USAGE: fastp <command> [--flags]

COMMANDS
  prefill  --model tiny|small100m --tokens 1024 [--seed N] [--dense true]
           [--artifacts DIR] [--native-sau true] [--native true]
           [--threads N]
           one functional prefill; --native runs every stage on the
           tiled parallel kernels (no artifacts needed; threads default
           to FASTP_THREADS or available parallelism)
  serve    --model tiny --requests 8 --tokens 1024 [--workers 2]
           [--policy fcfs|sjf] [--serial true] [--total-threads N]
           serve a synthetic trace (phase-pipelined by default; --serial
           is the end-to-end baseline), report latencies + phase waits
  sim      --model llama3.2-3b --tokens 131072 [--seed N]
           FPGA simulator + GPU cost model for one point
  table2   FPGA resource utilization (paper Table II)
  ttft     --model llama3.2-3b    TTFT sweep across paper context lengths
  kernels  [--require-simd true]
           print the micro-kernel dispatch decision (detected ISA,
           FASTP_KERNEL override, tile edge); with --require-simd,
           exit non-zero unless a vector backend is active — the CI
           kernel-matrix assertion
  tune     [--model tiny] [--out fastp_tune.json] [--budget-ms 10]
           [--check true] [--tokens 512]
           sweep every tile-edge x backend candidate per kernel shape
           class of the model and persist the winner table as a JSON
           autotune profile (activate with FASTP_AUTOTUNE=file +
           FASTP_TUNE_PROFILE=<path>, or let FASTP_AUTOTUNE=startup
           sweep a default grid at process start). --check reruns one
           prefill tuned vs untuned and fails unless bit-identical
  perf-trend --baseline ci/hotpath_baseline.json --fresh hotpath_micro.json
           [--tolerance 0.25] [--normalize score_tile.scalar_ns]
           diff the fresh hotpath summary against the checked-in
           baseline, per-kernel; exit non-zero on a regression (the CI
           perf-trend gate). --normalize divides every timing by the
           same file's reference kernel, cancelling absolute runner
           speed. Refresh the baseline with one command:
           FASTP_BENCH_JSON=ci/hotpath_baseline.json \\
               cargo bench --bench hotpath_micro
  help     this text"
    );
}

fn engine_config(flags: &HashMap<String, String>) -> Result<EngineConfig> {
    let model_name: String = flag(flags, "model", "tiny".to_string())?;
    let model = by_name(&model_name)
        .with_context(|| format!("unknown model {model_name}"))?
        .clone();
    let mut cfg = EngineConfig::new(model);
    if flag(flags, "dense", false)? {
        cfg.flex = None;
    }
    cfg.weight_seed = flag(flags, "seed", cfg.weight_seed)?;
    cfg.native_sau = flag(flags, "native-sau", cfg.native_sau)?;
    cfg.native_sigu = flag(flags, "native-sigu", cfg.native_sigu)?;
    cfg.native_linear = flag(flags, "native-linear", cfg.native_linear)?;
    if flag(flags, "native", false)? {
        cfg.native_sigu = true;
        cfg.native_sau = true;
        cfg.native_linear = true;
    }
    cfg.threads = flag(flags, "threads", cfg.threads)?;
    cfg.wave_qblocks = flag(flags, "wave", cfg.wave_qblocks)?;
    cfg.cache_blocks = flag(flags, "cache-blocks", cfg.cache_blocks)?;
    Ok(cfg)
}

fn cmd_prefill(args: &[String]) -> Result<()> {
    let (_, flags) = parse_flags(args);
    let dir: String = flag(&flags, "artifacts", "artifacts".to_string())?;
    let tokens: usize = flag(&flags, "tokens", 1024)?;
    let cfg = engine_config(&flags)?;
    let spec = PromptSpec { kind: PromptKind::Mixed, tokens, seed: flag(&flags, "seed", 1u64)? };
    if cfg.fully_native() {
        println!("native tiled-kernel backend (model {})...", cfg.model.name);
    } else {
        println!("loading artifacts from {dir} (model {})...", cfg.model.name);
    }
    let mut engine = Engine::new(&dir, cfg)?;
    println!("backend: {}", engine.platform());
    let toks = spec.generate();
    let run = engine.prefill(0, &toks)?;
    let m = &run.metrics;
    println!("first token        : {}", run.first_token);
    println!("TTFT               : {:.1} ms", m.ttft_us / 1e3);
    println!("  qkv / sigu / sau / ffn: {:.1} / {:.1} / {:.1} / {:.1} ms",
        m.t_qkv_us / 1e3, m.t_sigu_us / 1e3, m.t_sau_us / 1e3, m.t_ffn_us / 1e3);
    println!("attention density  : {:.1}%", m.density * 100.0);
    println!("query-aware heads  : {:.1}%", m.query_aware_frac * 100.0);
    println!("SAU jobs           : {}", m.jobs);
    println!("KV cache hit rate  : {:.1}%", m.cache_hit_rate * 100.0);
    if flag(&flags, "stats", false)? {
        println!("\nper-executable time (top 8):");
        for (name, calls, ms) in engine.exec_stats().into_iter().take(8) {
            println!("  {name:<32} {calls:>6} calls  {ms:>10.1} ms total  {:>8.2} ms/call",
                ms / calls.max(1) as f64);
        }
    }
    Ok(())
}

fn cmd_serve(args: &[String]) -> Result<()> {
    let (_, flags) = parse_flags(args);
    let dir: String = flag(&flags, "artifacts", "artifacts".to_string())?;
    let tokens: usize = flag(&flags, "tokens", 1024)?;
    let n_req: usize = flag(&flags, "requests", 8)?;
    let workers: usize = flag(&flags, "workers", 2)?;
    let policy = match flag(&flags, "policy", "fcfs".to_string())?.as_str() {
        "fcfs" => Policy::Fcfs,
        "sjf" => Policy::Sjf,
        "preemptive" => Policy::Preemptive,
        p => bail!("unknown policy {p}"),
    };
    let mut opts = ServerOptions::new(workers, policy);
    if flag(&flags, "serial", false)? {
        opts.pipelined = false;
    }
    opts.total_threads = flag(&flags, "total-threads", 0usize)?;
    let cfg = engine_config(&flags)?;
    let trace = RequestTrace::generate(n_req, tokens, 1000, flag(&flags, "seed", 7u64)?);
    println!(
        "serving {n_req} requests x {tokens} tokens on {workers} workers ({policy:?}, {})...",
        if opts.pipelined { "phase-pipelined" } else { "serial" }
    );
    let t0 = std::time::Instant::now();
    let server = Server::start_with(dir.into(), cfg, opts)?;
    for r in trace.requests {
        server.submit(r);
    }
    let completions = server.drain()?;
    let wall = t0.elapsed().as_secs_f64();
    let mut t = Table::new(&[
        "req", "TTFT (ms)", "queue (ms)", "phase-wait (ms)", "e2e (ms)", "density %", "hit %",
    ]);
    let mut samples: Vec<ServeSample> = Vec::new();
    for c in &completions {
        samples.push(c.sample());
        t.row(&[
            c.request_id.to_string(),
            fnum(c.run.metrics.ttft_us / 1e3),
            fnum(c.queue_us / 1e3),
            fnum(c.pipeline_wait_us / 1e3),
            fnum(c.e2e_us / 1e3),
            fnum(c.run.metrics.density * 100.0),
            fnum(c.run.metrics.cache_hit_rate * 100.0),
        ]);
    }
    t.print();
    let total_tokens = (n_req * tokens) as f64;
    let summary = ServeSummary::from_samples(&samples);
    println!("wall {:.2}s  throughput {:.0} tok/s", wall, total_tokens / wall);
    println!("{}", summary.render("summary"));
    Ok(())
}

fn sim_point(model: &str, tokens: usize, seed: u64) -> Result<()> {
    let cfg = by_name(model).with_context(|| format!("unknown model {model}"))?;
    let n = tokens / config::BLOCK;
    let sim_layers = 2.min(cfg.n_layers);
    let idx = synth_model_indices(
        cfg.n_heads,
        sim_layers,
        n,
        32,
        &HeadMix::default(),
        &FlexParams::default(),
        seed,
    );
    let fpga = config::u280_fast_prefill();
    let frep = simulate_prefill(&fpga, cfg, tokens, &idx);
    let grep = simulate_gpu_prefill(&config::a5000(), cfg, tokens, &idx);
    println!("model {model}  context {}", fmt_ctx(tokens));
    println!("  density {:.1}%  jobs/layer {}", frep.avg_density * 100.0,
        frep.total_jobs / cfg.n_layers);
    println!("  FPGA  TTFT {:>9.1} ms  (qkv {:.0} sigu {:.0} sau {:.0} ffn {:.0})  E {:.2} J  hit {:.0}%",
        frep.ttft_ms, frep.t_qkv_ms, frep.t_sigu_ms, frep.t_sau_ms, frep.t_ffn_ms,
        frep.energy_j, frep.cache_hit_rate * 100.0);
    println!("  GPU   TTFT {:>9.1} ms  (lin {:.0} idxG {:.0} idxC {:.0} attn {:.0} fw {:.0})  E {:.2} J",
        grep.ttft_ms, grep.t_linear_ms, grep.t_index_gpu_ms, grep.t_index_cpu_ms,
        grep.t_attn_ms, grep.t_framework_ms, grep.energy_j);
    println!("  speedup {:.2}x   energy-eff ratio {:.2}x",
        grep.ttft_ms / frep.ttft_ms,
        frep.tokens_per_joule() / grep.tokens_per_joule());
    Ok(())
}

fn cmd_sim(args: &[String]) -> Result<()> {
    let (_, flags) = parse_flags(args);
    let model: String = flag(&flags, "model", "llama3.2-3b".to_string())?;
    let tokens: usize = flag(&flags, "tokens", 131072)?;
    sim_point(&model, tokens, flag(&flags, "seed", 1u64)?)
}

fn cmd_ttft(args: &[String]) -> Result<()> {
    let (_, flags) = parse_flags(args);
    let model: String = flag(&flags, "model", "llama3.2-3b".to_string())?;
    for ctx in config::paper_context_lengths() {
        sim_point(&model, ctx, flag(&flags, "seed", 1u64)?)?;
    }
    Ok(())
}

fn cmd_kernels(args: &[String]) -> Result<()> {
    let (_, flags) = parse_flags(args);
    let detected = simd::detect();
    let active = simd::active();
    let ctx = tile::KernelCtx::from_env();
    println!("arch             : {}", std::env::consts::ARCH);
    println!("detected backend : {}", detected.name());
    println!(
        "active backend   : {}  ({}={})",
        active.name(),
        simd::KERNEL_ENV,
        std::env::var(simd::KERNEL_ENV).unwrap_or_else(|_| "<unset>".into())
    );
    println!("worker threads   : {}", ctx.threads());
    println!(
        "tile edge        : {}  ({}={})",
        ctx.tile,
        tile::TILE_ENV,
        std::env::var(tile::TILE_ENV).unwrap_or_else(|_| "<unset>".into())
    );
    println!(
        "autotune         : {}  ({}={}, {} tuned shapes)",
        ctx.tune_label(),
        tune::AUTOTUNE_ENV,
        std::env::var(tune::AUTOTUNE_ENV).unwrap_or_else(|_| "<unset>".into()),
        ctx.tune.as_ref().map_or(0, |p| p.entries.len())
    );
    if flag(&flags, "require-simd", false)? && !active.is_vector() {
        bail!(
            "a vector backend was required but dispatch resolved '{}' \
             (detected '{}' on {}) — the SIMD leg would silently run scalar",
            active.name(),
            detected.name(),
            std::env::consts::ARCH
        );
    }
    Ok(())
}

/// Offline autotune sweep: time every tile-edge x backend candidate for
/// each kernel shape class the model hits, persist the winner table, and
/// (with `--check`) prove a tuned prefill is bit-identical to untuned.
fn cmd_tune(args: &[String]) -> Result<()> {
    let (_, flags) = parse_flags(args);
    let model_name: String = flag(&flags, "model", "tiny".to_string())?;
    let model = by_name(&model_name)
        .with_context(|| format!("unknown model {model_name}"))?
        .clone();
    let default_out = std::env::var(tune::PROFILE_ENV)
        .ok()
        .filter(|p| !p.trim().is_empty())
        .unwrap_or_else(|| "fastp_tune.json".into());
    let out: String = flag(&flags, "out", default_out)?;
    let budget_ms: f64 = flag(&flags, "budget-ms", 10.0)?;
    let detected = simd::detect();
    let shapes = tune::model_shapes(&model);
    println!(
        "sweeping {} shape classes of model {} ({} tile candidates x {} backend rungs, \
         {budget_ms} ms/candidate)...",
        shapes.len(),
        model.name,
        tune::TILE_CANDIDATES.len(),
        if detected.is_vector() { 2 } else { 1 }
    );
    let prof = tune::sweep(&shapes, budget_ms);
    let mut t = Table::new(&["shape class", "tile", "backend", "best (us)"]);
    for (key, c) in &prof.entries {
        t.row(&[
            key.clone(),
            c.tile.to_string(),
            if c.vector { detected.name().to_string() } else { "scalar".to_string() },
            fnum(c.ns / 1000.0),
        ]);
    }
    t.print();
    prof.save(&out).map_err(|e| anyhow::anyhow!(e))?;
    println!(
        "profile saved to {out} ({} entries); activate with {}=file {}={out}",
        prof.entries.len(),
        tune::AUTOTUNE_ENV,
        tune::PROFILE_ENV
    );
    if flag(&flags, "check", false)? {
        let tokens: usize = flag(&flags, "tokens", 512)?;
        let toks: Vec<u8> = (0..tokens).map(|i| (i * 31 % 256) as u8).collect();
        let mut base_cfg = EngineConfig::new_native(model.clone());
        base_cfg.tune = TuneOverride::Off;
        let mut tuned_cfg = EngineConfig::new_native(model);
        tuned_cfg.tune = TuneOverride::Profile(std::sync::Arc::new(prof));
        let a = Engine::new_native(base_cfg)?.prefill(0, &toks)?;
        let b = Engine::new_native(tuned_cfg)?.prefill(0, &toks)?;
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
        anyhow::ensure!(
            a.first_token == b.first_token,
            "tuned first token {} != untuned {}",
            b.first_token,
            a.first_token
        );
        anyhow::ensure!(
            bits(&a.logits_last) == bits(&b.logits_last),
            "tuned logits diverge bitwise from untuned"
        );
        anyhow::ensure!(
            bits(&a.hidden_last_chunk) == bits(&b.hidden_last_chunk),
            "tuned hidden state diverges bitwise from untuned"
        );
        println!(
            "check: tuned prefill bit-identical to untuned ({tokens} tokens, {} tuned shapes, \
             mode {})",
            b.metrics.tuned_shapes,
            b.metrics.tune_mode
        );
    }
    Ok(())
}

fn cmd_perf_trend(args: &[String]) -> Result<()> {
    use fast_prefill::util::trend::compare_trend;
    let (_, flags) = parse_flags(args);
    let baseline_path: String = flag(&flags, "baseline", "ci/hotpath_baseline.json".to_string())?;
    let fresh_path: String = flag(&flags, "fresh", "hotpath_micro.json".to_string())?;
    let tolerance: f64 = flag(&flags, "tolerance", 0.25)?;
    let normalize: String = flag(&flags, "normalize", String::new())?;
    let baseline = std::fs::read_to_string(&baseline_path)
        .with_context(|| format!("reading baseline {baseline_path}"))?;
    let fresh = std::fs::read_to_string(&fresh_path)
        .with_context(|| format!("reading fresh summary {fresh_path}"))?;
    let norm_key = (!normalize.is_empty()).then_some(normalize.as_str());
    let report = compare_trend(&baseline, &fresh, tolerance, norm_key)
        .map_err(|e| anyhow::anyhow!(e))?;
    println!(
        "perf-trend: {} vs {} (tolerance {:.0}%{}{})",
        fresh_path,
        baseline_path,
        tolerance * 100.0,
        if norm_key.is_some() { ", normalized by " } else { "" },
        normalize
    );
    let mut t = Table::new(&["kernel", "baseline", "fresh", "ratio", "margin", "status"]);
    for p in &report.points {
        t.row(&[
            p.key.clone(),
            fnum(p.baseline),
            fnum(p.fresh),
            format!("{:.3}", p.ratio),
            format!("{:+.3}", p.margin),
            if p.regressed { "REGRESSED".into() } else { "ok".into() },
        ]);
    }
    t.print();
    for m in &report.missing {
        println!("MISSING: baseline kernel '{m}' absent from the fresh summary");
    }
    if report.provisional {
        println!(
            "baseline is PROVISIONAL (hand-written seed): reporting only. Arm the gate by \
             refreshing it on a representative runner:\n  \
             FASTP_BENCH_JSON=ci/hotpath_baseline.json cargo bench --bench hotpath_micro"
        );
        return Ok(());
    }
    if report.failed() {
        bail!(
            "{} kernel(s) regressed beyond {:.0}% (and {} missing); refresh the baseline if \
             intentional: FASTP_BENCH_JSON={} cargo bench --bench hotpath_micro",
            report.regressions().len(),
            tolerance * 100.0,
            report.missing.len(),
            baseline_path
        );
    }
    // name the baseline on success too: an armed-gate pass in CI logs
    // should say what it passed against, with the margin table above it
    println!(
        "perf-trend: PASS ({} kernels within {:.0}% of {})",
        report.points.len(),
        tolerance * 100.0,
        baseline_path
    );
    Ok(())
}

fn cmd_table2(_args: &[String]) -> Result<()> {
    let rep = resource_report(&config::u280_fast_prefill());
    let mut t = Table::new(&["Module", "LUT (k)", "FF (k)", "BRAM", "URAM", "DSP"]);
    for (name, r) in &rep.components {
        t.row(&[
            name.to_string(),
            fnum(r.lut_k),
            fnum(r.ff_k),
            fnum(r.bram),
            fnum(r.uram),
            fnum(r.dsp),
        ]);
    }
    t.row(&[
        "Used".into(),
        fnum(rep.total.lut_k),
        fnum(rep.total.ff_k),
        fnum(rep.total.bram),
        fnum(rep.total.uram),
        fnum(rep.total.dsp),
    ]);
    t.row(&[
        "Available".into(),
        fnum(rep.available.lut_k),
        fnum(rep.available.ff_k),
        fnum(rep.available.bram),
        fnum(rep.available.uram),
        fnum(rep.available.dsp),
    ]);
    t.print();
    Ok(())
}
