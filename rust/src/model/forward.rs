//! Pure-Rust W8A8 chunked prefill — the functional oracle.
//!
//! Mirrors the PJRT pipeline operation-for-operation (same quantization
//! points, same online-softmax state, same FlexPrefill semantics) so the
//! coordinator's artifact-backed execution can be validated against it.
//! Follows the paper's per-layer phasing (§IV-A): KV generation for all
//! chunks -> SIGU -> SAU (block-major) -> FFN.

use crate::config::{FlexParams, BLOCK};
use crate::flexprefill::{generate_head_index, scores, HeadIndex, HeadPattern, HeadStats};
use crate::quant::{int8_matmul_bt, int8_matmul_deq, quant_scale, quantize_with};
use crate::tensor::ops::{block_pool, rmsnorm, rope, silu};
use crate::tensor::{MatF32, MatI8};

use super::weights::ModelWeights;

/// Result of a reference prefill.
#[derive(Clone, Debug)]
pub struct PrefillOutput {
    /// argmax of the last position's logits — the first generated token.
    pub first_token: u8,
    pub logits_last: Vec<f32>,
    /// Hidden states after the final layer (pre final-norm), [S, D].
    pub hidden: MatF32,
    /// Pattern decision per [layer][head].
    pub patterns: Vec<Vec<HeadPattern>>,
    /// Mean computed fraction of the causal attention matrix.
    pub avg_density: f64,
    /// Sparse index sets per [layer][head] (empty when dense).
    pub index_sets: Vec<Vec<HeadIndex>>,
}

/// Quantized per-chunk activations for one layer's attention.
struct ChunkQkv {
    q: Vec<MatI8>, // per head: [B, dh]
    qs: f32,
    k: Vec<MatI8>, // per kv head
    ks: f32,
    v: Vec<MatI8>, // per kv head
    vs: f32,
    qpool: MatF32, // [H, dh]
    kpool: MatF32, // [Hk, dh]
}

/// One W8A8 online-softmax attention step (the Rust mirror of
/// `ref.attn_block_step_ref` / the `attn_block_step` artifact).
/// `diag` applies the intra-block causal mask.
#[allow(clippy::too_many_arguments)]
pub fn attn_step_w8a8(
    q: &MatI8,
    qs: f32,
    k: &MatI8,
    ks: f32,
    v: &MatI8,
    vs: f32,
    m: &mut [f32],
    l: &mut [f32],
    acc: &mut MatF32,
    diag: bool,
) {
    let b = q.rows;
    let dh = q.cols;
    let acc_i32 = int8_matmul_bt(q, k);
    let scale = qs * ks / (dh as f32).sqrt();
    let mut p_i8 = vec![0i8; k.rows];
    for r in 0..b {
        let srow = &acc_i32[r * k.rows..(r + 1) * k.rows];
        let ncols = if diag { r + 1 } else { k.rows };
        let mut rmax = f32::NEG_INFINITY;
        for &sv in &srow[..ncols] {
            rmax = rmax.max(sv as f32 * scale);
        }
        let m_new = m[r].max(rmax);
        let corr = (m[r] - m_new).exp();
        let mut lsum = 0.0f32;
        for (c, &sv) in srow[..ncols].iter().enumerate() {
            let p = ((sv as f32 * scale) - m_new).exp();
            lsum += p;
            // W8A8: requantize P with fixed scale 1/127 (ties-to-even like jnp)
            p_i8[c] = (p * 127.0).round_ties_even().clamp(-127.0, 127.0) as i8;
        }
        for c in ncols..k.rows {
            p_i8[c] = 0;
        }
        l[r] = l[r] * corr + lsum;
        m[r] = m_new;
        // acc = acc*corr + (P_i8 @ V_i8) * vs/127
        let arow = acc.row_mut(r);
        let pv_scale = vs / 127.0;
        for av in arow.iter_mut() {
            *av *= corr;
        }
        for (c, &pq) in p_i8.iter().enumerate().take(k.rows) {
            if pq == 0 {
                continue;
            }
            let vrow = v.row(c);
            let pf = pq as i32;
            for (av, &vv) in arow.iter_mut().zip(vrow) {
                *av += (pf * vv as i32) as f32 * pv_scale;
            }
        }
    }
}

/// Finalize: out = acc / l.
pub fn attn_finalize(l: &[f32], acc: &MatF32) -> MatF32 {
    let mut out = acc.clone();
    for r in 0..out.rows {
        let inv = 1.0 / l[r].max(1e-8);
        for v in out.row_mut(r) {
            *v *= inv;
        }
    }
    out
}

fn qkv_chunk(w: &ModelWeights, li: usize, x: &MatF32, pos0: i32) -> ChunkQkv {
    let cfg = &w.cfg;
    let lw = &w.layers[li];
    let b = x.rows;
    let xn = rmsnorm(x, &lw.g_attn, cfg.rms_eps);
    let xs = quant_scale(&xn.data);
    let mut x_i8 = MatI8::zeros(b, cfg.d_model);
    quantize_with(&xn.data, xs, &mut x_i8.data);
    let q = int8_matmul_deq(&x_i8, xs, &lw.wq.q, lw.wq.scale); // [B, H*dh]
    let k = int8_matmul_deq(&x_i8, xs, &lw.wk.q, lw.wk.scale);
    let v = int8_matmul_deq(&x_i8, xs, &lw.wv.q, lw.wv.scale);
    let pos: Vec<i32> = (0..b as i32).map(|i| pos0 + i).collect();

    // split per head, rope q/k, pool, then quantize per chunk (per-tensor
    // scale across all heads — matching python's quant_scale(q))
    let split = |m: &MatF32, heads: usize| -> Vec<MatF32> {
        (0..heads)
            .map(|h| {
                MatF32::from_fn(b, cfg.d_head, |r, c| m.at(r, h * cfg.d_head + c))
            })
            .collect()
    };
    let mut qh = split(&q, cfg.n_heads);
    let mut kh = split(&k, cfg.n_kv_heads);
    let vh = split(&v, cfg.n_kv_heads);
    for hq in qh.iter_mut() {
        rope(hq, &pos, cfg.rope_theta);
    }
    for hk in kh.iter_mut() {
        rope(hk, &pos, cfg.rope_theta);
    }
    let qpool = MatF32::from_fn(cfg.n_heads, cfg.d_head, |h, c| {
        qh[h].data.iter().skip(c).step_by(cfg.d_head).sum::<f32>() / b as f32
    });
    let kpool = MatF32::from_fn(cfg.n_kv_heads, cfg.d_head, |h, c| {
        kh[h].data.iter().skip(c).step_by(cfg.d_head).sum::<f32>() / b as f32
    });
    let scale_of = |hs: &[MatF32]| -> f32 {
        let mut mx = 0.0f32;
        for m in hs {
            for &v in &m.data {
                mx = mx.max(v.abs());
            }
        }
        mx.max(crate::quant::SCALE_EPS) / 127.0
    };
    let (qs, ks, vs) = (scale_of(&qh), scale_of(&kh), scale_of(&vh));
    let quant_all = |hs: &[MatF32], s: f32| -> Vec<MatI8> {
        hs.iter()
            .map(|m| {
                let mut q = MatI8::zeros(m.rows, m.cols);
                quantize_with(&m.data, s, &mut q.data);
                q
            })
            .collect()
    };
    ChunkQkv {
        q: quant_all(&qh, qs),
        qs,
        k: quant_all(&kh, ks),
        ks,
        v: quant_all(&vh, vs),
        vs,
        qpool,
        kpool,
    }
}

fn ffn_chunk(w: &ModelWeights, li: usize, x: &MatF32) -> MatF32 {
    let cfg = &w.cfg;
    let lw = &w.layers[li];
    let xn = rmsnorm(x, &lw.g_ffn, cfg.rms_eps);
    let xs = quant_scale(&xn.data);
    let mut x_i8 = MatI8::zeros(x.rows, cfg.d_model);
    quantize_with(&xn.data, xs, &mut x_i8.data);
    let mut gate = int8_matmul_deq(&x_i8, xs, &lw.wg.q, lw.wg.scale);
    silu(&mut gate);
    let up = int8_matmul_deq(&x_i8, xs, &lw.wu.q, lw.wu.scale);
    let mut h = gate;
    for (hv, uv) in h.data.iter_mut().zip(&up.data) {
        *hv *= uv;
    }
    let hs = quant_scale(&h.data);
    let mut h_i8 = MatI8::zeros(h.rows, h.cols);
    quantize_with(&h.data, hs, &mut h_i8.data);
    let down = int8_matmul_deq(&h_i8, hs, &lw.wd.q, lw.wd.scale);
    let mut out = x.clone();
    for (o, d) in out.data.iter_mut().zip(&down.data) {
        *o += d;
    }
    out
}

/// Reference chunked prefill. `flex: None` => dense causal attention.
pub fn prefill_reference(
    w: &ModelWeights,
    tokens: &[u8],
    flex: Option<&FlexParams>,
) -> PrefillOutput {
    let cfg = &w.cfg;
    let s = tokens.len();
    assert!(s % BLOCK == 0 && s > 0, "context must be a multiple of {BLOCK}");
    let n = s / BLOCK;
    let mut hidden = w.embed_tokens(tokens);
    let mut patterns = Vec::new();
    let mut index_sets = Vec::new();
    let mut density_sum = 0.0f64;
    let mut density_cnt = 0usize;

    for li in 0..cfg.n_layers {
        // ---- phase 1: KV generation over all chunks ----
        let chunks: Vec<ChunkQkv> = (0..n)
            .map(|ci| {
                let x = hidden.slice_rows(ci * BLOCK, (ci + 1) * BLOCK);
                qkv_chunk(w, li, &x, (ci * BLOCK) as i32)
            })
            .collect();

        // ---- phase 2: SIGU per head ----
        let indices: Vec<HeadIndex> = (0..cfg.n_heads)
            .map(|h| {
                if let Some(params) = flex {
                    let g = h / cfg.group_size();
                    let qhat = &chunks[n - 1].q[h];
                    let kblocks: Vec<(MatI8, f32)> =
                        chunks.iter().map(|c| (c.k[g].clone(), c.ks)).collect();
                    let (vertical, slash, a_hat) =
                        scores::stream_head_scores(qhat, chunks[n - 1].qs, &kblocks);
                    let kpool =
                        MatF32::from_fn(n, cfg.d_head, |b, c| chunks[b].kpool.at(g, c));
                    let qpool_all =
                        MatF32::from_fn(n, cfg.d_head, |b, c| chunks[b].qpool.at(h, c));
                    let qpool_hat: Vec<f32> = qpool_all.row(n - 1).to_vec();
                    let a_bar = scores::pooled_estimate(&qpool_hat, &kpool);
                    let stats = HeadStats { vertical, slash, a_bar, a_hat, qpool_all, kpool };
                    generate_head_index(&stats, params)
                } else {
                    // dense causal: q block attends to all blocks <= q
                    HeadIndex {
                        pattern: HeadPattern::VerticalSlash,
                        d_js: 0.0,
                        blocks: (0..n).map(|q| (0..=q as u32).collect()).collect(),
                    }
                }
            })
            .collect();
        for idx in &indices {
            density_sum += idx.density();
            density_cnt += 1;
        }
        patterns.push(indices.iter().map(|i| i.pattern).collect());

        // ---- phase 3: SAU (per (head, q-block), kv blocks ascending) ----
        let mut attn_chunks: Vec<MatF32> =
            (0..n).map(|_| MatF32::zeros(BLOCK, cfg.q_dim())).collect();
        for (h, idx) in indices.iter().enumerate() {
            let g = h / cfg.group_size();
            for (qb, sel) in idx.blocks.iter().enumerate() {
                let mut m = vec![-1e30f32; BLOCK];
                let mut l = vec![0.0f32; BLOCK];
                let mut acc = MatF32::zeros(BLOCK, cfg.d_head);
                for &kb in sel {
                    let kb = kb as usize;
                    attn_step_w8a8(
                        &chunks[qb].q[h],
                        chunks[qb].qs,
                        &chunks[kb].k[g],
                        chunks[kb].ks,
                        &chunks[kb].v[g],
                        chunks[kb].vs,
                        &mut m,
                        &mut l,
                        &mut acc,
                        kb == qb,
                    );
                }
                let out = attn_finalize(&l, &acc);
                for r in 0..BLOCK {
                    attn_chunks[qb].row_mut(r)[h * cfg.d_head..(h + 1) * cfg.d_head]
                        .copy_from_slice(out.row(r));
                }
            }
        }
        index_sets.push(indices);

        // ---- phase 4: o_proj + residual, FFN + residual, per chunk ----
        let lw = &w.layers[li];
        for ci in 0..n {
            let attn = &attn_chunks[ci];
            let s_a = quant_scale(&attn.data);
            let mut a_i8 = MatI8::zeros(BLOCK, cfg.q_dim());
            quantize_with(&attn.data, s_a, &mut a_i8.data);
            let proj = int8_matmul_deq(&a_i8, s_a, &lw.wo.q, lw.wo.scale);
            let mut x = hidden.slice_rows(ci * BLOCK, (ci + 1) * BLOCK);
            for (xv, pv) in x.data.iter_mut().zip(&proj.data) {
                *xv += pv;
            }
            let x = ffn_chunk(w, li, &x);
            hidden.data[ci * BLOCK * cfg.d_model..(ci + 1) * BLOCK * cfg.d_model]
                .copy_from_slice(&x.data);
        }
    }

    // ---- final norm + LM head on the last chunk ----
    let last = hidden.slice_rows(s - BLOCK, s);
    let xn = rmsnorm(&last, &w.g_final, cfg.rms_eps);
    let xs = quant_scale(&xn.data);
    let mut x_i8 = MatI8::zeros(BLOCK, cfg.d_model);
    quantize_with(&xn.data, xs, &mut x_i8.data);
    let logits = int8_matmul_deq(&x_i8, xs, &w.lm_head.q, w.lm_head.scale);
    let last_row = logits.row(BLOCK - 1);
    let first_token = last_row
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i as u8)
        .unwrap_or(0);

    PrefillOutput {
        first_token,
        logits_last: last_row.to_vec(),
        hidden,
        patterns,
        avg_density: if density_cnt > 0 { density_sum / density_cnt as f64 } else { 1.0 },
        index_sets,
    }
}

/// Convenience: `block_pool` re-export used by accuracy tooling.
pub fn pool_blocks(x: &MatF32) -> MatF32 {
    block_pool(x, BLOCK)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{FlexParams, TINY};
    use crate::util::prng::Prng;

    fn tokens(n: usize, seed: u64) -> Vec<u8> {
        let mut rng = Prng::new(seed);
        (0..n).map(|_| rng.below(256) as u8).collect()
    }

    #[test]
    fn dense_prefill_runs_and_is_deterministic() {
        let w = ModelWeights::generate(&TINY, 11);
        let t = tokens(256, 1);
        let a = prefill_reference(&w, &t, None);
        let b = prefill_reference(&w, &t, None);
        assert_eq!(a.first_token, b.first_token);
        assert_eq!(a.logits_last, b.logits_last);
        assert!((a.avg_density - 1.0).abs() < 1e-12);
    }

    #[test]
    fn flex_prefill_is_sparser_than_dense() {
        let w = ModelWeights::generate(&TINY, 12);
        let t = tokens(512, 2);
        let flex = FlexParams { gamma: 0.7, ..Default::default() };
        let out = prefill_reference(&w, &t, Some(&flex));
        assert!(out.avg_density <= 1.0);
        for layer in &out.index_sets {
            for idx in layer {
                idx.validate().expect("legal index set");
            }
        }
    }

    #[test]
    fn flex_with_gamma_one_close_to_dense_output() {
        // gamma=1.0 selects every block with mass => nearly dense
        let w = ModelWeights::generate(&TINY, 13);
        let t = tokens(256, 3);
        let dense = prefill_reference(&w, &t, None);
        let flex = FlexParams { gamma: 1.0, ..Default::default() };
        let sparse = prefill_reference(&w, &t, Some(&flex));
        // with 2 blocks and full coverage the outputs should agree closely
        let rel = crate::util::stats::rel_l2(&sparse.hidden.data, &dense.hidden.data);
        assert!(rel < 0.05, "rel {rel}");
    }

    #[test]
    fn attn_step_diag_masks_future() {
        let mut rng = Prng::new(4);
        let mut mk = |r: usize, c: usize| MatI8 {
            rows: r,
            cols: c,
            data: (0..r * c).map(|_| rng.i8_sym()).collect(),
        };
        let q = mk(8, 16);
        let k = mk(8, 16);
        let v = mk(8, 16);
        let mut m = vec![-1e30f32; 8];
        let mut l = vec![0.0f32; 8];
        let mut acc = MatF32::zeros(8, 16);
        attn_step_w8a8(&q, 0.02, &k, 0.02, &v, 0.02, &mut m, &mut l, &mut acc, true);
        // row 0 sees only col 0 => l[0] == 1 (exp(s - m) with m == s)
        assert!((l[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn logits_have_vocab_len() {
        let w = ModelWeights::generate(&TINY, 15);
        let out = prefill_reference(&w, &tokens(128, 5), None);
        assert_eq!(out.logits_last.len(), TINY.vocab);
    }
}
