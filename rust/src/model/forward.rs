//! Pure-Rust W8A8 chunked prefill — the functional oracle.
//!
//! Mirrors the PJRT pipeline operation-for-operation (same quantization
//! points, same online-softmax state, same FlexPrefill semantics) so the
//! coordinator's artifact-backed execution can be validated against it.
//! Follows the paper's per-layer phasing (§IV-A): KV generation for all
//! chunks -> SIGU -> SAU (block-major) -> FFN.
//!
//! Execution is block-major and parallel: every phase fans independent
//! jobs (per-chunk QKV/FFN, per-head SIGU, per-(head, query-block) SAU
//! states inside each wave of the `coordinator::joblist` schedule) over
//! the shared worker pool, with the tiled kernels of `tensor::tile` doing
//! the arithmetic. Each job's math is sequential and self-contained, so
//! the output is **bit-identical for every thread count** (tested).

use crate::config::{FlexParams, BLOCK};
use crate::coordinator::joblist::{build_schedule, DEFAULT_WAVE_QBLOCKS};
use crate::flexprefill::{generate_head_index, scores, HeadIndex, HeadPattern, HeadStats};
use crate::quant::{quant_scale, quantize_with_bk};
use crate::tensor::ops::{block_pool, rmsnorm_bk, rope_bk, silu};
use crate::tensor::simd;
use crate::tensor::tile::{self, KernelCtx};
use crate::tensor::{MatF32, MatI8};

use super::weights::ModelWeights;

/// Result of a reference prefill.
#[derive(Clone, Debug)]
pub struct PrefillOutput {
    /// argmax of the last position's logits — the first generated token.
    pub first_token: u8,
    pub logits_last: Vec<f32>,
    /// Hidden states after the final layer (pre final-norm), [S, D].
    pub hidden: MatF32,
    /// Pattern decision per [layer][head].
    pub patterns: Vec<Vec<HeadPattern>>,
    /// Mean computed fraction of the causal attention matrix.
    pub avg_density: f64,
    /// Sparse index sets per [layer][head] (empty when dense).
    pub index_sets: Vec<Vec<HeadIndex>>,
}

/// Quantized per-chunk activations for one layer's attention. Shared with
/// the coordinator's native (artifact-free) execution path. `Clone` so the
/// prefix KV store can publish/restore per-block chunks across requests.
#[derive(Clone)]
pub struct ChunkQkv {
    pub q: Vec<MatI8>, // per head: [B, dh]
    pub qs: f32,
    pub k: Vec<MatI8>, // per kv head
    pub ks: f32,
    pub v: Vec<MatI8>, // per kv head
    pub vs: f32,
    pub qpool: MatF32, // [H, dh]
    pub kpool: MatF32, // [Hk, dh]
}

/// One W8A8 online-softmax attention step (the Rust mirror of
/// `ref.attn_block_step_ref` / the `attn_block_step` artifact).
/// `diag` applies the intra-block causal mask. The score matmul runs
/// through the tiled kernel layer (exact integers, same as the oracle),
/// on the process-wide active SIMD backend.
#[allow(clippy::too_many_arguments)]
pub fn attn_step_w8a8(
    q: &MatI8,
    qs: f32,
    k: &MatI8,
    ks: f32,
    v: &MatI8,
    vs: f32,
    m: &mut [f32],
    l: &mut [f32],
    acc: &mut MatF32,
    diag: bool,
) {
    attn_step_w8a8_bk(q, qs, k, ks, v, vs, m, l, acc, diag, simd::active());
}

/// [`attn_step_w8a8`] on an explicit micro-kernel backend (the engine
/// passes its `KernelCtx` backend; tests pin scalar vs vector). The
/// score matmul is exact-integer (backend-order-free); the d-wide
/// rescale and P@V accumulate vectorize across output columns only, so
/// every backend is bit-identical to the scalar reference.
#[allow(clippy::too_many_arguments)]
pub fn attn_step_w8a8_bk(
    q: &MatI8,
    qs: f32,
    k: &MatI8,
    ks: f32,
    v: &MatI8,
    vs: f32,
    m: &mut [f32],
    l: &mut [f32],
    acc: &mut MatF32,
    diag: bool,
    bk: simd::Backend,
) {
    let b = q.rows;
    let dh = q.cols;
    let acc_i32 = tile::int8_matmul_bt_with_bk(q, k, tile::env_tile(), bk);
    let scale = qs * ks / (dh as f32).sqrt();
    let mut p_i8 = vec![0i8; k.rows];
    for r in 0..b {
        let srow = &acc_i32[r * k.rows..(r + 1) * k.rows];
        let ncols = if diag { r + 1 } else { k.rows };
        let mut rmax = f32::NEG_INFINITY;
        for &sv in &srow[..ncols] {
            rmax = rmax.max(sv as f32 * scale);
        }
        let m_new = m[r].max(rmax);
        let corr = (m[r] - m_new).exp();
        let mut lsum = 0.0f32;
        for (c, &sv) in srow[..ncols].iter().enumerate() {
            let p = ((sv as f32 * scale) - m_new).exp();
            lsum += p;
            // W8A8: requantize P with fixed scale 1/127 (ties-to-even like jnp)
            p_i8[c] = (p * 127.0).round_ties_even().clamp(-127.0, 127.0) as i8;
        }
        for c in ncols..k.rows {
            p_i8[c] = 0;
        }
        l[r] = l[r] * corr + lsum;
        m[r] = m_new;
        // acc = acc*corr + (P_i8 @ V_i8) * vs/127
        let arow = acc.row_mut(r);
        let pv_scale = vs / 127.0;
        bk.f32_scale(arow, corr);
        for (c, &pq) in p_i8.iter().enumerate().take(k.rows) {
            if pq == 0 {
                continue;
            }
            bk.f32_axpy_i8(arow, v.row(c), pq as i32, pv_scale);
        }
    }
}

/// Finalize: out = acc / l.
pub fn attn_finalize(l: &[f32], acc: &MatF32) -> MatF32 {
    let mut out = acc.clone();
    for r in 0..out.rows {
        let inv = 1.0 / l[r].max(1e-8);
        for v in out.row_mut(r) {
            *v *= inv;
        }
    }
    out
}

/// QKV generation for one chunk: rmsnorm, quantize, project, rope, pool,
/// requantize. Public so the coordinator's native path executes the exact
/// same math as the reference (bit-identical chunks). The projections run
/// through the kernel context's tiled W8A8 matmul.
pub fn qkv_chunk(ctx: &KernelCtx, w: &ModelWeights, li: usize, x: &MatF32, pos0: i32) -> ChunkQkv {
    let cfg = &w.cfg;
    let lw = &w.layers[li];
    let b = x.rows;
    let xn = rmsnorm_bk(x, &lw.g_attn, cfg.rms_eps, ctx.backend);
    let xs = quant_scale(&xn.data);
    let mut x_i8 = MatI8::zeros(b, cfg.d_model);
    quantize_with_bk(&xn.data, xs, &mut x_i8.data, ctx.backend);
    let q = ctx.int8_matmul_deq(&x_i8, xs, &lw.wq.q, lw.wq.scale); // [B, H*dh]
    let k = ctx.int8_matmul_deq(&x_i8, xs, &lw.wk.q, lw.wk.scale);
    let v = ctx.int8_matmul_deq(&x_i8, xs, &lw.wv.q, lw.wv.scale);
    let pos: Vec<i32> = (0..b as i32).map(|i| pos0 + i).collect();

    // split per head, rope q/k, pool, then quantize per chunk (per-tensor
    // scale across all heads — matching python's quant_scale(q))
    let split = |m: &MatF32, heads: usize| -> Vec<MatF32> {
        (0..heads)
            .map(|h| {
                MatF32::from_fn(b, cfg.d_head, |r, c| m.at(r, h * cfg.d_head + c))
            })
            .collect()
    };
    let mut qh = split(&q, cfg.n_heads);
    let mut kh = split(&k, cfg.n_kv_heads);
    let vh = split(&v, cfg.n_kv_heads);
    for hq in qh.iter_mut() {
        rope_bk(hq, &pos, cfg.rope_theta, ctx.backend);
    }
    for hk in kh.iter_mut() {
        rope_bk(hk, &pos, cfg.rope_theta, ctx.backend);
    }
    let qpool = MatF32::from_fn(cfg.n_heads, cfg.d_head, |h, c| {
        qh[h].data.iter().skip(c).step_by(cfg.d_head).sum::<f32>() / b as f32
    });
    let kpool = MatF32::from_fn(cfg.n_kv_heads, cfg.d_head, |h, c| {
        kh[h].data.iter().skip(c).step_by(cfg.d_head).sum::<f32>() / b as f32
    });
    let scale_of = |hs: &[MatF32]| -> f32 {
        let mut mx = 0.0f32;
        for m in hs {
            for &v in &m.data {
                mx = mx.max(v.abs());
            }
        }
        mx.max(crate::quant::SCALE_EPS) / 127.0
    };
    let (qs, ks, vs) = (scale_of(&qh), scale_of(&kh), scale_of(&vh));
    let quant_all = |hs: &[MatF32], s: f32| -> Vec<MatI8> {
        hs.iter()
            .map(|m| {
                let mut q = MatI8::zeros(m.rows, m.cols);
                quantize_with_bk(&m.data, s, &mut q.data, ctx.backend);
                q
            })
            .collect()
    };
    ChunkQkv {
        q: quant_all(&qh, qs),
        qs,
        k: quant_all(&kh, ks),
        ks,
        v: quant_all(&vh, vs),
        vs,
        qpool,
        kpool,
    }
}

/// FFN for one chunk (rmsnorm, gate/up, SiLU, down, residual). Public for
/// the coordinator's native path.
pub fn ffn_chunk(ctx: &KernelCtx, w: &ModelWeights, li: usize, x: &MatF32) -> MatF32 {
    let cfg = &w.cfg;
    let lw = &w.layers[li];
    let xn = rmsnorm_bk(x, &lw.g_ffn, cfg.rms_eps, ctx.backend);
    let xs = quant_scale(&xn.data);
    let mut x_i8 = MatI8::zeros(x.rows, cfg.d_model);
    quantize_with_bk(&xn.data, xs, &mut x_i8.data, ctx.backend);
    let mut gate = ctx.int8_matmul_deq(&x_i8, xs, &lw.wg.q, lw.wg.scale);
    silu(&mut gate);
    let up = ctx.int8_matmul_deq(&x_i8, xs, &lw.wu.q, lw.wu.scale);
    let mut h = gate;
    for (hv, uv) in h.data.iter_mut().zip(&up.data) {
        *hv *= uv;
    }
    let hs = quant_scale(&h.data);
    let mut h_i8 = MatI8::zeros(h.rows, h.cols);
    quantize_with_bk(&h.data, hs, &mut h_i8.data, ctx.backend);
    let down = ctx.int8_matmul_deq(&h_i8, hs, &lw.wd.q, lw.wd.scale);
    let mut out = x.clone();
    for (o, d) in out.data.iter_mut().zip(&down.data) {
        *o += d;
    }
    out
}

/// o_proj + residual followed by FFN + residual for one chunk: the whole
/// post-attention tail of a layer. Public for the coordinator's native
/// path (bit-identical to the reference).
pub fn oproj_ffn_chunk(
    ctx: &KernelCtx,
    w: &ModelWeights,
    li: usize,
    attn: &MatF32,
    x: &MatF32,
) -> MatF32 {
    let lw = &w.layers[li];
    let s_a = quant_scale(&attn.data);
    let mut a_i8 = MatI8::zeros(attn.rows, attn.cols);
    quantize_with_bk(&attn.data, s_a, &mut a_i8.data, ctx.backend);
    let proj = ctx.int8_matmul_deq(&a_i8, s_a, &lw.wo.q, lw.wo.scale);
    let mut x = x.clone();
    for (xv, pv) in x.data.iter_mut().zip(&proj.data) {
        *xv += pv;
    }
    ffn_chunk(ctx, w, li, &x)
}

/// Final norm + LM head over the last chunk. Public for the coordinator's
/// native path.
pub fn logits_last_chunk(ctx: &KernelCtx, w: &ModelWeights, last: &MatF32) -> MatF32 {
    let cfg = &w.cfg;
    let xn = rmsnorm_bk(last, &w.g_final, cfg.rms_eps, ctx.backend);
    let xs = quant_scale(&xn.data);
    let mut x_i8 = MatI8::zeros(last.rows, cfg.d_model);
    quantize_with_bk(&xn.data, xs, &mut x_i8.data, ctx.backend);
    ctx.int8_matmul_deq(&x_i8, xs, &w.lm_head.q, w.lm_head.scale)
}

/// argmax of a logits row (first generated token).
pub fn argmax_token(row: &[f32]) -> u8 {
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i as u8)
        .unwrap_or(0)
}

/// SIGU statistics + Algorithm 1 for every head, fanned over the pool.
/// Each head job borrows the chunk state (no K copies) and is sequential
/// inside, so results do not depend on the thread count. Shared with the
/// coordinator's native path.
pub fn sigu_indices(
    ctx: &KernelCtx,
    cfg: &crate::config::ModelConfig,
    chunks: &[ChunkQkv],
    n: usize,
    params: &FlexParams,
) -> Vec<HeadIndex> {
    ctx.pool.map(cfg.n_heads, |h| {
        let g = h / cfg.group_size();
        let job = scores::HeadJob {
            qhat: &chunks[n - 1].q[h],
            qs: chunks[n - 1].qs,
            kblocks: chunks.iter().map(|c| (&c.k[g], c.ks)).collect(),
        };
        let (vertical, slash, a_hat) = job.stream_with(ctx.backend);
        let kpool = MatF32::from_fn(n, cfg.d_head, |b, c| chunks[b].kpool.at(g, c));
        let qpool_all = MatF32::from_fn(n, cfg.d_head, |b, c| chunks[b].qpool.at(h, c));
        let qpool_hat: Vec<f32> = qpool_all.row(n - 1).to_vec();
        let a_bar = scores::pooled_estimate(&qpool_hat, &kpool);
        let stats = HeadStats { vertical, slash, a_bar, a_hat, qpool_all, kpool };
        generate_head_index(&stats, params)
    })
}

/// SIGU statistics + Algorithm 1 fused across co-resident lanes: one job
/// per query head, each streaming the head's kv-group K block sequence
/// **once** for the whole group and scoring every lane's Q-hat against it
/// ([`scores::FusedHeadJob`]). Per-lane math is the exact solo
/// [`sigu_indices`] sequence (independent state, ascending block order),
/// so each lane's index set is bit-identical to its solo run for any
/// fusion width, thread count and backend (tested). Lanes must share the
/// kv-head layout (same `cfg` — asserted via the job key space).
pub fn sigu_indices_batch(
    ctx: &KernelCtx,
    cfg: &crate::config::ModelConfig,
    chunk_lanes: &[&[ChunkQkv]],
    ns: &[usize],
    params: &FlexParams,
) -> Vec<Vec<HeadIndex>> {
    assert_eq!(chunk_lanes.len(), ns.len(), "chunk lanes vs block counts");
    let lanes = chunk_lanes.len();
    let per_head: Vec<Vec<HeadIndex>> = ctx.pool.map(cfg.n_heads, |h| {
        let g = h / cfg.group_size();
        let fused = scores::FusedHeadJob {
            lanes: (0..lanes)
                .map(|li| {
                    let (chunks, n) = (chunk_lanes[li], ns[li]);
                    scores::HeadJob {
                        qhat: &chunks[n - 1].q[h],
                        qs: chunks[n - 1].qs,
                        kblocks: chunks.iter().map(|c| (&c.k[g], c.ks)).collect(),
                    }
                })
                .collect(),
        };
        let streams = fused.stream_with(ctx.backend);
        streams
            .into_iter()
            .enumerate()
            .map(|(li, (vertical, slash, a_hat))| {
                let (chunks, n) = (chunk_lanes[li], ns[li]);
                let kpool = MatF32::from_fn(n, cfg.d_head, |b, c| chunks[b].kpool.at(g, c));
                let qpool_all = MatF32::from_fn(n, cfg.d_head, |b, c| chunks[b].qpool.at(h, c));
                let qpool_hat: Vec<f32> = qpool_all.row(n - 1).to_vec();
                let a_bar = scores::pooled_estimate(&qpool_hat, &kpool);
                let stats = HeadStats { vertical, slash, a_bar, a_hat, qpool_all, kpool };
                generate_head_index(&stats, params)
            })
            .collect::<Vec<HeadIndex>>()
    });
    // transpose [head][lane] -> [lane][head]
    let mut out: Vec<Vec<HeadIndex>> =
        (0..lanes).map(|_| Vec::with_capacity(cfg.n_heads)).collect();
    for head_out in per_head {
        for (li, idx) in head_out.into_iter().enumerate() {
            out[li].push(idx);
        }
    }
    out
}

/// Dense causal index set (every query block attends to all blocks <= it).
pub fn dense_indices(n_heads: usize, n: usize) -> Vec<HeadIndex> {
    (0..n_heads)
        .map(|_| HeadIndex {
            pattern: HeadPattern::VerticalSlash,
            d_js: 0.0,
            blocks: (0..n).map(|q| (0..=q as u32).collect()).collect(),
        })
        .collect()
}

/// Dense causal index set for a prefill resuming at block `resume_from`
/// (prefix-KV reuse): query blocks below the resume point have already
/// been attended in the published run and get empty lists (no SAU states,
/// no jobs), while every novel query block keeps its full causal list —
/// including the reused prefix KV blocks, so the memory spine still walks
/// (and prices) them. With `resume_from == 0` this is exactly
/// [`dense_indices`].
pub fn suffix_dense_indices(n_heads: usize, n: usize, resume_from: usize) -> Vec<HeadIndex> {
    (0..n_heads)
        .map(|_| HeadIndex {
            pattern: HeadPattern::VerticalSlash,
            d_js: 0.0,
            blocks: (0..n)
                .map(|q| if q < resume_from { Vec::new() } else { (0..=q as u32).collect() })
                .collect(),
        })
        .collect()
}

/// Execute one layer's SAU over the given block-major wave schedule,
/// fanning the per-(head, query-block) accumulator states of each wave
/// over the pool. Per state, KV blocks fold in ascending order (the
/// schedule's block-major order restricted to that state), matching the
/// scalar reference exactly. Shared with the coordinator's native path
/// (which builds its schedule from `EngineConfig::wave_qblocks`).
pub fn sau_layer(
    ctx: &KernelCtx,
    cfg: &crate::config::ModelConfig,
    chunks: &[ChunkQkv],
    schedule: &crate::coordinator::joblist::Schedule,
    n: usize,
) -> Vec<MatF32> {
    let hq = cfg.n_heads;
    let mut attn_chunks: Vec<MatF32> = (0..n).map(|_| MatF32::zeros(BLOCK, cfg.q_dim())).collect();
    for wave in &schedule.waves {
        let wq = (wave.q_end - wave.q_start) as usize;
        // Invert the wave's block-major job lists into per-state ascending
        // KV lists (states = live (head, q-block) accumulators).
        let mut state_kvs: Vec<Vec<u32>> = vec![Vec::new(); hq * wq];
        for bj in &wave.blocks {
            for job in &bj.jobs {
                state_kvs[job.head as usize * wq + (job.qblock - wave.q_start) as usize]
                    .push(bj.block);
            }
        }
        let states: Vec<(usize, usize)> = (0..hq * wq)
            .filter(|&st| !state_kvs[st].is_empty())
            .map(|st| (st / wq, wave.q_start as usize + st % wq))
            .collect();
        let outs: Vec<MatF32> = ctx.pool.map(states.len(), |si| {
            let (h, qb) = states[si];
            let g = h / cfg.group_size();
            let mut m = vec![-1e30f32; BLOCK];
            let mut l = vec![0.0f32; BLOCK];
            let mut acc = MatF32::zeros(BLOCK, cfg.d_head);
            for &kb in &state_kvs[h * wq + (qb - wave.q_start as usize)] {
                let kb = kb as usize;
                attn_step_w8a8_bk(
                    &chunks[qb].q[h],
                    chunks[qb].qs,
                    &chunks[kb].k[g],
                    chunks[kb].ks,
                    &chunks[kb].v[g],
                    chunks[kb].vs,
                    &mut m,
                    &mut l,
                    &mut acc,
                    kb == qb,
                    ctx.backend,
                );
            }
            attn_finalize(&l, &acc)
        });
        for ((h, qb), out) in states.into_iter().zip(outs) {
            for r in 0..BLOCK {
                attn_chunks[qb].row_mut(r)[h * cfg.d_head..(h + 1) * cfg.d_head]
                    .copy_from_slice(out.row(r));
            }
        }
    }
    attn_chunks
}

/// Batched SAU over a merged [`BatchSchedule`]: every lane's live wave
/// accumulator states fan out in **one** pool map per batch wave, so
/// co-resident requests share the sweep (and the worker slots) instead of
/// running back-to-back. Each (lane, head, q-block) state still folds its
/// KV blocks in ascending order with that lane's own chunk data — exactly
/// the solo [`sau_layer`] arithmetic — so per-lane outputs are
/// bit-identical to running the lanes one at a time.
pub fn sau_layer_batch(
    ctx: &KernelCtx,
    cfg: &crate::config::ModelConfig,
    chunk_lanes: &[&[ChunkQkv]],
    batch: &crate::coordinator::joblist::BatchSchedule,
) -> Vec<Vec<MatF32>> {
    assert_eq!(chunk_lanes.len(), batch.lanes, "chunk lanes vs schedule lanes");
    let mut attn_lanes: Vec<Vec<MatF32>> = batch
        .n_blocks
        .iter()
        .map(|&n| (0..n).map(|_| MatF32::zeros(BLOCK, cfg.q_dim())).collect())
        .collect();
    for wave in &batch.waves {
        // per-lane state bases: lane's states are (head, q_local) banks
        let mut base = vec![0usize; batch.lanes];
        let mut nstates = 0usize;
        for (lane, r) in wave.q_ranges.iter().enumerate() {
            base[lane] = nstates;
            if let Some((qs, qe)) = r {
                nstates += cfg.n_heads * (qe - qs) as usize;
            }
        }
        let state_of = |j: &crate::coordinator::joblist::BatchJob| -> usize {
            let (qs, qe) = wave.q_ranges[j.lane as usize].expect("job on live lane");
            debug_assert!((qs..qe).contains(&j.qblock));
            base[j.lane as usize]
                + j.head as usize * (qe - qs) as usize
                + (j.qblock - qs) as usize
        };
        // invert merged block-major lists into per-state ascending KV lists
        let mut state_kvs: Vec<Vec<u32>> = vec![Vec::new(); nstates];
        for bj in &wave.blocks {
            for job in &bj.jobs {
                state_kvs[state_of(job)].push(bj.block);
            }
        }
        let mut states: Vec<(usize, usize, usize, usize)> = Vec::new(); // (lane, h, qb, st)
        for (lane, r) in wave.q_ranges.iter().enumerate() {
            let Some((qs, qe)) = r else { continue };
            let wq = (qe - qs) as usize;
            for h in 0..cfg.n_heads {
                for ql in 0..wq {
                    let st = base[lane] + h * wq + ql;
                    if !state_kvs[st].is_empty() {
                        states.push((lane, h, *qs as usize + ql, st));
                    }
                }
            }
        }
        let outs: Vec<MatF32> = ctx.pool.map(states.len(), |si| {
            let (lane, h, qb, st) = states[si];
            let chunks = chunk_lanes[lane];
            let g = h / cfg.group_size();
            let mut m = vec![-1e30f32; BLOCK];
            let mut l = vec![0.0f32; BLOCK];
            let mut acc = MatF32::zeros(BLOCK, cfg.d_head);
            for &kb in &state_kvs[st] {
                let kb = kb as usize;
                attn_step_w8a8_bk(
                    &chunks[qb].q[h],
                    chunks[qb].qs,
                    &chunks[kb].k[g],
                    chunks[kb].ks,
                    &chunks[kb].v[g],
                    chunks[kb].vs,
                    &mut m,
                    &mut l,
                    &mut acc,
                    kb == qb,
                    ctx.backend,
                );
            }
            attn_finalize(&l, &acc)
        });
        for ((lane, h, qb, _), out) in states.into_iter().zip(outs) {
            for r in 0..BLOCK {
                attn_lanes[lane][qb].row_mut(r)[h * cfg.d_head..(h + 1) * cfg.d_head]
                    .copy_from_slice(out.row(r));
            }
        }
    }
    attn_lanes
}

/// Batched FFN tail over co-resident lanes at the **same layer**: one
/// pool fan-out over every (lane, chunk) job, so the layer's o_proj/FFN
/// weights stream through the cache once for the whole batch — the same
/// amortization the QKV batch gets. Each job runs the unchanged
/// [`oproj_ffn_chunk`] on its own lane's data, so per-lane outputs are
/// **bit-identical** to running the lanes solo. `attn_lanes[l][ci]` is
/// lane `l`'s chunk-`ci` attention rows (`[BLOCK, H*dh]` flattened);
/// returns each lane's new hidden chunks in chunk order.
pub fn ffn_tail_batch(
    ctx: &KernelCtx,
    w: &ModelWeights,
    li: usize,
    attn_lanes: &[&[Vec<f32>]],
    hidden_lanes: &[&MatF32],
) -> Vec<Vec<MatF32>> {
    assert_eq!(attn_lanes.len(), hidden_lanes.len(), "attn lanes vs hidden lanes");
    let hq_dh = w.cfg.q_dim();
    let mut jobs: Vec<(usize, usize)> = Vec::new(); // (lane, chunk)
    for (lane, attn) in attn_lanes.iter().enumerate() {
        jobs.extend((0..attn.len()).map(|ci| (lane, ci)));
    }
    let outs = ctx.pool.map(jobs.len(), |j| {
        let (lane, ci) = jobs[j];
        let a = MatF32 { rows: BLOCK, cols: hq_dh, data: attn_lanes[lane][ci].clone() };
        let x = hidden_lanes[lane].slice_rows(ci * BLOCK, (ci + 1) * BLOCK);
        oproj_ffn_chunk(ctx, w, li, &a, &x)
    });
    let mut lanes: Vec<Vec<MatF32>> =
        attn_lanes.iter().map(|a| Vec::with_capacity(a.len())).collect();
    for ((lane, _), out) in jobs.into_iter().zip(outs) {
        lanes[lane].push(out);
    }
    lanes
}

/// Reference chunked prefill with the default kernel context
/// (`FASTP_THREADS` workers). `flex: None` => dense causal attention.
pub fn prefill_reference(
    w: &ModelWeights,
    tokens: &[u8],
    flex: Option<&FlexParams>,
) -> PrefillOutput {
    prefill_reference_ctx(w, tokens, flex, &KernelCtx::from_env())
}

/// Reference chunked prefill over an explicit kernel context. Output is
/// bit-identical for every pool size (each job is sequential inside).
pub fn prefill_reference_ctx(
    w: &ModelWeights,
    tokens: &[u8],
    flex: Option<&FlexParams>,
    ctx: &KernelCtx,
) -> PrefillOutput {
    let cfg = &w.cfg;
    let s = tokens.len();
    assert!(s % BLOCK == 0 && s > 0, "context must be a multiple of {BLOCK}");
    let n = s / BLOCK;
    let mut hidden = w.embed_tokens(tokens);
    let mut patterns = Vec::new();
    let mut index_sets = Vec::new();
    let mut density_sum = 0.0f64;
    let mut density_cnt = 0usize;

    for li in 0..cfg.n_layers {
        // ---- phase 1: KV generation, one job per chunk ----
        let chunks: Vec<ChunkQkv> = ctx.pool.map(n, |ci| {
            let x = hidden.slice_rows(ci * BLOCK, (ci + 1) * BLOCK);
            qkv_chunk(ctx, w, li, &x, (ci * BLOCK) as i32)
        });

        // ---- phase 2: SIGU, one job per head ----
        let indices: Vec<HeadIndex> = match flex {
            Some(params) => sigu_indices(ctx, cfg, &chunks, n, params),
            None => dense_indices(cfg.n_heads, n),
        };
        for idx in &indices {
            density_sum += idx.density();
            density_cnt += 1;
        }
        patterns.push(indices.iter().map(|i| i.pattern).collect());

        // ---- phase 3: SAU waves, one job per (head, q-block) state ----
        let schedule = build_schedule(&indices, cfg.group_size(), DEFAULT_WAVE_QBLOCKS);
        let attn_chunks = sau_layer(ctx, cfg, &chunks, &schedule, n);
        index_sets.push(indices);

        // ---- phase 4: o_proj + residual, FFN + residual, per chunk ----
        let new_chunks: Vec<MatF32> = ctx.pool.map(n, |ci| {
            let x = hidden.slice_rows(ci * BLOCK, (ci + 1) * BLOCK);
            oproj_ffn_chunk(ctx, w, li, &attn_chunks[ci], &x)
        });
        for (ci, x) in new_chunks.into_iter().enumerate() {
            hidden.data[ci * BLOCK * cfg.d_model..(ci + 1) * BLOCK * cfg.d_model]
                .copy_from_slice(&x.data);
        }
    }

    // ---- final norm + LM head on the last chunk ----
    let last = hidden.slice_rows(s - BLOCK, s);
    let logits = logits_last_chunk(ctx, w, &last);
    let last_row = logits.row(BLOCK - 1);
    let first_token = argmax_token(last_row);

    PrefillOutput {
        first_token,
        logits_last: last_row.to_vec(),
        hidden,
        patterns,
        avg_density: if density_cnt > 0 { density_sum / density_cnt as f64 } else { 1.0 },
        index_sets,
    }
}

/// Convenience: `block_pool` re-export used by accuracy tooling.
pub fn pool_blocks(x: &MatF32) -> MatF32 {
    block_pool(x, BLOCK)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{FlexParams, TINY};
    use crate::util::prng::Prng;

    fn tokens(n: usize, seed: u64) -> Vec<u8> {
        let mut rng = Prng::new(seed);
        (0..n).map(|_| rng.below(256) as u8).collect()
    }

    #[test]
    fn dense_prefill_runs_and_is_deterministic() {
        let w = ModelWeights::generate(&TINY, 11);
        let t = tokens(256, 1);
        let a = prefill_reference(&w, &t, None);
        let b = prefill_reference(&w, &t, None);
        assert_eq!(a.first_token, b.first_token);
        assert_eq!(a.logits_last, b.logits_last);
        assert!((a.avg_density - 1.0).abs() < 1e-12);
    }

    #[test]
    fn flex_prefill_is_sparser_than_dense() {
        let w = ModelWeights::generate(&TINY, 12);
        let t = tokens(512, 2);
        let flex = FlexParams { gamma: 0.7, ..Default::default() };
        let out = prefill_reference(&w, &t, Some(&flex));
        assert!(out.avg_density <= 1.0);
        for layer in &out.index_sets {
            for idx in layer {
                idx.validate().expect("legal index set");
            }
        }
    }

    #[test]
    fn flex_with_gamma_one_close_to_dense_output() {
        // gamma=1.0 selects every block with mass => nearly dense
        let w = ModelWeights::generate(&TINY, 13);
        let t = tokens(256, 3);
        let dense = prefill_reference(&w, &t, None);
        let flex = FlexParams { gamma: 1.0, ..Default::default() };
        let sparse = prefill_reference(&w, &t, Some(&flex));
        // with 2 blocks and full coverage the outputs should agree closely
        let rel = crate::util::stats::rel_l2(&sparse.hidden.data, &dense.hidden.data);
        assert!(rel < 0.05, "rel {rel}");
    }

    #[test]
    fn prefill_bit_identical_across_thread_counts() {
        // the acceptance property of the parallel kernel core
        let w = ModelWeights::generate(&TINY, 21);
        let t = tokens(384, 9);
        let flex = FlexParams::default();
        let one = prefill_reference_ctx(&w, &t, Some(&flex), &KernelCtx::with_threads(1));
        for threads in [2usize, 8] {
            let par = prefill_reference_ctx(&w, &t, Some(&flex), &KernelCtx::with_threads(threads));
            assert_eq!(one.first_token, par.first_token, "threads={threads}");
            assert_eq!(one.logits_last, par.logits_last, "threads={threads}");
            assert_eq!(one.hidden.data, par.hidden.data, "threads={threads}");
            for (la, lb) in one.index_sets.iter().zip(&par.index_sets) {
                for (ia, ib) in la.iter().zip(lb) {
                    assert_eq!(ia.pattern, ib.pattern);
                    assert_eq!(ia.blocks, ib.blocks);
                }
            }
        }
    }

    #[test]
    fn batched_sau_bit_identical_to_solo_lanes() {
        use crate::coordinator::joblist::build_schedule_batch;
        let w = ModelWeights::generate(&TINY, 31);
        let ctx = KernelCtx::with_threads(3);
        let flex = FlexParams::default();
        // two co-resident "requests" with different context lengths
        let lanes: Vec<(Vec<ChunkQkv>, Vec<HeadIndex>, usize)> = [(384usize, 41u64), (256, 42)]
            .iter()
            .map(|&(toks, seed)| {
                let t = tokens(toks, seed);
                let hidden = w.embed_tokens(&t);
                let n = toks / BLOCK;
                let chunks: Vec<ChunkQkv> = (0..n)
                    .map(|ci| {
                        let x = hidden.slice_rows(ci * BLOCK, (ci + 1) * BLOCK);
                        qkv_chunk(&ctx, &w, 0, &x, (ci * BLOCK) as i32)
                    })
                    .collect();
                let indices = sigu_indices(&ctx, &TINY, &chunks, n, &flex);
                (chunks, indices, n)
            })
            .collect();
        let schedules: Vec<_> = lanes
            .iter()
            .map(|(_, idx, _)| build_schedule(idx, TINY.group_size(), 2))
            .collect();
        let solo: Vec<Vec<MatF32>> = lanes
            .iter()
            .zip(&schedules)
            .map(|((chunks, _, n), s)| sau_layer(&ctx, &TINY, chunks, s, *n))
            .collect();
        let batch = build_schedule_batch(&schedules.iter().collect::<Vec<_>>());
        batch.check_invariants(&schedules.iter().collect::<Vec<_>>()).unwrap();
        let chunk_lanes: Vec<&[ChunkQkv]> = lanes.iter().map(|(c, _, _)| c.as_slice()).collect();
        let batched = sau_layer_batch(&ctx, &TINY, &chunk_lanes, &batch);
        for (lane, (b, s)) in batched.iter().zip(&solo).enumerate() {
            assert_eq!(b.len(), s.len(), "lane {lane}");
            for (bm, sm) in b.iter().zip(s) {
                assert_eq!(bm.data, sm.data, "lane {lane}");
            }
        }
    }

    #[test]
    fn batched_sigu_bit_identical_to_solo_lanes() {
        // cross-lane IndexGen fusion: per-lane index sets must match the
        // solo sigu_indices run exactly, for every thread count
        let w = ModelWeights::generate(&TINY, 35);
        let flex = FlexParams::default();
        let lanes: Vec<(Vec<ChunkQkv>, usize)> = [(384usize, 71u64), (256, 72), (512, 73)]
            .iter()
            .map(|&(toks, seed)| {
                let ctx = KernelCtx::with_threads(1);
                let hidden = w.embed_tokens(&tokens(toks, seed));
                let n = toks / BLOCK;
                let chunks: Vec<ChunkQkv> = (0..n)
                    .map(|ci| {
                        let x = hidden.slice_rows(ci * BLOCK, (ci + 1) * BLOCK);
                        qkv_chunk(&ctx, &w, 0, &x, (ci * BLOCK) as i32)
                    })
                    .collect();
                (chunks, n)
            })
            .collect();
        let solo: Vec<Vec<HeadIndex>> = lanes
            .iter()
            .map(|(chunks, n)| {
                sigu_indices(&KernelCtx::with_threads(1), &TINY, chunks, *n, &flex)
            })
            .collect();
        let chunk_refs: Vec<&[ChunkQkv]> = lanes.iter().map(|(c, _)| c.as_slice()).collect();
        let ns: Vec<usize> = lanes.iter().map(|(_, n)| *n).collect();
        for threads in [1usize, 2, 8] {
            let ctx = KernelCtx::with_threads(threads);
            let batched = sigu_indices_batch(&ctx, &TINY, &chunk_refs, &ns, &flex);
            assert_eq!(batched.len(), solo.len());
            for (lane, (b, s)) in batched.iter().zip(&solo).enumerate() {
                assert_eq!(b.len(), s.len(), "lane {lane} heads (threads={threads})");
                for (ib, is) in b.iter().zip(s) {
                    assert_eq!(ib.pattern, is.pattern, "lane {lane} threads={threads}");
                    assert_eq!(ib.blocks, is.blocks, "lane {lane} threads={threads}");
                }
            }
        }
    }

    #[test]
    fn ffn_tail_batch_bit_identical_to_solo_chunks() {
        let w = ModelWeights::generate(&TINY, 33);
        let ctx = KernelCtx::with_threads(3);
        // two lanes with different context lengths at the same layer
        let lanes: Vec<(MatF32, Vec<Vec<f32>>)> = [(384usize, 51u64), (256, 52)]
            .iter()
            .map(|&(toks, seed)| {
                let hidden = w.embed_tokens(&tokens(toks, seed));
                let n = toks / BLOCK;
                let mut rng = Prng::new(seed ^ 0xFF);
                let attn: Vec<Vec<f32>> = (0..n)
                    .map(|_| (0..BLOCK * TINY.q_dim()).map(|_| rng.f32() - 0.5).collect())
                    .collect();
                (hidden, attn)
            })
            .collect();
        let solo: Vec<Vec<MatF32>> = lanes
            .iter()
            .map(|(hidden, attn)| {
                attn.iter()
                    .enumerate()
                    .map(|(ci, a)| {
                        let am = MatF32 { rows: BLOCK, cols: TINY.q_dim(), data: a.clone() };
                        let x = hidden.slice_rows(ci * BLOCK, (ci + 1) * BLOCK);
                        oproj_ffn_chunk(&ctx, &w, 0, &am, &x)
                    })
                    .collect()
            })
            .collect();
        let attn_refs: Vec<&[Vec<f32>]> = lanes.iter().map(|(_, a)| a.as_slice()).collect();
        let hidden_refs: Vec<&MatF32> = lanes.iter().map(|(h, _)| h).collect();
        let batched = ffn_tail_batch(&ctx, &w, 0, &attn_refs, &hidden_refs);
        for (lane, (b, s)) in batched.iter().zip(&solo).enumerate() {
            assert_eq!(b.len(), s.len(), "lane {lane}");
            for (bm, sm) in b.iter().zip(s) {
                assert_eq!(bm.data, sm.data, "lane {lane}");
            }
        }
    }

    #[test]
    fn attn_step_diag_masks_future() {
        let mut rng = Prng::new(4);
        let mut mk = |r: usize, c: usize| MatI8 {
            rows: r,
            cols: c,
            data: (0..r * c).map(|_| rng.i8_sym()).collect(),
        };
        let q = mk(8, 16);
        let k = mk(8, 16);
        let v = mk(8, 16);
        let mut m = vec![-1e30f32; 8];
        let mut l = vec![0.0f32; 8];
        let mut acc = MatF32::zeros(8, 16);
        attn_step_w8a8(&q, 0.02, &k, 0.02, &v, 0.02, &mut m, &mut l, &mut acc, true);
        // row 0 sees only col 0 => l[0] == 1 (exp(s - m) with m == s)
        assert!((l[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn logits_have_vocab_len() {
        let w = ModelWeights::generate(&TINY, 15);
        let out = prefill_reference(&w, &tokens(128, 5), None);
        assert_eq!(out.logits_last.len(), TINY.vocab);
    }
}
