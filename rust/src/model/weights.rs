//! Seeded-random quantized model weights.
//!
//! No checkpoints can be downloaded in this environment (see DESIGN.md
//! substitution table), so weights are generated from a seed with
//! Xavier-style scaling — TTFT and sparsity behaviour depend on shapes, not
//! on trained values. Weight tensors are stored exactly as the AOT
//! artifacts consume them: int8 + per-tensor f32 scale, layout [in, out].

use crate::config::ModelConfig;
use crate::quant::quantize_mat;
use crate::tensor::{MatF32, QTensor};
use crate::util::prng::Prng;

/// One transformer layer's quantized weights.
#[derive(Clone, Debug)]
pub struct LayerWeights {
    pub wq: QTensor, // [D, H*dh]
    pub wk: QTensor, // [D, Hk*dh]
    pub wv: QTensor, // [D, Hk*dh]
    pub wo: QTensor, // [H*dh, D]
    pub wg: QTensor, // [D, F]
    pub wu: QTensor, // [D, F]
    pub wd: QTensor, // [F, D]
    pub g_attn: Vec<f32>, // RMSNorm gain (pre-attention)
    pub g_ffn: Vec<f32>,  // RMSNorm gain (pre-FFN)
}

/// Full model: embedding (f32), layers, final norm, LM head.
#[derive(Clone, Debug)]
pub struct ModelWeights {
    pub cfg: ModelConfig,
    pub embed: MatF32, // [V, D]
    pub layers: Vec<LayerWeights>,
    pub g_final: Vec<f32>,
    pub lm_head: QTensor, // [D, V]
}

fn rand_mat(rng: &mut Prng, rows: usize, cols: usize, std: f32) -> MatF32 {
    MatF32::from_fn(rows, cols, |_, _| rng.normal() * std)
}

fn rand_q(rng: &mut Prng, rows: usize, cols: usize) -> QTensor {
    // Xavier-ish: std = 1/sqrt(fan_in)
    let std = 1.0 / (rows as f32).sqrt();
    quantize_mat(&rand_mat(rng, rows, cols, std))
}

impl ModelWeights {
    /// Generate a model deterministically from `seed`.
    pub fn generate(cfg: &ModelConfig, seed: u64) -> Self {
        let mut root = Prng::new(seed);
        let d = cfg.d_model;
        let embed = rand_mat(&mut root.fork(0xE), cfg.vocab, d, 1.0);
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for li in 0..cfg.n_layers {
            let mut r = root.fork(li as u64 + 1);
            layers.push(LayerWeights {
                wq: rand_q(&mut r, d, cfg.q_dim()),
                wk: rand_q(&mut r, d, cfg.kv_dim()),
                wv: rand_q(&mut r, d, cfg.kv_dim()),
                wo: rand_q(&mut r, cfg.q_dim(), d),
                wg: rand_q(&mut r, d, cfg.d_ffn),
                wu: rand_q(&mut r, d, cfg.d_ffn),
                wd: rand_q(&mut r, cfg.d_ffn, d),
                g_attn: (0..d).map(|_| 1.0 + 0.1 * r.normal()).collect(),
                g_ffn: (0..d).map(|_| 1.0 + 0.1 * r.normal()).collect(),
            });
        }
        let g_final = vec![1.0; d];
        let lm_head = rand_q(&mut root.fork(0x1F), d, cfg.vocab);
        ModelWeights { cfg: cfg.clone(), embed, layers, g_final, lm_head }
    }

    /// Embed a byte-token sequence: [S, D].
    pub fn embed_tokens(&self, tokens: &[u8]) -> MatF32 {
        let d = self.cfg.d_model;
        let mut out = MatF32::zeros(tokens.len(), d);
        for (r, &t) in tokens.iter().enumerate() {
            out.row_mut(r).copy_from_slice(self.embed.row(t as usize % self.cfg.vocab));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TINY;

    #[test]
    fn generation_is_deterministic() {
        let a = ModelWeights::generate(&TINY, 7);
        let b = ModelWeights::generate(&TINY, 7);
        assert_eq!(a.layers[0].wq.q.data, b.layers[0].wq.q.data);
        assert_eq!(a.layers[1].wd.scale, b.layers[1].wd.scale);
    }

    #[test]
    fn different_seeds_differ() {
        let a = ModelWeights::generate(&TINY, 1);
        let b = ModelWeights::generate(&TINY, 2);
        assert_ne!(a.layers[0].wq.q.data, b.layers[0].wq.q.data);
    }

    #[test]
    fn shapes_match_config() {
        let m = ModelWeights::generate(&TINY, 3);
        assert_eq!(m.layers.len(), TINY.n_layers);
        let l = &m.layers[0];
        assert_eq!((l.wq.q.rows, l.wq.q.cols), (TINY.d_model, TINY.q_dim()));
        assert_eq!((l.wk.q.rows, l.wk.q.cols), (TINY.d_model, TINY.kv_dim()));
        assert_eq!((l.wd.q.rows, l.wd.q.cols), (TINY.d_ffn, TINY.d_model));
        assert_eq!(m.embed.rows, TINY.vocab);
    }

    #[test]
    fn embed_tokens_lookup() {
        let m = ModelWeights::generate(&TINY, 4);
        let e = m.embed_tokens(&[0, 5, 0]);
        assert_eq!(e.rows, 3);
        assert_eq!(e.row(0), e.row(2));
        assert_ne!(e.row(0), e.row(1));
    }

    #[test]
    fn weight_scales_reasonable() {
        let m = ModelWeights::generate(&TINY, 5);
        for l in &m.layers {
            assert!(l.wq.scale > 0.0 && l.wq.scale < 1.0);
        }
    }
}
