//! Decode stage (paper §II-A): after prefill emits the first token, tokens
//! are generated auto-regressively — the prefill's matrix-matrix work
//! becomes matrix-vector work over the stored KV cache.
//!
//! The paper scopes its contribution to prefill ("optimizations of ...
//! efficient token generation in the decode stage are orthogonal"); this
//! module provides the orthogonal piece so the system is usable end to end:
//! dense W8A8 decode attention over the quantized KV built during prefill,
//! one token per step. Sparsity is intentionally not applied (FlexPrefill
//! is a prefill-time algorithm).
//!
//! Matmuls dispatch through a [`KernelCtx`] (tile/SIMD/tune ladder), so
//! decode rides the same kernel layer as prefill; every backend is
//! bit-identical to the scalar oracle by the kernel contract (pinned per
//! backend by `decode_is_deterministic`). The KV/position state is
//! detachable ([`Decoder::into_parts`] / [`Decoder::from_parts`]) so the
//! serving layer can park a request between decode steps without holding
//! a weights borrow.

use crate::config::BLOCK;
use crate::quant::{quant_scale, quantize_one, quantize_with};
use crate::tensor::ops::{rmsnorm, rope, silu};
use crate::tensor::tile::KernelCtx;
use crate::tensor::{MatF32, MatI8};

use super::weights::ModelWeights;

/// Per-layer quantized KV cache for decode: token-major rows.
#[derive(Clone, Debug)]
pub struct DecodeKv {
    /// [n_kv_heads][tokens x d_head] int8, one scale per appended token.
    pub k: Vec<MatI8>,
    pub v: Vec<MatI8>,
    /// Per-token scales (shared across kv heads, one per appended token
    /// group; prefill chunks contribute BLOCK tokens per scale).
    pub k_scales: Vec<f32>,
    pub v_scales: Vec<f32>,
    /// scale index per token row.
    pub scale_of: Vec<u32>,
}

impl DecodeKv {
    pub fn new(n_kv_heads: usize, d_head: usize) -> Self {
        DecodeKv {
            k: (0..n_kv_heads).map(|_| MatI8::zeros(0, d_head)).collect(),
            v: (0..n_kv_heads).map(|_| MatI8::zeros(0, d_head)).collect(),
            k_scales: vec![],
            v_scales: vec![],
            scale_of: vec![],
        }
    }

    pub fn tokens(&self) -> usize {
        self.scale_of.len()
    }

    /// Append one token's K/V rows (already quantized with the given
    /// scales) for every kv head.
    pub fn append(&mut self, k_rows: &[Vec<i8>], v_rows: &[Vec<i8>], ks: f32, vs: f32) {
        let sidx = self.k_scales.len() as u32;
        self.k_scales.push(ks);
        self.v_scales.push(vs);
        self.scale_of.push(sidx);
        for (g, row) in k_rows.iter().enumerate() {
            self.k[g].rows += 1;
            self.k[g].data.extend_from_slice(row);
        }
        for (g, row) in v_rows.iter().enumerate() {
            self.v[g].rows += 1;
            self.v[g].data.extend_from_slice(row);
        }
    }
}

/// Decoder state: hidden residual for the current token + KV per layer.
pub struct Decoder<'w> {
    pub w: &'w ModelWeights,
    /// Kernel-layer context the decode matmuls dispatch through.
    pub ctx: KernelCtx,
    pub kv: Vec<DecodeKv>,
    pub pos: usize,
}

impl<'w> Decoder<'w> {
    /// Build a decoder from a completed prefill's hidden states by
    /// re-deriving the KV cache layer by layer (token-exact with prefill's
    /// per-chunk quantization when `hidden_per_layer` comes from
    /// `prefill_reference`; for the engine path use its stored chunks).
    /// For simplicity and testability this constructor re-runs the KV
    /// projection over the provided per-layer inputs. Kernels run on a
    /// single-threaded scalar-or-active default context; use
    /// [`Decoder::from_prefill_inputs_ctx`] to supply the serving ctx.
    pub fn from_prefill_inputs(w: &'w ModelWeights, layer_inputs: &[MatF32]) -> Self {
        Decoder::from_prefill_inputs_ctx(w, KernelCtx::single_threaded(), layer_inputs)
    }

    /// [`Decoder::from_prefill_inputs`] with an explicit [`KernelCtx`].
    pub fn from_prefill_inputs_ctx(
        w: &'w ModelWeights,
        ctx: KernelCtx,
        layer_inputs: &[MatF32],
    ) -> Self {
        assert_eq!(layer_inputs.len(), w.cfg.n_layers);
        let cfg = &w.cfg;
        let s = layer_inputs[0].rows;
        let mut kv = Vec::with_capacity(cfg.n_layers);
        for (li, x) in layer_inputs.iter().enumerate() {
            let mut cache = DecodeKv::new(cfg.n_kv_heads, cfg.d_head);
            // per chunk, mirror forward::qkv_chunk quantization granularity
            for c0 in (0..s).step_by(BLOCK) {
                let chunk = x.slice_rows(c0, (c0 + BLOCK).min(s));
                let (krows, vrows, ks, vs) = project_kv(w, &ctx, li, &chunk, c0 as i32);
                for t in 0..chunk.rows {
                    let kr: Vec<Vec<i8>> = krows.iter().map(|m| m.row(t).to_vec()).collect();
                    let vr: Vec<Vec<i8>> = vrows.iter().map(|m| m.row(t).to_vec()).collect();
                    cache.append(&kr, &vr, ks, vs);
                }
            }
            kv.push(cache);
        }
        Decoder { w, ctx, kv, pos: s }
    }

    /// Reattach a decoder around detached KV/position state — the serving
    /// layer's per-step entry: decode units park `(kv, pos)` between
    /// steps (no weights borrow) and rebuild the view to advance.
    pub fn from_parts(w: &'w ModelWeights, ctx: KernelCtx, kv: Vec<DecodeKv>, pos: usize) -> Self {
        assert_eq!(kv.len(), w.cfg.n_layers);
        Decoder { w, ctx, kv, pos }
    }

    /// Detach the KV cache + position (drops the weights borrow).
    pub fn into_parts(self) -> (Vec<DecodeKv>, usize) {
        (self.kv, self.pos)
    }

    /// One decode step: consume `token`, return the next token.
    pub fn step(&mut self, token: u8) -> u8 {
        let cfg = &self.w.cfg;
        let d = cfg.d_model;
        let ctx = &self.ctx;
        let mut x = MatF32::from_vec(1, d, self.w.embed.row(token as usize % cfg.vocab).to_vec());
        for li in 0..cfg.n_layers {
            let lw = &self.w.layers[li];
            // --- attention (dense decode over cached KV) ---
            let (q_heads, qs) = project_q(self.w, ctx, li, &x, self.pos as i32);
            // append this token's KV first (self-attention includes itself)
            let xn = rm(&x, &lw.g_attn, cfg.rms_eps);
            let (krows, vrows, ks, vs) = project_kv_at(self.w, ctx, li, &xn, self.pos as i32);
            let kr: Vec<Vec<i8>> = krows.iter().map(|m| m.row(0).to_vec()).collect();
            let vr: Vec<Vec<i8>> = vrows.iter().map(|m| m.row(0).to_vec()).collect();
            self.kv[li].append(&kr, &vr, ks, vs);

            let mut attn_out = vec![0.0f32; cfg.q_dim()];
            let cache = &self.kv[li];
            for h in 0..cfg.n_heads {
                let g = h / cfg.group_size();
                let q = &q_heads[h];
                let kmat = &cache.k[g];
                // scores over all cached tokens (exact integer dot — loop
                // order free, so the scalar loop is already the oracle)
                let n = kmat.rows;
                let mut scores = vec![0.0f32; n];
                let inv = 1.0 / (cfg.d_head as f32).sqrt();
                for t in 0..n {
                    let mut acc = 0i32;
                    for (qv, kv8) in q.iter().zip(kmat.row(t)) {
                        acc += *qv as i32 * *kv8 as i32;
                    }
                    let ks_t = cache.k_scales[cache.scale_of[t] as usize];
                    scores[t] = acc as f32 * qs * ks_t * inv;
                }
                let p = crate::tensor::ops::softmax(&scores);
                let vmat = &cache.v[g];
                let out = &mut attn_out[h * cfg.d_head..(h + 1) * cfg.d_head];
                for t in 0..n {
                    // W8A8: quantize p with fixed 1/127 scale, like the SAU
                    let pq = quantize_one(p[t] * 127.0, 1.0) as f32;
                    if pq == 0.0 {
                        continue;
                    }
                    let vs_t = cache.v_scales[cache.scale_of[t] as usize];
                    for (o, vv) in out.iter_mut().zip(vmat.row(t)) {
                        *o += pq * *vv as f32 * (vs_t / 127.0);
                    }
                }
            }
            // o_proj + residual
            let s_a = quant_scale(&attn_out);
            let mut a_i8 = MatI8::zeros(1, cfg.q_dim());
            quantize_with(&attn_out, s_a, &mut a_i8.data);
            let proj = ctx.int8_matmul_deq(&a_i8, s_a, &lw.wo.q, lw.wo.scale);
            for (xv, pv) in x.data.iter_mut().zip(&proj.data) {
                *xv += pv;
            }
            // FFN + residual
            let xn = rm(&x, &lw.g_ffn, cfg.rms_eps);
            let xs = quant_scale(&xn.data);
            let mut x_i8 = MatI8::zeros(1, d);
            quantize_with(&xn.data, xs, &mut x_i8.data);
            let mut gate = ctx.int8_matmul_deq(&x_i8, xs, &lw.wg.q, lw.wg.scale);
            silu(&mut gate);
            let up = ctx.int8_matmul_deq(&x_i8, xs, &lw.wu.q, lw.wu.scale);
            for (gv, uv) in gate.data.iter_mut().zip(&up.data) {
                *gv *= uv;
            }
            let hs = quant_scale(&gate.data);
            let mut h_i8 = MatI8::zeros(1, cfg.d_ffn);
            quantize_with(&gate.data, hs, &mut h_i8.data);
            let down = ctx.int8_matmul_deq(&h_i8, hs, &lw.wd.q, lw.wd.scale);
            for (xv, dv) in x.data.iter_mut().zip(&down.data) {
                *xv += dv;
            }
        }
        self.pos += 1;
        // final norm + lm head
        let xn = rm(&x, &self.w.g_final, cfg.rms_eps);
        let xs = quant_scale(&xn.data);
        let mut x_i8 = MatI8::zeros(1, d);
        quantize_with(&xn.data, xs, &mut x_i8.data);
        let logits = ctx.int8_matmul_deq(&x_i8, xs, &self.w.lm_head.q, self.w.lm_head.scale);
        logits
            .data
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i as u8)
            .unwrap_or(0)
    }

    /// Generate `n` tokens starting from `first`.
    pub fn generate(&mut self, first: u8, n: usize) -> Vec<u8> {
        let mut out = Vec::with_capacity(n);
        let mut tok = first;
        for _ in 0..n {
            tok = self.step(tok);
            out.push(tok);
        }
        out
    }
}

fn rm(x: &MatF32, g: &[f32], eps: f32) -> MatF32 {
    rmsnorm(x, g, eps)
}

/// Project (already-normalized input) to quantized K/V rows per kv head.
fn project_kv_at(
    w: &ModelWeights,
    ctx: &KernelCtx,
    li: usize,
    xn: &MatF32,
    pos0: i32,
) -> (Vec<MatI8>, Vec<MatI8>, f32, f32) {
    let cfg = &w.cfg;
    let lw = &w.layers[li];
    let xs = quant_scale(&xn.data);
    let mut x_i8 = MatI8::zeros(xn.rows, cfg.d_model);
    quantize_with(&xn.data, xs, &mut x_i8.data);
    let k = ctx.int8_matmul_deq(&x_i8, xs, &lw.wk.q, lw.wk.scale);
    let v = ctx.int8_matmul_deq(&x_i8, xs, &lw.wv.q, lw.wv.scale);
    let pos: Vec<i32> = (0..xn.rows as i32).map(|i| pos0 + i).collect();
    let mut kh: Vec<MatF32> = (0..cfg.n_kv_heads)
        .map(|g| MatF32::from_fn(xn.rows, cfg.d_head, |r, c| k.at(r, g * cfg.d_head + c)))
        .collect();
    let vh: Vec<MatF32> = (0..cfg.n_kv_heads)
        .map(|g| MatF32::from_fn(xn.rows, cfg.d_head, |r, c| v.at(r, g * cfg.d_head + c)))
        .collect();
    for m in kh.iter_mut() {
        rope(m, &pos, cfg.rope_theta);
    }
    let scale_all = |hs: &[MatF32]| {
        let mut mx = 0.0f32;
        for m in hs {
            for &val in &m.data {
                mx = mx.max(val.abs());
            }
        }
        mx.max(crate::quant::SCALE_EPS) / 127.0
    };
    let (ks, vs) = (scale_all(&kh), scale_all(&vh));
    let qz = |hs: &[MatF32], s: f32| -> Vec<MatI8> {
        hs.iter()
            .map(|m| {
                let mut q = MatI8::zeros(m.rows, m.cols);
                quantize_with(&m.data, s, &mut q.data);
                q
            })
            .collect()
    };
    (qz(&kh, ks), qz(&vh, vs), ks, vs)
}

fn project_kv(
    w: &ModelWeights,
    ctx: &KernelCtx,
    li: usize,
    xn: &MatF32,
    pos0: i32,
) -> (Vec<MatI8>, Vec<MatI8>, f32, f32) {
    project_kv_at(w, ctx, li, xn, pos0)
}

/// Project to quantized per-head query rows for one token.
fn project_q(
    w: &ModelWeights,
    ctx: &KernelCtx,
    li: usize,
    x: &MatF32,
    pos: i32,
) -> (Vec<Vec<i8>>, f32) {
    let cfg = &w.cfg;
    let lw = &w.layers[li];
    let xn = rm(x, &lw.g_attn, cfg.rms_eps);
    let xs = quant_scale(&xn.data);
    let mut x_i8 = MatI8::zeros(1, cfg.d_model);
    quantize_with(&xn.data, xs, &mut x_i8.data);
    let q = ctx.int8_matmul_deq(&x_i8, xs, &lw.wq.q, lw.wq.scale);
    let mut heads: Vec<MatF32> = (0..cfg.n_heads)
        .map(|h| MatF32::from_fn(1, cfg.d_head, |_, c| q.at(0, h * cfg.d_head + c)))
        .collect();
    for m in heads.iter_mut() {
        rope(m, &[pos], cfg.rope_theta);
    }
    let mut mx = 0.0f32;
    for m in &heads {
        for &v in &m.data {
            mx = mx.max(v.abs());
        }
    }
    let qs = mx.max(crate::quant::SCALE_EPS) / 127.0;
    let out: Vec<Vec<i8>> = heads
        .iter()
        .map(|m| {
            let mut q8 = vec![0i8; cfg.d_head];
            quantize_with(&m.data, qs, &mut q8);
            q8
        })
        .collect();
    (out, qs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TINY;
    use crate::tensor::simd::{self, Backend};
    use crate::util::prng::Prng;

    fn inputs(w: &ModelWeights, s: usize, seed: u64) -> Vec<MatF32> {
        // stand-in layer inputs: embedding stream repeated per layer (the
        // decode tests exercise mechanics, not cross-layer numerics)
        let mut rng = Prng::new(seed);
        let toks: Vec<u8> = (0..s).map(|_| rng.below(256) as u8).collect();
        (0..w.cfg.n_layers).map(|_| w.embed_tokens(&toks)).collect()
    }

    #[test]
    fn decoder_appends_kv_and_advances() {
        let w = ModelWeights::generate(&TINY, 21);
        let mut dec = Decoder::from_prefill_inputs(&w, &inputs(&w, 128, 1));
        assert_eq!(dec.pos, 128);
        assert_eq!(dec.kv[0].tokens(), 128);
        let t = dec.step(42);
        assert_eq!(dec.pos, 129);
        assert_eq!(dec.kv[0].tokens(), 129);
        let _ = t;
    }

    #[test]
    fn decode_is_deterministic() {
        // determinism per ctx, and bit-identity across every backend and
        // thread count the kernel ladder can dispatch to — the contract
        // the serving layer's decode units lean on
        let w = ModelWeights::generate(&TINY, 22);
        let mut a = Decoder::from_prefill_inputs(&w, &inputs(&w, 128, 2));
        let mut b = Decoder::from_prefill_inputs(&w, &inputs(&w, 128, 2));
        let want = a.generate(7, 6);
        assert_eq!(want, b.generate(7, 6));
        for bk in [Backend::Scalar, simd::detect()] {
            for threads in [1usize, 4] {
                let ctx = KernelCtx::with_threads(threads).with_backend(bk);
                let mut d = Decoder::from_prefill_inputs_ctx(&w, ctx, &inputs(&w, 128, 2));
                assert_eq!(d.generate(7, 6), want, "backend {} threads {threads}", bk.name());
            }
        }
    }

    #[test]
    fn decoder_parts_roundtrip_resumes_exactly() {
        // park/reattach between steps (the serving layer's shape) must
        // match an uninterrupted generate bit-for-bit
        let w = ModelWeights::generate(&TINY, 25);
        let mut solo = Decoder::from_prefill_inputs(&w, &inputs(&w, 128, 6));
        let want = solo.generate(3, 5);
        let dec = Decoder::from_prefill_inputs(&w, &inputs(&w, 128, 6));
        let (mut kv, mut pos) = dec.into_parts();
        let mut tok = 3u8;
        let mut got = Vec::new();
        for _ in 0..5 {
            let mut d = Decoder::from_parts(&w, KernelCtx::single_threaded(), kv, pos);
            tok = d.step(tok);
            got.push(tok);
            let parts = d.into_parts();
            kv = parts.0;
            pos = parts.1;
        }
        assert_eq!(got, want);
    }

    #[test]
    fn generation_produces_n_tokens() {
        let w = ModelWeights::generate(&TINY, 23);
        let mut dec = Decoder::from_prefill_inputs(&w, &inputs(&w, 128, 3));
        let out = dec.generate(0, 10);
        assert_eq!(out.len(), 10);
        assert_eq!(dec.kv[0].tokens(), 138);
    }

    #[test]
    fn different_contexts_generate_differently() {
        let w = ModelWeights::generate(&TINY, 24);
        let mut a = Decoder::from_prefill_inputs(&w, &inputs(&w, 128, 4));
        let mut b = Decoder::from_prefill_inputs(&w, &inputs(&w, 128, 5));
        // different KV caches should (overwhelmingly) diverge
        assert_ne!(a.generate(7, 8), b.generate(7, 8));
    }
}
