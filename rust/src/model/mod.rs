//! Model substrate: seeded-random quantized weights, byte-level embedding,
//! and the pure-Rust W8A8 prefill forward used as the oracle for the
//! PJRT-backed coordinator pipeline.

pub mod decode;
pub mod forward;
pub mod weights;

pub use forward::{prefill_reference, prefill_reference_ctx, PrefillOutput};
pub use weights::{LayerWeights, ModelWeights};
