//! PJRT runtime: loads HLO-text artifacts and executes them on the CPU
//! client from the L3 hot path (adapted from /opt/xla-example/load_hlo).
//!
//! Interchange is HLO *text* — jax >= 0.5 emits 64-bit instruction ids in
//! serialized protos which xla_extension 0.5.1 rejects; the text parser
//! reassigns ids. Every entry point is compiled once and cached; arguments
//! are validated against the AOT manifest before each call (debug) or at
//! registration (release).
//!
//! The `xla` bindings are only available when the crate is built with the
//! `pjrt` feature. Without it (the offline default) this module compiles a
//! stub whose [`Runtime::load`] always fails — artifact-backed tests and
//! examples detect that and either skip or fall back to the native tiled
//! kernel path (`Engine` native mode, see `coordinator::engine`).

pub mod artifacts;
pub mod exec;

use std::collections::HashMap;

#[cfg(not(feature = "pjrt"))]
use anyhow::anyhow;
#[cfg(feature = "pjrt")]
use anyhow::{anyhow, Context, Result};
#[cfg(not(feature = "pjrt"))]
use anyhow::Result;

pub use artifacts::{ArtifactDecl, Dtype, Manifest, ShapeDecl};
pub use exec::{literal_f32, literal_i8, literal_scalar_f32, Arg, Literal};

/// A compiled entry point plus its manifest declaration.
pub struct Executable {
    pub decl: ArtifactDecl,
    #[cfg(feature = "pjrt")]
    exe: xla::PjRtLoadedExecutable,
    /// cumulative wall time spent in execute (ns) + call count (perf).
    pub exec_ns: std::cell::Cell<u64>,
    pub calls: std::cell::Cell<u64>,
}

#[cfg(feature = "pjrt")]
impl Executable {
    /// Execute with typed args; returns the decomposed result tuple.
    pub fn run(&self, args: &[Arg<'_>]) -> Result<Vec<Literal>> {
        if args.len() != self.decl.inputs.len() {
            return Err(anyhow!(
                "{}: {} args given, {} expected",
                self.decl.entry,
                args.len(),
                self.decl.inputs.len()
            ));
        }
        if cfg!(debug_assertions) {
            for (i, (a, d)) in args.iter().zip(&self.decl.inputs).enumerate() {
                a.check(d, i)?;
            }
        }
        let lits: Vec<Literal> = args.iter().map(|a| a.to_literal()).collect::<Result<_>>()?;
        let t0 = std::time::Instant::now();
        let out = self
            .exe
            .execute::<Literal>(&lits)
            .with_context(|| format!("executing {}", self.decl.entry))?;
        let result = out[0][0].to_literal_sync().context("fetch result")?;
        self.exec_ns.set(self.exec_ns.get() + t0.elapsed().as_nanos() as u64);
        self.calls.set(self.calls.get() + 1);
        // aot.py lowers with return_tuple=True: root is always a tuple.
        let mut result = result;
        let parts = result.decompose_tuple().context("decompose tuple")?;
        if parts.len() != self.decl.outputs.len() {
            return Err(anyhow!(
                "{}: {} results, manifest says {}",
                self.decl.entry,
                parts.len(),
                self.decl.outputs.len()
            ));
        }
        Ok(parts)
    }
}

#[cfg(not(feature = "pjrt"))]
impl Executable {
    /// Stub: the `pjrt` feature is off, so no artifact can execute. A stub
    /// [`Runtime`] can never be constructed, so this is unreachable in
    /// practice; it exists to keep the artifact-backed call sites compiling.
    pub fn run(&self, _args: &[Arg<'_>]) -> Result<Vec<Literal>> {
        Err(anyhow!(
            "artifact {} unavailable: fast_prefill was built without the `pjrt` feature",
            self.decl.entry
        ))
    }
}

/// The PJRT runtime: client + compiled-executable registry.
pub struct Runtime {
    pub manifest: Manifest,
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
    exes: HashMap<String, Executable>,
}

impl Runtime {
    fn key(cfg: &str, entry: &str) -> String {
        format!("{cfg}::{entry}")
    }

    /// Perf counters: (entry, calls, total_ms) for every compiled executable.
    pub fn exec_stats(&self) -> Vec<(String, u64, f64)> {
        let mut v: Vec<(String, u64, f64)> = self
            .exes
            .iter()
            .map(|(k, e)| (k.clone(), e.calls.get(), e.exec_ns.get() as f64 / 1e6))
            .collect();
        v.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap());
        v
    }
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Create a CPU-client runtime over an artifact directory.
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        Ok(Runtime { manifest, client, exes: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) an entry point for a config.
    pub fn get(&mut self, cfg: &str, entry: &str) -> Result<&Executable> {
        let key = Self::key(cfg, entry);
        if !self.exes.contains_key(&key) {
            let decl = self
                .manifest
                .find(cfg, entry)
                .ok_or_else(|| anyhow!("artifact {cfg}::{entry} not in manifest"))?
                .clone();
            let proto = xla::HloModuleProto::from_text_file(&decl.file)
                .with_context(|| format!("parsing {:?}", decl.file))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).with_context(|| format!("compiling {key}"))?;
            self.exes.insert(
                key.clone(),
                Executable {
                    decl,
                    exe,
                    exec_ns: std::cell::Cell::new(0),
                    calls: std::cell::Cell::new(0),
                },
            );
        }
        Ok(&self.exes[&key])
    }

    /// Eager-compile every entry point of a config (avoids first-call jitter).
    pub fn warmup(&mut self, cfg: &str) -> Result<()> {
        let entries: Vec<String> = self
            .manifest
            .artifacts
            .iter()
            .filter(|a| a.cfg == cfg)
            .map(|a| a.entry.clone())
            .collect();
        if entries.is_empty() {
            return Err(anyhow!("no artifacts for config {cfg}"));
        }
        for e in entries {
            self.get(cfg, &e)?;
        }
        Ok(())
    }
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    /// Stub: always fails, regardless of whether the artifacts exist —
    /// there is no PJRT client to execute them. Callers treat this like
    /// missing artifacts (skip, or fall back to the native kernel path).
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<Runtime> {
        Err(anyhow!(
            "PJRT runtime unavailable: built without the `pjrt` feature \
             (artifacts in {:?} cannot be executed); use the Engine native \
             kernel path instead",
            dir.as_ref()
        ))
    }

    pub fn platform(&self) -> String {
        "stub (no pjrt)".to_string()
    }

    /// Stub: unreachable in practice (no stub Runtime can be constructed).
    pub fn get(&mut self, cfg: &str, entry: &str) -> Result<&Executable> {
        let _ = Self::key(cfg, entry);
        Err(anyhow!("artifact {cfg}::{entry} unavailable: built without the `pjrt` feature"))
    }

    /// Stub: unreachable in practice.
    pub fn warmup(&mut self, cfg: &str) -> Result<()> {
        Err(anyhow!("cannot warm up {cfg}: built without the `pjrt` feature"))
    }
}
