//! Typed argument/result marshalling between Rust slices and XLA literals.
//!
//! [`Arg`] and its manifest-shape validation are always available; the
//! literal conversions exist only with the `pjrt` feature. The stub
//! [`Literal`] (no `pjrt`) can never be produced at runtime — stub
//! executables fail before constructing one — so its accessors only need to
//! keep the call sites typechecking.

use anyhow::{bail, Context, Result};
#[cfg(feature = "pjrt")]
use xla::ElementType;
#[cfg(feature = "pjrt")]
pub use xla::Literal;

use super::artifacts::{Dtype, ShapeDecl};

/// Stub literal for builds without the `pjrt` feature.
#[cfg(not(feature = "pjrt"))]
#[derive(Debug)]
pub struct Literal {
    _unconstructible: (),
}

#[cfg(not(feature = "pjrt"))]
impl Literal {
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        bail!("literal access: built without the `pjrt` feature")
    }

    pub fn get_first_element<T>(&self) -> Result<T> {
        bail!("literal access: built without the `pjrt` feature")
    }
}

/// A typed argument for an artifact call. Borrowed slices avoid copies on
/// the caller side; the literal construction is the single copy point.
#[derive(Clone, Debug)]
pub enum Arg<'a> {
    F32(&'a [f32], &'a [usize]),
    I8(&'a [i8], &'a [usize]),
    I32(&'a [i32], &'a [usize]),
    ScalarF32(f32),
    ScalarI32(i32),
}

impl<'a> Arg<'a> {
    pub fn dtype(&self) -> Dtype {
        match self {
            Arg::F32(..) | Arg::ScalarF32(_) => Dtype::F32,
            Arg::I8(..) => Dtype::S8,
            Arg::I32(..) | Arg::ScalarI32(_) => Dtype::S32,
        }
    }

    pub fn dims(&self) -> Vec<usize> {
        match self {
            Arg::F32(_, d) | Arg::I8(_, d) | Arg::I32(_, d) => d.to_vec(),
            Arg::ScalarF32(_) | Arg::ScalarI32(_) => vec![],
        }
    }

    /// Validate against a manifest shape declaration.
    pub fn check(&self, decl: &ShapeDecl, pos: usize) -> Result<()> {
        if self.dtype() != decl.dtype {
            bail!("arg {pos}: dtype {:?} != manifest {:?}", self.dtype(), decl.dtype);
        }
        let dims = self.dims();
        if dims != decl.dims {
            bail!("arg {pos}: dims {:?} != manifest {:?}", dims, decl.dims);
        }
        let len = match self {
            Arg::F32(v, _) => v.len(),
            Arg::I8(v, _) => v.len(),
            Arg::I32(v, _) => v.len(),
            _ => 1,
        };
        if len != decl.elements() {
            bail!("arg {pos}: {len} elements for dims {:?}", decl.dims);
        }
        Ok(())
    }

    /// Build the XLA literal (one host copy).
    #[cfg(feature = "pjrt")]
    pub fn to_literal(&self) -> Result<Literal> {
        fn bytes_of<T>(v: &[T]) -> &[u8] {
            unsafe {
                std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v))
            }
        }
        let lit = match self {
            Arg::F32(v, d) => {
                Literal::create_from_shape_and_untyped_data(ElementType::F32, d, bytes_of(v))
                    .context("f32 literal")?
            }
            Arg::I8(v, d) => {
                Literal::create_from_shape_and_untyped_data(ElementType::S8, d, bytes_of(v))
                    .context("i8 literal")?
            }
            Arg::I32(v, d) => {
                Literal::create_from_shape_and_untyped_data(ElementType::S32, d, bytes_of(v))
                    .context("i32 literal")?
            }
            Arg::ScalarF32(v) => Literal::scalar(*v),
            Arg::ScalarI32(v) => Literal::scalar(*v),
        };
        Ok(lit)
    }
}

/// Typed result extraction.
pub fn literal_f32(lit: &Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().context("f32 result")
}

pub fn literal_i8(lit: &Literal) -> Result<Vec<i8>> {
    lit.to_vec::<i8>().context("i8 result")
}

pub fn literal_scalar_f32(lit: &Literal) -> Result<f32> {
    Ok(literal_f32(lit)?[0])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arg_dims_and_dtype() {
        let v = [1.0f32, 2.0];
        let a = Arg::F32(&v, &[2]);
        assert_eq!(a.dtype(), Dtype::F32);
        assert_eq!(a.dims(), vec![2]);
        assert_eq!(Arg::ScalarI32(3).dims(), Vec::<usize>::new());
    }

    #[test]
    fn check_validates() {
        let v = [1i8, 2, 3, 4];
        let a = Arg::I8(&v, &[2, 2]);
        let ok = ShapeDecl { dtype: Dtype::S8, dims: vec![2, 2] };
        let bad_dims = ShapeDecl { dtype: Dtype::S8, dims: vec![4] };
        let bad_ty = ShapeDecl { dtype: Dtype::F32, dims: vec![2, 2] };
        assert!(a.check(&ok, 0).is_ok());
        assert!(a.check(&bad_dims, 0).is_err());
        assert!(a.check(&bad_ty, 0).is_err());
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn literal_roundtrip_f32() {
        let v = [1.5f32, -2.5, 3.5, 0.0];
        let lit = Arg::F32(&v, &[2, 2]).to_literal().unwrap();
        assert_eq!(literal_f32(&lit).unwrap(), v.to_vec());
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn literal_roundtrip_i8() {
        let v = [-127i8, 0, 127, 5];
        let lit = Arg::I8(&v, &[4]).to_literal().unwrap();
        assert_eq!(literal_i8(&lit).unwrap(), v.to_vec());
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn scalar_literal() {
        let lit = Arg::ScalarF32(2.5).to_literal().unwrap();
        assert_eq!(lit.get_first_element::<f32>().unwrap(), 2.5);
    }
}
