//! AOT artifact manifest: parsing + shape validation.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.txt` describing every
//! lowered entry point (dtype + dims of each parameter and result) and the
//! model configs it lowered for. The runtime parses this before compiling
//! anything so Rust/Python config drift fails loudly at load time, not as a
//! shape error deep inside PJRT.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

/// Element dtype of an artifact parameter/result.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    S8,
    S32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(Dtype::F32),
            "s8" => Ok(Dtype::S8),
            "s32" => Ok(Dtype::S32),
            other => bail!("unknown dtype {other}"),
        }
    }
    pub fn size_bytes(&self) -> usize {
        match self {
            Dtype::F32 | Dtype::S32 => 4,
            Dtype::S8 => 1,
        }
    }
}

/// Shape of one parameter or result ("scalar" == rank 0).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShapeDecl {
    pub dtype: Dtype,
    pub dims: Vec<usize>,
}

impl ShapeDecl {
    pub fn elements(&self) -> usize {
        self.dims.iter().product()
    }
}

/// One lowered entry point.
#[derive(Clone, Debug)]
pub struct ArtifactDecl {
    pub cfg: String,
    pub entry: String,
    pub file: PathBuf,
    pub inputs: Vec<ShapeDecl>,
    pub outputs: Vec<ShapeDecl>,
}

/// Model dims recorded by the AOT driver for cross-language validation.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CfgDims {
    pub d_model: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_head: usize,
    pub d_ffn: usize,
    pub n_layers: usize,
    pub vocab: usize,
    pub sau_batch: usize,
}

/// Parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub dir: PathBuf,
    pub configs: HashMap<String, CfgDims>,
    pub artifacts: Vec<ArtifactDecl>,
}

impl Manifest {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let mut m = Manifest { dir: dir.clone(), ..Default::default() };
        let mut cur: Option<ArtifactDecl> = None;
        for (lno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let mut parts = line.split_whitespace();
            let kind = parts.next().unwrap();
            let rest: Vec<&str> = parts.collect();
            match kind {
                "cfg" => {
                    let name = rest.first().ok_or_else(|| anyhow!("cfg line {lno}"))?;
                    let mut dims = CfgDims::default();
                    for kv in &rest[1..] {
                        let (k, v) = kv
                            .split_once('=')
                            .ok_or_else(|| anyhow!("bad cfg kv {kv} at line {lno}"))?;
                        let v: usize = v.parse().context("cfg value")?;
                        match k {
                            "d_model" => dims.d_model = v,
                            "n_heads" => dims.n_heads = v,
                            "n_kv_heads" => dims.n_kv_heads = v,
                            "d_head" => dims.d_head = v,
                            "d_ffn" => dims.d_ffn = v,
                            "n_layers" => dims.n_layers = v,
                            "vocab" => dims.vocab = v,
                            "sau_batch" => dims.sau_batch = v,
                            _ => bail!("unknown cfg key {k} at line {lno}"),
                        }
                    }
                    m.configs.insert(name.to_string(), dims);
                }
                "artifact" => {
                    if let Some(a) = cur.take() {
                        m.artifacts.push(a);
                    }
                    let [cfg, entry, file] = rest[..] else {
                        bail!("bad artifact line {lno}");
                    };
                    cur = Some(ArtifactDecl {
                        cfg: cfg.to_string(),
                        entry: entry.to_string(),
                        file: dir.join(file),
                        inputs: vec![],
                        outputs: vec![],
                    });
                }
                "in" | "out" => {
                    let a = cur.as_mut().ok_or_else(|| anyhow!("{kind} before artifact"))?;
                    let [_idx, dt, dims] = rest[..] else {
                        bail!("bad {kind} line {lno}");
                    };
                    let dtype = Dtype::parse(dt)?;
                    let dims: Vec<usize> = if dims == "scalar" {
                        vec![]
                    } else {
                        dims.split('x')
                            .map(|d| d.parse().context("dim"))
                            .collect::<Result<_>>()?
                    };
                    let decl = ShapeDecl { dtype, dims };
                    if kind == "in" {
                        a.inputs.push(decl);
                    } else {
                        a.outputs.push(decl);
                    }
                }
                other => bail!("unknown manifest line kind {other} at {lno}"),
            }
        }
        if let Some(a) = cur.take() {
            m.artifacts.push(a);
        }
        Ok(m)
    }

    pub fn find(&self, cfg: &str, entry: &str) -> Option<&ArtifactDecl> {
        self.artifacts.iter().find(|a| a.cfg == cfg && a.entry == entry)
    }

    /// Check the manifest's recorded dims against the Rust config.
    pub fn validate_config(&self, cfg: &crate::config::ModelConfig) -> Result<()> {
        let dims = self
            .configs
            .get(cfg.name)
            .ok_or_else(|| anyhow!("config {} not in manifest", cfg.name))?;
        let pairs = [
            ("d_model", dims.d_model, cfg.d_model),
            ("n_heads", dims.n_heads, cfg.n_heads),
            ("n_kv_heads", dims.n_kv_heads, cfg.n_kv_heads),
            ("d_head", dims.d_head, cfg.d_head),
            ("d_ffn", dims.d_ffn, cfg.d_ffn),
            ("n_layers", dims.n_layers, cfg.n_layers),
            ("vocab", dims.vocab, cfg.vocab),
        ];
        for (name, py, rs) in pairs {
            if py != rs {
                bail!("config drift on {}: python={} rust={} — re-run make artifacts", name, py, rs);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
cfg tiny d_model=256 n_heads=4 n_kv_heads=2 d_head=64 d_ffn=768 n_layers=2 vocab=256 sau_batch=8
artifact tiny qkv_chunk tiny__qkv_chunk.hlo.txt
in 0 f32 128x256
in 1 s8 256x256
in 2 f32 scalar
out 0 s8 4x128x64
out 1 f32 scalar
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = &m.artifacts[0];
        assert_eq!(a.entry, "qkv_chunk");
        assert_eq!(a.inputs.len(), 3);
        assert_eq!(a.inputs[0], ShapeDecl { dtype: Dtype::F32, dims: vec![128, 256] });
        assert_eq!(a.inputs[2].dims.len(), 0);
        assert_eq!(a.outputs[0].dtype, Dtype::S8);
        assert_eq!(m.configs["tiny"].d_ffn, 768);
    }

    #[test]
    fn validate_config_catches_drift() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        let mut cfg = crate::config::TINY.clone();
        assert!(m.validate_config(&cfg).is_ok());
        cfg.d_ffn = 1024;
        assert!(m.validate_config(&cfg).is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Manifest::parse("bogus line", PathBuf::from("/tmp")).is_err());
        assert!(Manifest::parse("in 0 f32 2x2", PathBuf::from("/tmp")).is_err());
    }

    #[test]
    fn elements_product() {
        let s = ShapeDecl { dtype: Dtype::F32, dims: vec![2, 3, 4] };
        assert_eq!(s.elements(), 24);
        let sc = ShapeDecl { dtype: Dtype::F32, dims: vec![] };
        assert_eq!(sc.elements(), 1);
    }
}
