//! A5000 GPU cost model for the Flex-Prefill baseline (Figures 5-6).
//!
//! Models the INT8 Flex-Prefill implementation the paper measures against:
//! dense GEMMs on tensor cores (dequantized to 16-bit per the paper), index
//! generation with its large intermediate tensors and the CPU-offloaded
//! selection step the paper describes, gather-bound sparse attention, and
//! per-layer framework overhead at batch size 1.
//!
//! Derating constants are calibrated so the model reproduces the paper's
//! *measured ratios* (TTFT speedup 1.2-2.5x growing with context, 4.5x
//! Token/Joule) — the paper reports no absolute baselines to pin against,
//! and its Table-I peak numbers alone would not produce its Figure-5 claim
//! (see EXPERIMENTS.md "Fidelity notes"); the factors encode the paper's
//! own qualitative explanation (memory-bound index generation, irregular
//! KV gathers, CPU offload) as explicit, auditable parameters.

use crate::config::{GpuConfig, ModelConfig, BLOCK};
use crate::flexprefill::{HeadIndex, HeadPattern};
use crate::sim::hbm::Traffic;

/// Tensor-core efficiency of the dense GEMM path at batch 1 with the
/// dequantize-to-16-bit INT8 flow (extra dequant kernels, no persistent
/// weights, PyTorch dispatch). CALIBRATION NOTE: reproducing the paper's
/// measured 1.2-2.5x TTFT ratios against its own Table-I peak numbers
/// (222 GPU TOPS vs 5.4 FPGA TOPS) requires the baseline to operate at a
/// few percent of peak, degrading further with context (the paper's
/// "memory-bound" + 24 GB memory-pressure argument). We encode that as an
/// explicit base efficiency with a memory-pressure knee — see
/// EXPERIMENTS.md "Fidelity notes" for the full discussion.
pub const DENSE_EFF_BASE: f64 = 0.034;
/// Context length (tokens) at which memory pressure halves the dense
/// efficiency (activation working set vs 24 GB board memory).
pub const MEM_PRESSURE_KNEE_TOKENS: f64 = 49152.0;

/// Context-dependent dense efficiency.
pub fn dense_eff(s: usize) -> f64 {
    DENSE_EFF_BASE / (1.0 + s as f64 / MEM_PRESSURE_KNEE_TOKENS)
}
/// CPU selection throughput (sorted keys/s) for the offloaded index
/// selection (argsort + prefix scan on one core, per the paper's
/// description of Flex-Prefill's implementation).
pub const CPU_SORT_KEYS_PER_S: f64 = 2.5e7;
/// Per-kernel launch + sync overhead (us) for the many small sparse
/// attention / scoring kernels at batch 1.
pub const LAUNCH_US: f64 = 8.0;
/// Per-layer framework overhead (us): dispatch, dynamic control flow,
/// D2H/H2D sync points of the dynamic sparsity path.
pub const FRAMEWORK_LAYER_US: f64 = 1800.0;
/// Jobs per fused sparse-attention kernel launch.
pub const JOBS_PER_LAUNCH: f64 = 64.0;

/// GPU-side phase breakdown (ms).
#[derive(Clone, Debug, Default)]
pub struct GpuReport {
    pub ttft_ms: f64,
    pub energy_j: f64,
    pub t_linear_ms: f64,
    pub t_index_gpu_ms: f64,
    pub t_index_cpu_ms: f64,
    pub t_attn_ms: f64,
    pub t_framework_ms: f64,
    pub traffic: Traffic,
}

impl GpuReport {
    pub fn tokens_per_joule(&self) -> f64 {
        if self.energy_j <= 0.0 {
            return 0.0;
        }
        1.0 / self.energy_j
    }
}

/// Dense GEMM time (ms) on tensor cores with the derated efficiency.
fn gemm_ms(g: &GpuConfig, s_ctx: usize, m: usize, k: usize, n: usize) -> f64 {
    let flops = 2.0 * m as f64 * k as f64 * n as f64;
    flops / (g.fp16_tflops * 1e12 * dense_eff(s_ctx)) * 1e3
}

/// Cost the Flex-Prefill baseline for one prefill over real index sets.
pub fn simulate_gpu_prefill(
    g: &GpuConfig,
    cfg: &ModelConfig,
    s: usize,
    index_sets: &[Vec<HeadIndex>],
) -> GpuReport {
    assert!(s % BLOCK == 0 && !index_sets.is_empty());
    let n = s / BLOCK;
    let d = cfg.d_model;
    let mut rep = GpuReport::default();
    let bw = g.mem_bw_gbs * 1e9; // bytes/s

    for li in 0..cfg.n_layers {
        let indices = &index_sets[li % index_sets.len()];

        // ---- dense linear path: QKV, o_proj, FFN (fp16 after dequant) ----
        let lin = gemm_ms(g, s, s, d, cfg.q_dim() + 2 * cfg.kv_dim())
            + gemm_ms(g, s, s, cfg.q_dim(), d)
            + gemm_ms(g, s, s, d, 2 * cfg.d_ffn)
            + gemm_ms(g, s, s, cfg.d_ffn, d);
        rep.t_linear_ms += lin;

        // ---- index generation, GPU part: score tensors + pooled maps ----
        // the naive implementation materializes Qhat K^T [128, S] fp16 per
        // head plus pooled maps; traffic = K read + intermediate write+read
        let per_head_bytes = (s * cfg.d_head * 2          // K (fp16)
            + 3 * BLOCK * s * 2) as f64; //  scores write + read + softmax
        let idx_gpu_s = cfg.n_heads as f64 * per_head_bytes / (bw * 0.7);
        rep.t_index_gpu_ms += idx_gpu_s * 1e3;
        rep.traffic.hbm_read_bytes += cfg.n_heads as f64 * per_head_bytes;

        // ---- index selection, CPU offload ----
        // vertical-slash: 2 sorts of S keys; query-aware: sort of N*N keys;
        // plus PCIe transfer of the score tensors
        let mut cpu_keys = 0.0;
        let mut pcie_bytes = 0.0;
        for idx in indices {
            match idx.pattern {
                HeadPattern::VerticalSlash => {
                    cpu_keys += 2.0 * s as f64;
                    pcie_bytes += 2.0 * s as f64 * 4.0;
                }
                HeadPattern::QueryAware => {
                    cpu_keys += (n * n) as f64;
                    pcie_bytes += (n * n) as f64 * 4.0;
                }
            }
        }
        rep.t_index_cpu_ms +=
            (cpu_keys / CPU_SORT_KEYS_PER_S + pcie_bytes / (g.pcie_gbs * 1e9)) * 1e3;

        // ---- sparse attention: gather-bound KV access + small kernels ----
        let jobs: f64 = indices.iter().map(|i| i.job_count() as f64).sum();
        // KV blocks are fp16 on the GPU (dequantized): 2 * 128 * dh * 2 B;
        // GQA reuse is imperfect (the paper's challenge 2c): each q head
        // gathers independently.
        let gather_bytes = jobs * (2 * BLOCK * cfg.d_head * 2) as f64;
        let t_gather = gather_bytes / (bw * g.gather_bw_eff);
        let flops = jobs * (4.0 * (BLOCK * BLOCK * cfg.d_head) as f64);
        let t_compute = flops / (g.fp16_tflops * 1e12 * g.sparse_eff);
        let t_launch = (jobs / JOBS_PER_LAUNCH).ceil() * LAUNCH_US * 1e-6;
        rep.t_attn_ms += (t_gather.max(t_compute) + t_launch) * 1e3;
        rep.traffic.hbm_read_bytes += gather_bytes;

        rep.t_framework_ms += FRAMEWORK_LAYER_US / 1e3;
    }

    rep.ttft_ms = rep.t_linear_ms
        + rep.t_index_gpu_ms
        + rep.t_index_cpu_ms
        + rep.t_attn_ms
        + rep.t_framework_ms;

    // energy: nvidia-smi board power — compute phases near TDP, memory
    // phases lower, CPU-offload phases at GPU idle
    let e = (rep.t_linear_ms + rep.t_attn_ms) * 1e-3 * (0.55 * g.tdp_w)
        + rep.t_index_gpu_ms * 1e-3 * (0.45 * g.tdp_w)
        + (rep.t_index_cpu_ms + rep.t_framework_ms) * 1e-3 * g.idle_power_w * 1.5;
    rep.energy_j = e;
    rep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{a5000, FlexParams, LLAMA32_3B};
    use crate::sim::synth::{synth_model_indices, HeadMix};

    fn idx(n: usize, heads: usize, seed: u64) -> Vec<Vec<HeadIndex>> {
        synth_model_indices(heads, 2, n, 32, &HeadMix::default(), &FlexParams::default(), seed)
    }

    #[test]
    fn ttft_grows_superlinearly_with_context() {
        let g = a5000();
        let cfg = &LLAMA32_3B;
        let a = simulate_gpu_prefill(&g, cfg, 4096, &idx(32, cfg.n_heads, 1));
        let b = simulate_gpu_prefill(&g, cfg, 32768, &idx(256, cfg.n_heads, 1));
        assert!(b.ttft_ms > 8.0 * a.ttft_ms, "{} vs {}", a.ttft_ms, b.ttft_ms);
    }

    #[test]
    fn cpu_offload_contributes() {
        let g = a5000();
        let cfg = &LLAMA32_3B;
        let r = simulate_gpu_prefill(&g, cfg, 16384, &idx(128, cfg.n_heads, 2));
        assert!(r.t_index_cpu_ms > 0.0);
        assert!(r.energy_j > 0.0);
    }

    #[test]
    fn phases_sum_to_ttft() {
        let g = a5000();
        let cfg = &LLAMA32_3B;
        let r = simulate_gpu_prefill(&g, cfg, 8192, &idx(64, cfg.n_heads, 3));
        let sum = r.t_linear_ms + r.t_index_gpu_ms + r.t_index_cpu_ms + r.t_attn_ms
            + r.t_framework_ms;
        assert!((sum - r.ttft_ms).abs() < 1e-9);
    }
}
