//! Table III accuracy proxy: retrieval through the real sparse-attention
//! stack under the paper's three precision modes.
//!
//! The paper's rows are all FlexPrefill variants: BF16 reference, INT8
//! (weights+activations quantized, matmuls dequantized to >=16 bit), and
//! FAST-Prefill's W8A8 (everything int8, int32 accumulate). We reproduce
//! exactly that contrast on the needle-retrieval proxy (see
//! `workload::needle` and DESIGN.md's substitution table): the sparse index
//! generation AND the attention arithmetic both run in the mode under test,
//! so both error sources of the real system are present.
//!
//! All matmuls and the fused softmax-accumulate here go through the tiled
//! kernel layer, which dispatches to the process-wide selected SIMD
//! backend (`tensor::simd`, `FASTP_KERNEL` override) — bit-identical to
//! the scalar oracles by the kernel-layer contract, so Table III numbers
//! do not depend on the backend (the CI kernel matrix pins this).

use crate::config::{FlexParams, BLOCK};
use crate::flexprefill::{coverage, scores};
use crate::model::forward::{attn_finalize, attn_step_w8a8};
use crate::quant::quantize_m;
use crate::tensor::tile;
use crate::tensor::{MatF32, MatI8};
use crate::util::pool::WorkerPool;
use crate::workload::needle::{NeedleTask, RetrievalOutcome};

/// Precision mode of Table III.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    /// "FlexPrefill (BF-16)": full-precision scores and attention (f32 here;
    /// bf16's 8-bit mantissa sits between f32 and int8 — f32 is the
    /// conservative stand-in and is labeled as such in reports).
    Bf16,
    /// "FlexPrefill (INT-8)": Q/K/V quantized to int8 but matmuls computed
    /// on dequantized values (the "requires dequantization to 16 bits" row).
    Int8Deq,
    /// "FAST-Prefill" W8A8: int8 x int8 -> int32 end to end, P requantized.
    W8A8,
}

impl Precision {
    pub fn label(&self) -> &'static str {
        match self {
            Precision::Bf16 => "FlexPrefill (BF-16)",
            Precision::Int8Deq => "FlexPrefill (INT-8)",
            Precision::W8A8 => "FAST-Prefill (W8A8)",
        }
    }
}

/// Select KV blocks for the last query block of a needle task using the
/// vertical-score coverage path (qhat is the last block, so only the
/// vertical selection is meaningful for retrieval; slash/diagonal adds the
/// trailing blocks). Returns ascending block ids.
fn select_blocks(task: &NeedleTask, prec: Precision, params: &FlexParams) -> Vec<u32> {
    let (vertical, slash, _a_hat) = match prec {
        Precision::Bf16 => scores::stream_head_scores_f32(&task.qhat, &task.kblocks),
        Precision::Int8Deq | Precision::W8A8 => {
            // both quantize Q/K before scoring; Int8Deq dequantizes inside
            // the matmul which is numerically identical to the int8 product
            // times scales — the score *tile* differs from Bf16 only by the
            // quantization of Q/K, which is exactly what we model.
            let (q, qs) = quantize_m(&task.qhat);
            let kq: Vec<(MatI8, f32)> = task.kblocks.iter().map(quantize_m).collect();
            scores::stream_head_scores(&q, qs, &kq)
        }
    };
    let mut sel = coverage::coverage_select(&vertical, params.gamma);
    // slash selection maps to blocks behind the last query block
    let n = task.n_blocks;
    for g in coverage::coverage_select(&slash, params.gamma) {
        let b = n as i64 - 1 - g as i64;
        if b >= 0 {
            sel.push(b as u32);
        }
    }
    if params.force_diagonal {
        sel.push(n as u32 - 1);
    }
    if params.force_sink {
        sel.push(0);
    }
    sel.sort_unstable();
    sel.dedup();
    sel
}

/// Stream f32 attention over selected blocks with the fused tiled
/// softmax-accumulate kernel — the same block-major SAU structure the
/// W8A8 path uses, in full precision.
fn stream_f32_attention(qhat: &MatF32, sel: &[u32], mut kv: impl FnMut(usize) -> (MatF32, MatF32)) -> MatF32 {
    let d = qhat.cols;
    let inv = 1.0 / (d as f32).sqrt();
    let mut m = vec![-1e30f32; qhat.rows];
    let mut l = vec![0.0f32; qhat.rows];
    let mut acc = MatF32::zeros(qhat.rows, d);
    for &b in sel {
        let (kb, vb) = kv(b as usize);
        let mut s = tile::matmul_bt(qhat, &kb);
        for x in s.data.iter_mut() {
            *x *= inv;
        }
        tile::fused_softmax_acc(&s, &vb, &mut m, &mut l, &mut acc);
    }
    attn_finalize(&l, &acc)
}

/// Run sparse attention over the selected blocks in the given precision and
/// score retrieval accuracy.
pub fn evaluate(task: &NeedleTask, prec: Precision, params: &FlexParams) -> RetrievalOutcome {
    let sel = select_blocks(task, prec, params);
    let d = task.d;
    let out = match prec {
        Precision::Bf16 => {
            // exact-arithmetic attention, streamed block-major
            stream_f32_attention(&task.qhat, &sel, |b| {
                (task.kblocks[b].clone(), task.vblocks[b].clone())
            })
        }
        Precision::Int8Deq => {
            // quantize Q/K/V, dequantize, f32 attention (the INT-8 row)
            let (q, qs) = quantize_m(&task.qhat);
            let qd = q.dequant(qs);
            stream_f32_attention(&qd, &sel, |b| {
                let (kq, ks) = quantize_m(&task.kblocks[b]);
                let (vq, vs) = quantize_m(&task.vblocks[b]);
                (kq.dequant(ks), vq.dequant(vs))
            })
        }
        Precision::W8A8 => {
            // the exact SAU pipeline: per-block W8A8 online-softmax steps
            let (q, qs) = quantize_m(&task.qhat);
            let mut m = vec![-1e30f32; BLOCK];
            let mut l = vec![0.0f32; BLOCK];
            let mut acc = MatF32::zeros(BLOCK, d);
            for &b in &sel {
                let (kq, ks) = quantize_m(&task.kblocks[b as usize]);
                let (vq, vs) = quantize_m(&task.vblocks[b as usize]);
                attn_step_w8a8(&q, qs, &kq, ks, &vq, vs, &mut m, &mut l, &mut acc, false);
            }
            attn_finalize(&l, &acc)
        }
    };
    task.score(&out)
}

/// Sweep a (context-length, precision) grid — one Table III cell per call.
/// Returns accuracy in percent averaged over `n_tasks` seeded tasks.
/// Tasks are independent (per-task seeds), so they fan out over the
/// worker pool; the mean is accumulated in task order, keeping the cell
/// value identical for every thread count.
pub fn table3_cell_spec(
    spec: &crate::workload::needle::TaskSpec,
    prec: Precision,
    params: &FlexParams,
    n_tasks: usize,
    seed: u64,
) -> f64 {
    let pool = WorkerPool::from_env();
    let accs = pool.map(n_tasks, |t| {
        let task = NeedleTask::from_spec(spec, seed + t as u64);
        evaluate(&task, prec, params).accuracy()
    });
    accs.iter().sum::<f64>() / n_tasks as f64
}

/// Back-compat convenience without outlier channels.
#[allow(clippy::too_many_arguments)]
pub fn table3_cell(
    n_blocks: usize,
    d: usize,
    prec: Precision,
    params: &FlexParams,
    n_tasks: usize,
    match_gain: f32,
    noise: f32,
    seed: u64,
) -> f64 {
    let spec = crate::workload::needle::TaskSpec::new(n_blocks, d, match_gain, noise);
    table3_cell_spec(&spec, prec, params, n_tasks, seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> FlexParams {
        FlexParams::default()
    }

    #[test]
    fn bf16_retrieves_well_at_small_context() {
        let task = NeedleTask::generate(4, 64, 1.2, 0.2, 10);
        let r = evaluate(&task, Precision::Bf16, &params());
        assert!(r.accuracy() > 85.0, "bf16 accuracy {}", r.accuracy());
    }

    #[test]
    fn precision_ordering_holds_on_average() {
        // BF16 >= W8A8-ish ordering with harder noise settings, averaged
        let p = params();
        let bf = table3_cell(8, 64, Precision::Bf16, &p, 3, 0.8, 0.55, 42);
        let w8 = table3_cell(8, 64, Precision::W8A8, &p, 3, 0.8, 0.55, 42);
        assert!(bf >= w8 - 5.0, "bf {bf} vs w8a8 {w8}");
    }

    #[test]
    fn w8a8_close_to_int8deq() {
        // the paper's headline: W8A8 ~= INT8 dequant accuracy
        let p = params();
        let i8d = table3_cell(8, 64, Precision::Int8Deq, &p, 4, 0.9, 0.5, 7);
        let w8 = table3_cell(8, 64, Precision::W8A8, &p, 4, 0.9, 0.5, 7);
        assert!((i8d - w8).abs() < 15.0, "int8 {i8d} vs w8a8 {w8}");
    }

    #[test]
    fn selection_includes_forced_blocks() {
        let task = NeedleTask::generate(6, 64, 1.0, 0.3, 3);
        let sel = select_blocks(&task, Precision::Bf16, &params());
        assert!(sel.contains(&0));
        assert!(sel.contains(&5));
        assert!(sel.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn labels_are_paper_rows() {
        assert!(Precision::Bf16.label().contains("BF-16"));
        assert!(Precision::W8A8.label().contains("FAST-Prefill"));
    }
}
