//! FPGA power/energy model.
//!
//! Component dynamic power scales with resource counts and per-phase
//! activity factors; board static power is constant. Constants are
//! calibrated so the paper's design point draws ~50 W under full load
//! (typical for a U280 accelerator of this utilization; the paper's 4.5x
//! Token/Joule claim against a 230 W A5000 pins the same band).

use super::resources::{resource_report, Resources};
use crate::config::FpgaConfig;

/// Dynamic power coefficients at 175 MHz, full activity.
pub const W_PER_KLUT: f64 = 0.014;
pub const W_PER_KFF: f64 = 0.004;
pub const W_PER_BRAM: f64 = 0.004;
pub const W_PER_URAM: f64 = 0.006;
pub const W_PER_DSP: f64 = 0.0012;
/// HBM interface at full bandwidth.
pub const W_HBM_FULL: f64 = 6.5;

/// Dynamic power (W) of a resource vector at given activity in [0, 1].
pub fn dynamic_w(r: &Resources, activity: f64) -> f64 {
    activity
        * (r.lut_k * W_PER_KLUT
            + r.ff_k * W_PER_KFF
            + r.bram * W_PER_BRAM
            + r.uram * W_PER_URAM
            + r.dsp * W_PER_DSP)
}

/// Average board power (W) given compute activity and HBM bandwidth
/// utilization over an interval.
pub fn board_power_w(f: &FpgaConfig, compute_activity: f64, hbm_util: f64) -> f64 {
    let rep = resource_report(f);
    f.idle_power_w + dynamic_w(&rep.total, compute_activity) + W_HBM_FULL * hbm_util
}

/// Energy (J) over `us` microseconds.
pub fn energy_j(f: &FpgaConfig, compute_activity: f64, hbm_util: f64, us: f64) -> f64 {
    board_power_w(f, compute_activity, hbm_util) * us * 1e-6
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::u280_fast_prefill;

    #[test]
    fn full_load_power_in_band() {
        let f = u280_fast_prefill();
        let p = board_power_w(&f, 0.85, 0.6);
        assert!(p > 35.0 && p < f.max_power_w + 10.0, "power {p}");
    }

    #[test]
    fn idle_power_is_floor() {
        let f = u280_fast_prefill();
        assert!((board_power_w(&f, 0.0, 0.0) - f.idle_power_w).abs() < 1e-9);
    }

    #[test]
    fn energy_scales_with_time() {
        let f = u280_fast_prefill();
        let a = energy_j(&f, 0.5, 0.5, 1e6);
        let b = energy_j(&f, 0.5, 0.5, 2e6);
        assert!((b / a - 2.0).abs() < 1e-9);
    }
}
