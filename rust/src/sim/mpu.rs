//! Hybrid MPU cycle model (paper §IV-D).
//!
//! Each 32x32 systolic array retires one 32x32x1 MAC slab per cycle when
//! fed; a tiled M x K x N matmul on one array costs
//! `ceil(M/32)*ceil(N/32)*(K + FILL)` cycles (output-stationary drain
//! folded into FILL). Arrays work on independent output tiles, so the MPU
//! finishes in `ceil(tiles / arrays)` rounds. The DSP-only ablation simply
//! instantiates half the arrays (Fig. 8).

use crate::config::FpgaConfig;

/// Pipeline fill+drain cycles per output tile.
pub const TILE_FILL_CYCLES: f64 = 64.0;

/// Cycle cost of an M x K x N int8 matmul on the full hybrid MPU.
pub fn matmul_cycles(f: &FpgaConfig, m: usize, k: usize, n: usize) -> f64 {
    let arrays = (f.mpu_dsp_arrays + f.mpu_lut_arrays).max(1) as f64;
    let ad = f.mpu_array_dim as f64;
    let tiles = (m as f64 / ad).ceil() * (n as f64 / ad).ceil();
    let per_tile = k as f64 + TILE_FILL_CYCLES;
    (tiles / arrays).ceil() * per_tile
}

/// Same in microseconds at the achieved clock.
pub fn matmul_us(f: &FpgaConfig, m: usize, k: usize, n: usize) -> f64 {
    matmul_cycles(f, m, k, n) / f.freq_mhz
}

/// Achieved MAC utilization of a matmul (for roofline reporting).
pub fn utilization(f: &FpgaConfig, m: usize, k: usize, n: usize) -> f64 {
    let ideal_macs = (m * k * n) as f64;
    let cycles = matmul_cycles(f, m, k, n);
    let peak_macs = f.mpu_macs_per_cycle() as f64 * cycles;
    ideal_macs / peak_macs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{u280_dsp_only, u280_fast_prefill};

    #[test]
    fn hybrid_is_about_2x_dsp_only() {
        let full = u280_fast_prefill();
        let half = u280_dsp_only();
        // use a workload-sized matmul — tiny tile counts quantize the ratio
        let a = matmul_cycles(&full, 512, 64, 512);
        let b = matmul_cycles(&half, 512, 64, 512);
        assert!(b / a > 1.5 && b / a < 2.5, "ratio {}", b / a);
    }

    #[test]
    fn cycles_scale_with_k() {
        let f = u280_fast_prefill();
        let a = matmul_cycles(&f, 128, 64, 128);
        let b = matmul_cycles(&f, 128, 512, 128);
        assert!(b > 3.0 * a);
    }

    #[test]
    fn score_tile_latency_sane() {
        // 128x64x128 on 12 arrays @175MHz: 16 tiles / 12 arrays -> 2 rounds
        // x 128 cycles = 256 cycles ~ 1.5us
        let f = u280_fast_prefill();
        let us = matmul_us(&f, 128, 64, 128);
        assert!(us > 0.5 && us < 5.0, "{us}");
    }

    #[test]
    fn utilization_bounded() {
        let f = u280_fast_prefill();
        for (m, k, n) in [(128, 64, 128), (128, 2048, 768), (32, 32, 32)] {
            let u = utilization(&f, m, k, n);
            assert!(u > 0.0 && u <= 1.0, "{u}");
        }
    }

    #[test]
    fn big_matmuls_utilize_well() {
        let f = u280_fast_prefill();
        assert!(utilization(&f, 128, 2048, 768) > 0.8);
    }
}
