//! Synthetic head-score generation at paper scale.
//!
//! Running the functional pipeline at 128K tokens on CPU is infeasible, but
//! the *index-generation, scheduling and cache* code only needs score
//! distributions, which we can synthesize directly at block granularity at
//! any context length. The generator produces heavy-tailed vertical/slash
//! and pooled-attention distributions whose resulting FlexPrefill densities
//! match the bands measured on the functional pipeline at 4K-8K (see
//! EXPERIMENTS.md §calibration), so the simulator consumes *real* index
//! sets computed by the *real* Algorithm 1 at full scale.

use crate::config::FlexParams;
use crate::flexprefill::{generate_head_index, HeadIndex, HeadStats};
use crate::tensor::MatF32;
use crate::util::prng::Prng;

/// Head archetypes observed in dynamic sparse attention.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HeadKind {
    /// A few dominant global columns + local diagonal: vertical-slash.
    Sink,
    /// Strong locality: slash-dominant.
    Local,
    /// Distributed relevance: drives the query-aware path.
    Diffuse,
}

/// Mix of head kinds in a model (fractions sum to 1).
#[derive(Clone, Copy, Debug)]
pub struct HeadMix {
    pub sink: f64,
    pub local: f64,
    pub diffuse: f64,
}

impl Default for HeadMix {
    /// Band measured on the functional pipeline (small100m, mixed prompts).
    fn default() -> Self {
        HeadMix { sink: 0.35, local: 0.40, diffuse: 0.25 }
    }
}

fn zipf_scores(rng: &mut Prng, n: usize, alpha: f64, n_peaks: usize) -> Vec<f32> {
    // scale-free heavy tail: a handful of strong peaks carry most of the
    // mass regardless of N (attention concentrates; coverage-k stays
    // roughly constant as context grows — the FlexPrefill observation)
    let mut v: Vec<f32> = (0..n)
        .map(|k| ((1.0 + k as f64).powf(-alpha) * (0.2 + 0.2 * rng.f32() as f64)) as f32)
        .collect();
    rng.shuffle(&mut v);
    for _ in 0..n_peaks.max(1) {
        let at = rng.below(n);
        v[at] += 3.0 + 6.0 * rng.f32();
    }
    v
}

/// Generate per-head statistics for a head of `kind` over `n` blocks.
pub fn synth_head_stats(kind: HeadKind, n: usize, d: usize, rng: &mut Prng) -> HeadStats {
    let (v_alpha, s_alpha, v_peaks, s_peaks, agree) = match kind {
        // (vertical decay, slash decay, vertical peaks, slash peaks,
        //  pooled-estimate agreement with true scores)
        HeadKind::Sink => (2.4, 1.8, 6, 2, 0.3),
        HeadKind::Local => (1.8, 2.8, 2, 6, 0.3),
        HeadKind::Diffuse => (1.5, 1.5, 3, 3, 0.995),
    };
    let mut vertical = zipf_scores(rng, n, v_alpha, v_peaks);
    vertical[0] += 4.0; // attention sink: block 0 always strong
    // slash scores indexed by diagonal distance: locality = fast decay in g
    let mut slash: Vec<f32> = (0..n)
        .map(|g| ((1.0 + g as f64).powf(-s_alpha) * (0.2 + 0.2 * rng.f32() as f64)) as f32)
        .collect();
    slash[0] += 4.0; // the diagonal itself always carries mass
    for _ in 0..s_peaks {
        let at = rng.below(n);
        slash[at] += 2.0 + 3.0 * rng.f32();
    }
    // normalize vertical to total mass BLOCK (as the real pipeline produces)
    let total: f32 = vertical.iter().sum();
    for v in vertical.iter_mut() {
        *v *= 128.0 / total.max(1e-6);
    }
    let a_hat: Vec<f32> = vertical.iter().map(|v| v / 128.0).collect();
    // pooled estimate: convex blend of truth and noise — `agree` controls
    // the JSD and hence the pattern decision
    let mut a_bar: Vec<f32> = a_hat
        .iter()
        .map(|&t| (agree as f32) * t + (1.0 - agree as f32) * (rng.f32() / n as f32 * 2.0))
        .collect();
    let s: f32 = a_bar.iter().sum();
    for v in a_bar.iter_mut() {
        *v /= s.max(1e-9);
    }
    // pooled q/k: each query block anchors on a few key directions so the
    // query-aware map's rows are concentrated (scale-free coverage)
    let kpool = MatF32::from_fn(n, d, |_, _| rng.normal());
    let gain = 1.3f32;
    let qpool_all = MatF32::from_fn(n, d, |b, c| {
        let anchor = (b * 7 + 3) % (b + 1).max(1); // causal-reachable anchor
        gain * kpool.at(anchor, c) + 0.4 * rng.normal()
    });
    HeadStats { vertical, slash, a_bar, a_hat, qpool_all, kpool }
}

/// Generate full-model index sets at paper scale: `heads` per layer,
/// `layers` simulated layers (statistically iid), `n` blocks.
///
/// Head generation fans out over the shared worker pool: a cheap
/// sequential pass draws each head's archetype and forks an independent
/// PRNG stream for it, then the stats + Algorithm-1 jobs run in parallel.
/// Forked streams make the result deterministic for every thread count.
pub fn synth_model_indices(
    heads: usize,
    layers: usize,
    n: usize,
    d: usize,
    mix: &HeadMix,
    params: &FlexParams,
    seed: u64,
) -> Vec<Vec<HeadIndex>> {
    synth_model_indices_pool(
        heads,
        layers,
        n,
        d,
        mix,
        params,
        seed,
        &crate::util::pool::WorkerPool::from_env(),
    )
}

/// [`synth_model_indices`] over an explicit worker pool.
#[allow(clippy::too_many_arguments)]
pub fn synth_model_indices_pool(
    heads: usize,
    layers: usize,
    n: usize,
    d: usize,
    mix: &HeadMix,
    params: &FlexParams,
    seed: u64,
    pool: &crate::util::pool::WorkerPool,
) -> Vec<Vec<HeadIndex>> {
    let mut rng = Prng::new(seed);
    let jobs: Vec<(HeadKind, Prng)> = (0..layers * heads)
        .map(|i| {
            let r = rng.f32() as f64;
            let kind = if r < mix.sink {
                HeadKind::Sink
            } else if r < mix.sink + mix.local {
                HeadKind::Local
            } else {
                HeadKind::Diffuse
            };
            (kind, rng.fork(i as u64))
        })
        .collect();
    let indices = pool.map(jobs.len(), |i| {
        let (kind, child) = &jobs[i];
        let mut rng = child.clone();
        let stats = synth_head_stats(*kind, n, d, &mut rng);
        generate_head_index(&stats, params)
    });
    let mut out: Vec<Vec<HeadIndex>> = Vec::with_capacity(layers);
    let mut it = indices.into_iter();
    for _ in 0..layers {
        out.push(it.by_ref().take(heads).collect());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sink_heads_choose_vertical_slash() {
        let mut rng = Prng::new(1);
        let params = FlexParams::default();
        let mut vs = 0;
        for _ in 0..10 {
            let stats = synth_head_stats(HeadKind::Sink, 64, 32, &mut rng);
            let idx = generate_head_index(&stats, &params);
            if idx.pattern == crate::flexprefill::HeadPattern::VerticalSlash {
                vs += 1;
            }
        }
        assert!(vs >= 8, "only {vs}/10 vertical-slash");
    }

    #[test]
    fn diffuse_heads_choose_query_aware() {
        let mut rng = Prng::new(2);
        let params = FlexParams::default();
        let mut qa = 0;
        for _ in 0..10 {
            let stats = synth_head_stats(HeadKind::Diffuse, 64, 32, &mut rng);
            let idx = generate_head_index(&stats, &params);
            if idx.pattern == crate::flexprefill::HeadPattern::QueryAware {
                qa += 1;
            }
        }
        assert!(qa >= 7, "only {qa}/10 query-aware");
    }

    #[test]
    fn density_falls_with_context() {
        let params = FlexParams::default();
        let mix = HeadMix::default();
        let d32 = mean_density(&synth_model_indices(8, 2, 32, 32, &mix, &params, 3));
        let d256 = mean_density(&synth_model_indices(8, 2, 256, 32, &mix, &params, 3));
        assert!(d256 < d32, "density {d256} !< {d32}");
    }

    #[test]
    fn synth_indices_deterministic_across_thread_counts() {
        let params = FlexParams::default();
        let mix = HeadMix::default();
        let run = |threads: usize| {
            let pool = crate::util::pool::WorkerPool::with_threads(threads);
            synth_model_indices_pool(6, 2, 48, 16, &mix, &params, 11, &pool)
        };
        let a = run(1);
        let b = run(8);
        for (la, lb) in a.iter().zip(&b) {
            for (ia, ib) in la.iter().zip(lb) {
                assert_eq!(ia.pattern, ib.pattern);
                assert_eq!(ia.blocks, ib.blocks);
            }
        }
    }

    #[test]
    fn indices_are_valid_at_scale() {
        let params = FlexParams::default();
        let sets = synth_model_indices(4, 1, 128, 32, &HeadMix::default(), &params, 7);
        for idx in &sets[0] {
            idx.validate().unwrap();
        }
    }

    fn mean_density(sets: &[Vec<HeadIndex>]) -> f64 {
        let mut s = 0.0;
        let mut c = 0;
        for layer in sets {
            for idx in layer {
                s += idx.density();
                c += 1;
            }
        }
        s / c as f64
    }
}
