//! Off-chip memory timing model (HBM2 + DDR4 on U280).
//!
//! Burst-efficiency model: a transfer of `bytes` issued as bursts of
//! `burst_bytes` achieves `peak * burst/(burst + OVERHEAD)` of the peak
//! bandwidth — short head-dependent reads (the paper's challenge 2) are
//! penalized, long sequential streams approach peak. Channel parallelism is
//! folded into the peak figure; a transfer additionally pays a fixed
//! per-request latency.

/// Per-burst protocol/row-activation overhead (equivalent bytes at peak bw).
pub const BURST_OVERHEAD_BYTES: f64 = 64.0;

/// Fixed request latency (ns) — HBM2 closed-page random access.
pub const HBM_REQ_LATENCY_NS: f64 = 120.0;
pub const DDR_REQ_LATENCY_NS: f64 = 90.0;

/// One off-chip memory channel group.
#[derive(Clone, Copy, Debug)]
pub struct MemModel {
    pub peak_gbs: f64,
    pub req_latency_ns: f64,
}

impl MemModel {
    pub fn hbm(peak_gbs: f64) -> Self {
        MemModel { peak_gbs, req_latency_ns: HBM_REQ_LATENCY_NS }
    }
    pub fn ddr(peak_gbs: f64) -> Self {
        MemModel { peak_gbs, req_latency_ns: DDR_REQ_LATENCY_NS }
    }

    /// Effective bandwidth (GB/s) at a given burst size.
    pub fn eff_gbs(&self, burst_bytes: f64) -> f64 {
        self.peak_gbs * burst_bytes / (burst_bytes + BURST_OVERHEAD_BYTES)
    }

    /// Time (us) to move `bytes` using bursts of `burst_bytes`.
    pub fn transfer_us(&self, bytes: f64, burst_bytes: f64) -> f64 {
        if bytes <= 0.0 {
            return 0.0;
        }
        let bursts = (bytes / burst_bytes).ceil().max(1.0);
        let bw = self.eff_gbs(burst_bytes); // GB/s == bytes/ns
        bytes / bw * 1e-3 + bursts * self.req_latency_ns * 1e-3 / 16.0
        // /16: request pipelining across the 16+ in-flight transactions the
        // HBM AXI adapters sustain — latency is mostly hidden, not per-burst.
    }
}

/// Traffic accounting per memory kind.
#[derive(Clone, Copy, Debug, Default)]
pub struct Traffic {
    pub hbm_read_bytes: f64,
    pub hbm_write_bytes: f64,
    pub ddr_read_bytes: f64,
}

impl Traffic {
    pub fn total_gb(&self) -> f64 {
        (self.hbm_read_bytes + self.hbm_write_bytes + self.ddr_read_bytes) / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn long_bursts_approach_peak() {
        let m = MemModel::hbm(460.0);
        assert!(m.eff_gbs(16384.0) > 0.99 * 460.0);
        assert!(m.eff_gbs(128.0) < 0.70 * 460.0);
    }

    #[test]
    fn transfer_monotone_in_bytes() {
        let m = MemModel::hbm(460.0);
        let a = m.transfer_us(1e6, 4096.0);
        let b = m.transfer_us(2e6, 4096.0);
        assert!(b > a);
    }

    #[test]
    fn small_bursts_cost_more() {
        let m = MemModel::hbm(460.0);
        let seq = m.transfer_us(1e6, 16384.0);
        let rnd = m.transfer_us(1e6, 128.0);
        assert!(rnd > 1.3 * seq, "rnd {rnd} seq {seq}");
    }

    #[test]
    fn ddr_slower_than_hbm() {
        let hbm = MemModel::hbm(460.0);
        let ddr = MemModel::ddr(38.0);
        assert!(ddr.transfer_us(1e6, 4096.0) > hbm.transfer_us(1e6, 4096.0));
    }

    #[test]
    fn zero_bytes_zero_time() {
        assert_eq!(MemModel::hbm(460.0).transfer_us(0.0, 4096.0), 0.0);
    }
}
