//! Special Function Unit timing (softmax, SiLU, normalization) — §IV-E.
//!
//! The SFU is a 128-lane elementwise pipeline shared across compute units
//! and arbitrated by the global FSM; a reduction (softmax denominator,
//! RMS) costs one extra pass. LUT-based exp approximation retires one
//! element per lane per cycle.

use crate::config::FpgaConfig;

pub const SFU_LANES: f64 = 128.0;
pub const SFU_PIPE_FILL: f64 = 32.0;

/// Time (us) for an elementwise pass over `elems` elements.
pub fn elementwise_us(f: &FpgaConfig, elems: f64) -> f64 {
    ((elems / SFU_LANES) + SFU_PIPE_FILL) / f.freq_mhz
}

/// Time (us) for a softmax over `rows` rows of `cols` (max + exp-sum +
/// normalize ~ 3 passes, pipelined to ~2.2).
pub fn softmax_us(f: &FpgaConfig, rows: f64, cols: f64) -> f64 {
    elementwise_us(f, rows * cols) * 2.2
}

/// SiLU / gating over `elems`.
pub fn silu_us(f: &FpgaConfig, elems: f64) -> f64 {
    elementwise_us(f, elems) * 1.2
}

/// RMSNorm over `rows` x `cols` (square+reduce+scale ~ 2 passes).
pub fn rmsnorm_us(f: &FpgaConfig, rows: f64, cols: f64) -> f64 {
    elementwise_us(f, rows * cols) * 2.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::u280_fast_prefill;

    #[test]
    fn softmax_tile_latency_reasonable() {
        let f = u280_fast_prefill();
        // 128x128 tile: ~128 cycles + fill, x2.2 -> < 3us
        let t = softmax_us(&f, 128.0, 128.0);
        assert!(t > 0.2 && t < 5.0, "{t}");
    }

    #[test]
    fn scales_linearly() {
        let f = u280_fast_prefill();
        let a = elementwise_us(&f, 1e6);
        let b = elementwise_us(&f, 2e6);
        assert!((b / a - 2.0).abs() < 0.05);
    }
}
