//! Whole-prefill FPGA performance model: composes the MPU/SFU/HBM/cache
//! models over the real block-major schedules to produce TTFT and energy
//! for a (model, context) point — the generator behind Figures 5-8.
//!
//! Phase structure per layer (paper Fig. 2): chunked QKV generation ->
//! SIGU -> SAU (block-major waves, liveness cache, lookahead prefetch) ->
//! FFN. Weight and activation streams overlap compute (dataflow design);
//! each phase costs max(compute, memory) plus FSM transition overhead.
//!
//! SAU cache traffic is **not** re-derived here: the simulator prices the
//! events emitted by the canonical [`ScheduleWalk`] spine
//! (`coordinator::walk`) — the same walk the functional engine drives —
//! so the two sides produce identical `CacheStats` by construction
//! (pinned by `rust/tests/memory_spine.rs`).
//!
//! Batch-merged schedules price through the same spine
//! ([`simulate_prefill_batch`]): co-resident lanes share each layer's
//! weight streams (read once per batch, not once per request), merge
//! their SAU waves (co-missing lanes fetch back-to-back as one long HBM
//! burst, and merged-visit compute overlaps the next fetch), and pay FSM
//! phase transitions once — which is why a batch point beats N
//! independent solo simulations on both TTFT and traffic.

use crate::config::{FpgaConfig, ModelConfig, BLOCK};
use crate::coordinator::engine::Phase;
use crate::coordinator::joblist::{build_schedule, build_schedule_batch, Schedule};
use crate::coordinator::walk::{k_block_bytes, IndexGenPricing, IndexGenWalk, ScheduleWalk};
use crate::flexprefill::HeadIndex;
use crate::kvcache::LivenessCache;

use super::hbm::{MemModel, Traffic};
use super::{mpu, power, sfu};

/// FSM phase-transition overhead (cycles).
pub const FSM_PHASE_CYCLES: f64 = 256.0;

/// Simulated outcome for one prefill.
#[derive(Clone, Debug, Default)]
pub struct SimReport {
    pub ttft_ms: f64,
    pub energy_j: f64,
    pub t_qkv_ms: f64,
    pub t_sigu_ms: f64,
    pub t_sau_ms: f64,
    pub t_ffn_ms: f64,
    pub traffic: Traffic,
    pub cache_hit_rate: f64,
    pub avg_density: f64,
    pub total_jobs: usize,
    /// Mean MPU utilization during compute phases.
    pub mpu_utilization: f64,
}

impl SimReport {
    pub fn tokens_per_joule(&self) -> f64 {
        if self.energy_j <= 0.0 {
            return 0.0;
        }
        1.0 / self.energy_j
    }
}

/// Per-lane memory attribution of a batch-merged simulation.
#[derive(Clone, Debug, Default)]
pub struct LaneSim {
    pub context_tokens: usize,
    /// KV-block HBM fetch traffic attributed to this lane (bytes).
    pub hbm_read_bytes: f64,
    /// IndexGen K-stream HBM traffic attributed to this lane (bytes) —
    /// the lane's share of the fused per-kv-head stream, priced by
    /// [`IndexGenWalk::price`] (the same spine the engine charges), so
    /// engine and simulator agree on it exactly.
    pub sigu_hbm_read_bytes: u64,
    pub cache_hit_rate: f64,
    pub bypasses: u64,
    pub jobs: usize,
}

/// Outcome of a batch-merged prefill simulation: the combined (makespan)
/// report plus per-lane memory attribution.
#[derive(Clone, Debug)]
pub struct BatchSimReport {
    pub combined: SimReport,
    pub lanes: Vec<LaneSim>,
}

/// KV block bytes (int8 K + V for one kv head).
fn kv_block_bytes(cfg: &ModelConfig) -> f64 {
    cfg.kv_block_bytes() as f64
}

/// Per-layer liveness cache for the simulator: converts the platform's
/// byte budget to block slots, then defers to the **shared**
/// [`crate::kvcache::layer_cache`] derivation — the same one
/// `Engine::new_layer_cache` uses, so the spine's two consumers cannot
/// drift apart on cache sizing or the t_hot threshold.
fn sim_layer_cache(
    f: &FpgaConfig,
    cfg: &ModelConfig,
    n: usize,
    schedule: &Schedule,
) -> LivenessCache {
    let cache_blocks = if f.kv_cache_bytes == 0 {
        0
    } else {
        (f.kv_cache_bytes as f64 / kv_block_bytes(cfg)) as usize
    };
    crate::kvcache::layer_cache(
        cache_blocks,
        f.hot_fraction,
        f.t_hot_frac,
        n,
        cfg.group_size(),
        schedule.uses.iter().copied(),
    )
}

/// Price one SAU walk — solo or batch-merged — over per-lane caches,
/// updating `traffic`; returns (time_us, compute_us_portion).
///
/// This is the simulator's consumer of the [`ScheduleWalk`] spine: per
/// emitted coordinate visit, every participating lane's jobs run on the
/// MPU/SFU and every *fetching* lane's KV block moves over HBM. Lanes
/// co-missing a coordinate fetch back-to-back as **one** coalesced burst
/// (the merged-wave saving); the lookahead prefetcher overlaps each
/// visit's fetch with the previous visit's compute within a wave.
/// Cacheless lanes (capacity 0) instead pay the paper's on-demand
/// short-burst gather per job, serialized with compute.
pub fn price_sau_walk(
    f: &FpgaConfig,
    cfg: &ModelConfig,
    walk: &ScheduleWalk,
    caches: &mut [LivenessCache],
    traffic: &mut Traffic,
) -> (f64, f64) {
    let hbm = MemModel::hbm(f.hbm_bw_gbs);
    let blk_bytes = kv_block_bytes(cfg);
    // per-job compute: score 128xdhx128 + PV 128x128xdh on the MPU + SFU
    // softmax, fused/pipelined -> max of the two engines
    let score_us = mpu::matmul_us(f, BLOCK, cfg.d_head, BLOCK);
    let pv_us = mpu::matmul_us(f, BLOCK, BLOCK, cfg.d_head);
    let sm_us = sfu::softmax_us(f, BLOCK as f64, BLOCK as f64);
    let job_us = (score_us + pv_us).max(sm_us);
    // on-demand gather (cacheless design): the block arrives as many short
    // beats with bounded memory-level parallelism and no prefetch overlap —
    // the paper's challenge 2(b) "many small off-chip memory reads ...
    // under-utilized bandwidth and pipeline stalls". Exposed latency:
    // beats * t_req / MLP.
    let demand_beats = (blk_bytes / 128.0).ceil();
    let demand_fetch_us =
        demand_beats * hbm.req_latency_ns * 1e-3 / 5.0 + hbm.transfer_us(blk_bytes, 128.0);
    let cacheless: Vec<bool> = caches.iter().map(|c| c.capacity() == 0).collect();

    let mut total_us = 0.0;
    let mut compute_us_total = 0.0;
    // lookahead prefetch overlap does not span waves
    let mut prev_compute_us = 0.0f64;
    let mut cur_wave = usize::MAX;
    walk.run(caches, |v| {
        if v.wave != cur_wave {
            cur_wave = v.wave;
            prev_compute_us = 0.0;
        }
        let mut compute_us = 0.0;
        let mut demand_us = 0.0;
        let mut fetching = 0.0f64;
        for lv in v.lanes {
            let jobs = lv.jobs as f64;
            compute_us += jobs * job_us;
            if cacheless[lv.lane as usize] {
                traffic.hbm_read_bytes += blk_bytes * jobs;
                demand_us += jobs * demand_fetch_us;
            } else if lv.outcome.is_fetch() {
                traffic.hbm_read_bytes += blk_bytes;
                fetching += 1.0;
            }
        }
        // coordinated burst fetch (prefetched design): co-missing lanes'
        // blocks stream back-to-back as one coalesced burst...
        let mem_us = if fetching > 0.0 {
            hbm.transfer_us(blk_bytes * fetching, blk_bytes * fetching)
        } else {
            0.0
        };
        // ...and the fetch overlaps the previous visit's compute; only
        // the remainder stalls the pipe
        let stall = (mem_us - prev_compute_us).max(0.0);
        total_us += compute_us + demand_us + stall;
        compute_us_total += compute_us;
        prev_compute_us = compute_us;
    });
    (total_us, compute_us_total)
}

/// SIGU timing for one fused index-generation group: the group's lanes
/// share one sequential K stream per kv head over the **merged**
/// (longest-lane) block extent — priced through the canonical
/// [`IndexGenWalk`] spine, the same one the engine charges, so fused
/// engine stats and this simulator agree exactly, warm and cold — while
/// score (MPU, per query head per block) and the streaming selection pass
/// still run per lane. With one lane this is exactly the solo SIGU cost.
pub fn sigu_group_us(
    f: &FpgaConfig,
    cfg: &ModelConfig,
    lane_blocks: &[usize],
    traffic: &mut Traffic,
) -> (f64, IndexGenPricing) {
    let hbm = MemModel::hbm(f.hbm_bw_gbs);
    let walk = IndexGenWalk::new(cfg.n_kv_heads, cfg.group_size(), lane_blocks.to_vec());
    let pricing = walk.price(k_block_bytes(cfg));
    let kblk_bytes = (BLOCK * cfg.d_head) as f64;
    // one sequential burst stream of K per kv head, merged extent
    let stream_us = hbm.transfer_us(kblk_bytes * walk.merged_blocks() as f64, 16384.0)
        * cfg.n_kv_heads as f64;
    traffic.hbm_read_bytes += pricing.fused_bytes as f64;
    let mut score_us = 0.0;
    let mut select_us = 0.0;
    for &n in lane_blocks {
        // score compute: per query head, per block: 128 x dh x 128
        score_us += mpu::matmul_us(f, BLOCK, cfg.d_head, BLOCK) * (n * cfg.n_heads) as f64;
        // selection: streaming coverage scan, ~4 passes over N-length
        // buffers per head + pooled map for query-aware heads (N x N / 4)
        select_us += cfg.n_heads as f64
            * (sfu::elementwise_us(f, 4.0 * n as f64)
                + sfu::elementwise_us(f, (n * n) as f64 * 0.25));
    }
    (stream_us.max(score_us) + select_us, pricing)
}

/// Priced marginal TTFT saving (µs, per layer) of adding a candidate lane
/// to an existing phase-fusion group — the simulator's admission-time
/// answer to "is growing the group worth it?". The saving is the memory
/// stream the candidate would pay again solo but rides fused: the layer's
/// weight stream for the linear phases, the overlapping K extent (once
/// per kv head) for IndexGen, and the amortized FSM phase transition for
/// SAU (whose KV traffic is already priced per lane by the merged walk).
pub fn marginal_fuse_saving_us(
    f: &FpgaConfig,
    cfg: &ModelConfig,
    phase: Phase,
    group_blocks: &[usize],
    cand_blocks: usize,
) -> f64 {
    if group_blocks.is_empty() {
        return 0.0;
    }
    let hbm = MemModel::hbm(f.hbm_bw_gbs);
    match phase {
        Phase::Qkv => {
            let w = (cfg.d_model * (cfg.q_dim() + 2 * cfg.kv_dim())) as f64;
            hbm.transfer_us(w, 16384.0)
        }
        Phase::FfnLogits => {
            let w = (cfg.q_dim() * cfg.d_model + 3 * cfg.d_model * cfg.d_ffn) as f64;
            hbm.transfer_us(w, 16384.0)
        }
        Phase::IndexGen => {
            let merged = group_blocks.iter().copied().max().unwrap_or(0);
            let overlap = cand_blocks.min(merged);
            hbm.transfer_us((BLOCK * cfg.d_head) as f64 * overlap as f64, 16384.0)
                * cfg.n_kv_heads as f64
        }
        Phase::Sau => FSM_PHASE_CYCLES / f.freq_mhz,
        Phase::Done => 0.0,
    }
}

/// Linear layers (QKV + o_proj + FFN) for one layer over every lane's
/// chunks: weight-stationary tiles, activation streaming overlapped. The
/// batch's saving is structural — the layer's weights stream from HBM
/// **once** for all lanes, while per-lane activations still move.
fn linear_layers_us(
    f: &FpgaConfig,
    cfg: &ModelConfig,
    lane_s: &[usize],
    traffic: &mut Traffic,
) -> (f64, f64, f64) {
    let hbm = MemModel::hbm(f.hbm_bw_gbs);
    let d = cfg.d_model;
    let qkv_macs_cols = cfg.q_dim() + 2 * cfg.kv_dim();
    let mut qkv_us = 0.0;
    let mut oproj_us = 0.0;
    let mut ffn_us = 0.0;
    let mut act_bytes = 0.0;
    for &s in lane_s {
        qkv_us += mpu::matmul_us(f, s, d, qkv_macs_cols);
        oproj_us += mpu::matmul_us(f, s, cfg.q_dim(), d);
        ffn_us += mpu::matmul_us(f, s, d, 2 * cfg.d_ffn)
            + mpu::matmul_us(f, s, cfg.d_ffn, d)
            + sfu::silu_us(f, (s * cfg.d_ffn) as f64);
        // activations read+written once per stage, per lane
        act_bytes += (s * d) as f64 * 6.0;
    }
    // weights streamed once per layer for the whole batch (int8, resident
    // in HBM)
    let w_bytes = (d * qkv_macs_cols + cfg.q_dim() * d + 3 * d * cfg.d_ffn) as f64;
    traffic.hbm_read_bytes += w_bytes + act_bytes * 0.5;
    traffic.hbm_write_bytes += act_bytes * 0.5;
    let mem_us = hbm.transfer_us(w_bytes + act_bytes, 16384.0);
    let compute = qkv_us + oproj_us + ffn_us;
    (compute.max(mem_us), qkv_us + oproj_us, ffn_us)
}

/// Full prefill simulation over real index sets.
///
/// `index_sets[layer][head]` — from the functional pipeline (small scale)
/// or `synth::synth_model_indices` (paper scale). If fewer layers of
/// indices than `cfg.n_layers` are provided they are cycled (layers are
/// statistically identical).
pub fn simulate_prefill(
    f: &FpgaConfig,
    cfg: &ModelConfig,
    s: usize,
    index_sets: &[Vec<HeadIndex>],
) -> SimReport {
    simulate_prefill_batch(f, cfg, &[s], &[index_sets]).combined
}

/// Batch-merged prefill simulation: co-resident requests ("lanes") run
/// the whole layer body fused — shared weight streams, per-lane SIGU, and
/// one merged SAU sweep priced through the canonical [`ScheduleWalk`] —
/// producing the combined (makespan) report plus per-lane memory
/// attribution. With one lane this is exactly [`simulate_prefill`].
///
/// Per-lane cache outcomes are identical to each lane's solo simulation
/// (the spine's stats-identity contract); the batch's TTFT/traffic saving
/// comes from amortized weight streams, coalesced co-miss bursts and
/// once-per-phase FSM transitions.
pub fn simulate_prefill_batch(
    f: &FpgaConfig,
    cfg: &ModelConfig,
    lane_s: &[usize],
    lane_index_sets: &[&[Vec<HeadIndex>]],
) -> BatchSimReport {
    let zeros = vec![0usize; lane_s.len()];
    simulate_prefill_batch_prefixed(f, cfg, lane_s, lane_index_sets, &zeros)
}

/// [`simulate_prefill_batch`] with per-lane prefix KV reuse
/// (`lane_prefix[lane]` = leading blocks served by the cross-request
/// prefix store; 0 = cold). A prefixed lane prices its linear layers and
/// SIGU on the **novel** tokens only (the engine skips QKV/IndexGen/FFN
/// for covered blocks), and its per-layer cache is pre-seeded through the
/// same [`crate::coordinator::prefix::seed_prefix`] the engine calls —
/// so reused blocks show up as priced cache *hits* on the canonical
/// schedule walk and the hit-stat identity with `Engine` stats holds
/// warm as well as cold. Callers model the engine's resume semantics by
/// passing the same suffix index sets it would build (e.g.
/// `forward::suffix_dense_indices`).
pub fn simulate_prefill_batch_prefixed(
    f: &FpgaConfig,
    cfg: &ModelConfig,
    lane_s: &[usize],
    lane_index_sets: &[&[Vec<HeadIndex>]],
    lane_prefix: &[usize],
) -> BatchSimReport {
    assert_eq!(lane_s.len(), lane_index_sets.len(), "lane contexts vs index sets");
    assert_eq!(lane_s.len(), lane_prefix.len(), "lane contexts vs prefix lengths");
    assert!(!lane_s.is_empty());
    for ((&s, sets), &p) in lane_s.iter().zip(lane_index_sets).zip(lane_prefix) {
        assert!(s % BLOCK == 0 && !sets.is_empty());
        assert!(p < s / BLOCK, "a lane must keep at least one novel block");
    }
    let n_lanes = lane_s.len();
    let blk_bytes = kv_block_bytes(cfg);
    let wave_q = sau_wave_qblocks(f, cfg);
    let fsm_us = FSM_PHASE_CYCLES / f.freq_mhz;
    // linear/SIGU phases run on novel tokens only; the SAU schedule still
    // spans the full context (prefix K/V participate as cached operands)
    let lane_novel: Vec<usize> =
        lane_s.iter().zip(lane_prefix).map(|(&s, &p)| s - p * BLOCK).collect();

    let mut rep = SimReport::default();
    let mut traffic = Traffic::default();
    let mut lanes: Vec<LaneSim> = lane_s
        .iter()
        .map(|&s| LaneSim { context_tokens: s, ..LaneSim::default() })
        .collect();
    let mut hits = vec![0u64; n_lanes];
    let mut lookups = vec![0u64; n_lanes];
    let mut density_sum = 0.0;
    let mut density_cnt = 0usize;
    let mut compute_us_sum = 0.0;

    for li in 0..cfg.n_layers {
        let (lin_us, qkv_us, ffn_us) = linear_layers_us(f, cfg, &lane_novel, &mut traffic);
        rep.t_qkv_ms += (qkv_us / (qkv_us + ffn_us).max(1e-9)) * lin_us / 1000.0;
        rep.t_ffn_ms += (ffn_us / (qkv_us + ffn_us).max(1e-9)) * lin_us / 1000.0;
        compute_us_sum += lin_us;

        // one fused IndexGen group per layer: co-resident lanes share the
        // per-kv-head K stream over the merged extent
        let sigu_blocks: Vec<usize> = lane_novel.iter().map(|&s| s / BLOCK).collect();
        let (sigu_us, sigu_pricing) = sigu_group_us(f, cfg, &sigu_blocks, &mut traffic);
        rep.t_sigu_ms += (sigu_us + fsm_us) / 1000.0;
        for (lane, &b) in sigu_pricing.lane_bytes.iter().enumerate() {
            lanes[lane].sigu_hbm_read_bytes += b;
        }

        let schedules: Vec<Schedule> = lane_index_sets
            .iter()
            .map(|sets| build_schedule(&sets[li % sets.len()], cfg.group_size(), wave_q))
            .collect();
        let mut caches: Vec<LivenessCache> = schedules
            .iter()
            .zip(lane_s)
            .map(|(sch, &s)| sim_layer_cache(f, cfg, s / BLOCK, sch))
            .collect();
        for ((cache, sch), &p) in caches.iter_mut().zip(&schedules).zip(lane_prefix) {
            if p > 0 {
                // the SAME residency-seeding call the engine makes, so the
                // two spine consumers price reuse identically
                crate::coordinator::prefix::seed_prefix(cache, sch.n_kv_heads, p);
            }
        }
        for (lane, sch) in schedules.iter().enumerate() {
            rep.total_jobs += sch.total_jobs;
            lanes[lane].jobs += sch.total_jobs;
            let sets = lane_index_sets[lane];
            for idx in &sets[li % sets.len()] {
                density_sum += idx.density();
                density_cnt += 1;
            }
        }
        // 1-lane runs walk the schedule directly — batch-of-one is
        // equivalent (joblist/walk tests pin it) but would materialize a
        // needless merged copy of every job on the hot solo-sweep path
        let (sau_us, sau_compute_us) = if n_lanes == 1 {
            let walk = ScheduleWalk::solo(&schedules[0]);
            price_sau_walk(f, cfg, &walk, &mut caches, &mut traffic)
        } else {
            let refs: Vec<&Schedule> = schedules.iter().collect();
            let batch = build_schedule_batch(&refs);
            let walk = ScheduleWalk::batched(&batch);
            price_sau_walk(f, cfg, &walk, &mut caches, &mut traffic)
        };
        compute_us_sum += sau_compute_us;
        rep.t_sau_ms += (sau_us + fsm_us) / 1000.0;
        for (lane, cache) in caches.iter().enumerate() {
            let cs = cache.stats();
            hits[lane] += cs.hits();
            lookups[lane] += cs.lookups;
            lanes[lane].bypasses += cs.bypasses;
            lanes[lane].hbm_read_bytes += if cache.capacity() == 0 {
                blk_bytes * schedules[lane].total_jobs as f64
            } else {
                blk_bytes * cs.misses as f64
            };
        }
    }

    rep.ttft_ms = rep.t_qkv_ms + rep.t_sigu_ms + rep.t_sau_ms + rep.t_ffn_ms;
    let (h, l) = (hits.iter().sum::<u64>(), lookups.iter().sum::<u64>());
    rep.cache_hit_rate = if l > 0 { h as f64 / l as f64 } else { 0.0 };
    rep.avg_density = if density_cnt > 0 { density_sum / density_cnt as f64 } else { 1.0 };
    rep.traffic = traffic;
    // activity: fraction of TTFT the MPU is busy; HBM util from traffic
    let busy = (compute_us_sum / 1000.0 / rep.ttft_ms).clamp(0.0, 1.0);
    let hbm_util = (traffic.total_gb() / (f.hbm_bw_gbs * rep.ttft_ms / 1000.0)).clamp(0.0, 1.0);
    rep.mpu_utilization = busy;
    rep.energy_j = power::energy_j(f, 0.3 + 0.6 * busy, hbm_util, rep.ttft_ms * 1000.0);
    for (lane, ls) in lanes.iter_mut().enumerate() {
        ls.cache_hit_rate =
            if lookups[lane] > 0 { hits[lane] as f64 / lookups[lane] as f64 } else { 0.0 };
    }
    BatchSimReport { combined: rep, lanes }
}

/// Simulated outcome for a span of decode steps — the decode-side twin
/// of [`simulate_prefill`], so engine-vs-sim stat identity extends to
/// mixed prefill+decode traces.
#[derive(Clone, Debug, Default)]
pub struct DecodeSimReport {
    /// Total simulated time for the span (us).
    pub total_us: f64,
    /// Mean time-per-output-token over the span (us).
    pub tpot_us: f64,
    /// KV gather reads over the span (bytes) — identical to the engine's
    /// [`crate::coordinator::engine::DecodeState`] counters by
    /// construction (both price through [`DecodeStepWalk`]).
    pub kv_read_bytes: u64,
    /// KV append writes over the span (bytes).
    pub kv_write_bytes: u64,
}

/// Price `steps` decode steps starting at context position `pos0`
/// (tokens resident before the first step).
///
/// KV traffic prices through the canonical
/// [`crate::coordinator::walk::DecodeStepWalk`] — the same derivation the
/// engine's per-step counters use — so the byte totals here equal the
/// engine's for any interleaving of the same steps (pinned by
/// `rust/tests/memory_spine.rs`). Per-step time is the roofline of the
/// matvec weight-streaming compute (every weight matrix crosses HBM once
/// per step at batch 1 — decode's defining memory-bound regime) against
/// the KV gather, plus the FSM phase overhead per layer walk.
pub fn simulate_decode_steps(
    f: &FpgaConfig,
    cfg: &ModelConfig,
    pos0: usize,
    steps: usize,
) -> DecodeSimReport {
    use crate::coordinator::walk::DecodeStepWalk;
    let mut rep = DecodeSimReport::default();
    if steps == 0 {
        return rep;
    }
    let walk = DecodeStepWalk::new(cfg);
    let hbm = MemModel::hbm(f.hbm_bw_gbs);
    let d = cfg.d_model;
    // per-layer weight bytes streamed per step (int8): QKV + o_proj + FFN
    let layer_weight_bytes =
        (d * (cfg.q_dim() + 2 * cfg.kv_dim()) + cfg.q_dim() * d + 3 * d * cfg.d_ffn) as f64;
    let head_bytes = (cfg.vocab * d) as f64;
    // single-token matvec compute per layer on the MPU
    let layer_compute_us = mpu::matmul_us(f, 1, d, cfg.q_dim() + 2 * cfg.kv_dim())
        + mpu::matmul_us(f, 1, cfg.q_dim(), d)
        + mpu::matmul_us(f, 1, d, 2 * cfg.d_ffn)
        + mpu::matmul_us(f, 1, cfg.d_ffn, d)
        + sfu::silu_us(f, cfg.d_ffn as f64);
    for i in 0..steps {
        let pos = pos0 + i;
        let t = walk.price(pos);
        rep.kv_read_bytes += t.read_bytes;
        rep.kv_write_bytes += t.write_bytes;
        // attention scores + PV per head over pos+1 resident tokens
        let attn_us = (0..cfg.n_layers)
            .map(|_| {
                mpu::matmul_us(f, 1, cfg.d_head, pos + 1) * cfg.n_heads as f64
                    + mpu::matmul_us(f, 1, pos + 1, cfg.d_head) * cfg.n_heads as f64
                    + sfu::softmax_us(f, cfg.n_heads as f64, (pos + 1) as f64)
            })
            .sum::<f64>();
        let compute_us = cfg.n_layers as f64 * layer_compute_us
            + attn_us
            + mpu::matmul_us(f, 1, d, cfg.vocab);
        let mem_bytes = cfg.n_layers as f64 * layer_weight_bytes
            + head_bytes
            + (t.read_bytes + t.write_bytes) as f64;
        let mem_us = hbm.transfer_us(mem_bytes, kv_block_bytes(cfg));
        let fsm_us = cfg.n_layers as f64 * FSM_PHASE_CYCLES / f.freq_mhz;
        rep.total_us += compute_us.max(mem_us) + fsm_us;
    }
    rep.tpot_us = rep.total_us / steps as f64;
    rep
}

/// Wave size from the banked-accumulator URAM budget: states are
/// (m, l, acc) per (head, q-block) = BLOCK*(dh+2)*4 bytes.
pub fn sau_wave_qblocks(_f: &FpgaConfig, cfg: &ModelConfig) -> usize {
    let state_bytes = BLOCK * (cfg.d_head + 2) * 4;
    let budget = 4 << 20; // 4 MB of URAM reserved for accumulator banks
    let states = budget / state_bytes;
    (states / cfg.n_heads).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{u280_cacheless, u280_dsp_only, u280_fast_prefill, FlexParams, LLAMA32_3B};
    use crate::sim::synth::{synth_model_indices, HeadMix};

    fn indices(n: usize, heads: usize, layers: usize, seed: u64) -> Vec<Vec<HeadIndex>> {
        synth_model_indices(heads, layers, n, 32, &HeadMix::default(), &FlexParams::default(), seed)
    }

    #[test]
    fn ttft_grows_with_context() {
        let f = u280_fast_prefill();
        let cfg = &LLAMA32_3B;
        let a = simulate_prefill(&f, cfg, 4096, &indices(32, cfg.n_heads, 2, 1));
        let b = simulate_prefill(&f, cfg, 16384, &indices(128, cfg.n_heads, 2, 1));
        assert!(b.ttft_ms > 2.0 * a.ttft_ms, "{} vs {}", a.ttft_ms, b.ttft_ms);
    }

    #[test]
    fn cache_improves_ttft() {
        let cfg = &LLAMA32_3B;
        let idx = indices(128, cfg.n_heads, 2, 2);
        let with = simulate_prefill(&u280_fast_prefill(), cfg, 16384, &idx);
        let without = simulate_prefill(&u280_cacheless(), cfg, 16384, &idx);
        assert!(without.ttft_ms > with.ttft_ms, "{} !> {}", without.ttft_ms, with.ttft_ms);
        assert!(with.cache_hit_rate > 0.2, "hit rate {}", with.cache_hit_rate);
        assert_eq!(without.cache_hit_rate, 0.0);
    }

    #[test]
    fn hybrid_mpu_beats_dsp_only() {
        let cfg = &LLAMA32_3B;
        let idx = indices(64, cfg.n_heads, 2, 3);
        let hybrid = simulate_prefill(&u280_fast_prefill(), cfg, 8192, &idx);
        let dsp = simulate_prefill(&u280_dsp_only(), cfg, 8192, &idx);
        let ratio = dsp.ttft_ms / hybrid.ttft_ms;
        assert!(ratio > 1.3 && ratio < 2.2, "ratio {ratio}");
    }

    #[test]
    fn energy_positive_and_scales() {
        let cfg = &LLAMA32_3B;
        let f = u280_fast_prefill();
        let a = simulate_prefill(&f, cfg, 4096, &indices(32, cfg.n_heads, 1, 4));
        assert!(a.energy_j > 0.0);
        assert!(a.tokens_per_joule() > 0.0);
    }

    #[test]
    fn traffic_accounted() {
        let cfg = &LLAMA32_3B;
        let f = u280_fast_prefill();
        let r = simulate_prefill(&f, cfg, 4096, &indices(32, cfg.n_heads, 1, 5));
        assert!(r.traffic.hbm_read_bytes > 0.0);
        assert!(r.mpu_utilization > 0.0 && r.mpu_utilization <= 1.0);
    }

    #[test]
    fn one_lane_batched_walk_prices_like_the_solo_walk() {
        // simulate_prefill_batch short-circuits n_lanes == 1 to the solo
        // walk; pin that a *forced* 1-lane batch-merged walk agrees on
        // pricing, traffic and cache stats, so the shortcut stays honest
        let cfg = &LLAMA32_3B;
        let f = u280_fast_prefill();
        let idx = indices(48, cfg.n_heads, 1, 6);
        let schedule = build_schedule(&idx[0], cfg.group_size(), sau_wave_qblocks(&f, cfg));

        let mut solo_traffic = Traffic::default();
        let mut solo_cache = sim_layer_cache(&f, cfg, 48, &schedule);
        let solo_walk = ScheduleWalk::solo(&schedule);
        let solo = price_sau_walk(
            &f, cfg, &solo_walk, std::slice::from_mut(&mut solo_cache), &mut solo_traffic,
        );

        let batch = build_schedule_batch(&[&schedule]);
        let mut b_traffic = Traffic::default();
        let mut b_cache = sim_layer_cache(&f, cfg, 48, &schedule);
        let b_walk = ScheduleWalk::batched(&batch);
        let batched =
            price_sau_walk(&f, cfg, &b_walk, std::slice::from_mut(&mut b_cache), &mut b_traffic);

        assert_eq!(solo, batched, "1-lane batched pricing diverged from solo");
        assert_eq!(solo_cache.stats(), b_cache.stats());
        assert_eq!(solo_traffic.hbm_read_bytes, b_traffic.hbm_read_bytes);
    }

    #[test]
    fn batch_point_beats_independent_solo_sims() {
        // the merged-wave / shared-weight-stream saving must be visible:
        // one batch=2 point is faster and moves fewer bytes than the sum
        // of two independent solo simulations of the same lanes
        let cfg = &LLAMA32_3B;
        let f = u280_fast_prefill();
        let idx_a = indices(64, cfg.n_heads, 2, 7);
        let idx_b = indices(64, cfg.n_heads, 2, 8);
        let solo_a = simulate_prefill(&f, cfg, 8192, &idx_a);
        let solo_b = simulate_prefill(&f, cfg, 8192, &idx_b);
        let batch =
            simulate_prefill_batch(&f, cfg, &[8192, 8192], &[idx_a.as_slice(), idx_b.as_slice()]);
        let sum_ttft = solo_a.ttft_ms + solo_b.ttft_ms;
        let sum_read = solo_a.traffic.hbm_read_bytes + solo_b.traffic.hbm_read_bytes;
        assert!(
            batch.combined.ttft_ms < sum_ttft,
            "batch {} !< solo sum {}",
            batch.combined.ttft_ms,
            sum_ttft
        );
        assert!(
            batch.combined.traffic.hbm_read_bytes < sum_read,
            "batch read {} !< solo sum {}",
            batch.combined.traffic.hbm_read_bytes,
            sum_read
        );
        // per-lane cache outcomes are solo-identical (stats identity)
        assert!((batch.lanes[0].cache_hit_rate - solo_a.cache_hit_rate).abs() < 1e-12);
        assert!((batch.lanes[1].cache_hit_rate - solo_b.cache_hit_rate).abs() < 1e-12);
    }

    #[test]
    fn prefixed_lane_prices_reuse_as_hits_and_cuts_ttft() {
        // warm lane: 16 of 32 blocks served by the prefix store. Linear +
        // SIGU price on novel tokens only and the seeded residency turns
        // prefix coordinates into priced hits, so TTFT and KV traffic
        // both drop vs the cold run of the same request
        use crate::model::forward::suffix_dense_indices;
        let cfg = &LLAMA32_3B;
        let f = u280_fast_prefill();
        let (n, p) = (32usize, 16usize);
        let cold_idx = vec![suffix_dense_indices(cfg.n_heads, n, 0)];
        let warm_idx = vec![suffix_dense_indices(cfg.n_heads, n, p)];
        let cold = simulate_prefill_batch(&f, cfg, &[n * BLOCK], &[cold_idx.as_slice()]);
        let warm = simulate_prefill_batch_prefixed(
            &f,
            cfg,
            &[n * BLOCK],
            &[warm_idx.as_slice()],
            &[p],
        );
        assert!(
            warm.combined.ttft_ms < cold.combined.ttft_ms,
            "warm {} !< cold {}",
            warm.combined.ttft_ms,
            cold.combined.ttft_ms
        );
        assert!(
            warm.combined.traffic.hbm_read_bytes < cold.combined.traffic.hbm_read_bytes,
            "warm read {} !< cold read {}",
            warm.combined.traffic.hbm_read_bytes,
            cold.combined.traffic.hbm_read_bytes
        );
        assert!(warm.combined.cache_hit_rate > 0.0, "seeded residency prices as hits");
        // zero-prefix delegation is exactly the unprefixed entry point
        let zero = simulate_prefill_batch_prefixed(
            &f,
            cfg,
            &[n * BLOCK],
            &[cold_idx.as_slice()],
            &[0],
        );
        assert_eq!(zero.combined.ttft_ms, cold.combined.ttft_ms);
    }

    #[test]
    fn fused_sigu_streams_merged_extent_once() {
        // a 2-lane fused IndexGen group moves the K stream once over the
        // merged extent: traffic equals one solo lane of the longer length
        // and each lane's attributed share sums back to the fused total
        let cfg = &LLAMA32_3B;
        let f = u280_fast_prefill();
        let mut fused_t = Traffic::default();
        let (fused_us, pricing) = sigu_group_us(&f, cfg, &[32, 48], &mut fused_t);
        let mut solo_t = Traffic::default();
        let (solo_a, _) = sigu_group_us(&f, cfg, &[32], &mut solo_t);
        let (solo_b, _) = sigu_group_us(&f, cfg, &[48], &mut solo_t);
        assert!(
            fused_t.hbm_read_bytes < solo_t.hbm_read_bytes,
            "fused K stream {} !< solo sum {}",
            fused_t.hbm_read_bytes,
            solo_t.hbm_read_bytes
        );
        let mut long_t = Traffic::default();
        sigu_group_us(&f, cfg, &[48], &mut long_t);
        assert_eq!(fused_t.hbm_read_bytes, long_t.hbm_read_bytes);
        assert_eq!(pricing.lane_bytes.iter().sum::<u64>(), pricing.fused_bytes);
        assert!(pricing.saved_bytes() > 0);
        assert!(fused_us < solo_a + solo_b, "fused time {fused_us} !< {}", solo_a + solo_b);
    }

    #[test]
    fn batch_sim_attributes_sigu_stream_per_lane() {
        let cfg = &LLAMA32_3B;
        let f = u280_fast_prefill();
        let idx_a = indices(32, cfg.n_heads, 1, 11);
        let idx_b = indices(32, cfg.n_heads, 1, 12);
        let batch =
            simulate_prefill_batch(&f, cfg, &[4096, 4096], &[idx_a.as_slice(), idx_b.as_slice()]);
        // equal-length lanes: lane 0 pays the whole fused stream, lane 1
        // rides it for free; fused total beats two solo streams
        assert!(batch.lanes[0].sigu_hbm_read_bytes > 0);
        assert_eq!(batch.lanes[1].sigu_hbm_read_bytes, 0);
        let fused_total: u64 = batch.lanes.iter().map(|l| l.sigu_hbm_read_bytes).sum();
        let solo_pair = 2 * batch.lanes[0].sigu_hbm_read_bytes;
        assert!(fused_total < solo_pair, "fused {fused_total} !< 2x solo {solo_pair}");
    }

    #[test]
    fn marginal_fuse_saving_prices_overlap() {
        let cfg = &LLAMA32_3B;
        let f = u280_fast_prefill();
        for ph in [Phase::Qkv, Phase::IndexGen, Phase::Sau, Phase::FfnLogits] {
            assert!(
                marginal_fuse_saving_us(&f, cfg, ph, &[32], 32) > 0.0,
                "no saving for {ph:?}"
            );
        }
        assert_eq!(marginal_fuse_saving_us(&f, cfg, Phase::Done, &[32], 32), 0.0);
        assert_eq!(marginal_fuse_saving_us(&f, cfg, Phase::IndexGen, &[], 32), 0.0);
        // a longer candidate only saves its overlap with the group extent
        let short = marginal_fuse_saving_us(&f, cfg, Phase::IndexGen, &[16], 64);
        let long = marginal_fuse_saving_us(&f, cfg, Phase::IndexGen, &[64], 64);
        assert!(short < long, "overlap clamp: {short} !< {long}");
    }

    #[test]
    fn lane_attribution_sums_to_kv_traffic() {
        // every lane's attributed KV fetch bytes are part of the combined
        // traffic, and jobs match the schedules
        let cfg = &LLAMA32_3B;
        let f = u280_fast_prefill();
        let idx_a = indices(32, cfg.n_heads, 1, 9);
        let idx_b = indices(32, cfg.n_heads, 1, 10);
        let batch =
            simulate_prefill_batch(&f, cfg, &[4096, 4096], &[idx_a.as_slice(), idx_b.as_slice()]);
        let lane_kv: f64 = batch.lanes.iter().map(|l| l.hbm_read_bytes).sum();
        assert!(lane_kv > 0.0);
        assert!(lane_kv <= batch.combined.traffic.hbm_read_bytes);
        assert_eq!(
            batch.lanes.iter().map(|l| l.jobs).sum::<usize>(),
            batch.combined.total_jobs
        );
    }

    #[test]
    fn decode_sim_prices_kv_through_the_spine() {
        let cfg = &LLAMA32_3B;
        let f = u280_fast_prefill();
        let rep = simulate_decode_steps(&f, cfg, 4096, 8);
        // byte identity with the canonical walk — the same invariant the
        // engine-vs-sim decode test pins end to end
        let span = crate::coordinator::walk::DecodeStepWalk::new(cfg).price_span(4096, 8);
        assert_eq!(rep.kv_read_bytes, span.read_bytes);
        assert_eq!(rep.kv_write_bytes, span.write_bytes);
        assert!(rep.total_us > 0.0 && rep.tpot_us > 0.0);
        // deeper contexts gather more KV per step and decode no faster
        let far = simulate_decode_steps(&f, cfg, 32 * 1024, 8);
        assert!(far.kv_read_bytes > rep.kv_read_bytes);
        assert!(far.tpot_us >= rep.tpot_us);
        assert_eq!(simulate_decode_steps(&f, cfg, 4096, 0).total_us, 0.0);
    }
}
