//! Whole-prefill FPGA performance model: composes the MPU/SFU/HBM/cache
//! models over the real block-major schedules to produce TTFT and energy
//! for a (model, context) point — the generator behind Figures 5-8.
//!
//! Phase structure per layer (paper Fig. 2): chunked QKV generation ->
//! SIGU -> SAU (block-major waves, liveness cache, lookahead prefetch) ->
//! FFN. Weight and activation streams overlap compute (dataflow design);
//! each phase costs max(compute, memory) plus FSM transition overhead.

use crate::config::{FpgaConfig, ModelConfig, BLOCK};
use crate::coordinator::joblist::{build_schedule, cache_key, Schedule};
use crate::flexprefill::HeadIndex;
use crate::kvcache::{Access, LivenessCache};

use super::hbm::{MemModel, Traffic};
use super::{mpu, power, sfu};

/// FSM phase-transition overhead (cycles).
pub const FSM_PHASE_CYCLES: f64 = 256.0;

/// Simulated outcome for one prefill.
#[derive(Clone, Debug, Default)]
pub struct SimReport {
    pub ttft_ms: f64,
    pub energy_j: f64,
    pub t_qkv_ms: f64,
    pub t_sigu_ms: f64,
    pub t_sau_ms: f64,
    pub t_ffn_ms: f64,
    pub traffic: Traffic,
    pub cache_hit_rate: f64,
    pub avg_density: f64,
    pub total_jobs: usize,
    /// Mean MPU utilization during compute phases.
    pub mpu_utilization: f64,
}

impl SimReport {
    pub fn tokens_per_joule(&self) -> f64 {
        if self.energy_j <= 0.0 {
            return 0.0;
        }
        1.0 / self.energy_j
    }
}

/// KV block bytes (int8 K + V for one kv head).
fn kv_block_bytes(cfg: &ModelConfig) -> f64 {
    (2 * BLOCK * cfg.d_head) as f64
}

/// Simulate the SAU over one layer's schedule, updating the cache and
/// traffic; returns (time_us, compute_us_portion).
fn sau_layer_us(
    f: &FpgaConfig,
    cfg: &ModelConfig,
    schedule: &Schedule,
    cache: &mut LivenessCache,
    traffic: &mut Traffic,
) -> (f64, f64) {
    let hbm = MemModel::hbm(f.hbm_bw_gbs);
    let blk_bytes = kv_block_bytes(cfg);
    // per-job compute: score 128xdhx128 + PV 128x128xdh on the MPU + SFU
    // softmax, fused/pipelined -> max of the two engines
    let score_us = mpu::matmul_us(f, BLOCK, cfg.d_head, BLOCK);
    let pv_us = mpu::matmul_us(f, BLOCK, BLOCK, cfg.d_head);
    let sm_us = sfu::softmax_us(f, BLOCK as f64, BLOCK as f64);
    let job_us = (score_us + pv_us).max(sm_us);
    // coordinated burst fetch of one KV block (prefetched design)
    let fetch_us = hbm.transfer_us(blk_bytes, blk_bytes);
    // on-demand gather (cacheless design): the block arrives as many short
    // beats with bounded memory-level parallelism and no prefetch overlap —
    // the paper's challenge 2(b) "many small off-chip memory reads ...
    // under-utilized bandwidth and pipeline stalls". Exposed latency:
    // beats * t_req / MLP.
    let demand_beats = (blk_bytes / 128.0).ceil();
    let demand_fetch_us = demand_beats * hbm.req_latency_ns * 1e-3 / 5.0
        + hbm.transfer_us(blk_bytes, 128.0);

    let mut total_us = 0.0;
    let mut compute_us_total = 0.0;
    for wave in &schedule.waves {
        let mut prev_compute_us = 0.0f64;
        for bj in &wave.blocks {
            let key = cache_key(bj.kv_head, bj.block);
            let jobs = bj.jobs.len() as f64;
            let compute_us = jobs * job_us;
            if cache.capacity() == 0 {
                // cacheless: demand-fetch per job group (no residency even
                // within the wave beyond the current tile), serialized with
                // compute (no lookahead prefetcher without the cache's
                // space accounting)
                cache.lookup(key); // records the miss
                traffic.hbm_read_bytes += blk_bytes * jobs;
                total_us += compute_us + jobs * demand_fetch_us;
                compute_us_total += compute_us;
                for _ in 0..bj.jobs.len() {
                    cache.consume(key);
                }
                continue;
            }
            let mem_us = match cache.lookup(key) {
                Access::Hit(_) => 0.0,
                Access::Miss => {
                    cache.admit(key);
                    traffic.hbm_read_bytes += blk_bytes;
                    fetch_us
                }
            };
            // lookahead prefetch: a block's fetch overlaps the previous
            // block's compute; only the remainder stalls the pipe
            let stall = (mem_us - prev_compute_us).max(0.0);
            total_us += compute_us + stall;
            compute_us_total += compute_us;
            prev_compute_us = compute_us;
            for _ in 0..bj.jobs.len() {
                cache.consume(key);
            }
        }
    }
    (total_us, compute_us_total)
}

/// SIGU timing for one layer: stream all key blocks once per kv head
/// (single-fetch hardware realization — DESIGN.md), score against Q-hat on
/// the MPU per *query* head, plus the streaming selection pass.
fn sigu_layer_us(f: &FpgaConfig, cfg: &ModelConfig, n: usize, traffic: &mut Traffic) -> f64 {
    let hbm = MemModel::hbm(f.hbm_bw_gbs);
    let kblk_bytes = (BLOCK * cfg.d_head) as f64;
    // sequential burst stream of K, once per kv head
    let stream_us =
        hbm.transfer_us(kblk_bytes * n as f64, 16384.0) * cfg.n_kv_heads as f64;
    traffic.hbm_read_bytes += kblk_bytes * n as f64 * cfg.n_kv_heads as f64;
    // score compute: per query head, per block: 128 x dh x 128
    let score_us =
        mpu::matmul_us(f, BLOCK, cfg.d_head, BLOCK) * (n * cfg.n_heads) as f64;
    // selection: streaming coverage scan, ~4 passes over N-length buffers
    // per head + pooled map for query-aware heads (N x N / lanes)
    let select_us = cfg.n_heads as f64
        * (sfu::elementwise_us(f, 4.0 * n as f64) + sfu::elementwise_us(f, (n * n) as f64 * 0.25));
    stream_us.max(score_us) + select_us
}

/// Linear layers (QKV + o_proj + FFN) for one layer over all chunks:
/// weight-stationary tiles, activation streaming overlapped.
fn linear_layer_us(f: &FpgaConfig, cfg: &ModelConfig, s: usize, traffic: &mut Traffic) -> (f64, f64, f64) {
    let hbm = MemModel::hbm(f.hbm_bw_gbs);
    let d = cfg.d_model;
    let qkv_macs_cols = cfg.q_dim() + 2 * cfg.kv_dim();
    let qkv_us = mpu::matmul_us(f, s, d, qkv_macs_cols);
    let oproj_us = mpu::matmul_us(f, s, cfg.q_dim(), d);
    let ffn_us = mpu::matmul_us(f, s, d, 2 * cfg.d_ffn) + mpu::matmul_us(f, s, cfg.d_ffn, d)
        + sfu::silu_us(f, (s * cfg.d_ffn) as f64);
    // weights streamed once per layer (int8, resident in HBM), activations
    // read+written once per stage
    let w_bytes = (d * qkv_macs_cols + cfg.q_dim() * d + 3 * d * cfg.d_ffn) as f64;
    let act_bytes = (s * d) as f64 * 6.0;
    traffic.hbm_read_bytes += w_bytes + act_bytes * 0.5;
    traffic.hbm_write_bytes += act_bytes * 0.5;
    let mem_us = hbm.transfer_us(w_bytes + act_bytes, 16384.0);
    let compute = qkv_us + oproj_us + ffn_us;
    (compute.max(mem_us), qkv_us + oproj_us, ffn_us)
}

/// Full prefill simulation over real index sets.
///
/// `index_sets[layer][head]` — from the functional pipeline (small scale)
/// or `synth::synth_model_indices` (paper scale). If fewer layers of
/// indices than `cfg.n_layers` are provided they are cycled (layers are
/// statistically identical).
pub fn simulate_prefill(
    f: &FpgaConfig,
    cfg: &ModelConfig,
    s: usize,
    index_sets: &[Vec<HeadIndex>],
) -> SimReport {
    assert!(s % BLOCK == 0 && !index_sets.is_empty());
    let n = s / BLOCK;
    let mut rep = SimReport::default();
    let mut traffic = Traffic::default();
    let cache_blocks = if f.kv_cache_bytes == 0 {
        0
    } else {
        (f.kv_cache_bytes as f64 / kv_block_bytes(cfg)) as usize
    };
    let wave_q = sau_wave_qblocks(f, cfg);
    let mut hits = 0u64;
    let mut lookups = 0u64;
    let mut density_sum = 0.0;
    let fsm_us = FSM_PHASE_CYCLES / f.freq_mhz;

    let mut compute_us_sum = 0.0;
    for li in 0..cfg.n_layers {
        let indices = &index_sets[li % index_sets.len()];
        let (lin_us, qkv_us, ffn_us) = linear_layer_us(f, cfg, s, &mut traffic);
        rep.t_qkv_ms += (qkv_us / (qkv_us + ffn_us).max(1e-9)) * lin_us / 1000.0;
        rep.t_ffn_ms += (ffn_us / (qkv_us + ffn_us).max(1e-9)) * lin_us / 1000.0;
        compute_us_sum += lin_us;

        rep.t_sigu_ms += (sigu_layer_us(f, cfg, n, &mut traffic) + fsm_us) / 1000.0;

        let schedule: Schedule = build_schedule(indices, cfg.group_size(), wave_q);
        rep.total_jobs += schedule.total_jobs;
        for idx in indices {
            density_sum += idx.density();
        }
        let t_hot = (f.t_hot_frac * (n * cfg.group_size()) as f64) as u32;
        let mut cache = if cache_blocks > 0 {
            LivenessCache::new(cache_blocks, f.hot_fraction, t_hot)
        } else {
            LivenessCache::disabled()
        };
        cache.init_uses(schedule.uses.iter().copied());
        let (sau_us, sau_compute_us) = sau_layer_us(f, cfg, &schedule, &mut cache, &mut traffic);
        compute_us_sum += sau_compute_us;
        rep.t_sau_ms += (sau_us + fsm_us) / 1000.0;
        hits += cache.stats().hits();
        lookups += cache.stats().lookups;
    }

    rep.ttft_ms = rep.t_qkv_ms + rep.t_sigu_ms + rep.t_sau_ms + rep.t_ffn_ms;
    rep.cache_hit_rate = if lookups > 0 { hits as f64 / lookups as f64 } else { 0.0 };
    rep.avg_density = density_sum / (cfg.n_layers * cfg.n_heads) as f64;
    rep.traffic = traffic;
    // activity: fraction of TTFT the MPU is busy; HBM util from traffic
    let busy = (compute_us_sum / 1000.0 / rep.ttft_ms).clamp(0.0, 1.0);
    let hbm_util = (traffic.total_gb() / (f.hbm_bw_gbs * rep.ttft_ms / 1000.0)).clamp(0.0, 1.0);
    rep.mpu_utilization = busy;
    rep.energy_j = power::energy_j(f, 0.3 + 0.6 * busy, hbm_util, rep.ttft_ms * 1000.0);
    rep
}

/// Wave size from the banked-accumulator URAM budget: states are
/// (m, l, acc) per (head, q-block) = BLOCK*(dh+2)*4 bytes.
pub fn sau_wave_qblocks(_f: &FpgaConfig, cfg: &ModelConfig) -> usize {
    let state_bytes = BLOCK * (cfg.d_head + 2) * 4;
    let budget = 4 << 20; // 4 MB of URAM reserved for accumulator banks
    let states = budget / state_bytes;
    (states / cfg.n_heads).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{u280_cacheless, u280_dsp_only, u280_fast_prefill, FlexParams, LLAMA32_3B};
    use crate::sim::synth::{synth_model_indices, HeadMix};

    fn indices(n: usize, heads: usize, layers: usize, seed: u64) -> Vec<Vec<HeadIndex>> {
        synth_model_indices(heads, layers, n, 32, &HeadMix::default(), &FlexParams::default(), seed)
    }

    #[test]
    fn ttft_grows_with_context() {
        let f = u280_fast_prefill();
        let cfg = &LLAMA32_3B;
        let a = simulate_prefill(&f, cfg, 4096, &indices(32, cfg.n_heads, 2, 1));
        let b = simulate_prefill(&f, cfg, 16384, &indices(128, cfg.n_heads, 2, 1));
        assert!(b.ttft_ms > 2.0 * a.ttft_ms, "{} vs {}", a.ttft_ms, b.ttft_ms);
    }

    #[test]
    fn cache_improves_ttft() {
        let cfg = &LLAMA32_3B;
        let idx = indices(128, cfg.n_heads, 2, 2);
        let with = simulate_prefill(&u280_fast_prefill(), cfg, 16384, &idx);
        let without = simulate_prefill(&u280_cacheless(), cfg, 16384, &idx);
        assert!(without.ttft_ms > with.ttft_ms, "{} !> {}", without.ttft_ms, with.ttft_ms);
        assert!(with.cache_hit_rate > 0.2, "hit rate {}", with.cache_hit_rate);
        assert_eq!(without.cache_hit_rate, 0.0);
    }

    #[test]
    fn hybrid_mpu_beats_dsp_only() {
        let cfg = &LLAMA32_3B;
        let idx = indices(64, cfg.n_heads, 2, 3);
        let hybrid = simulate_prefill(&u280_fast_prefill(), cfg, 8192, &idx);
        let dsp = simulate_prefill(&u280_dsp_only(), cfg, 8192, &idx);
        let ratio = dsp.ttft_ms / hybrid.ttft_ms;
        assert!(ratio > 1.3 && ratio < 2.2, "ratio {ratio}");
    }

    #[test]
    fn energy_positive_and_scales() {
        let cfg = &LLAMA32_3B;
        let f = u280_fast_prefill();
        let a = simulate_prefill(&f, cfg, 4096, &indices(32, cfg.n_heads, 1, 4));
        assert!(a.energy_j > 0.0);
        assert!(a.tokens_per_joule() > 0.0);
    }

    #[test]
    fn traffic_accounted() {
        let cfg = &LLAMA32_3B;
        let f = u280_fast_prefill();
        let r = simulate_prefill(&f, cfg, 4096, &indices(32, cfg.n_heads, 1, 5));
        assert!(r.traffic.hbm_read_bytes > 0.0);
        assert!(r.mpu_utilization > 0.0 && r.mpu_utilization <= 1.0);
    }
}
