//! FPGA resource model — regenerates Table II from the design parameters.
//!
//! Each architectural component contributes LUT/FF/BRAM/URAM/DSP derived
//! from the configuration (array counts, PE costs, cache capacity), so the
//! ablation configs (DSP-only MPU, cacheless) report their own utilization.
//! Constants are calibrated so the paper's design point reproduces the
//! paper's totals (838k LUT / 1232k FF / 2250 BRAM / 912 URAM / 6459 DSP).

use crate::config::FpgaConfig;
use crate::quant::nibble::LUTS_PER_NIBBLE_PE;

/// Resource vector.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Resources {
    pub lut_k: f64,
    pub ff_k: f64,
    pub bram: f64,
    pub uram: f64,
    pub dsp: f64,
}

impl Resources {
    pub fn add(&mut self, o: Resources) {
        self.lut_k += o.lut_k;
        self.ff_k += o.ff_k;
        self.bram += o.bram;
        self.uram += o.uram;
        self.dsp += o.dsp;
    }
}

/// Named component breakdown.
#[derive(Clone, Debug)]
pub struct ResourceReport {
    pub components: Vec<(&'static str, Resources)>,
    pub total: Resources,
    pub available: Resources,
}

impl ResourceReport {
    pub fn utilization(&self) -> [(String, f64, f64, f64); 5] {
        let t = &self.total;
        let a = &self.available;
        [
            ("LUT (k)".into(), t.lut_k, a.lut_k, 100.0 * t.lut_k / a.lut_k),
            ("FF (k)".into(), t.ff_k, a.ff_k, 100.0 * t.ff_k / a.ff_k),
            ("BRAM".into(), t.bram, a.bram, 100.0 * t.bram / a.bram),
            ("URAM".into(), t.uram, a.uram, 100.0 * t.uram / a.uram),
            ("DSP".into(), t.dsp, a.dsp, 100.0 * t.dsp / a.dsp),
        ]
    }
}

/// U280 URAM block = 288 Kb = 36 KB.
pub const URAM_BYTES: usize = 36 * 1024;

/// Compute the component breakdown for a design point.
pub fn resource_report(f: &FpgaConfig) -> ResourceReport {
    let pes_per_array = (f.mpu_array_dim * f.mpu_array_dim) as f64;
    let mut components = Vec::new();

    // Hybrid MPU — DSP arrays: 1 DSP48 per INT8 MAC PE + control LUTs/FFs.
    let dsp_pes = f.mpu_dsp_arrays as f64 * pes_per_array;
    components.push((
        "MPU (DSP arrays)",
        Resources {
            lut_k: dsp_pes * 8.0 / 1000.0,
            ff_k: dsp_pes * 36.0 / 1000.0,
            bram: 0.0,
            uram: 0.0,
            dsp: dsp_pes,
        },
    ));
    // Hybrid MPU — LUT bit-plane/nibble arrays.
    let lut_pes = f.mpu_lut_arrays as f64 * pes_per_array;
    components.push((
        "MPU (LUT bit-plane arrays)",
        Resources {
            lut_k: lut_pes * LUTS_PER_NIBBLE_PE as f64 / 1000.0,
            ff_k: lut_pes * 48.0 / 1000.0,
            bram: 0.0,
            uram: 0.0,
            dsp: 0.0,
        },
    ));
    // SIGU: score pipeline + accumulators + selection logic.
    components.push((
        "SIGU",
        Resources { lut_k: 120.0, ff_k: 180.0, bram: 400.0, uram: 48.0, dsp: 200.0 },
    ));
    // SAU + liveness cache: URAMs sized by capacity (K+V tiers + Q/output
    // staging ≈ 1.9x the raw KV capacity in URAM blocks — staging buffers
    // share banks with the cold tier), BRAM tags/FIFOs.
    let kv_urams = (1.9 * f.kv_cache_bytes as f64 / URAM_BYTES as f64).ceil();
    components.push((
        "SAU + KV cache",
        Resources {
            lut_k: 150.0,
            ff_k: 250.0,
            bram: if f.kv_cache_bytes > 0 { 600.0 } else { 150.0 },
            uram: kv_urams.min(f.uram_total as f64 - 48.0),
            dsp: 0.0,
        },
    ));
    // SFU (softmax / SiLU / normalization).
    components.push((
        "SFU",
        Resources { lut_k: 80.0, ff_k: 120.0, bram: 250.0, uram: 0.0, dsp: 115.0 },
    ));
    // HBM/DDR interfaces + NoC + global FSM.
    components.push((
        "Memory interfaces + FSM",
        Resources { lut_k: 95.0, ff_k: 166.0, bram: 1000.0, uram: 0.0, dsp: 0.0 },
    ));

    let mut total = Resources::default();
    for (_, r) in &components {
        total.add(*r);
    }
    let available = Resources {
        lut_k: f.lut_total_k as f64,
        ff_k: f.ff_total_k as f64,
        bram: f.bram_total as f64,
        uram: f.uram_total as f64,
        dsp: f.dsp_total as f64,
    };
    ResourceReport { components, total, available }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{u280_cacheless, u280_dsp_only, u280_fast_prefill};

    #[test]
    fn paper_design_point_matches_table2() {
        let r = resource_report(&u280_fast_prefill());
        // paper: LUT 838k, FF 1232k, BRAM 2250, URAM 912, DSP 6459
        assert!((r.total.lut_k - 838.0).abs() < 15.0, "lut {}", r.total.lut_k);
        assert!((r.total.ff_k - 1232.0).abs() < 20.0, "ff {}", r.total.ff_k);
        assert!((r.total.bram - 2250.0).abs() < 10.0, "bram {}", r.total.bram);
        assert!((r.total.uram - 912.0).abs() < 24.0, "uram {}", r.total.uram);
        assert!((r.total.dsp - 6459.0).abs() < 10.0, "dsp {}", r.total.dsp);
    }

    #[test]
    fn nothing_overflows_device() {
        let r = resource_report(&u280_fast_prefill());
        for (name, used, avail, _) in r.utilization() {
            assert!(used <= avail, "{name}: {used} > {avail}");
        }
    }

    #[test]
    fn dsp_only_frees_luts() {
        let full = resource_report(&u280_fast_prefill());
        let dsp = resource_report(&u280_dsp_only());
        assert!(dsp.total.lut_k < full.total.lut_k - 300.0);
        assert_eq!(dsp.total.dsp, full.total.dsp);
        // paper: without the hybrid MPU ~85% of LUTs idle
        let lut_util = dsp.total.lut_k / dsp.available.lut_k;
        assert!(lut_util < 0.45, "util {lut_util}");
    }

    #[test]
    fn cacheless_frees_uram() {
        let r = resource_report(&u280_cacheless());
        assert!(r.total.uram < 100.0, "uram {}", r.total.uram);
    }
}
