//! Cycle-approximate FPGA (Alveo U280) performance & energy model.
//!
//! The paper's bitstream is not reproducible offline; this simulator models
//! the architecture's first-order behaviour (§IV): hybrid-MPU systolic
//! throughput, HBM burst efficiency, the liveness-driven dual-tier cache
//! with lookahead prefetch, SFU pipelines, FSM phase transitions, and a
//! utilization-scaled power model. It consumes *real* sparse index sets —
//! from the functional pipeline at small scale or from the calibrated
//! synthetic score generator at paper scale — so the performance numbers
//! reflect genuine dynamic sparsity (DESIGN.md, substitution table).

pub mod hbm;
pub mod mpu;
pub mod power;
pub mod prefill;
pub mod resources;
pub mod sfu;
pub mod synth;

pub use prefill::{
    marginal_fuse_saving_us, price_sau_walk, sau_wave_qblocks, sigu_group_us,
    simulate_decode_steps, simulate_prefill, simulate_prefill_batch,
    simulate_prefill_batch_prefixed, BatchSimReport, DecodeSimReport, LaneSim, SimReport,
};
pub use resources::{resource_report, ResourceReport, Resources};
pub use synth::{synth_model_indices, synth_model_indices_pool, HeadKind, HeadMix};
