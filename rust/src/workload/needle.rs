//! Needle-in-a-haystack retrieval tasks — the Table III accuracy proxy.
//!
//! RULER cannot be run offline (no corpus, no trained weights); this task
//! preserves what Table III measures: whether the *sparse-index + quantized
//! attention* stack still routes each query to the value it must retrieve.
//! Each query row of the last block is tied to a target key planted in the
//! haystack; values carry codebook codes; retrieval is scored exact-match
//! by nearest-codebook decoding of the attention output (see
//! `accuracy::evaluate`).

use crate::config::BLOCK;
use crate::tensor::MatF32;
use crate::util::prng::Prng;

/// One synthetic retrieval instance over `n_blocks` KV blocks.
#[derive(Clone, Debug)]
pub struct NeedleTask {
    pub n_blocks: usize,
    pub d: usize,
    /// Last query block [BLOCK, d].
    pub qhat: MatF32,
    /// Key blocks, ascending order, each [BLOCK, d].
    pub kblocks: Vec<MatF32>,
    /// Value blocks, each [BLOCK, d].
    pub vblocks: Vec<MatF32>,
    /// Codebook of value embeddings [n_codes, d].
    pub codebook: MatF32,
    /// Gold code per query row.
    pub gold: Vec<usize>,
    /// Target (block, row) per query row.
    pub targets: Vec<(usize, usize)>,
}

/// Scoring outcome.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RetrievalOutcome {
    pub correct: usize,
    pub total: usize,
}

impl RetrievalOutcome {
    pub fn accuracy(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        100.0 * self.correct as f64 / self.total as f64
    }
}

/// Full task parameterization (one Table III cell's difficulty).
#[derive(Clone, Copy, Debug)]
pub struct TaskSpec {
    pub n_blocks: usize,
    pub d: usize,
    /// How strongly each query points at its target key (higher = easier).
    pub match_gain: f32,
    /// Additive query noise.
    pub noise: f32,
    /// Number of outlier channels. Real LLM activations carry a few
    /// large-magnitude "outlier feature" dimensions; per-tensor int8
    /// scales are set by them, starving the informative dimensions of
    /// resolution — the mechanism behind Table III's BF16 vs INT8 gap.
    /// Outlier channels are constant, so exact (BF16) arithmetic cancels
    /// them in the softmax while quantized arithmetic suffers.
    pub outlier_dims: usize,
    pub outlier_mag: f32,
    /// Hard negatives per query: near-duplicate keys (correlation `rho`
    /// with the target) carrying the *wrong* value code. Distinguishing
    /// them requires resolving sub-unit score margins — exactly what the
    /// outlier-inflated int8 step cannot do. RULER's hard retrieval
    /// variants create the same contrast.
    pub n_distractors: usize,
    pub distractor_rho: f32,
}

impl TaskSpec {
    pub fn new(n_blocks: usize, d: usize, match_gain: f32, noise: f32) -> Self {
        TaskSpec {
            n_blocks,
            d,
            match_gain,
            noise,
            outlier_dims: 0,
            outlier_mag: 0.0,
            n_distractors: 0,
            distractor_rho: 0.9,
        }
    }

    pub fn with_outliers(mut self, dims: usize, mag: f32) -> Self {
        self.outlier_dims = dims;
        self.outlier_mag = mag;
        self
    }

    pub fn with_distractors(mut self, n: usize, rho: f32) -> Self {
        self.n_distractors = n;
        self.distractor_rho = rho;
        self
    }
}

impl NeedleTask {
    /// Generate a task (no outlier channels).
    pub fn generate(n_blocks: usize, d: usize, match_gain: f32, noise: f32, seed: u64) -> Self {
        Self::from_spec(&TaskSpec::new(n_blocks, d, match_gain, noise), seed)
    }

    /// Generate a task from a full spec.
    pub fn from_spec(spec: &TaskSpec, seed: u64) -> Self {
        let (n_blocks, d) = (spec.n_blocks, spec.d);
        let (match_gain, noise) = (spec.match_gain, spec.noise);
        let mut rng = Prng::new(seed);
        let n_codes = 32;
        let codebook = MatF32::from_fn(n_codes, d, |_, _| rng.normal());
        // outlier channels: the last `outlier_dims` dims carry a large,
        // nearly constant value with small per-row jitter
        let out0 = d - spec.outlier_dims;
        let osign: Vec<f32> = (0..spec.outlier_dims)
            .map(|_| if rng.f32() < 0.5 { -1.0 } else { 1.0 })
            .collect();
        // haystack keys: unit-ish gaussian rows + outlier channels
        let kblocks: Vec<MatF32> = (0..n_blocks)
            .map(|_| {
                MatF32::from_fn(BLOCK, d, |_, c| {
                    if c >= out0 {
                        // constant per channel: the softmax cancels the
                        // (constant) score shift exactly in any precision;
                        // the tensor *scale* the channel sets is what starves
                        // the informative dimensions of int8 resolution
                        osign[c - out0] * spec.outlier_mag
                    } else {
                        rng.normal()
                    }
                })
            })
            .collect();
        // values: each row carries a code vector
        let mut codes = vec![vec![0usize; BLOCK]; n_blocks];
        let vblocks: Vec<MatF32> = (0..n_blocks)
            .map(|b| {
                MatF32::from_fn(BLOCK, d, |r, c| {
                    if c == 0 {
                        codes[b][r] = (b * 31 + r * 7) % n_codes;
                    }
                    codebook.at((b * 31 + r * 7) % n_codes, c)
                })
            })
            .collect();
        // queries: point at a random target key + noise; positions are kept
        // distinct so distractors never overwrite another query's target
        let mut kblocks = kblocks;
        let mut vblocks = vblocks;
        let mut gold = Vec::with_capacity(BLOCK);
        let mut targets = Vec::with_capacity(BLOCK);
        let mut used = std::collections::HashSet::new();
        let mut qhat = MatF32::zeros(BLOCK, d);
        let total_rows = n_blocks * BLOCK;
        for r in 0..BLOCK {
            let (tb, tr) = loop {
                let p = rng.below(total_rows);
                if used.insert(p) {
                    break (p / BLOCK, p % BLOCK);
                }
            };
            targets.push((tb, tr));
            gold.push(codes[tb][tr]);
            let krow: Vec<f32> = kblocks[tb].row(tr).to_vec();
            for (c, q) in qhat.row_mut(r).iter_mut().enumerate() {
                *q = match_gain * krow[c] + noise * rng.normal();
            }
            // hard negatives: near-duplicate keys with the wrong code
            let rho = spec.distractor_rho;
            let orth = (1.0 - rho * rho).max(0.0).sqrt();
            for _ in 0..spec.n_distractors {
                let (db, dr) = loop {
                    let p = rng.below(total_rows);
                    if used.insert(p) {
                        break (p / BLOCK, p % BLOCK);
                    }
                };
                let wrong = (codes[tb][tr] + 1 + rng.below(codebook.rows - 1)) % codebook.rows;
                codes[db][dr] = wrong;
                for c in 0..d {
                    let kv = if c >= out0 {
                        krow[c] // outlier channels stay constant
                    } else {
                        rho * krow[c] + orth * rng.normal()
                    };
                    *kblocks[db].at_mut(dr, c) = kv;
                    *vblocks[db].at_mut(dr, c) = codebook.at(wrong, c);
                }
            }
        }
        NeedleTask { n_blocks, d, qhat, kblocks, vblocks, codebook, gold, targets }
    }

    /// Decode attention outputs by nearest codebook row (cosine), score
    /// exact-match against gold codes.
    pub fn score(&self, outputs: &MatF32) -> RetrievalOutcome {
        assert_eq!(outputs.rows, BLOCK);
        assert_eq!(outputs.cols, self.d);
        let mut correct = 0;
        for r in 0..BLOCK {
            let out = outputs.row(r);
            let mut best = (f64::NEG_INFINITY, 0usize);
            for c in 0..self.codebook.rows {
                let sim = crate::util::stats::cosine(out, self.codebook.row(c));
                if sim > best.0 {
                    best = (sim, c);
                }
            }
            if best.1 == self.gold[r] {
                correct += 1;
            }
        }
        RetrievalOutcome { correct, total: BLOCK }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::{matmul_bt, softmax_rows};

    #[test]
    fn generation_shapes() {
        let t = NeedleTask::generate(4, 64, 1.0, 0.3, 1);
        assert_eq!(t.kblocks.len(), 4);
        assert_eq!(t.qhat.rows, BLOCK);
        assert_eq!(t.gold.len(), BLOCK);
    }

    #[test]
    fn exact_attention_retrieves_nearly_all() {
        // full-precision dense attention over the task must retrieve ~100%
        let t = NeedleTask::generate(4, 64, 1.2, 0.2, 2);
        let kfull = {
            let mut k = MatF32::zeros(4 * BLOCK, 64);
            for (b, kb) in t.kblocks.iter().enumerate() {
                k.data[b * BLOCK * 64..(b + 1) * BLOCK * 64].copy_from_slice(&kb.data);
            }
            k
        };
        let vfull = {
            let mut v = MatF32::zeros(4 * BLOCK, 64);
            for (b, vb) in t.vblocks.iter().enumerate() {
                v.data[b * BLOCK * 64..(b + 1) * BLOCK * 64].copy_from_slice(&vb.data);
            }
            v
        };
        let mut s = matmul_bt(&t.qhat, &kfull);
        let inv = 1.0 / (64.0f32).sqrt();
        for v in s.data.iter_mut() {
            *v *= inv;
        }
        softmax_rows(&mut s);
        let out = crate::tensor::ops::matmul(&s, &vfull);
        let r = t.score(&out);
        assert!(r.accuracy() > 90.0, "accuracy {}", r.accuracy());
    }

    #[test]
    fn random_outputs_score_near_chance() {
        let t = NeedleTask::generate(2, 64, 1.0, 0.3, 3);
        let mut rng = crate::util::prng::Prng::new(99);
        let junk = MatF32::from_fn(BLOCK, 64, |_, _| rng.normal());
        let r = t.score(&junk);
        // 32 codes -> chance ~3%; allow generous slack
        assert!(r.accuracy() < 25.0, "accuracy {}", r.accuracy());
    }

    #[test]
    fn deterministic() {
        let a = NeedleTask::generate(3, 32, 1.0, 0.2, 7);
        let b = NeedleTask::generate(3, 32, 1.0, 0.2, 7);
        assert_eq!(a.gold, b.gold);
        assert_eq!(a.qhat.data, b.qhat.data);
    }
}
