//! Synthetic prompt/token-stream generation with controllable structure,
//! plus request traces for the serving examples and benches.

use crate::util::prng::Prng;

/// The attention structure a synthetic prompt should induce.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PromptKind {
    /// Uniform random bytes — diffuse attention.
    Random,
    /// A few globally repeated motifs — vertical-column structure.
    Anchored,
    /// Strong local repetition — slash/diagonal structure.
    Local,
    /// Anchored + local mixture (document-like).
    Mixed,
    /// A cohort-shared leading context followed by a per-request tail —
    /// the shape cross-request prefix KV reuse exists for (a shared
    /// system prompt / document stem). The leading
    /// `prefix_blocks * BLOCK` tokens are generated from `prefix_seed`
    /// **only**, so every spec carrying the same `(prefix_seed,
    /// prefix_blocks)` pair shares those bytes exactly; the tail comes
    /// from the spec's own seed.
    SharedPrefix { prefix_seed: u32, prefix_blocks: u16 },
}

/// Specification for one synthetic prompt.
#[derive(Clone, Copy, Debug)]
pub struct PromptSpec {
    pub kind: PromptKind,
    pub tokens: usize,
    pub seed: u64,
}

impl PromptSpec {
    /// Materialize the byte-token stream.
    pub fn generate(&self) -> Vec<u8> {
        let mut rng = Prng::new(self.seed);
        let n = self.tokens;
        match self.kind {
            PromptKind::Random => (0..n).map(|_| rng.below(256) as u8).collect(),
            PromptKind::Anchored => {
                // ~3% of positions repeat one of 4 motifs of 8 bytes
                let motifs: Vec<Vec<u8>> = (0..4)
                    .map(|_| (0..8).map(|_| rng.below(256) as u8).collect())
                    .collect();
                let mut out: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
                let inserts = (n / 256).max(1);
                for _ in 0..inserts {
                    let m = &motifs[rng.below(4)];
                    let at = rng.below(n.saturating_sub(m.len()).max(1));
                    for (i, &b) in m.iter().enumerate() {
                        if at + i < n {
                            out[at + i] = b;
                        }
                    }
                }
                out
            }
            PromptKind::Local => {
                // runs of 16-64 repeated bytes — local self-similarity
                let mut out = Vec::with_capacity(n);
                while out.len() < n {
                    let b = rng.below(256) as u8;
                    let run = 16 + rng.below(49);
                    for _ in 0..run.min(n - out.len()) {
                        out.push(b);
                    }
                }
                out
            }
            PromptKind::Mixed => {
                let half = PromptSpec { kind: PromptKind::Anchored, tokens: n, seed: self.seed }
                    .generate();
                let local =
                    PromptSpec { kind: PromptKind::Local, tokens: n, seed: self.seed ^ 0xA5 }
                        .generate();
                half.iter()
                    .zip(&local)
                    .enumerate()
                    .map(|(i, (&a, &l))| if (i / 64) % 2 == 0 { a } else { l })
                    .collect()
            }
            PromptKind::SharedPrefix { prefix_seed, prefix_blocks } => {
                // the prefix is a function of (prefix_seed, prefix_blocks)
                // alone — byte-exact across every cohort member, whatever
                // their total length or per-request seed
                let plen = (prefix_blocks as usize * crate::config::BLOCK).min(n);
                let mut out = PromptSpec {
                    kind: PromptKind::Mixed,
                    tokens: plen,
                    seed: 0x5A17_0000u64 ^ prefix_seed as u64,
                }
                .generate();
                if n > plen {
                    out.extend(
                        PromptSpec { kind: PromptKind::Mixed, tokens: n - plen, seed: self.seed }
                            .generate(),
                    );
                }
                out
            }
        }
    }
}

/// Scheduling priority class of a serving request (ROADMAP serving
/// follow-on (b)). Preemptive policies rank `Interactive` requests ahead
/// of `Batch` at every phase boundary; non-preemptive policies ignore the
/// class entirely, so it is free to carry on every trace.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    /// Latency-sensitive: preferred at phase boundaries.
    #[default]
    Interactive,
    /// Throughput class: yields phase slots to `Interactive` requests,
    /// protected from starvation by the scheduler's aging bound.
    Batch,
}

impl Priority {
    pub fn name(self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Batch => "batch",
        }
    }
}

/// A single serving request in a trace.
#[derive(Clone, Debug)]
pub struct TraceRequest {
    pub id: u64,
    pub spec: PromptSpec,
    /// Offset from trace start (us) at which the request arrives.
    pub arrival_us: u64,
    /// Scheduling class (ignored by non-preemptive policies).
    pub priority: Priority,
    /// Decode tokens to generate after prefill (0 = prefill-only, the
    /// historical trace shape). The server runs these as per-token decode
    /// steps co-scheduled between other requests' prefill chunks.
    pub decode_tokens: usize,
}

/// A batch-of-requests trace for the serving example / benches.
#[derive(Clone, Debug)]
pub struct RequestTrace {
    pub requests: Vec<TraceRequest>,
}

impl RequestTrace {
    /// Poisson-ish arrivals with mean inter-arrival `mean_gap_us`.
    pub fn generate(
        n_requests: usize,
        tokens: usize,
        mean_gap_us: u64,
        seed: u64,
    ) -> RequestTrace {
        let mut rng = Prng::new(seed);
        let kinds = [PromptKind::Random, PromptKind::Anchored, PromptKind::Local, PromptKind::Mixed];
        let mut t = 0u64;
        let requests = (0..n_requests)
            .map(|i| {
                // exponential inter-arrival via inverse CDF
                let u = rng.f32().max(1e-6) as f64;
                t += (-(u.ln()) * mean_gap_us as f64) as u64;
                TraceRequest {
                    id: i as u64,
                    spec: PromptSpec {
                        kind: kinds[rng.below(kinds.len())],
                        tokens,
                        seed: seed.wrapping_mul(31).wrapping_add(i as u64),
                    },
                    arrival_us: t,
                    priority: Priority::Interactive,
                    decode_tokens: 0,
                }
            })
            .collect();
        RequestTrace { requests }
    }

    /// Like [`RequestTrace::generate`], but each request's context length
    /// is drawn from `token_choices` — the mixed-length contention trace
    /// the pipelined server is measured on (short requests expose SJF and
    /// phase-overlap behaviour that uniform lengths hide). Requests drawn
    /// at the longest choice are classed [`Priority::Batch`]; everything
    /// shorter is [`Priority::Interactive`] (uniform traces stay all
    /// interactive), so preemptive policies see the head-of-line shape
    /// the trace was built to expose.
    pub fn generate_mixed(
        n_requests: usize,
        token_choices: &[usize],
        mean_gap_us: u64,
        seed: u64,
    ) -> RequestTrace {
        assert!(!token_choices.is_empty());
        let mut rng = Prng::new(seed);
        let kinds =
            [PromptKind::Random, PromptKind::Anchored, PromptKind::Local, PromptKind::Mixed];
        let longest = *token_choices.iter().max().unwrap();
        let shortest = *token_choices.iter().min().unwrap();
        let mut t = 0u64;
        let requests = (0..n_requests)
            .map(|i| {
                let u = rng.f32().max(1e-6) as f64;
                t += (-(u.ln()) * mean_gap_us as f64) as u64;
                // same draw order as ever (kind, then length), so seeded
                // traces are unchanged from before classes existed
                let kind = kinds[rng.below(kinds.len())];
                let tokens = token_choices[rng.below(token_choices.len())];
                TraceRequest {
                    id: i as u64,
                    spec: PromptSpec {
                        kind,
                        tokens,
                        seed: seed.wrapping_mul(31).wrapping_add(i as u64),
                    },
                    arrival_us: t,
                    priority: Self::class_for(tokens, shortest, longest),
                    decode_tokens: 0,
                }
            })
            .collect();
        RequestTrace { requests }
    }

    /// Like [`RequestTrace::generate_mixed`], but requests are dealt
    /// round-robin into `n_cohorts` shared-prefix cohorts: every member
    /// of a cohort carries byte-identical leading
    /// `prefix_blocks * BLOCK` tokens (clamped so the shortest length
    /// choice keeps at least one novel block) with its own mixed tail —
    /// the workload shape the cross-request prefix KV store converts
    /// into priced cache hits. Arrival times, lengths and priority
    /// classes are exactly the `generate_mixed` draws for the same seed,
    /// so cohort traces are comparable to their no-prefix twins.
    pub fn generate_shared_prefix(
        n_requests: usize,
        token_choices: &[usize],
        mean_gap_us: u64,
        seed: u64,
        prefix_blocks: u16,
        n_cohorts: usize,
    ) -> RequestTrace {
        assert!(n_cohorts > 0 && prefix_blocks > 0);
        let shortest = *token_choices.iter().min().expect("token choices");
        let block = crate::config::BLOCK;
        let pb = (prefix_blocks as usize)
            .min((shortest / block).saturating_sub(1))
            .max(1) as u16;
        let mut trace =
            RequestTrace::generate_mixed(n_requests, token_choices, mean_gap_us, seed);
        for (i, r) in trace.requests.iter_mut().enumerate() {
            let cohort = (i % n_cohorts) as u32;
            r.spec.kind = PromptKind::SharedPrefix {
                prefix_seed: (seed as u32) ^ cohort.wrapping_mul(0x9E37_79B9),
                prefix_blocks: pb,
            };
        }
        trace
    }

    /// Continue every request into decode for `n` tokens — turns any
    /// prefill trace into a mixed prefill+decode (continuous batching)
    /// trace without perturbing arrivals, lengths or classes.
    pub fn with_decode_tokens(mut self, n: usize) -> RequestTrace {
        for r in &mut self.requests {
            r.decode_tokens = n;
        }
        self
    }

    /// The mixed-trace class rule: the longest length class is `Batch`,
    /// everything shorter (when the trace has any length spread at all)
    /// is `Interactive`.
    pub fn class_for(tokens: usize, shortest: usize, longest: usize) -> Priority {
        if tokens >= longest && shortest < longest {
            Priority::Batch
        } else {
            Priority::Interactive
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_right_length_all_kinds() {
        for kind in [PromptKind::Random, PromptKind::Anchored, PromptKind::Local, PromptKind::Mixed]
        {
            let p = PromptSpec { kind, tokens: 1024, seed: 3 }.generate();
            assert_eq!(p.len(), 1024, "{kind:?}");
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let s = PromptSpec { kind: PromptKind::Mixed, tokens: 512, seed: 9 };
        assert_eq!(s.generate(), s.generate());
    }

    #[test]
    fn local_has_long_runs() {
        let p = PromptSpec { kind: PromptKind::Local, tokens: 4096, seed: 1 }.generate();
        let mut max_run = 1;
        let mut run = 1;
        for i in 1..p.len() {
            if p[i] == p[i - 1] {
                run += 1;
                max_run = max_run.max(run);
            } else {
                run = 1;
            }
        }
        assert!(max_run >= 16, "max run {max_run}");
    }

    #[test]
    fn mixed_trace_draws_from_choices() {
        let choices = [256usize, 512, 1024];
        let t = RequestTrace::generate_mixed(24, &choices, 1000, 11);
        assert_eq!(t.requests.len(), 24);
        for r in &t.requests {
            assert!(choices.contains(&r.spec.tokens), "{}", r.spec.tokens);
        }
        // determinism per seed
        let u = RequestTrace::generate_mixed(24, &choices, 1000, 11);
        for (a, b) in t.requests.iter().zip(&u.requests) {
            assert_eq!(a.spec.tokens, b.spec.tokens);
            assert_eq!(a.spec.seed, b.spec.seed);
        }
        // with 24 draws over 3 choices, at least two distinct lengths
        let distinct: std::collections::HashSet<usize> =
            t.requests.iter().map(|r| r.spec.tokens).collect();
        assert!(distinct.len() >= 2);
    }

    #[test]
    fn mixed_trace_classes_longest_as_batch() {
        let choices = [256usize, 512, 1024];
        let t = RequestTrace::generate_mixed(32, &choices, 1000, 11);
        for r in &t.requests {
            let expect =
                if r.spec.tokens == 1024 { Priority::Batch } else { Priority::Interactive };
            assert_eq!(r.priority, expect, "tokens {}", r.spec.tokens);
        }
        // uniform-length traces have no batch class to carve out
        let u = RequestTrace::generate(8, 512, 1000, 3);
        assert!(u.requests.iter().all(|r| r.priority == Priority::Interactive));
        assert_eq!(RequestTrace::class_for(512, 512, 512), Priority::Interactive);
        assert_eq!(RequestTrace::class_for(1024, 256, 1024), Priority::Batch);
    }

    #[test]
    fn shared_prefix_cohort_members_share_leading_bytes_exactly() {
        let kind = PromptKind::SharedPrefix { prefix_seed: 7, prefix_blocks: 2 };
        let a = PromptSpec { kind, tokens: 512, seed: 100 }.generate();
        let b = PromptSpec { kind, tokens: 1024, seed: 200 }.generate();
        assert_eq!(a.len(), 512);
        assert_eq!(b.len(), 1024);
        // byte-identical prefix across lengths and per-request seeds...
        assert_eq!(a[..256], b[..256], "cohort prefix must be byte-exact");
        // ...with genuinely novel tails
        assert_ne!(a[256..512], b[256..512]);
        // a different cohort seed diverges inside the first block
        let c = PromptSpec {
            kind: PromptKind::SharedPrefix { prefix_seed: 8, prefix_blocks: 2 },
            tokens: 512,
            seed: 100,
        }
        .generate();
        assert_ne!(a[..256], c[..256]);
        // shorter than the prefix: truncated, still deterministic
        let d = PromptSpec { kind, tokens: 100, seed: 1 }.generate();
        assert_eq!(d.len(), 100);
        assert_eq!(d[..], a[..100]);
    }

    #[test]
    fn shared_prefix_trace_rides_the_mixed_draws() {
        let choices = [512usize, 1024];
        let mixed = RequestTrace::generate_mixed(16, &choices, 1000, 11);
        let t = RequestTrace::generate_shared_prefix(16, &choices, 1000, 11, 2, 2);
        assert_eq!(t.requests.len(), 16);
        for (r, m) in t.requests.iter().zip(&mixed.requests) {
            // arrivals, lengths, classes and per-request seeds unchanged
            assert_eq!(r.arrival_us, m.arrival_us);
            assert_eq!(r.spec.tokens, m.spec.tokens);
            assert_eq!(r.spec.seed, m.spec.seed);
            assert_eq!(r.priority, m.priority);
            match r.spec.kind {
                PromptKind::SharedPrefix { prefix_blocks, .. } => {
                    assert_eq!(prefix_blocks, 2);
                }
                k => panic!("expected a shared-prefix kind, got {k:?}"),
            }
        }
        // round-robin: requests 0 and 2 share a cohort, 0 and 1 do not
        let tok = |i: usize| t.requests[i].spec.generate();
        assert_eq!(tok(0)[..256], tok(2)[..256]);
        assert_ne!(tok(0)[..256], tok(1)[..256]);
    }

    #[test]
    fn trace_arrivals_monotone() {
        let t = RequestTrace::generate(20, 4096, 1000, 5);
        assert_eq!(t.requests.len(), 20);
        for w in t.requests.windows(2) {
            assert!(w[0].arrival_us <= w[1].arrival_us);
        }
    }
}
