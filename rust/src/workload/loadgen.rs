//! Closed-loop load generation for cluster serving.
//!
//! Open-loop traces ([`RequestTrace::generate_mixed`]) model arrivals as
//! an external clock: requests land at recorded offsets whether or not
//! the servers keep up. A closed-loop generator models saturating
//! clients instead — `streams` concurrent clients that each keep exactly
//! one request outstanding and submit the next the moment the last
//! completes. That is the heavy-traffic shape the replica router exists
//! for: with `streams` in the hundreds, every replica's queue stays
//! non-empty and placement quality (not arrival luck) decides TTFT.
//!
//! The generator is a pure function of its construction parameters:
//! `request(stream, k)` is derived entirely from the seed and indices,
//! so the same `LoadGen` yields the same request set on every run —
//! the cluster's bit-identity and placement-replay contracts extend to
//! closed-loop driving unchanged. All requests carry `arrival_us = 0`:
//! in closed-loop serving the *submission moment* is decided by the
//! client loop (or, in the batch-submit harness, by queue admission),
//! not by the trace.

use crate::util::prng::Prng;
use crate::workload::prompts::{PromptKind, PromptSpec, RequestTrace, TraceRequest};

/// A deterministic closed-loop workload: `streams` clients ×
/// `requests_per_stream` requests each, lengths drawn per-request from
/// `token_choices` (longest choice classed `Batch`, like the open-loop
/// mixed trace).
#[derive(Clone, Debug)]
pub struct LoadGen {
    pub streams: usize,
    pub requests_per_stream: usize,
    pub token_choices: Vec<usize>,
    pub seed: u64,
}

impl LoadGen {
    pub fn new(
        streams: usize,
        requests_per_stream: usize,
        token_choices: &[usize],
        seed: u64,
    ) -> LoadGen {
        assert!(streams > 0 && requests_per_stream > 0 && !token_choices.is_empty());
        LoadGen {
            streams,
            requests_per_stream,
            token_choices: token_choices.to_vec(),
            seed,
        }
    }

    /// Total requests the generator produces.
    pub fn total(&self) -> usize {
        self.streams * self.requests_per_stream
    }

    /// The `k`-th request of client `stream` — a pure function of
    /// (seed, stream, k). Ids interleave streams round-robin
    /// (`k * streams + stream`), matching the submission order of
    /// clients that advance in lockstep, so id order is a meaningful
    /// global submission order for the batch-submit harness.
    pub fn request(&self, stream: usize, k: usize) -> TraceRequest {
        assert!(stream < self.streams && k < self.requests_per_stream);
        let id = (k * self.streams + stream) as u64;
        // one private rng per request: no draw-order coupling between
        // streams, so any subset of streams replays identically
        let mut rng = Prng::new(
            self.seed
                .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                .wrapping_add((stream as u64) << 32)
                .wrapping_add(k as u64),
        );
        let kinds =
            [PromptKind::Random, PromptKind::Anchored, PromptKind::Local, PromptKind::Mixed];
        let kind = kinds[rng.below(kinds.len())];
        let tokens = self.token_choices[rng.below(self.token_choices.len())];
        let shortest = *self.token_choices.iter().min().unwrap();
        let longest = *self.token_choices.iter().max().unwrap();
        TraceRequest {
            id,
            spec: PromptSpec {
                kind,
                tokens,
                seed: self.seed.wrapping_mul(31).wrapping_add(id),
            },
            arrival_us: 0,
            priority: RequestTrace::class_for(tokens, shortest, longest),
            decode_tokens: 0,
        }
    }

    /// The whole workload as a trace in global submission (= id) order,
    /// ready for the cluster's batch-submit harness: `arrival_us` is 0
    /// throughout, so replay degenerates to submit-as-fast-as-possible —
    /// the closed-loop saturation regime.
    pub fn trace(&self) -> RequestTrace {
        let mut requests = Vec::with_capacity(self.total());
        for k in 0..self.requests_per_stream {
            for stream in 0..self.streams {
                requests.push(self.request(stream, k));
            }
        }
        RequestTrace { requests }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loadgen_is_deterministic() {
        let a = LoadGen::new(8, 4, &[256, 512], 99).trace();
        let b = LoadGen::new(8, 4, &[256, 512], 99).trace();
        assert_eq!(a.requests.len(), 32);
        for (x, y) in a.requests.iter().zip(&b.requests) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.spec.tokens, y.spec.tokens);
            assert_eq!(x.spec.generate(), y.spec.generate());
            assert_eq!(x.priority, y.priority);
        }
    }

    #[test]
    fn ids_interleave_streams_round_robin() {
        let g = LoadGen::new(3, 2, &[256], 7);
        let trace = g.trace();
        let ids: Vec<u64> = trace.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4, 5]);
        // request(stream, k) addresses the same request the trace holds
        assert_eq!(g.request(1, 1).id, 4);
        assert_eq!(g.request(1, 1).spec.generate(), trace.requests[4].spec.generate());
    }

    #[test]
    fn streams_are_draw_independent() {
        // dropping a stream must not change the other streams' requests
        let wide = LoadGen::new(4, 2, &[256, 512], 11);
        let narrow = LoadGen::new(4, 1, &[256, 512], 11);
        for stream in 0..4 {
            let a = wide.request(stream, 0);
            let b = narrow.request(stream, 0);
            assert_eq!(a.spec.tokens, b.spec.tokens);
            assert_eq!(a.spec.generate(), b.spec.generate());
        }
    }

    #[test]
    fn scales_to_hundreds_in_flight() {
        let g = LoadGen::new(128, 3, &[256, 512, 1024], 2026);
        let trace = g.trace();
        assert_eq!(trace.requests.len(), 384);
        // every arrival is immediate (closed-loop submission order only)
        assert!(trace.requests.iter().all(|r| r.arrival_us == 0));
        // the length mix actually spans the choices
        for &c in &g.token_choices {
            assert!(trace.requests.iter().any(|r| r.spec.tokens == c), "no {c}-token draw");
        }
        // longest choice classes Batch, shorter ones Interactive
        assert!(trace
            .requests
            .iter()
            .all(|r| (r.spec.tokens == 1024) == (r.priority == crate::workload::Priority::Batch)));
    }
}
