//! Synthetic workload generation.
//!
//! No proprietary corpora are available offline; prompts are synthesized
//! with controllable attention structure (DESIGN.md substitution table):
//! repeated byte-level motifs create vertical columns (globally attended
//! tokens), local runs create slash diagonals, and uniform noise creates
//! diffuse query-aware mass. The needle workloads drive the Table III
//! retrieval proxy.

pub mod loadgen;
pub mod needle;
pub mod prompts;

pub use loadgen::LoadGen;
pub use needle::{NeedleTask, RetrievalOutcome};
pub use prompts::{Priority, PromptKind, PromptSpec, RequestTrace, TraceRequest};
