//! Deterministic PRNG (xoshiro256**) — the repo-wide randomness source.
//!
//! Everything in this repository that needs randomness (weights, workloads,
//! property tests) derives from a seeded [`Prng`], so every experiment in
//! EXPERIMENTS.md is exactly reproducible. No external `rand` crate is
//! available offline; this is a faithful xoshiro256** implementation.

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm).
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
}

impl Prng {
    /// Seed via SplitMix64 (the recommended seeding procedure).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Prng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent stream (for per-layer / per-head seeding).
    pub fn fork(&mut self, tag: u64) -> Prng {
        Prng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi > lo);
        lo + (self.next_u64() % (hi - lo) as u64) as i64
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f32().max(1e-9);
        let u2 = self.f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Random int8 in [-127, 127] (symmetric — never -128, matching the
    /// quantization contract).
    #[inline]
    pub fn i8_sym(&mut self) -> i8 {
        (self.range(-127, 128)) as i8
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut p = Prng::new(7);
        for _ in 0..10_000 {
            let x = p.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut p = Prng::new(9);
        for _ in 0..1000 {
            assert!(p.below(7) < 7);
        }
    }

    #[test]
    fn normal_moments() {
        let mut p = Prng::new(11);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| p.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn i8_sym_never_min() {
        let mut p = Prng::new(13);
        for _ in 0..10_000 {
            assert_ne!(p.i8_sym(), i8::MIN);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut p = Prng::new(17);
        let mut v: Vec<usize> = (0..50).collect();
        p.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut p = Prng::new(19);
        let s = p.sample_indices(100, 30);
        assert_eq!(s.len(), 30);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 30);
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Prng::new(3);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
