//! Mini property-based testing framework (proptest is unavailable offline).
//!
//! `forall(seed, cases, gen, prop)` runs `prop` over `cases` generated
//! inputs; on failure it retries with simpler inputs from the same generator
//! family (size-bounded shrinking) and reports the smallest failing case's
//! seed so the exact input is reproducible with [`crate::util::prng::Prng`].

use crate::util::prng::Prng;

/// Run a property over `cases` random inputs. `gen` receives a Prng and a
/// size hint in [1, 100] that grows over the run (small inputs first —
/// failures found early are already small).
pub fn forall<T, G, P>(seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Prng, usize) -> T,
    P: FnMut(&T) -> bool,
{
    let mut root = Prng::new(seed);
    for case in 0..cases {
        let size = 1 + (case * 100) / cases.max(1);
        let case_seed = root.next_u64();
        let mut rng = Prng::new(case_seed);
        let input = gen(&mut rng, size);
        if !prop(&input) {
            panic!(
                "property failed at case {case} (size {size}, case_seed {case_seed:#x}):\n{input:#?}"
            );
        }
    }
}

/// Like `forall` but the property returns `Result<(), String>` for richer
/// failure messages.
pub fn forall_ck<T, G, P>(seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Prng, usize) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut root = Prng::new(seed);
    for case in 0..cases {
        let size = 1 + (case * 100) / cases.max(1);
        let case_seed = root.next_u64();
        let mut rng = Prng::new(case_seed);
        let input = gen(&mut rng, size);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed at case {case} (size {size}, case_seed {case_seed:#x}): {msg}\n{input:#?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        forall(1, 50, |rng, size| rng.below(size.max(1)), |_| {
            n += 1;
            true
        });
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_input() {
        forall(2, 50, |rng, _| rng.below(10), |x| *x < 5);
    }

    #[test]
    fn sizes_grow() {
        let mut max_seen = 0;
        forall(3, 100, |_, size| size, |s| {
            max_seen = max_seen.max(*s);
            true
        });
        assert!(max_seen >= 99);
    }
}
