//! Perf-trend gate (ROADMAP SIMD follow-on (d)): compare a fresh
//! `hotpath_micro.json` summary against the checked-in
//! `ci/hotpath_baseline.json` with a per-kernel tolerance, so kernel
//! regressions fail the PR instead of silently drifting the paper
//! figures.
//!
//! No serde offline — a minimal hand-rolled JSON reader flattens the
//! (small, known-shape) summary into dotted numeric leaves
//! (`score_tile.scalar_ns`, ...). Only `*_ns` timing leaves are gated
//! (lower is better); ratio fields like `speedup` ride along for the
//! report but are not compared. Because absolute nanoseconds differ
//! across runner generations, the CI step compares **normalized** times:
//! every `_ns` leaf is divided by the same file's reference-kernel time
//! (`--normalize`), which cancels uniform machine speed and gates only
//! the *relative* shape of the hot paths.
//!
//! A baseline written by hand (or merged before ever running on the CI
//! runner class) can carry `"provisional": true`: the comparison is
//! reported but never fails. Refreshing the baseline with the bench
//! itself (one command: `FASTP_BENCH_JSON=ci/hotpath_baseline.json
//! cargo bench --bench hotpath_micro`) overwrites the file without the
//! flag and arms the gate.

/// One numeric leaf of a flattened JSON document.
pub type Metric = (String, f64);

/// Flatten every numeric (and boolean, as 0/1) leaf of a JSON document
/// into `parent.child` dotted keys. Supports the subset the bench
/// summaries use: objects, strings, numbers, booleans, null, and arrays
/// (indexed as `key.0`). Not a general validator — malformed input
/// errors out rather than panicking.
pub fn parse_metrics(json: &str) -> Result<Vec<Metric>, String> {
    let mut p = Reader { b: json.as_bytes(), i: 0 };
    let mut out = Vec::new();
    p.ws();
    p.value("", &mut out)?;
    p.ws();
    if p.i != p.b.len() {
        return Err(format!("trailing content at byte {}", p.i));
    }
    Ok(out)
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl Reader<'_> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let start = self.i;
        while let Some(c) = self.peek() {
            match c {
                b'"' => {
                    let s = std::str::from_utf8(&self.b[start..self.i])
                        .map_err(|e| e.to_string())?
                        .to_string();
                    self.i += 1;
                    return Ok(s);
                }
                b'\\' => self.i += 2, // skip the escaped char
                _ => self.i += 1,
            }
        }
        Err("unterminated string".into())
    }

    fn value(&mut self, path: &str, out: &mut Vec<Metric>) -> Result<(), String> {
        self.ws();
        match self.peek() {
            Some(b'{') => self.object(path, out),
            Some(b'[') => self.array(path, out),
            Some(b'"') => {
                self.string()?; // string leaves are not gated
                Ok(())
            }
            Some(b't') => self.literal("true", path, Some(1.0), out),
            Some(b'f') => self.literal("false", path, Some(0.0), out),
            Some(b'n') => self.literal("null", path, None, out),
            Some(_) => self.number(path, out),
            None => Err("unexpected end of input".into()),
        }
    }

    fn literal(
        &mut self,
        word: &str,
        path: &str,
        leaf: Option<f64>,
        out: &mut Vec<Metric>,
    ) -> Result<(), String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            if let Some(v) = leaf {
                out.push((path.to_string(), v));
            }
            Ok(())
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self, path: &str, out: &mut Vec<Metric>) -> Result<(), String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?;
        let v: f64 = s.parse().map_err(|_| format!("bad number '{s}' at byte {start}"))?;
        out.push((path.to_string(), v));
        Ok(())
    }

    fn object(&mut self, path: &str, out: &mut Vec<Metric>) -> Result<(), String> {
        self.expect(b'{')?;
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(());
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            let child = if path.is_empty() { key.clone() } else { format!("{path}.{key}") };
            self.value(&child, out)?;
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(());
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self, path: &str, out: &mut Vec<Metric>) -> Result<(), String> {
        self.expect(b'[')?;
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(());
        }
        let mut idx = 0usize;
        loop {
            self.value(&format!("{path}.{idx}"), out)?;
            idx += 1;
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(());
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }
}

/// One gated kernel timing, baseline vs fresh (normalized when a
/// reference key was given).
#[derive(Clone, Debug)]
pub struct TrendPoint {
    pub key: String,
    pub baseline: f64,
    pub fresh: f64,
    /// fresh / baseline (> 1 is slower).
    pub ratio: f64,
    /// Headroom to the gate: `(1 + tolerance) - ratio`. Positive =
    /// within tolerance by that much; negative = over by that much.
    /// Surfaced in the CLI table on success too, so a shrinking margin
    /// is visible in CI logs before it becomes a regression.
    pub margin: f64,
    /// Over the tolerance: this point is a regression.
    pub regressed: bool,
}

/// The perf-trend comparison result.
#[derive(Clone, Debug)]
pub struct TrendReport {
    pub points: Vec<TrendPoint>,
    /// Baseline `_ns` keys missing from the fresh summary — a renamed or
    /// dropped kernel; fails the gate until the baseline is refreshed.
    pub missing: Vec<String>,
    /// The baseline is marked `"provisional": true`: report, never fail.
    pub provisional: bool,
    pub tolerance: f64,
}

impl TrendReport {
    /// Regressed points (empty on a passing run).
    pub fn regressions(&self) -> Vec<&TrendPoint> {
        self.points.iter().filter(|p| p.regressed).collect()
    }

    /// Does this comparison fail the gate?
    pub fn failed(&self) -> bool {
        !self.provisional && (!self.missing.is_empty() || self.points.iter().any(|p| p.regressed))
    }
}

fn lookup(metrics: &[Metric], key: &str) -> Option<f64> {
    metrics.iter().find(|(k, _)| k == key).map(|&(_, v)| v)
}

/// Compare two bench summaries: every `_ns` leaf of the baseline must be
/// matched in the fresh run within `fresh <= baseline * (1 + tolerance)`.
/// With `normalize_key`, each file's `_ns` leaves are first divided by
/// that file's value at the key (which must be a positive `_ns` leaf in
/// both), gating relative shape instead of absolute runner speed.
pub fn compare_trend(
    baseline_json: &str,
    fresh_json: &str,
    tolerance: f64,
    normalize_key: Option<&str>,
) -> Result<TrendReport, String> {
    let base = parse_metrics(baseline_json).map_err(|e| format!("baseline: {e}"))?;
    let fresh = parse_metrics(fresh_json).map_err(|e| format!("fresh: {e}"))?;
    let provisional = lookup(&base, "provisional") == Some(1.0);
    let (base_div, fresh_div) = match normalize_key {
        None => (1.0, 1.0),
        Some(k) => {
            let b = lookup(&base, k)
                .filter(|&v| v > 0.0)
                .ok_or_else(|| format!("baseline lacks a positive normalize key '{k}'"))?;
            let f = lookup(&fresh, k)
                .filter(|&v| v > 0.0)
                .ok_or_else(|| format!("fresh summary lacks a positive normalize key '{k}'"))?;
            (b, f)
        }
    };
    let mut points = Vec::new();
    let mut missing = Vec::new();
    for (key, bv) in base.iter().filter(|(k, _)| k.ends_with("_ns")) {
        if *bv <= 0.0 {
            continue; // degenerate baseline entry: nothing to gate against
        }
        match lookup(&fresh, key) {
            None => missing.push(key.clone()),
            Some(fv) => {
                let b = bv / base_div;
                let f = fv / fresh_div;
                let ratio = f / b;
                points.push(TrendPoint {
                    key: key.clone(),
                    baseline: b,
                    fresh: f,
                    ratio,
                    margin: (1.0 + tolerance) - ratio,
                    regressed: ratio > 1.0 + tolerance,
                });
            }
        }
    }
    Ok(TrendReport { points, missing, provisional, tolerance })
}

#[cfg(test)]
mod tests {
    use super::*;

    const BASE: &str = r#"{
        "bench": "hotpath_micro",
        "arch": "x86_64",
        "score_tile": {"scalar_ns": 1000.0, "simd_ns": 400.0, "speedup": 2.5},
        "prefill_4k_native_sau": {"scalar_backend_ns": 9.0e6, "simd_backend_ns": 6.0e6,
                                  "bit_identical": true}
    }"#;

    fn doctor(json: &str, key_fragment: &str, factor: f64) -> String {
        // scale one numeric field of a known fixture (test helper)
        let at = json.find(key_fragment).unwrap();
        let colon = json[at..].find(':').unwrap() + at + 1;
        let end = json[colon..].find(|c: char| c == ',' || c == '}').unwrap() + colon;
        let v: f64 = json[colon..end].trim().parse().unwrap();
        format!("{}{}{}", &json[..colon], v * factor, &json[end..])
    }

    #[test]
    fn parses_nested_numeric_and_bool_leaves() {
        let m = parse_metrics(BASE).unwrap();
        assert_eq!(lookup(&m, "score_tile.scalar_ns"), Some(1000.0));
        assert_eq!(lookup(&m, "score_tile.speedup"), Some(2.5));
        assert_eq!(lookup(&m, "prefill_4k_native_sau.bit_identical"), Some(1.0));
        assert_eq!(lookup(&m, "bench"), None, "string leaves are not metrics");
        assert!(parse_metrics("{\"a\": }").is_err());
        assert!(parse_metrics("[1, 2.5]").unwrap().len() == 2);
    }

    #[test]
    fn identical_runs_pass() {
        let r = compare_trend(BASE, BASE, 0.25, None).unwrap();
        assert!(!r.failed());
        assert_eq!(r.points.len(), 4, "all four _ns leaves compared");
        assert!(r.regressions().is_empty());
        assert!(r.missing.is_empty());
    }

    #[test]
    fn injected_slowdown_fails_the_gate() {
        // 1.5x on one kernel vs a 25% tolerance: exactly the regression
        // the CI perf-trend step must catch
        let slow = doctor(BASE, "\"simd_ns\"", 1.5);
        let r = compare_trend(BASE, &slow, 0.25, None).unwrap();
        assert!(r.failed());
        let regs = r.regressions();
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].key, "score_tile.simd_ns");
        assert!((regs[0].ratio - 1.5).abs() < 1e-9);
        // within tolerance passes
        let ok = doctor(BASE, "\"simd_ns\"", 1.2);
        assert!(!compare_trend(BASE, &ok, 0.25, None).unwrap().failed());
    }

    #[test]
    fn normalization_cancels_uniform_machine_speed() {
        // a fresh run on a 3x slower machine: raw comparison fails,
        // normalized comparison passes (relative shape unchanged)
        let mut slow = BASE.to_string();
        let keys = ["\"scalar_ns\"", "\"simd_ns\"", "\"scalar_backend_ns\"", "\"simd_backend_ns\""];
        for key in keys {
            slow = doctor(&slow, key, 3.0);
        }
        assert!(compare_trend(BASE, &slow, 0.25, None).unwrap().failed());
        let r = compare_trend(BASE, &slow, 0.25, Some("score_tile.scalar_ns")).unwrap();
        assert!(!r.failed(), "normalized: {:?}", r.regressions());
        // ...but a *relative* slowdown still fails under normalization
        let skew = doctor(BASE, "\"simd_ns\"", 2.0);
        let r = compare_trend(BASE, &skew, 0.25, Some("score_tile.scalar_ns")).unwrap();
        assert!(r.failed());
        assert_eq!(r.regressions()[0].key, "score_tile.simd_ns");
    }

    #[test]
    fn provisional_baseline_reports_but_never_fails() {
        let prov = BASE.replacen('{', "{\n  \"provisional\": true,", 1);
        let slow = doctor(BASE, "\"simd_ns\"", 4.0);
        let r = compare_trend(&prov, &slow, 0.25, None).unwrap();
        assert!(r.provisional);
        assert_eq!(r.regressions().len(), 1, "regression still reported");
        assert!(!r.failed(), "provisional gates never fail");
    }

    #[test]
    fn missing_kernel_fails_until_baseline_refresh() {
        let fresh = BASE.replace("\"simd_ns\": 400.0, ", "");
        let r = compare_trend(BASE, &fresh, 0.25, None).unwrap();
        assert_eq!(r.missing, vec!["score_tile.simd_ns".to_string()]);
        assert!(r.failed());
    }

    #[test]
    fn margins_report_headroom_on_both_sides_of_the_gate() {
        // 1.2x vs 25% tolerance: passes with +0.05 headroom
        let ok = doctor(BASE, "\"simd_ns\"", 1.2);
        let r = compare_trend(BASE, &ok, 0.25, None).unwrap();
        let p = r.points.iter().find(|p| p.key == "score_tile.simd_ns").unwrap();
        assert!(!p.regressed);
        assert!((p.margin - 0.05).abs() < 1e-9, "margin {}", p.margin);
        // an untouched kernel carries the full tolerance as headroom
        let flat = r.points.iter().find(|p| p.key == "score_tile.scalar_ns").unwrap();
        assert!((flat.margin - 0.25).abs() < 1e-9);
        // 1.5x: fails with the overshoot as a negative margin
        let slow = doctor(BASE, "\"simd_ns\"", 1.5);
        let r = compare_trend(BASE, &slow, 0.25, None).unwrap();
        let p = r.points.iter().find(|p| p.key == "score_tile.simd_ns").unwrap();
        assert!(p.regressed);
        assert!((p.margin + 0.25).abs() < 1e-9, "margin {}", p.margin);
    }

    #[test]
    fn missing_normalize_key_is_an_error() {
        assert!(compare_trend(BASE, BASE, 0.25, Some("nope_ns")).is_err());
    }
}
