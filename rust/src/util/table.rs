//! Plain-text table formatting for bench/report output (paper-style rows).

/// A simple aligned table printer.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths = vec![0usize; ncol];
        for (i, h) in self.headers.iter().enumerate() {
            widths[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::from("| ");
            for (c, w) in cells.iter().zip(widths) {
                s.push_str(&format!("{:<width$} | ", c, width = w));
            }
            s.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str("|");
        for w in &widths {
            out.push_str(&"-".repeat(w + 2));
            out.push('|');
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float with engineering-style precision for tables.
pub fn fnum(x: f64) -> String {
    if x == 0.0 {
        "0".into()
    } else if x.abs() >= 1000.0 {
        format!("{:.0}", x)
    } else if x.abs() >= 10.0 {
        format!("{:.1}", x)
    } else if x.abs() >= 0.1 {
        format!("{:.2}", x)
    } else {
        format!("{:.4}", x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["a", "long-header"]);
        t.row_strs(&["1", "2"]);
        let s = t.render();
        assert!(s.contains("| a | long-header |"));
        assert!(s.lines().count() == 3);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new(&["a"]);
        t.row_strs(&["1", "2"]);
    }

    #[test]
    fn fnum_ranges() {
        assert_eq!(fnum(0.0), "0");
        assert_eq!(fnum(1234.0), "1234");
        assert_eq!(fnum(12.34), "12.3");
        assert_eq!(fnum(0.5), "0.50");
        assert_eq!(fnum(0.01234), "0.0123");
    }
}
