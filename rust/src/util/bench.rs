//! Minimal benchmarking harness (criterion is unavailable offline).
//!
//! Every `rust/benches/*.rs` binary uses this: warmup, timed iterations,
//! mean/p50/p95 reporting, and a black-box to defeat the optimizer. Output
//! formatting matches the row/series layout of the paper tables so that
//! `cargo bench | tee bench_output.txt` regenerates them directly.

use std::time::Instant;

/// Re-export of `std::hint::black_box` for bench binaries.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Timing result for a benchmarked closure.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 { self.mean_ns / 1e6 }
    pub fn mean_us(&self) -> f64 { self.mean_ns / 1e3 }
}

/// Run `f` for `iters` timed iterations after `warmup` untimed ones.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    let mean = crate::util::stats::mean(&samples);
    BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        p50_ns: crate::util::stats::percentile(&samples, 50.0),
        p95_ns: crate::util::stats::percentile(&samples, 95.0),
    }
}

/// Adaptive: time-boxed benchmark — at least `min_iters`, stop after
/// `budget_ms` of measurement.
pub fn bench_for<F: FnMut()>(name: &str, budget_ms: u64, min_iters: usize, mut f: F) -> BenchResult {
    f(); // warmup
    let start = Instant::now();
    let mut samples = Vec::new();
    while samples.len() < min_iters || start.elapsed().as_millis() < budget_ms as u128 {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
        if samples.len() > 1_000_000 {
            break;
        }
    }
    let mean = crate::util::stats::mean(&samples);
    BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean_ns: mean,
        p50_ns: crate::util::stats::percentile(&samples, 50.0),
        p95_ns: crate::util::stats::percentile(&samples, 95.0),
    }
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<42} {:>10.3} ms/iter  (p50 {:>9.3}, p95 {:>9.3}, n={})",
            self.name,
            self.mean_ns / 1e6,
            self.p50_ns / 1e6,
            self.p95_ns / 1e6,
            self.iters
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iters() {
        let mut n = 0u64;
        let r = bench("t", 2, 10, || {
            n += 1;
        });
        assert_eq!(r.iters, 10);
        assert_eq!(n, 12); // warmup + timed
        assert!(r.mean_ns >= 0.0);
    }

    #[test]
    fn bench_for_respects_min_iters() {
        let r = bench_for("t", 0, 5, || {});
        assert!(r.iters >= 5);
    }
}
