//! Shared utilities: deterministic PRNG, statistics, bench harness,
//! property-testing, table formatting.

pub mod bench;
pub mod prng;
pub mod prop;
pub mod stats;
pub mod table;
