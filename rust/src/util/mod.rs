//! Shared utilities: deterministic PRNG, statistics, bench harness,
//! property-testing, table formatting, the kernel worker pool, and the
//! perf-trend comparator behind the CI gate.

pub mod bench;
pub mod pool;
pub mod prng;
pub mod prop;
pub mod stats;
pub mod table;
pub mod trend;
