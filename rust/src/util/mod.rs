//! Shared utilities: deterministic PRNG, statistics, bench harness,
//! property-testing, table formatting, and the kernel worker pool.

pub mod bench;
pub mod pool;
pub mod prng;
pub mod prop;
pub mod stats;
pub mod table;
