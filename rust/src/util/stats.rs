//! Small statistics helpers used by benches, metrics and the simulator.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() { return 0.0; }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 { return 0.0; }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-th percentile (nearest-rank, p in [0, 100]).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() { return 0.0; }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Geometric mean (positive inputs).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() { return 0.0; }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Cosine similarity between two vectors.
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let (mut dot, mut na, mut nb) = (0f64, 0f64, 0f64);
    for (x, y) in a.iter().zip(b) {
        dot += *x as f64 * *y as f64;
        na += *x as f64 * *x as f64;
        nb += *y as f64 * *y as f64;
    }
    if na == 0.0 || nb == 0.0 { return 0.0; }
    dot / (na.sqrt() * nb.sqrt())
}

/// Max absolute difference.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

/// Relative L2 error ||a-b|| / ||b||.
pub fn rel_l2(a: &[f32], b: &[f32]) -> f64 {
    let num: f64 = a.iter().zip(b).map(|(x, y)| ((x - y) as f64).powi(2)).sum();
    let den: f64 = b.iter().map(|y| (*y as f64).powi(2)).sum();
    if den == 0.0 { return num.sqrt(); }
    (num / den).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_simple() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn percentile_endpoints() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
    }

    #[test]
    fn geomean_of_powers() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn cosine_identical_is_one() {
        let v = [1.0f32, -2.0, 3.0];
        assert!((cosine(&v, &v) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn cosine_orthogonal_is_zero() {
        assert!(cosine(&[1.0, 0.0], &[0.0, 1.0]).abs() < 1e-9);
    }

    #[test]
    fn rel_l2_zero_for_equal() {
        let v = [1.0f32, 2.0];
        assert_eq!(rel_l2(&v, &v), 0.0);
    }

    #[test]
    fn stddev_constant_is_zero() {
        assert_eq!(stddev(&[3.0, 3.0, 3.0]), 0.0);
    }
}
