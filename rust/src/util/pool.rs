//! Shared worker pool for the block-major kernel layer (std threads only;
//! rayon is unavailable offline).
//!
//! The pool executes *independent jobs* — per-head SIGU scoring, per-state
//! SAU accumulator folds, per-chunk QKV/FFN — with dynamic work stealing
//! over a shared atomic counter. Each job's arithmetic is entirely local to
//! the worker that claims it and results are re-assembled in job order, so
//! the output is **bit-identical for every thread count** (asserted by
//! property tests and by the engine's FASTP_THREADS=1 vs N test).
//!
//! Sizing: `FASTP_THREADS` env var; default = available parallelism.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Environment variable that bounds the worker count.
pub const THREADS_ENV: &str = "FASTP_THREADS";

/// A fixed-width pool of scoped worker threads.
///
/// The pool is a *sizing policy*, not a set of live threads: workers are
/// spawned per [`WorkerPool::map`] call with `std::thread::scope`, which
/// lets jobs borrow caller state (chunks, weights, schedules) without any
/// `'static` or `Arc` ceremony.
#[derive(Clone, Debug)]
pub struct WorkerPool {
    threads: usize,
}

impl WorkerPool {
    /// Pool sized by `FASTP_THREADS`, defaulting to available parallelism.
    pub fn from_env() -> WorkerPool {
        let threads = std::env::var(THREADS_ENV)
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            });
        WorkerPool { threads }
    }

    /// Pool with an explicit worker count (clamped to >= 1).
    pub fn with_threads(n: usize) -> WorkerPool {
        WorkerPool { threads: n.max(1) }
    }

    /// Single-threaded pool (jobs run inline on the caller).
    pub fn single_threaded() -> WorkerPool {
        WorkerPool { threads: 1 }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(0..n_jobs)` across the pool and return the results in job
    /// order. Jobs are claimed dynamically (atomic counter) so skewed job
    /// costs balance; because each job is computed independently and
    /// results are slotted by index, the output does not depend on the
    /// thread count or claim interleaving.
    pub fn map<T, F>(&self, n_jobs: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let workers = self.threads.min(n_jobs);
        if workers <= 1 {
            return (0..n_jobs).map(f).collect();
        }
        let next = AtomicUsize::new(0);
        let parts: Vec<Vec<(usize, T)>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..workers)
                .map(|_| {
                    s.spawn(|| {
                        let mut local = Vec::new();
                        loop {
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= n_jobs {
                                break;
                            }
                            local.push((i, f(i)));
                        }
                        local
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("pool worker panicked")).collect()
        });
        let mut slots: Vec<Option<T>> = (0..n_jobs).map(|_| None).collect();
        for part in parts {
            for (i, v) in part {
                slots[i] = Some(v);
            }
        }
        slots.into_iter().map(|o| o.expect("pool job not executed")).collect()
    }

    /// Run a side-effect-free-per-index job for its effects only.
    pub fn for_each<F>(&self, n_jobs: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        let _ = self.map(n_jobs, |i| {
            f(i);
        });
    }
}

impl Default for WorkerPool {
    fn default() -> Self {
        WorkerPool::from_env()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_preserves_job_order() {
        for threads in [1, 2, 8] {
            let pool = WorkerPool::with_threads(threads);
            let out = pool.map(37, |i| i * i);
            assert_eq!(out, (0..37).map(|i| i * i).collect::<Vec<_>>(), "threads={threads}");
        }
    }

    #[test]
    fn map_handles_empty_and_single() {
        let pool = WorkerPool::with_threads(4);
        assert_eq!(pool.map(0, |i| i), Vec::<usize>::new());
        assert_eq!(pool.map(1, |i| i + 10), vec![10]);
    }

    #[test]
    fn results_identical_across_thread_counts() {
        // heavier, uneven jobs: claim order varies, results must not
        let work = |i: usize| -> u64 {
            let mut acc = 0u64;
            for k in 0..(i % 7) * 1000 + 10 {
                acc = acc.wrapping_mul(31).wrapping_add(k as u64 ^ i as u64);
            }
            acc
        };
        let seq = WorkerPool::single_threaded().map(64, work);
        for threads in [2, 3, 8] {
            assert_eq!(WorkerPool::with_threads(threads).map(64, work), seq);
        }
    }

    #[test]
    fn jobs_can_borrow_caller_state() {
        let data: Vec<f32> = (0..100).map(|i| i as f32).collect();
        let pool = WorkerPool::with_threads(4);
        let sums = pool.map(10, |i| data[i * 10..(i + 1) * 10].iter().sum::<f32>());
        assert_eq!(sums.iter().sum::<f32>(), data.iter().sum::<f32>());
    }

    #[test]
    fn with_threads_clamps_to_one() {
        assert_eq!(WorkerPool::with_threads(0).threads(), 1);
    }

    #[test]
    fn for_each_runs_all_jobs() {
        let hits = AtomicUsize::new(0);
        WorkerPool::with_threads(3).for_each(25, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 25);
    }
}
