//! Shared worker pool for the block-major kernel layer (std threads only;
//! rayon is unavailable offline).
//!
//! The pool executes *independent jobs* — per-head SIGU scoring, per-state
//! SAU accumulator folds, per-chunk QKV/FFN — with dynamic work stealing
//! over a shared atomic counter. Each job's arithmetic is entirely local to
//! the worker that claims it and results are re-assembled in job order, so
//! the output is **bit-identical for every thread count** (asserted by
//! property tests and by the engine's FASTP_THREADS=1 vs N test).
//!
//! Sizing: `FASTP_THREADS` env var; default = available parallelism.
//!
//! Multi-engine serving shares one machine-wide budget through
//! [`PoolBudget`]: each `map` call *leases* up to `min(threads, n_jobs)`
//! slots for its duration, so concurrent engines split the cores
//! dynamically instead of oversubscribing `n_engines x pool_size` threads.
//! The lease size only changes how many workers claim jobs, never the
//! results (see the bit-identity contract above).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Environment variable that bounds the worker count.
pub const THREADS_ENV: &str = "FASTP_THREADS";

/// A machine-wide compute-slot budget shared by several [`WorkerPool`]s.
///
/// Admission is blocking but minimal: a lease waits only until *one* slot
/// is free, then takes as many as are available (capped by the request).
/// Leases are released when the `map` call finishes, so waits are bounded
/// by in-flight kernel phases. Jobs must not issue nested `map` calls on a
/// budget-backed pool (the outer lease would starve the inner one); no
/// kernel-layer job does.
#[derive(Debug)]
pub struct PoolBudget {
    total: usize,
    free: Mutex<usize>,
    cond: Condvar,
}

impl PoolBudget {
    /// A budget of `total` slots (clamped to >= 1).
    pub fn new(total: usize) -> Arc<PoolBudget> {
        let total = total.max(1);
        Arc::new(PoolBudget { total, free: Mutex::new(total), cond: Condvar::new() })
    }

    /// Budget sized by `FASTP_THREADS` (default: available parallelism).
    pub fn from_env() -> Arc<PoolBudget> {
        PoolBudget::new(env_threads())
    }

    pub fn total(&self) -> usize {
        self.total
    }

    /// Slots currently unleased (snapshot; for tests/diagnostics).
    pub fn available(&self) -> usize {
        *self.free.lock().unwrap()
    }

    /// Block until at least one slot is free, then take `min(want, free)`.
    fn acquire(&self, want: usize) -> usize {
        let want = want.max(1);
        let mut free = self.free.lock().unwrap();
        while *free == 0 {
            free = self.cond.wait(free).unwrap();
        }
        let granted = want.min(*free);
        *free -= granted;
        granted
    }

    fn release(&self, n: usize) {
        let mut free = self.free.lock().unwrap();
        *free += n;
        drop(free);
        self.cond.notify_all();
    }
}

/// Phase slots tracked by [`AdaptiveHints`] — the engine's four prefill
/// phases (QKV, IndexGen, SAU, FFN/logits), by `Phase` order.
pub const HINT_PHASES: usize = 4;

/// Smoothing factor for the per-phase cost EWMA (weight of the newest
/// observation).
pub const HINT_EWMA_ALPHA: f64 = 0.3;

/// EWMA-fed adaptive lease-want sizing (ROADMAP serving follow-on (e)).
///
/// The serving loop records each completed request's measured per-phase
/// job cost ([`AdaptiveHints::observe`]); engines size each phase's
/// [`WorkerPool::with_want_cap`] lease request from the EWMA
/// ([`AdaptiveHints::want`]): the most expensive phase wants the full
/// thread budget, cheaper phases want a proportional share (floored at 2
/// so a phase never serializes itself). Until the phase's **first
/// observation** lands, `want` returns the caller's static split
/// unchanged — cold-start behavior is identical to the static hints.
/// Want sizing never changes results (the pool's bit-identity contract);
/// it only shifts which co-resident fan-out holds how many slots.
#[derive(Debug)]
pub struct AdaptiveHints {
    /// Per-phase (EWMA us-per-job, observation count).
    state: Mutex<[(f64, u64); HINT_PHASES]>,
    alpha: f64,
}

impl AdaptiveHints {
    pub fn new(alpha: f64) -> Arc<AdaptiveHints> {
        let alpha = alpha.clamp(0.0, 1.0);
        Arc::new(AdaptiveHints { state: Mutex::new([(0.0, 0); HINT_PHASES]), alpha })
    }

    /// Fold one measured per-job cost (us) into the phase's EWMA. The
    /// first observation seeds the EWMA directly; non-finite or
    /// non-positive observations are dropped (a phase that ran no jobs
    /// reports 0 and must not poison the average).
    pub fn observe(&self, phase: usize, us_per_job: f64) {
        if phase >= HINT_PHASES || !us_per_job.is_finite() || us_per_job <= 0.0 {
            return;
        }
        let mut st = self.state.lock().unwrap();
        let (ewma, n) = st[phase];
        st[phase] = if n == 0 {
            (us_per_job, 1)
        } else {
            (self.alpha * us_per_job + (1.0 - self.alpha) * ewma, n + 1)
        };
    }

    /// The current EWMA cost for a phase (0.0 before any observation).
    pub fn ewma(&self, phase: usize) -> f64 {
        if phase >= HINT_PHASES {
            return 0.0;
        }
        self.state.lock().unwrap()[phase].0
    }

    /// Lease-want for `phase` on a `threads`-wide budget: `fallback` (the
    /// static split) until the phase has an observation, then `threads`
    /// scaled by this phase's share of the most expensive observed
    /// phase's EWMA cost, clamped to `[min(2, threads), threads]`.
    pub fn want(&self, phase: usize, threads: usize, fallback: usize) -> usize {
        let threads = threads.max(1);
        if phase >= HINT_PHASES {
            return fallback;
        }
        let st = self.state.lock().unwrap();
        let (ewma, n) = st[phase];
        let max = st.iter().filter(|(_, n)| *n > 0).map(|(e, _)| *e).fold(0.0f64, f64::max);
        if n == 0 || max <= 0.0 {
            return fallback; // first-observation clamp: static split
        }
        let scaled = ((threads as f64) * ewma / max).ceil() as usize;
        scaled.clamp(2.min(threads), threads)
    }
}

/// RAII slot lease: releases on drop (also on unwind out of `map`).
struct Lease<'a> {
    budget: &'a PoolBudget,
    n: usize,
}

impl Drop for Lease<'_> {
    fn drop(&mut self) {
        self.budget.release(self.n);
    }
}

/// Validate a want-cap value: must be positive (a `map` call's lease
/// always covers the caller thread, so a cap of 0 cannot be honored).
/// The single validation point for [`WorkerPool::with_want_cap`].
pub fn validate_want_cap(cap: usize) -> Result<usize, String> {
    if cap == 0 {
        return Err("want cap 0 is invalid (a lease always needs one slot)".into());
    }
    Ok(cap)
}

/// Validate a `FASTP_THREADS` value: a positive worker count.
pub fn parse_threads(raw: &str) -> Result<usize, String> {
    raw.trim()
        .parse::<usize>()
        .ok()
        .filter(|&n| n > 0)
        .ok_or_else(|| format!("{THREADS_ENV}={raw:?} must be a positive integer"))
}

fn env_threads() -> usize {
    crate::config::env::knob(THREADS_ENV, parse_threads, || {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

/// A fixed-width pool of scoped worker threads.
///
/// The pool is a *sizing policy*, not a set of live threads: workers are
/// spawned per [`WorkerPool::map`] call with `std::thread::scope`, which
/// lets jobs borrow caller state (chunks, weights, schedules) without any
/// `'static` or `Arc` ceremony.
#[derive(Clone, Debug)]
pub struct WorkerPool {
    threads: usize,
    /// When set, every `map` call leases its workers from this budget.
    budget: Option<Arc<PoolBudget>>,
    /// Per-phase lease *hint*: caps how many slots a `map` call wants
    /// (and therefore uses). Phases with few cheap jobs (IndexGen) set a
    /// small cap so wide fan-outs of co-resident requests keep the cores;
    /// `None` keeps the uniform `min(threads, n_jobs)` want.
    want_cap: Option<usize>,
}

impl WorkerPool {
    /// Pool sized by `FASTP_THREADS`, defaulting to available parallelism.
    pub fn from_env() -> WorkerPool {
        WorkerPool { threads: env_threads(), budget: None, want_cap: None }
    }

    /// Pool with an explicit worker count (clamped to >= 1).
    pub fn with_threads(n: usize) -> WorkerPool {
        WorkerPool { threads: n.max(1), budget: None, want_cap: None }
    }

    /// Single-threaded pool (jobs run inline on the caller).
    pub fn single_threaded() -> WorkerPool {
        WorkerPool { threads: 1, budget: None, want_cap: None }
    }

    /// Pool that leases its workers from a shared [`PoolBudget`]: each
    /// `map` admits `min(threads, n_jobs)` wanted slots and runs with
    /// however many the budget grants (>= 1). Used by the serving path so
    /// co-resident engines split `FASTP_THREADS` cores instead of each
    /// spawning a full-size pool.
    pub fn shared(threads: usize, budget: Arc<PoolBudget>) -> WorkerPool {
        WorkerPool { threads: threads.max(1), budget: Some(budget), want_cap: None }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The shared budget this pool leases from, if any.
    pub fn budget(&self) -> Option<&Arc<PoolBudget>> {
        self.budget.as_ref()
    }

    /// A clone of this pool whose budget-lease requests want at most
    /// `cap` slots — the per-phase lease hint (ROADMAP serving follow-on
    /// (d)). On a budget-backed pool the smaller request leaves the
    /// remaining slots to co-resident phases; a private pool has no lease
    /// to shrink, so the cap is inert there (solo engines keep full
    /// parallelism). Never affects results (bit-identity contract).
    ///
    /// An invalid cap (0 — a lease always covers at least the caller
    /// thread) warns and falls back to 1, following the `FASTP_TILE`
    /// validate-warn-default convention (see [`validate_want_cap`]).
    pub fn with_want_cap(&self, cap: usize) -> WorkerPool {
        let cap = match validate_want_cap(cap) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("warning: ignoring want cap: {e} (using 1)");
                1
            }
        };
        WorkerPool { want_cap: Some(cap), ..self.clone() }
    }

    /// The slot want a budget lease requests for an `n_jobs` fan-out.
    fn want(&self, n_jobs: usize) -> usize {
        self.threads.min(n_jobs).min(self.want_cap.unwrap_or(usize::MAX)).max(1)
    }

    /// Run `f(0..n_jobs)` across the pool and return the results in job
    /// order. Jobs are claimed dynamically (atomic counter) so skewed job
    /// costs balance; because each job is computed independently and
    /// results are slotted by index, the output does not depend on the
    /// thread count or claim interleaving.
    pub fn map<T, F>(&self, n_jobs: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        if n_jobs == 0 {
            return Vec::new();
        }
        // Lease compute slots for the duration of this call. The caller
        // thread does the work itself (inline or blocked on the scope), so
        // the lease covers it too: `workers` threads compute in total.
        let _lease = self.budget.as_deref().map(|b| {
            let n = b.acquire(self.want(n_jobs));
            Lease { budget: b, n }
        });
        let workers = match &_lease {
            Some(l) => l.n,
            None => self.threads.min(n_jobs),
        };
        if workers <= 1 {
            return (0..n_jobs).map(f).collect();
        }
        let next = AtomicUsize::new(0);
        let parts: Vec<Vec<(usize, T)>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..workers)
                .map(|_| {
                    s.spawn(|| {
                        let mut local = Vec::new();
                        loop {
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= n_jobs {
                                break;
                            }
                            local.push((i, f(i)));
                        }
                        local
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("pool worker panicked")).collect()
        });
        let mut slots: Vec<Option<T>> = (0..n_jobs).map(|_| None).collect();
        for part in parts {
            for (i, v) in part {
                slots[i] = Some(v);
            }
        }
        slots.into_iter().map(|o| o.expect("pool job not executed")).collect()
    }

    /// Run a side-effect-free-per-index job for its effects only.
    pub fn for_each<F>(&self, n_jobs: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        let _ = self.map(n_jobs, |i| {
            f(i);
        });
    }
}

impl Default for WorkerPool {
    fn default() -> Self {
        WorkerPool::from_env()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_preserves_job_order() {
        for threads in [1, 2, 8] {
            let pool = WorkerPool::with_threads(threads);
            let out = pool.map(37, |i| i * i);
            assert_eq!(out, (0..37).map(|i| i * i).collect::<Vec<_>>(), "threads={threads}");
        }
    }

    #[test]
    fn map_handles_empty_and_single() {
        let pool = WorkerPool::with_threads(4);
        assert_eq!(pool.map(0, |i| i), Vec::<usize>::new());
        assert_eq!(pool.map(1, |i| i + 10), vec![10]);
    }

    #[test]
    fn results_identical_across_thread_counts() {
        // heavier, uneven jobs: claim order varies, results must not
        let work = |i: usize| -> u64 {
            let mut acc = 0u64;
            for k in 0..(i % 7) * 1000 + 10 {
                acc = acc.wrapping_mul(31).wrapping_add(k as u64 ^ i as u64);
            }
            acc
        };
        let seq = WorkerPool::single_threaded().map(64, work);
        for threads in [2, 3, 8] {
            assert_eq!(WorkerPool::with_threads(threads).map(64, work), seq);
        }
    }

    #[test]
    fn jobs_can_borrow_caller_state() {
        let data: Vec<f32> = (0..100).map(|i| i as f32).collect();
        let pool = WorkerPool::with_threads(4);
        let sums = pool.map(10, |i| data[i * 10..(i + 1) * 10].iter().sum::<f32>());
        assert_eq!(sums.iter().sum::<f32>(), data.iter().sum::<f32>());
    }

    #[test]
    fn with_threads_clamps_to_one() {
        assert_eq!(WorkerPool::with_threads(0).threads(), 1);
    }

    #[test]
    fn for_each_runs_all_jobs() {
        let hits = AtomicUsize::new(0);
        WorkerPool::with_threads(3).for_each(25, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 25);
    }

    #[test]
    fn budget_grants_at_most_free_slots() {
        let b = PoolBudget::new(3);
        assert_eq!(b.total(), 3);
        let g1 = b.acquire(2);
        assert_eq!(g1, 2);
        let g2 = b.acquire(5); // only 1 left
        assert_eq!(g2, 1);
        b.release(g1);
        b.release(g2);
        assert_eq!(b.available(), 3);
    }

    #[test]
    fn shared_pool_results_match_private_pool() {
        let work = |i: usize| -> u64 {
            let mut acc = 7u64;
            for k in 0..(i % 5) * 400 + 5 {
                acc = acc.wrapping_mul(33).wrapping_add(k as u64 ^ i as u64);
            }
            acc
        };
        let seq = WorkerPool::single_threaded().map(48, work);
        let budget = PoolBudget::new(4);
        let shared = WorkerPool::shared(4, Arc::clone(&budget));
        assert_eq!(shared.map(48, work), seq);
        assert_eq!(budget.available(), 4, "lease released after map");
    }

    #[test]
    fn concurrent_shared_pools_never_exceed_budget() {
        let budget = PoolBudget::new(4);
        let peak = Arc::new(AtomicUsize::new(0));
        let live = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|s| {
            for _ in 0..3 {
                let pool = WorkerPool::shared(4, Arc::clone(&budget));
                let peak = Arc::clone(&peak);
                let live = Arc::clone(&live);
                s.spawn(move || {
                    for _ in 0..8 {
                        pool.for_each(16, |_| {
                            let now = live.fetch_add(1, Ordering::SeqCst) + 1;
                            peak.fetch_max(now, Ordering::SeqCst);
                            std::thread::sleep(std::time::Duration::from_micros(50));
                            live.fetch_sub(1, Ordering::SeqCst);
                        });
                    }
                });
            }
        });
        // each map's lease covers all its computing threads, so no more
        // than `total` jobs can execute at any instant
        assert!(peak.load(Ordering::SeqCst) <= 4, "peak {}", peak.load(Ordering::SeqCst));
        assert_eq!(budget.available(), 4);
    }

    #[test]
    fn want_cap_bounds_lease_and_preserves_results() {
        let work = |i: usize| i * 3 + 1;
        let seq = WorkerPool::single_threaded().map(30, work);
        // budget-backed: a capped pool leaves slots unleased for peers
        let budget = PoolBudget::new(8);
        let capped = WorkerPool::shared(8, Arc::clone(&budget)).with_want_cap(2);
        let seen_free = Arc::new(AtomicUsize::new(usize::MAX));
        {
            let seen = Arc::clone(&seen_free);
            let b = Arc::clone(&budget);
            let out = capped.map(30, move |i| {
                seen.fetch_min(b.available(), Ordering::SeqCst);
                work(i)
            });
            assert_eq!(out, seq);
        }
        // with at most 2 slots leased, at least 6 stayed available
        assert!(seen_free.load(Ordering::SeqCst) >= 6, "{}", seen_free.load(Ordering::SeqCst));
        assert_eq!(budget.available(), 8);
        // private pool: no lease to shrink — the cap is inert, results identical
        assert_eq!(WorkerPool::with_threads(8).with_want_cap(3).map(30, work), seq);
    }

    #[test]
    fn want_cap_zero_is_rejected_then_clamped() {
        assert!(validate_want_cap(0).is_err());
        assert_eq!(validate_want_cap(1), Ok(1));
        assert_eq!(validate_want_cap(7), Ok(7));
        // the constructor path warns (stderr) and falls back to 1; the
        // pool must stay fully functional with the clamped cap
        let budget = PoolBudget::new(4);
        let pool = WorkerPool::shared(4, Arc::clone(&budget)).with_want_cap(0);
        let out = pool.map(12, |i| i * 2);
        assert_eq!(out, (0..12).map(|i| i * 2).collect::<Vec<_>>());
        assert_eq!(budget.available(), 4);
    }

    #[test]
    fn adaptive_hints_fall_back_until_first_observation() {
        let h = AdaptiveHints::new(HINT_EWMA_ALPHA);
        // cold start: every phase returns the caller's static split
        for phase in 0..HINT_PHASES {
            assert_eq!(h.want(phase, 8, 3), 3, "phase {phase}");
        }
        // one phase observed, another not: the unobserved one still
        // falls back
        h.observe(0, 100.0);
        assert_eq!(h.want(1, 8, 2), 2);
        // the observed (and only, hence most expensive) phase wants it all
        assert_eq!(h.want(0, 8, 3), 8);
    }

    #[test]
    fn adaptive_hints_scale_by_cost_share_and_clamp() {
        let h = AdaptiveHints::new(HINT_EWMA_ALPHA);
        h.observe(0, 800.0); // expensive phase
        h.observe(1, 100.0); // cheap phase: 1/8 share
        h.observe(2, 1e-9); // negligible: must clamp to the floor of 2
        assert_eq!(h.want(0, 8, 8), 8);
        assert_eq!(h.want(1, 8, 8), 2, "ceil(8/8)=1 clamps to the floor");
        assert_eq!(h.want(2, 8, 8), 2);
        // the floor respects a tiny budget
        assert_eq!(h.want(2, 1, 1), 1);
        // never exceeds the budget
        assert!(h.want(0, 4, 4) <= 4);
    }

    #[test]
    fn adaptive_hints_ewma_blends_observations() {
        let h = AdaptiveHints::new(0.5);
        h.observe(3, 100.0);
        assert!((h.ewma(3) - 100.0).abs() < 1e-9, "first observation seeds");
        h.observe(3, 200.0);
        assert!((h.ewma(3) - 150.0).abs() < 1e-9, "0.5 blend");
        // invalid observations are dropped, not folded in
        h.observe(3, f64::NAN);
        h.observe(3, -5.0);
        h.observe(3, 0.0);
        h.observe(99, 1.0);
        assert!((h.ewma(3) - 150.0).abs() < 1e-9);
        assert_eq!(h.ewma(99), 0.0);
    }

    #[test]
    fn budget_pool_empty_map_does_not_lease() {
        let budget = PoolBudget::new(1);
        let pool = WorkerPool::shared(1, Arc::clone(&budget));
        assert_eq!(pool.map(0, |i| i), Vec::<usize>::new());
        assert_eq!(budget.available(), 1);
    }
}
