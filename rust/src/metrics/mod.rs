//! Metrics & reporting: TTFT, energy efficiency, sparsity and cache
//! statistics, with paper-style table/series emitters.

use crate::util::table::{fnum, Table};
use crate::workload::prompts::Priority;

/// Per-request prefill metrics collected by the coordinator.
#[derive(Clone, Debug, Default)]
pub struct PrefillMetrics {
    pub request_id: u64,
    pub context_tokens: usize,
    /// Micro-kernel backend the engine's `KernelCtx` dispatched to
    /// (`"scalar"` / `"avx2"` / `"neon"`; see `tensor::simd`). Empty for
    /// defaulted metrics that never ran a kernel.
    pub kernel_backend: &'static str,
    /// Autotune source the engine's `KernelCtx` resolved kernel shapes
    /// from (`"off"` when untuned, else the `FASTP_AUTOTUNE` mode name or
    /// `"profile"` for an injected profile; see `tensor::tune`). Empty
    /// for defaulted metrics that never ran a kernel.
    pub tune_mode: &'static str,
    /// Shape classes carried by the active autotune profile (0 = untuned).
    pub tuned_shapes: usize,
    /// Wall-clock time-to-first-token of the functional pipeline (us).
    pub ttft_us: f64,
    /// Mean computed fraction of the causal attention matrix.
    pub density: f64,
    /// Fraction of heads that chose the query-aware pattern.
    pub query_aware_frac: f64,
    /// KV cache statistics of the SAU schedule.
    pub cache_hit_rate: f64,
    /// Modeled KV-block HBM fetch traffic across the request's SAU
    /// schedules (bytes): one kv-block fetch per cache miss along the
    /// canonical schedule walk (one on-demand gather per job on the
    /// cacheless ablation) — the same accounting the cycle simulator
    /// prices, attributed per request.
    pub hbm_read_bytes: u64,
    /// KV-block fetches the cache could not retain (bypasses) across the
    /// request's SAU schedules.
    pub cache_bypasses: u64,
    /// Modeled IndexGen K-stream HBM traffic attributed to this request
    /// (bytes): one pass per kv head over the request's blocks when solo;
    /// under cross-lane fusion, the request's share of the single fused
    /// stream (lowest-live-lane attribution along the canonical
    /// `IndexGenWalk` — the same pricing the cycle simulator charges).
    /// Kept separate from `hbm_read_bytes`, whose SAU-schedule semantics
    /// are attribution-invariant across fused and solo serving.
    pub sigu_hbm_read_bytes: u64,
    /// IndexGen K-stream bytes this request did **not** re-read because a
    /// fused group's shared stream covered them (solo-cost minus
    /// attributed share; 0 when never fused).
    pub sigu_hbm_saved_bytes: u64,
    /// IndexGen phases this request ran inside a fused (width > 1) group.
    pub sigu_fused_phases: u32,
    /// Sum of fused-group widths over those phases (mean width =
    /// `sigu_fused_width_sum / sigu_fused_phases`).
    pub sigu_fused_width_sum: u64,
    /// Total SAU jobs executed.
    pub jobs: usize,
    /// Leading token-blocks resumed from the cross-request prefix KV
    /// store (0 on a cold run or with no store attached).
    pub prefix_blocks_reused: usize,
    /// Tokens whose QKV/IndexGen/FFN work was skipped via prefix reuse
    /// (`prefix_blocks_reused * BLOCK`).
    pub prefix_tokens_skipped: u64,
    /// Time breakdown (us).
    pub t_qkv_us: f64,
    pub t_sigu_us: f64,
    pub t_sau_us: f64,
    pub t_ffn_us: f64,
    /// Measured mean per-job kernel cost of each phase across the run
    /// (us/job) — the observations the serving loop's EWMA feeds back
    /// into adaptive lease-want sizing (ROADMAP serving (e)). 0.0 when
    /// the phase ran no jobs.
    pub qkv_job_us: f64,
    pub sigu_job_us: f64,
    pub sau_job_us: f64,
    pub ffn_job_us: f64,
}

impl PrefillMetrics {
    pub fn tokens_per_s(&self) -> f64 {
        if self.ttft_us <= 0.0 {
            return 0.0;
        }
        self.context_tokens as f64 / (self.ttft_us / 1e6)
    }
}

/// One served request's latency decomposition (all in us). The serving
/// layer converts its completions into these samples.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServeSample {
    /// Micro-kernel backend that served the request (from
    /// [`PrefillMetrics::kernel_backend`]).
    pub kernel_backend: &'static str,
    /// Scheduling class the request was served under.
    pub priority: Priority,
    pub ttft_us: f64,
    pub queue_us: f64,
    /// Time parked between phases waiting for a worker (pipeline stall).
    pub pipeline_wait_us: f64,
    pub e2e_us: f64,
    /// Phase-boundary slots this request yielded to higher-ranked
    /// requests under a preemptive policy (0 elsewhere; `Batch` yields
    /// are bounded by the scheduler's aging limit).
    pub preemptions: u64,
    /// Modeled KV HBM fetch traffic attributed to this request (bytes).
    pub hbm_read_bytes: f64,
    /// KV cache hit rate over the request's SAU schedules.
    pub cache_hit_rate: f64,
    /// Tokens skipped via cross-request prefix KV reuse (0 = cold).
    pub prefix_tokens_skipped: u64,
    /// IndexGen K-stream HBM bytes attributed to this request (see
    /// [`PrefillMetrics::sigu_hbm_read_bytes`]).
    pub sigu_hbm_read_bytes: u64,
    /// IndexGen K-stream bytes saved by riding fused group streams.
    pub sigu_hbm_saved_bytes: u64,
    /// IndexGen phases served inside a fused group / their summed widths.
    pub sigu_fused_phases: u32,
    pub sigu_fused_width_sum: u64,
    /// Submission -> first token (the user-perceived TTFT once requests
    /// continue into decode: `e2e_us` then also covers generation). 0 on
    /// prefill-only samples, where it coincides with `e2e_us`.
    pub first_token_us: f64,
    /// Decode tokens generated after prefill (0 = prefill-only request).
    pub decode_tokens: u64,
    /// Mean time-per-output-token across the request's decode steps (us).
    pub tpot_us: f64,
    /// p95 inter-token latency across the request's decode steps (us).
    pub itl_p95_us: f64,
    /// Decode-side KV gather/append HBM traffic priced through the
    /// memory spine (bytes).
    pub decode_hbm_read_bytes: u64,
    pub decode_hbm_write_bytes: u64,
    /// Replica that served the request under cluster serving (0 on a
    /// bare single server; stamped from the router's placement log by
    /// [`crate::coordinator::cluster::ClusterRun::samples`]).
    pub replica: usize,
}

impl ServeSample {
    /// Submission -> first token: `first_token_us` when the serving layer
    /// recorded it, else the end-to-end latency (prefill-only samples).
    pub fn ttft_e2e_us(&self) -> f64 {
        if self.first_token_us > 0.0 { self.first_token_us } else { self.e2e_us }
    }
}

/// TTFT statistics of one priority class within a [`ServeSummary`].
///
/// Per-class TTFT is **user-perceived**: submission -> first token,
/// which for prefill-only serving is the end-to-end latency (queue wait
/// + phase waits + compute). The engine-level `ttft_us` clock only
/// starts at admission, so it cannot see the head-of-line blocking a
/// preemptive policy exists to remove.
#[derive(Clone, Copy, Debug, Default)]
pub struct ClassTtft {
    pub n: usize,
    pub ttft_mean_ms: f64,
    pub ttft_p95_ms: f64,
}

impl ClassTtft {
    fn from_samples(samples: &[ServeSample], class: Priority) -> ClassTtft {
        use crate::util::stats::{mean, percentile};
        let ttft: Vec<f64> = samples
            .iter()
            .filter(|s| s.priority == class)
            .map(|s| s.ttft_e2e_us() / 1e3)
            .collect();
        ClassTtft {
            n: ttft.len(),
            ttft_mean_ms: mean(&ttft),
            ttft_p95_ms: percentile(&ttft, 95.0),
        }
    }
}

/// Aggregate serving statistics for one scheduling mode.
#[derive(Clone, Debug, Default)]
pub struct ServeSummary {
    pub n: usize,
    /// Micro-kernel backend the trace ran on (`"mixed"` if samples
    /// disagree — they never should within one server).
    pub kernel_backend: &'static str,
    pub ttft_mean_ms: f64,
    pub ttft_p95_ms: f64,
    pub queue_mean_ms: f64,
    pub pipeline_wait_mean_ms: f64,
    pub e2e_mean_ms: f64,
    pub e2e_p95_ms: f64,
    /// Per-class TTFT breakdown (preemptive policies optimize
    /// `interactive` at `batch`'s expense; both classes are reported).
    pub interactive: ClassTtft,
    pub batch: ClassTtft,
    /// Total phase-boundary yields across the trace (0 under
    /// non-preemptive policies).
    pub preemptions: u64,
    /// Total modeled KV HBM fetch traffic across the trace (GB).
    pub hbm_read_gb: f64,
    /// Mean per-request KV cache hit rate.
    pub cache_hit_rate_mean: f64,
    /// Fraction of requests that resumed from the cross-request prefix
    /// KV store (at least one leading block reused).
    pub prefix_hit_rate: f64,
    /// Total tokens skipped via prefix reuse across the trace.
    pub prefix_tokens_skipped: u64,
    /// Reuse-attributed TTFT delta: mean user-perceived TTFT of cold
    /// requests minus that of prefix-hit requests, in ms (positive =
    /// reuse was faster; 0.0 when either group is empty).
    pub prefix_ttft_delta_ms: f64,
    /// Total IndexGen phases served inside fused (width > 1) groups.
    pub sigu_fused_phases: u64,
    /// Mean fused-group width over those phases (0.0 when never fused).
    pub sigu_fused_width_mean: f64,
    /// Total IndexGen K-stream traffic attributed across the trace (GB).
    pub sigu_hbm_read_gb: f64,
    /// Total IndexGen K-stream traffic saved by fusion (GB).
    pub sigu_hbm_saved_gb: f64,
    /// Total decode tokens generated across the trace (0 = prefill-only).
    pub decode_tokens: u64,
    /// Mean TPOT over decoding requests, weighted by their token counts
    /// (us per output token).
    pub tpot_mean_us: f64,
    /// Mean of per-request p95 inter-token latencies (us).
    pub itl_p95_us: f64,
    /// Aggregate decode throughput: decode tokens per second of summed
    /// decode time (0.0 when no request decoded).
    pub decode_tokens_per_s: f64,
    /// Total decode-side KV HBM traffic priced through the spine (GB).
    pub decode_hbm_read_gb: f64,
    pub decode_hbm_write_gb: f64,
    /// Replica count the trace was served across (1 = single server).
    pub replicas: usize,
    /// Requests placed on each replica (length = `replicas`).
    pub replica_requests: Vec<u64>,
    /// Each replica's share of the summed engine-busy time
    /// (`ttft + tpot * decode_tokens` per request; length = `replicas`,
    /// sums to 1.0 when any work ran). The router's balance metric: a
    /// placement-blind policy on a skewed trace shows up here as a
    /// lopsided share vector.
    pub replica_utilization: Vec<f64>,
}

impl ServeSummary {
    pub fn from_samples(samples: &[ServeSample]) -> ServeSummary {
        ServeSummary::from_samples_sharded(samples, 1)
    }

    /// Aggregate with per-replica counters padded to at least
    /// `n_replicas` slots (a replica that served nothing still reports
    /// zero requests and zero utilization). Samples from replica
    /// indices beyond the hint widen the vectors.
    pub fn from_samples_sharded(samples: &[ServeSample], n_replicas: usize) -> ServeSummary {
        let replicas = samples
            .iter()
            .map(|s| s.replica + 1)
            .max()
            .unwrap_or(0)
            .max(n_replicas)
            .max(1);
        let mut replica_requests = vec![0u64; replicas];
        let mut busy = vec![0.0f64; replicas];
        for s in samples {
            replica_requests[s.replica] += 1;
            busy[s.replica] += s.ttft_us + s.tpot_us * s.decode_tokens as f64;
        }
        let total_busy: f64 = busy.iter().sum();
        let replica_utilization = if total_busy > 0.0 {
            busy.iter().map(|b| b / total_busy).collect()
        } else {
            vec![0.0; replicas]
        };
        let mut summary = ServeSummary::from_samples_flat(samples);
        summary.replicas = replicas;
        summary.replica_requests = replica_requests;
        summary.replica_utilization = replica_utilization;
        summary
    }

    /// The replica-blind aggregation shared by both entry points.
    fn from_samples_flat(samples: &[ServeSample]) -> ServeSummary {
        use crate::util::stats::{mean, percentile};
        let ttft: Vec<f64> = samples.iter().map(|s| s.ttft_us / 1e3).collect();
        let queue: Vec<f64> = samples.iter().map(|s| s.queue_us / 1e3).collect();
        let wait: Vec<f64> = samples.iter().map(|s| s.pipeline_wait_us / 1e3).collect();
        let e2e: Vec<f64> = samples.iter().map(|s| s.e2e_us / 1e3).collect();
        let hits: Vec<f64> = samples.iter().map(|s| s.cache_hit_rate).collect();
        let backend = match samples.first().map(|s| s.kernel_backend) {
            None => "",
            Some(b) if samples.iter().all(|s| s.kernel_backend == b) => b,
            Some(_) => "mixed",
        };
        let warm_e2e: Vec<f64> = samples
            .iter()
            .filter(|s| s.prefix_tokens_skipped > 0)
            .map(|s| s.e2e_us / 1e3)
            .collect();
        let cold_e2e: Vec<f64> = samples
            .iter()
            .filter(|s| s.prefix_tokens_skipped == 0)
            .map(|s| s.e2e_us / 1e3)
            .collect();
        let prefix_ttft_delta_ms = if warm_e2e.is_empty() || cold_e2e.is_empty() {
            0.0
        } else {
            mean(&cold_e2e) - mean(&warm_e2e)
        };
        ServeSummary {
            n: samples.len(),
            kernel_backend: backend,
            ttft_mean_ms: mean(&ttft),
            ttft_p95_ms: percentile(&ttft, 95.0),
            queue_mean_ms: mean(&queue),
            pipeline_wait_mean_ms: mean(&wait),
            e2e_mean_ms: mean(&e2e),
            e2e_p95_ms: percentile(&e2e, 95.0),
            interactive: ClassTtft::from_samples(samples, Priority::Interactive),
            batch: ClassTtft::from_samples(samples, Priority::Batch),
            preemptions: samples.iter().map(|s| s.preemptions).sum(),
            hbm_read_gb: samples.iter().map(|s| s.hbm_read_bytes).sum::<f64>() / 1e9,
            cache_hit_rate_mean: mean(&hits),
            prefix_hit_rate: if samples.is_empty() {
                0.0
            } else {
                warm_e2e.len() as f64 / samples.len() as f64
            },
            prefix_tokens_skipped: samples.iter().map(|s| s.prefix_tokens_skipped).sum(),
            prefix_ttft_delta_ms,
            sigu_fused_phases: samples.iter().map(|s| s.sigu_fused_phases as u64).sum(),
            sigu_fused_width_mean: {
                let phases: u64 = samples.iter().map(|s| s.sigu_fused_phases as u64).sum();
                let widths: u64 = samples.iter().map(|s| s.sigu_fused_width_sum).sum();
                if phases > 0 { widths as f64 / phases as f64 } else { 0.0 }
            },
            sigu_hbm_read_gb: samples.iter().map(|s| s.sigu_hbm_read_bytes as f64).sum::<f64>()
                / 1e9,
            sigu_hbm_saved_gb: samples.iter().map(|s| s.sigu_hbm_saved_bytes as f64).sum::<f64>()
                / 1e9,
            decode_tokens: samples.iter().map(|s| s.decode_tokens).sum(),
            tpot_mean_us: {
                let toks: u64 = samples.iter().map(|s| s.decode_tokens).sum();
                let us: f64 =
                    samples.iter().map(|s| s.tpot_us * s.decode_tokens as f64).sum();
                if toks > 0 { us / toks as f64 } else { 0.0 }
            },
            itl_p95_us: {
                let itl: Vec<f64> = samples
                    .iter()
                    .filter(|s| s.decode_tokens > 0)
                    .map(|s| s.itl_p95_us)
                    .collect();
                mean(&itl)
            },
            decode_tokens_per_s: {
                let toks: u64 = samples.iter().map(|s| s.decode_tokens).sum();
                let us: f64 =
                    samples.iter().map(|s| s.tpot_us * s.decode_tokens as f64).sum();
                if us > 0.0 { toks as f64 / (us / 1e6) } else { 0.0 }
            },
            decode_hbm_read_gb: samples
                .iter()
                .map(|s| s.decode_hbm_read_bytes as f64)
                .sum::<f64>()
                / 1e9,
            decode_hbm_write_gb: samples
                .iter()
                .map(|s| s.decode_hbm_write_bytes as f64)
                .sum::<f64>()
                / 1e9,
            // overwritten by from_samples_sharded, the only caller
            replicas: 1,
            replica_requests: Vec::new(),
            replica_utilization: Vec::new(),
        }
    }

    /// One-line report for banners/examples. Per-class TTFT and yield
    /// counts are appended only when the trace actually carried both
    /// priority classes.
    pub fn render(&self, label: &str) -> String {
        let backend = if self.kernel_backend.is_empty() { "?" } else { self.kernel_backend };
        let mut line = format!(
            "{label}: {} req [{backend} kernels] | TTFT mean {:.0} ms p95 {:.0} ms | \
             queue mean {:.0} ms | \
             phase-wait mean {:.0} ms | e2e mean {:.0} ms p95 {:.0} ms | \
             KV fetch {:.3} GB | hit {:.0}%",
            self.n,
            self.ttft_mean_ms,
            self.ttft_p95_ms,
            self.queue_mean_ms,
            self.pipeline_wait_mean_ms,
            self.e2e_mean_ms,
            self.e2e_p95_ms,
            self.hbm_read_gb,
            self.cache_hit_rate_mean * 100.0
        );
        if self.batch.n > 0 && self.interactive.n > 0 {
            line.push_str(&format!(
                " | int TTFT {:.0}/{:.0} ms (n={}) | batch TTFT {:.0}/{:.0} ms (n={}) | \
                 yields {}",
                self.interactive.ttft_mean_ms,
                self.interactive.ttft_p95_ms,
                self.interactive.n,
                self.batch.ttft_mean_ms,
                self.batch.ttft_p95_ms,
                self.batch.n,
                self.preemptions
            ));
        }
        if self.prefix_tokens_skipped > 0 {
            line.push_str(&format!(
                " | prefix hit {:.0}% skip {} tok dTTFT {:.0} ms",
                self.prefix_hit_rate * 100.0,
                self.prefix_tokens_skipped,
                self.prefix_ttft_delta_ms
            ));
        }
        if self.sigu_fused_phases > 0 {
            line.push_str(&format!(
                " | idxgen fused {} phases width {:.2} saved {:.3} GB",
                self.sigu_fused_phases, self.sigu_fused_width_mean, self.sigu_hbm_saved_gb
            ));
        }
        if self.decode_tokens > 0 {
            line.push_str(&format!(
                " | decode {} tok TPOT {:.2} ms ITL p95 {:.2} ms {:.0} tok/s",
                self.decode_tokens,
                self.tpot_mean_us / 1e3,
                self.itl_p95_us / 1e3,
                self.decode_tokens_per_s
            ));
        }
        if self.replicas > 1 {
            let req: Vec<String> =
                self.replica_requests.iter().map(|r| r.to_string()).collect();
            let util: Vec<String> =
                self.replica_utilization.iter().map(|u| format!("{:.0}%", u * 100.0)).collect();
            line.push_str(&format!(
                " | {} replicas req [{}] util [{}]",
                self.replicas,
                req.join(" "),
                util.join(" ")
            ));
        }
        line
    }

    /// Machine-readable summary (hand-rolled JSON; no serde offline) —
    /// the serving smoke uploads this as a CI workflow artifact.
    pub fn to_json(&self, label: &str) -> String {
        let replica_requests: Vec<String> =
            self.replica_requests.iter().map(|r| r.to_string()).collect();
        let replica_utilization: Vec<String> =
            self.replica_utilization.iter().map(|u| format!("{u:.4}")).collect();
        format!(
            "{{\"label\": \"{}\", \"n\": {}, \"kernel_backend\": \"{}\", \
             \"ttft_mean_ms\": {:.3}, \"ttft_p95_ms\": {:.3}, \
             \"queue_mean_ms\": {:.3}, \"pipeline_wait_mean_ms\": {:.3}, \
             \"e2e_mean_ms\": {:.3}, \"e2e_p95_ms\": {:.3}, \
             \"interactive\": {{\"n\": {}, \"ttft_mean_ms\": {:.3}, \"ttft_p95_ms\": {:.3}}}, \
             \"batch\": {{\"n\": {}, \"ttft_mean_ms\": {:.3}, \"ttft_p95_ms\": {:.3}}}, \
             \"preemptions\": {}, \"hbm_read_gb\": {:.6}, \"cache_hit_rate_mean\": {:.4}, \
             \"prefix_hit_rate\": {:.4}, \"prefix_tokens_skipped\": {}, \
             \"prefix_ttft_delta_ms\": {:.3}, \
             \"sigu_fused_phases\": {}, \"sigu_fused_width_mean\": {:.3}, \
             \"sigu_hbm_read_gb\": {:.6}, \"sigu_hbm_saved_gb\": {:.6}, \
             \"decode_tokens\": {}, \"tpot_mean_us\": {:.3}, \"itl_p95_us\": {:.3}, \
             \"decode_tokens_per_s\": {:.3}, \
             \"decode_hbm_read_gb\": {:.6}, \"decode_hbm_write_gb\": {:.6}, \
             \"replicas\": {}, \"replica_requests\": [{}], \
             \"replica_utilization\": [{}]}}",
            label,
            self.n,
            self.kernel_backend,
            self.ttft_mean_ms,
            self.ttft_p95_ms,
            self.queue_mean_ms,
            self.pipeline_wait_mean_ms,
            self.e2e_mean_ms,
            self.e2e_p95_ms,
            self.interactive.n,
            self.interactive.ttft_mean_ms,
            self.interactive.ttft_p95_ms,
            self.batch.n,
            self.batch.ttft_mean_ms,
            self.batch.ttft_p95_ms,
            self.preemptions,
            self.hbm_read_gb,
            self.cache_hit_rate_mean,
            self.prefix_hit_rate,
            self.prefix_tokens_skipped,
            self.prefix_ttft_delta_ms,
            self.sigu_fused_phases,
            self.sigu_fused_width_mean,
            self.sigu_hbm_read_gb,
            self.sigu_hbm_saved_gb,
            self.decode_tokens,
            self.tpot_mean_us,
            self.itl_p95_us,
            self.decode_tokens_per_s,
            self.decode_hbm_read_gb,
            self.decode_hbm_write_gb,
            self.replicas,
            replica_requests.join(", "),
            replica_utilization.join(", ")
        )
    }

    /// Mean-TTFT saving of `self` relative to a baseline summary, in
    /// percent (positive = self is faster).
    pub fn ttft_saving_pct(&self, baseline: &ServeSummary) -> f64 {
        if baseline.ttft_mean_ms <= 0.0 {
            return 0.0;
        }
        (1.0 - self.ttft_mean_ms / baseline.ttft_mean_ms) * 100.0
    }
}

/// A simulated/estimated platform result for one (model, context) point.
#[derive(Clone, Debug)]
pub struct PlatformPoint {
    pub platform: String,
    pub model: String,
    pub context: usize,
    pub ttft_ms: f64,
    pub energy_j: f64,
}

impl PlatformPoint {
    /// Paper metric: Token/Joule with token count 1 (prefill emits 1 token).
    pub fn tokens_per_joule(&self) -> f64 {
        if self.energy_j <= 0.0 {
            return 0.0;
        }
        1.0 / self.energy_j
    }
}

/// Render a Fig.5/6-style series: rows = context lengths, cols = platforms.
pub fn render_series(
    title: &str,
    contexts: &[usize],
    platforms: &[&str],
    value: impl Fn(usize, &str) -> f64,
    unit: &str,
) -> String {
    let mut headers: Vec<String> = vec![format!("context")];
    headers.extend(platforms.iter().map(|p| format!("{p} ({unit})")));
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr_refs);
    for &ctx in contexts {
        let mut row = vec![fmt_ctx(ctx)];
        for p in platforms {
            row.push(fnum(value(ctx, p)));
        }
        t.row(&row);
    }
    format!("== {title} ==\n{}", t.render())
}

/// "4K", "128K" formatting for context lengths.
pub fn fmt_ctx(tokens: usize) -> String {
    if tokens % 1024 == 0 {
        format!("{}K", tokens / 1024)
    } else {
        format!("{tokens}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_per_joule_inverse_energy() {
        let p = PlatformPoint {
            platform: "x".into(),
            model: "m".into(),
            context: 4096,
            ttft_ms: 10.0,
            energy_j: 0.5,
        };
        assert!((p.tokens_per_joule() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn fmt_ctx_k() {
        assert_eq!(fmt_ctx(4096), "4K");
        assert_eq!(fmt_ctx(131072), "128K");
        assert_eq!(fmt_ctx(100), "100");
    }

    #[test]
    fn render_series_shape() {
        let s = render_series("t", &[4096, 8192], &["FPGA", "GPU"], |c, p| {
            (c / 1024) as f64 * if p == "GPU" { 2.0 } else { 1.0 }
        }, "ms");
        assert!(s.contains("4K"));
        assert!(s.contains("FPGA (ms)"));
        assert!(s.lines().count() == 5);
    }

    #[test]
    fn serve_summary_aggregates() {
        let samples: Vec<ServeSample> = (1..=4)
            .map(|i| ServeSample {
                kernel_backend: "avx2",
                ttft_us: i as f64 * 1000.0,
                queue_us: 500.0,
                pipeline_wait_us: 100.0,
                e2e_us: i as f64 * 1000.0 + 500.0,
                hbm_read_bytes: 2.5e8,
                cache_hit_rate: 0.5,
                ..Default::default()
            })
            .collect();
        let s = ServeSummary::from_samples(&samples);
        assert_eq!(s.n, 4);
        assert_eq!(s.kernel_backend, "avx2");
        assert!(s.render("x").contains("[avx2 kernels]"));
        assert!((s.ttft_mean_ms - 2.5).abs() < 1e-9);
        assert!((s.queue_mean_ms - 0.5).abs() < 1e-9);
        assert!((s.pipeline_wait_mean_ms - 0.1).abs() < 1e-9);
        assert!((s.hbm_read_gb - 1.0).abs() < 1e-9);
        assert!((s.cache_hit_rate_mean - 0.5).abs() < 1e-9);
        let faster = ServeSummary { ttft_mean_ms: 2.0, ..s.clone() };
        assert!((faster.ttft_saving_pct(&s) - 20.0).abs() < 1e-9);
        assert!(s.render("x").contains("4 req"));
        // all-interactive trace: no per-class tail on the banner line
        assert_eq!(s.batch.n, 0);
        assert!(!s.render("x").contains("batch TTFT"));
    }

    #[test]
    fn serve_summary_splits_priority_classes() {
        // per-class TTFT is user-perceived (submission -> first token),
        // i.e. computed from e2e, not the admission-started engine clock
        let mk = |ttft_ms: f64, priority, preemptions| ServeSample {
            priority,
            preemptions,
            e2e_us: ttft_ms * 1e3,
            ..Default::default()
        };
        let samples = vec![
            mk(10.0, Priority::Interactive, 0),
            mk(20.0, Priority::Interactive, 0),
            mk(100.0, Priority::Batch, 7),
        ];
        let s = ServeSummary::from_samples(&samples);
        assert_eq!(s.interactive.n, 2);
        assert_eq!(s.batch.n, 1);
        assert!((s.interactive.ttft_mean_ms - 15.0).abs() < 1e-9);
        assert!((s.batch.ttft_mean_ms - 100.0).abs() < 1e-9);
        assert_eq!(s.preemptions, 7);
        let line = s.render("x");
        assert!(line.contains("int TTFT"), "{line}");
        assert!(line.contains("yields 7"), "{line}");
        let json = s.to_json("pipelined");
        assert!(json.contains("\"label\": \"pipelined\""), "{json}");
        assert!(json.contains("\"preemptions\": 7"), "{json}");
        assert!(json.contains("\"interactive\": {\"n\": 2"), "{json}");
    }

    #[test]
    fn serve_summary_prefix_reuse_aggregates() {
        let mk = |e2e_ms: f64, skipped| ServeSample {
            e2e_us: e2e_ms * 1e3,
            prefix_tokens_skipped: skipped,
            ..Default::default()
        };
        // two cold requests at 40ms, two warm (prefix-hit) at 10ms
        let samples =
            vec![mk(40.0, 0), mk(40.0, 0), mk(10.0, 256), mk(10.0, 128)];
        let s = ServeSummary::from_samples(&samples);
        assert!((s.prefix_hit_rate - 0.5).abs() < 1e-9);
        assert_eq!(s.prefix_tokens_skipped, 384);
        assert!((s.prefix_ttft_delta_ms - 30.0).abs() < 1e-9);
        let line = s.render("x");
        assert!(line.contains("prefix hit 50%"), "{line}");
        assert!(line.contains("skip 384 tok"), "{line}");
        let json = s.to_json("x");
        assert!(json.contains("\"prefix_tokens_skipped\": 384"), "{json}");
        assert!(json.contains("\"prefix_hit_rate\": 0.5000"), "{json}");
        // an all-cold trace keeps the banner line unchanged
        let cold = ServeSummary::from_samples(&[mk(40.0, 0)]);
        assert!(!cold.render("x").contains("prefix hit"));
        assert!((cold.prefix_ttft_delta_ms - 0.0).abs() < 1e-12);
    }

    #[test]
    fn serve_summary_fused_indexgen_aggregates() {
        let mk = |phases, widths, read, saved| ServeSample {
            sigu_fused_phases: phases,
            sigu_fused_width_sum: widths,
            sigu_hbm_read_bytes: read,
            sigu_hbm_saved_bytes: saved,
            ..Default::default()
        };
        // two lanes fused for 2 phases each at width 2, one solo request
        let samples = vec![
            mk(2, 4, 2_000_000_000, 0),
            mk(2, 4, 0, 2_000_000_000),
            mk(0, 0, 1_000_000_000, 0),
        ];
        let s = ServeSummary::from_samples(&samples);
        assert_eq!(s.sigu_fused_phases, 4);
        assert!((s.sigu_fused_width_mean - 2.0).abs() < 1e-9);
        assert!((s.sigu_hbm_read_gb - 3.0).abs() < 1e-9);
        assert!((s.sigu_hbm_saved_gb - 2.0).abs() < 1e-9);
        let line = s.render("x");
        assert!(line.contains("idxgen fused 4 phases width 2.00"), "{line}");
        let json = s.to_json("x");
        assert!(json.contains("\"sigu_fused_phases\": 4"), "{json}");
        assert!(json.contains("\"sigu_fused_width_mean\": 2.000"), "{json}");
        assert!(json.contains("\"sigu_hbm_saved_gb\": 2.000000"), "{json}");
        // a never-fused trace keeps the banner line unchanged
        let solo = ServeSummary::from_samples(&[mk(0, 0, 5, 0)]);
        assert!(!solo.render("x").contains("idxgen fused"));
        assert_eq!(solo.sigu_fused_width_mean, 0.0);
    }

    #[test]
    fn serve_summary_decode_aggregates() {
        let mk = |tokens: u64, tpot_us: f64, itl: f64, first_ms: f64, e2e_ms: f64| ServeSample {
            decode_tokens: tokens,
            tpot_us,
            itl_p95_us: itl,
            first_token_us: first_ms * 1e3,
            e2e_us: e2e_ms * 1e3,
            decode_hbm_read_bytes: tokens * 1_000_000,
            decode_hbm_write_bytes: tokens * 1_000,
            ..Default::default()
        };
        // 8 tokens at 500us/tok and 2 tokens at 1000us/tok, plus one
        // prefill-only request that must not dilute TPOT/ITL
        let samples = vec![
            mk(8, 500.0, 700.0, 10.0, 14.0),
            mk(2, 1000.0, 1100.0, 20.0, 22.0),
            ServeSample { e2e_us: 30.0 * 1e3, ..Default::default() },
        ];
        let s = ServeSummary::from_samples(&samples);
        assert_eq!(s.decode_tokens, 10);
        // token-weighted TPOT: (8*500 + 2*1000) / 10
        assert!((s.tpot_mean_us - 600.0).abs() < 1e-9, "{}", s.tpot_mean_us);
        assert!((s.itl_p95_us - 900.0).abs() < 1e-9);
        // 10 tokens over 6000us of decode time
        assert!((s.decode_tokens_per_s - 10.0 / 6e-3).abs() < 1e-6);
        assert!((s.decode_hbm_read_gb - 0.01).abs() < 1e-12);
        let line = s.render("x");
        assert!(line.contains("decode 10 tok TPOT 0.60 ms"), "{line}");
        let json = s.to_json("x");
        assert!(json.contains("\"decode_tokens\": 10"), "{json}");
        assert!(json.contains("\"tpot_mean_us\": 600.000"), "{json}");
        // per-class TTFT is submission -> *first token*, not full e2e
        assert!((s.interactive.ttft_mean_ms - (10.0 + 20.0 + 30.0) / 3.0).abs() < 1e-9);
        // a prefill-only trace keeps the banner line unchanged
        let solo = ServeSummary::from_samples(&[ServeSample::default()]);
        assert!(!solo.render("x").contains("decode"));
        assert_eq!(solo.decode_tokens_per_s, 0.0);
    }

    #[test]
    fn serve_summary_replica_aggregates() {
        let mk = |replica: usize, ttft_ms: f64| ServeSample {
            replica,
            ttft_us: ttft_ms * 1e3,
            e2e_us: ttft_ms * 1e3,
            ..Default::default()
        };
        // replica 0 carries 3x the busy time of replica 1; replica 2
        // (from the hint) served nothing
        let samples = vec![mk(0, 10.0), mk(0, 20.0), mk(1, 10.0)];
        let s = ServeSummary::from_samples_sharded(&samples, 3);
        assert_eq!(s.replicas, 3);
        assert_eq!(s.replica_requests, vec![2, 1, 0]);
        assert!((s.replica_utilization[0] - 0.75).abs() < 1e-9);
        assert!((s.replica_utilization[1] - 0.25).abs() < 1e-9);
        assert_eq!(s.replica_utilization[2], 0.0);
        let line = s.render("x");
        assert!(line.contains("3 replicas req [2 1 0] util [75% 25% 0%]"), "{line}");
        let json = s.to_json("x");
        assert!(json.contains("\"replicas\": 3"), "{json}");
        assert!(json.contains("\"replica_requests\": [2, 1, 0]"), "{json}");
        assert!(
            json.contains("\"replica_utilization\": [0.7500, 0.2500, 0.0000]"),
            "{json}"
        );
        // a sample beyond the hint widens the vectors
        let wide = ServeSummary::from_samples_sharded(&[mk(3, 5.0)], 2);
        assert_eq!(wide.replicas, 4);
        assert_eq!(wide.replica_requests, vec![0, 0, 0, 1]);
        // single-replica serving keeps the banner line unchanged but
        // still reports the counters in JSON
        let solo = ServeSummary::from_samples(&[mk(0, 5.0)]);
        assert_eq!(solo.replicas, 1);
        assert!(!solo.render("x").contains("replicas"));
        assert!(solo.to_json("x").contains("\"replicas\": 1"));
    }

    #[test]
    fn throughput_math() {
        let m = PrefillMetrics { context_tokens: 4096, ttft_us: 1e6, ..Default::default() };
        assert!((m.tokens_per_s() - 4096.0).abs() < 1e-9);
    }
}
