//! Metrics & reporting: TTFT, energy efficiency, sparsity and cache
//! statistics, with paper-style table/series emitters.

use crate::util::table::{fnum, Table};

/// Per-request prefill metrics collected by the coordinator.
#[derive(Clone, Debug, Default)]
pub struct PrefillMetrics {
    pub request_id: u64,
    pub context_tokens: usize,
    /// Micro-kernel backend the engine's `KernelCtx` dispatched to
    /// (`"scalar"` / `"avx2"` / `"neon"`; see `tensor::simd`). Empty for
    /// defaulted metrics that never ran a kernel.
    pub kernel_backend: &'static str,
    /// Wall-clock time-to-first-token of the functional pipeline (us).
    pub ttft_us: f64,
    /// Mean computed fraction of the causal attention matrix.
    pub density: f64,
    /// Fraction of heads that chose the query-aware pattern.
    pub query_aware_frac: f64,
    /// KV cache statistics of the SAU schedule.
    pub cache_hit_rate: f64,
    /// Modeled KV-block HBM fetch traffic across the request's SAU
    /// schedules (bytes): one kv-block fetch per cache miss along the
    /// canonical schedule walk (one on-demand gather per job on the
    /// cacheless ablation) — the same accounting the cycle simulator
    /// prices, attributed per request.
    pub hbm_read_bytes: u64,
    /// KV-block fetches the cache could not retain (bypasses) across the
    /// request's SAU schedules.
    pub cache_bypasses: u64,
    /// Total SAU jobs executed.
    pub jobs: usize,
    /// Time breakdown (us).
    pub t_qkv_us: f64,
    pub t_sigu_us: f64,
    pub t_sau_us: f64,
    pub t_ffn_us: f64,
}

impl PrefillMetrics {
    pub fn tokens_per_s(&self) -> f64 {
        if self.ttft_us <= 0.0 {
            return 0.0;
        }
        self.context_tokens as f64 / (self.ttft_us / 1e6)
    }
}

/// One served request's latency decomposition (all in us). The serving
/// layer converts its completions into these samples.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServeSample {
    /// Micro-kernel backend that served the request (from
    /// [`PrefillMetrics::kernel_backend`]).
    pub kernel_backend: &'static str,
    pub ttft_us: f64,
    pub queue_us: f64,
    /// Time parked between phases waiting for a worker (pipeline stall).
    pub pipeline_wait_us: f64,
    pub e2e_us: f64,
    /// Modeled KV HBM fetch traffic attributed to this request (bytes).
    pub hbm_read_bytes: f64,
    /// KV cache hit rate over the request's SAU schedules.
    pub cache_hit_rate: f64,
}

/// Aggregate serving statistics for one scheduling mode.
#[derive(Clone, Debug, Default)]
pub struct ServeSummary {
    pub n: usize,
    /// Micro-kernel backend the trace ran on (`"mixed"` if samples
    /// disagree — they never should within one server).
    pub kernel_backend: &'static str,
    pub ttft_mean_ms: f64,
    pub ttft_p95_ms: f64,
    pub queue_mean_ms: f64,
    pub pipeline_wait_mean_ms: f64,
    pub e2e_mean_ms: f64,
    pub e2e_p95_ms: f64,
    /// Total modeled KV HBM fetch traffic across the trace (GB).
    pub hbm_read_gb: f64,
    /// Mean per-request KV cache hit rate.
    pub cache_hit_rate_mean: f64,
}

impl ServeSummary {
    pub fn from_samples(samples: &[ServeSample]) -> ServeSummary {
        use crate::util::stats::{mean, percentile};
        let ttft: Vec<f64> = samples.iter().map(|s| s.ttft_us / 1e3).collect();
        let queue: Vec<f64> = samples.iter().map(|s| s.queue_us / 1e3).collect();
        let wait: Vec<f64> = samples.iter().map(|s| s.pipeline_wait_us / 1e3).collect();
        let e2e: Vec<f64> = samples.iter().map(|s| s.e2e_us / 1e3).collect();
        let hits: Vec<f64> = samples.iter().map(|s| s.cache_hit_rate).collect();
        let backend = match samples.first().map(|s| s.kernel_backend) {
            None => "",
            Some(b) if samples.iter().all(|s| s.kernel_backend == b) => b,
            Some(_) => "mixed",
        };
        ServeSummary {
            n: samples.len(),
            kernel_backend: backend,
            ttft_mean_ms: mean(&ttft),
            ttft_p95_ms: percentile(&ttft, 95.0),
            queue_mean_ms: mean(&queue),
            pipeline_wait_mean_ms: mean(&wait),
            e2e_mean_ms: mean(&e2e),
            e2e_p95_ms: percentile(&e2e, 95.0),
            hbm_read_gb: samples.iter().map(|s| s.hbm_read_bytes).sum::<f64>() / 1e9,
            cache_hit_rate_mean: mean(&hits),
        }
    }

    /// One-line report for banners/examples.
    pub fn render(&self, label: &str) -> String {
        let backend = if self.kernel_backend.is_empty() { "?" } else { self.kernel_backend };
        format!(
            "{label}: {} req [{backend} kernels] | TTFT mean {:.0} ms p95 {:.0} ms | \
             queue mean {:.0} ms | \
             phase-wait mean {:.0} ms | e2e mean {:.0} ms p95 {:.0} ms | \
             KV fetch {:.3} GB | hit {:.0}%",
            self.n,
            self.ttft_mean_ms,
            self.ttft_p95_ms,
            self.queue_mean_ms,
            self.pipeline_wait_mean_ms,
            self.e2e_mean_ms,
            self.e2e_p95_ms,
            self.hbm_read_gb,
            self.cache_hit_rate_mean * 100.0
        )
    }

    /// Mean-TTFT saving of `self` relative to a baseline summary, in
    /// percent (positive = self is faster).
    pub fn ttft_saving_pct(&self, baseline: &ServeSummary) -> f64 {
        if baseline.ttft_mean_ms <= 0.0 {
            return 0.0;
        }
        (1.0 - self.ttft_mean_ms / baseline.ttft_mean_ms) * 100.0
    }
}

/// A simulated/estimated platform result for one (model, context) point.
#[derive(Clone, Debug)]
pub struct PlatformPoint {
    pub platform: String,
    pub model: String,
    pub context: usize,
    pub ttft_ms: f64,
    pub energy_j: f64,
}

impl PlatformPoint {
    /// Paper metric: Token/Joule with token count 1 (prefill emits 1 token).
    pub fn tokens_per_joule(&self) -> f64 {
        if self.energy_j <= 0.0 {
            return 0.0;
        }
        1.0 / self.energy_j
    }
}

/// Render a Fig.5/6-style series: rows = context lengths, cols = platforms.
pub fn render_series(
    title: &str,
    contexts: &[usize],
    platforms: &[&str],
    value: impl Fn(usize, &str) -> f64,
    unit: &str,
) -> String {
    let mut headers: Vec<String> = vec![format!("context")];
    headers.extend(platforms.iter().map(|p| format!("{p} ({unit})")));
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr_refs);
    for &ctx in contexts {
        let mut row = vec![fmt_ctx(ctx)];
        for p in platforms {
            row.push(fnum(value(ctx, p)));
        }
        t.row(&row);
    }
    format!("== {title} ==\n{}", t.render())
}

/// "4K", "128K" formatting for context lengths.
pub fn fmt_ctx(tokens: usize) -> String {
    if tokens % 1024 == 0 {
        format!("{}K", tokens / 1024)
    } else {
        format!("{tokens}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_per_joule_inverse_energy() {
        let p = PlatformPoint {
            platform: "x".into(),
            model: "m".into(),
            context: 4096,
            ttft_ms: 10.0,
            energy_j: 0.5,
        };
        assert!((p.tokens_per_joule() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn fmt_ctx_k() {
        assert_eq!(fmt_ctx(4096), "4K");
        assert_eq!(fmt_ctx(131072), "128K");
        assert_eq!(fmt_ctx(100), "100");
    }

    #[test]
    fn render_series_shape() {
        let s = render_series("t", &[4096, 8192], &["FPGA", "GPU"], |c, p| {
            (c / 1024) as f64 * if p == "GPU" { 2.0 } else { 1.0 }
        }, "ms");
        assert!(s.contains("4K"));
        assert!(s.contains("FPGA (ms)"));
        assert!(s.lines().count() == 5);
    }

    #[test]
    fn serve_summary_aggregates() {
        let samples: Vec<ServeSample> = (1..=4)
            .map(|i| ServeSample {
                kernel_backend: "avx2",
                ttft_us: i as f64 * 1000.0,
                queue_us: 500.0,
                pipeline_wait_us: 100.0,
                e2e_us: i as f64 * 1000.0 + 500.0,
                hbm_read_bytes: 2.5e8,
                cache_hit_rate: 0.5,
            })
            .collect();
        let s = ServeSummary::from_samples(&samples);
        assert_eq!(s.n, 4);
        assert_eq!(s.kernel_backend, "avx2");
        assert!(s.render("x").contains("[avx2 kernels]"));
        assert!((s.ttft_mean_ms - 2.5).abs() < 1e-9);
        assert!((s.queue_mean_ms - 0.5).abs() < 1e-9);
        assert!((s.pipeline_wait_mean_ms - 0.1).abs() < 1e-9);
        assert!((s.hbm_read_gb - 1.0).abs() < 1e-9);
        assert!((s.cache_hit_rate_mean - 0.5).abs() < 1e-9);
        let faster = ServeSummary { ttft_mean_ms: 2.0, ..s.clone() };
        assert!((faster.ttft_saving_pct(&s) - 20.0).abs() < 1e-9);
        assert!(s.render("x").contains("4 req"));
    }

    #[test]
    fn throughput_math() {
        let m = PrefillMetrics { context_tokens: 4096, ttft_us: 1e6, ..Default::default() };
        assert!((m.tokens_per_s() - 4096.0).abs() < 1e-9);
    }
}
