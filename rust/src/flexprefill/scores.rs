//! Streaming score statistics — the Rust mirror of the SIGU pipeline and of
//! the `index_phase_a` / `index_phase_b` Pallas kernels.
//!
//! Phase A streams K blocks in ascending order keeping only per-row online
//! softmax state (m, l); phase B re-streams them and emits three scalars per
//! block (vsum / slo / sup). The simulator models the single-fetch hardware
//! realization (deferred-rescale buffers); numerically the two are
//! identical — see DESIGN.md.


use crate::tensor::simd::{self, Backend};
use crate::tensor::{tile, MatF32, MatI8};
use crate::util::pool::WorkerPool;

/// Per-row online softmax state for the last query block.
#[derive(Clone, Debug)]
pub struct StreamState {
    pub m: Vec<f32>,
    pub l: Vec<f32>,
}

impl StreamState {
    pub fn new(rows: usize) -> Self {
        StreamState { m: vec![-1e30; rows], l: vec![0.0; rows] }
    }
}

/// Compute the dequantized score tile s = (Qhat @ Kblk^T) * qs * ks / sqrt(d).
/// Qhat: [B, d] i8; kblk: [B, d] i8 (rows are key tokens). The exact W8A8
/// product runs through the tiled kernel layer (identical integers to the
/// scalar `quant::int8_matmul_bt` oracle) on the active SIMD backend.
fn score_tile(qhat: &MatI8, qs: f32, kblk: &MatI8, ks: f32) -> MatF32 {
    score_tile_bk(qhat, qs, kblk, ks, simd::active())
}

/// [`score_tile`] on an explicit backend (the engine threads its
/// `KernelCtx` backend through [`HeadJob::stream_with`]); exact
/// integers, so every backend produces the same tile.
fn score_tile_bk(qhat: &MatI8, qs: f32, kblk: &MatI8, ks: f32, bk: Backend) -> MatF32 {
    let acc = tile::int8_matmul_bt_with_bk(qhat, kblk, tile::env_tile(), bk);
    let scale = qs * ks / (qhat.cols as f32).sqrt();
    MatF32 {
        rows: qhat.rows,
        cols: kblk.rows,
        data: acc.iter().map(|&v| v as f32 * scale).collect(),
    }
}

/// Phase A: fold one K block into the online (m, l) state.
/// Matches `ref.index_phase_a_ref` / the `index_phase_a` artifact.
pub fn phase_a(qhat: &MatI8, qs: f32, kblk: &MatI8, ks: f32, st: &mut StreamState) {
    let s = score_tile(qhat, qs, kblk, ks);
    for r in 0..s.rows {
        let row = s.row(r);
        let rmax = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let m_new = st.m[r].max(rmax);
        let mut sum = 0.0f32;
        for &v in row {
            sum += (v - m_new).exp();
        }
        st.l[r] = st.l[r] * (st.m[r] - m_new).exp() + sum;
        st.m[r] = m_new;
    }
}

/// Phase B output for one block.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BlockStats {
    /// Total probability mass in this key block (vertical contribution).
    pub vsum: f32,
    /// Mass with intra-tile offset i-j >= 0 (slash group N-1-b).
    pub slo: f32,
    /// Mass with intra-tile offset i-j < 0 (slash group N-2-b).
    pub sup: f32,
}

/// Phase B: normalized per-block statistics given the final (M, L).
/// Matches `ref.index_phase_b_ref` / the `index_phase_b` artifact.
pub fn phase_b(qhat: &MatI8, qs: f32, kblk: &MatI8, ks: f32, st: &StreamState) -> BlockStats {
    let s = score_tile(qhat, qs, kblk, ks);
    let mut vsum = 0.0f32;
    let mut slo = 0.0f32;
    for r in 0..s.rows {
        let inv_l = 1.0 / st.l[r].max(1e-8);
        let m = st.m[r];
        for (c, &v) in s.row(r).iter().enumerate() {
            let p = (v - m).exp() * inv_l;
            vsum += p;
            if r >= c {
                slo += p;
            }
        }
    }
    BlockStats { vsum, slo, sup: vsum - slo }
}

/// Generic streaming statistics over any score-tile provider: two passes,
/// identical math to phase A + phase B. `tile(b)` must return the
/// dequantized score tile for key block b ([rows, BLOCK] f32).
///
/// Slash mapping (see flex_index.py): block b's lower-triangle mass lands
/// in diagonal group N-1-b and its upper-triangle mass in group N-2-b
/// (dropped for b = N-1, where those offsets are acausal).
pub fn stream_scores_generic(
    n: usize,
    rows: usize,
    mut tile: impl FnMut(usize) -> MatF32,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut st = StreamState::new(rows);
    for b in 0..n {
        let s = tile(b);
        fold_tile(&mut st, &s);
    }
    let mut vertical = vec![0.0f32; n];
    let mut slash = vec![0.0f32; n];
    for b in 0..n {
        let s = tile(b);
        let (vsum, slo) = block_mass(&st, &s);
        vertical[b] = vsum;
        slash[n - 1 - b] += slo;
        if b + 2 <= n {
            slash[n - 2 - b] += vsum - slo;
        }
    }
    let a_hat: Vec<f32> = vertical.iter().map(|v| v / rows as f32).collect();
    (vertical, slash, a_hat)
}

/// Pass-A step: fold one score tile into the online (m, l) state. Shared
/// by the solo and fused streams so both run the very same float ops.
fn fold_tile(st: &mut StreamState, s: &MatF32) {
    for r in 0..s.rows {
        let row = s.row(r);
        let rmax = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let m_new = st.m[r].max(rmax);
        let mut sum = 0.0f32;
        for &v in row {
            sum += (v - m_new).exp();
        }
        st.l[r] = st.l[r] * (st.m[r] - m_new).exp() + sum;
        st.m[r] = m_new;
    }
}

/// Pass-B step: (vsum, slo) block mass of one score tile under the final
/// (m, l) state. Shared by the solo and fused streams.
fn block_mass(st: &StreamState, s: &MatF32) -> (f32, f32) {
    let mut vsum = 0.0f32;
    let mut slo = 0.0f32;
    for r in 0..s.rows {
        let inv_l = 1.0 / st.l[r].max(1e-8);
        let m = st.m[r];
        for (c, &v) in s.row(r).iter().enumerate() {
            let p = (v - m).exp() * inv_l;
            vsum += p;
            if r >= c {
                slo += p;
            }
        }
    }
    (vsum, slo)
}

/// One head's SIGU scoring job for the parallel path: everything borrowed
/// from the caller's chunk state (no K-block copies).
pub struct HeadJob<'a> {
    /// Last query block, quantized [B, d].
    pub qhat: &'a MatI8,
    pub qs: f32,
    /// (K block, scale) in ascending block order.
    pub kblocks: Vec<(&'a MatI8, f32)>,
}

impl HeadJob<'_> {
    /// Run the sequential two-pass streaming math for this head
    /// ([`stream_scores_generic`] over the borrowed K blocks) on the
    /// active SIMD backend.
    pub fn stream(&self) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        self.stream_with(simd::active())
    }

    /// [`HeadJob::stream`] on an explicit backend — how the engine's
    /// SIGU phase threads its `KernelCtx` backend down to the score
    /// tiles (bit-identical for every backend; the tiles are exact).
    pub fn stream_with(&self, bk: Backend) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        stream_scores_generic(self.kblocks.len(), self.qhat.rows, |b| {
            let (kb, ks) = self.kblocks[b];
            score_tile_bk(self.qhat, self.qs, kb, ks, bk)
        })
    }
}

/// Stream every head's statistics across the worker pool — the SIGU's
/// per-head lanes as independent jobs. Each job runs the sequential
/// two-pass math of [`HeadJob::stream`], so the results are bit-identical
/// for every thread count (property-tested).
pub fn stream_heads_parallel(
    pool: &WorkerPool,
    jobs: &[HeadJob<'_>],
) -> Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> {
    pool.map(jobs.len(), |h| jobs[h].stream())
}

/// One query head's SIGU scoring job **fused across co-resident lanes**:
/// the kv-head's K block sequence is streamed once, in ascending block
/// index over the merged (longest-lane) extent, and every lane's Q-hat is
/// scored against its own K data at the shared stream position. Lanes may
/// have different block counts — a lane simply stops riding the stream
/// past its last block.
///
/// Bit-identity: per-lane online state and outputs are fully independent,
/// and each lane's tiles fold in the lane's own ascending block order
/// through the exact pass-A/pass-B steps of [`stream_scores_generic`]
/// (shared helpers), so every lane's result is bit-identical to its solo
/// [`HeadJob::stream_with`] for any fusion width (property-tested).
pub struct FusedHeadJob<'a> {
    /// Per-lane queries riding the shared K stream, in lane order.
    pub lanes: Vec<HeadJob<'a>>,
}

impl FusedHeadJob<'_> {
    /// Per-lane (vertical, slash, a_hat), on the active SIMD backend.
    pub fn stream(&self) -> Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        self.stream_with(simd::active())
    }

    /// [`FusedHeadJob::stream`] on an explicit backend.
    pub fn stream_with(&self, bk: Backend) -> Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let max_n = self.lanes.iter().map(|l| l.kblocks.len()).max().unwrap_or(0);
        let mut states: Vec<StreamState> =
            self.lanes.iter().map(|l| StreamState::new(l.qhat.rows)).collect();
        // pass A: one merged ascending sweep over the shared stream
        for b in 0..max_n {
            for (lane, st) in self.lanes.iter().zip(states.iter_mut()) {
                if b < lane.kblocks.len() {
                    let (kb, ks) = lane.kblocks[b];
                    let s = score_tile_bk(lane.qhat, lane.qs, kb, ks, bk);
                    fold_tile(st, &s);
                }
            }
        }
        // pass B: re-stream, emitting per-lane block stats independently
        let mut out: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> = self
            .lanes
            .iter()
            .map(|l| {
                let n = l.kblocks.len();
                (vec![0.0f32; n], vec![0.0f32; n], vec![0.0f32; n])
            })
            .collect();
        for b in 0..max_n {
            for (li, lane) in self.lanes.iter().enumerate() {
                let n = lane.kblocks.len();
                if b >= n {
                    continue;
                }
                let (kb, ks) = lane.kblocks[b];
                let s = score_tile_bk(lane.qhat, lane.qs, kb, ks, bk);
                let (vsum, slo) = block_mass(&states[li], &s);
                out[li].0[b] = vsum;
                out[li].1[n - 1 - b] += slo;
                if b + 2 <= n {
                    out[li].1[n - 2 - b] += vsum - slo;
                }
            }
        }
        for (o, lane) in out.iter_mut().zip(&self.lanes) {
            let rows = lane.qhat.rows as f32;
            o.2 = o.0.iter().map(|v| v / rows).collect();
        }
        out
    }
}

/// Full streaming statistics for one head (W8A8 tiles): vertical[N],
/// slash[N], a_hat[N]. `kblocks` are (quantized K block, scale) in
/// ascending block order — exactly the stream the paper's Key Block Fetch
/// Unit produces. Matches phase_a + phase_b composition (unit-tested).
pub fn stream_head_scores(
    qhat: &MatI8,
    qs: f32,
    kblocks: &[(MatI8, f32)],
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    stream_scores_generic(kblocks.len(), qhat.rows, |b| {
        score_tile(qhat, qs, &kblocks[b].0, kblocks[b].1)
    })
}

/// f32 (BF16-like) variant for the accuracy harness: tiles computed in
/// full precision from unquantized Q-hat and K blocks.
pub fn stream_head_scores_f32(qhat: &MatF32, kblocks: &[MatF32]) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let inv_sqrt_d = 1.0 / (qhat.cols as f32).sqrt();
    stream_scores_generic(kblocks.len(), qhat.rows, |b| {
        let kb = &kblocks[b];
        let mut t = tile::matmul_bt(qhat, kb);
        for v in t.data.iter_mut() {
            *v *= inv_sqrt_d;
        }
        t
    })
}

/// Estimated block-pooled attention a_bar = softmax(pool(Qhat).pool(K)^T/sqrt d)
/// (Algorithm 1 line 2). `qpool_hat` is the pooled last query block [d];
/// `kpool` is [N, d].
pub fn pooled_estimate(qpool_hat: &[f32], kpool: &MatF32) -> Vec<f32> {
    let d = qpool_hat.len();
    assert_eq!(kpool.cols, d);
    let inv_sqrt_d = 1.0 / (d as f32).sqrt();
    let scores: Vec<f32> = (0..kpool.rows)
        .map(|b| {
            let row = kpool.row(b);
            let mut s = 0.0f32;
            for (x, y) in qpool_hat.iter().zip(row) {
                s += x * y;
            }
            s * inv_sqrt_d
        })
        .collect();
    crate::tensor::ops::softmax(&scores)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::BLOCK;
    use crate::util::prng::Prng;

    fn rand_blk(rng: &mut Prng, rows: usize, d: usize) -> MatI8 {
        MatI8 { rows, cols: d, data: (0..rows * d).map(|_| rng.i8_sym()).collect() }
    }

    fn setup(n: usize, seed: u64) -> (MatI8, f32, Vec<(MatI8, f32)>) {
        let mut rng = Prng::new(seed);
        let qhat = rand_blk(&mut rng, BLOCK, 64);
        let kblocks: Vec<(MatI8, f32)> =
            (0..n).map(|_| (rand_blk(&mut rng, BLOCK, 64), 0.02)).collect();
        (qhat, 0.02, kblocks)
    }

    #[test]
    fn vertical_mass_sums_to_rows() {
        let (qhat, qs, kblocks) = setup(4, 1);
        let (vertical, _, a_hat) = stream_head_scores(&qhat, qs, &kblocks);
        let total: f32 = vertical.iter().sum();
        assert!((total - BLOCK as f32).abs() < 1e-2, "total {total}");
        let ah: f32 = a_hat.iter().sum();
        assert!((ah - 1.0).abs() < 1e-4);
    }

    #[test]
    fn slash_mass_conserved_minus_dropped_group() {
        let (qhat, qs, kblocks) = setup(3, 2);
        let n = kblocks.len();
        let (_, slash, _) = stream_head_scores(&qhat, qs, &kblocks);
        // all mass except the acausal sup of block N-1 is distributed
        let mut st = StreamState::new(BLOCK);
        for (kb, ks) in &kblocks {
            phase_a(&qhat, qs, kb, *ks, &mut st);
        }
        let dropped = phase_b(&qhat, qs, &kblocks[n - 1].0, kblocks[n - 1].1, &st).sup;
        let slash_total: f32 = slash.iter().sum();
        assert!(((slash_total + dropped) - BLOCK as f32).abs() < 1e-2);
    }

    #[test]
    fn phase_b_consistency_vsum_decomposes() {
        let (qhat, qs, kblocks) = setup(2, 3);
        let mut st = StreamState::new(BLOCK);
        for (kb, ks) in &kblocks {
            phase_a(&qhat, qs, kb, *ks, &mut st);
        }
        for (kb, ks) in &kblocks {
            let s = phase_b(&qhat, qs, kb, *ks, &st);
            assert!((s.vsum - (s.slo + s.sup)).abs() < 1e-4);
            assert!(s.vsum >= 0.0 && s.slo >= 0.0);
        }
    }

    #[test]
    fn online_state_matches_two_block_direct() {
        // direct softmax over concatenated blocks == streamed (m, l)
        let (qhat, qs, kblocks) = setup(2, 4);
        let mut st = StreamState::new(BLOCK);
        for (kb, ks) in &kblocks {
            phase_a(&qhat, qs, kb, *ks, &mut st);
        }
        // direct: row 0 denominator
        let t0 = score_tile(&qhat, qs, &kblocks[0].0, kblocks[0].1);
        let t1 = score_tile(&qhat, qs, &kblocks[1].0, kblocks[1].1);
        let row: Vec<f32> = t0.row(0).iter().chain(t1.row(0)).cloned().collect();
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let l: f32 = row.iter().map(|v| (v - mx).exp()).sum();
        assert!((st.m[0] - mx).abs() < 1e-6);
        assert!((st.l[0] - l).abs() / l < 1e-5);
    }

    #[test]
    fn parallel_heads_match_sequential_bitwise() {
        let n = 5;
        let heads: Vec<(MatI8, f32, Vec<(MatI8, f32)>)> = (0..6)
            .map(|h| {
                let (qhat, qs, kblocks) = setup(n, 100 + h);
                (qhat, qs, kblocks)
            })
            .collect();
        let jobs: Vec<HeadJob<'_>> = heads
            .iter()
            .map(|(qhat, qs, kblocks)| HeadJob {
                qhat,
                qs: *qs,
                kblocks: kblocks.iter().map(|(kb, ks)| (kb, *ks)).collect(),
            })
            .collect();
        let seq: Vec<_> = heads
            .iter()
            .map(|(qhat, qs, kblocks)| stream_head_scores(qhat, *qs, kblocks))
            .collect();
        for threads in [1usize, 2, 8] {
            let par = stream_heads_parallel(&WorkerPool::with_threads(threads), &jobs);
            assert_eq!(par, seq, "threads={threads}");
        }
    }

    #[test]
    fn fused_stream_matches_solo_per_lane_bitwise() {
        // the cross-lane fusion contract: for any fusion width, block
        // counts and backend, each lane of a FusedHeadJob is bit-identical
        // to its solo stream
        use crate::tensor::simd;
        use crate::util::prop::forall_ck;
        let backends = [simd::Backend::Scalar, simd::detect()];
        forall_ck(
            0x5EED_F05E,
            24,
            |rng, size| {
                let lanes = 1 + rng.below(4);
                let blocks: Vec<usize> =
                    (0..lanes).map(|_| 1 + rng.below(2 + size / 20)).collect();
                let seed = rng.next_u64();
                (blocks, seed)
            },
            |(blocks, seed)| {
                let lanes: Vec<(MatI8, f32, Vec<(MatI8, f32)>)> = blocks
                    .iter()
                    .enumerate()
                    .map(|(li, &n)| setup(n, seed ^ (li as u64) << 17))
                    .collect();
                for bk in backends {
                    let solo: Vec<_> = lanes
                        .iter()
                        .map(|(qhat, qs, kblocks)| {
                            HeadJob {
                                qhat,
                                qs: *qs,
                                kblocks: kblocks.iter().map(|(kb, ks)| (kb, *ks)).collect(),
                            }
                            .stream_with(bk)
                        })
                        .collect();
                    let fused = FusedHeadJob {
                        lanes: lanes
                            .iter()
                            .map(|(qhat, qs, kblocks)| HeadJob {
                                qhat,
                                qs: *qs,
                                kblocks: kblocks.iter().map(|(kb, ks)| (kb, *ks)).collect(),
                            })
                            .collect(),
                    }
                    .stream_with(bk);
                    if fused != solo {
                        return Err(format!("fused != solo on {}", bk.name()));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn pooled_estimate_is_distribution() {
        let mut rng = Prng::new(5);
        let qp: Vec<f32> = (0..64).map(|_| rng.normal()).collect();
        let kp = MatF32::from_fn(6, 64, |_, _| rng.normal());
        let a = pooled_estimate(&qp, &kp);
        let s: f32 = a.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
    }
}
