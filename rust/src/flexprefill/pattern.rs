//! Pattern decision (Algorithm 1 lines 2-9): JSD between the estimated and
//! true block-pooled attention distributions, thresholded at tau.
//!
//! In hardware this is the SIGU's Divergence Evaluation module (LUT
//! arithmetic + comparators); here it is exact f32 math matching
//! `ref.jsd_ref`.

use super::HeadPattern;
use crate::tensor::ops::jsd;

/// d_JS = sqrt(JSD(a_bar || a_hat)) (Algorithm 1 line 4).
pub fn divergence(a_bar: &[f32], a_hat: &[f32]) -> f32 {
    jsd(a_bar, a_hat).max(0.0).sqrt()
}

/// Line 5-9: low divergence => the cheap pooled estimate is faithful =>
/// query-aware pattern; high divergence => conservative vertical-slash.
pub fn decide(d_js: f32, tau: f32) -> HeadPattern {
    if d_js < tau {
        HeadPattern::QueryAware
    } else {
        HeadPattern::VerticalSlash
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_distributions_are_query_aware() {
        let p = vec![0.25f32; 4];
        let d = divergence(&p, &p);
        assert!(d < 1e-3);
        assert_eq!(decide(d, 0.1), HeadPattern::QueryAware);
    }

    #[test]
    fn disjoint_distributions_are_vertical_slash() {
        let p = [1.0, 0.0, 0.0, 0.0];
        let q = [0.0, 0.0, 0.0, 1.0];
        let d = divergence(&p, &q);
        assert!(d > 0.5);
        assert_eq!(decide(d, 0.1), HeadPattern::VerticalSlash);
    }

    #[test]
    fn divergence_bounded_by_sqrt_ln2() {
        let p = [1.0, 0.0];
        let q = [0.0, 1.0];
        let d = divergence(&p, &q);
        assert!(d <= (std::f32::consts::LN_2).sqrt() + 1e-5);
    }

    #[test]
    fn threshold_boundary() {
        assert_eq!(decide(0.0999, 0.1), HeadPattern::QueryAware);
        assert_eq!(decide(0.1, 0.1), HeadPattern::VerticalSlash);
    }
}
