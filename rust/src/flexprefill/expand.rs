//! Expansion of selected score indices into per-query-block KV block lists.
//!
//! Vertical-slash (Algorithm 1 lines 10-20): a selected *vertical* block v
//! is attended by every query block q >= v; a selected *slash* group g
//! (block-diagonal distance g from the diagonal) maps query block q to KV
//! block q - g. Query-aware (lines 21-27): coverage selection over the
//! flattened causal pooled attention map picks (q, k) pairs directly.

use crate::config::FlexParams;
use crate::tensor::ops::softmax;
use crate::tensor::MatF32;

/// Expand vertical block ids + slash group ids into per-query-block lists.
/// `nq` = number of query blocks, `n` = number of KV blocks (nq == n for
/// full prefill; nq < n never occurs here but is kept general).
pub fn vertical_slash(vertical: &[u32], slash: &[u32], nq: usize, n: usize) -> Vec<Vec<u32>> {
    let mut out: Vec<Vec<u32>> = vec![Vec::new(); nq];
    let q_off = n - nq; // global block index of query block 0
    for (qi, row) in out.iter_mut().enumerate() {
        let q_abs = qi + q_off;
        for &v in vertical {
            if (v as usize) <= q_abs {
                row.push(v);
            }
        }
        for &g in slash {
            let k = q_abs as i64 - g as i64;
            if k >= 0 {
                row.push(k as u32);
            }
        }
        row.sort_unstable();
        row.dedup();
    }
    out
}

/// Causal block-pooled attention map (Algorithm 1 line 22, with the causal
/// mask the full map requires): softmax(pool(Q) pool(K)^T / sqrt d).
pub fn pooled_attention_causal(qpool: &MatF32, kpool: &MatF32) -> MatF32 {
    let d = qpool.cols;
    assert_eq!(kpool.cols, d);
    let (nq, n) = (qpool.rows, kpool.rows);
    let q_off = n - nq;
    let inv_sqrt_d = 1.0 / (d as f32).sqrt();
    let mut out = MatF32::zeros(nq, n);
    for qi in 0..nq {
        let qrow = qpool.row(qi);
        let limit = qi + q_off; // causal: key block <= query block
        let mut scores = vec![f32::NEG_INFINITY; n];
        for (b, s) in scores.iter_mut().enumerate().take(limit + 1) {
            let krow = kpool.row(b);
            let mut acc = 0.0f32;
            for (x, y) in qrow.iter().zip(krow) {
                acc += x * y;
            }
            *s = acc * inv_sqrt_d;
        }
        let sm = softmax(&scores[..limit + 1]);
        out.row_mut(qi)[..limit + 1].copy_from_slice(&sm);
    }
    out
}

/// Query-aware selection (Algorithm 1 lines 23-26): flatten the causal map,
/// normalize, coverage-select (q, k) pairs.
pub fn query_aware(a: &MatF32, gamma: f32) -> Vec<Vec<u32>> {
    let (nq, n) = (a.rows, a.cols);
    let sel = super::coverage::coverage_select(&a.data, gamma);
    let mut out: Vec<Vec<u32>> = vec![Vec::new(); nq];
    for &flat in &sel {
        let q = flat as usize / n;
        let k = (flat as usize % n) as u32;
        out[q].push(k);
    }
    for row in out.iter_mut() {
        row.sort_unstable();
        row.dedup();
    }
    out
}

/// Force-include the diagonal (self) block and block 0 (attention sink),
/// per `FlexParams` — guarantees a non-empty softmax for every query block.
pub fn apply_forced_blocks(blocks: &mut [Vec<u32>], params: &FlexParams) {
    let n = blocks.len();
    for (qi, row) in blocks.iter_mut().enumerate() {
        let q_abs = qi; // nq == n in prefill
        if params.force_diagonal && !row.contains(&(q_abs as u32)) {
            row.push(q_abs as u32);
        }
        if params.force_sink && n > 0 && !row.contains(&0) {
            row.push(0);
        }
        row.sort_unstable();
        row.dedup();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    #[test]
    fn vertical_applies_to_all_later_queries() {
        let out = vertical_slash(&[1], &[], 4, 4);
        assert!(out[0].is_empty());
        assert_eq!(out[1], vec![1]);
        assert_eq!(out[2], vec![1]);
        assert_eq!(out[3], vec![1]);
    }

    #[test]
    fn slash_follows_diagonal() {
        let out = vertical_slash(&[], &[0, 2], 4, 4);
        assert_eq!(out[0], vec![0]); // g=0 -> self; g=2 acausal for q=0,1
        assert_eq!(out[1], vec![1]);
        assert_eq!(out[2], vec![0, 2]);
        assert_eq!(out[3], vec![1, 3]);
    }

    #[test]
    fn union_dedups() {
        let out = vertical_slash(&[2], &[0], 4, 4);
        assert_eq!(out[2], vec![2]); // vertical 2 == slash g=0 at q=2
    }

    #[test]
    fn pooled_attention_rows_are_distributions() {
        let mut rng = Prng::new(1);
        let qp = MatF32::from_fn(4, 16, |_, _| rng.normal());
        let kp = MatF32::from_fn(4, 16, |_, _| rng.normal());
        let a = pooled_attention_causal(&qp, &kp);
        for q in 0..4 {
            let s: f32 = a.row(q).iter().sum();
            assert!((s - 1.0).abs() < 1e-5, "row {q} sums {s}");
            for k in q + 1..4 {
                assert_eq!(a.at(q, k), 0.0, "acausal mass at ({q},{k})");
            }
        }
    }

    #[test]
    fn query_aware_respects_causality() {
        let mut rng = Prng::new(2);
        let qp = MatF32::from_fn(6, 8, |_, _| rng.normal());
        let kp = MatF32::from_fn(6, 8, |_, _| rng.normal());
        let a = pooled_attention_causal(&qp, &kp);
        let sel = query_aware(&a, 0.9);
        assert_eq!(sel.len(), 6);
        for (q, row) in sel.iter().enumerate() {
            for &k in row {
                assert!(k as usize <= q, "future block selected");
            }
        }
        // gamma=0.9 must select a nonempty set overall
        assert!(sel.iter().any(|r| !r.is_empty()));
    }

    #[test]
    fn forced_blocks_added() {
        let mut blocks = vec![vec![], vec![], vec![1u32]];
        apply_forced_blocks(&mut blocks, &FlexParams::default());
        assert_eq!(blocks[0], vec![0]);
        assert_eq!(blocks[1], vec![0, 1]);
        assert_eq!(blocks[2], vec![0, 1, 2]);
    }

    #[test]
    fn forced_blocks_respect_flags() {
        let mut blocks = vec![vec![], vec![]];
        let p = FlexParams { force_diagonal: false, force_sink: false, ..Default::default() };
        apply_forced_blocks(&mut blocks, &p);
        assert!(blocks[0].is_empty() && blocks[1].is_empty());
    }
}
