//! FlexPrefill (Algorithm 1) — dynamic sparse-attention index generation.
//!
//! This is the pure-Rust reference implementation of the algorithm the
//! paper's SIGU executes in hardware. It is used three ways:
//!
//!  1. as the *functional oracle* for the PJRT-backed pipeline (the
//!     coordinator can compute head statistics either through the AOT
//!     `index_phase_a/b` artifacts or through [`scores`] — they agree to
//!     f32 tolerance, asserted in integration tests);
//!  2. as the *input generator* for the FPGA simulator and GPU cost model —
//!     both consume the real [`SparseIndexSet`] produced here, so the
//!     performance numbers reflect genuine dynamic sparsity;
//!  3. as the algorithm under test for the accuracy proxy (Table III).
//!
//! Decomposition mirrors the SIGU datapath (paper §IV-B):
//!   [`scores`]    — streaming score statistics (vertical / slash / pooled)
//!   [`pattern`]   — JSD divergence evaluation + pattern decision
//!   [`coverage`]  — streaming coverage-constrained top-k selection
//!   [`expand`]    — block-set expansion into per-query-block index lists

pub mod coverage;
pub mod expand;
pub mod pattern;
pub mod scores;

use crate::config::FlexParams;
use crate::tensor::MatF32;

/// Which sparsity pattern a head follows (Algorithm 1 lines 5-9).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HeadPattern {
    QueryAware,
    VerticalSlash,
}

/// Sparse index set for one attention head: for each query block, the
/// ascending list of KV block indices that participate in attention.
#[derive(Clone, Debug)]
pub struct HeadIndex {
    pub pattern: HeadPattern,
    /// sqrt(JSD) divergence that drove the decision.
    pub d_js: f32,
    /// `blocks[q]` = sorted, deduplicated KV block ids for query block q.
    pub blocks: Vec<Vec<u32>>,
}

impl HeadIndex {
    /// Total number of (query-block, kv-block) jobs.
    pub fn job_count(&self) -> usize {
        self.blocks.iter().map(|b| b.len()).sum()
    }

    /// Fraction of the causal attention matrix that is computed.
    pub fn density(&self) -> f64 {
        let n = self.blocks.len();
        let causal_total: usize = n * (n + 1) / 2;
        if causal_total == 0 {
            return 0.0;
        }
        self.job_count() as f64 / causal_total as f64
    }

    /// Invariant check: every selected block is causal-legal and sorted.
    pub fn validate(&self) -> Result<(), String> {
        for (q, blocks) in self.blocks.iter().enumerate() {
            let mut prev: Option<u32> = None;
            for &b in blocks {
                if b as usize > q {
                    return Err(format!("q-block {q} selects future kv-block {b}"));
                }
                if let Some(p) = prev {
                    if b <= p {
                        return Err(format!("q-block {q} unsorted/dup at {b}"));
                    }
                }
                prev = Some(b);
            }
        }
        Ok(())
    }
}

/// Per-head statistics produced by the streaming SIGU score pipeline —
/// everything Algorithm 1 needs after the Key stream has been consumed.
#[derive(Clone, Debug)]
pub struct HeadStats {
    /// vertical[b]: probability mass of key block b under the last query
    /// block (length N).
    pub vertical: Vec<f32>,
    /// slash[g]: probability mass of block-diagonal group g (g = 0 is the
    /// diagonal; length N).
    pub slash: Vec<f32>,
    /// Block-pooled *estimated* attention: softmax(pool(Qhat) pool(K)^T/sqrt d)
    pub a_bar: Vec<f32>,
    /// Block-pooled *true* attention: vertical / BLOCK_ROWS.
    pub a_hat: Vec<f32>,
    /// Pooled query vectors for ALL query blocks [Nq, d] (query-aware path).
    pub qpool_all: MatF32,
    /// Pooled key vectors [N, d].
    pub kpool: MatF32,
}

/// Run Algorithm 1 for one head given its streaming statistics.
pub fn generate_head_index(stats: &HeadStats, params: &FlexParams) -> HeadIndex {
    let n = stats.vertical.len();
    let nq = stats.qpool_all.rows;
    let d_js = pattern::divergence(&stats.a_bar, &stats.a_hat);
    let pattern = pattern::decide(d_js, params.tau);
    let mut blocks = match pattern {
        HeadPattern::VerticalSlash => {
            let sv = coverage::coverage_select(&stats.vertical, params.gamma);
            let ss = coverage::coverage_select(&stats.slash, params.gamma);
            expand::vertical_slash(&sv, &ss, nq, n)
        }
        HeadPattern::QueryAware => {
            let a = expand::pooled_attention_causal(&stats.qpool_all, &stats.kpool);
            expand::query_aware(&a, params.gamma)
        }
    };
    expand::apply_forced_blocks(&mut blocks, params);
    HeadIndex { pattern, d_js, blocks }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_index(blocks: Vec<Vec<u32>>) -> HeadIndex {
        HeadIndex { pattern: HeadPattern::VerticalSlash, d_js: 0.0, blocks }
    }

    #[test]
    fn density_full_causal_is_one() {
        let idx = mk_index(vec![vec![0], vec![0, 1], vec![0, 1, 2]]);
        assert!((idx.density() - 1.0).abs() < 1e-12);
        assert_eq!(idx.job_count(), 6);
    }

    #[test]
    fn validate_rejects_future_blocks() {
        let idx = mk_index(vec![vec![1]]);
        assert!(idx.validate().is_err());
    }

    #[test]
    fn validate_rejects_unsorted() {
        let idx = mk_index(vec![vec![0], vec![1, 0]]);
        assert!(idx.validate().is_err());
    }

    #[test]
    fn validate_accepts_legal() {
        let idx = mk_index(vec![vec![0], vec![0, 1], vec![0, 2]]);
        assert!(idx.validate().is_ok());
    }
}
