//! Coverage-constrained top-k selection (Algorithm 1 lines 13-18 / 24-26).
//!
//! Given non-negative scores, select the minimum number of highest-scoring
//! items whose normalized cumulative sum reaches gamma. Two implementations:
//!
//!  * [`coverage_select`] — reference: full descending sort, prefix scan.
//!  * [`coverage_select_streaming`] — the paper's Streaming Top-k Selection
//!    Module: no global argsort; maintains a bounded candidate list and
//!    extracts maxima in rounds (comparator-tree semantics). Exactly the
//!    same result set, hardware-shaped control flow — this is the variant
//!    whose cost the simulator models.

/// Reference: sort-based coverage selection. Returns ascending indices.
pub fn coverage_select(scores: &[f32], gamma: f32) -> Vec<u32> {
    let total: f32 = scores.iter().sum();
    if total <= 0.0 || scores.is_empty() {
        return vec![];
    }
    let mut order: Vec<u32> = (0..scores.len() as u32).collect();
    // descending by score; ties broken by ascending index for determinism
    order.sort_by(|&a, &b| {
        scores[b as usize]
            .partial_cmp(&scores[a as usize])
            .unwrap()
            .then(a.cmp(&b))
    });
    let target = gamma * total;
    let mut cum = 0.0f32;
    let mut picked = Vec::new();
    for &i in &order {
        picked.push(i);
        cum += scores[i as usize];
        if cum >= target {
            break;
        }
    }
    picked.sort_unstable();
    picked
}

/// Streaming coverage selection with a bounded candidate window.
///
/// Each round scans the score buffer once, collecting the top `window`
/// not-yet-selected entries (a comparator-tree insertion, O(window) state),
/// then consumes them in descending order until gamma coverage is reached
/// or the window empties (then rescan). Identical result to
/// [`coverage_select`]; bounded memory like the hardware unit.
pub fn coverage_select_streaming(scores: &[f32], gamma: f32, window: usize) -> Vec<u32> {
    let total: f32 = scores.iter().sum();
    if total <= 0.0 || scores.is_empty() {
        return vec![];
    }
    let window = window.max(1);
    let target = gamma * total;
    let mut selected = vec![false; scores.len()];
    let mut picked: Vec<u32> = Vec::new();
    let mut cum = 0.0f32;
    'outer: loop {
        // one streaming pass: bounded insertion-sorted candidate list
        let mut cand: Vec<u32> = Vec::with_capacity(window + 1);
        for i in 0..scores.len() {
            if selected[i] {
                continue;
            }
            let s = scores[i];
            // insert position in descending order (ties: ascending index)
            let pos = cand
                .iter()
                .position(|&c| {
                    let cs = scores[c as usize];
                    s > cs || (s == cs && (i as u32) < c)
                })
                .unwrap_or(cand.len());
            if pos < window {
                cand.insert(pos, i as u32);
                if cand.len() > window {
                    cand.pop();
                }
            }
        }
        if cand.is_empty() {
            break;
        }
        for &i in &cand {
            selected[i as usize] = true;
            picked.push(i);
            cum += scores[i as usize];
            if cum >= target {
                break 'outer;
            }
        }
    }
    picked.sort_unstable();
    picked
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;
    use crate::util::prop::forall_ck;

    #[test]
    fn selects_minimum_set() {
        let scores = [0.5, 0.3, 0.1, 0.1];
        assert_eq!(coverage_select(&scores, 0.75), vec![0, 1]);
        assert_eq!(coverage_select(&scores, 0.8), vec![0, 1]);
        assert_eq!(coverage_select(&scores, 0.81), vec![0, 1, 2]);
    }

    #[test]
    fn gamma_one_selects_all_positive() {
        let scores = [0.2, 0.0, 0.8];
        let sel = coverage_select(&scores, 1.0);
        // zero-score entries may be needed only if gamma*total unreachable
        // without them; here 0.2+0.8 == total so index 1 is not needed.
        assert_eq!(sel, vec![0, 2]);
    }

    #[test]
    fn empty_and_zero_scores() {
        assert!(coverage_select(&[], 0.9).is_empty());
        assert!(coverage_select(&[0.0, 0.0], 0.9).is_empty());
    }

    #[test]
    fn single_dominant_block() {
        let scores = [0.01, 0.95, 0.04];
        assert_eq!(coverage_select(&scores, 0.9), vec![1]);
    }

    #[test]
    fn streaming_matches_reference_small_window() {
        let scores = [0.05, 0.3, 0.02, 0.25, 0.08, 0.3];
        for gamma in [0.1, 0.5, 0.9, 0.99] {
            for window in [1, 2, 4, 16] {
                assert_eq!(
                    coverage_select_streaming(&scores, gamma, window),
                    coverage_select(&scores, gamma),
                    "gamma={gamma} window={window}"
                );
            }
        }
    }

    #[test]
    fn prop_streaming_equals_reference() {
        forall_ck(
            17,
            60,
            |rng, size| {
                let n = 1 + size;
                let scores: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
                let gamma = rng.range_f32(0.05, 0.99);
                let window = 1 + rng.below(8);
                (scores, gamma, window)
            },
            |(scores, gamma, window)| {
                let a = coverage_select(scores, *gamma);
                let b = coverage_select_streaming(scores, *gamma, *window);
                if a == b {
                    Ok(())
                } else {
                    Err(format!("ref {a:?} vs streaming {b:?}"))
                }
            },
        );
    }

    #[test]
    fn prop_coverage_reached_and_minimal() {
        forall_ck(
            19,
            60,
            |rng, size| {
                let n = 2 + size;
                let scores: Vec<f32> = (0..n).map(|_| rng.f32() + 0.001).collect();
                let gamma = rng.range_f32(0.1, 0.95);
                (scores, gamma)
            },
            |(scores, gamma)| {
                let sel = coverage_select(scores, *gamma);
                let total: f32 = scores.iter().sum();
                let cum: f32 = sel.iter().map(|&i| scores[i as usize]).sum();
                if cum < gamma * total - 1e-5 {
                    return Err(format!("coverage not reached: {cum} < {}", gamma * total));
                }
                // minimality: removing the smallest selected score must
                // break coverage
                if let Some(&min_i) = sel
                    .iter()
                    .min_by(|&&a, &&b| scores[a as usize].partial_cmp(&scores[b as usize]).unwrap())
                {
                    let without = cum - scores[min_i as usize];
                    if without >= gamma * total {
                        return Err("selection not minimal".into());
                    }
                }
                Ok(())
            },
        );
    }
}
