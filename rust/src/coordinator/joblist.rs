//! Job-list bucketization — the paper's block-major SAU schedule (§IV-C).
//!
//! The sparse index set (per head, per query block) is transformed in
//! linear time into a per-KV-block consumer list: each KV block (kv_head,
//! block) carries the jobs (head, q_block) that need it. Execution then
//! iterates KV blocks in ascending index order ("block-major"), which turns
//! head-dependent gathers into sequential HBM bursts.
//!
//! Because the banked accumulator memory is bounded, query blocks are
//! partitioned into *waves*: only `wave_qblocks` query blocks' (m, l, acc)
//! states are live at once, and each wave streams the KV blocks it needs.
//! Cross-wave KV reuse is what the liveness cache exploits (Fig. 7); the
//! block-use counters span the whole schedule, so evict-on-nil only fires
//! when a block is truly dead.

use crate::flexprefill::HeadIndex;

/// Default number of live query blocks per SAU wave — the paper's banked
/// accumulator budget. Shared by the engine config and the reference
/// prefill (wave size never changes numerics, only memory/locality).
pub const DEFAULT_WAVE_QBLOCKS: usize = 8;

/// One SAU job: (query head, query block) consuming some KV block.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Job {
    pub head: u16,
    pub qblock: u32,
}

/// All consumers of one KV block within one wave.
#[derive(Clone, Debug)]
pub struct BlockJobs {
    pub kv_head: u16,
    pub block: u32,
    pub jobs: Vec<Job>,
}

/// A wave: a contiguous query-block range plus its block-major job lists.
#[derive(Clone, Debug)]
pub struct Wave {
    /// Query blocks [start, end) whose accumulators are live in this wave.
    pub q_start: u32,
    pub q_end: u32,
    /// KV blocks in ascending (kv_head, block) order.
    pub blocks: Vec<BlockJobs>,
}

/// The full SAU schedule for one layer.
#[derive(Clone, Debug)]
pub struct Schedule {
    pub waves: Vec<Wave>,
    /// Exact remaining-use counters per cache key, over the whole schedule.
    pub uses: Vec<(u64, u32)>,
    pub total_jobs: usize,
    pub n_blocks: usize,
    pub n_kv_heads: usize,
}

/// Cache key for a KV block: (kv_head, block) packed.
#[inline]
pub fn cache_key(kv_head: u16, block: u32) -> u64 {
    ((kv_head as u64) << 32) | block as u64
}

/// The kv-head layout a schedule family is built over — the
/// fusion-compatibility key for cross-lane IndexGen. Two lanes may ride
/// one fused K stream only when their query heads map onto kv heads the
/// same way: same kv-head count and same GQA group size. (Lanes served by
/// one engine share a `ModelConfig` and are compatible by construction;
/// the gate keeps the invariant explicit and checkable.)
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KvLayout {
    pub n_kv_heads: usize,
    pub group_size: usize,
}

impl KvLayout {
    /// The layout of a model config's attention geometry.
    pub fn of(cfg: &crate::config::ModelConfig) -> KvLayout {
        KvLayout { n_kv_heads: cfg.n_kv_heads, group_size: cfg.group_size() }
    }

    /// True when lanes with these layouts may share a fused IndexGen
    /// K stream (per-head job spaces line up exactly).
    pub fn compatible(&self, other: &KvLayout) -> bool {
        self == other
    }
}

/// Build the block-major wave schedule from per-head sparse indices.
///
/// `indices[h].blocks[q]` lists KV blocks for query head h / query block q;
/// `group_size` maps query head -> kv head (GQA); `wave_qblocks` bounds the
/// live accumulator set (0 => single wave over everything).
pub fn build_schedule(indices: &[HeadIndex], group_size: usize, wave_qblocks: usize) -> Schedule {
    assert!(!indices.is_empty());
    let n_blocks = indices[0].blocks.len();
    let n_heads = indices.len();
    let n_kv_heads = n_heads.div_ceil(group_size);
    let wave_q = if wave_qblocks == 0 { n_blocks.max(1) } else { wave_qblocks };
    let mut uses: std::collections::HashMap<u64, u32> = std::collections::HashMap::new();
    let mut total_jobs = 0usize;
    let mut waves = Vec::new();

    let mut q_start = 0usize;
    while q_start < n_blocks {
        let q_end = (q_start + wave_q).min(n_blocks);
        // bucketize: (kv_head, block) -> jobs, via counting into a dense map
        let mut buckets: Vec<Vec<Job>> = vec![Vec::new(); n_kv_heads * n_blocks];
        for (h, idx) in indices.iter().enumerate() {
            let g = h / group_size;
            for q in q_start..q_end {
                for &b in &idx.blocks[q] {
                    buckets[g * n_blocks + b as usize]
                        .push(Job { head: h as u16, qblock: q as u32 });
                }
            }
        }
        let mut blocks = Vec::new();
        for g in 0..n_kv_heads {
            for b in 0..n_blocks {
                let jobs = std::mem::take(&mut buckets[g * n_blocks + b]);
                if jobs.is_empty() {
                    continue;
                }
                total_jobs += jobs.len();
                *uses.entry(cache_key(g as u16, b as u32)).or_insert(0) += jobs.len() as u32;
                blocks.push(BlockJobs { kv_head: g as u16, block: b as u32, jobs });
            }
        }
        waves.push(Wave { q_start: q_start as u32, q_end: q_end as u32, blocks });
        q_start = q_end;
    }

    let mut uses: Vec<(u64, u32)> = uses.into_iter().collect();
    uses.sort_unstable();
    Schedule { waves, uses, total_jobs, n_blocks, n_kv_heads }
}

// ---------------------------------------------------------------------------
// batch axis: co-resident requests sharing one wave sweep
// ---------------------------------------------------------------------------

/// One SAU job of a *batched* wave: request lane + (head, q_block).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BatchJob {
    /// Request lane (index into the co-resident request set).
    pub lane: u16,
    pub head: u16,
    pub qblock: u32,
}

/// All consumers of one (kv_head, block) coordinate across every lane of
/// one batched wave. Lanes read their own KV data, but the block-major
/// sweep visits the coordinate once — co-resident requests amortize the
/// per-block weight/schedule traffic of the wave.
#[derive(Clone, Debug)]
pub struct BatchBlockJobs {
    pub kv_head: u16,
    pub block: u32,
    pub jobs: Vec<BatchJob>,
}

/// A batched wave: each lane's live query-block range (None once a lane
/// has run out of waves) plus the merged block-major job lists.
#[derive(Clone, Debug)]
pub struct BatchWave {
    /// Per lane: [start, end) of live query blocks, or None if idle.
    pub q_ranges: Vec<Option<(u32, u32)>>,
    /// Merged (kv_head, block) coordinates in ascending order.
    pub blocks: Vec<BatchBlockJobs>,
}

/// A batched SAU schedule over several co-resident requests ("lanes").
#[derive(Clone, Debug)]
pub struct BatchSchedule {
    pub waves: Vec<BatchWave>,
    pub lanes: usize,
    pub total_jobs: usize,
    /// Per lane: number of query/KV blocks (the lane's context length).
    pub n_blocks: Vec<usize>,
}

/// Merge per-lane wave schedules into one batched sweep: wave w of the
/// batch contains wave w of every lane that still has one, with block
/// lists merged coordinate-wise. Per-lane job order is preserved (each
/// lane's jobs still see their KV blocks in ascending order), so batched
/// execution is bit-identical to running the lanes solo.
pub fn build_schedule_batch(lanes: &[&Schedule]) -> BatchSchedule {
    assert!(!lanes.is_empty());
    let max_waves = lanes.iter().map(|s| s.waves.len()).max().unwrap_or(0);
    let mut waves = Vec::with_capacity(max_waves);
    let mut total_jobs = 0usize;
    for w in 0..max_waves {
        let mut q_ranges = vec![None; lanes.len()];
        let mut merged: std::collections::BTreeMap<(u16, u32), Vec<BatchJob>> =
            std::collections::BTreeMap::new();
        for (lane, s) in lanes.iter().enumerate() {
            let Some(wave) = s.waves.get(w) else { continue };
            q_ranges[lane] = Some((wave.q_start, wave.q_end));
            for bj in &wave.blocks {
                let bucket = merged.entry((bj.kv_head, bj.block)).or_default();
                bucket.extend(bj.jobs.iter().map(|j| BatchJob {
                    lane: lane as u16,
                    head: j.head,
                    qblock: j.qblock,
                }));
                total_jobs += bj.jobs.len();
            }
        }
        let blocks = merged
            .into_iter()
            .map(|((kv_head, block), jobs)| BatchBlockJobs { kv_head, block, jobs })
            .collect();
        waves.push(BatchWave { q_ranges, blocks });
    }
    BatchSchedule {
        waves,
        lanes: lanes.len(),
        total_jobs,
        n_blocks: lanes.iter().map(|s| s.n_blocks).collect(),
    }
}

impl BatchSchedule {
    /// Invariants: ascending merged block order, every job inside its
    /// lane's live range, job conservation against the lane schedules.
    pub fn check_invariants(&self, lanes: &[&Schedule]) -> Result<(), String> {
        if lanes.len() != self.lanes {
            return Err(format!("lane count {} != {}", lanes.len(), self.lanes));
        }
        let mut seen = 0usize;
        for w in &self.waves {
            let mut prev: Option<(u16, u32)> = None;
            for bj in &w.blocks {
                let cur = (bj.kv_head, bj.block);
                if let Some(p) = prev {
                    if cur <= p {
                        return Err(format!("batch blocks not ascending: {p:?} -> {cur:?}"));
                    }
                }
                prev = Some(cur);
                for j in &bj.jobs {
                    let Some((qs, qe)) = w.q_ranges.get(j.lane as usize).copied().flatten()
                    else {
                        return Err(format!("job {j:?} on an idle lane"));
                    };
                    if !(qs..qe).contains(&j.qblock) {
                        return Err(format!("job {j:?} outside lane range [{qs}, {qe})"));
                    }
                }
                seen += bj.jobs.len();
            }
        }
        let expected: usize = lanes.iter().map(|s| s.total_jobs).sum();
        if seen != expected || self.total_jobs != expected {
            return Err(format!(
                "batch job conservation: scheduled {seen} (recorded {}) != lanes {expected}",
                self.total_jobs
            ));
        }
        Ok(())
    }
}

impl Schedule {
    /// Invariants used by property tests: ascending block order per wave,
    /// job conservation, use counters match job references.
    pub fn check_invariants(&self, indices: &[HeadIndex], group_size: usize) -> Result<(), String> {
        let mut seen = 0usize;
        for w in &self.waves {
            let mut prev: Option<(u16, u32)> = None;
            for bj in &w.blocks {
                let cur = (bj.kv_head, bj.block);
                if let Some(p) = prev {
                    if cur <= p {
                        return Err(format!("blocks not ascending: {p:?} -> {cur:?}"));
                    }
                }
                prev = Some(cur);
                for j in &bj.jobs {
                    if !(w.q_start..w.q_end).contains(&j.qblock) {
                        return Err(format!("job {j:?} outside wave [{}, {})", w.q_start, w.q_end));
                    }
                    let g = j.head as usize / group_size;
                    if g != bj.kv_head as usize {
                        return Err(format!("job {j:?} in wrong kv-head bucket {}", bj.kv_head));
                    }
                    if !indices[j.head as usize].blocks[j.qblock as usize].contains(&bj.block) {
                        return Err(format!("phantom job {j:?} for block {}", bj.block));
                    }
                }
                seen += bj.jobs.len();
            }
        }
        let expected: usize = indices.iter().map(|i| i.job_count()).sum();
        if seen != expected {
            return Err(format!("job conservation: scheduled {seen} != indexed {expected}"));
        }
        let use_total: u32 = self.uses.iter().map(|(_, u)| *u).sum();
        if use_total as usize != expected {
            return Err(format!("use counters {use_total} != jobs {expected}"));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flexprefill::HeadPattern;

    fn idx(blocks: Vec<Vec<u32>>) -> HeadIndex {
        HeadIndex { pattern: HeadPattern::VerticalSlash, d_js: 0.0, blocks }
    }

    #[test]
    fn single_wave_bucketization() {
        // 2 heads, group_size 2 (1 kv head), 3 blocks
        let indices = vec![
            idx(vec![vec![0], vec![0, 1], vec![2]]),
            idx(vec![vec![0], vec![1], vec![0, 2]]),
        ];
        let s = build_schedule(&indices, 2, 0);
        assert_eq!(s.waves.len(), 1);
        assert_eq!(s.total_jobs, 8);
        s.check_invariants(&indices, 2).unwrap();
        // block 0 consumed by: h0q0, h0q1, h1q0, h1q2 => 4 uses
        let key0 = cache_key(0, 0);
        let u0 = s.uses.iter().find(|(k, _)| *k == key0).unwrap().1;
        assert_eq!(u0, 4);
    }

    #[test]
    fn waves_partition_query_blocks() {
        let indices = vec![idx(vec![vec![0], vec![0, 1], vec![0, 2], vec![3]])];
        let s = build_schedule(&indices, 1, 2);
        assert_eq!(s.waves.len(), 2);
        assert_eq!((s.waves[0].q_start, s.waves[0].q_end), (0, 2));
        assert_eq!((s.waves[1].q_start, s.waves[1].q_end), (2, 4));
        s.check_invariants(&indices, 1).unwrap();
        // block 0 used in both waves: remaining-use spans the schedule
        let u0 = s.uses.iter().find(|(k, _)| *k == cache_key(0, 0)).unwrap().1;
        assert_eq!(u0, 3);
    }

    #[test]
    fn gqa_buckets_by_kv_head() {
        // 4 heads, group_size 2 => 2 kv heads
        let indices = vec![
            idx(vec![vec![0]]),
            idx(vec![vec![0]]),
            idx(vec![vec![0]]),
            idx(vec![vec![0]]),
        ];
        let s = build_schedule(&indices, 2, 0);
        assert_eq!(s.waves[0].blocks.len(), 2); // one bucket per kv head
        assert_eq!(s.waves[0].blocks[0].jobs.len(), 2);
        assert_eq!(s.uses.len(), 2);
        s.check_invariants(&indices, 2).unwrap();
    }

    #[test]
    fn empty_selections_produce_no_buckets() {
        let indices = vec![idx(vec![vec![], vec![]])];
        let s = build_schedule(&indices, 1, 0);
        assert_eq!(s.total_jobs, 0);
        assert!(s.waves[0].blocks.is_empty());
    }

    #[test]
    fn batch_schedule_merges_shared_coordinates() {
        // two lanes touching overlapping (kv_head, block) coordinates
        let a_idx = vec![idx(vec![vec![0], vec![0, 1]])];
        let b_idx = vec![idx(vec![vec![0], vec![1]])];
        let a = build_schedule(&a_idx, 1, 0);
        let b = build_schedule(&b_idx, 1, 0);
        let batch = build_schedule_batch(&[&a, &b]);
        batch.check_invariants(&[&a, &b]).unwrap();
        assert_eq!(batch.lanes, 2);
        assert_eq!(batch.total_jobs, a.total_jobs + b.total_jobs);
        assert_eq!(batch.waves.len(), 1);
        // block (0, 0) serves jobs from both lanes in one visit
        let b0 = &batch.waves[0].blocks[0];
        assert_eq!((b0.kv_head, b0.block), (0, 0));
        let lanes: Vec<u16> = b0.jobs.iter().map(|j| j.lane).collect();
        assert!(lanes.contains(&0) && lanes.contains(&1));
    }

    #[test]
    fn batch_schedule_handles_uneven_wave_counts() {
        // lane 0: 4 q-blocks in waves of 2 => 2 waves; lane 1: 1 wave
        let a_idx = vec![idx(vec![vec![0], vec![1], vec![2], vec![3]])];
        let b_idx = vec![idx(vec![vec![0], vec![0, 1]])];
        let a = build_schedule(&a_idx, 1, 2);
        let b = build_schedule(&b_idx, 1, 2);
        let batch = build_schedule_batch(&[&a, &b]);
        batch.check_invariants(&[&a, &b]).unwrap();
        assert_eq!(batch.waves.len(), 2);
        assert!(batch.waves[1].q_ranges[1].is_none(), "lane 1 idle in wave 2");
        assert!(batch.waves[1].blocks.iter().all(|bj| bj.jobs.iter().all(|j| j.lane == 0)));
    }

    #[test]
    fn kv_layout_gates_on_head_geometry() {
        let tiny = KvLayout::of(&crate::config::TINY);
        assert_eq!(tiny, KvLayout { n_kv_heads: 2, group_size: 2 });
        assert!(tiny.compatible(&KvLayout::of(&crate::config::TINY)));
        assert!(!tiny.compatible(&KvLayout { n_kv_heads: 4, group_size: 2 }));
        assert!(!tiny.compatible(&KvLayout { n_kv_heads: 2, group_size: 1 }));
    }

    #[test]
    fn batch_of_one_matches_solo_schedule() {
        let indices = vec![idx(vec![vec![0], vec![0, 1], vec![2]])];
        let s = build_schedule(&indices, 1, 2);
        let batch = build_schedule_batch(&[&s]);
        batch.check_invariants(&[&s]).unwrap();
        assert_eq!(batch.waves.len(), s.waves.len());
        assert_eq!(batch.total_jobs, s.total_jobs);
        for (bw, sw) in batch.waves.iter().zip(&s.waves) {
            assert_eq!(bw.q_ranges[0], Some((sw.q_start, sw.q_end)));
            assert_eq!(bw.blocks.len(), sw.blocks.len());
        }
    }
}
