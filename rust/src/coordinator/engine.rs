//! The prefill engine: chunked execution of the full pipeline (paper
//! Fig. 2) — KV generation -> SIGU -> block-major SAU with the liveness
//! cache -> FFN -> first token.
//!
//! Two backends exist for every matmul-heavy stage:
//!
//!  * **PJRT artifacts** (`pjrt` feature + `make artifacts`): the AOT
//!    HLO entry points execute on the CPU client (the "MPU").
//!  * **native tiled kernels**: the bit-compatible Rust mirror built on
//!    `tensor::tile` + the shared worker pool. Per-phase switches
//!    (`native_sigu`, `native_sau`, `native_linear`) choose per stage;
//!    with all three on, the engine needs no artifacts at all
//!    ([`Engine::new_native`]) and fans its work over a [`KernelCtx`]:
//!    chunks (QKV/FFN), heads (SIGU), and the wave's (head, query-block)
//!    accumulator states (SAU) run as independent pool jobs, so results
//!    are bit-identical for every `FASTP_THREADS` value.
//!
//! Decision logic, coverage selection, job-list bucketization and cache
//! policy always run natively (the paper's FSM/SFU/comparator logic);
//! cache traffic is driven through the canonical
//! [`crate::coordinator::walk::ScheduleWalk`] spine — the same walk the
//! cycle simulator prices — so cache statistics are deterministic,
//! backend-independent, and engine/simulator-identical by construction.
//!
//! Prefill is **resumable**: [`Engine::prefill_start`] yields a
//! [`PrefillState`] that steps through the per-layer phases
//! ([`Phase::Qkv`] -> [`Phase::IndexGen`] -> [`Phase::Sau`] ->
//! [`Phase::FfnLogits`]) one call at a time, which is what the serving
//! scheduler pipelines across co-resident requests; the monolithic
//! [`Engine::prefill`] is a thin wrapper that steps to completion. Fused
//! group steps ([`Engine::phase_step_group`]) batch same-phase requests:
//! QKV, IndexGen and the FFN tail on a shared layer and SAU at any
//! layer, bit-identical to solo stepping.

use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::config::{FlexParams, ModelConfig, BLOCK};
use crate::coordinator::joblist::{
    build_schedule, build_schedule_batch, Schedule, DEFAULT_WAVE_QBLOCKS,
};
use crate::coordinator::prefix::{self, PrefixStore};
use crate::coordinator::walk::{k_block_bytes, DecodeStepWalk, IndexGenWalk, ScheduleWalk};
use crate::flexprefill::{generate_head_index, scores, HeadIndex, HeadPattern, HeadStats};
use crate::kvcache::{CacheStats, LivenessCache};
use crate::metrics::PrefillMetrics;
use crate::model::decode::{DecodeKv, Decoder};
use crate::model::forward::{self as fwd, attn_finalize, ChunkQkv};
use crate::model::ModelWeights;
use crate::runtime::{literal_f32, literal_i8, Arg, Runtime};
use crate::tensor::tile::KernelCtx;
use crate::tensor::tune::{self, TuneOverride};
use crate::tensor::{MatF32, MatI8};
use crate::util::pool::AdaptiveHints;

/// [`AdaptiveHints`] slot of each phase (the serving loop observes into
/// and the engine sizes leases from the same slots).
pub fn phase_hint_slot(p: Phase) -> usize {
    match p {
        Phase::Qkv => 0,
        Phase::IndexGen => 1,
        Phase::Sau => 2,
        Phase::FfnLogits | Phase::Done => 3,
    }
}

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub model: ModelConfig,
    /// None => dense causal attention (baseline).
    pub flex: Option<FlexParams>,
    pub weight_seed: u64,
    /// Live query blocks per SAU wave (0 = all — unbounded accumulator).
    pub wave_qblocks: usize,
    /// KV cache capacity in blocks (0 = cacheless ablation).
    pub cache_blocks: usize,
    pub hot_fraction: f64,
    /// t_hot as a fraction of per-key maximum consumers.
    pub t_hot_frac: f64,
    /// Compute SIGU statistics natively instead of via artifacts.
    pub native_sigu: bool,
    /// Compute SAU attention natively instead of via artifacts.
    pub native_sau: bool,
    /// Compute QKV, o_proj+FFN and logits natively (tiled kernels)
    /// instead of via artifacts. With `native_sigu` and `native_sau` this
    /// makes the engine artifact-free.
    pub native_linear: bool,
    /// Worker threads for the kernel context (0 = `FASTP_THREADS` env,
    /// default available parallelism).
    pub threads: usize,
    /// Autotune profile source for the kernel context:
    /// [`TuneOverride::Env`] follows `FASTP_AUTOTUNE` (the default),
    /// `Off` forces the untuned static defaults, and `Profile` injects an
    /// explicit [`crate::tensor::tune::TuneProfile`] (what `fastp tune
    /// --check` and the engine bit-identity test use). Never changes
    /// results — only which (tile, backend) pair each kernel shape runs
    /// with.
    pub tune: TuneOverride,
}

impl EngineConfig {
    pub fn new(model: ModelConfig) -> Self {
        EngineConfig {
            model,
            flex: Some(FlexParams::default()),
            weight_seed: 0xFA57,
            wave_qblocks: DEFAULT_WAVE_QBLOCKS,
            cache_blocks: 1024,
            hot_fraction: 0.5,
            t_hot_frac: 0.5,
            native_sigu: true,
            native_sau: false,
            native_linear: false,
            threads: 0,
            tune: TuneOverride::default(),
        }
    }

    /// Fully-native config: every stage through the tiled kernel layer,
    /// no artifacts required.
    pub fn new_native(model: ModelConfig) -> Self {
        let mut cfg = Self::new(model);
        cfg.native_sigu = true;
        cfg.native_sau = true;
        cfg.native_linear = true;
        cfg
    }

    /// True when no stage needs the PJRT artifacts.
    pub fn fully_native(&self) -> bool {
        self.native_sigu && self.native_sau && self.native_linear
    }

    fn kernel_ctx(&self) -> KernelCtx {
        let ctx = if self.threads > 0 {
            KernelCtx::with_threads(self.threads)
        } else {
            KernelCtx::from_env()
        };
        match &self.tune {
            TuneOverride::Env => ctx,
            TuneOverride::Off => ctx.with_tune(None),
            TuneOverride::Profile(p) => ctx.with_tune(Some(p.clone())),
        }
    }
}

/// Phase cursor of a resumable prefill: the per-layer stages of paper
/// Fig. 2, walked layer by layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Chunked KV generation for the current layer.
    Qkv,
    /// SIGU sparse index generation.
    IndexGen,
    /// Block-major SAU over the wave schedule (with the liveness cache).
    Sau,
    /// o_proj + FFN tail; after the last layer, final norm + logits.
    FfnLogits,
    /// The run is finished and has been handed out.
    Done,
}

/// Resumable per-request prefill progress. Created by
/// [`Engine::prefill_start`] and advanced one phase at a time by the
/// `phase_*` methods, so a serving scheduler can interleave the phases of
/// co-resident requests on one engine (or hand the state to any other
/// engine over the same weights — the state holds no engine resources).
/// Stepping the phases in order is *exactly* the monolithic
/// [`Engine::prefill`] computation, so per-request outputs are bit-identical
/// however the phases are interleaved across requests.
pub struct PrefillState {
    pub request_id: u64,
    phase: Phase,
    layer: usize,
    /// Total layer count (from the engine config; for remaining-cost
    /// estimates at scheduling time).
    n_layers: usize,
    /// Context length in tokens / in BLOCK chunks.
    s: usize,
    n: usize,
    // per-phase fan-out job counters (for measured per-job cost)
    qkv_jobs: usize,
    sigu_jobs: usize,
    ffn_jobs: usize,
    t_start: Instant,
    hidden: MatF32,
    metrics: PrefillMetrics,
    patterns: Vec<Vec<HeadPattern>>,
    index_sets: Vec<Vec<HeadIndex>>,
    density_sum: f64,
    density_cnt: usize,
    qa_heads: usize,
    cache_hits: u64,
    cache_lookups: u64,
    // intra-layer hand-offs between phases
    chunks: Option<Vec<ChunkQkv>>,
    indices: Option<Vec<HeadIndex>>,
    attn: Option<Vec<Vec<f32>>>,
    // ---- chunked prefill (token-slice scheduling) ----
    /// Token-slice width in BLOCK chunks (0 = monolithic). When set, the
    /// layer loop runs once per slice: the outer loop walks token slices
    /// `[chunk_from, chunk_to)` and the inner loop walks layers, with each
    /// layer's KV retained in `layer_kv` between slices. Dense causal
    /// attention, absolute RoPE and per-chunk quant scales make each slice
    /// closed over its predecessors, so the chunked walk is bit-identical
    /// to the monolithic one (the same argument as prefix resume).
    chunk_blocks: usize,
    /// Current slice bounds in BLOCK chunks (monolithic: `[0, n)`).
    chunk_from: usize,
    chunk_to: usize,
    /// Retained per-layer KV from completed slices, `layer_kv[layer]`
    /// holding chunks `[0, chunk_from)` (empty when monolithic).
    layer_kv: Vec<Vec<ChunkQkv>>,
    /// Decode-seed capture: per-layer inputs (the rows entering each
    /// layer's QKV projection), accumulated slice by slice. `Some` iff the
    /// request continues into decode ([`PrefillArgs::capture_decode`]) —
    /// exactly what [`crate::model::decode::Decoder::from_prefill_inputs`]
    /// consumes.
    capture: Option<Vec<MatF32>>,
    // ---- cross-request prefix KV reuse (coordinator::prefix) ----
    /// Leading blocks covered by the prefix store (0 = cold start). The
    /// per-layer phases skip QKV/SAU/FFN work below this block index.
    resume_from: usize,
    /// Store-served per-layer prefix chunks, `reused[layer][block]`
    /// (`block < resume_from`); each layer's vec is spliced into that
    /// layer's QKV phase and left empty.
    reused: Vec<Vec<ChunkQkv>>,
    /// Rolling chain hash of the full context (nonempty iff this run
    /// publishes back to an attached store).
    prefix_chain: Vec<u64>,
    /// Token copy for publication (block content is verified on hit).
    prefix_tokens: Vec<u8>,
    /// Per-layer full chunk clones gathered by the QKV phases,
    /// `publish_chunks[layer][block]`; transposed and published on finish.
    publish_chunks: Vec<Vec<ChunkQkv>>,
}

impl PrefillState {
    pub fn phase(&self) -> Phase {
        self.phase
    }

    pub fn layer(&self) -> usize {
        self.layer
    }

    pub fn context_tokens(&self) -> usize {
        self.s
    }

    /// Leading blocks resumed from the prefix store (0 = cold start).
    pub fn resume_from(&self) -> usize {
        self.resume_from
    }

    /// True when this prefill runs as token slices (chunked prefill).
    pub fn chunked(&self) -> bool {
        self.chunk_blocks > 0
    }

    /// Zero-based index of the current token slice (always 0 monolithic).
    pub fn chunk_index(&self) -> usize {
        if self.chunk_blocks > 0 { self.chunk_from / self.chunk_blocks } else { 0 }
    }

    /// Current slice bounds `[from, to)` in BLOCK chunks.
    pub fn chunk_cursor(&self) -> (usize, usize) {
        (self.chunk_from, self.chunk_to)
    }

    /// Block range the current layer pass computes: the active token
    /// slice when chunked, else the novel suffix above any prefix resume.
    fn slice_bounds(&self) -> (usize, usize) {
        if self.chunk_blocks > 0 {
            (self.chunk_from, self.chunk_to)
        } else {
            (self.resume_from, self.n)
        }
    }

    /// KV extent (blocks) the current SAU pass attends over: the slice's
    /// end when chunked (earlier slices' KV is retained and visible),
    /// else the full context.
    fn kv_extent(&self) -> usize {
        if self.chunk_blocks > 0 { self.chunk_to } else { self.n }
    }

    /// Phase steps left before this request finishes, counting the phase
    /// it is currently parked at (0 once [`Phase::Done`]). Chunked states
    /// count the full 4-phase layer walk of every remaining token slice.
    pub fn remaining_phase_steps(&self) -> usize {
        if self.phase == Phase::Done {
            return 0;
        }
        let in_layer = match self.phase {
            Phase::Qkv => 4,
            Phase::IndexGen => 3,
            Phase::Sau => 2,
            Phase::FfnLogits => 1,
            Phase::Done => 0,
        };
        let this_pass = (self.n_layers.saturating_sub(self.layer + 1)) * 4 + in_layer;
        if self.chunk_blocks == 0 {
            return this_pass;
        }
        let remaining_blocks = self.n.saturating_sub(self.chunk_to);
        let slices_after = (remaining_blocks + self.chunk_blocks - 1) / self.chunk_blocks;
        this_pass + slices_after * self.n_layers * 4
    }

    /// Scheduler remaining-cost estimate: remaining phase steps weighted
    /// by context length. Deterministic (no clocks), monotone in both
    /// progress and context size — what a preemptive policy ranks
    /// runnable requests by. The same units as
    /// [`crate::coordinator::server`]'s queued-request estimate
    /// (`4 * n_layers * tokens`), so parked and queued work compare.
    /// Chunked states weight each step by the slice's tokens, so a
    /// chunked and a monolithic prefill of the same context start from
    /// (approximately) the same total cost.
    pub fn remaining_cost(&self) -> u64 {
        let step_tokens =
            if self.chunk_blocks > 0 { (self.chunk_blocks * BLOCK).min(self.s) } else { self.s };
        self.remaining_phase_steps() as u64 * step_tokens as u64
    }
}

/// Result of one prefill run.
#[derive(Clone, Debug)]
pub struct PrefillRun {
    pub first_token: u8,
    pub logits_last: Vec<f32>,
    pub metrics: PrefillMetrics,
    pub patterns: Vec<Vec<HeadPattern>>,
    /// Per-layer per-head index sets (feed the simulator / GPU model).
    pub index_sets: Vec<Vec<HeadIndex>>,
    /// Final-layer hidden state of the last chunk (validation hook).
    pub hidden_last_chunk: Vec<f32>,
    /// Captured per-layer inputs for decode seeding (`Some` iff the
    /// prefill ran with [`PrefillArgs::capture_decode`]) — feed
    /// [`crate::model::decode::Decoder::from_prefill_inputs`] or
    /// [`Engine::decode_start`].
    pub decode_inputs: Option<Vec<MatF32>>,
}

/// Admission options for [`Engine::prefill_start_with`] — how the
/// request's lifecycle continues past plain monolithic prefill.
#[derive(Clone, Copy, Debug, Default)]
pub struct PrefillArgs {
    /// Token-slice width in BLOCK chunks (0 = monolithic). Chunking is a
    /// dense-only transform (sparse SIGU is not chunk-closed, the same
    /// restriction as prefix reuse): on a sparse engine, or when the
    /// slice covers the whole context, the request silently runs
    /// monolithic. Chunked requests skip prefix participation.
    pub chunk_blocks: usize,
    /// Capture each layer's input rows for decode seeding (the request
    /// continues into token generation). Capturing requests skip prefix
    /// resume: store-served blocks leave hidden rows below the resume
    /// point stale, which decode seeding must read.
    pub capture_decode: bool,
}

/// Append hidden rows `[from*BLOCK, to*BLOCK)` to the layer's decode-seed
/// capture. Chunked prefills call this once per (slice, layer) with
/// advancing slices, so each layer's capture accumulates its full input
/// in token order.
fn capture_layer_input(cap: &mut [MatF32], layer: usize, hidden: &MatF32, from: usize, to: usize) {
    let d = hidden.cols;
    let dst = &mut cap[layer];
    debug_assert_eq!(dst.cols, d);
    debug_assert_eq!(dst.rows, from * BLOCK, "capture slices must arrive in order");
    dst.rows += (to - from) * BLOCK;
    dst.data.extend_from_slice(&hidden.data[from * BLOCK * d..to * BLOCK * d]);
}

/// A request parked between decode steps: the detached KV/position of a
/// [`crate::model::decode::Decoder`] plus serving counters. One decode
/// step is one scheduler work unit — phase-sized, so the serving loop can
/// slot steps between co-resident prefill chunks. Created by
/// [`Engine::decode_start`] from a finished capture-enabled prefill and
/// advanced by [`Engine::decode_step`] / [`Engine::decode_step_group`];
/// the emitted tokens are bit-identical to a solo
/// [`crate::model::decode::Decoder::generate`] over the same prefill
/// (decode is backend/thread-count invariant, pinned by
/// `decode_is_deterministic`).
pub struct DecodeState {
    pub request_id: u64,
    kv: Vec<DecodeKv>,
    pos: usize,
    /// The next step's input token (prefill's first token initially).
    last: u8,
    /// Tokens generated so far (excludes prefill's first token).
    pub tokens: Vec<u8>,
    /// Steps left before the request completes.
    remaining: usize,
    /// Per-step wall-clock (us) — TPOT mean / inter-token-latency tails.
    pub step_us: Vec<f64>,
    /// Per-step KV gather/append traffic priced through the canonical
    /// [`DecodeStepWalk`] — the same derivation `sim::simulate_decode_steps`
    /// prices, so engine and simulator decode bytes agree exactly.
    pub hbm_read_bytes: u64,
    pub hbm_write_bytes: u64,
}

impl DecodeState {
    /// True once every requested token has been generated.
    pub fn done(&self) -> bool {
        self.remaining == 0
    }

    /// Zero-based index of the next decode step.
    pub fn step_index(&self) -> usize {
        self.tokens.len()
    }

    /// Tokens resident in the KV cache (context + generated so far).
    pub fn context_tokens(&self) -> usize {
        self.pos
    }

    pub fn remaining_steps(&self) -> usize {
        self.remaining
    }

    /// Scheduler remaining-cost estimate, in the same units as
    /// [`PrefillState::remaining_cost`]. A decode step touches one token
    /// per layer walk, so its cost is tiny next to any prefill phase —
    /// which is exactly why a preemptive policy slots decode steps
    /// between prefill chunks (latency-critical, near-zero cost).
    pub fn remaining_cost(&self) -> u64 {
        self.remaining as u64
    }
}

/// The prefill engine (one optional PJRT runtime + one shared model
/// instance + one kernel context). Weights are behind an `Arc` so a
/// multi-worker server holds **one** generated model in memory, not one
/// per worker.
pub struct Engine {
    rt: Option<Runtime>,
    pub ctx: KernelCtx,
    pub cfg: EngineConfig,
    pub weights: Arc<ModelWeights>,
    /// Adaptive per-phase lease-want hints (ROADMAP serving (e)). When
    /// the server installs a shared [`AdaptiveHints`], each phase sizes
    /// its `with_want_cap` lease request from the EWMA of measured job
    /// costs; `None` (solo engines, the serial baseline) keeps the
    /// static split. An active autotune profile pre-seeds the EWMAs from
    /// its measured per-phase costs ([`tune::warm_hints`]), so tuned
    /// engines start with warm hints instead of cold fallbacks. Never
    /// changes results — only lease sizing.
    pub hints: Option<Arc<AdaptiveHints>>,
    /// Content-hashed cross-request prefix KV store
    /// ([`crate::coordinator::prefix`]). When attached (the server shares
    /// one across its workers; solo engines can attach one too) and the
    /// engine runs **dense** (`cfg.flex` is `None` — sparse SIGU is not
    /// prefix-closed), every prefill consults it at admission and
    /// publishes its blocks on completion. Reused-prefix outputs are
    /// bit-identical to cold runs; reuse is priced as seeded cache
    /// residency through the memory spine.
    pub prefix: Option<Arc<Mutex<PrefixStore>>>,
}

impl Engine {
    /// Build an engine. Fully-native configs skip the artifacts entirely;
    /// anything else loads + compiles the artifact entry points (which
    /// fails without the `pjrt` feature or without `make artifacts`).
    pub fn new(artifact_dir: impl AsRef<std::path::Path>, cfg: EngineConfig) -> Result<Engine> {
        let weights = Arc::new(ModelWeights::generate(&cfg.model, cfg.weight_seed));
        Engine::with_weights(artifact_dir, cfg, weights)
    }

    /// Build an engine over pre-generated shared weights (the caller is
    /// responsible for `weights` matching `cfg.model`/`cfg.weight_seed`).
    /// This is how the server shares one model across its workers.
    pub fn with_weights(
        artifact_dir: impl AsRef<std::path::Path>,
        cfg: EngineConfig,
        weights: Arc<ModelWeights>,
    ) -> Result<Engine> {
        anyhow::ensure!(
            weights.cfg.name == cfg.model.name,
            "weights generated for {} but engine configured for {}",
            weights.cfg.name,
            cfg.model.name
        );
        let rt = if cfg.fully_native() {
            None
        } else {
            let mut rt = Runtime::load(artifact_dir)?;
            rt.manifest.validate_config(&cfg.model).context("manifest/config check")?;
            rt.warmup(cfg.model.name)?;
            Some(rt)
        };
        let ctx = cfg.kernel_ctx();
        let hints = tune::warm_hints(ctx.tune.as_ref());
        Ok(Engine { rt, ctx, cfg, weights, hints, prefix: None })
    }

    /// Build an artifact-free engine on the tiled native kernels.
    pub fn new_native(model_cfg: EngineConfig) -> Result<Engine> {
        let mut cfg = model_cfg;
        cfg.native_sigu = true;
        cfg.native_sau = true;
        cfg.native_linear = true;
        let weights = Arc::new(ModelWeights::generate(&cfg.model, cfg.weight_seed));
        let ctx = cfg.kernel_ctx();
        let hints = tune::warm_hints(ctx.tune.as_ref());
        Ok(Engine { rt: None, ctx, cfg, weights, hints, prefix: None })
    }

    /// Backend description (for banners / examples).
    pub fn platform(&self) -> String {
        match &self.rt {
            Some(rt) => rt.platform(),
            None => format!(
                "native tiled kernels ({} threads, {} micro-kernels)",
                self.ctx.threads(),
                self.ctx.backend.name()
            ),
        }
    }

    /// Per-executable perf counters (empty in native mode).
    pub fn exec_stats(&self) -> Vec<(String, u64, f64)> {
        self.rt.as_ref().map(|rt| rt.exec_stats()).unwrap_or_default()
    }

    fn runtime(&mut self) -> Result<&mut Runtime> {
        self.rt.as_mut().ok_or_else(|| {
            anyhow!("artifact backend requested but the engine was built native-only")
        })
    }

    fn sau_batch(&self) -> usize {
        self.rt
            .as_ref()
            .map(|rt| rt.manifest.configs[self.cfg.model.name].sau_batch.max(1))
            .unwrap_or(1)
    }

    /// Kernel context for one phase's fan-out: sized by the adaptive
    /// lease-want hint when the server installed [`AdaptiveHints`], else
    /// by the static split (IndexGen asks for `max(threads/4, 2)`, the
    /// wide phases keep the uniform `min(threads, n_jobs)` want). A want
    /// of the full thread count needs no cap at all.
    fn phase_ctx(&self, phase: Phase) -> KernelCtx {
        let threads = self.ctx.threads();
        let fallback = match phase {
            Phase::IndexGen => index_gen_want(threads),
            _ => threads,
        };
        let want = match &self.hints {
            Some(h) => h.want(phase_hint_slot(phase), threads, fallback),
            None => fallback,
        };
        if want >= threads {
            self.ctx.clone()
        } else {
            self.ctx.with_want_cap(want)
        }
    }

    /// Run the full prefill for a byte-token context. Context length must be
    /// a multiple of BLOCK. Thin wrapper over the resumable phase methods:
    /// the phases step in order with no interleaving, which is the same
    /// computation a phase-pipelined scheduler performs per request.
    pub fn prefill(&mut self, request_id: u64, tokens: &[u8]) -> Result<PrefillRun> {
        let mut st = self.prefill_start(request_id, tokens)?;
        loop {
            if let Some(run) = self.phase_step(&mut st)? {
                return Ok(run);
            }
        }
    }

    // ------------------------------------------------------------------
    // resumable phase API (the serving scheduler's unit of work)
    // ------------------------------------------------------------------

    /// Admit a request: validate, embed, and return a state at the first
    /// phase of layer 0. TTFT is measured from this call.
    ///
    /// With a prefix store attached (dense mode only — sparse SIGU is not
    /// prefix-closed), the request's leading blocks are resolved against
    /// the store here: hash-matching blocks are restored verbatim and the
    /// state resumes mid-trace at the first novel block, capped at `n - 1`
    /// so the finish phase always has fresh last-chunk hidden rows.
    pub fn prefill_start(&self, request_id: u64, tokens: &[u8]) -> Result<PrefillState> {
        self.prefill_start_with(request_id, tokens, PrefillArgs::default())
    }

    /// [`Engine::prefill_start`] with lifecycle options: chunked token
    /// slices and/or decode-seed capture (see [`PrefillArgs`]).
    pub fn prefill_start_with(
        &self,
        request_id: u64,
        tokens: &[u8],
        args: PrefillArgs,
    ) -> Result<PrefillState> {
        let s = tokens.len();
        anyhow::ensure!(s > 0 && s % BLOCK == 0, "context must be a positive multiple of {BLOCK}");
        let n = s / BLOCK;
        let n_layers = self.cfg.model.n_layers;
        // chunking is dense-only and meaningful only when it splits the
        // context into more than one slice
        let chunk_blocks = if self.cfg.flex.is_some() || args.chunk_blocks >= n {
            0
        } else {
            args.chunk_blocks
        };
        let mut resume_from = 0usize;
        let mut reused: Vec<Vec<ChunkQkv>> = Vec::new();
        let mut prefix_chain = Vec::new();
        let mut prefix_tokens = Vec::new();
        if self.cfg.flex.is_none() && chunk_blocks == 0 && !args.capture_decode {
            if let Some(store) = &self.prefix {
                let hit = store.lock().unwrap().lookup(tokens, n - 1, n_layers);
                resume_from = hit.covered;
                // transpose the hit's [block][layer] clones into the
                // per-layer splices the QKV phases consume
                reused = (0..n_layers).map(|_| Vec::with_capacity(resume_from)).collect();
                for block_layers in hit.blocks {
                    for (li, c) in block_layers.into_iter().enumerate() {
                        reused[li].push(c);
                    }
                }
                prefix_chain = hit.chain;
                prefix_tokens = tokens.to_vec();
            }
        }
        Ok(PrefillState {
            request_id,
            phase: Phase::Qkv,
            layer: 0,
            n_layers,
            s,
            n,
            qkv_jobs: 0,
            sigu_jobs: 0,
            ffn_jobs: 0,
            t_start: Instant::now(),
            hidden: self.weights.embed_tokens(tokens),
            metrics: PrefillMetrics {
                request_id,
                context_tokens: s,
                kernel_backend: self.ctx.backend.name(),
                tune_mode: self.ctx.tune_label(),
                tuned_shapes: self.ctx.tune.as_ref().map_or(0, |p| p.entries.len()),
                prefix_blocks_reused: resume_from,
                prefix_tokens_skipped: (resume_from * BLOCK) as u64,
                ..Default::default()
            },
            patterns: Vec::new(),
            index_sets: Vec::new(),
            density_sum: 0.0,
            density_cnt: 0,
            qa_heads: 0,
            cache_hits: 0,
            cache_lookups: 0,
            chunks: None,
            indices: None,
            attn: None,
            chunk_blocks,
            chunk_from: 0,
            chunk_to: if chunk_blocks > 0 { chunk_blocks } else { n },
            layer_kv: if chunk_blocks > 0 {
                (0..n_layers).map(|_| Vec::new()).collect()
            } else {
                Vec::new()
            },
            capture: if args.capture_decode {
                let d = self.cfg.model.d_model;
                Some(
                    (0..n_layers)
                        .map(|_| MatF32 { rows: 0, cols: d, data: Vec::new() })
                        .collect(),
                )
            } else {
                None
            },
            resume_from,
            reused,
            prefix_chain,
            prefix_tokens,
            publish_chunks: Vec::new(),
        })
    }

    /// Advance whatever phase the state is at; returns the finished run
    /// after the final phase of the last layer.
    pub fn phase_step(&mut self, st: &mut PrefillState) -> Result<Option<PrefillRun>> {
        match st.phase {
            Phase::Qkv => self.phase_qkv(st).map(|_| None),
            Phase::IndexGen => self.phase_index_gen(st).map(|_| None),
            Phase::Sau => self.phase_sau(st).map(|_| None),
            Phase::FfnLogits => self.phase_ffn_logits(st),
            Phase::Done => Err(anyhow!("phase_step on a finished prefill")),
        }
    }

    /// Step a same-phase group of co-resident requests. `Qkv` and
    /// `FfnLogits` groups on one layer and `Sau` groups at any layer run
    /// *fused* (one pool fan-out over every lane's jobs); anything else
    /// steps state by state. Returns per-state finished runs.
    pub fn phase_step_group(
        &mut self,
        states: &mut [PrefillState],
    ) -> Result<Vec<Option<PrefillRun>>> {
        if states.len() > 1
            && states.iter().all(|s| s.phase == Phase::Qkv && s.layer == states[0].layer)
        {
            self.phase_qkv_batch(states)?;
            return Ok(states.iter().map(|_| None).collect());
        }
        if states.len() > 1
            && states.iter().all(|s| s.phase == Phase::IndexGen && s.layer == states[0].layer)
        {
            self.phase_index_gen_batch(states)?;
            return Ok(states.iter().map(|_| None).collect());
        }
        if states.len() > 1 && states.iter().all(|s| s.phase == Phase::Sau) {
            self.phase_sau_batch(states)?;
            return Ok(states.iter().map(|_| None).collect());
        }
        if states.len() > 1
            && states.iter().all(|s| s.phase == Phase::FfnLogits && s.layer == states[0].layer)
        {
            return self.phase_ffn_logits_batch(states);
        }
        states.iter_mut().map(|st| self.phase_step(st)).collect()
    }

    /// Phase 1: chunked KV generation for the current layer. Resumed
    /// states splice the store-served prefix chunks in front and compute
    /// only the novel blocks; publishing states clone the layer's full
    /// chunk set for publication on finish.
    pub fn phase_qkv(&mut self, st: &mut PrefillState) -> Result<()> {
        anyhow::ensure!(st.phase == Phase::Qkv, "phase_qkv in {:?}", st.phase);
        let t0 = Instant::now();
        let (from, to) = st.slice_bounds();
        // decode-seed capture: the rows entering this layer's QKV are
        // exactly what `Decoder::from_prefill_inputs` re-projects
        if let Some(cap) = st.capture.as_mut() {
            capture_layer_input(cap, st.layer, &st.hidden, from, to);
        }
        let mut chunks = if st.chunked() {
            // KV retained from completed token slices (blocks [0, from))
            std::mem::take(&mut st.layer_kv[st.layer])
        } else if st.resume_from > 0 {
            std::mem::take(&mut st.reused[st.layer])
        } else {
            Vec::new()
        };
        chunks.extend(self.run_qkv_layer(st.layer, &st.hidden, from, to)?);
        st.metrics.t_qkv_us += t0.elapsed().as_micros() as f64;
        st.qkv_jobs += to - from;
        if !st.prefix_chain.is_empty() {
            st.publish_chunks.push(chunks.clone());
        }
        st.chunks = Some(chunks);
        st.phase = Phase::IndexGen;
        Ok(())
    }

    /// Fused phase 1 for several requests at the same layer: one pool
    /// fan-out over all (request, chunk) jobs, so the layer's weights
    /// stream through the cache once for the whole batch (the ROADMAP
    /// batch>1 item). Falls back to per-state stepping when the group is
    /// not fusable. Per-lane results are bit-identical to solo phases; the
    /// fused elapsed time is charged to every lane.
    pub fn phase_qkv_batch(&mut self, states: &mut [PrefillState]) -> Result<()> {
        let fusable = states.len() > 1
            && self.cfg.native_linear
            && states.iter().all(|s| s.phase == Phase::Qkv && s.layer == states[0].layer)
            // resumed lanes compute a chunk suffix, not the full range —
            // keep them out of the fused fan-out so splicing stays local;
            // chunked lanes likewise compute only the active token slice
            && states.iter().all(|s| s.resume_from == 0 && !s.chunked());
        if !fusable {
            for st in states.iter_mut() {
                self.phase_qkv(st)?;
            }
            return Ok(());
        }
        let li = states[0].layer;
        for st in states.iter_mut() {
            if let Some(cap) = st.capture.as_mut() {
                capture_layer_input(cap, li, &st.hidden, 0, st.n);
            }
        }
        let t0 = Instant::now();
        let mut jobs: Vec<(usize, usize)> = Vec::new(); // (lane, chunk)
        for (lane, st) in states.iter().enumerate() {
            jobs.extend((0..st.n).map(|ci| (lane, ci)));
        }
        let outs = {
            let hiddens: Vec<&MatF32> = states.iter().map(|s| &s.hidden).collect();
            let weights: &ModelWeights = &self.weights;
            let ctx = self.phase_ctx(Phase::Qkv);
            let ctx = &ctx;
            ctx.pool.map(jobs.len(), |j| {
                let (lane, ci) = jobs[j];
                let x = hiddens[lane].slice_rows(ci * BLOCK, (ci + 1) * BLOCK);
                fwd::qkv_chunk(ctx, weights, li, &x, (ci * BLOCK) as i32)
            })
        };
        let dt = t0.elapsed().as_micros() as f64;
        let mut outs = outs.into_iter();
        for st in states.iter_mut() {
            let chunks: Vec<ChunkQkv> = outs.by_ref().take(st.n).collect();
            if !st.prefix_chain.is_empty() {
                st.publish_chunks.push(chunks.clone());
            }
            st.chunks = Some(chunks);
            st.phase = Phase::IndexGen;
            st.metrics.t_qkv_us += dt;
            st.qkv_jobs += st.n;
        }
        Ok(())
    }

    /// Phase 2: SIGU sparse index generation.
    pub fn phase_index_gen(&mut self, st: &mut PrefillState) -> Result<()> {
        anyhow::ensure!(st.phase == Phase::IndexGen, "phase_index_gen in {:?}", st.phase);
        let t0 = Instant::now();
        // chunked: index only the active slice's query blocks over the
        // KV extent so far; monolithic: the novel suffix over the full
        // context (identical when neither chunked nor resumed)
        let (from, _) = st.slice_bounds();
        let extent = st.kv_extent();
        let indices = {
            let chunks =
                st.chunks.as_ref().ok_or_else(|| anyhow!("index_gen without qkv chunks"))?;
            self.run_sigu_layer(chunks, extent, from)?
        };
        st.metrics.t_sigu_us += t0.elapsed().as_micros() as f64;
        st.sigu_jobs += self.cfg.model.n_heads;
        if self.cfg.flex.is_some() {
            // solo K stream: one pass per kv head over this lane's blocks
            // (dense index generation streams nothing) — the same
            // IndexGenWalk pricing a fused phase attributes per lane
            let pricing = IndexGenWalk::new(
                self.cfg.model.n_kv_heads,
                self.cfg.model.group_size(),
                vec![st.n],
            )
            .price(k_block_bytes(&self.cfg.model));
            st.metrics.sigu_hbm_read_bytes += pricing.fused_bytes;
        }
        for idx in &indices {
            st.density_sum += idx.density();
            st.density_cnt += 1;
            if idx.pattern == HeadPattern::QueryAware {
                st.qa_heads += 1;
            }
        }
        st.patterns.push(indices.iter().map(|i| i.pattern).collect());
        st.indices = Some(indices);
        st.phase = Phase::Sau;
        Ok(())
    }

    /// Fused phase 2 for several sparse requests at the same layer (native
    /// SIGU path): the lanes share each kv head's K block stream — one
    /// [`scores::FusedHeadJob`] per query head scores every lane's Q-hat
    /// against a single pass over the merged (longest-lane) block extent,
    /// writing per-lane outputs independently, so each lane's index set is
    /// bit-identical to its solo phase (pinned by proptests in
    /// `flexprefill::scores` and `rust/tests/memory_spine.rs`). The fused
    /// K-stream bytes are priced through [`IndexGenWalk`] with
    /// lowest-live-lane attribution — the same spine
    /// [`crate::sim::sigu_group_us`] prices, so engine stats and the
    /// simulator agree exactly. Falls back to per-state stepping when the
    /// group is not fusable (dense lanes, resumed lanes, artifact SIGU).
    /// As with the other fused phases, wall-clock time is charged to every
    /// lane.
    pub fn phase_index_gen_batch(&mut self, states: &mut [PrefillState]) -> Result<()> {
        let fusable = states.len() > 1
            && self.cfg.native_sigu
            && self.cfg.flex.is_some()
            && states.iter().all(|s| s.phase == Phase::IndexGen && s.layer == states[0].layer)
            && states.iter().all(|s| s.resume_from == 0);
        if !fusable {
            for st in states.iter_mut() {
                self.phase_index_gen(st)?;
            }
            return Ok(());
        }
        let cfg = self.cfg.model.clone();
        let params = self.cfg.flex.expect("fusable implies sparse");
        let t0 = Instant::now();
        let lane_indices = {
            let chunk_lanes: Vec<&[ChunkQkv]> = states
                .iter()
                .map(|s| {
                    s.chunks.as_deref().ok_or_else(|| anyhow!("index_gen without qkv chunks"))
                })
                .collect::<Result<_>>()?;
            let ns: Vec<usize> = states.iter().map(|s| s.n).collect();
            let ctx = self.phase_ctx(Phase::IndexGen);
            fwd::sigu_indices_batch(&ctx, &cfg, &chunk_lanes, &ns, &params)
        };
        let dt = t0.elapsed().as_micros() as f64;
        let lane_blocks: Vec<usize> = states.iter().map(|s| s.n).collect();
        let pricing = IndexGenWalk::new(cfg.n_kv_heads, cfg.group_size(), lane_blocks)
            .price(k_block_bytes(&cfg));
        let width = states.len() as u64;
        for (lane, (st, indices)) in states.iter_mut().zip(lane_indices).enumerate() {
            st.metrics.t_sigu_us += dt;
            st.sigu_jobs += cfg.n_heads;
            st.metrics.sigu_hbm_read_bytes += pricing.lane_bytes[lane];
            st.metrics.sigu_hbm_saved_bytes += pricing.lane_saved[lane];
            st.metrics.sigu_fused_phases += 1;
            st.metrics.sigu_fused_width_sum += width;
            for idx in &indices {
                st.density_sum += idx.density();
                st.density_cnt += 1;
                if idx.pattern == HeadPattern::QueryAware {
                    st.qa_heads += 1;
                }
            }
            st.patterns.push(indices.iter().map(|i| i.pattern).collect());
            st.indices = Some(indices);
            st.phase = Phase::Sau;
        }
        Ok(())
    }

    /// Phase 3: block-major SAU over the wave schedule, with the
    /// deterministic cache-traffic walk.
    pub fn phase_sau(&mut self, st: &mut PrefillState) -> Result<()> {
        anyhow::ensure!(st.phase == Phase::Sau, "phase_sau in {:?}", st.phase);
        let t0 = Instant::now();
        let cfg = self.cfg.model.clone();
        let chunks = st.chunks.take().ok_or_else(|| anyhow!("sau without qkv chunks"))?;
        let indices = st.indices.take().ok_or_else(|| anyhow!("sau without indices"))?;
        let schedule = build_schedule(&indices, cfg.group_size(), self.cfg.wave_qblocks);
        st.metrics.jobs += schedule.total_jobs;
        // chunked slices attend over the KV extent retained so far; each
        // slice's walk starts cold (no seed_prefix) — earlier slices' KV
        // re-fetches are real traffic the chunked schedule pays, and the
        // pricing reflects it honestly
        let extent = st.kv_extent();
        let mut cache = self.new_layer_cache(extent, &schedule);
        if st.resume_from > 0 {
            // store-served prefix blocks arrive already resident, so reuse
            // shows up as priced cache hits on the walk below
            prefix::seed_prefix(&mut cache, schedule.n_kv_heads, st.resume_from);
        }
        let attn = self.run_sau_layer(&chunks, &schedule, &mut cache, extent)?;
        self.absorb_cache_stats(st, cache.stats(), schedule.total_jobs);
        st.metrics.t_sau_us += t0.elapsed().as_micros() as f64;
        st.index_sets.push(indices);
        if st.chunked() {
            // retain this layer's KV for the next token slice
            st.layer_kv[st.layer] = chunks;
        }
        st.attn = Some(attn);
        st.phase = Phase::FfnLogits;
        Ok(())
    }

    /// Fused phase 3 for co-resident requests (native SAU path): the
    /// lanes' wave accumulator states fan out together over one merged
    /// [`build_schedule_batch`] sweep, and cache traffic for the whole
    /// group runs as **one batched [`ScheduleWalk`]** over per-lane caches
    /// — each lane's hit/miss/bypass outcomes are identical to its solo
    /// walk (the spine's stats-identity contract, pinned by
    /// `rust/tests/memory_spine.rs`), so per-request stats stay
    /// deterministic. Lanes may sit at different layers — SAU only touches
    /// the lane's own chunk data.
    pub fn phase_sau_batch(&mut self, states: &mut [PrefillState]) -> Result<()> {
        let fusable = states.len() > 1
            && self.cfg.native_sau
            // chunked lanes size their cache to the slice's KV extent and
            // retain chunks across slices — solo-step them
            && states.iter().all(|s| s.phase == Phase::Sau && !s.chunked());
        if !fusable {
            for st in states.iter_mut() {
                self.phase_sau(st)?;
            }
            return Ok(());
        }
        let t0 = Instant::now();
        let cfg = self.cfg.model.clone();
        let mut schedules = Vec::with_capacity(states.len());
        let mut caches = Vec::with_capacity(states.len());
        for st in states.iter_mut() {
            let indices = st.indices.take().ok_or_else(|| anyhow!("sau without indices"))?;
            let schedule = build_schedule(&indices, cfg.group_size(), self.cfg.wave_qblocks);
            st.metrics.jobs += schedule.total_jobs;
            let mut cache = self.new_layer_cache(st.n, &schedule);
            if st.resume_from > 0 {
                prefix::seed_prefix(&mut cache, schedule.n_kv_heads, st.resume_from);
            }
            caches.push(cache);
            st.index_sets.push(indices);
            schedules.push(schedule);
        }
        let lane_refs: Vec<&Schedule> = schedules.iter().collect();
        let batch = build_schedule_batch(&lane_refs);
        ScheduleWalk::batched(&batch).drive(&mut caches);
        for ((st, cache), sch) in states.iter_mut().zip(&caches).zip(&schedules) {
            self.absorb_cache_stats(st, cache.stats(), sch.total_jobs);
        }
        let attns = {
            let chunk_lanes: Vec<&[ChunkQkv]> = states
                .iter()
                .map(|s| s.chunks.as_deref().expect("sau without qkv chunks"))
                .collect();
            let ctx = self.phase_ctx(Phase::Sau);
            fwd::sau_layer_batch(&ctx, &cfg, &chunk_lanes, &batch)
        };
        let dt = t0.elapsed().as_micros() as f64;
        for (st, attn) in states.iter_mut().zip(attns) {
            st.chunks = None;
            st.attn = Some(attn.into_iter().map(|m| m.data).collect());
            st.phase = Phase::FfnLogits;
            st.metrics.t_sau_us += dt;
        }
        Ok(())
    }

    /// Fused phase 4 for several requests at the same layer (native linear
    /// path): one pool fan-out over all (request, chunk) o_proj+FFN jobs,
    /// so the layer's tail weights stream through the cache once for the
    /// whole batch — completing the batch axis across the full layer body
    /// (QKV, SAU and now the FFN tail). Per-lane results are bit-identical
    /// to solo phases; lanes finishing their last layer run final norm +
    /// logits individually (per-request by definition). Falls back to
    /// per-state stepping when the group is not fusable. As with the QKV
    /// and SAU batches (PR 2 convention), the fused **wall-clock** time is
    /// charged to every lane's `t_ffn_us` — phase timings measure elapsed
    /// time a request spent in the phase, not an exclusive core share, so
    /// summing them across co-resident requests over-counts by design.
    pub fn phase_ffn_logits_batch(
        &mut self,
        states: &mut [PrefillState],
    ) -> Result<Vec<Option<PrefillRun>>> {
        let fusable = states.len() > 1
            && self.cfg.native_linear
            && states.iter().all(|s| s.phase == Phase::FfnLogits && s.layer == states[0].layer)
            && states.iter().all(|s| s.resume_from == 0 && !s.chunked());
        if !fusable {
            return states.iter_mut().map(|st| self.phase_ffn_logits(st)).collect();
        }
        let li = states[0].layer;
        let t0 = Instant::now();
        let attns: Vec<Vec<Vec<f32>>> = states
            .iter_mut()
            .map(|st| st.attn.take().ok_or_else(|| anyhow!("ffn without sau output")))
            .collect::<Result<_>>()?;
        let new_hiddens = {
            let attn_refs: Vec<&[Vec<f32>]> = attns.iter().map(|a| a.as_slice()).collect();
            let hidden_refs: Vec<&MatF32> = states.iter().map(|s| &s.hidden).collect();
            let ctx = self.phase_ctx(Phase::FfnLogits);
            fwd::ffn_tail_batch(&ctx, &self.weights, li, &attn_refs, &hidden_refs)
        };
        let dt = t0.elapsed().as_micros() as f64;
        let d = self.cfg.model.d_model;
        let n_layers = self.cfg.model.n_layers;
        let mut out = Vec::with_capacity(states.len());
        for (st, chunks) in states.iter_mut().zip(new_hiddens) {
            for (ci, x) in chunks.into_iter().enumerate() {
                st.hidden.data[ci * BLOCK * d..(ci + 1) * BLOCK * d].copy_from_slice(&x.data);
            }
            st.metrics.t_ffn_us += dt;
            st.ffn_jobs += st.n;
            st.layer += 1;
        }
        for st in states.iter_mut() {
            if st.layer < n_layers {
                st.phase = Phase::Qkv;
                out.push(None);
            } else {
                out.push(Some(self.finish(st)?));
            }
        }
        Ok(out)
    }

    /// Phase 4: o_proj + FFN tail; advances to the next layer, or — after
    /// the last layer — runs final norm + logits and finishes the request.
    pub fn phase_ffn_logits(&mut self, st: &mut PrefillState) -> Result<Option<PrefillRun>> {
        anyhow::ensure!(st.phase == Phase::FfnLogits, "phase_ffn_logits in {:?}", st.phase);
        let t0 = Instant::now();
        let attn = st.attn.take().ok_or_else(|| anyhow!("ffn without sau output"))?;
        let li = st.layer;
        let (from, to) = st.slice_bounds();
        // prefix chunks' hidden rows go stale after a skipped tail, but
        // nothing downstream reads them: QKV splices stored chunks for
        // covered blocks and `finish` reads only the last (novel) chunk
        self.run_tail_layer(li, &mut st.hidden, &attn, from, to)?;
        st.metrics.t_ffn_us += t0.elapsed().as_micros() as f64;
        st.ffn_jobs += to - from;
        st.layer += 1;
        if st.layer < self.cfg.model.n_layers {
            st.phase = Phase::Qkv;
            return Ok(None);
        }
        if st.chunked() && st.chunk_to < st.n {
            // token slice complete: rewind to layer 0 with the cursor
            // advanced — the outer loop of the chunked walk
            st.layer = 0;
            st.phase = Phase::Qkv;
            st.chunk_from = st.chunk_to;
            st.chunk_to = (st.chunk_to + st.chunk_blocks).min(st.n);
            return Ok(None);
        }
        self.finish(st).map(Some)
    }

    /// Final norm + LM head; seals the state and produces the run. A
    /// prefix-eligible request also publishes its full per-layer chunk set
    /// to the store here — every block, not just the blocks it reused, so
    /// any longer request sharing the token stream can resume deeper (each
    /// consumer caps coverage at its own `n - 1`).
    fn finish(&mut self, st: &mut PrefillState) -> Result<PrefillRun> {
        let cfg = self.cfg.model.clone();
        let d = cfg.d_model;
        let last: Vec<f32> = st.hidden.data[(st.s - BLOCK) * d..].to_vec();
        let logits = self.run_logits(&last)?;
        let last_row = &logits[(BLOCK - 1) * cfg.vocab..];
        let first_token = fwd::argmax_token(last_row);

        if !st.prefix_chain.is_empty() {
            if let Some(store) = &self.prefix {
                let layers = std::mem::take(&mut st.publish_chunks);
                let n_layers = layers.len();
                let mut per_block: Vec<Vec<ChunkQkv>> =
                    (0..st.n).map(|_| Vec::with_capacity(n_layers)).collect();
                for layer in layers {
                    for (b, chunk) in layer.into_iter().enumerate() {
                        per_block[b].push(chunk);
                    }
                }
                store.lock().unwrap().publish(&st.prefix_chain, &st.prefix_tokens, per_block);
            }
        }

        st.phase = Phase::Done;
        let mut metrics = std::mem::take(&mut st.metrics);
        metrics.ttft_us = st.t_start.elapsed().as_micros() as f64;
        // measured per-phase job cost (us/job) — what the server's EWMA
        // feeds back into adaptive lease-want sizing. Fused group phases
        // charge wall-clock time to every lane (PR 2 convention), so
        // under batching these are upper bounds — fine for a hint.
        let per_job = |us: f64, jobs: usize| if jobs > 0 { us / jobs as f64 } else { 0.0 };
        metrics.qkv_job_us = per_job(metrics.t_qkv_us, st.qkv_jobs);
        metrics.sigu_job_us = per_job(metrics.t_sigu_us, st.sigu_jobs);
        metrics.sau_job_us = per_job(metrics.t_sau_us, metrics.jobs);
        metrics.ffn_job_us = per_job(metrics.t_ffn_us, st.ffn_jobs);
        metrics.density =
            if st.density_cnt > 0 { st.density_sum / st.density_cnt as f64 } else { 1.0 };
        metrics.query_aware_frac =
            if st.density_cnt > 0 { st.qa_heads as f64 / st.density_cnt as f64 } else { 0.0 };
        metrics.cache_hit_rate =
            if st.cache_lookups > 0 { st.cache_hits as f64 / st.cache_lookups as f64 } else { 0.0 };

        let decode_inputs = st.capture.take();
        if let Some(cap) = &decode_inputs {
            debug_assert!(
                cap.iter().all(|m| m.rows == st.s),
                "decode capture must cover the full context per layer"
            );
        }
        // chunked runs retain per-layer KV between slices; free it now
        st.layer_kv = Vec::new();

        Ok(PrefillRun {
            first_token,
            logits_last: last_row.to_vec(),
            metrics,
            patterns: std::mem::take(&mut st.patterns),
            index_sets: std::mem::take(&mut st.index_sets),
            hidden_last_chunk: last,
            decode_inputs,
        })
    }

    // ------------------------------------------------------------------
    // decode steps (the serving scheduler's post-prefill work units)
    // ------------------------------------------------------------------

    /// Seed a decode unit from a finished prefill. Requires the prefill
    /// to have captured its per-layer inputs
    /// ([`PrefillArgs::capture_decode`]); the KV cache is re-derived from
    /// them through `Decoder::from_prefill_inputs`, mirroring prefill's
    /// per-BLOCK quantization exactly.
    pub fn decode_start(
        &self,
        request_id: u64,
        run: &PrefillRun,
        n_tokens: usize,
    ) -> Result<DecodeState> {
        let inputs = run.decode_inputs.as_ref().ok_or_else(|| {
            anyhow!("decode_start needs a capture-enabled prefill (PrefillArgs::capture_decode)")
        })?;
        let dec = Decoder::from_prefill_inputs_ctx(&self.weights, self.ctx.clone(), inputs);
        let (kv, pos) = dec.into_parts();
        Ok(DecodeState {
            request_id,
            kv,
            pos,
            last: run.first_token,
            tokens: Vec::new(),
            remaining: n_tokens,
            step_us: Vec::new(),
            hbm_read_bytes: 0,
            hbm_write_bytes: 0,
        })
    }

    /// One decode step: reattach the parked KV, emit one token, park
    /// again. KV gather/append traffic is priced through the canonical
    /// [`DecodeStepWalk`] at the pre-step position.
    pub fn decode_step(&mut self, st: &mut DecodeState) -> Result<u8> {
        anyhow::ensure!(st.remaining > 0, "decode_step on a finished request");
        let t0 = Instant::now();
        let pre_pos = st.pos;
        let kv = std::mem::take(&mut st.kv);
        let mut dec = Decoder::from_parts(&self.weights, self.ctx.clone(), kv, pre_pos);
        let tok = dec.step(st.last);
        let (kv, pos) = dec.into_parts();
        st.kv = kv;
        st.pos = pos;
        st.last = tok;
        st.tokens.push(tok);
        st.remaining -= 1;
        let t = DecodeStepWalk::new(&self.cfg.model).price(pre_pos);
        st.hbm_read_bytes += t.read_bytes;
        st.hbm_write_bytes += t.write_bytes;
        st.step_us.push(t0.elapsed().as_micros() as f64);
        Ok(tok)
    }

    /// Fused decode step over co-resident requests: every lane's
    /// matvec-bound layer walk runs as one pool fan-out, sharing the
    /// weight stream across the batch axis (the decode analogue of the
    /// fused prefill phases). Each lane steps on its own single-threaded
    /// child context — decode results are backend- and thread-count
    /// invariant (`decode_is_deterministic`), so fused lanes are
    /// bit-identical to solo stepping. As with the fused prefill phases,
    /// the fused wall-clock time is charged to every lane.
    pub fn decode_step_group(&mut self, states: &mut [&mut DecodeState]) -> Result<Vec<u8>> {
        if states.len() == 1 {
            let tok = self.decode_step(states[0])?;
            return Ok(vec![tok]);
        }
        for st in states.iter() {
            anyhow::ensure!(st.remaining > 0, "decode_step on a finished request");
        }
        let t0 = Instant::now();
        let walk = DecodeStepWalk::new(&self.cfg.model);
        let backend = self.ctx.backend;
        let tune = self.ctx.tune.clone();
        let weights: &ModelWeights = &self.weights;
        let lanes: Vec<Mutex<Option<(Vec<DecodeKv>, usize, u8)>>> = states
            .iter_mut()
            .map(|st| Mutex::new(Some((std::mem::take(&mut st.kv), st.pos, st.last))))
            .collect();
        let outs = self.ctx.pool.map(lanes.len(), |i| {
            let (kv, pos, last) = lanes[i].lock().unwrap().take().expect("one take per lane");
            let ctx =
                KernelCtx::single_threaded().with_backend(backend).with_tune(tune.clone());
            let mut dec = Decoder::from_parts(weights, ctx, kv, pos);
            let tok = dec.step(last);
            let (kv, pos) = dec.into_parts();
            (kv, pos, tok)
        });
        let dt = t0.elapsed().as_micros() as f64;
        let mut toks = Vec::with_capacity(states.len());
        for (st, (kv, pos, tok)) in states.iter_mut().zip(outs) {
            let pre_pos = st.pos;
            st.kv = kv;
            st.pos = pos;
            st.last = tok;
            st.tokens.push(tok);
            st.remaining -= 1;
            let t = walk.price(pre_pos);
            st.hbm_read_bytes += t.read_bytes;
            st.hbm_write_bytes += t.write_bytes;
            st.step_us.push(dt);
            toks.push(tok);
        }
        Ok(toks)
    }

    /// Fold one layer's cache outcomes into the request's running hit-rate
    /// numerators and memory attribution — the same accounting the cycle
    /// simulator prices over the shared schedule walk: one KV-block fetch
    /// per miss with a cache, one on-demand gather per *job* on the
    /// cacheless ablation (`schedule_jobs`), plus bypass counts.
    fn absorb_cache_stats(&self, st: &mut PrefillState, cs: CacheStats, schedule_jobs: usize) {
        st.cache_hits += cs.hits();
        st.cache_lookups += cs.lookups;
        st.metrics.cache_bypasses += cs.bypasses;
        let fetches =
            if self.cfg.cache_blocks == 0 { schedule_jobs as u64 } else { cs.misses };
        st.metrics.hbm_read_bytes += fetches * self.cfg.model.kv_block_bytes() as u64;
    }

    /// Per-layer liveness cache seeded with the schedule's use counters —
    /// through the shared [`crate::kvcache::layer_cache`] derivation, so
    /// the engine and the simulator cannot drift apart on cache sizing.
    fn new_layer_cache(&self, n: usize, schedule: &Schedule) -> LivenessCache {
        crate::kvcache::layer_cache(
            self.cfg.cache_blocks,
            self.cfg.hot_fraction,
            self.cfg.t_hot_frac,
            n,
            self.cfg.model.group_size(),
            schedule.uses.iter().copied(),
        )
    }

    // ------------------------------------------------------------------
    // phase implementations
    // ------------------------------------------------------------------

    /// QKV for chunks `from..n` only — `from > 0` when a store-served
    /// prefix already covers the leading blocks. RoPE positions and
    /// per-chunk quant scales depend only on the chunk's own content and
    /// absolute offset, so computing a suffix in isolation is bit-identical
    /// to the same chunks of a full-range run.
    fn run_qkv_layer(
        &mut self,
        li: usize,
        hidden: &MatF32,
        from: usize,
        n: usize,
    ) -> Result<Vec<ChunkQkv>> {
        if self.cfg.native_linear {
            let weights: &ModelWeights = &self.weights;
            let ctx = self.phase_ctx(Phase::Qkv);
            let ctx = &ctx;
            return Ok(ctx.pool.map(n - from, |i| {
                let ci = from + i;
                let x = hidden.slice_rows(ci * BLOCK, (ci + 1) * BLOCK);
                fwd::qkv_chunk(ctx, weights, li, &x, (ci * BLOCK) as i32)
            }));
        }
        let cfg = self.cfg.model.clone();
        let (d, dh, hq, hk) = (cfg.d_model, cfg.d_head, cfg.n_heads, cfg.n_kv_heads);
        // artifact outputs are head-major [heads, B, dh]; split per head
        let split = |flat: Vec<i8>| -> Vec<MatI8> {
            flat.chunks(BLOCK * dh).map(|c| MatI8::from_vec(BLOCK, dh, c.to_vec())).collect()
        };
        let mut chunks = Vec::with_capacity(n - from);
        for ci in from..n {
            let x = &hidden.data[ci * BLOCK * d..(ci + 1) * BLOCK * d];
            let lw = &self.weights.layers[li];
            let exe = self
                .rt
                .as_mut()
                .ok_or_else(|| anyhow!("artifact backend requested but the engine is native-only"))?
                .get(cfg.name, "qkv_chunk")?;
            let out = exe.run(&[
                Arg::F32(x, &[BLOCK, d]),
                Arg::F32(&lw.g_attn, &[d]),
                Arg::I8(&lw.wq.q.data, &[d, hq * dh]),
                Arg::ScalarF32(lw.wq.scale),
                Arg::I8(&lw.wk.q.data, &[d, hk * dh]),
                Arg::ScalarF32(lw.wk.scale),
                Arg::I8(&lw.wv.q.data, &[d, hk * dh]),
                Arg::ScalarF32(lw.wv.scale),
                Arg::ScalarI32((ci * BLOCK) as i32),
            ])?;
            chunks.push(ChunkQkv {
                q: split(literal_i8(&out[0])?),
                qs: out[1].get_first_element::<f32>()?,
                k: split(literal_i8(&out[2])?),
                ks: out[3].get_first_element::<f32>()?,
                v: split(literal_i8(&out[4])?),
                vs: out[5].get_first_element::<f32>()?,
                qpool: MatF32::from_vec(hq, dh, literal_f32(&out[6])?),
                kpool: MatF32::from_vec(hk, dh, literal_f32(&out[7])?),
            });
        }
        Ok(chunks)
    }

    fn run_sigu_layer(
        &mut self,
        chunks: &[ChunkQkv],
        n: usize,
        resume_from: usize,
    ) -> Result<Vec<HeadIndex>> {
        let cfg = self.cfg.model.clone();
        let dh = cfg.d_head;
        let params = match &self.cfg.flex {
            Some(p) => *p,
            // dense causal attention is prefix-closed, so a resumed request
            // only re-attends from its first novel q-block; with
            // `resume_from == 0` this is exactly `dense_indices`
            None => return Ok(fwd::suffix_dense_indices(cfg.n_heads, n, resume_from)),
        };
        if self.cfg.native_sigu {
            // the reference's parallel per-head jobs, over the same
            // chunks; IndexGen leases only a small slot share — adaptive
            // (EWMA of measured job cost) when hints are installed, else
            // the static index_gen_want split
            let ctx = self.phase_ctx(Phase::IndexGen);
            return Ok(fwd::sigu_indices(&ctx, &cfg, chunks, n, &params));
        }
        let mut out = Vec::with_capacity(cfg.n_heads);
        for h in 0..cfg.n_heads {
            let g = h / cfg.group_size();
            let (vertical, slash, a_hat) = self.sigu_via_artifacts(chunks, h, g, n)?;
            // pooled estimate + decision inputs
            let kpool = MatF32::from_fn(n, dh, |b, c| chunks[b].kpool.at(g, c));
            let qpool_all = MatF32::from_fn(n, dh, |b, c| chunks[b].qpool.at(h, c));
            let qpool_hat: Vec<f32> = qpool_all.row(n - 1).to_vec();
            let a_bar = scores::pooled_estimate(&qpool_hat, &kpool);
            let stats = HeadStats { vertical, slash, a_bar, a_hat, qpool_all, kpool };
            out.push(generate_head_index(&stats, &params));
        }
        Ok(out)
    }

    fn sigu_via_artifacts(
        &mut self,
        chunks: &[ChunkQkv],
        h: usize,
        g: usize,
        n: usize,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let cfg = self.cfg.model.clone();
        let dh = cfg.d_head;
        let qs = chunks[n - 1].qs;
        let qhat = chunks[n - 1].q[h].data.clone();
        let mut m = vec![-1e30f32; BLOCK];
        let mut l = vec![0.0f32; BLOCK];
        for b in 0..n {
            let exe = self.runtime()?.get(cfg.name, "index_phase_a")?;
            let out = exe.run(&[
                Arg::I8(&qhat, &[BLOCK, dh]),
                Arg::ScalarF32(qs),
                Arg::I8(&chunks[b].k[g].data, &[BLOCK, dh]),
                Arg::ScalarF32(chunks[b].ks),
                Arg::F32(&m, &[BLOCK]),
                Arg::F32(&l, &[BLOCK]),
            ])?;
            m = literal_f32(&out[0])?;
            l = literal_f32(&out[1])?;
        }
        let mut vertical = vec![0.0f32; n];
        let mut slash = vec![0.0f32; n];
        for b in 0..n {
            let exe = self.runtime()?.get(cfg.name, "index_phase_b")?;
            let out = exe.run(&[
                Arg::I8(&qhat, &[BLOCK, dh]),
                Arg::ScalarF32(qs),
                Arg::I8(&chunks[b].k[g].data, &[BLOCK, dh]),
                Arg::ScalarF32(chunks[b].ks),
                Arg::F32(&m, &[BLOCK]),
                Arg::F32(&l, &[BLOCK]),
            ])?;
            let stats = literal_f32(&out[0])?;
            vertical[b] = stats[0];
            slash[n - 1 - b] += stats[1];
            if b + 2 <= n {
                slash[n - 2 - b] += stats[2];
            }
        }
        let a_hat: Vec<f32> = vertical.iter().map(|v| v / BLOCK as f32).collect();
        Ok((vertical, slash, a_hat))
    }

    /// Block-major SAU over the wave schedule; returns per-chunk attention
    /// outputs [n][B * H*dh].
    ///
    /// Cache traffic is driven through the canonical
    /// [`ScheduleWalk`] spine — the same walk the cycle simulator prices —
    /// so cache statistics are identical for every backend, thread count,
    /// and batching decision; the arithmetic then runs natively in
    /// parallel or through batched artifact calls.
    fn run_sau_layer(
        &mut self,
        chunks: &[ChunkQkv],
        schedule: &Schedule,
        cache: &mut LivenessCache,
        n: usize,
    ) -> Result<Vec<Vec<f32>>> {
        ScheduleWalk::solo(schedule).drive(std::slice::from_mut(cache));
        if self.cfg.native_sau {
            // the reference's parallel wave execution over this engine's
            // schedule (waves sized by cfg.wave_qblocks)
            let ctx = self.phase_ctx(Phase::Sau);
            let attn = fwd::sau_layer(&ctx, &self.cfg.model, chunks, schedule, n);
            Ok(attn.into_iter().map(|m| m.data).collect())
        } else {
            self.sau_pjrt(chunks, schedule, n)
        }
    }

    /// PJRT SAU: batched artifact calls over the block-major job lists.
    fn sau_pjrt(
        &mut self,
        chunks: &[ChunkQkv],
        schedule: &Schedule,
        n: usize,
    ) -> Result<Vec<Vec<f32>>> {
        let cfg = self.cfg.model.clone();
        let (dh, hq) = (cfg.d_head, cfg.n_heads);
        let j_max = self.sau_batch();
        let mut attn: Vec<Vec<f32>> = (0..n).map(|_| vec![0.0f32; BLOCK * hq * dh]).collect();

        for wave in &schedule.waves {
            let wq = (wave.q_end - wave.q_start) as usize;
            // keyed accumulator banks for this wave: (h, q_local)
            let nstates = hq * wq;
            let mut m = vec![-1e30f32; nstates * BLOCK];
            let mut l = vec![0.0f32; nstates * BLOCK];
            let mut acc = vec![0.0f32; nstates * BLOCK * dh];

            for bj in &wave.blocks {
                let g = bj.kv_head as usize;
                let b = bj.block as usize;
                let kblk: &[i8] = &chunks[b].k[g].data;
                let vblk: &[i8] = &chunks[b].v[g].data;
                for group in bj.jobs.chunks(j_max) {
                    self.sau_batch_call(chunks, wave.q_start, wq, group, b, kblk, vblk,
                                        &mut m, &mut l, &mut acc, j_max)?;
                }
            }

            // finalize wave states into the attention output buffer
            for h in 0..hq {
                for ql in 0..wq {
                    let st = h * wq + ql;
                    let qb = wave.q_start as usize + ql;
                    let accm = MatF32::from_vec(
                        BLOCK,
                        dh,
                        acc[st * BLOCK * dh..(st + 1) * BLOCK * dh].to_vec(),
                    );
                    let out = attn_finalize(&l[st * BLOCK..(st + 1) * BLOCK], &accm);
                    for r in 0..BLOCK {
                        attn[qb][r * hq * dh + h * dh..r * hq * dh + (h + 1) * dh]
                            .copy_from_slice(out.row(r));
                    }
                }
            }
        }
        Ok(attn)
    }

    /// One padded `attn_block_batch` artifact call over <= J jobs.
    #[allow(clippy::too_many_arguments)]
    fn sau_batch_call(
        &mut self,
        chunks: &[ChunkQkv],
        q_start: u32,
        wq: usize,
        group: &[crate::coordinator::joblist::Job],
        b: usize,
        kblk: &[i8],
        vblk: &[i8],
        m: &mut [f32],
        l: &mut [f32],
        acc: &mut [f32],
        j_max: usize,
    ) -> Result<()> {
        let cfg = self.cfg.model.clone();
        let dh = cfg.d_head;
        let jn = group.len();
        let mut qb_buf = vec![0i8; j_max * BLOCK * dh];
        let mut kb_buf = vec![0i8; j_max * BLOCK * dh];
        let mut vb_buf = vec![0i8; j_max * BLOCK * dh];
        let mut qs_buf = vec![0f32; j_max];
        let mut ks_buf = vec![0f32; j_max];
        let mut vs_buf = vec![0f32; j_max];
        let mut m_buf = vec![-1e30f32; j_max * BLOCK];
        let mut l_buf = vec![0f32; j_max * BLOCK];
        let mut acc_buf = vec![0f32; j_max * BLOCK * dh];
        let mut diag_buf = vec![0f32; j_max];
        for (j, job) in group.iter().enumerate() {
            let st = job.head as usize * wq + (job.qblock - q_start) as usize;
            qb_buf[j * BLOCK * dh..(j + 1) * BLOCK * dh]
                .copy_from_slice(&chunks[job.qblock as usize].q[job.head as usize].data);
            kb_buf[j * BLOCK * dh..(j + 1) * BLOCK * dh].copy_from_slice(kblk);
            vb_buf[j * BLOCK * dh..(j + 1) * BLOCK * dh].copy_from_slice(vblk);
            qs_buf[j] = chunks[job.qblock as usize].qs;
            ks_buf[j] = chunks[b].ks;
            vs_buf[j] = chunks[b].vs;
            m_buf[j * BLOCK..(j + 1) * BLOCK].copy_from_slice(&m[st * BLOCK..(st + 1) * BLOCK]);
            l_buf[j * BLOCK..(j + 1) * BLOCK].copy_from_slice(&l[st * BLOCK..(st + 1) * BLOCK]);
            acc_buf[j * BLOCK * dh..(j + 1) * BLOCK * dh]
                .copy_from_slice(&acc[st * BLOCK * dh..(st + 1) * BLOCK * dh]);
            diag_buf[j] = if b == job.qblock as usize { 1.0 } else { 0.0 };
        }
        let exe = self.runtime()?.get(cfg.name, "attn_block_batch")?;
        let out = exe.run(&[
            Arg::I8(&qb_buf, &[j_max, BLOCK, dh]),
            Arg::F32(&qs_buf, &[j_max]),
            Arg::I8(&kb_buf, &[j_max, BLOCK, dh]),
            Arg::F32(&ks_buf, &[j_max]),
            Arg::I8(&vb_buf, &[j_max, BLOCK, dh]),
            Arg::F32(&vs_buf, &[j_max]),
            Arg::F32(&m_buf, &[j_max, BLOCK]),
            Arg::F32(&l_buf, &[j_max, BLOCK]),
            Arg::F32(&acc_buf, &[j_max, BLOCK, dh]),
            Arg::F32(&diag_buf, &[j_max]),
        ])?;
        let m_out = literal_f32(&out[0])?;
        let l_out = literal_f32(&out[1])?;
        let acc_out = literal_f32(&out[2])?;
        for (j, job) in group.iter().enumerate().take(jn) {
            let st = job.head as usize * wq + (job.qblock - q_start) as usize;
            m[st * BLOCK..(st + 1) * BLOCK].copy_from_slice(&m_out[j * BLOCK..(j + 1) * BLOCK]);
            l[st * BLOCK..(st + 1) * BLOCK].copy_from_slice(&l_out[j * BLOCK..(j + 1) * BLOCK]);
            acc[st * BLOCK * dh..(st + 1) * BLOCK * dh]
                .copy_from_slice(&acc_out[j * BLOCK * dh..(j + 1) * BLOCK * dh]);
        }
        Ok(())
    }

    /// Phase 4 (o_proj + residual + FFN + residual) for chunks `from..n` —
    /// `from > 0` when a store-served prefix made the leading chunks'
    /// hidden state irrelevant (their KV is spliced in at QKV instead).
    fn run_tail_layer(
        &mut self,
        li: usize,
        hidden: &mut MatF32,
        attn: &[Vec<f32>],
        from: usize,
        n: usize,
    ) -> Result<()> {
        let cfg = self.cfg.model.clone();
        let (d, dh, hq) = (cfg.d_model, cfg.d_head, cfg.n_heads);
        if self.cfg.native_linear {
            let weights: &ModelWeights = &self.weights;
            let ctx = self.phase_ctx(Phase::FfnLogits);
            let ctx = &ctx;
            let hidden_ref = &*hidden;
            let new_chunks: Vec<MatF32> = ctx.pool.map(n - from, |i| {
                let ci = from + i;
                let a = MatF32 {
                    rows: BLOCK,
                    cols: hq * dh,
                    data: attn[ci].clone(),
                };
                let x = hidden_ref.slice_rows(ci * BLOCK, (ci + 1) * BLOCK);
                fwd::oproj_ffn_chunk(ctx, weights, li, &a, &x)
            });
            for (i, x) in new_chunks.into_iter().enumerate() {
                let ci = from + i;
                hidden.data[ci * BLOCK * d..(ci + 1) * BLOCK * d].copy_from_slice(&x.data);
            }
            return Ok(());
        }
        for ci in from..n {
            let resid: Vec<f32> = hidden.data[ci * BLOCK * d..(ci + 1) * BLOCK * d].to_vec();
            let lw = &self.weights.layers[li];
            let exe = self
                .rt
                .as_mut()
                .ok_or_else(|| anyhow!("artifact backend requested but the engine is native-only"))?
                .get(cfg.name, "o_proj_chunk")?;
            let out = exe.run(&[
                Arg::F32(&attn[ci], &[BLOCK, hq * dh]),
                Arg::I8(&lw.wo.q.data, &[hq * dh, d]),
                Arg::ScalarF32(lw.wo.scale),
                Arg::F32(&resid, &[BLOCK, d]),
            ])?;
            let x = literal_f32(&out[0])?;
            let exe = self
                .rt
                .as_mut()
                .ok_or_else(|| anyhow!("artifact backend requested but the engine is native-only"))?
                .get(cfg.name, "ffn_chunk")?;
            let out = exe.run(&[
                Arg::F32(&x, &[BLOCK, d]),
                Arg::F32(&lw.g_ffn, &[d]),
                Arg::I8(&lw.wg.q.data, &[d, cfg.d_ffn]),
                Arg::ScalarF32(lw.wg.scale),
                Arg::I8(&lw.wu.q.data, &[d, cfg.d_ffn]),
                Arg::ScalarF32(lw.wu.scale),
                Arg::I8(&lw.wd.q.data, &[cfg.d_ffn, d]),
                Arg::ScalarF32(lw.wd.scale),
            ])?;
            let x = literal_f32(&out[0])?;
            hidden.data[ci * BLOCK * d..(ci + 1) * BLOCK * d].copy_from_slice(&x);
        }
        Ok(())
    }

    /// Final norm + LM head over the last chunk.
    fn run_logits(&mut self, last: &[f32]) -> Result<Vec<f32>> {
        let cfg = self.cfg.model.clone();
        let d = cfg.d_model;
        if self.cfg.native_linear {
            let last_m = MatF32 { rows: BLOCK, cols: d, data: last.to_vec() };
            return Ok(fwd::logits_last_chunk(&self.ctx, self.weights.as_ref(), &last_m).data);
        }
        let weights = &self.weights;
        let exe = self
            .rt
            .as_mut()
            .ok_or_else(|| anyhow!("artifact backend requested but the engine is native-only"))?
            .get(cfg.name, "logits_chunk")?;
        let out = exe.run(&[
            Arg::F32(last, &[BLOCK, d]),
            Arg::F32(&weights.g_final, &[d]),
            Arg::I8(&weights.lm_head.q.data, &[d, cfg.vocab]),
            Arg::ScalarF32(weights.lm_head.scale),
        ])?;
        literal_f32(&out[0])
    }
}

/// IndexGen runs a handful of cheap per-head jobs; under a shared serving
/// budget it should not hoard slots that co-resident SAU/QKV fan-outs can
/// use. Lease-want hint: a quarter of the context's threads, at least 2
/// (ROADMAP serving follow-on (d)). The wide phases keep the uniform
/// `min(threads, n_jobs)` want.
fn index_gen_want(threads: usize) -> usize {
    (threads / 4).max(2).min(threads.max(1))
}
