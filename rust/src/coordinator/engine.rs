//! The prefill engine: chunked execution of the full pipeline (paper
//! Fig. 2) — KV generation -> SIGU -> block-major SAU with the liveness
//! cache -> FFN -> first token.
//!
//! Two backends exist for every matmul-heavy stage:
//!
//!  * **PJRT artifacts** (`pjrt` feature + `make artifacts`): the AOT
//!    HLO entry points execute on the CPU client (the "MPU").
//!  * **native tiled kernels**: the bit-compatible Rust mirror built on
//!    `tensor::tile` + the shared worker pool. Per-phase switches
//!    (`native_sigu`, `native_sau`, `native_linear`) choose per stage;
//!    with all three on, the engine needs no artifacts at all
//!    ([`Engine::new_native`]) and fans its work over a [`KernelCtx`]:
//!    chunks (QKV/FFN), heads (SIGU), and the wave's (head, query-block)
//!    accumulator states (SAU) run as independent pool jobs, so results
//!    are bit-identical for every `FASTP_THREADS` value.
//!
//! Decision logic, coverage selection, job-list bucketization and cache
//! policy always run natively (the paper's FSM/SFU/comparator logic); the
//! cache-traffic walk stays sequential in schedule order so cache
//! statistics are deterministic and backend-independent.

use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::config::{FlexParams, ModelConfig, BLOCK};
use crate::coordinator::joblist::{build_schedule, cache_key, Schedule, DEFAULT_WAVE_QBLOCKS};
use crate::flexprefill::{generate_head_index, scores, HeadIndex, HeadPattern, HeadStats};
use crate::kvcache::{Access, LivenessCache};
use crate::metrics::PrefillMetrics;
use crate::model::forward::{self as fwd, attn_finalize, ChunkQkv};
use crate::model::ModelWeights;
use crate::runtime::{literal_f32, literal_i8, Arg, Runtime};
use crate::tensor::tile::KernelCtx;
use crate::tensor::{MatF32, MatI8};

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub model: ModelConfig,
    /// None => dense causal attention (baseline).
    pub flex: Option<FlexParams>,
    pub weight_seed: u64,
    /// Live query blocks per SAU wave (0 = all — unbounded accumulator).
    pub wave_qblocks: usize,
    /// KV cache capacity in blocks (0 = cacheless ablation).
    pub cache_blocks: usize,
    pub hot_fraction: f64,
    /// t_hot as a fraction of per-key maximum consumers.
    pub t_hot_frac: f64,
    /// Compute SIGU statistics natively instead of via artifacts.
    pub native_sigu: bool,
    /// Compute SAU attention natively instead of via artifacts.
    pub native_sau: bool,
    /// Compute QKV, o_proj+FFN and logits natively (tiled kernels)
    /// instead of via artifacts. With `native_sigu` and `native_sau` this
    /// makes the engine artifact-free.
    pub native_linear: bool,
    /// Worker threads for the kernel context (0 = `FASTP_THREADS` env,
    /// default available parallelism).
    pub threads: usize,
}

impl EngineConfig {
    pub fn new(model: ModelConfig) -> Self {
        EngineConfig {
            model,
            flex: Some(FlexParams::default()),
            weight_seed: 0xFA57,
            wave_qblocks: DEFAULT_WAVE_QBLOCKS,
            cache_blocks: 1024,
            hot_fraction: 0.5,
            t_hot_frac: 0.5,
            native_sigu: true,
            native_sau: false,
            native_linear: false,
            threads: 0,
        }
    }

    /// Fully-native config: every stage through the tiled kernel layer,
    /// no artifacts required.
    pub fn new_native(model: ModelConfig) -> Self {
        let mut cfg = Self::new(model);
        cfg.native_sigu = true;
        cfg.native_sau = true;
        cfg.native_linear = true;
        cfg
    }

    /// True when no stage needs the PJRT artifacts.
    pub fn fully_native(&self) -> bool {
        self.native_sigu && self.native_sau && self.native_linear
    }

    fn kernel_ctx(&self) -> KernelCtx {
        if self.threads > 0 {
            KernelCtx::with_threads(self.threads)
        } else {
            KernelCtx::from_env()
        }
    }
}

/// Result of one prefill run.
#[derive(Clone, Debug)]
pub struct PrefillRun {
    pub first_token: u8,
    pub logits_last: Vec<f32>,
    pub metrics: PrefillMetrics,
    pub patterns: Vec<Vec<HeadPattern>>,
    /// Per-layer per-head index sets (feed the simulator / GPU model).
    pub index_sets: Vec<Vec<HeadIndex>>,
    /// Final-layer hidden state of the last chunk (validation hook).
    pub hidden_last_chunk: Vec<f32>,
}

/// The prefill engine (one optional PJRT runtime + one model instance +
/// one kernel context).
pub struct Engine {
    rt: Option<Runtime>,
    pub ctx: KernelCtx,
    pub cfg: EngineConfig,
    pub weights: ModelWeights,
}

impl Engine {
    /// Build an engine. Fully-native configs skip the artifacts entirely;
    /// anything else loads + compiles the artifact entry points (which
    /// fails without the `pjrt` feature or without `make artifacts`).
    pub fn new(artifact_dir: impl AsRef<std::path::Path>, cfg: EngineConfig) -> Result<Engine> {
        let rt = if cfg.fully_native() {
            None
        } else {
            let mut rt = Runtime::load(artifact_dir)?;
            rt.manifest.validate_config(&cfg.model).context("manifest/config check")?;
            rt.warmup(cfg.model.name)?;
            Some(rt)
        };
        let weights = ModelWeights::generate(&cfg.model, cfg.weight_seed);
        let ctx = cfg.kernel_ctx();
        Ok(Engine { rt, ctx, cfg, weights })
    }

    /// Build an artifact-free engine on the tiled native kernels.
    pub fn new_native(model_cfg: EngineConfig) -> Result<Engine> {
        let mut cfg = model_cfg;
        cfg.native_sigu = true;
        cfg.native_sau = true;
        cfg.native_linear = true;
        let weights = ModelWeights::generate(&cfg.model, cfg.weight_seed);
        let ctx = cfg.kernel_ctx();
        Ok(Engine { rt: None, ctx, cfg, weights })
    }

    /// Backend description (for banners / examples).
    pub fn platform(&self) -> String {
        match &self.rt {
            Some(rt) => rt.platform(),
            None => format!("native tiled kernels ({} threads)", self.ctx.threads()),
        }
    }

    /// Per-executable perf counters (empty in native mode).
    pub fn exec_stats(&self) -> Vec<(String, u64, f64)> {
        self.rt.as_ref().map(|rt| rt.exec_stats()).unwrap_or_default()
    }

    fn runtime(&mut self) -> Result<&mut Runtime> {
        self.rt.as_mut().ok_or_else(|| {
            anyhow!("artifact backend requested but the engine was built native-only")
        })
    }

    fn sau_batch(&self) -> usize {
        self.rt
            .as_ref()
            .map(|rt| rt.manifest.configs[self.cfg.model.name].sau_batch.max(1))
            .unwrap_or(1)
    }

    /// Run the full prefill for a byte-token context. Context length must be
    /// a multiple of BLOCK.
    pub fn prefill(&mut self, request_id: u64, tokens: &[u8]) -> Result<PrefillRun> {
        let cfg = self.cfg.model.clone();
        let s = tokens.len();
        anyhow::ensure!(s > 0 && s % BLOCK == 0, "context must be a positive multiple of {BLOCK}");
        let n = s / BLOCK;
        let d = cfg.d_model;
        let t_start = Instant::now();
        let mut metrics = PrefillMetrics {
            request_id,
            context_tokens: s,
            ..Default::default()
        };

        let mut hidden = self.weights.embed_tokens(tokens);
        let mut patterns = Vec::new();
        let mut index_sets: Vec<Vec<HeadIndex>> = Vec::new();
        let mut density_sum = 0.0;
        let mut density_cnt = 0usize;
        let mut qa_heads = 0usize;
        let mut cache_hits = 0u64;
        let mut cache_lookups = 0u64;

        for li in 0..cfg.n_layers {
            // ---------------- phase 1: chunked KV generation ----------------
            let t0 = Instant::now();
            let chunks = self.run_qkv_layer(li, &hidden, n)?;
            metrics.t_qkv_us += t0.elapsed().as_micros() as f64;

            // ---------------- phase 2: SIGU ----------------
            let t0 = Instant::now();
            let indices = self.run_sigu_layer(&chunks, n)?;
            metrics.t_sigu_us += t0.elapsed().as_micros() as f64;
            for idx in &indices {
                density_sum += idx.density();
                density_cnt += 1;
                if idx.pattern == HeadPattern::QueryAware {
                    qa_heads += 1;
                }
            }
            patterns.push(indices.iter().map(|i| i.pattern).collect());

            // ---------------- phase 3: SAU (block-major, cached) ------------
            let t0 = Instant::now();
            let schedule = build_schedule(&indices, cfg.group_size(), self.cfg.wave_qblocks);
            metrics.jobs += schedule.total_jobs;
            let t_hot = (self.cfg.t_hot_frac * (n * cfg.group_size()) as f64) as u32;
            let mut cache = if self.cfg.cache_blocks > 0 {
                LivenessCache::new(self.cfg.cache_blocks, self.cfg.hot_fraction, t_hot)
            } else {
                LivenessCache::disabled()
            };
            cache.init_uses(schedule.uses.iter().copied());
            let attn = self.run_sau_layer(&chunks, &schedule, &mut cache, n)?;
            let cs = cache.stats();
            cache_hits += cs.hits();
            cache_lookups += cs.lookups;
            metrics.t_sau_us += t0.elapsed().as_micros() as f64;
            index_sets.push(indices);

            // ---------------- phase 4: o_proj + FFN ----------------
            let t0 = Instant::now();
            self.run_tail_layer(li, &mut hidden, &attn, n)?;
            metrics.t_ffn_us += t0.elapsed().as_micros() as f64;
        }

        // ---------------- first token ----------------
        let last: Vec<f32> = hidden.data[(s - BLOCK) * d..].to_vec();
        let logits = self.run_logits(&last)?;
        let last_row = &logits[(BLOCK - 1) * cfg.vocab..];
        let first_token = fwd::argmax_token(last_row);

        metrics.ttft_us = t_start.elapsed().as_micros() as f64;
        metrics.density = if density_cnt > 0 { density_sum / density_cnt as f64 } else { 1.0 };
        metrics.query_aware_frac =
            if density_cnt > 0 { qa_heads as f64 / density_cnt as f64 } else { 0.0 };
        metrics.cache_hit_rate =
            if cache_lookups > 0 { cache_hits as f64 / cache_lookups as f64 } else { 0.0 };

        Ok(PrefillRun {
            first_token,
            logits_last: last_row.to_vec(),
            metrics,
            patterns,
            index_sets,
            hidden_last_chunk: last,
        })
    }

    // ------------------------------------------------------------------
    // phase implementations
    // ------------------------------------------------------------------

    fn run_qkv_layer(&mut self, li: usize, hidden: &MatF32, n: usize) -> Result<Vec<ChunkQkv>> {
        if self.cfg.native_linear {
            let weights = &self.weights;
            let ctx = &self.ctx;
            return Ok(ctx.pool.map(n, |ci| {
                let x = hidden.slice_rows(ci * BLOCK, (ci + 1) * BLOCK);
                fwd::qkv_chunk(ctx, weights, li, &x, (ci * BLOCK) as i32)
            }));
        }
        let cfg = self.cfg.model.clone();
        let (d, dh, hq, hk) = (cfg.d_model, cfg.d_head, cfg.n_heads, cfg.n_kv_heads);
        // artifact outputs are head-major [heads, B, dh]; split per head
        let split = |flat: Vec<i8>| -> Vec<MatI8> {
            flat.chunks(BLOCK * dh).map(|c| MatI8::from_vec(BLOCK, dh, c.to_vec())).collect()
        };
        let mut chunks = Vec::with_capacity(n);
        for ci in 0..n {
            let x = &hidden.data[ci * BLOCK * d..(ci + 1) * BLOCK * d];
            let lw = &self.weights.layers[li];
            let exe = self
                .rt
                .as_mut()
                .ok_or_else(|| anyhow!("artifact backend requested but the engine is native-only"))?
                .get(cfg.name, "qkv_chunk")?;
            let out = exe.run(&[
                Arg::F32(x, &[BLOCK, d]),
                Arg::F32(&lw.g_attn, &[d]),
                Arg::I8(&lw.wq.q.data, &[d, hq * dh]),
                Arg::ScalarF32(lw.wq.scale),
                Arg::I8(&lw.wk.q.data, &[d, hk * dh]),
                Arg::ScalarF32(lw.wk.scale),
                Arg::I8(&lw.wv.q.data, &[d, hk * dh]),
                Arg::ScalarF32(lw.wv.scale),
                Arg::ScalarI32((ci * BLOCK) as i32),
            ])?;
            chunks.push(ChunkQkv {
                q: split(literal_i8(&out[0])?),
                qs: out[1].get_first_element::<f32>()?,
                k: split(literal_i8(&out[2])?),
                ks: out[3].get_first_element::<f32>()?,
                v: split(literal_i8(&out[4])?),
                vs: out[5].get_first_element::<f32>()?,
                qpool: MatF32::from_vec(hq, dh, literal_f32(&out[6])?),
                kpool: MatF32::from_vec(hk, dh, literal_f32(&out[7])?),
            });
        }
        Ok(chunks)
    }

    fn run_sigu_layer(&mut self, chunks: &[ChunkQkv], n: usize) -> Result<Vec<HeadIndex>> {
        let cfg = self.cfg.model.clone();
        let dh = cfg.d_head;
        let params = match &self.cfg.flex {
            Some(p) => *p,
            None => return Ok(fwd::dense_indices(cfg.n_heads, n)),
        };
        if self.cfg.native_sigu {
            // the reference's parallel per-head jobs, over the same chunks
            return Ok(fwd::sigu_indices(&self.ctx, &cfg, chunks, n, &params));
        }
        let mut out = Vec::with_capacity(cfg.n_heads);
        for h in 0..cfg.n_heads {
            let g = h / cfg.group_size();
            let (vertical, slash, a_hat) = self.sigu_via_artifacts(chunks, h, g, n)?;
            // pooled estimate + decision inputs
            let kpool = MatF32::from_fn(n, dh, |b, c| chunks[b].kpool.at(g, c));
            let qpool_all = MatF32::from_fn(n, dh, |b, c| chunks[b].qpool.at(h, c));
            let qpool_hat: Vec<f32> = qpool_all.row(n - 1).to_vec();
            let a_bar = scores::pooled_estimate(&qpool_hat, &kpool);
            let stats = HeadStats { vertical, slash, a_bar, a_hat, qpool_all, kpool };
            out.push(generate_head_index(&stats, &params));
        }
        Ok(out)
    }

    fn sigu_via_artifacts(
        &mut self,
        chunks: &[ChunkQkv],
        h: usize,
        g: usize,
        n: usize,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let cfg = self.cfg.model.clone();
        let dh = cfg.d_head;
        let qs = chunks[n - 1].qs;
        let qhat = chunks[n - 1].q[h].data.clone();
        let mut m = vec![-1e30f32; BLOCK];
        let mut l = vec![0.0f32; BLOCK];
        for b in 0..n {
            let exe = self.runtime()?.get(cfg.name, "index_phase_a")?;
            let out = exe.run(&[
                Arg::I8(&qhat, &[BLOCK, dh]),
                Arg::ScalarF32(qs),
                Arg::I8(&chunks[b].k[g].data, &[BLOCK, dh]),
                Arg::ScalarF32(chunks[b].ks),
                Arg::F32(&m, &[BLOCK]),
                Arg::F32(&l, &[BLOCK]),
            ])?;
            m = literal_f32(&out[0])?;
            l = literal_f32(&out[1])?;
        }
        let mut vertical = vec![0.0f32; n];
        let mut slash = vec![0.0f32; n];
        for b in 0..n {
            let exe = self.runtime()?.get(cfg.name, "index_phase_b")?;
            let out = exe.run(&[
                Arg::I8(&qhat, &[BLOCK, dh]),
                Arg::ScalarF32(qs),
                Arg::I8(&chunks[b].k[g].data, &[BLOCK, dh]),
                Arg::ScalarF32(chunks[b].ks),
                Arg::F32(&m, &[BLOCK]),
                Arg::F32(&l, &[BLOCK]),
            ])?;
            let stats = literal_f32(&out[0])?;
            vertical[b] = stats[0];
            slash[n - 1 - b] += stats[1];
            if b + 2 <= n {
                slash[n - 2 - b] += stats[2];
            }
        }
        let a_hat: Vec<f32> = vertical.iter().map(|v| v / BLOCK as f32).collect();
        Ok((vertical, slash, a_hat))
    }

    /// Block-major SAU over the wave schedule; returns per-chunk attention
    /// outputs [n][B * H*dh].
    ///
    /// The cache-traffic walk always runs sequentially in schedule order
    /// (deterministic stats, identical for both backends); the arithmetic
    /// then runs natively in parallel or through batched artifact calls.
    fn run_sau_layer(
        &mut self,
        chunks: &[ChunkQkv],
        schedule: &Schedule,
        cache: &mut LivenessCache,
        n: usize,
    ) -> Result<Vec<Vec<f32>>> {
        // fetch-or-hit; the functional path always has the data in host
        // memory — the cache records the *traffic* outcome.
        for wave in &schedule.waves {
            for bj in &wave.blocks {
                let key = cache_key(bj.kv_head, bj.block);
                if matches!(cache.lookup(key), Access::Miss) {
                    cache.admit(key);
                }
                for _ in &bj.jobs {
                    cache.consume(key);
                }
            }
        }
        if self.cfg.native_sau {
            // the reference's parallel wave execution over this engine's
            // schedule (waves sized by cfg.wave_qblocks)
            let attn = fwd::sau_layer(&self.ctx, &self.cfg.model, chunks, schedule, n);
            Ok(attn.into_iter().map(|m| m.data).collect())
        } else {
            self.sau_pjrt(chunks, schedule, n)
        }
    }

    /// PJRT SAU: batched artifact calls over the block-major job lists.
    fn sau_pjrt(
        &mut self,
        chunks: &[ChunkQkv],
        schedule: &Schedule,
        n: usize,
    ) -> Result<Vec<Vec<f32>>> {
        let cfg = self.cfg.model.clone();
        let (dh, hq) = (cfg.d_head, cfg.n_heads);
        let j_max = self.sau_batch();
        let mut attn: Vec<Vec<f32>> = (0..n).map(|_| vec![0.0f32; BLOCK * hq * dh]).collect();

        for wave in &schedule.waves {
            let wq = (wave.q_end - wave.q_start) as usize;
            // keyed accumulator banks for this wave: (h, q_local)
            let nstates = hq * wq;
            let mut m = vec![-1e30f32; nstates * BLOCK];
            let mut l = vec![0.0f32; nstates * BLOCK];
            let mut acc = vec![0.0f32; nstates * BLOCK * dh];

            for bj in &wave.blocks {
                let g = bj.kv_head as usize;
                let b = bj.block as usize;
                let kblk: &[i8] = &chunks[b].k[g].data;
                let vblk: &[i8] = &chunks[b].v[g].data;
                for group in bj.jobs.chunks(j_max) {
                    self.sau_batch_call(chunks, wave.q_start, wq, group, b, kblk, vblk,
                                        &mut m, &mut l, &mut acc, j_max)?;
                }
            }

            // finalize wave states into the attention output buffer
            for h in 0..hq {
                for ql in 0..wq {
                    let st = h * wq + ql;
                    let qb = wave.q_start as usize + ql;
                    let accm = MatF32::from_vec(
                        BLOCK,
                        dh,
                        acc[st * BLOCK * dh..(st + 1) * BLOCK * dh].to_vec(),
                    );
                    let out = attn_finalize(&l[st * BLOCK..(st + 1) * BLOCK], &accm);
                    for r in 0..BLOCK {
                        attn[qb][r * hq * dh + h * dh..r * hq * dh + (h + 1) * dh]
                            .copy_from_slice(out.row(r));
                    }
                }
            }
        }
        Ok(attn)
    }

    /// One padded `attn_block_batch` artifact call over <= J jobs.
    #[allow(clippy::too_many_arguments)]
    fn sau_batch_call(
        &mut self,
        chunks: &[ChunkQkv],
        q_start: u32,
        wq: usize,
        group: &[crate::coordinator::joblist::Job],
        b: usize,
        kblk: &[i8],
        vblk: &[i8],
        m: &mut [f32],
        l: &mut [f32],
        acc: &mut [f32],
        j_max: usize,
    ) -> Result<()> {
        let cfg = self.cfg.model.clone();
        let dh = cfg.d_head;
        let jn = group.len();
        let mut qb_buf = vec![0i8; j_max * BLOCK * dh];
        let mut kb_buf = vec![0i8; j_max * BLOCK * dh];
        let mut vb_buf = vec![0i8; j_max * BLOCK * dh];
        let mut qs_buf = vec![0f32; j_max];
        let mut ks_buf = vec![0f32; j_max];
        let mut vs_buf = vec![0f32; j_max];
        let mut m_buf = vec![-1e30f32; j_max * BLOCK];
        let mut l_buf = vec![0f32; j_max * BLOCK];
        let mut acc_buf = vec![0f32; j_max * BLOCK * dh];
        let mut diag_buf = vec![0f32; j_max];
        for (j, job) in group.iter().enumerate() {
            let st = job.head as usize * wq + (job.qblock - q_start) as usize;
            qb_buf[j * BLOCK * dh..(j + 1) * BLOCK * dh]
                .copy_from_slice(&chunks[job.qblock as usize].q[job.head as usize].data);
            kb_buf[j * BLOCK * dh..(j + 1) * BLOCK * dh].copy_from_slice(kblk);
            vb_buf[j * BLOCK * dh..(j + 1) * BLOCK * dh].copy_from_slice(vblk);
            qs_buf[j] = chunks[job.qblock as usize].qs;
            ks_buf[j] = chunks[b].ks;
            vs_buf[j] = chunks[b].vs;
            m_buf[j * BLOCK..(j + 1) * BLOCK].copy_from_slice(&m[st * BLOCK..(st + 1) * BLOCK]);
            l_buf[j * BLOCK..(j + 1) * BLOCK].copy_from_slice(&l[st * BLOCK..(st + 1) * BLOCK]);
            acc_buf[j * BLOCK * dh..(j + 1) * BLOCK * dh]
                .copy_from_slice(&acc[st * BLOCK * dh..(st + 1) * BLOCK * dh]);
            diag_buf[j] = if b == job.qblock as usize { 1.0 } else { 0.0 };
        }
        let exe = self.runtime()?.get(cfg.name, "attn_block_batch")?;
        let out = exe.run(&[
            Arg::I8(&qb_buf, &[j_max, BLOCK, dh]),
            Arg::F32(&qs_buf, &[j_max]),
            Arg::I8(&kb_buf, &[j_max, BLOCK, dh]),
            Arg::F32(&ks_buf, &[j_max]),
            Arg::I8(&vb_buf, &[j_max, BLOCK, dh]),
            Arg::F32(&vs_buf, &[j_max]),
            Arg::F32(&m_buf, &[j_max, BLOCK]),
            Arg::F32(&l_buf, &[j_max, BLOCK]),
            Arg::F32(&acc_buf, &[j_max, BLOCK, dh]),
            Arg::F32(&diag_buf, &[j_max]),
        ])?;
        let m_out = literal_f32(&out[0])?;
        let l_out = literal_f32(&out[1])?;
        let acc_out = literal_f32(&out[2])?;
        for (j, job) in group.iter().enumerate().take(jn) {
            let st = job.head as usize * wq + (job.qblock - q_start) as usize;
            m[st * BLOCK..(st + 1) * BLOCK].copy_from_slice(&m_out[j * BLOCK..(j + 1) * BLOCK]);
            l[st * BLOCK..(st + 1) * BLOCK].copy_from_slice(&l_out[j * BLOCK..(j + 1) * BLOCK]);
            acc[st * BLOCK * dh..(st + 1) * BLOCK * dh]
                .copy_from_slice(&acc_out[j * BLOCK * dh..(j + 1) * BLOCK * dh]);
        }
        Ok(())
    }

    /// Phase 4 (o_proj + residual + FFN + residual) for every chunk.
    fn run_tail_layer(
        &mut self,
        li: usize,
        hidden: &mut MatF32,
        attn: &[Vec<f32>],
        n: usize,
    ) -> Result<()> {
        let cfg = self.cfg.model.clone();
        let (d, dh, hq) = (cfg.d_model, cfg.d_head, cfg.n_heads);
        if self.cfg.native_linear {
            let weights = &self.weights;
            let ctx = &self.ctx;
            let hidden_ref = &*hidden;
            let new_chunks: Vec<MatF32> = ctx.pool.map(n, |ci| {
                let a = MatF32 {
                    rows: BLOCK,
                    cols: hq * dh,
                    data: attn[ci].clone(),
                };
                let x = hidden_ref.slice_rows(ci * BLOCK, (ci + 1) * BLOCK);
                fwd::oproj_ffn_chunk(ctx, weights, li, &a, &x)
            });
            for (ci, x) in new_chunks.into_iter().enumerate() {
                hidden.data[ci * BLOCK * d..(ci + 1) * BLOCK * d].copy_from_slice(&x.data);
            }
            return Ok(());
        }
        for ci in 0..n {
            let resid: Vec<f32> = hidden.data[ci * BLOCK * d..(ci + 1) * BLOCK * d].to_vec();
            let lw = &self.weights.layers[li];
            let exe = self
                .rt
                .as_mut()
                .ok_or_else(|| anyhow!("artifact backend requested but the engine is native-only"))?
                .get(cfg.name, "o_proj_chunk")?;
            let out = exe.run(&[
                Arg::F32(&attn[ci], &[BLOCK, hq * dh]),
                Arg::I8(&lw.wo.q.data, &[hq * dh, d]),
                Arg::ScalarF32(lw.wo.scale),
                Arg::F32(&resid, &[BLOCK, d]),
            ])?;
            let x = literal_f32(&out[0])?;
            let exe = self
                .rt
                .as_mut()
                .ok_or_else(|| anyhow!("artifact backend requested but the engine is native-only"))?
                .get(cfg.name, "ffn_chunk")?;
            let out = exe.run(&[
                Arg::F32(&x, &[BLOCK, d]),
                Arg::F32(&lw.g_ffn, &[d]),
                Arg::I8(&lw.wg.q.data, &[d, cfg.d_ffn]),
                Arg::ScalarF32(lw.wg.scale),
                Arg::I8(&lw.wu.q.data, &[d, cfg.d_ffn]),
                Arg::ScalarF32(lw.wu.scale),
                Arg::I8(&lw.wd.q.data, &[cfg.d_ffn, d]),
                Arg::ScalarF32(lw.wd.scale),
            ])?;
            let x = literal_f32(&out[0])?;
            hidden.data[ci * BLOCK * d..(ci + 1) * BLOCK * d].copy_from_slice(&x);
        }
        Ok(())
    }

    /// Final norm + LM head over the last chunk.
    fn run_logits(&mut self, last: &[f32]) -> Result<Vec<f32>> {
        let cfg = self.cfg.model.clone();
        let d = cfg.d_model;
        if self.cfg.native_linear {
            let last_m = MatF32 { rows: BLOCK, cols: d, data: last.to_vec() };
            return Ok(fwd::logits_last_chunk(&self.ctx, &self.weights, &last_m).data);
        }
        let weights = &self.weights;
        let exe = self
            .rt
            .as_mut()
            .ok_or_else(|| anyhow!("artifact backend requested but the engine is native-only"))?
            .get(cfg.name, "logits_chunk")?;
        let out = exe.run(&[
            Arg::F32(last, &[BLOCK, d]),
            Arg::F32(&weights.g_final, &[d]),
            Arg::I8(&weights.lm_head.q.data, &[d, cfg.vocab]),
            Arg::ScalarF32(weights.lm_head.scale),
        ])?;
        literal_f32(&out[0])
    }
}
