//! L3 coordinator: the paper's system pipeline in Rust.
//!
//!  * [`joblist`] — block-major SAU scheduling (bucketization, waves,
//!    remaining-use counters) — paper §IV-C — plus the batch axis that
//!    merges co-resident requests' waves into one sweep.
//!  * [`engine`]  — chunked prefill (artifacts or native kernels): KV
//!    generation, SIGU, cached SAU, FFN, first token — paper Fig. 2 —
//!    exposed both monolithically and as resumable per-layer phases.
//!  * [`walk`]    — the schedule-execution **memory spine**: the one
//!    canonical walk of a (solo or batch-merged) schedule through the
//!    liveness cache, consumed by both the engine and the cycle simulator.
//!  * [`prefix`]  — content-hashed cross-request prefix KV store: completed
//!    prefills publish their leading blocks' per-layer KV; later requests
//!    with hash-matching leading tokens resume mid-trace at the first
//!    novel block, bit-identical to a cold run, with reuse priced through
//!    the memory spine as seeded cache residency.
//!  * [`server`]  — request router + phase-pipelined multi-worker serving
//!    loop over one shared thread budget (serial baseline included),
//!    driving each request through the unified lifecycle
//!    `Queued -> Prefilling{chunk} -> Decoding{step} -> Done`: prefill
//!    runs as schedulable token slices (chunked prefill) and decode
//!    continues as phase-sized per-token steps co-scheduled between
//!    prefill chunks (continuous batching).
//!  * [`cluster`] — sharded multi-replica serving: N replica servers
//!    (each its own worker pool share + prefix store) over one shared
//!    weight instance, behind a deterministic cost-model router
//!    (`RoundRobin`/`LeastLoaded`/`CostModel`) whose placements are a
//!    replayable pure function of the submission stream.

pub mod cluster;
pub mod engine;
pub mod joblist;
pub mod prefix;
pub mod server;
pub mod walk;

pub use cluster::{Cluster, ClusterRun, Placement, Router, RouterPolicy};
pub use engine::{
    phase_hint_slot, DecodeState, Engine, EngineConfig, Phase, PrefillArgs, PrefillRun,
    PrefillState,
};
pub use joblist::{
    build_schedule, build_schedule_batch, cache_key, BatchBlockJobs, BatchJob, BatchSchedule,
    BatchWave, BlockJobs, Job, KvLayout, Schedule, Wave, DEFAULT_WAVE_QBLOCKS,
};
pub use prefix::{seed_prefix, EvictPolicy, PrefixConfig, PrefixHit, PrefixStats, PrefixStore};
pub use server::{
    Completion, Lifecycle, Policy, Server, ServerOptions, ServerOptionsBuilder,
    DEFAULT_MAX_YIELDS,
};
pub use walk::{
    k_block_bytes, kv_token_bytes, BlockOutcome, BlockVisit, DecodeStepTraffic, DecodeStepWalk,
    IndexGenPricing, IndexGenVisit, IndexGenWalk, LaneVisit, ScheduleWalk,
};
