//! L3 coordinator: the paper's system pipeline in Rust.
//!
//!  * [`joblist`] — block-major SAU scheduling (bucketization, waves,
//!    remaining-use counters) — paper §IV-C.
//!  * [`engine`]  — chunked prefill over the AOT artifacts: KV generation,
//!    SIGU, cached SAU, FFN, first token — paper Fig. 2.
//!  * [`server`]  — request router + multi-worker serving loop.

pub mod engine;
pub mod joblist;
pub mod server;

pub use engine::{Engine, EngineConfig, PrefillRun};
pub use joblist::{build_schedule, cache_key, BlockJobs, Job, Schedule, Wave, DEFAULT_WAVE_QBLOCKS};
pub use server::{Completion, Policy, Server};
