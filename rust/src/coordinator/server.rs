//! Request router + serving loop (std threads; tokio is unavailable
//! offline).
//!
//! The paper serves batch-size-1 prefill; the router's job is admission,
//! ordering and dispatch across worker engines. Policies: FCFS and
//! shortest-job-first (by context length — prefill cost is superlinear in
//! context, so SJF cuts mean TTFT under contention; the serving example
//! reports both).

use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::engine::{Engine, EngineConfig, PrefillRun};
use crate::workload::prompts::TraceRequest;

/// Queueing policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    Fcfs,
    /// Shortest (context) job first.
    Sjf,
}

/// A completed request with serving-side timing.
#[derive(Clone, Debug)]
pub struct Completion {
    pub request_id: u64,
    pub run: PrefillRun,
    /// Queue wait (us) before an engine picked the request up.
    pub queue_us: f64,
    /// End-to-end latency including queueing (us).
    pub e2e_us: f64,
}

/// The admission queue shared between router and workers.
struct Shared {
    queue: VecDeque<(TraceRequest, Instant)>,
    closed: bool,
    policy: Policy,
}

/// Multi-worker prefill server. Each worker owns an [`Engine`] (PJRT
/// clients are not shared across threads).
pub struct Server {
    shared: Arc<Mutex<Shared>>,
    workers: Vec<std::thread::JoinHandle<Result<()>>>,
    results_rx: Receiver<Completion>,
}

impl Server {
    /// Spawn `n_workers` engines over the same artifacts/config.
    pub fn start(
        artifact_dir: std::path::PathBuf,
        cfg: EngineConfig,
        n_workers: usize,
        policy: Policy,
    ) -> Result<Server> {
        let shared = Arc::new(Mutex::new(Shared { queue: VecDeque::new(), closed: false, policy }));
        let (tx, rx): (Sender<Completion>, Receiver<Completion>) = channel();
        let mut workers = Vec::new();
        for _ in 0..n_workers.max(1) {
            let shared = Arc::clone(&shared);
            let tx = tx.clone();
            let dir = artifact_dir.clone();
            let cfg = cfg.clone();
            workers.push(std::thread::spawn(move || -> Result<()> {
                let mut engine = Engine::new(&dir, cfg)?;
                loop {
                    let item = {
                        let mut s = shared.lock().unwrap();
                        match next_item(&mut s) {
                            Some(it) => it,
                            None if s.closed => return Ok(()),
                            None => {
                                drop(s);
                                std::thread::sleep(std::time::Duration::from_micros(200));
                                continue;
                            }
                        }
                    };
                    let (req, enqueued_at) = item;
                    let queue_us = enqueued_at.elapsed().as_micros() as f64;
                    let tokens = req.spec.generate();
                    let run = engine.prefill(req.id, &tokens)?;
                    let e2e_us = queue_us + run.metrics.ttft_us;
                    let _ = tx.send(Completion { request_id: req.id, run, queue_us, e2e_us });
                }
            }));
        }
        drop(tx);
        Ok(Server { shared, workers, results_rx: rx })
    }

    /// Enqueue a request (non-blocking).
    pub fn submit(&self, req: TraceRequest) {
        let mut s = self.shared.lock().unwrap();
        s.queue.push_back((req, Instant::now()));
    }

    /// Close the queue and collect all completions.
    pub fn drain(self) -> Result<Vec<Completion>> {
        {
            let mut s = self.shared.lock().unwrap();
            s.closed = true;
        }
        let mut out = Vec::new();
        for c in self.results_rx.iter() {
            out.push(c);
        }
        for w in self.workers {
            w.join().expect("worker panicked")?;
        }
        out.sort_by_key(|c| c.request_id);
        Ok(out)
    }
}

fn next_item(s: &mut Shared) -> Option<(TraceRequest, Instant)> {
    if s.queue.is_empty() {
        return None;
    }
    let idx = match s.policy {
        Policy::Fcfs => 0,
        Policy::Sjf => s
            .queue
            .iter()
            .enumerate()
            .min_by_key(|(_, (r, _))| r.spec.tokens)
            .map(|(i, _)| i)
            .unwrap_or(0),
    };
    s.queue.remove(idx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::prompts::{PromptKind, PromptSpec};

    fn req(id: u64, tokens: usize) -> TraceRequest {
        TraceRequest {
            id,
            spec: PromptSpec { kind: PromptKind::Random, tokens, seed: id },
            arrival_us: 0,
        }
    }

    #[test]
    fn sjf_picks_shortest() {
        let mut s = Shared {
            queue: VecDeque::new(),
            closed: false,
            policy: Policy::Sjf,
        };
        s.queue.push_back((req(1, 4096), Instant::now()));
        s.queue.push_back((req(2, 1024), Instant::now()));
        s.queue.push_back((req(3, 2048), Instant::now()));
        let (r, _) = next_item(&mut s).unwrap();
        assert_eq!(r.id, 2);
    }

    #[test]
    fn fcfs_preserves_order() {
        let mut s = Shared {
            queue: VecDeque::new(),
            closed: false,
            policy: Policy::Fcfs,
        };
        s.queue.push_back((req(1, 4096), Instant::now()));
        s.queue.push_back((req(2, 1024), Instant::now()));
        let (r, _) = next_item(&mut s).unwrap();
        assert_eq!(r.id, 1);
    }

    #[test]
    fn empty_queue_returns_none() {
        let mut s = Shared {
            queue: VecDeque::new(),
            closed: false,
            policy: Policy::Fcfs,
        };
        assert!(next_item(&mut s).is_none());
    }
}
